"""Replicated serving-tier benchmark: tail latency + saturation under
injected faults (DESIGN.md §3.10).

Drives open-loop Poisson query traffic at a replicated PDASC serving tier
(``serving.ReplicaSet`` behind the retry/hedge/backoff ``Router``) and
records, into ``BENCH_serve.json``:

  * saturation QPS (closed-loop, all workers pinned) per scenario,
  * open-loop p50/p99/p999 latency at ~0.6x saturation,
  * caller-visible errors (the acceptance bar: ZERO, faulted or not),
  * router activity: retries, hedges, degraded serves, health events.

A third ``telemetry`` scenario (DESIGN.md §3.11) serves a store-backed
``two_stage`` tier with 1-in-4 request tracing and records the full
``repro.obs`` metrics snapshot, a p99 exemplar span tree, and the measured
instrumentation overhead (``--smoke`` asserts non-zero engine/router/store
series, a complete exemplar trace, and overhead ratio >= 0.95).

A fourth ``quality`` scenario (DESIGN.md §3.12) adds shadow recall
sampling, the plan-cost JSONL log and SLO burn alerts on the same tier,
asserting: online recall within +-0.05 of the offline recall over the
same served queries; a non-empty re-loadable cost log; >= 1 SLO burn
alert under an injected wedge and zero fault-free; and instrumented +
shadow-sampled throughput >= 0.93x uninstrumented. The run always leaves
``experiments/serve_metrics.json`` behind for
``python -m repro.obs.report`` (the CI offline-report contract).

Scenarios: ``fault_free``, and ``wedged`` — a deterministic ``FaultPlan``
wedges 1 of 4 replicas mid-run (its batch handler stalls per dispatch).
The router must route around it: hedges rescue the stalled requests,
consecutive failures eject the replica, and once the wedge window passes a
half-open probe readmits it. Asserted here (smoke and full):

  * zero caller-visible errors in every scenario,
  * the faulted run's event log shows ``eject`` AND ``readmit``,
  * (full only) faulted p99 within 3x of fault-free p99.

    PYTHONPATH=src python -m benchmarks.bench_serve [--smoke]
        [--out experiments/serve.json] [--bench-out BENCH_serve.json]

``--smoke`` runs a tiny config (correctness + fault-recovery assertions
only, no saturation sweep) so CI catches serving-tier regressions after
``bench_kernels``, matching the other ``--smoke`` benches.
"""

from __future__ import annotations

import argparse
import json
import os
import threading
import time

import numpy as np

from repro import obs
from repro.obs import names as mnames
from repro.core.index import PDASCIndex
from repro.data import make_dataset
from repro.query import Query, degraded
from repro.serving import FaultPlan, ReplicaSet, Router, RouterConfig

N_REPLICAS = 4


def _build(smoke: bool, seed: int):
    if smoke:
        n, n_queries, gl = 1200, 256, 64
    else:
        n, n_queries, gl = 7800, 512, 256
    data = make_dataset("dense_embed", n=n + n_queries, seed=seed)
    train, test = data[:n], data[n:n + n_queries]
    idx = PDASCIndex.build(train, gl=gl, distance="euclidean",
                           radius_quantile=0.35)
    return idx, test, dict(dataset="dense_embed", n=n, gl=gl,
                           n_queries=n_queries, distance="euclidean")


def _make_tier(idx, query, fault_plan, seed):
    rs = ReplicaSet(
        idx, query, n_replicas=N_REPLICAS, batch_size=8, max_wait_ms=1.0,
        degraded_query=degraded(query), fault_plan=fault_plan,
    )
    router = Router(rs, RouterConfig(
        deadline_s=5.0, max_retries=2, hedge=True, hedge_min_s=0.02,
        eject_failures=2, probe_cooldown_s=0.1, probe_timeout_s=0.25,
        probe_interval_s=0.02, seed=seed,
    ))
    # Warm every replica's engine (they share the jitted executables, but
    # each engine must see one batch so the bench never times a compile).
    warm = [r.submit(r.probe_payload()) for r in rs.replicas]
    for req in warm:
        req.wait(timeout=300)
    return rs, router


def _closed_loop_qps(router, test, *, workers=8, per_worker=40):
    """Saturation throughput: every worker pinned in a search loop."""
    errors = [0] * workers

    def worker(w):
        rng = np.random.default_rng(w)
        for _ in range(per_worker):
            try:
                router.search(test[rng.integers(len(test))])
            except Exception:  # noqa: BLE001 — counted below
                errors[w] += 1

    threads = [threading.Thread(target=worker, args=(w,))
               for w in range(workers)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    elapsed = time.perf_counter() - t0
    return workers * per_worker / elapsed, sum(errors)


def _open_loop(router, test, *, qps: float, n: int, seed: int):
    """Open-loop Poisson arrivals at ``qps``: the dispatcher never waits
    for a response before the next arrival (each request runs its own
    waiter thread — the router's retry/hedge state machine is driven from
    the waiting caller), so queueing delay shows up in the latencies
    instead of silently throttling the offered load."""
    rng = np.random.default_rng(seed)
    order = rng.integers(0, len(test), n)
    gaps = rng.exponential(1.0 / qps, n)
    lats, errors = [], []
    lock = threading.Lock()
    retries = [0]
    hedges = [0]
    degraded_n = [0]

    def fire(i):
        try:
            res = router.search(test[order[i]])
        except Exception as e:  # noqa: BLE001 — the acceptance counter
            with lock:
                errors.append(type(e).__name__)
            return
        with lock:
            lats.append(res.latency_s)
            retries[0] += res.retries
            hedges[0] += int(res.hedged)
            degraded_n[0] += int(res.degraded)

    threads = []
    next_at = time.perf_counter()
    for i in range(n):
        next_at += gaps[i]
        delay = next_at - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
        t = threading.Thread(target=fire, args=(i,))
        t.start()
        threads.append(t)
    for t in threads:
        t.join(timeout=60)
    lat_ms = np.array(lats) * 1e3
    return dict(
        qps_offered=round(qps, 1),
        completed=len(lats),
        errors=len(errors),
        error_kinds=sorted(set(errors)),
        p50_ms=round(float(np.percentile(lat_ms, 50)), 2),
        p99_ms=round(float(np.percentile(lat_ms, 99)), 2),
        p999_ms=round(float(np.percentile(lat_ms, 99.9)), 2),
        retries=retries[0],
        hedges=hedges[0],
        degraded=degraded_n[0],
    )


def _await_recovery(router, test, *, timeout_s: float = 30.0):
    """Keep light traffic flowing until the ejected replica is readmitted
    (probes advance the wedged replica's dispatch window past its end)."""
    t0 = time.time()
    i = 0
    while time.time() - t0 < timeout_s:
        if router.event_counts().get("readmit", 0) > 0:
            return True
        try:
            router.search(test[i % len(test)])
        except Exception:  # noqa: BLE001 — recovery traffic is best-effort
            pass
        i += 1
        time.sleep(0.05)
    return router.event_counts().get("readmit", 0) > 0


def _series_total(snap: dict, name: str) -> float:
    """Sum a metric's value (counters/gauges) or observation count
    (histograms) across every label set in the snapshot."""
    entry = snap.get(name)
    if entry is None:
        return 0.0
    if entry["kind"] == "histogram":
        return float(sum(row["hist"]["count"] for row in entry["series"]))
    return float(sum(row["value"] for row in entry["series"]))


def _closed_loop_seq(router, test, *, n: int, seed: int) -> float:
    """Sequential closed-loop throughput (one caller pinned) — the
    low-variance probe the overhead guard compares on/off with."""
    rng = np.random.default_rng(seed)
    order = rng.integers(0, len(test), n)
    t0 = time.perf_counter()
    for i in order:
        router.search(test[i])
    return n / (time.perf_counter() - t0)


def telemetry(smoke: bool = False, seed: int = 0):
    """Telemetry scenario (DESIGN.md §3.11): a store-backed two_stage tier
    behind the router with deterministic 1-in-4 request tracing. Records
    the full ``obs.snapshot()``, a p99 exemplar trace, and the measured
    instrumentation overhead (same tier, registry disabled vs enabled,
    best-of-k alternating trials) into the bench payload. The smoke
    assertions here are the CI contract: non-zero engine/router/store
    series, a valid exemplar span tree, bounded overhead.
    """
    # Reset BEFORE building the tier: engines/routers pre-bind their series
    # handles at construction, and a reset would orphan existing handles.
    obs.reset()
    if smoke:
        n, gl, n_queries, n_probe, trials = 1500, 64, 160, 96, 3
    else:
        n, gl, n_queries, n_probe, trials = 6000, 256, 400, 200, 3
    data = make_dataset("dense_embed", n=n + 64, seed=seed)
    train, test = data[:n], data[n:]
    idx = PDASCIndex.build(train, gl=gl, distance="euclidean",
                           radius_quantile=0.35, store="int8",
                           store_block=128)
    idx.release_dense_payload()  # serve from the tiered store, not the seed
    query = Query(k=10, execution="two_stage", beam=32, rerank_width=64,
                  with_stats=False)
    rs = ReplicaSet(idx, query, n_replicas=2, batch_size=8, max_wait_ms=1.0)
    router = Router(rs, RouterConfig(deadline_s=30.0, seed=seed,
                                     trace_every=4))
    try:
        warm = [r.submit(test[0]) for r in rs.replicas]
        for req in warm:
            req.wait(timeout=300)

        # Overhead guard: alternate disabled/enabled trials over the same
        # tier and compare best-of throughput. Tracing is suspended for
        # both legs (its per-sampled-request block_until_ready is a
        # *measurement* cost the guard is not about); the enabled leg pays
        # every counter/gauge/histogram update on the full request path.
        every_n, router._sampler.every_n = router._sampler.every_n, 0
        qps_off, qps_on = [], []
        for t in range(trials):
            obs.set_enabled(False)
            qps_off.append(_closed_loop_seq(router, test, n=n_probe,
                                            seed=seed + 10 + t))
            obs.set_enabled(True)
            qps_on.append(_closed_loop_seq(router, test, n=n_probe,
                                           seed=seed + 10 + t))
        router._sampler.every_n = every_n
        overhead = dict(
            qps_uninstrumented=round(max(qps_off), 1),
            qps_instrumented=round(max(qps_on), 1),
            ratio=round(max(qps_on) / max(qps_off), 3),
            trials=trials, probe_queries=n_probe,
        )

        # Traced traffic: every 4th request records the full span tree
        # (queue -> dispatch -> batch -> scan -> rerank -> granule fetch).
        rng = np.random.default_rng(seed + 1)
        lats = []
        for i in rng.integers(0, len(test), n_queries):
            res = router.search(test[i])
            lats.append(res.latency_s)
        p99_s = float(np.percentile(np.array(lats), 99))
        exemplar = router.traces.exemplar(p99_s)

        snap = obs.snapshot()
        subsystems = sorted({mnames.subsystem(k) for k in snap})
        n_series = sum(len(v["series"]) for v in snap.values())
        row = dict(
            scenario="telemetry",
            config=dict(dataset="dense_embed", n=n, gl=gl,
                        n_queries=n_queries, store="int8",
                        execution="two_stage", n_replicas=2, trace_every=4),
            p99_ms=round(p99_s * 1e3, 2),
            n_series=n_series,
            subsystems=subsystems,
            overhead=overhead,
            key_series={name: _series_total(snap, name) for name in (
                mnames.ENGINE_REQUESTS, mnames.ENGINE_BATCHES,
                mnames.ROUTER_REQUESTS, mnames.ROUTER_LATENCY,
                mnames.PLAN_EXECUTIONS, mnames.STORE_FETCHES,
                mnames.STORE_FETCH_BYTES, mnames.TRACE_FINISHED,
            )},
            exemplar_trace=(exemplar.to_dict() if exemplar else None),
        )
        print(f"[serve] telemetry: {n_series} series across "
              f"{subsystems} p99={row['p99_ms']}ms "
              f"overhead_ratio={overhead['ratio']}", flush=True)

        # -- the CI contract (smoke and full) ------------------------------
        for name in (mnames.ENGINE_REQUESTS, mnames.ROUTER_REQUESTS,
                     mnames.STORE_FETCHES, mnames.PLAN_EXECUTIONS):
            assert _series_total(snap, name) > 0, (
                f"telemetry: series {name} is zero/absent after "
                f"{n_queries} two_stage queries"
            )
        assert n_series >= 25 and len(subsystems) >= 5, (
            f"telemetry: expected >= 25 series over >= 5 subsystems, got "
            f"{n_series} over {subsystems}"
        )
        assert exemplar is not None, "telemetry: no trace was retained"
        span_names = {s.name for s in exemplar.root.walk()}
        for expect in ("attempt", "queue_wait", "execute", "plan", "scan",
                       "rerank", "granule_fetch"):
            assert expect in span_names, (
                f"telemetry: exemplar trace is missing a {expect!r} span "
                f"(got {sorted(span_names)})"
            )
        assert overhead["ratio"] >= 0.95, (
            f"telemetry: instrumented throughput is "
            f"{overhead['ratio']:.3f}x uninstrumented (< 0.95x bound): "
            f"{overhead}"
        )
        return row
    finally:
        router.close(close_replicas=True)


def quality(smoke: bool = False, seed: int = 0,
            costlog_path: str = "experiments/serve_costlog.jsonl"):
    """Quality & SLO scenario (DESIGN.md §3.12): a store-backed two_stage
    tier with shadow recall sampling, a plan-cost log on the traced
    requests, and an SLO tracker with multi-rate burn alerts. The four
    acceptance bars (smoke and full):

      * the online (shadow-sampled) recall estimate lands within +-0.05 of
        the offline recall computed over the same served queries,
      * the cost log is non-empty and loads back with the documented
        schema (v/seq/latency_s/spans + plan features),
      * the SLO tracker fires >= 1 burn alert under an injected wedge and
        ZERO on the fault-free leg,
      * instrumented + shadow-sampled throughput stays >= 0.93x the
        uninstrumented tier (same alternating best-of guard as telemetry).
    """
    obs.reset()  # before building: engines pre-bind series handles
    if smoke:
        n, gl, n_queries, n_probe, trials = 1500, 64, 240, 96, 3
        n_slo, n_wedged = 60, 36
    else:
        n, gl, n_queries, n_probe, trials = 6000, 256, 480, 200, 3
        n_slo, n_wedged = 120, 48
    k = 10
    data = make_dataset("dense_embed", n=n + 64, seed=seed)
    train, test = data[:n], data[n:]
    idx = PDASCIndex.build(train, gl=gl, distance="euclidean",
                           radius_quantile=0.35, store="int8",
                           store_block=128)
    idx.release_dense_payload()
    query = Query(k=k, execution="two_stage", beam=32, rerank_width=64,
                  with_stats=False)
    rs = ReplicaSet(idx, query, n_replicas=2, batch_size=8, max_wait_ms=1.0)
    os.makedirs(os.path.dirname(costlog_path) or ".", exist_ok=True)
    if os.path.exists(costlog_path):
        os.remove(costlog_path)
    from repro.obs import costlog as costlog_lib

    costlog = obs.CostLog(costlog_path)
    router = Router(rs, RouterConfig(deadline_s=30.0, seed=seed,
                                     trace_every=4, shadow_every=4),
                    costlog=costlog)
    est = router.quality
    try:
        warm = [r.submit(test[0]) for r in rs.replicas]
        for req in warm:
            req.wait(timeout=300)
        # Warm the shadow path too (reference read + exact-kNN compile on
        # the worker) so the overhead guard never times a compile.
        for i in range(4):
            router.search(test[i])
        assert est.drain(timeout=120), "quality: shadow warmup never drained"

        # -- (d) overhead guard: uninstrumented vs instrumented+shadowed --
        every_n, router._sampler.every_n = router._sampler.every_n, 0
        qps_off, qps_on = [], []
        for t in range(trials):
            obs.set_enabled(False)
            est.every_n = 0
            qps_off.append(_closed_loop_seq(router, test, n=n_probe,
                                            seed=seed + 10 + t))
            obs.set_enabled(True)
            est.every_n = 4
            qps_on.append(_closed_loop_seq(router, test, n=n_probe,
                                           seed=seed + 10 + t))
        router._sampler.every_n = every_n
        est.drain(timeout=120)
        overhead = dict(
            qps_uninstrumented=round(max(qps_off), 1),
            qps_instrumented=round(max(qps_on), 1),
            ratio=round(max(qps_on) / max(qps_off), 3),
            trials=trials, probe_queries=n_probe, shadow_every=4,
        )

        # -- (a) measured pass: online estimate vs offline ground truth ---
        est.reset_stats()
        rng = np.random.default_rng(seed + 1)
        rows_served = []  # (test row, served ids) for EVERY query
        lats = []
        for i in rng.integers(0, len(test), n_queries):
            res = router.search(test[int(i)])
            rows_served.append((int(i), np.asarray(res.ids).reshape(-1)))
            lats.append(res.latency_s)
        assert est.drain(timeout=120), "quality: shadow queue never drained"
        online = est.estimate()
        from repro.baselines.exact import exact_knn

        q_rows = np.array([r for r, _ in rows_served])
        _, gt = exact_knn(test[q_rows], train, distance="euclidean", k=k)
        gt = np.asarray(gt)
        offline = float(np.mean([
            len(set(int(x) for x in served if x >= 0)
                & set(int(x) for x in gt[j])) / k
            for j, (_, served) in enumerate(rows_served)
        ]))

        # -- (b) the cost log loads back with the documented schema -------
        costlog.close()
        recs = costlog_lib.load(costlog_path)

        # -- (c) SLO: zero alerts fault-free, >= 1 under a wedge ----------
        p99_s = float(np.percentile(np.array(lats), 99))
        target_s = max(5.0 * p99_s, 0.25)
        spec = obs.SLOSpec(latency_p99_s=target_s, window_s=8.0,
                           fast_window_frac=0.25, min_samples=4)
        slo_ff = obs.SLOTracker(spec)
        router.slo = slo_ff  # hooks pick the tracker up per request
        for i in rng.integers(0, len(test), n_slo):
            router.search(test[int(i)])
            slo_ff.evaluate()
    finally:
        router.close(close_replicas=True)

    # Wedged leg: 1 of 2 replicas stalls 0.8s per dispatch mid-window —
    # far past the derived latency target. Hedging is off so the stalls
    # stay caller-visible as latency (not rescued), which is exactly what
    # the burn alert must catch.
    wedge_plan = f"wedge:r1@6+{n_wedged // 3}:0.8"
    rs2 = ReplicaSet(idx, query, n_replicas=2, batch_size=8,
                     max_wait_ms=1.0, degraded_query=degraded(query),
                     fault_plan=FaultPlan.parse(wedge_plan))
    slo_wedged = obs.SLOTracker(spec)
    router2 = Router(rs2, RouterConfig(deadline_s=30.0, hedge=False,
                                       seed=seed),
                     slo=slo_wedged)
    try:
        warm = [r.submit(test[0]) for r in rs2.replicas]
        for req in warm:
            req.wait(timeout=300)
        rng2 = np.random.default_rng(seed + 2)
        for i in rng2.integers(0, len(test), n_wedged):
            router2.search(test[int(i)])
            slo_wedged.evaluate()
    finally:
        router2.close(close_replicas=True)

    row = dict(
        scenario="quality",
        config=dict(dataset="dense_embed", n=n, gl=gl,
                    n_queries=n_queries, store="int8",
                    execution="two_stage", n_replicas=2,
                    trace_every=4, shadow_every=4, k=k),
        online_recall=round(online["recall"], 4),
        online_wilson=[round(online["wilson_lo"], 4),
                       round(online["wilson_hi"], 4)],
        shadow_samples=online["queries"],
        offline_recall=round(offline, 4),
        recall_gap=round(abs(online["recall"] - offline), 4),
        cost_records=len(recs),
        costlog_path=costlog_path,
        slo=dict(latency_target_ms=round(target_s * 1e3, 1),
                 fault_free_alerts=sum(slo_ff.alert_counts().values()),
                 wedged_alerts=sum(slo_wedged.alert_counts().values()),
                 wedged_events=slo_wedged.events()[:8],
                 faults=wedge_plan),
        overhead=overhead,
    )
    print(f"[serve] quality: online={row['online_recall']} "
          f"offline={row['offline_recall']} gap={row['recall_gap']} "
          f"({row['shadow_samples']} shadow samples) "
          f"cost_records={row['cost_records']} "
          f"slo_alerts=ff:{row['slo']['fault_free_alerts']}/"
          f"wedged:{row['slo']['wedged_alerts']} "
          f"overhead_ratio={overhead['ratio']}", flush=True)

    # -- the CI contract (smoke and full) ---------------------------------
    assert online["recall"] is not None and online["queries"] >= 30, (
        f"quality: too few shadow samples answered: {online}"
    )
    assert abs(online["recall"] - offline) <= 0.05, (
        f"quality: online estimate {online['recall']:.3f} vs offline "
        f"{offline:.3f} over the same served queries (gap > 0.05)"
    )
    assert len(recs) > 0, "quality: the cost log is empty"
    for key in ("v", "seq", "latency_s", "spans", "pipeline",
                "effective_pipeline", "query", "index", "counts"):
        assert key in recs[0], (
            f"quality: cost record is missing {key!r}: {sorted(recs[0])}"
        )
    assert recs[0]["pipeline"] == "two_stage" and \
        recs[0]["index"]["store"] == "int8" and \
        "code_format" in recs[0]["index"], (
            f"quality: cost record carries the wrong plan features: "
            f"{recs[0]}"
        )
    assert sum(slo_ff.alert_counts().values()) == 0, (
        f"quality: SLO burn alert fired on the fault-free leg: "
        f"{slo_ff.events()}"
    )
    assert sum(slo_wedged.alert_counts().values()) >= 1, (
        f"quality: no SLO burn alert under {wedge_plan}: "
        f"{slo_wedged.status()}"
    )
    assert overhead["ratio"] >= 0.93, (
        f"quality: instrumented+shadowed throughput is "
        f"{overhead['ratio']:.3f}x uninstrumented (< 0.93x bound): "
        f"{overhead}"
    )
    return row


def run(smoke: bool = False, seed: int = 0):
    idx, test, cfg = _build(smoke, seed)
    query = Query(k=10, execution="beam", beam=32, with_stats=False)
    n_open = 200 if smoke else 600
    # The wedge window is in per-replica handler dispatches: it opens a few
    # batches in (mid-run for any sane traffic level) and is short enough
    # that post-ejection probes can cross it to the recovery side.
    wedge = FaultPlan.parse("wedge:r1@6+5:0.5")

    rows = []
    scenarios = [("fault_free", None), ("wedged", wedge)]
    for name, plan in scenarios:
        rs, router = _make_tier(idx, query, plan, seed)
        try:
            if smoke:
                sat_qps, sat_errors = None, 0
                qps = 120.0
            else:
                sat_qps, sat_errors = _closed_loop_qps(router, test)
                qps = 0.6 * sat_qps
            row = _open_loop(router, test, qps=qps, n=n_open, seed=seed + 1)
            recovered = None
            if plan is not None:
                recovered = _await_recovery(router, test)
            events = router.event_counts()
            row.update(
                scenario=name, config=cfg, n_replicas=N_REPLICAS,
                faults=("wedge:r1@6+5:0.5" if plan is not None else None),
                saturation_qps=(round(sat_qps, 1) if sat_qps else None),
                saturation_errors=sat_errors,
                events=events,
            )
            rows.append(row)
            print(f"[serve] {name}: offered={row['qps_offered']}qps "
                  f"p50={row['p50_ms']}ms p99={row['p99_ms']}ms "
                  f"p999={row['p999_ms']}ms errors={row['errors']} "
                  f"retries={row['retries']} hedges={row['hedges']} "
                  f"events={events}", flush=True)
            assert row["errors"] == 0, (
                f"{name}: {row['errors']} caller-visible errors "
                f"({row['error_kinds']}) — the router must absorb faults"
            )
            assert sat_errors == 0, (
                f"{name}: {sat_errors} errors during the saturation sweep"
            )
            if plan is not None:
                assert events.get("eject", 0) >= 1, (
                    f"wedged replica was never ejected: {events}"
                )
                assert recovered, (
                    f"wedged replica was never readmitted: {events}"
                )
        finally:
            router.close(close_replicas=True)

    if not smoke:
        ratio = rows[1]["p99_ms"] / rows[0]["p99_ms"]
        rows[1]["p99_vs_fault_free"] = round(ratio, 2)
        assert ratio <= 3.0, (
            f"faulted p99 {rows[1]['p99_ms']}ms is {ratio:.1f}x the "
            f"fault-free {rows[0]['p99_ms']}ms (> 3x bound)"
        )
    return rows


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="tiny config, fault-recovery assertions only (CI)")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--out", default="experiments/serve.json")
    p.add_argument("--bench-out", default="BENCH_serve.json")
    args = p.parse_args(argv)

    rows = run(smoke=args.smoke, seed=args.seed)
    telemetry_row = telemetry(smoke=args.smoke, seed=args.seed)
    quality_row = quality(smoke=args.smoke, seed=args.seed)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rows + [telemetry_row, quality_row], f, indent=1)
    # Always (smoke included) leave a metrics snapshot on disk: CI feeds it
    # to ``python -m repro.obs.report`` as the offline-report contract.
    metrics_out = os.path.join(os.path.dirname(args.out) or ".",
                               "serve_metrics.json")
    obs.MetricsDumper(obs.registry(), metrics_out, period_s=0).dump()
    print(f"[serve] wrote {metrics_out}")
    if not args.smoke:
        payload = dict(
            bench="replicated_serving_under_faults",
            baseline="fault-free replica pool (same router, no FaultPlan)",
            new="1-of-4 replicas wedged mid-run: hedge/retry routing, "
                "health ejection + half-open readmission, zero "
                "caller-visible errors",
            rows=rows,
            telemetry=telemetry_row,
            quality=quality_row,
        )
        with open(args.bench_out, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"[serve] wrote {args.bench_out}")


if __name__ == "__main__":
    main()
