"""Replicated serving-tier benchmark: tail latency + saturation under
injected faults (DESIGN.md §3.10).

Drives open-loop Poisson query traffic at a replicated PDASC serving tier
(``serving.ReplicaSet`` behind the retry/hedge/backoff ``Router``) and
records, into ``BENCH_serve.json``:

  * saturation QPS (closed-loop, all workers pinned) per scenario,
  * open-loop p50/p99/p999 latency at ~0.6x saturation,
  * caller-visible errors (the acceptance bar: ZERO, faulted or not),
  * router activity: retries, hedges, degraded serves, health events.

A third ``telemetry`` scenario (DESIGN.md §3.11) serves a store-backed
``two_stage`` tier with 1-in-4 request tracing and records the full
``repro.obs`` metrics snapshot, a p99 exemplar span tree, and the measured
instrumentation overhead (``--smoke`` asserts non-zero engine/router/store
series, a complete exemplar trace, and overhead ratio >= 0.95).

Scenarios: ``fault_free``, and ``wedged`` — a deterministic ``FaultPlan``
wedges 1 of 4 replicas mid-run (its batch handler stalls per dispatch).
The router must route around it: hedges rescue the stalled requests,
consecutive failures eject the replica, and once the wedge window passes a
half-open probe readmits it. Asserted here (smoke and full):

  * zero caller-visible errors in every scenario,
  * the faulted run's event log shows ``eject`` AND ``readmit``,
  * (full only) faulted p99 within 3x of fault-free p99.

    PYTHONPATH=src python -m benchmarks.bench_serve [--smoke]
        [--out experiments/serve.json] [--bench-out BENCH_serve.json]

``--smoke`` runs a tiny config (correctness + fault-recovery assertions
only, no saturation sweep) so CI catches serving-tier regressions after
``bench_kernels``, matching the other ``--smoke`` benches.
"""

from __future__ import annotations

import argparse
import json
import os
import threading
import time

import numpy as np

from repro import obs
from repro.obs import names as mnames
from repro.core.index import PDASCIndex
from repro.data import make_dataset
from repro.query import Query, degraded
from repro.serving import FaultPlan, ReplicaSet, Router, RouterConfig

N_REPLICAS = 4


def _build(smoke: bool, seed: int):
    if smoke:
        n, n_queries, gl = 1200, 256, 64
    else:
        n, n_queries, gl = 7800, 512, 256
    data = make_dataset("dense_embed", n=n + n_queries, seed=seed)
    train, test = data[:n], data[n:n + n_queries]
    idx = PDASCIndex.build(train, gl=gl, distance="euclidean",
                           radius_quantile=0.35)
    return idx, test, dict(dataset="dense_embed", n=n, gl=gl,
                           n_queries=n_queries, distance="euclidean")


def _make_tier(idx, query, fault_plan, seed):
    rs = ReplicaSet(
        idx, query, n_replicas=N_REPLICAS, batch_size=8, max_wait_ms=1.0,
        degraded_query=degraded(query), fault_plan=fault_plan,
    )
    router = Router(rs, RouterConfig(
        deadline_s=5.0, max_retries=2, hedge=True, hedge_min_s=0.02,
        eject_failures=2, probe_cooldown_s=0.1, probe_timeout_s=0.25,
        probe_interval_s=0.02, seed=seed,
    ))
    # Warm every replica's engine (they share the jitted executables, but
    # each engine must see one batch so the bench never times a compile).
    warm = [r.submit(r.probe_payload()) for r in rs.replicas]
    for req in warm:
        req.wait(timeout=300)
    return rs, router


def _closed_loop_qps(router, test, *, workers=8, per_worker=40):
    """Saturation throughput: every worker pinned in a search loop."""
    errors = [0] * workers

    def worker(w):
        rng = np.random.default_rng(w)
        for _ in range(per_worker):
            try:
                router.search(test[rng.integers(len(test))])
            except Exception:  # noqa: BLE001 — counted below
                errors[w] += 1

    threads = [threading.Thread(target=worker, args=(w,))
               for w in range(workers)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    elapsed = time.perf_counter() - t0
    return workers * per_worker / elapsed, sum(errors)


def _open_loop(router, test, *, qps: float, n: int, seed: int):
    """Open-loop Poisson arrivals at ``qps``: the dispatcher never waits
    for a response before the next arrival (each request runs its own
    waiter thread — the router's retry/hedge state machine is driven from
    the waiting caller), so queueing delay shows up in the latencies
    instead of silently throttling the offered load."""
    rng = np.random.default_rng(seed)
    order = rng.integers(0, len(test), n)
    gaps = rng.exponential(1.0 / qps, n)
    lats, errors = [], []
    lock = threading.Lock()
    retries = [0]
    hedges = [0]
    degraded_n = [0]

    def fire(i):
        try:
            res = router.search(test[order[i]])
        except Exception as e:  # noqa: BLE001 — the acceptance counter
            with lock:
                errors.append(type(e).__name__)
            return
        with lock:
            lats.append(res.latency_s)
            retries[0] += res.retries
            hedges[0] += int(res.hedged)
            degraded_n[0] += int(res.degraded)

    threads = []
    next_at = time.perf_counter()
    for i in range(n):
        next_at += gaps[i]
        delay = next_at - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
        t = threading.Thread(target=fire, args=(i,))
        t.start()
        threads.append(t)
    for t in threads:
        t.join(timeout=60)
    lat_ms = np.array(lats) * 1e3
    return dict(
        qps_offered=round(qps, 1),
        completed=len(lats),
        errors=len(errors),
        error_kinds=sorted(set(errors)),
        p50_ms=round(float(np.percentile(lat_ms, 50)), 2),
        p99_ms=round(float(np.percentile(lat_ms, 99)), 2),
        p999_ms=round(float(np.percentile(lat_ms, 99.9)), 2),
        retries=retries[0],
        hedges=hedges[0],
        degraded=degraded_n[0],
    )


def _await_recovery(router, test, *, timeout_s: float = 30.0):
    """Keep light traffic flowing until the ejected replica is readmitted
    (probes advance the wedged replica's dispatch window past its end)."""
    t0 = time.time()
    i = 0
    while time.time() - t0 < timeout_s:
        if router.event_counts().get("readmit", 0) > 0:
            return True
        try:
            router.search(test[i % len(test)])
        except Exception:  # noqa: BLE001 — recovery traffic is best-effort
            pass
        i += 1
        time.sleep(0.05)
    return router.event_counts().get("readmit", 0) > 0


def _series_total(snap: dict, name: str) -> float:
    """Sum a metric's value (counters/gauges) or observation count
    (histograms) across every label set in the snapshot."""
    entry = snap.get(name)
    if entry is None:
        return 0.0
    if entry["kind"] == "histogram":
        return float(sum(row["hist"]["count"] for row in entry["series"]))
    return float(sum(row["value"] for row in entry["series"]))


def _closed_loop_seq(router, test, *, n: int, seed: int) -> float:
    """Sequential closed-loop throughput (one caller pinned) — the
    low-variance probe the overhead guard compares on/off with."""
    rng = np.random.default_rng(seed)
    order = rng.integers(0, len(test), n)
    t0 = time.perf_counter()
    for i in order:
        router.search(test[i])
    return n / (time.perf_counter() - t0)


def telemetry(smoke: bool = False, seed: int = 0):
    """Telemetry scenario (DESIGN.md §3.11): a store-backed two_stage tier
    behind the router with deterministic 1-in-4 request tracing. Records
    the full ``obs.snapshot()``, a p99 exemplar trace, and the measured
    instrumentation overhead (same tier, registry disabled vs enabled,
    best-of-k alternating trials) into the bench payload. The smoke
    assertions here are the CI contract: non-zero engine/router/store
    series, a valid exemplar span tree, bounded overhead.
    """
    # Reset BEFORE building the tier: engines/routers pre-bind their series
    # handles at construction, and a reset would orphan existing handles.
    obs.reset()
    if smoke:
        n, gl, n_queries, n_probe, trials = 1500, 64, 160, 96, 3
    else:
        n, gl, n_queries, n_probe, trials = 6000, 256, 400, 200, 3
    data = make_dataset("dense_embed", n=n + 64, seed=seed)
    train, test = data[:n], data[n:]
    idx = PDASCIndex.build(train, gl=gl, distance="euclidean",
                           radius_quantile=0.35, store="int8",
                           store_block=128)
    idx.release_dense_payload()  # serve from the tiered store, not the seed
    query = Query(k=10, execution="two_stage", beam=32, rerank_width=64,
                  with_stats=False)
    rs = ReplicaSet(idx, query, n_replicas=2, batch_size=8, max_wait_ms=1.0)
    router = Router(rs, RouterConfig(deadline_s=30.0, seed=seed,
                                     trace_every=4))
    try:
        warm = [r.submit(test[0]) for r in rs.replicas]
        for req in warm:
            req.wait(timeout=300)

        # Overhead guard: alternate disabled/enabled trials over the same
        # tier and compare best-of throughput. Tracing is suspended for
        # both legs (its per-sampled-request block_until_ready is a
        # *measurement* cost the guard is not about); the enabled leg pays
        # every counter/gauge/histogram update on the full request path.
        every_n, router._sampler.every_n = router._sampler.every_n, 0
        qps_off, qps_on = [], []
        for t in range(trials):
            obs.set_enabled(False)
            qps_off.append(_closed_loop_seq(router, test, n=n_probe,
                                            seed=seed + 10 + t))
            obs.set_enabled(True)
            qps_on.append(_closed_loop_seq(router, test, n=n_probe,
                                           seed=seed + 10 + t))
        router._sampler.every_n = every_n
        overhead = dict(
            qps_uninstrumented=round(max(qps_off), 1),
            qps_instrumented=round(max(qps_on), 1),
            ratio=round(max(qps_on) / max(qps_off), 3),
            trials=trials, probe_queries=n_probe,
        )

        # Traced traffic: every 4th request records the full span tree
        # (queue -> dispatch -> batch -> scan -> rerank -> granule fetch).
        rng = np.random.default_rng(seed + 1)
        lats = []
        for i in rng.integers(0, len(test), n_queries):
            res = router.search(test[i])
            lats.append(res.latency_s)
        p99_s = float(np.percentile(np.array(lats), 99))
        exemplar = router.traces.exemplar(p99_s)

        snap = obs.snapshot()
        subsystems = sorted({mnames.subsystem(k) for k in snap})
        n_series = sum(len(v["series"]) for v in snap.values())
        row = dict(
            scenario="telemetry",
            config=dict(dataset="dense_embed", n=n, gl=gl,
                        n_queries=n_queries, store="int8",
                        execution="two_stage", n_replicas=2, trace_every=4),
            p99_ms=round(p99_s * 1e3, 2),
            n_series=n_series,
            subsystems=subsystems,
            overhead=overhead,
            key_series={name: _series_total(snap, name) for name in (
                mnames.ENGINE_REQUESTS, mnames.ENGINE_BATCHES,
                mnames.ROUTER_REQUESTS, mnames.ROUTER_LATENCY,
                mnames.PLAN_EXECUTIONS, mnames.STORE_FETCHES,
                mnames.STORE_FETCH_BYTES, mnames.TRACE_FINISHED,
            )},
            exemplar_trace=(exemplar.to_dict() if exemplar else None),
        )
        print(f"[serve] telemetry: {n_series} series across "
              f"{subsystems} p99={row['p99_ms']}ms "
              f"overhead_ratio={overhead['ratio']}", flush=True)

        # -- the CI contract (smoke and full) ------------------------------
        for name in (mnames.ENGINE_REQUESTS, mnames.ROUTER_REQUESTS,
                     mnames.STORE_FETCHES, mnames.PLAN_EXECUTIONS):
            assert _series_total(snap, name) > 0, (
                f"telemetry: series {name} is zero/absent after "
                f"{n_queries} two_stage queries"
            )
        assert n_series >= 25 and len(subsystems) >= 5, (
            f"telemetry: expected >= 25 series over >= 5 subsystems, got "
            f"{n_series} over {subsystems}"
        )
        assert exemplar is not None, "telemetry: no trace was retained"
        span_names = {s.name for s in exemplar.root.walk()}
        for expect in ("attempt", "queue_wait", "execute", "plan", "scan",
                       "rerank", "granule_fetch"):
            assert expect in span_names, (
                f"telemetry: exemplar trace is missing a {expect!r} span "
                f"(got {sorted(span_names)})"
            )
        assert overhead["ratio"] >= 0.95, (
            f"telemetry: instrumented throughput is "
            f"{overhead['ratio']:.3f}x uninstrumented (< 0.95x bound): "
            f"{overhead}"
        )
        return row
    finally:
        router.close(close_replicas=True)


def run(smoke: bool = False, seed: int = 0):
    idx, test, cfg = _build(smoke, seed)
    query = Query(k=10, execution="beam", beam=32, with_stats=False)
    n_open = 200 if smoke else 600
    # The wedge window is in per-replica handler dispatches: it opens a few
    # batches in (mid-run for any sane traffic level) and is short enough
    # that post-ejection probes can cross it to the recovery side.
    wedge = FaultPlan.parse("wedge:r1@6+5:0.5")

    rows = []
    scenarios = [("fault_free", None), ("wedged", wedge)]
    for name, plan in scenarios:
        rs, router = _make_tier(idx, query, plan, seed)
        try:
            if smoke:
                sat_qps, sat_errors = None, 0
                qps = 120.0
            else:
                sat_qps, sat_errors = _closed_loop_qps(router, test)
                qps = 0.6 * sat_qps
            row = _open_loop(router, test, qps=qps, n=n_open, seed=seed + 1)
            recovered = None
            if plan is not None:
                recovered = _await_recovery(router, test)
            events = router.event_counts()
            row.update(
                scenario=name, config=cfg, n_replicas=N_REPLICAS,
                faults=("wedge:r1@6+5:0.5" if plan is not None else None),
                saturation_qps=(round(sat_qps, 1) if sat_qps else None),
                saturation_errors=sat_errors,
                events=events,
            )
            rows.append(row)
            print(f"[serve] {name}: offered={row['qps_offered']}qps "
                  f"p50={row['p50_ms']}ms p99={row['p99_ms']}ms "
                  f"p999={row['p999_ms']}ms errors={row['errors']} "
                  f"retries={row['retries']} hedges={row['hedges']} "
                  f"events={events}", flush=True)
            assert row["errors"] == 0, (
                f"{name}: {row['errors']} caller-visible errors "
                f"({row['error_kinds']}) — the router must absorb faults"
            )
            assert sat_errors == 0, (
                f"{name}: {sat_errors} errors during the saturation sweep"
            )
            if plan is not None:
                assert events.get("eject", 0) >= 1, (
                    f"wedged replica was never ejected: {events}"
                )
                assert recovered, (
                    f"wedged replica was never readmitted: {events}"
                )
        finally:
            router.close(close_replicas=True)

    if not smoke:
        ratio = rows[1]["p99_ms"] / rows[0]["p99_ms"]
        rows[1]["p99_vs_fault_free"] = round(ratio, 2)
        assert ratio <= 3.0, (
            f"faulted p99 {rows[1]['p99_ms']}ms is {ratio:.1f}x the "
            f"fault-free {rows[0]['p99_ms']}ms (> 3x bound)"
        )
    return rows


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="tiny config, fault-recovery assertions only (CI)")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--out", default="experiments/serve.json")
    p.add_argument("--bench-out", default="BENCH_serve.json")
    args = p.parse_args(argv)

    rows = run(smoke=args.smoke, seed=args.seed)
    telemetry_row = telemetry(smoke=args.smoke, seed=args.seed)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rows + [telemetry_row], f, indent=1)
    if not args.smoke:
        payload = dict(
            bench="replicated_serving_under_faults",
            baseline="fault-free replica pool (same router, no FaultPlan)",
            new="1-of-4 replicas wedged mid-run: hedge/retry routing, "
                "health ejection + half-open readmission, zero "
                "caller-visible errors",
            rows=rows,
            telemetry=telemetry_row,
        )
        with open(args.bench_out, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"[serve] wrote {args.bench_out}")


if __name__ == "__main__":
    main()
