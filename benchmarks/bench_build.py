"""Paper §3.1 (Fig. 2): index construction behaviour — level structure,
per-level TD, outlier promotion, build time vs gl, k-medoids vs k-means."""

from __future__ import annotations

import time

import numpy as np

from repro.core.index import PDASCIndex
from repro.data import make_dataset


def run(seed: int = 0):
    rows = []
    data = make_dataset("dense_embed", n=6000, seed=seed)
    for gl in (64, 128, 256, 512):
        t0 = time.perf_counter()
        idx = PDASCIndex.build(data, gl=gl, distance="euclidean")
        dt = time.perf_counter() - t0
        rows.append(dict(
            bench="build_gl", gl=gl, n_levels=idx.n_levels,
            level_sizes=list(idx.stats.level_sizes),
            build_s=round(dt, 2),
            td0=round(idx.stats.level_td[0], 1),
        ))
        print(f"[build] gl={gl}: levels={idx.stats.level_sizes} "
              f"t={dt:.2f}s", flush=True)

    # clusterer comparison (paper §3.3: k-means is Euclidean-bound)
    for method in ("pam", "alternate", "build", "kmeans"):
        t0 = time.perf_counter()
        idx = PDASCIndex.build(data[:3000], gl=128, distance="euclidean",
                               method=method)
        dt = time.perf_counter() - t0
        rows.append(dict(bench="build_method", method=method,
                         build_s=round(dt, 2),
                         td0=round(idx.stats.level_td[0], 1)))
        print(f"[build] method={method}: td0={idx.stats.level_td[0]:.1f} "
              f"t={dt:.2f}s", flush=True)

    # outlier promotion: islands (geo) keep their own prototypes
    geo = make_dataset("geo_clusters", n=2000, seed=seed)
    idx = PDASCIndex.build(geo, gl=60, distance="haversine")
    top = np.asarray(idx.data.levels[-1].points)
    top = top[np.asarray(idx.data.levels[-1].valid)]
    lat_deg = top[:, 0] * 180 / np.pi
    n_island = int((lat_deg < 32).sum())
    rows.append(dict(bench="outliers", top_level_protos=len(top),
                     island_protos=n_island))
    print(f"[build] top-level prototypes={len(top)}, island={n_island}")
    assert n_island >= 1, "island outliers must surface at the top level"
    return rows


def main(argv=None):
    import json
    import os

    rows = run()
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/build.json", "w") as f:
        json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
