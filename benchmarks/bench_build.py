"""MSA build benchmark: the seed path vs the kernel-layer build substrate.

Seed path (preserved in-tree as the baseline): dense whole-level [G, g, g]
pairwise (``group_chunk=0``) + vmapped scalar greedy BUILD + the
one-swap-per-sweep FasterPAM loop (``method="pam_reference"``,
``swap_tol=0``). New path (the defaults): candidate-pruned batched BUILD +
eager multi-swap FasterPAM with the ``swap_tol`` convergence cutoff, either
dense (``group_chunk=0``) or streamed in ``group_chunk`` slabs (the
memory-bounded mode — peak clustering memory O(group_chunk · gl²)).

    PYTHONPATH=src python -m benchmarks.bench_build [--smoke]
        [--out experiments/build.json] [--bench-out BENCH_build.json]

``--smoke`` runs a tiny config (2 gl values, small n, correctness assertions
only — no wall-time assertions) so CI can catch build-path regressions after
the tier-1 suite; the full run also records the seed-vs-new wall-time table
into ``BENCH_build.json`` and asserts the gl=256 speedup.

Every seed-vs-new pair asserts identical ``level_sizes`` (same key => same
shuffle => same grouping) and level-0 TD within 1% of the seed swap loop.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.core import msa
from repro.core.index import PDASCIndex
from repro.data import make_dataset

SEED_KW = dict(method="pam_reference", group_chunk=0, swap_tol=0.0)
NEW_DENSE_KW = dict(method="pam", group_chunk=0)
NEW_STREAM_KW = dict(method="pam")  # group_chunk default (streamed slabs)


def _timed_build(data, *, gl, repeats, key_warm, key_time, **kw):
    """Warm (compile) with one key, then time re-builds with another."""
    _, stats = msa.build_index(data, gl=gl, key=key_warm, **kw)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        msa.build_index(data, gl=gl, key=key_time, **kw)
        ts.append(time.perf_counter() - t0)
    return min(ts), stats


def _check_pair(row, seed_stats, new_stats, label):
    assert seed_stats.level_sizes == new_stats.level_sizes, (
        label, seed_stats.level_sizes, new_stats.level_sizes)
    drift = new_stats.level_td[0] / max(seed_stats.level_td[0], 1e-9) - 1.0
    row[f"td_drift_pct_{label}"] = round(100 * drift, 4)
    assert abs(drift) < 0.01, (label, drift)


def run(smoke: bool = False, seed: int = 0):
    if smoke:
        n, gls, repeats = 1200, (32, 64), 1
        method_n, method_gl = 600, 32
    else:
        n, gls, repeats = 6000, (64, 128, 256, 512), 5
        method_n, method_gl = 3000, 128
    data = make_dataset("dense_embed", n=n, seed=seed).astype(np.float32)
    kw_warm, kw_time = jax.random.PRNGKey(0), jax.random.PRNGKey(1)
    rows = []

    # -- seed vs new across group lengths (pam) ------------------------------
    for gl in gls:
        t_seed, st_seed = _timed_build(
            data, gl=gl, repeats=repeats, key_warm=kw_warm, key_time=kw_time,
            **SEED_KW)
        t_new, st_new = _timed_build(
            data, gl=gl, repeats=repeats, key_warm=kw_warm, key_time=kw_time,
            **NEW_DENSE_KW)
        t_str, st_str = _timed_build(
            data, gl=gl, repeats=repeats, key_warm=kw_warm, key_time=kw_time,
            **NEW_STREAM_KW)
        row = dict(
            bench="build_seed_vs_new", gl=gl, n=n,
            level_sizes=list(st_seed.level_sizes),
            seed_s=round(t_seed, 3),
            new_dense_s=round(t_new, 3),
            new_streamed_s=round(t_str, 3),
            speedup_dense=round(t_seed / t_new, 2),
            speedup_streamed=round(t_seed / t_str, 2),
            td0_seed=round(st_seed.level_td[0], 1),
            td0_new=round(st_new.level_td[0], 1),
        )
        _check_pair(row, st_seed, st_new, "dense")
        _check_pair(row, st_seed, st_str, "streamed")
        row["build_s"] = row["new_dense_s"]  # headline value (run.py CSV)
        rows.append(row)
        print(f"[build] gl={gl}: seed {t_seed:.3f}s  dense {t_new:.3f}s "
              f"({row['speedup_dense']}x)  streamed {t_str:.3f}s "
              f"({row['speedup_streamed']}x)", flush=True)
    if not smoke:
        # Wall-clock bar checked softly here (run() is also called by the
        # benchmarks.run aggregator on arbitrary machines); main() enforces
        # it before recording BENCH_build.json.
        r256 = next(r for r in rows if r.get("bench") == "build_seed_vs_new" and r.get("gl") == 256)
        if r256["speedup_dense"] < 2.0:
            print(f"[build] WARNING: gl=256 dense speedup "
                  f"{r256['speedup_dense']}x below the 2x bar "
                  f"(noisy/loaded machine?)", flush=True)

    # -- seed vs new per clusterer method ------------------------------------
    mdata = data[:method_n]
    for method in ("pam", "alternate", "build", "kmeans"):
        seed_m = "pam_reference" if method == "pam" else method
        t_seed, st_seed = _timed_build(
            mdata, gl=method_gl, repeats=repeats, key_warm=kw_warm,
            key_time=kw_time, method=seed_m, group_chunk=0, swap_tol=0.0)
        t_new, st_new = _timed_build(
            mdata, gl=method_gl, repeats=repeats, key_warm=kw_warm,
            key_time=kw_time, method=method)
        row = dict(
            bench="build_method", method=method, gl=method_gl, n=method_n,
            seed_s=round(t_seed, 3), new_s=round(t_new, 3),
            speedup=round(t_seed / t_new, 2),
            td0_seed=round(st_seed.level_td[0], 1),
            td0_new=round(st_new.level_td[0], 1),
        )
        assert st_seed.level_sizes == st_new.level_sizes, (method, st_seed, st_new)
        if method in ("pam", "alternate", "build"):  # kmeans reports td=0
            _check_pair(row, st_seed, st_new, method)
        row["build_s"] = row["new_s"]  # headline value (run.py CSV)
        rows.append(row)
        print(f"[build] method={method}: seed {t_seed:.3f}s new {t_new:.3f}s "
              f"({row['speedup']}x)", flush=True)

    # -- outlier promotion (paper Fig. 2): islands keep their prototypes -----
    if smoke:  # covered by tier-1 tests; skip the extra haversine build in CI
        return rows
    geo = make_dataset("geo_clusters", n=2000, seed=seed)
    idx = PDASCIndex.build(geo, gl=60, distance="haversine")
    top = np.asarray(idx.data.levels[-1].points)
    top = top[np.asarray(idx.data.levels[-1].valid)]
    n_island = int((top[:, 0] * 180 / np.pi < 32).sum())
    rows.append(dict(bench="outliers", top_level_protos=len(top),
                     island_protos=n_island))
    print(f"[build] top-level prototypes={len(top)}, island={n_island}")
    assert n_island >= 1, "island outliers must surface at the top level"
    return rows


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="tiny config, correctness assertions only (CI)")
    p.add_argument("--out", default="experiments/build.json")
    p.add_argument("--bench-out", default="BENCH_build.json")
    args = p.parse_args(argv)

    rows = run(smoke=args.smoke)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    if not args.smoke:
        r256 = next(r for r in rows if r.get("bench") == "build_seed_vs_new" and r.get("gl") == 256)
        assert r256["speedup_dense"] >= 2.0, (
            "gl=256 dense speedup below the recorded 2x bar", r256)
        payload = dict(
            bench="msa_build_seed_vs_kernel_layer",
            backend=jax.default_backend(),
            baseline=("seed: dense whole-level [G,g,g] pairwise + vmapped "
                      "scalar greedy BUILD + one-swap-per-sweep FasterPAM "
                      "(method=pam_reference, group_chunk=0, swap_tol=0)"),
            new=("candidate-pruned batched BUILD + eager multi-swap "
                 "FasterPAM (swap_tol=1e-3); dense (group_chunk=0) and "
                 "streamed (group_chunk slabs, peak clustering memory "
                 "O(group_chunk*gl^2)) layouts"),
            rows=rows,
        )
        with open(args.bench_out, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"wrote {args.bench_out}")


if __name__ == "__main__":
    main()
