"""Online substrate benchmark: interleaved upsert/delete/search workload.

For the reference config (dense_embed, gl=256, euclidean, k=10, beam=32) it
drives a seeded interleaved churn stream against a mutable PDASC index and
records, into ``BENCH_online.json``:

  * write throughput (upserts+deletes applied per second, incl. leaf
    routing),
  * search QPS under churn (delta merge + tombstone mask in the hot path)
    vs the frozen baseline QPS,
  * recall@10 deltas vs a from-scratch rebuild on the final live set:
    pre-compaction (the delta/tombstone serving state) and post-compaction
    (epoch swap), plus the compaction wall-time split by scope
    (affected-groups vs full rebuild) and the payload blocks requantised.

Acceptance bars asserted here (and in ``tests/test_online.py``): deleted
ids never surface; pre-compaction recall within 0.02 of the fresh rebuild;
post-compaction result sets identical to exact over the live set.

    PYTHONPATH=src python -m benchmarks.bench_online [--smoke]
        [--out experiments/online.json] [--bench-out BENCH_online.json]

``--smoke`` runs a tiny config (correctness assertions only, no wall-time
numbers recorded) so CI catches online-path regressions after the tier-1
suite, matching ``bench_build --smoke`` / ``bench_store --smoke``.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from benchmarks.bench_search import _recall
from repro.baselines import exact_knn
from repro.core.index import PDASCIndex
from repro.data import make_dataset
from repro.online import live_dataset
from repro.query import Query


def _recall_mapped(res_ids, live_ids, gt):
    """Recall where ``res_ids`` are rows into the live array."""
    mapped = np.where(
        res_ids >= 0, live_ids[np.clip(res_ids, 0, len(live_ids) - 1)], -1
    )
    return _recall(mapped, gt)


def run(smoke: bool = False, seed: int = 0):
    if smoke:
        n, n_queries, gl, n_writes, delta_cap = 1200, 64, 64, 120, 256
    else:
        n, n_queries, gl, n_writes, delta_cap = 7800, 512, 256, 1024, 2048
    k, beam = 10, 32
    rng = np.random.default_rng(seed)
    data = make_dataset("dense_embed", n=n + n_queries, seed=seed)
    train, test = data[:n], data[n:n + n_queries]

    idx = PDASCIndex.build(train, gl=gl, distance="euclidean",
                           radius_quantile=0.35)
    idx.enable_mutations(delta_capacity=delta_cap)
    r = idx.default_radius

    # frozen-baseline search throughput, measured at the same 16-query
    # micro-batches the churn loop uses (per-dispatch overhead comparable)
    q_beam = Query(k=k, execution="beam", beam=beam)
    res = idx.plan(q_beam)(test[:16])  # compile
    np.asarray(res.ids)
    t0 = time.perf_counter()
    for lo in range(0, n_queries, 16):
        np.asarray(idx.plan(q_beam)(test[lo:lo + 16]).ids)
    qps_frozen = (n_queries // 16) * 16 / (time.perf_counter() - t0)

    # warm the churn-path executables (masked search + delta scan + merge)
    # outside the timed loop, then reset the online tiers
    warm_ids = idx.upsert(train[:1] + 0.01)
    idx.delete([int(np.asarray(idx.data.leaf_ids)[0])])
    np.asarray(idx.plan(q_beam)(test[:16]).ids)
    idx.delete(warm_ids)

    # --- interleaved churn stream -------------------------------------------
    deleted: set[int] = {int(np.asarray(idx.data.leaf_ids)[0])}
    upserted: list[int] = []
    n_upserts = 0
    t_write = 0.0
    t_search = 0.0
    searches = 0
    for i in range(n_writes):
        t0 = time.perf_counter()
        if upserted and rng.random() < 0.35:
            victim = upserted.pop(int(rng.integers(len(upserted))))
            idx.delete([victim])
            deleted.add(victim)
        elif rng.random() < 0.25:
            victim = int(rng.integers(n))
            if victim not in deleted:
                idx.delete([victim])
                deleted.add(victim)
        else:
            v = train[rng.integers(n)] + rng.normal(
                0, 0.05, train.shape[1]
            ).astype(np.float32)
            upserted.extend(int(x) for x in idx.upsert(v[None]))
            n_upserts += 1
        t_write += time.perf_counter() - t0
        if i % 8 == 0:  # interleave searches with the write stream
            qs = test[rng.integers(0, n_queries, 16)]
            t0 = time.perf_counter()
            out = idx.plan(q_beam)(qs)
            ids = np.asarray(out.ids)
            t_search += time.perf_counter() - t0
            searches += 16
            hit = deleted & set(ids.ravel().tolist())
            assert not hit, f"deleted ids surfaced under churn: {hit}"
    writes_per_s = n_writes / t_write
    qps_churn = searches / t_search if t_search else float("nan")

    # --- recall vs a from-scratch rebuild on the live set -------------------
    live_vecs, live_ids = live_dataset(idx)
    _, gt_rows = exact_knn(test, live_vecs, distance="euclidean", k=k)
    gt = live_ids[np.asarray(gt_rows)]
    fresh = PDASCIndex.build(live_vecs, gl=gl, distance="euclidean",
                             radius_quantile=0.35)
    q_beam_r = Query(k=k, execution="beam", beam=beam, radius=float(r))
    rec_mut = _recall(np.asarray(idx.plan(q_beam_r)(test).ids), gt)
    rec_fresh = _recall_mapped(
        np.asarray(fresh.plan(q_beam_r)(test).ids), live_ids, gt,
    )
    pre_delta = rec_fresh - rec_mut
    assert pre_delta <= 0.02, (
        f"pre-compaction recall degraded {pre_delta:.4f} > 0.02 vs fresh "
        f"rebuild ({rec_mut:.4f} vs {rec_fresh:.4f})"
    )

    # --- compaction: epoch swap + parity ------------------------------------
    idx.attach_store("int8", block=min(gl, 256))
    t0 = time.perf_counter()
    comp = idx.compact(scope="affected")
    t_affected = time.perf_counter() - t0
    t0 = time.perf_counter()
    comp = idx.compact(scope="affected")  # warm: executables compiled
    t_affected_warm = time.perf_counter() - t0
    t0 = time.perf_counter()
    comp_full = idx.compact(scope="full")
    t_full = time.perf_counter() - t0
    requant = comp.store.last_rebuild if comp.store is not None else None
    # exact search over the compacted epoch == exact ground truth
    res_c = np.asarray(
        comp.plan(Query(k=k, execution="dense", radius=1e9))(test).ids)
    np.testing.assert_array_equal(np.sort(res_c, axis=1), np.sort(gt, axis=1))
    rec_comp = _recall(np.asarray(comp.plan(q_beam_r)(test).ids), gt)
    rec_comp_full = _recall(
        np.asarray(comp_full.plan(q_beam_r)(test).ids), gt,
    )

    rows = [dict(
        bench="online", config=dict(
            dataset="dense_embed", n=n, n_queries=n_queries, gl=gl,
            distance="euclidean", k=k, beam=beam, n_writes=n_writes,
            delta_capacity=delta_cap,
        ),
        writes_per_s=round(writes_per_s, 1),
        qps_frozen=round(qps_frozen, 1),
        qps_churn=round(qps_churn, 1),
        qps_churn_ratio=round(qps_churn / qps_frozen, 4),
        n_upserts=n_upserts,
        n_deletes=len(deleted),
        recall_fresh=round(rec_fresh, 4),
        recall_churn=round(rec_mut, 4),
        recall_delta_pre_compaction=round(pre_delta, 4),
        recall_post_compaction=round(rec_comp, 4),
        recall_post_compaction_full=round(rec_comp_full, 4),
        compact_s_affected=round(t_affected, 3),
        compact_s_affected_warm=round(t_affected_warm, 3),
        compact_s_full=round(t_full, 3),
        payload_blocks_requantized=requant,
        epoch=comp.epoch,
    )]
    print(f"[online] writes/s={writes_per_s:.1f} "
          f"qps churn/frozen={qps_churn:.1f}/{qps_frozen:.1f} "
          f"recall churn={rec_mut:.4f} fresh={rec_fresh:.4f} "
          f"post-compact={rec_comp:.4f} "
          f"compact {t_affected:.2f}s affected ({t_affected_warm:.2f}s "
          f"warm) / {t_full:.2f}s full "
          f"requant={requant}", flush=True)
    return rows


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="tiny config, correctness assertions only (CI)")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--out", default="experiments/online.json")
    p.add_argument("--bench-out", default="BENCH_online.json")
    args = p.parse_args(argv)

    rows = run(smoke=args.smoke, seed=args.seed)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    if not args.smoke:
        payload = dict(
            bench="online_mutability_under_churn",
            baseline="frozen index + from-scratch rebuild on the live set",
            new="delta-buffer upserts + tombstoned deletes + epoch-swap "
                "compaction (affected-groups scope) serving live traffic",
            rows=rows,
        )
        with open(args.bench_out, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"[online] wrote {args.bench_out}")


if __name__ == "__main__":
    main()
