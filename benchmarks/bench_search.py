"""Search performance: dense vs beam NSA (pruning/recall trade-off), the
batched kernel-layer beam vs the seed per-query vmap beam (seed-vs-new,
recorded in ``BENCH_search.json``), radius sensitivity (paper §5 future-work:
per-level dynamic radii), and the kernel micro-bench (CPU wall time; the TPU
story is the §Roofline dry-run).

    PYTHONPATH=src python -m benchmarks.bench_search [--mode all|dense|beam|radius|kernel]
        [--out experiments/search.json] [--bench-out BENCH_search.json]

``--mode beam`` runs the seed-vs-new comparison only: for each beam width it
times ``search_beam_vmap`` (the seed baseline, a vmap of scalar ``dist.point``
gathers) against the batched ``search_beam`` (one gather + one fused
``ops.rank_candidates`` per level) and reports the query-throughput speedup.

Every timed call goes through the query/plan layer (``idx.plan(Query(...))``
— the serving pattern), and the per-pipeline planner counters (plan
compiles / cache hits / replans / executions) are recorded into
``BENCH_search.json`` so a retracing regression shows up in the perf
trajectory.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines import exact_knn
from repro.core.index import PDASCIndex
from repro.data import make_dataset
from repro.kernels import ops
from repro.kernels.ref import knn_ref, pairwise_ref
from repro.query import Query, plan_stats, reset_plan_stats

BEAMS = (4, 16, 32, 64, 128)


def _recall(ids, gt):
    return float(np.mean([
        len(set(ids[i][ids[i] >= 0].tolist()) & set(gt[i].tolist())) / gt.shape[1]
        for i in range(len(gt))
    ]))


def _setup(seed: int, n_queries: int = 128, need_index: bool = True):
    data = make_dataset("dense_embed", n=7800 + n_queries, seed=seed)
    train, test = data[:7800], data[7800:7800 + n_queries]
    if not need_index:  # kernel micro-bench needs only the raw arrays
        return train, test, None, None
    _, gt = exact_knn(test, train, distance="euclidean", k=10)
    idx = PDASCIndex.build(train, gl=256, distance="euclidean",
                           radius_quantile=0.35)
    return train, test, np.asarray(gt), idx


def _timed(fn, n_queries: int, repeats: int = 3):
    """us/query over the best of ``repeats`` post-compile runs."""
    res = fn()  # compile
    jax.block_until_ready(res)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        res = fn()
        jax.block_until_ready(res)
        best = min(best, time.perf_counter() - t0)
    return res, best / n_queries * 1e6


def run_beam_comparison(idx, test, gt):
    """Seed vmap beam vs batched kernel-layer beam (the tentpole numbers)."""
    rows = []
    Q = jnp.asarray(test)
    for beam in BEAMS:
        # resolve through the plan cache per call (the serving pattern) so
        # the timed number includes the cache-hit lookup and the recorded
        # plan stats show hits alongside compiles
        q_old = Query(k=10, execution="beam_vmap", beam=beam)
        q_new = Query(k=10, execution="beam", beam=beam)
        res_old, us_old = _timed(lambda: idx.plan(q_old)(Q), len(test))
        res_new, us_new = _timed(lambda: idx.plan(q_new)(Q), len(test))
        row = dict(
            bench="beam_batched_vs_vmap", beam=beam,
            us_per_q_vmap=round(us_old, 1), us_per_q_batched=round(us_new, 1),
            speedup=round(us_old / us_new, 2),
            recall_vmap=_recall(np.asarray(res_old.ids), gt),
            recall_batched=_recall(np.asarray(res_new.ids), gt),
            candidates=int(np.asarray(res_new.n_candidates).mean()),
        )
        rows.append(row)
        print(f"[search] beam={beam}: vmap {row['us_per_q_vmap']}us "
              f"batched {row['us_per_q_batched']}us "
              f"speedup {row['speedup']}x", flush=True)
    return rows


def run_dense(idx, test, gt):
    """Dense (faithful) NSA timing; the beam sweep lives in
    run_beam_comparison (which also reports the batched recalls)."""
    q = Query(k=10, execution="dense")
    res, us = _timed(lambda: idx.plan(q)(jnp.asarray(test)), len(test))
    row = dict(bench="nsa", mode="dense", beam=-1,
               recall=_recall(np.asarray(res.ids), gt),
               us_per_q=round(us, 1),
               candidates=int(np.asarray(res.n_candidates).mean()))
    print(f"[search] dense: {row}", flush=True)
    return [row]


def run_radius(train, test, gt, idx):
    rows = []
    for q in (0.1, 0.3, 0.5):
        idx_q = PDASCIndex.build(train, gl=256, distance="euclidean",
                                 radius_quantile=q)
        res = idx_q.plan(Query(k=10, execution="dense"))(test)
        rows.append(dict(bench="radius", quantile=q,
                         recall=_recall(np.asarray(res.ids), gt),
                         candidates=int(np.asarray(res.n_candidates).mean())))
    radii = idx.per_level_radii()
    from repro.core import nsa as nsa_lib
    from repro.core import distances as dl

    res = nsa_lib.search_dense(idx.data, jnp.asarray(test),
                               dist=dl.get("euclidean"), k=10, r=tuple(radii))
    rows.append(dict(bench="radius", quantile="per-level",
                     recall=_recall(np.asarray(res.ids), gt),
                     candidates=int(np.asarray(res.n_candidates).mean())))
    print(f"[search] per-level radii: {rows[-1]}", flush=True)
    return rows


def run_kernel_micro(train, test):
    """Fused flash-knn vs materialise+topk (CPU wall)."""
    rows = []
    Q = jnp.asarray(test)
    DB = jnp.asarray(train)
    for name, fn in [
        ("knn_ref_materialise", lambda: knn_ref(Q, DB, 10, "l2")),
        ("knn_fused_interpret", lambda: ops.knn(Q, DB, "l2", k=10,
                                                force_pallas=True)),
    ]:
        _, us = _timed(fn, len(test), repeats=1)
        rows.append(dict(bench="kernel", name=name, us_per_q=round(us, 1)))
    return rows


def run(seed: int = 0, modes=("dense", "beam", "radius", "kernel")):
    # The seed-vs-new comparison runs at serving batch size (512 queries):
    # the batched path exists to amortise per-level work over the batch.
    reset_plan_stats()  # per-run planner counters (compiles / cache hits)
    train, test, gt, idx = _setup(
        seed, n_queries=512 if "beam" in modes else 128,
        need_index=any(m in modes for m in ("dense", "beam", "radius")),
    )
    rows = []
    if idx is not None:
        # Per-tier resident bytes (navigation vs payload) alongside the QPS
        # numbers; bench_store.py records the tiered-store counterpart.
        mem = idx.memory_bytes()
        print(f"[search] memory: {mem}", flush=True)
        rows.append(dict(bench="memory", **mem))
    if "dense" in modes:
        rows += run_dense(idx, test, gt)
    if "beam" in modes:
        rows += run_beam_comparison(idx, test, gt)
    if "radius" in modes:
        rows += run_radius(train, test, gt, idx)
    if "kernel" in modes:
        rows += run_kernel_micro(train, test)
        # Default-vs-tuned kernel configs at the bench shapes: the blocks
        # the dispatch resolves untouched vs under KernelConfig(auto=True)
        # (identical until bench_kernels.py populates the tuner cache).
        d_dim = train.shape[1]
        kern_auto = ops.KernelConfig(auto=True)
        cfg_rows = {
            op: dict(
                default=ops.resolve_blocks(op, "l2", "float32", shape),
                tuned=ops.resolve_blocks(op, "l2", "float32", shape,
                                         kern_auto),
            )
            for op, shape in (
                ("pairwise", (len(test), len(train), d_dim)),
                ("knn", (len(test), len(train), d_dim)),
                ("rank", (len(test), 512, d_dim)),
            )
        }
        rows.append(dict(bench="kernel_configs", configs=cfg_rows))
        print(f"[search] kernel configs: {cfg_rows}", flush=True)
    stats = plan_stats()
    if stats:
        # Planner honesty record: each timed pipeline should show ONE plan
        # compile and executions >> compiles — a retracing regression shows
        # up here as compiles growing with the execution count.
        print(f"[search] plan stats: {stats}", flush=True)
        rows.append(dict(bench="plan_stats", per_pipeline=stats))
    return rows


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--mode", default="all",
                   choices=["all", "dense", "beam", "radius", "kernel"])
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--out", default="experiments/search.json")
    p.add_argument("--bench-out", default="BENCH_search.json",
                   help="seed-vs-new beam comparison artifact")
    args = p.parse_args(argv)
    modes = (("dense", "beam", "radius", "kernel") if args.mode == "all"
             else (args.mode,))

    rows = run(seed=args.seed, modes=modes)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)

    cmp_rows = [r for r in rows if r.get("bench") == "beam_batched_vs_vmap"]
    mem_rows = [r for r in rows if r.get("bench") == "memory"]
    stat_rows = [r for r in rows if r.get("bench") == "plan_stats"]
    cfg_rows = [r for r in rows if r.get("bench") == "kernel_configs"]
    if cmp_rows:
        # Headline: the default serving beam width (PDASCIndex.search).
        headline = next((r for r in cmp_rows if r["beam"] == 32), cmp_rows[-1])
        summary = dict(
            bench="nsa_beam_seed_vs_kernel_layer",
            backend=jax.default_backend(),
            config=dict(dataset="dense_embed", n=7800, n_queries=512,
                        gl=256, distance="euclidean", k=10),
            baseline="search_beam_vmap (seed: per-query vmap of "
                     "dist.point gathers + per-level top_k)",
            new="search_beam (batched: one candidate gather + one fused "
                "kernel-layer rank per level)",
            rows=cmp_rows,
            headline_beam=headline["beam"],
            headline_speedup=headline["speedup"],
            min_speedup=min(r["speedup"] for r in cmp_rows),
            max_speedup=max(r["speedup"] for r in cmp_rows),
            memory=mem_rows[0] if mem_rows else None,
            # Per-pipeline plan-compile counts and plan-cache hits (the
            # query/plan layer, DESIGN.md §3.8): compiles should stay O(one
            # per distinct Query) while executions grow with traffic.
            plan_stats=stat_rows[0]["per_pipeline"] if stat_rows else None,
            # blocks the dispatch resolves by default vs KernelConfig(auto=
            # True) against the current tuner cache (bench_kernels.py)
            kernel_configs=cfg_rows[0]["configs"] if cfg_rows else None,
        )
        with open(args.bench_out, "w") as f:
            json.dump(summary, f, indent=1)
        print(f"[search] wrote {args.bench_out}: speedups "
              f"{[r['speedup'] for r in cmp_rows]} "
              f"(headline beam={headline['beam']}: {headline['speedup']}x)")


if __name__ == "__main__":
    main()
