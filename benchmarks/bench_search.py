"""Search performance: dense vs beam NSA (pruning/recall trade-off), radius
sensitivity (paper §5 future-work: per-level dynamic radii), kernel
micro-bench (CPU wall time; the TPU story is the §Roofline dry-run)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines import exact_knn
from repro.core.index import PDASCIndex
from repro.data import make_dataset
from repro.kernels import ops
from repro.kernels.ref import knn_ref, pairwise_ref


def _recall(ids, gt):
    return float(np.mean([
        len(set(ids[i][ids[i] >= 0].tolist()) & set(gt[i].tolist())) / gt.shape[1]
        for i in range(len(gt))
    ]))


def run(seed: int = 0):
    rows = []
    data = make_dataset("dense_embed", n=8000, seed=seed)
    train, test = data[:7800], data[7800:7928]
    _, gt = exact_knn(test, train, distance="euclidean", k=10)
    gt = np.asarray(gt)
    idx = PDASCIndex.build(train, gl=256, distance="euclidean",
                           radius_quantile=0.35)

    def timed_search(**kw):
        res = idx.search(test, k=10, **kw)  # compile
        jax.block_until_ready(res.dists)
        t0 = time.perf_counter()
        res = idx.search(test, k=10, **kw)
        jax.block_until_ready(res.dists)
        dt = time.perf_counter() - t0
        return res, dt / len(test) * 1e6

    res, us = timed_search(mode="dense")
    rows.append(dict(bench="nsa", mode="dense", beam=-1,
                     recall=_recall(np.asarray(res.ids), gt),
                     us_per_q=round(us, 1),
                     candidates=int(np.asarray(res.n_candidates).mean())))
    for beam in (4, 16, 48, 128):
        res, us = timed_search(mode="beam", beam=beam)
        rows.append(dict(bench="nsa", mode="beam", beam=beam,
                         recall=_recall(np.asarray(res.ids), gt),
                         us_per_q=round(us, 1),
                         candidates=int(np.asarray(res.n_candidates).mean())))
        print(f"[search] beam={beam}: {rows[-1]}", flush=True)

    # radius sensitivity + per-level dynamic radii (paper future work)
    for q in (0.1, 0.3, 0.5):
        idx_q = PDASCIndex.build(train, gl=256, distance="euclidean",
                                 radius_quantile=q)
        res = idx_q.search(test, k=10, mode="dense")
        rows.append(dict(bench="radius", quantile=q,
                         recall=_recall(np.asarray(res.ids), gt),
                         candidates=int(np.asarray(res.n_candidates).mean())))
    radii = idx.per_level_radii()
    from repro.core import nsa as nsa_lib
    from repro.core import distances as dl

    res = nsa_lib.search_dense(idx.data, jnp.asarray(test),
                               dist=dl.get("euclidean"), k=10, r=tuple(radii))
    rows.append(dict(bench="radius", quantile="per-level",
                     recall=_recall(np.asarray(res.ids), gt),
                     candidates=int(np.asarray(res.n_candidates).mean())))
    print(f"[search] per-level radii: {rows[-1]}", flush=True)

    # kernel micro-bench: fused flash-knn vs materialise+topk (CPU wall)
    Q = jnp.asarray(test)
    DB = jnp.asarray(train)
    for name, fn in [
        ("knn_ref_materialise", lambda: knn_ref(Q, DB, 10, "l2")),
        ("knn_fused_interpret", lambda: ops.knn(Q, DB, "l2", k=10,
                                                force_pallas=True)),
    ]:
        out = fn()
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        us = (time.perf_counter() - t0) / len(test) * 1e6
        rows.append(dict(bench="kernel", name=name, us_per_q=round(us, 1)))
    return rows


def main(argv=None):
    import json
    import os

    rows = run()
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/search.json", "w") as f:
        json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
