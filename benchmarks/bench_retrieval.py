"""The `retrieval_cand` regime at bench scale: exact distributed dot-product
top-k vs PDASC-pruned retrieval over candidate embeddings (the paper's
technique applied to the recsys retrieval cell)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines import exact_knn
from repro.core.index import PDASCIndex
from repro.data import make_dataset
from repro.query import Query


def run(seed: int = 0, n_cand: int = 20_000, d: int = 64, n_q: int = 64,
        k: int = 100):
    rng = np.random.default_rng(seed)
    cands = make_dataset("dense_embed", n=n_cand, seed=seed)[:, :d]
    queries = cands[rng.integers(0, n_cand, n_q)] + \
        rng.normal(0, 0.1, size=(n_q, d)).astype(np.float32)
    rows = []

    # exact (the production default for this cell)
    t0 = time.perf_counter()
    _, gt = exact_knn(queries, cands, distance="dot", k=k)
    t_exact = time.perf_counter() - t0
    gt = np.asarray(gt)
    rows.append(dict(method="exact_dot", recall=1.0,
                     us_per_q=round(t_exact / n_q * 1e6, 1),
                     scanned=n_cand))

    # PDASC-pruned retrieval (cosine index — MIPS-adjacent for normalised-ish
    # embeddings; dot itself is indexable too since k-medoids is
    # dissimilarity-agnostic)
    for distance in ("cosine", "dot"):
        idx = PDASCIndex.build(cands, gl=512, distance=distance,
                               radius_quantile=0.3)
        plan = idx.plan(Query(k=k, execution="dense"))
        res = plan(queries)  # compile
        jax.block_until_ready(res.dists)
        t0 = time.perf_counter()
        res = plan(queries)
        jax.block_until_ready(res.dists)
        dt = time.perf_counter() - t0
        ids = np.asarray(res.ids)
        rec = float(np.mean([
            len(set(ids[i][ids[i] >= 0].tolist()) & set(gt[i].tolist())) / k
            for i in range(n_q)
        ]))
        rows.append(dict(method=f"pdasc_{distance}", recall=round(rec, 3),
                         us_per_q=round(dt / n_q * 1e6, 1),
                         scanned=int(np.asarray(res.n_candidates).mean())))
        print(f"[retrieval] {rows[-1]}", flush=True)
    return rows


def main(argv=None):
    import json
    import os

    rows = run()
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/retrieval.json", "w") as f:
        json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
