"""Kernel block-size autotuner benchmark (``BENCH_kernels.json``).

For each kernel op (pairwise / knn / rank / scan / swap) at a representative
serving shape it runs the autotuner sweep (``repro.kernels.autotune.tune``):
every VMEM-feasible candidate tiling from the backend's grid is timed
(warmup + median-of-k) and scored ``median_us * (1 + padding_waste)``; the
winner is persisted into the versioned on-disk cache that
``KernelConfig(auto=True)`` resolves from at dispatch time.

Recorded per op: the full sweep (knobs, us, waste, score), the hand-set
default's row, the winner, and the winner-vs-default speedup. The
acceptance bar — the winner's score never exceeds the default's — is
structural (the default is always a sweep member and the winner is the
argmin) and asserted here so a scoring regression cannot ship silently.

    PYTHONPATH=src python -m benchmarks.bench_kernels [--smoke]
        [--out experiments/kernels.json] [--bench-out BENCH_kernels.json]

``--smoke`` sweeps tiny shapes with one rep into a throwaway cache
(correctness of the tune -> cache -> resolve loop, no stable numbers) so CI
can catch autotuner regressions after the tier-1 suite, matching the other
``--smoke`` bench steps. On CPU all timing runs the interpret-mode kernels —
relative tile rankings are indicative, the TPU story is the dry-run roofline.
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import jax

from repro.kernels import autotune
from repro.kernels import ops as kops

# (op, form, dtype-key, shape) — serving-representative shapes: the
# dense_embed bench config (n=7800, d=100, gl=256) for the search ops, the
# packed payload formats for the scan, the group-length axis for the swap.
SWEEPS = [
    ("pairwise", "l2", "float32", (512, 2048, 100)),
    ("pairwise", "l1", "float32", (256, 512, 64)),
    ("knn", "l2", "float32", (128, 2048, 100)),
    ("rank", "l2", "float32", (128, 512, 100)),
    ("scan", "l2", "int8", (128, 512, 100)),
    ("scan", "l2", "int4", (128, 512, 100)),
    ("scan", "l2", "binary", (128, 512, 100)),
    ("swap", "none", "float32", (1024,)),
]

SMOKE_SWEEPS = [
    ("pairwise", "l2", "float32", (64, 96, 32)),
    ("knn", "l2", "float32", (32, 128, 16)),
    ("rank", "l2", "float32", (16, 64, 16)),
    ("scan", "l2", "int4", (16, 64, 16)),
    ("swap", "none", "float32", (96,)),
]


def run(smoke: bool = False):
    sweeps = SMOKE_SWEEPS if smoke else SWEEPS
    reps, warmup = (1, 0) if smoke else (5, 2)
    rows = []
    for op, form, dtype, shape in sweeps:
        t0 = time.perf_counter()
        r = autotune.tune(op, form=form, dtype=dtype, shape=shape,
                          reps=reps, warmup=warmup, force=True)
        wall = time.perf_counter() - t0
        winner_row = next(
            s for s in r["sweep"] if s["knobs"] == r["winner"]
        )
        default_row = next(
            s for s in r["sweep"] if s["knobs"] == r["default"]
        )
        # Structural acceptance: the default is a sweep member and the
        # winner is the score argmin, so this can only fire on a scoring /
        # grid bug — exactly what it is here to catch.
        assert winner_row["score"] <= default_row["score"], (
            "tuned winner scored worse than the hand-set default",
            op, form, dtype, shape, winner_row, default_row,
        )
        row = dict(
            bench="kernel_autotune", op=op, form=form, dtype=dtype,
            shape=list(shape), candidates=len(r["sweep"]),
            default=r["default"], default_us=round(r["default_us"], 1),
            winner=r["winner"], winner_us=round(r["winner_us"], 1),
            speedup_vs_default=round(
                r["default_us"] / max(r["winner_us"], 1e-9), 2
            ),
            default_waste=round(default_row["waste"], 4),
            winner_waste=round(winner_row["waste"], 4),
            sweep=r["sweep"],
            tune_wall_s=round(wall, 2),
        )
        rows.append(row)
        print(f"[kernels] {op}/{form}/{dtype}{tuple(shape)}: "
              f"default {row['default']} {row['default_us']}us -> "
              f"winner {row['winner']} {row['winner_us']}us "
              f"({row['speedup_vs_default']}x, {row['candidates']} "
              f"candidates)", flush=True)

    # Round-trip the resolution chain the serving path uses: the winners
    # just recorded must be what KernelConfig(auto=True) resolves.
    for row in rows:
        op, form, dtype, shape = (row["op"], row["form"], row["dtype"],
                                  tuple(row["shape"]))
        resolved = kops.resolve_blocks(
            op, form, dtype, shape, kops.KernelConfig(auto=True)
        )
        for knob, val in row["winner"].items():
            assert resolved[knob] == val, (
                "auto=True did not resolve the tuned winner",
                op, knob, val, resolved,
            )
    print(f"[kernels] auto=True resolves all {len(rows)} recorded winners "
          f"(cache: {autotune.cache_path()}, gen {autotune.generation()})",
          flush=True)
    return rows


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="tiny shapes, one rep, throwaway cache (CI)")
    p.add_argument("--out", default="experiments/kernels.json")
    p.add_argument("--bench-out", default="BENCH_kernels.json")
    args = p.parse_args(argv)

    if args.smoke:
        # never pollute the user's winner cache from a CI smoke run
        tmp = tempfile.mkdtemp(prefix="repro-tune-smoke-")
        autotune.set_cache_path(os.path.join(tmp, "tune.json"))

    rows = run(smoke=args.smoke)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    if not args.smoke:
        payload = dict(
            bench="kernel_block_autotuner",
            backend=jax.default_backend(),
            cache_version=autotune.CACHE_VERSION,
            baseline="hand-set per-op block defaults (tiling.OP_DEFAULTS), "
                     "shrink-to-shape + VMEM-budget fitted",
            new="per-(backend, op, form, dtype, shape-bucket) tuned winner "
                "from the timed sweep, persisted and resolved by "
                "KernelConfig(auto=True)",
            score="median_us * (1 + padding_waste)",
            rows=rows,
            headline=[
                dict(op=r["op"], form=r["form"], dtype=r["dtype"],
                     winner=r["winner"],
                     speedup_vs_default=r["speedup_vs_default"])
                for r in rows
            ],
        )
        with open(args.bench_out, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"[kernels] wrote {args.bench_out}: "
              f"{[r['speedup_vs_default'] for r in rows]}x vs defaults")


if __name__ == "__main__":
    main()
