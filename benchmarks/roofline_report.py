"""Aggregate the dry-run JSONs into the §Roofline table.

Reads ``experiments/dryrun/*.json`` (written by ``repro.launch.dryrun``) and
emits a markdown table: per (arch x shape x mesh) the three roofline terms,
the dominant bottleneck, MODEL_FLOPS/HLO ratio and the per-device memory.
"""

from __future__ import annotations

import glob
import json
import os


def load(dirname: str = "experiments/dryrun"):
    rows = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def _fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.0f}us"


def table(rows, mesh: str = "single") -> str:
    out = [
        "| arch | shape | kind | compute | memory | collective | bottleneck "
        "| MODEL/HLO | mem/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("mesh") != mesh:
            continue
        if not r.get("ok"):
            out.append(f"| {r['arch']} | {r['shape']} | - | FAILED: "
                       f"{r.get('error', '?')[:40]} | | | | | |")
            continue
        rf = r["roofline"]
        ratio = rf.get("useful_flops_ratio")
        mem = r.get("memory_analysis", {})
        peak = mem.get("temp_size_in_bytes", 0) + mem.get(
            "argument_size_in_bytes", 0)
        # One row built cell-by-cell — only the MODEL/HLO ratio cell is
        # conditional. (The old code made the *whole row pair* the
        # conditional's operands, so the two copies had to be kept in sync
        # by hand and a drifted branch silently emitted a truncated row.)
        row = (
            f"| {r['arch']} | {r['shape']} | {r['kind']} "
            f"| {_fmt_s(rf['compute_s'])} | {_fmt_s(rf['memory_s'])} "
            f"| {_fmt_s(rf['collective_s'])} "
            f"| {rf['bottleneck'].replace('_s', '')} "
        )
        row += f"| {ratio:.2f} " if ratio else "| - "
        row += f"| {peak / 2**30:.2f}GiB |"
        out.append(row)
    return "\n".join(out)


def summarise(rows):
    ok = [r for r in rows if r.get("ok")]
    fail = [r for r in rows if not r.get("ok")]
    by_b = {}
    for r in ok:
        by_b.setdefault(r["roofline"]["bottleneck"], []).append(
            (r["arch"], r["shape"], r["mesh"]))
    return dict(total=len(rows), ok=len(ok), failed=len(fail),
                bottleneck_counts={k: len(v) for k, v in by_b.items()})


def main(argv=None):
    import argparse

    p = argparse.ArgumentParser()
    p.add_argument("--dir", default="experiments/dryrun")
    p.add_argument("--mesh", default="single")
    args = p.parse_args(argv)
    rows = load(args.dir)
    print(table(rows, args.mesh))
    print()
    print(json.dumps(summarise(rows), indent=1))


if __name__ == "__main__":
    main()
