"""Paper Fig. 5 / Table 2 protocol: 10-NN recall per dataset x distance x
method (PDASC vs IVF-Flat [FLANN stand-in] vs NN-Descent [PyNN stand-in]).

Datasets are the seeded surrogates (DESIGN.md §5); ground truth is exact
brute force under the same distance (paper §4.3). Sizes are scaled for this
CPU container (--full restores paper-scale n).
"""

from __future__ import annotations

import time

import numpy as np

from repro.baselines import IVFFlatIndex, NNDescentIndex, exact_knn
from repro.baselines.ivf_flat import SUPPORTED as IVF_SUPPORTED
from repro.core.index import PDASCIndex
from repro.data import make_dataset
from repro.query import Query

K = 10

# dataset -> (n, gl, radius_quantile, distances)
_BASE = {
    "geo_clusters": (4000, 60, 0.5,
                     ("manhattan", "euclidean", "chebyshev", "cosine",
                      "haversine")),
    "sparse_highdim": (4000, 256, 0.45,
                       ("manhattan", "euclidean", "chebyshev", "cosine")),
    "dense_embed": (8000, 256, 0.35,
                    ("manhattan", "euclidean", "chebyshev", "cosine")),
    "tfidf_like": (6000, 256, 0.35,
                   ("manhattan", "euclidean", "chebyshev", "cosine")),
}
_FULL_N = {"geo_clusters": 8130, "sparse_highdim": 69_000,
           "dense_embed": 1_000_000, "tfidf_like": 290_000}


def _recall(ids, gt):
    return float(np.mean([
        len(set(ids[i][ids[i] >= 0].tolist()) & set(gt[i].tolist())) / gt.shape[1]
        for i in range(len(gt))
    ]))


def run(full: bool = False, n_queries: int = 64, seed: int = 0):
    import jax

    rows = []
    for ds, (n, gl, rq, distances) in _BASE.items():
        jax.clear_caches()  # long runs exhaust the CPU JIT otherwise
        n = _FULL_N[ds] if full else n
        data = make_dataset(ds, n=n, seed=seed)
        n_train = n - n_queries
        train, test = data[:n_train], data[n_train:]
        for distance in distances:
            _, gt = exact_knn(test, train, distance=distance, k=K)
            gt = np.asarray(gt)

            # --- PDASC (the paper's method, k-medoids) -----------------------
            t0 = time.perf_counter()
            idx = PDASCIndex.build(train, gl=gl, distance=distance,
                                   radius_quantile=rq)
            t_build = time.perf_counter() - t0
            t0 = time.perf_counter()
            res = idx.plan(Query(k=K, execution="dense"))(test)
            t_search = time.perf_counter() - t0
            rows.append(dict(
                dataset=ds, distance=distance, method="pdasc",
                recall=_recall(np.asarray(res.ids), gt),
                build_s=round(t_build, 2),
                search_us_per_q=round(t_search / len(test) * 1e6, 1),
                candidates=int(np.asarray(res.n_candidates).mean()),
            ))

            # --- IVF-Flat (FLANN stand-in; limited distance support) ---------
            if distance in IVF_SUPPORTED:
                t0 = time.perf_counter()
                ivf = IVFFlatIndex.build(train, n_cells=max(16, n_train // 256),
                                         distance=distance)
                t_build = time.perf_counter() - t0
                t0 = time.perf_counter()
                _, ids = ivf.search(test, k=K, n_probe=8)
                t_search = time.perf_counter() - t0
                rows.append(dict(
                    dataset=ds, distance=distance, method="ivf_flat",
                    recall=_recall(ids, gt), build_s=round(t_build, 2),
                    search_us_per_q=round(t_search / len(test) * 1e6, 1),
                    candidates=-1,
                ))
            else:
                rows.append(dict(dataset=ds, distance=distance,
                                 method="ivf_flat", recall=float("nan"),
                                 build_s=float("nan"),
                                 search_us_per_q=float("nan"), candidates=-1))

            # --- NN-Descent (PyNN stand-in) ----------------------------------
            t0 = time.perf_counter()
            nnd = NNDescentIndex.build(train[:4000], n_neighbors=15,
                                       distance=distance, iters=5)
            t_build = time.perf_counter() - t0
            _, gt_nnd = exact_knn(test, train[:4000], distance=distance, k=K)
            t0 = time.perf_counter()
            _, ids = nnd.search(test, k=K, n_seeds=24, max_steps=40)
            t_search = time.perf_counter() - t0
            rows.append(dict(
                dataset=ds, distance=distance, method="nndescent",
                recall=_recall(ids, np.asarray(gt_nnd)),
                build_s=round(t_build, 2),
                search_us_per_q=round(t_search / len(test) * 1e6, 1),
                candidates=-1,
            ))
            print(f"[recall] {ds:16s} {distance:10s} "
                  + " ".join(f"{r['method']}={r['recall']:.3f}"
                             for r in rows[-3:]), flush=True)
    return rows


def main(argv=None):
    import argparse
    import json

    p = argparse.ArgumentParser()
    p.add_argument("--full", action="store_true")
    p.add_argument("--out", default="experiments/recall.json")
    args = p.parse_args(argv)
    rows = run(full=args.full)
    import os

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
