"""Storage substrate benchmark: tiered leaf store vs the dense resident path.

For the reference config (dense_embed, gl=256, euclidean, k=10, beam=32) it
records, per payload backend (dense fp32 / fp16 / int8 / packed int4 /
packed binary):

  * recall@10 against exact ground truth,
  * us/query (two-stage search includes the host-side granule fetch — that
    *is* the storage access being measured),
  * resident payload bytes/vector and the ratio vs the dense seed path,

into ``BENCH_store.json``, and asserts the headline acceptance bars: the
int8 payload tier at <= 0.30x the dense resident bytes/vector with recall@10
within 1% of ``search_beam``; the packed int4 tier at <= 0.5x the *int8*
resident bytes with recall@10 within 0.02 of the int8 two-stage run (the
rerank absorbing the extra quantisation loss); and ``rerank_width=None``
(∞) bit-identical to ``search_beam``. The default-vs-tuned kernel configs
the scan would use (``KernelConfig(auto=True)``, kernels/autotune.py) are
recorded alongside.

    PYTHONPATH=src python -m benchmarks.bench_store [--smoke]
        [--scenario tiers|remote|all]
        [--out experiments/store.json] [--bench-out BENCH_store.json]

``--smoke`` runs a tiny config (correctness assertions only, no wall-time
numbers recorded) so CI can catch storage-path regressions after the tier-1
suite, matching the ``bench_build.py --smoke`` step.

``--scenario remote`` exercises the out-of-core remote tier (DESIGN.md
§3.13): ``build_streaming`` consumes the dataset as shards that never
coexist in memory (the full run is >= 100x the smoke scale: 10 shards x
12288 rows = 122,880 rows), flushing exact fp32 granules into a
``SimulatedObjectStore``; two-stage serving then runs with the payload
behind the host LRU. Asserted: per-node resident bytes (quantised codes +
host cache, i.e. everything except the navigation tier that is inherently
O(n*d)) stay below a configured ceiling while ``remote_bytes`` carries the
whole payload; recall@10 within 0.02 of the same index served with an
in-memory exact payload. Recorded: the ceiling, cache hit ratio, prefetch
stats and recall into BENCH_store.json.
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import jax
import numpy as np

from benchmarks.bench_search import _recall
from repro.baselines import exact_knn
from repro.core.index import PDASCIndex
from repro.data import make_dataset
from repro.kernels import ops as kops
from repro.query import Query


def _timed(fn, n_queries: int, repeats: int = 3):
    """us/query over the best of ``repeats`` post-compile runs."""
    res = fn()  # compile
    jax.block_until_ready(res)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        res = fn()
        jax.block_until_ready(res)
        best = min(best, time.perf_counter() - t0)
    return res, best / n_queries * 1e6


def run(smoke: bool = False, seed: int = 0):
    if smoke:
        n, n_queries, gl, block, rerank, repeats = 1200, 64, 64, 64, 64, 1
    else:
        n, n_queries, gl, block, rerank, repeats = 7800, 512, 256, 256, 128, 3
    k, beam = 10, 32
    data = make_dataset("dense_embed", n=n + n_queries, seed=seed)
    train, test = data[:n], data[n:n + n_queries]
    _, gt = exact_knn(test, train, distance="euclidean", k=k)
    gt = np.asarray(gt)

    idx = PDASCIndex.build(train, gl=gl, distance="euclidean",
                           radius_quantile=0.35)
    n_points = idx.n_points
    mem_dense = idx.memory_bytes()
    dense_ppv = mem_dense["payload_bytes_per_vector"]
    print(f"[store] dense memory: {mem_dense}", flush=True)

    rows = []
    plan_beam = idx.plan(Query(k=k, execution="beam", beam=beam))
    res_beam, us_beam = _timed(lambda: plan_beam(test), n_queries, repeats)
    recall_beam = _recall(np.asarray(res_beam.ids), gt)
    rows.append(dict(
        bench="store", backend="fp32_dense", mode="beam",
        recall=recall_beam, us_per_q=round(us_beam, 1),
        payload_bytes_per_vector=dense_ppv, payload_ratio=1.0,
    ))
    print(f"[store] dense beam: recall {recall_beam:.4f} "
          f"{us_beam:.1f}us/q  {dense_ppv}B/vec", flush=True)

    tmp = tempfile.mkdtemp()
    for backend, path in (("fp16", None),
                          ("int8", os.path.join(tmp, "payload.bin")),
                          ("int4", None),
                          ("binary", None)):
        store = idx.attach_store(backend, block=block, path=path)
        # ∞ rerank must reproduce search_beam exactly (the acceptance gate).
        res_inf = idx.plan(Query(k=k, execution="two_stage", beam=beam,
                                 rerank_width=None))(test)
        np.testing.assert_array_equal(np.asarray(res_inf.ids),
                                      np.asarray(res_beam.ids))
        np.testing.assert_array_equal(np.asarray(res_inf.dists),
                                      np.asarray(res_beam.dists))
        plan_ts = idx.plan(Query(k=k, execution="two_stage", beam=beam,
                                 rerank_width=rerank))
        res_ts, us_ts = _timed(lambda: plan_ts(test), n_queries, repeats)
        recall_ts = _recall(np.asarray(res_ts.ids), gt)
        ppv = round(store.resident_bytes / n_points, 2)
        # codes alone (no per-block scales): the packed-format comparison
        # bar — the 4B/block scale overhead is identical across backends.
        codes_ppv = round(
            store.codes.size * store.codes.dtype.itemsize / n_points, 2
        )
        row = dict(
            bench="store", backend=backend, mode="two_stage",
            rerank_width=rerank, block=block,
            on_disk=store.exact.on_disk,
            code_format=store.code_format,
            recall=recall_ts, us_per_q=round(us_ts, 1),
            payload_bytes_per_vector=ppv,
            code_bytes_per_vector=codes_ppv,
            payload_ratio=round(ppv / dense_ppv, 4),
            recall_delta_vs_beam=round(recall_ts - recall_beam, 4),
        )
        rows.append(row)
        print(f"[store] {backend}{' (memmap)' if row['on_disk'] else ''}: "
              f"recall {recall_ts:.4f} (Δbeam {row['recall_delta_vs_beam']}) "
              f"{us_ts:.1f}us/q  {ppv}B/vec "
              f"({row['payload_ratio']}x dense)", flush=True)

    # Serving footprint: drop the resident fp32 leaf array (the int8 store
    # stays attached) — the per-node memory the paper's deployment budgets.
    idx.release_dense_payload()
    mem_rel = idx.memory_bytes()
    res_rel = idx.plan(Query(k=k, execution="two_stage", beam=beam,
                             rerank_width=rerank))(test)
    # res_ts is the int8 run (last loop iteration): releasing the dense copy
    # must not change two-stage results.
    np.testing.assert_array_equal(np.asarray(res_rel.ids),
                                  np.asarray(res_ts.ids))
    rows.append(dict(bench="memory_released", **mem_rel))
    print(f"[store] released memory: {mem_rel}", flush=True)

    # Default-vs-tuned kernel configs for the stage-1 scan per code dtype:
    # what the scan dispatch would use untouched vs under
    # KernelConfig(auto=True) (identical until a tuner cache is populated —
    # benchmarks/bench_kernels.py writes one).
    d_dim = train.shape[1]
    scan_shape = (n_queries, 512, d_dim)
    cfg_rows = {
        dtype_key: dict(
            default=kops.resolve_blocks("scan", "l2", dtype_key, scan_shape),
            tuned=kops.resolve_blocks("scan", "l2", dtype_key, scan_shape,
                                      kops.KernelConfig(auto=True)),
        )
        for dtype_key in ("int8", "int4", "binary")
    }
    rows.append(dict(bench="kernel_configs", op="scan",
                     shape=list(scan_shape), configs=cfg_rows))
    print(f"[store] scan kernel configs: {cfg_rows}", flush=True)

    int8_row = next(r for r in rows if r.get("backend") == "int8")
    assert int8_row["payload_ratio"] <= 0.30, (
        "int8 payload tier above the 0.30x resident bytes bar", int8_row)
    assert abs(int8_row["recall_delta_vs_beam"]) <= 0.01, (
        "int8 two-stage recall drifted >1% from search_beam", int8_row)
    # Packed int4 bars: half the int8 code bytes (exact: two codes/byte),
    # recall within 0.02 of the int8 two-stage run — the exact rerank
    # absorbing the coarser scan. Binary has no recall bar (sign-only scan
    # is a recall/memory trade the numbers document, not gate).
    int4_row = next(r for r in rows if r.get("backend") == "int4")
    # +0.01B slack: both sides are rounded to 2 decimals for the report, and
    # exactly-half values can round across the bar (50.665 -> 50.67).
    assert int4_row["code_bytes_per_vector"] <= (
        0.5 * int8_row["code_bytes_per_vector"] + 0.01
    ), ("int4 payload code bytes above half of int8", int4_row, int8_row)
    assert abs(int4_row["recall"] - int8_row["recall"]) <= 0.02, (
        "int4 two-stage recall drifted >0.02 from int8", int4_row, int8_row)
    return rows


def run_remote(smoke: bool = False, seed: int = 0):
    """The out-of-core remote scenario: streaming build + remote serving."""
    from repro.store import SimulatedObjectStore, build_streaming
    from repro.store.leaf_store import ExactSource

    if smoke:
        shard_rows, n_shards, n_queries = 2048, 3, 32
        gl, block, rerank, repeats = 64, 64, 64, 1
        cache_granules, latency_ms = 8, 0.0
    else:
        # >= 100x the tiers-scenario smoke scale (1200 rows): ten shards
        # of 12288 rows = 122,880 rows, never coexisting in host memory
        # on the build path.
        shard_rows, n_shards, n_queries = 12288, 10, 256
        gl, block, rerank, repeats = 256, 256, 128, 2
        cache_granules, latency_ms = 64, 0.2
    k, beam = 10, 32
    n = shard_rows * n_shards
    data = make_dataset("dense_embed", n=n + n_queries, seed=seed)
    train, test = data[:n], data[n:]
    d_dim = train.shape[1]
    _, gt = exact_knn(test, train, distance="euclidean", k=k)
    gt = np.asarray(gt)

    obj = SimulatedObjectStore(latency_ms=latency_ms, parallelism=8)

    def shards():
        for s in range(n_shards):
            yield train[s * shard_rows:(s + 1) * shard_rows]

    t0 = time.time()
    idx = build_streaming(
        shards(), gl=gl, remote=obj, distance="euclidean", store="int8",
        block=block, method="kmeans", radius_quantile=0.35,
        cache_granules=cache_granules,
    )
    build_s = time.time() - t0
    dense_payload = n * d_dim * 4
    # The resident ceiling covers the per-node *payload* memory: quantised
    # codes + scales + the bounded host cache of decoded granules. The
    # navigation tier is excluded — it is O(n*d) by construction (prototype
    # hierarchy) and identical across local/remote payload tiers.
    ceiling = int(0.40 * dense_payload)
    print(f"[store] remote: streamed {n_shards} shards ({n} rows) in "
          f"{build_s:.1f}s; {obj.total_bytes} bytes in object store",
          flush=True)

    plan = idx.plan(Query(k=k, execution="two_stage", beam=beam,
                          rerank_width=rerank))
    res, us_q = _timed(lambda: plan(test), n_queries, repeats)
    recall_remote = _recall(np.asarray(res.ids), gt)
    mem = idx.memory_bytes()
    resident = mem["payload"] + mem["host_cache"]
    src = idx.store.exact
    st = src.stats
    hit_ratio = (st["hits"] / max(st["hits"] + st["fetches"], 1))
    pf = src.pool.stats
    assert mem["remote_bytes"] == dense_payload, (
        "remote tier must carry the whole exact payload", mem)
    assert resident <= ceiling, (
        f"resident payload bytes {resident} above the configured ceiling "
        f"{ceiling} (codes+scales+host cache must stay bounded)", mem)

    # In-memory payload reference: the *same* index (codes, navigation,
    # radii all identical) served with the exact tier as a host array —
    # recall deltas isolate the remote tier, and equality of the fetched
    # bytes validates granule round-tripping.
    idx.store.exact = ExactSource(src.read_all(), block)
    idx._plan_cache = None  # capability fingerprint changed (remote flag)
    res_mem = idx.plan(Query(k=k, execution="two_stage", beam=beam,
                             rerank_width=rerank))(test)
    recall_mem = _recall(np.asarray(res_mem.ids), gt)
    src.close()
    delta = recall_remote - recall_mem
    assert abs(delta) <= 0.02, (
        "remote-payload recall drifted >0.02 from the in-memory path",
        recall_remote, recall_mem)

    row = dict(
        bench="store_remote", mode="two_stage_streaming",
        n=n, n_shards=n_shards, d=d_dim, gl=gl, block=block,
        rerank_width=rerank, remote_latency_ms=latency_ms,
        cache_granules=cache_granules,
        build_s=round(build_s, 2), us_per_q=round(us_q, 1),
        recall=recall_remote, recall_in_memory=recall_mem,
        recall_delta_vs_in_memory=round(delta, 4),
        resident_bytes=int(resident),
        resident_ceiling_bytes=ceiling,
        resident_ratio_vs_dense=round(resident / dense_payload, 4),
        remote_bytes=int(mem["remote_bytes"]),
        host_cache_bytes=int(mem["host_cache"]),
        cache_hit_ratio=round(hit_ratio, 4),
        cache_fetches=int(st["fetches"]), cache_hits=int(st["hits"]),
        prefetch=dict(pf),
        remote_ops=dict(obj.op_counts),
    )
    print(f"[store] remote serve: recall {recall_remote:.4f} "
          f"(Δin-memory {row['recall_delta_vs_in_memory']}) "
          f"{us_q:.1f}us/q  resident {resident}B "
          f"<= ceiling {ceiling}B ({row['resident_ratio_vs_dense']}x dense) "
          f"cache hit ratio {hit_ratio:.3f}", flush=True)
    return [row]


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="tiny config, correctness assertions only (CI)")
    p.add_argument("--scenario", default="tiers",
                   choices=["tiers", "remote", "all"],
                   help="tiers: quantised-backend sweep (the original "
                        "bench); remote: streaming build + remote payload "
                        "serving (DESIGN.md §3.13)")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--out", default="experiments/store.json")
    p.add_argument("--bench-out", default="BENCH_store.json")
    args = p.parse_args(argv)

    rows = []
    if args.scenario in ("tiers", "all"):
        rows += run(smoke=args.smoke, seed=args.seed)
    if args.scenario in ("remote", "all"):
        rows += run_remote(smoke=args.smoke, seed=args.seed)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    if args.smoke:
        return
    payload = None
    if args.scenario in ("tiers", "all"):
        int8_row = next(r for r in rows if r.get("backend") == "int8")
        int4_row = next(r for r in rows if r.get("backend") == "int4")
        payload = dict(
            bench="tiered_leaf_store_vs_dense_resident",
            backend=jax.default_backend(),
            config=dict(dataset="dense_embed", n=7800, n_queries=512,
                        gl=256, distance="euclidean", k=10, beam=32),
            baseline="search_beam over the dense resident fp32 leaf array "
                     "(the seed payload path)",
            new="two-stage search over the tiered leaf store: quantised "
                "payload scan (ops.scan_quantized, native dtype) -> exact "
                "fp32 rerank over the top-rerank_width from the out-of-core "
                "granule store",
            rows=rows,
            headline_payload_ratio=int8_row["payload_ratio"],
            headline_recall_delta=int8_row["recall_delta_vs_beam"],
            headline_int4_code_ratio_vs_int8=round(
                int4_row["code_bytes_per_vector"]
                / int8_row["code_bytes_per_vector"], 4
            ),
            headline_int4_recall_delta_vs_int8=round(
                int4_row["recall"] - int8_row["recall"], 4
            ),
        )
    if args.scenario in ("remote", "all"):
        remote_row = next(r for r in rows
                          if r.get("bench") == "store_remote")
        if payload is None:
            # remote-only invocation: extend the existing BENCH_store.json
            # (the tiers scenario's last full run) rather than clobber it
            try:
                with open(args.bench_out) as f:
                    payload = json.load(f)
            except (OSError, json.JSONDecodeError):
                payload = dict(bench="tiered_leaf_store_vs_dense_resident",
                               backend=jax.default_backend())
        payload["remote"] = remote_row
        payload["headline_remote_resident_ratio"] = \
            remote_row["resident_ratio_vs_dense"]
        payload["headline_remote_recall_delta"] = \
            remote_row["recall_delta_vs_in_memory"]
        payload["headline_remote_cache_hit_ratio"] = \
            remote_row["cache_hit_ratio"]
    with open(args.bench_out, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"[store] wrote {args.bench_out} (scenario={args.scenario})")


if __name__ == "__main__":
    main()
