"""Storage substrate benchmark: tiered leaf store vs the dense resident path.

For the reference config (dense_embed, gl=256, euclidean, k=10, beam=32) it
records, per payload backend (dense fp32 / fp16 / int8 / packed int4 /
packed binary):

  * recall@10 against exact ground truth,
  * us/query (two-stage search includes the host-side granule fetch — that
    *is* the storage access being measured),
  * resident payload bytes/vector and the ratio vs the dense seed path,

into ``BENCH_store.json``, and asserts the headline acceptance bars: the
int8 payload tier at <= 0.30x the dense resident bytes/vector with recall@10
within 1% of ``search_beam``; the packed int4 tier at <= 0.5x the *int8*
resident bytes with recall@10 within 0.02 of the int8 two-stage run (the
rerank absorbing the extra quantisation loss); and ``rerank_width=None``
(∞) bit-identical to ``search_beam``. The default-vs-tuned kernel configs
the scan would use (``KernelConfig(auto=True)``, kernels/autotune.py) are
recorded alongside.

    PYTHONPATH=src python -m benchmarks.bench_store [--smoke]
        [--out experiments/store.json] [--bench-out BENCH_store.json]

``--smoke`` runs a tiny config (correctness assertions only, no wall-time
numbers recorded) so CI can catch storage-path regressions after the tier-1
suite, matching the ``bench_build.py --smoke`` step.
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import jax
import numpy as np

from benchmarks.bench_search import _recall
from repro.baselines import exact_knn
from repro.core.index import PDASCIndex
from repro.data import make_dataset
from repro.kernels import ops as kops
from repro.query import Query


def _timed(fn, n_queries: int, repeats: int = 3):
    """us/query over the best of ``repeats`` post-compile runs."""
    res = fn()  # compile
    jax.block_until_ready(res)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        res = fn()
        jax.block_until_ready(res)
        best = min(best, time.perf_counter() - t0)
    return res, best / n_queries * 1e6


def run(smoke: bool = False, seed: int = 0):
    if smoke:
        n, n_queries, gl, block, rerank, repeats = 1200, 64, 64, 64, 64, 1
    else:
        n, n_queries, gl, block, rerank, repeats = 7800, 512, 256, 256, 128, 3
    k, beam = 10, 32
    data = make_dataset("dense_embed", n=n + n_queries, seed=seed)
    train, test = data[:n], data[n:n + n_queries]
    _, gt = exact_knn(test, train, distance="euclidean", k=k)
    gt = np.asarray(gt)

    idx = PDASCIndex.build(train, gl=gl, distance="euclidean",
                           radius_quantile=0.35)
    n_points = idx.n_points
    mem_dense = idx.memory_bytes()
    dense_ppv = mem_dense["payload_bytes_per_vector"]
    print(f"[store] dense memory: {mem_dense}", flush=True)

    rows = []
    plan_beam = idx.plan(Query(k=k, execution="beam", beam=beam))
    res_beam, us_beam = _timed(lambda: plan_beam(test), n_queries, repeats)
    recall_beam = _recall(np.asarray(res_beam.ids), gt)
    rows.append(dict(
        bench="store", backend="fp32_dense", mode="beam",
        recall=recall_beam, us_per_q=round(us_beam, 1),
        payload_bytes_per_vector=dense_ppv, payload_ratio=1.0,
    ))
    print(f"[store] dense beam: recall {recall_beam:.4f} "
          f"{us_beam:.1f}us/q  {dense_ppv}B/vec", flush=True)

    tmp = tempfile.mkdtemp()
    for backend, path in (("fp16", None),
                          ("int8", os.path.join(tmp, "payload.bin")),
                          ("int4", None),
                          ("binary", None)):
        store = idx.attach_store(backend, block=block, path=path)
        # ∞ rerank must reproduce search_beam exactly (the acceptance gate).
        res_inf = idx.plan(Query(k=k, execution="two_stage", beam=beam,
                                 rerank_width=None))(test)
        np.testing.assert_array_equal(np.asarray(res_inf.ids),
                                      np.asarray(res_beam.ids))
        np.testing.assert_array_equal(np.asarray(res_inf.dists),
                                      np.asarray(res_beam.dists))
        plan_ts = idx.plan(Query(k=k, execution="two_stage", beam=beam,
                                 rerank_width=rerank))
        res_ts, us_ts = _timed(lambda: plan_ts(test), n_queries, repeats)
        recall_ts = _recall(np.asarray(res_ts.ids), gt)
        ppv = round(store.resident_bytes / n_points, 2)
        # codes alone (no per-block scales): the packed-format comparison
        # bar — the 4B/block scale overhead is identical across backends.
        codes_ppv = round(
            store.codes.size * store.codes.dtype.itemsize / n_points, 2
        )
        row = dict(
            bench="store", backend=backend, mode="two_stage",
            rerank_width=rerank, block=block,
            on_disk=store.exact.on_disk,
            code_format=store.code_format,
            recall=recall_ts, us_per_q=round(us_ts, 1),
            payload_bytes_per_vector=ppv,
            code_bytes_per_vector=codes_ppv,
            payload_ratio=round(ppv / dense_ppv, 4),
            recall_delta_vs_beam=round(recall_ts - recall_beam, 4),
        )
        rows.append(row)
        print(f"[store] {backend}{' (memmap)' if row['on_disk'] else ''}: "
              f"recall {recall_ts:.4f} (Δbeam {row['recall_delta_vs_beam']}) "
              f"{us_ts:.1f}us/q  {ppv}B/vec "
              f"({row['payload_ratio']}x dense)", flush=True)

    # Serving footprint: drop the resident fp32 leaf array (the int8 store
    # stays attached) — the per-node memory the paper's deployment budgets.
    idx.release_dense_payload()
    mem_rel = idx.memory_bytes()
    res_rel = idx.plan(Query(k=k, execution="two_stage", beam=beam,
                             rerank_width=rerank))(test)
    # res_ts is the int8 run (last loop iteration): releasing the dense copy
    # must not change two-stage results.
    np.testing.assert_array_equal(np.asarray(res_rel.ids),
                                  np.asarray(res_ts.ids))
    rows.append(dict(bench="memory_released", **mem_rel))
    print(f"[store] released memory: {mem_rel}", flush=True)

    # Default-vs-tuned kernel configs for the stage-1 scan per code dtype:
    # what the scan dispatch would use untouched vs under
    # KernelConfig(auto=True) (identical until a tuner cache is populated —
    # benchmarks/bench_kernels.py writes one).
    d_dim = train.shape[1]
    scan_shape = (n_queries, 512, d_dim)
    cfg_rows = {
        dtype_key: dict(
            default=kops.resolve_blocks("scan", "l2", dtype_key, scan_shape),
            tuned=kops.resolve_blocks("scan", "l2", dtype_key, scan_shape,
                                      kops.KernelConfig(auto=True)),
        )
        for dtype_key in ("int8", "int4", "binary")
    }
    rows.append(dict(bench="kernel_configs", op="scan",
                     shape=list(scan_shape), configs=cfg_rows))
    print(f"[store] scan kernel configs: {cfg_rows}", flush=True)

    int8_row = next(r for r in rows if r.get("backend") == "int8")
    assert int8_row["payload_ratio"] <= 0.30, (
        "int8 payload tier above the 0.30x resident bytes bar", int8_row)
    assert abs(int8_row["recall_delta_vs_beam"]) <= 0.01, (
        "int8 two-stage recall drifted >1% from search_beam", int8_row)
    # Packed int4 bars: half the int8 code bytes (exact: two codes/byte),
    # recall within 0.02 of the int8 two-stage run — the exact rerank
    # absorbing the coarser scan. Binary has no recall bar (sign-only scan
    # is a recall/memory trade the numbers document, not gate).
    int4_row = next(r for r in rows if r.get("backend") == "int4")
    # +0.01B slack: both sides are rounded to 2 decimals for the report, and
    # exactly-half values can round across the bar (50.665 -> 50.67).
    assert int4_row["code_bytes_per_vector"] <= (
        0.5 * int8_row["code_bytes_per_vector"] + 0.01
    ), ("int4 payload code bytes above half of int8", int4_row, int8_row)
    assert abs(int4_row["recall"] - int8_row["recall"]) <= 0.02, (
        "int4 two-stage recall drifted >0.02 from int8", int4_row, int8_row)
    return rows


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="tiny config, correctness assertions only (CI)")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--out", default="experiments/store.json")
    p.add_argument("--bench-out", default="BENCH_store.json")
    args = p.parse_args(argv)

    rows = run(smoke=args.smoke, seed=args.seed)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    if not args.smoke:
        int8_row = next(r for r in rows if r.get("backend") == "int8")
        int4_row = next(r for r in rows if r.get("backend") == "int4")
        payload = dict(
            bench="tiered_leaf_store_vs_dense_resident",
            backend=jax.default_backend(),
            config=dict(dataset="dense_embed", n=7800, n_queries=512,
                        gl=256, distance="euclidean", k=10, beam=32),
            baseline="search_beam over the dense resident fp32 leaf array "
                     "(the seed payload path)",
            new="two-stage search over the tiered leaf store: quantised "
                "payload scan (ops.scan_quantized, native dtype) -> exact "
                "fp32 rerank over the top-rerank_width from the out-of-core "
                "granule store",
            rows=rows,
            headline_payload_ratio=int8_row["payload_ratio"],
            headline_recall_delta=int8_row["recall_delta_vs_beam"],
            headline_int4_code_ratio_vs_int8=round(
                int4_row["code_bytes_per_vector"]
                / int8_row["code_bytes_per_vector"], 4
            ),
            headline_int4_recall_delta_vs_int8=round(
                int4_row["recall"] - int8_row["recall"], 4
            ),
        )
        with open(args.bench_out, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"[store] wrote {args.bench_out}: int8 payload "
              f"{int8_row['payload_ratio']}x dense, recall delta "
              f"{int8_row['recall_delta_vs_beam']}")


if __name__ == "__main__":
    main()
