"""Render the §Reproduction recall table (markdown) from
experiments/bench_results.json — the paper's Fig. 5 as a table."""

from __future__ import annotations

import json


def render(path="experiments/bench_results.json") -> str:
    rows = json.load(open(path))["recall"]
    datasets, distances = [], []
    for r in rows:
        if r["dataset"] not in datasets:
            datasets.append(r["dataset"])
        if r["distance"] not in distances:
            distances.append(r["distance"])
    by = {(r["dataset"], r["distance"], r["method"]): r for r in rows}
    out = ["| dataset | distance | PDASC | IVF-Flat (FLANN~) | NN-Descent (PyNN~) | PDASC candidates |",
           "|---|---|---|---|---|---|"]
    for ds in datasets:
        for d in distances:
            p = by.get((ds, d, "pdasc"))
            if p is None:
                continue
            i = by.get((ds, d, "ivf_flat"))
            n = by.get((ds, d, "nndescent"))

            def fmt(r):
                if r is None or r["recall"] != r["recall"]:  # NaN
                    return "unsupported"
                return f"{r['recall']:.3f}"

            out.append(
                f"| {ds} | {d} | **{p['recall']:.3f}** | {fmt(i)} | {fmt(n)} "
                f"| {p['candidates']} |")
    return "\n".join(out)


if __name__ == "__main__":
    print(render())
