"""Benchmark aggregator: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only recall,build]

Emits ``name,value,derived`` CSV lines per row + writes JSON artifacts under
experiments/. The roofline table itself comes from the (separately run)
dry-run: ``python -m repro.launch.dryrun --all`` then
``python -m benchmarks.roofline_report``.
"""

from __future__ import annotations

import argparse
import json
import os
import time


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--full", action="store_true")
    p.add_argument("--only", default=None,
                   help="comma list: recall,build,search,retrieval,store")
    args = p.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None
    os.makedirs("experiments", exist_ok=True)

    t_start = time.time()
    results = {}

    def want(name):
        return only is None or name in only

    if want("build"):
        from benchmarks import bench_build

        results["build"] = bench_build.run()
    if want("search"):
        from benchmarks import bench_search

        results["search"] = bench_search.run()
    if want("retrieval"):
        from benchmarks import bench_retrieval

        results["retrieval"] = bench_retrieval.run()
    if want("store"):
        from benchmarks import bench_store

        results["store"] = bench_store.run()
    if want("recall"):
        from benchmarks import bench_recall

        results["recall"] = bench_recall.run(full=args.full)

    print("\n==== CSV ====")
    for bench, rows in results.items():
        for r in rows:
            key = ",".join(str(r.get(c)) for c in ("dataset", "distance",
                                                   "method", "mode", "beam",
                                                   "gl", "name", "quantile")
                           if r.get(c) is not None)
            val = r.get("recall", r.get("us_per_q", r.get("build_s", "")))
            derived = {k: v for k, v in r.items()
                       if k not in ("dataset", "distance", "method", "bench")}
            print(f"{bench}:{key},{val},{json.dumps(derived)}")

    with open("experiments/bench_results.json", "w") as f:
        json.dump(results, f, indent=1)
    print(f"\nall benchmarks done in {time.time() - t_start:.0f}s "
          f"-> experiments/bench_results.json")


if __name__ == "__main__":
    main()
