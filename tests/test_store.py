"""Tiered leaf store (DESIGN.md §3.6): quantisation bounds, scan-kernel
parity, two-stage search equivalence / recall, out-of-core backends,
format-v2 persistence and the storage-aware serving hooks."""

import json
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_in_devices
from repro.core import distances as dl
from repro.core import nsa
from repro.core.index import PDASCIndex
from repro.kernels import ops, ref as kref
from repro.serving import BatchingEngine
from repro.store import ExactSource, LeafStore, dequantize, quantize

SCAN_FORMS = ["l2", "sqeuclidean", "cosine", "dot", "l1", "chebyshev"]


def _points(n=300, d=9, seed=7):
    return np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32)


def _scales_rows(scales, cand_idx, block):
    return jnp.take(scales, jnp.clip(cand_idx // block, 0, scales.shape[0] - 1))


# ---------------------------------------------------------------------------
# Quantisation round trip
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("block", [32, 100, 512])
def test_int8_roundtrip_error_bounded_by_half_scale(block):
    x = _points()
    codes, scales = quantize(x, "int8", block)
    xr = np.asarray(dequantize(codes, scales, block))
    s_rows = np.asarray(scales)[np.minimum(
        np.arange(len(x)) // block, len(np.asarray(scales)) - 1)]
    # symmetric round-to-nearest: per-coordinate error <= scale/2
    assert (np.abs(xr - x) <= s_rows[:, None] * 0.5 + 1e-7).all()
    assert codes.dtype == jnp.int8
    assert int(jnp.max(jnp.abs(codes.astype(jnp.int32)))) <= 127


def test_fp16_roundtrip_near_exact():
    x = _points()
    codes, scales = quantize(x, "fp16", 64)
    xr = np.asarray(dequantize(codes, scales, 64))
    np.testing.assert_allclose(xr, x, rtol=1e-3, atol=1e-3)
    np.testing.assert_array_equal(np.asarray(scales), 1.0)


@pytest.mark.parametrize("n,d,block", [
    (1, 1, 1), (1, 16, 90), (80, 3, 7), (79, 16, 80), (64, 8, 64),
    (33, 5, 90),
])
def test_int8_roundtrip_shape_sweep(n, d, block):
    """Odd shapes / short last blocks / block > n all stay within bound."""
    x = np.random.default_rng(n * 31 + d).normal(size=(n, d)).astype(np.float32)
    codes, scales = quantize(x, "int8", block)
    xr = np.asarray(dequantize(codes, scales, block))
    bound = float(np.asarray(scales).max()) * 0.5 + 1e-7
    assert np.abs(xr - x).max() <= bound


def test_quantize_rejects_unknown_backend():
    with pytest.raises(ValueError):
        quantize(_points(8), "int2", 4)
    with pytest.raises(ValueError):
        LeafStore.create(_points(8), "int2")


# ---------------------------------------------------------------------------
# Packed backends (int4 / binary): round trip + container geometry
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("block", [32, 100, 512])
def test_int4_roundtrip_error_bounded_by_half_scale(block):
    x = _points()
    codes, scales = quantize(x, "int4", block)
    # packed container: two codes per int8 byte
    assert codes.dtype == jnp.int8
    assert codes.shape == (len(x), -(-x.shape[1] // 2))
    xr = np.asarray(dequantize(codes, scales, block,
                               code_format="int4", d=x.shape[1]))
    s_rows = np.asarray(scales)[np.minimum(
        np.arange(len(x)) // block, len(np.asarray(scales)) - 1)]
    # symmetric round-to-nearest at 3 magnitude bits: error <= scale/2
    assert (np.abs(xr - x) <= s_rows[:, None] * 0.5 + 1e-7).all()
    # unpacked codes stay in the signed-nibble range
    cu = np.asarray(kref.unpack_codes(codes, "int4", x.shape[1]))
    assert cu.min() >= -7 and cu.max() <= 7


@pytest.mark.parametrize("n,d,block", [
    (1, 1, 1), (1, 16, 90), (80, 3, 7), (79, 16, 80), (33, 5, 90),
])
def test_int4_roundtrip_shape_sweep(n, d, block):
    """Odd d (padded nibble), short last blocks, block > n stay bounded."""
    x = np.random.default_rng(n * 31 + d).normal(size=(n, d)).astype(np.float32)
    codes, scales = quantize(x, "int4", block)
    assert codes.shape == (n, -(-d // 2))
    xr = np.asarray(dequantize(codes, scales, block, code_format="int4", d=d))
    bound = float(np.asarray(scales).max()) * 0.5 + 1e-7
    assert np.abs(xr - x).max() <= bound


def test_binary_roundtrip_signs_and_scale(block=32):
    x = _points()
    codes, scales = quantize(x, "binary", block)
    # packed container: eight sign bits per uint8 byte
    assert codes.dtype == jnp.uint8
    assert codes.shape == (len(x), -(-x.shape[1] // 8))
    xr = np.asarray(dequantize(codes, scales, block,
                               code_format="binary", d=x.shape[1]))
    # every dequantised entry is ±scale_b with the sign of the input
    np.testing.assert_array_equal(np.sign(xr), np.where(x >= 0, 1.0, -1.0))
    s_rows = np.asarray(scales)[np.minimum(
        np.arange(len(x)) // block, len(np.asarray(scales)) - 1)]
    np.testing.assert_allclose(np.abs(xr), s_rows[:, None].repeat(
        x.shape[1], axis=1), rtol=1e-6)
    # per-block scale is mean |x| over the block's real rows
    np.testing.assert_allclose(
        float(np.asarray(scales)[0]), np.abs(x[:block]).mean(), rtol=1e-5)


def test_packed_dequantize_requires_d():
    codes, scales = quantize(_points(16, 8), "int4", 8)
    with pytest.raises(ValueError):
        dequantize(codes, scales, 8, code_format="int4")


def test_packed_resident_bytes_halve_and_eighth():
    """int4 codes are exactly half the int8 code bytes; binary an eighth
    (d divisible by 8 here, so no padding slack)."""
    x = _points(256, 16)
    s8 = LeafStore.create(x, "int8", block=64)
    s4 = LeafStore.create(x, "int4", block=64)
    sb = LeafStore.create(x, "binary", block=64)
    bytes8 = s8.codes.size * s8.codes.dtype.itemsize
    assert s4.codes.size * s4.codes.dtype.itemsize * 2 == bytes8
    assert sb.codes.size * sb.codes.dtype.itemsize * 8 == bytes8
    assert s4.code_format == "int4" and sb.code_format == "binary"
    assert s8.code_format == "dense"


# ---------------------------------------------------------------------------
# scan_quantized: interpret-mode kernel parity vs the ref.py oracle
# ---------------------------------------------------------------------------


_BACKEND_FMT = {"int8": "dense", "fp16": "dense",
                "int4": "int4", "binary": "binary"}


@pytest.mark.parametrize("form", SCAN_FORMS)
@pytest.mark.parametrize("backend", ["int8", "fp16", "int4", "binary"])
def test_scan_kernel_parity(form, backend):
    rng = np.random.default_rng(11)
    n, d, b, w, k, block = 300, 9, 13, 37, 6, 32
    fmt = _BACKEND_FMT[backend]
    codes, scales = quantize(_points(n, d), backend, block)
    Q = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    ci = jnp.asarray(rng.integers(0, n, size=(b, w)), jnp.int32)
    ok = jnp.asarray(rng.random(size=(b, w)) > 0.2)
    gd, gi = ops.scan_quantized(Q, codes, scales, ci, ok, form, k=k,
                                block=block, code_format=fmt,
                                force_pallas=True, bq=4, bn=16)
    wd, wi = kref.scan_quantized_ref(
        Q, jnp.take(codes, ci, axis=0), _scales_rows(scales, ci, block),
        ok, k, form, fmt=fmt)
    np.testing.assert_allclose(np.asarray(gd), np.asarray(wd),
                               rtol=2e-4, atol=2e-4)
    # slots agree where distances are distinct (ties may permute)
    g, r = np.asarray(gd), np.asarray(wd)
    same = np.isclose(g, r, rtol=2e-4, atol=2e-4)
    assert same.all()
    # slot contract: always in [0, w)
    assert ((np.asarray(gi) >= 0) & (np.asarray(gi) < w)).all()


@pytest.mark.parametrize("backend", ["int8", "int4", "binary"])
def test_scan_kernel_vmapped_parity(backend):
    """vmap over an outer batch axis lifts into the kernel grid."""
    rng = np.random.default_rng(12)
    n, d, b, w, k, block = 200, 7, 6, 25, 5, 32
    fmt = _BACKEND_FMT[backend]
    codes, scales = quantize(_points(n, d), backend, block)
    Qv = jnp.asarray(rng.normal(size=(3, b, d)).astype(np.float32))
    civ = jnp.asarray(rng.integers(0, n, size=(3, b, w)), jnp.int32)
    okv = jnp.asarray(rng.random(size=(3, b, w)) > 0.2)
    gd, _ = jax.vmap(
        lambda q, ci, ok: ops.scan_quantized(
            q, codes, scales, ci, ok, "l2", k=k, block=block,
            code_format=fmt, force_pallas=True, bq=4, bn=16)
    )(Qv, civ, okv)
    wd, _ = jax.vmap(
        lambda q, ci, ok: kref.scan_quantized_ref(
            q, jnp.take(codes, ci, axis=0), _scales_rows(scales, ci, block),
            ok, k, "l2", fmt=fmt)
    )(Qv, civ, okv)
    np.testing.assert_allclose(np.asarray(gd), np.asarray(wd),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("backend", ["int4", "binary"])
def test_scan_packed_masked_slots_rank_big(backend):
    codes, scales = quantize(_points(50, 9), backend, 16)
    Q = jnp.zeros((2, 9))
    ci = jnp.zeros((2, 8), jnp.int32)
    ok = jnp.zeros((2, 8), bool)  # everything masked
    d, s = ops.scan_quantized(Q, codes, scales, ci, ok, "l2", k=3, block=16,
                              code_format=_BACKEND_FMT[backend],
                              force_pallas=True, bq=4, bn=16)
    assert (np.asarray(d) >= kref.BIG / 2).all()
    assert ((np.asarray(s) >= 0) & (np.asarray(s) < 8)).all()


def test_scan_masked_slots_rank_big():
    codes, scales = quantize(_points(50, 4), "int8", 16)
    Q = jnp.zeros((2, 4))
    ci = jnp.zeros((2, 8), jnp.int32)
    ok = jnp.zeros((2, 8), bool)  # everything masked
    d, s = ops.scan_quantized(Q, codes, scales, ci, ok, "l2", k=3, block=16)
    assert (np.asarray(d) >= kref.BIG / 2).all()
    assert ((np.asarray(s) >= 0) & (np.asarray(s) < 8)).all()


def test_scan_registry_fallback_non_kernel_form():
    """Non-kernelised distances stay functional (registry fallback)."""
    x = np.abs(_points(60, 5))
    codes, scales = quantize(x, "int8", 16)
    Q = jnp.asarray(np.abs(_points(3, 5, seed=1)))
    ci = jnp.asarray(np.random.default_rng(2).integers(0, 60, (3, 10)),
                     jnp.int32)
    ok = jnp.ones((3, 10), bool)
    d, s = ops.scan_quantized(Q, codes, scales, ci, ok, "fractional05",
                              k=4, block=16)
    assert np.isfinite(np.asarray(d)).all()


# ---------------------------------------------------------------------------
# Two-stage search over the tiered store
# ---------------------------------------------------------------------------


def _build_index(n=600, d=12, gl=48, seed=0, **kw):
    data = np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32)
    idx = PDASCIndex.build(data, gl=gl, distance="euclidean",
                           radius_quantile=0.4, **kw)
    return data, idx


def test_two_stage_infinite_rerank_bit_identical_to_beam():
    """The acceptance gate: rerank_width=∞ reproduces search_beam exactly
    (dists, ids and candidate counts are equal arrays)."""
    data, idx = _build_index(store="int8", store_block=64)
    Q = data[:16]
    beam = idx.search(Q, k=5, mode="beam", beam=16)
    for width in (None, 0):
        ts = idx.search(Q, k=5, mode="two_stage", beam=16, rerank_width=width)
        np.testing.assert_array_equal(np.asarray(beam.dists),
                                      np.asarray(ts.dists))
        np.testing.assert_array_equal(np.asarray(beam.ids), np.asarray(ts.ids))
        np.testing.assert_array_equal(np.asarray(beam.n_candidates),
                                      np.asarray(ts.n_candidates))


def test_two_stage_recall_guard_vs_beam():
    """Seed-config recall guard: at the same beam, int8 scan + exact rerank
    stays within 1% of the dense-payload ``search_beam`` it replaces (any
    further gap to ``search_dense`` is beam pruning, present in both)."""
    data, idx = _build_index(n=800, store="int8", store_block=64)
    Q = data[:40]
    beam = idx.search(Q, k=10, mode="beam", beam=32)
    ts = idx.search(Q, k=10, mode="two_stage", beam=32, rerank_width=64)
    b_ids, t_ids = np.asarray(beam.ids), np.asarray(ts.ids)
    per_q = [
        len(set(t_ids[i][t_ids[i] >= 0]) & set(b_ids[i][b_ids[i] >= 0]))
        / (b_ids[i] >= 0).sum()
        for i in range(len(Q))
        if (b_ids[i] >= 0).any()  # empty rows (nothing in radius) carry no signal
    ]
    assert per_q and np.mean(per_q) >= 0.99, np.mean(per_q)


def test_two_stage_packed_recall_guard_vs_int8():
    """The rerank absorbs the coarser int4 scan: at the same beam /
    rerank width, int4 two-stage recall stays within 0.02 of the int8
    two-stage run (the PR acceptance bar); binary still returns full,
    plausible results (its recall is a documented trade, not a gate)."""
    data, idx = _build_index(n=800, store="int8", store_block=64)
    Q = data[:40]
    k = 10

    def _run():
        return idx.search(Q, k=k, mode="two_stage", beam=32, rerank_width=64)

    def _recall(res, ref):
        a, b = np.asarray(res.ids), np.asarray(ref.ids)
        per_q = [
            len(set(a[i][a[i] >= 0]) & set(b[i][b[i] >= 0]))
            / (b[i] >= 0).sum()
            for i in range(len(Q)) if (b[i] >= 0).any()
        ]
        return float(np.mean(per_q))

    ts8 = _run()
    idx.attach_store("int4", block=64)
    ts4 = _run()
    assert _recall(ts4, ts8) >= 0.98, _recall(ts4, ts8)
    idx.attach_store("binary", block=64)
    tsb = _run()
    ids_b = np.asarray(tsb.ids)
    assert ids_b.shape == (len(Q), k)
    # reported distances are exact (stage-2 rerank), so they stay sorted
    # (inf - inf = nan in the padded tail of a short row: also fine)
    db = np.asarray(tsb.dists)
    dif = np.diff(np.where(db < kref.BIG / 2, db, np.inf), axis=1)
    assert (np.isnan(dif) | (dif >= -1e-6)).all()


def test_two_stage_fp16_store_and_fp32_store():
    data, idx = _build_index()
    Q = data[:8]
    beam = idx.search(Q, k=5, mode="beam", beam=16)
    idx.attach_store("fp16", block=64)
    ts16 = idx.search(Q, k=5, mode="two_stage", beam=16, rerank_width=48)
    b_ids, t_ids = np.asarray(beam.ids), np.asarray(ts16.ids)
    overlap = np.mean([
        len(set(t_ids[i]) & set(b_ids[i])) / 5 for i in range(len(Q))
    ])
    assert overlap >= 0.95, overlap  # fp16 scan orders the field near-exactly
    # fp32 store: no approximate tier; always the dense-equivalent path
    idx.attach_store("fp32", block=64)
    ts32 = idx.search(Q, k=5, mode="two_stage", beam=16, rerank_width=8)
    np.testing.assert_array_equal(np.asarray(beam.dists),
                                  np.asarray(ts32.dists))
    np.testing.assert_array_equal(np.asarray(beam.ids), np.asarray(ts32.ids))


def test_memmap_store_equals_in_memory(tmp_path):
    data, idx = _build_index(store="int8", store_block=64)
    Q = data[:12]
    res_mem = idx.search(Q, k=5, mode="two_stage", beam=16, rerank_width=32)
    idx.attach_store("int8", block=64, path=str(tmp_path / "payload.bin"),
                     cache_granules=2)  # tiny cache: force granule eviction
    assert idx.store.exact.on_disk
    res_mm = idx.search(Q, k=5, mode="two_stage", beam=16, rerank_width=32)
    np.testing.assert_array_equal(np.asarray(res_mem.dists),
                                  np.asarray(res_mm.dists))
    np.testing.assert_array_equal(np.asarray(res_mem.ids),
                                  np.asarray(res_mm.ids))
    assert idx.store.exact.stats["fetches"] > 0


def test_release_dense_payload_memory_and_search():
    data, idx = _build_index(store="int8", store_block=64)
    Q = data[:10]
    before = idx.memory_bytes()
    ts = idx.search(Q, k=5, mode="two_stage", beam=16, rerank_width=32)
    idx.release_dense_payload()
    after = idx.memory_bytes()
    # int8 payload tier <= 0.30x the dense resident payload (the bench bar)
    dense_payload = before["payload"] - idx.store.resident_bytes
    assert after["payload"] <= 0.30 * dense_payload
    assert after["total_resident"] < before["total_resident"]
    assert after["out_of_core"] == dense_payload
    ts2 = idx.search(Q, k=5, mode="two_stage", beam=16, rerank_width=32)
    np.testing.assert_array_equal(np.asarray(ts.ids), np.asarray(ts2.ids))
    with pytest.raises(ValueError, match="released"):
        idx.search(Q, k=5, mode="beam")
    with pytest.raises(ValueError, match="released"):
        idx.attach_store("fp16")


def test_rerank_width_below_k_still_returns_k_results():
    """rerank_width bounds fetch traffic, never the result count: a width
    below k is clamped so every query still gets k neighbours."""
    data, idx = _build_index(store="int8", store_block=64)
    res = idx.search(data[:8], k=10, mode="two_stage", beam=32,
                     rerank_width=2)
    ids = np.asarray(res.ids)
    assert ids.shape == (8, 10)
    # self-query with a generous pool: a full k of real neighbours
    assert (ids[np.asarray(res.dists) < 1e29] >= 0).all()
    assert (ids >= 0).sum(axis=1).min() >= 5


def test_two_stage_requires_store():
    data, idx = _build_index()
    with pytest.raises(ValueError, match="two_stage"):
        idx.search(data[:2], k=3, mode="two_stage")


def test_descend_beam_matches_beam_candidates():
    """descend_beam is the shared stage 0: its candidate table feeds both
    the fused leaf rank and the quantised scan."""
    data, idx = _build_index()
    dist = dl.get("euclidean")
    Q = jnp.asarray(data[:6])
    ci, ok = nsa.descend_beam(idx.data, Q, dist=dist, r=idx.default_radius,
                              beam=16, max_children=idx.max_children)
    assert ci.shape == ok.shape and ci.ndim == 2
    # every beam result id must be reachable from the candidate table
    res = idx.search(data[:6], k=5, mode="beam", beam=16)
    leaf_ids = np.asarray(idx.data.leaf_ids)
    cand_ids = leaf_ids[np.asarray(ci)]
    cand_ids = np.where(np.asarray(ok), cand_ids, -2)
    for i in range(6):
        got = set(np.asarray(res.ids[i]).tolist()) - {-1}
        assert got <= set(cand_ids[i].tolist())


# ---------------------------------------------------------------------------
# Persistence: format v2 + v1 compatibility
# ---------------------------------------------------------------------------


def test_save_load_v2_roundtrip_quantized_payload(tmp_path):
    data, idx = _build_index(store="int8", store_block=64)
    res1 = idx.search(data[:6], k=5, mode="two_stage", beam=16,
                      rerank_width=32)
    path = str(tmp_path / "idx")
    idx.save(path)
    meta = json.load(open(path + ".json"))
    assert meta["version"] == 2
    assert meta["store"] == {"backend": "int8", "block": 64}
    idx2 = PDASCIndex.load(path)
    assert idx2.store is not None and idx2.store.backend == "int8"
    np.testing.assert_array_equal(np.asarray(idx.store.codes),
                                  np.asarray(idx2.store.codes))
    np.testing.assert_array_equal(np.asarray(idx.store.scales),
                                  np.asarray(idx2.store.scales))
    res2 = idx2.search(data[:6], k=5, mode="two_stage", beam=16,
                       rerank_width=32)
    np.testing.assert_array_equal(np.asarray(res1.ids), np.asarray(res2.ids))
    np.testing.assert_array_equal(np.asarray(res1.dists),
                                  np.asarray(res2.dists))


@pytest.mark.parametrize("backend", ["int4", "binary"])
def test_save_load_v4_roundtrip_packed_payload(tmp_path, backend):
    """Packed backends persist as format v4 — packed containers verbatim —
    and searches round-trip; v2/v3 artifacts are untouched (the dense-code
    test above still writes and reads version 2)."""
    data, idx = _build_index(store=backend, store_block=64)
    res1 = idx.search(data[:6], k=5, mode="two_stage", beam=16,
                      rerank_width=32)
    path = str(tmp_path / "idx")
    idx.save(path)
    meta = json.load(open(path + ".json"))
    assert meta["version"] == 4
    assert meta["store"] == {"backend": backend, "block": 64}
    idx2 = PDASCIndex.load(path)
    assert idx2.store.backend == backend
    assert idx2.store.code_format == backend
    assert idx2.store.codes.dtype == idx.store.codes.dtype
    np.testing.assert_array_equal(np.asarray(idx.store.codes),
                                  np.asarray(idx2.store.codes))
    np.testing.assert_array_equal(np.asarray(idx.store.scales),
                                  np.asarray(idx2.store.scales))
    res2 = idx2.search(data[:6], k=5, mode="two_stage", beam=16,
                       rerank_width=32)
    np.testing.assert_array_equal(np.asarray(res1.ids), np.asarray(res2.ids))
    np.testing.assert_array_equal(np.asarray(res1.dists),
                                  np.asarray(res2.dists))


def test_save_load_of_released_index_is_self_contained(tmp_path):
    data, idx = _build_index(store="int8", store_block=64)
    res1 = idx.search(data[:6], k=5, mode="beam")
    idx.release_dense_payload()
    path = str(tmp_path / "idx")
    idx.save(path)  # level0 points restored from the out-of-core source
    idx2 = PDASCIndex.load(path)
    res2 = idx2.search(data[:6], k=5, mode="beam")  # dense payload is back
    np.testing.assert_array_equal(np.asarray(res1.ids), np.asarray(res2.ids))


def test_v1_artifact_loads_with_dense_payload(tmp_path):
    """v1 artifacts (no store metadata) still load: the payload tier
    defaults to the dense fp32 leaf array."""
    data, idx = _build_index()
    res1 = idx.search(data[:6], k=5)
    path = str(tmp_path / "idx")
    idx.save(path)
    meta = json.load(open(path + ".json"))
    meta["version"] = 1
    meta.pop("store")
    json.dump(meta, open(path + ".json", "w"))
    idx1 = PDASCIndex.load(path)
    assert idx1.store is None
    res2 = idx1.search(data[:6], k=5)
    np.testing.assert_array_equal(np.asarray(res1.ids), np.asarray(res2.ids))


def test_unknown_version_raises_clear_error(tmp_path):
    data, idx = _build_index()
    path = str(tmp_path / "idx")
    idx.save(path)
    meta = json.load(open(path + ".json"))
    meta["version"] = 99
    json.dump(meta, open(path + ".json", "w"))
    with pytest.raises(ValueError, match="version"):
        PDASCIndex.load(path)
    del meta["version"]
    json.dump(meta, open(path + ".json", "w"))
    with pytest.raises(ValueError, match="version"):  # not a KeyError
        PDASCIndex.load(path)


# ---------------------------------------------------------------------------
# Exact source: granule fetch + cache
# ---------------------------------------------------------------------------


def test_exact_source_granule_cache_and_prefetch():
    x = _points(128, 4)
    src = ExactSource(x, block=16, cache_granules=4)
    src.prefetch([0, 1])
    assert src.stats["fetches"] == 2
    out = src.fetch_rows(np.array([0, 5, 17, 31]))
    np.testing.assert_array_equal(out, x[[0, 5, 17, 31]])
    assert src.stats["hits"] >= 2  # granules 0 and 1 were prewarmed
    # eviction: touching > cache_granules distinct granules stays correct
    out = src.fetch_rows(np.arange(128))
    np.testing.assert_array_equal(out, x)


def test_store_prefetch_rows_threadsafe():
    x = _points(256, 4)
    st_ = LeafStore.create(x, "int8", block=32, cache_granules=8)
    rows = np.random.default_rng(0).integers(0, 256, (4, 64))
    threads = [threading.Thread(target=st_.prefetch_rows, args=(rows,))
               for _ in range(4)]
    [t.start() for t in threads]
    [t.join() for t in threads]
    np.testing.assert_array_equal(st_.fetch_rows(rows), x[rows])


# ---------------------------------------------------------------------------
# Serving: submit-after-close + prefetch hook
# ---------------------------------------------------------------------------


def test_engine_submit_after_close_raises():
    eng = BatchingEngine(lambda b, n: b, batch_size=2, max_wait_ms=5)
    req = eng.submit({"x": np.zeros(2, np.float32)})
    req.wait(timeout=10)
    eng.close()
    with pytest.raises(RuntimeError, match="closed"):
        eng.submit({"x": np.zeros(2, np.float32)})


def test_engine_prefetch_hook_sees_queued_payloads():
    seen = []
    release = threading.Event()

    def handler(batch, n_valid):
        release.wait(timeout=5)  # hold the first batch so a queue builds up
        return {"y": batch["x"]}

    eng = BatchingEngine(handler, batch_size=1, max_wait_ms=1,
                         prefetch_fn=lambda ps: seen.append(len(ps)))
    reqs = [eng.submit({"x": np.full(2, i, np.float32)}) for i in range(6)]
    time.sleep(0.05)
    release.set()
    for r in reqs:
        r.wait(timeout=10)
    eng.close()
    assert eng.stats["prefetches"] >= 1
    assert seen and max(seen) >= 1  # a snapshot of queued payloads arrived


# ---------------------------------------------------------------------------
# Distributed: payload tier sharded, navigation replicated
# ---------------------------------------------------------------------------


def test_sharded_payload_scan_matches_single_device():
    out = run_in_devices("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import distributed as dd
from repro.kernels import ops
from repro.launch.mesh import make_mesh
from repro.store import LeafStore

mesh = make_mesh((4,), ("data",))
rng = np.random.default_rng(3)
n, d, b, w, k, block = 512, 8, 6, 40, 9, 32
pts = rng.normal(size=(n, d)).astype(np.float32)
store = LeafStore.create(pts, "int8", block=block)
codes3, scales2 = dd.shard_payload(store, mesh, db_axes=("data",))
assert codes3.shape == (4, 128, d) and scales2.shape == (4, 128 // block)
Q = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
ci = jnp.asarray(rng.integers(0, n, size=(b, w)), jnp.int32)
ok = jnp.asarray(rng.random(size=(b, w)) > 0.15)
gd, gs = dd.scan_quantized_sharded(codes3, scales2, Q, ci, ok, mesh,
                                   db_axes=("data",), distance="l2", k=k,
                                   block=block)
wd, slot = ops.scan_quantized(Q, store.codes, store.scales, ci, ok, "l2",
                              k=k, block=block)
ws = np.where(np.asarray(wd) < 1e29, np.asarray(
    jnp.take_along_axis(ci, slot, axis=1)), -1)
np.testing.assert_allclose(np.asarray(gd), np.asarray(wd), rtol=1e-5,
                           atol=1e-5)
for i in range(b):
    assert set(np.asarray(gs[i]).tolist()) == set(ws[i].tolist()), i
print("SHARDED_SCAN_OK")
""")
    assert "SHARDED_SCAN_OK" in out


def test_sharded_packed_int4_scan_matches_single_device():
    """Sharded scan over a *packed* int4 payload: shards carry the packed
    containers ((n/P, ceil(d/2)) uint-nibble codes) and unpack per tile —
    results match the single-device ``ops.scan_quantized`` bit for bit."""
    out = run_in_devices("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import distributed as dd
from repro.kernels import ops
from repro.launch.mesh import make_mesh
from repro.store import LeafStore

mesh = make_mesh((4,), ("data",))
rng = np.random.default_rng(9)
n, d, b, w, k, block = 512, 8, 6, 40, 9, 32
pts = rng.normal(size=(n, d)).astype(np.float32)
store = LeafStore.create(pts, "int4", block=block)
assert store.code_format == "int4"
codes3, scales2 = dd.shard_payload(store, mesh, db_axes=("data",))
assert codes3.shape == (4, 128, d // 2)  # packed: two codes per byte
Q = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
ci = jnp.asarray(rng.integers(0, n, size=(b, w)), jnp.int32)
ok = jnp.asarray(rng.random(size=(b, w)) > 0.15)
gd, gs = dd.scan_quantized_sharded(codes3, scales2, Q, ci, ok, mesh,
                                   db_axes=("data",), distance="l2", k=k,
                                   block=block, code_format="int4")
wd, slot = ops.scan_quantized(Q, store.codes, store.scales, ci, ok, "l2",
                              k=k, block=block, code_format="int4")
ws = np.where(np.asarray(wd) < 1e29, np.asarray(
    jnp.take_along_axis(ci, slot, axis=1)), -1)
np.testing.assert_allclose(np.asarray(gd), np.asarray(wd), rtol=1e-5,
                           atol=1e-5)
for i in range(b):
    assert set(np.asarray(gs[i]).tolist()) == set(ws[i].tolist()), i
print("SHARDED_INT4_OK")
""")
    assert "SHARDED_INT4_OK" in out


def test_shard_payload_rejects_misaligned():
    out = run_in_devices("""
from repro.launch.mesh import make_mesh
from repro.core import distributed as dd
from repro.store import LeafStore
import numpy as np
mesh = make_mesh((4,), ("data",))
pts = np.zeros((512, 4), np.float32)
try:
    dd.shard_payload(LeafStore.create(pts, "fp32"), mesh)
except ValueError as e:
    assert "quantised" in str(e)
try:  # block 256 > per-shard 128: scales cannot shard cleanly
    dd.shard_payload(LeafStore.create(pts, "int8", block=256), mesh)
except ValueError as e:
    assert "granule" in str(e)
print("ALIGN_OK")
""")
    assert "ALIGN_OK" in out
