"""The kernel-layer build substrate (DESIGN.md §3.5): eager multi-swap
FasterPAM properties, the fused Pallas swap-sweep kernel vs its oracle,
group-chunked streaming memory honesty, level-loop termination, and the
end-to-end seed-vs-new build guard."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import distances as dl
from repro.core import kmedoids as km
from repro.core import msa, nsa
from repro.data import make_dataset
from repro.kernels import ops
from repro.kernels.ref import knn_ref, swap_deltas_ref


def _pairwise(X, name="euclidean"):
    X = jnp.asarray(X)
    return jnp.asarray(np.asarray(dl.get(name).pairwise(X, X)))


# ---------------------------------------------------------------------------
# Eager multi-swap FasterPAM properties
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_eager_sweep_td_monotone(seed):
    """TD never increases across eager sweeps, and the carried TD matches an
    exact recompute after every sweep (the single-swap fallback guard)."""
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(70, 4)).astype(np.float32)
    D = _pairwise(X, "manhattan")
    valid = jnp.ones((70,), bool)
    medoids = km.build(D, 10, valid)
    _, td = km._labels_and_td(D, medoids, valid)
    for _ in range(12):
        medoids, td, _, improving = km.sweep_once(D, valid, medoids, td)
        _, td_exact = km._labels_and_td(D, medoids, valid)
        np.testing.assert_allclose(float(td), float(td_exact), rtol=1e-5)
        if not bool(improving):
            break
    assert not bool(improving), "swap loop must converge within the budget"


def test_eager_final_td_not_worse_than_seed_loop():
    """Both loops stop when no single swap improves, so both end at
    single-swap local optima — the eager one must be at least as good on
    average over random instances, and never more than a whisker worse on
    any one (different accept order => occasionally a different, near-equal
    optimum)."""
    news, refs = [], []
    for seed in range(8):
        rng = np.random.default_rng(100 + seed)
        g, k = 90, 14
        X = rng.normal(size=(g, 5)).astype(np.float32)
        D = _pairwise(X)
        new = km.kmedoids(D, k=k, method="pam")
        ref = km.kmedoids(D, k=k, method="pam_reference")
        news.append(float(new.td))
        refs.append(float(ref.td))
        assert news[-1] <= refs[-1] * 1.005 + 1e-5, (seed, news[-1], refs[-1])
    assert np.mean(news) <= np.mean(refs) + 1e-4, (news, refs)


def test_eager_swap_masked_padding():
    """Padding points are never swapped in by the eager accept."""
    rng = np.random.default_rng(7)
    X = np.concatenate(
        [rng.normal(size=(40, 3)), np.full((12, 3), 1e3)]
    ).astype(np.float32)
    D = _pairwise(X)
    valid = jnp.asarray([True] * 40 + [False] * 12)
    res = km.kmedoids(D, k=6, valid=valid, method="pam")
    med = np.asarray(res.medoids)
    assert (med[med >= 0] < 40).all()


def test_build_grouped_matches_scalar_build():
    """The batched [G, g, g] BUILD contraction reproduces the per-group
    greedy BUILD exactly (same argmin tie order)."""
    rng = np.random.default_rng(9)
    Xg = rng.normal(size=(5, 24, 3)).astype(np.float32)
    Dg = jnp.stack([_pairwise(x, "cosine") for x in Xg])
    valid = jnp.asarray(rng.random((5, 24)) > 0.2)
    grouped = km.build_grouped(Dg, 6, valid)
    for i in range(5):
        single = km.build(Dg[i], 6, valid[i])
        np.testing.assert_array_equal(np.asarray(grouped[i]), np.asarray(single))


# ---------------------------------------------------------------------------
# Fused swap-sweep kernel: interpret-mode Pallas vs the ref.py oracle
# ---------------------------------------------------------------------------

SWEEP_SHAPES = [(20, 5, 8), (64, 32, 16), (33, 7, 128), (130, 65, 32),
                (256, 128, 64)]


@pytest.mark.parametrize("g,k,bg", SWEEP_SHAPES)
def test_swap_deltas_kernel_interpret_parity(g, k, bg):
    rng = np.random.default_rng(g * 7 + k)
    X = rng.normal(size=(g, 4)).astype(np.float32)
    D = _pairwise(X)
    valid = jnp.asarray(rng.random(g) > 0.2)
    medoids = km.build(D, k, valid)
    d1, n1, d2 = km._nearest_caches(D, medoids, valid)
    want = swap_deltas_ref(D, d1, d2, n1, valid, k)
    got = ops.swap_deltas(D, d1, d2, n1, valid, k=k, bg=bg, force_pallas=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_swap_deltas_kernel_vmapped_parity():
    """vmap over a groups axis (the MSA layout) lifts into the kernel grid."""
    rng = np.random.default_rng(21)
    Xg = rng.normal(size=(3, 40, 4)).astype(np.float32)
    Dg = jnp.stack([_pairwise(x) for x in Xg])
    valid = jnp.ones((3, 40), bool)
    med = jax.vmap(lambda D, v: km.build(D, 9, v))(Dg, valid)
    d1, n1, d2 = jax.vmap(km._nearest_caches)(Dg, med, valid)
    got = jax.vmap(
        lambda D, a, b, c, v: ops.swap_deltas(
            D, a, b, c, v, k=9, bg=16, force_pallas=True
        )
    )(Dg, d1, d2, n1, valid)
    want = jax.vmap(lambda D, a, b, c, v: swap_deltas_ref(D, a, b, c, v, 9))(
        Dg, d1, d2, n1, valid
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Memory honesty (jaxpr scans, mirroring test_dense_l1_never_materialises_cube)
# ---------------------------------------------------------------------------


def _max_outvar_elems(jaxpr, into_params=True):
    seen = [0]

    def scan(jx):
        for eqn in jx.eqns:
            for v in eqn.outvars:
                aval = getattr(v, "aval", None)
                if aval is not None and hasattr(aval, "shape"):
                    elems = 1
                    for s in aval.shape:
                        elems *= int(s)
                    seen[0] = max(seen[0], elems)
            if not into_params:
                continue
            for val in eqn.params.values():
                if isinstance(val, jax.core.ClosedJaxpr):
                    scan(val.jaxpr)
                elif isinstance(val, jax.core.Jaxpr):
                    scan(val)
                elif isinstance(val, (tuple, list)):
                    for x in val:
                        if isinstance(x, jax.core.ClosedJaxpr):
                            scan(x.jaxpr)

    scan(jaxpr)
    return seen[0]


def test_chunked_build_never_materialises_all_group_matrices():
    """With group_chunk streaming, no intermediate of the traced MSA build
    reaches [G, g, g] elements: the clustering working set is bounded by
    [group_chunk, g, g] however many groups the level holds."""
    n, d, gl, gc = 2048, 4, 64, 4
    G = n // gl  # 32 >> group_chunk
    data = jnp.zeros((n, d), jnp.float32)
    closed = jax.make_jaxpr(
        lambda x: msa.build_index_arrays(
            x, gl=gl, distance="euclidean", method="pam", group_chunk=gc
        )
    )(data)
    seen = _max_outvar_elems(closed.jaxpr)
    assert seen < G * gl * gl, (seen, G * gl * gl)
    assert seen <= gc * gl * gl, (seen, gc * gl * gl)


def test_sweep_kernel_streams_row_tiles():
    """Inside the Pallas sweep-kernel body nothing larger than one streamed
    [bg, g] tile / the persistent [k, g] accumulator exists — the [g, g]
    gain/removal matrices of the oracle are never materialised."""
    g, k, bg = 256, 16, 16
    rng = np.random.default_rng(3)
    X = rng.normal(size=(g, 4)).astype(np.float32)
    D = _pairwise(X)
    valid = jnp.ones((g,), bool)
    medoids = km.build(D, k, valid)
    d1, n1, d2 = km._nearest_caches(D, medoids, valid)
    closed = jax.make_jaxpr(
        lambda *a: ops.swap_deltas(*a, k=k, bg=bg, force_pallas=True)
    )(D, d1, d2, n1, valid)

    # Find the pallas_call eqn and scan only its kernel-body jaxpr.
    bodies = []

    def find(jx):
        for eqn in jx.eqns:
            if "pallas" in eqn.primitive.name:
                for val in eqn.params.values():
                    if isinstance(val, jax.core.ClosedJaxpr):
                        bodies.append(val.jaxpr)
                    elif isinstance(val, jax.core.Jaxpr):
                        bodies.append(val)
            for val in eqn.params.values():
                if isinstance(val, jax.core.ClosedJaxpr):
                    find(val.jaxpr)

    find(closed.jaxpr)
    assert bodies, "no pallas_call in the traced sweep"
    gc_pad = -(-g // 128) * 128
    kp = -(-k // 8) * 8
    tile_bound = max(bg, kp) * gc_pad
    for body in bodies:
        seen = _max_outvar_elems(body)
        assert seen <= tile_bound < g * g, (seen, tile_bound, g * g)


# ---------------------------------------------------------------------------
# Level-loop termination (regression: k == gl used to loop forever)
# ---------------------------------------------------------------------------


def test_n_prototypes_equal_gl_raises():
    rng = np.random.default_rng(11)
    data = rng.normal(size=(100, 4)).astype(np.float32)
    with pytest.raises(ValueError, match="never reduces"):
        msa.build_index(data, gl=10, n_prototypes=10)
    with pytest.raises(ValueError, match="never reduces"):
        msa.n_levels_for(100, 10, 10)


def test_n_prototypes_above_half_gl_raises():
    """Any k > gl // 2 sticks at >= 2 groups (ceil(2k/gl) == 2), not just
    k == gl."""
    with pytest.raises(ValueError, match="never reduces"):
        msa.n_levels_for(1000, 10, 6)


def test_single_group_allows_k_up_to_gl():
    """n <= gl is one group clustered once; k == gl just promotes all."""
    assert msa.n_levels_for(20, 32, 32) == 1
    rng = np.random.default_rng(12)
    data = rng.normal(size=(20, 4)).astype(np.float32)
    idx, stats = msa.build_index(data, gl=32, n_prototypes=32)
    assert stats.level_sizes == (20, 20)


# ---------------------------------------------------------------------------
# End-to-end guard: new-built index serves like the seed-built index
# ---------------------------------------------------------------------------


def _recall(ids, gt):
    return np.mean(
        [len(set(ids[i].tolist()) & set(gt[i].tolist())) / gt.shape[1]
         for i in range(len(gt))]
    )


def test_new_build_matches_seed_build_recall():
    """Same key => same shuffle => same grouping: the eager-swap, chunked
    build must yield the seed level structure, a final TD within 1%, and
    dense/beam search recall within noise of the seed-built index."""
    data = make_dataset("dense_embed", n=1560, seed=0).astype(np.float32)
    data = data[:, :16]
    key = jax.random.PRNGKey(0)
    seed_idx, seed_stats = msa.build_index(
        data, gl=64, method="pam_reference", group_chunk=0, key=key
    )
    new_idx, new_stats = msa.build_index(
        data, gl=64, method="pam", group_chunk=4, key=key
    )
    assert new_stats.level_sizes == seed_stats.level_sizes
    assert new_stats.level_td[0] <= seed_stats.level_td[0] * 1.01

    dist = dl.get("euclidean")
    Q = jnp.asarray(data[:64])
    _, gt = knn_ref(Q, jnp.asarray(data), 10, "l2")
    gt = np.asarray(gt)
    r = 1.15 * float(np.median(np.asarray(
        dl.get("euclidean").pairwise(Q, jnp.asarray(data))
    )))
    recs = {}
    for name, idx in (("seed", seed_idx), ("new", new_idx)):
        dres = nsa.search_dense(idx, Q, dist=dist, k=10, r=r)
        bres = nsa.search_beam(idx, Q, dist=dist, k=10, r=r, beam=32,
                               max_children=msa.max_children(idx))
        recs[name, "dense"] = _recall(np.asarray(dres.ids), gt)
        recs[name, "beam"] = _recall(np.asarray(bres.ids), gt)
    for mode in ("dense", "beam"):
        assert abs(recs["new", mode] - recs["seed", mode]) < 0.05, recs
    assert recs["new", "dense"] > 0.8, recs


@pytest.mark.parametrize("method", ["pam", "kmeans"])
def test_chunked_build_equals_dense_build(method):
    """group_chunk only changes the execution schedule: the chunked build
    returns the same index as the whole-level build (same key, same
    arithmetic per group — for kmeans that includes the per-group PRNG
    keys, which must not depend on the chunk padding)."""
    rng = np.random.default_rng(13)
    data = rng.normal(size=(600, 5)).astype(np.float32)
    key = jax.random.PRNGKey(3)
    a, _ = msa.build_index(data, gl=32, method=method, group_chunk=0, key=key)
    b, _ = msa.build_index(data, gl=32, method=method, group_chunk=3, key=key)
    for la, lb in zip(a.levels, b.levels):
        np.testing.assert_array_equal(np.asarray(la.valid), np.asarray(lb.valid))
        np.testing.assert_allclose(
            np.asarray(la.points), np.asarray(lb.points), rtol=1e-6, atol=1e-6
        )
        np.testing.assert_array_equal(np.asarray(la.parent), np.asarray(lb.parent))
    np.testing.assert_array_equal(np.asarray(a.leaf_ids), np.asarray(b.leaf_ids))


def test_build_end_to_end_force_pallas():
    """A full MSA build with force_pallas=True runs the Pallas sweep-kernel
    body (interpret mode) on every swap sweep and lands on the same level
    structure and TD (to fp tolerance) as the oracle dispatch."""
    rng = np.random.default_rng(15)
    data = rng.normal(size=(300, 5)).astype(np.float32)
    key = jax.random.PRNGKey(4)
    ref_idx, ref_stats = msa.build_index(data, gl=32, key=key, bg=16)
    pal_idx, pal_stats = msa.build_index(data, gl=32, key=key, bg=16,
                                         force_pallas=True)
    assert ref_stats.level_sizes == pal_stats.level_sizes
    for a, b in zip(ref_stats.level_td, pal_stats.level_td):
        np.testing.assert_allclose(a, b, rtol=1e-3)


def test_kmeans_chunked_relabel_valid():
    """kmeans path under chunking: labels index medoid slots and the index
    invariants hold (relabel now computes [g, k] against snapped medoids
    through the kernel layer)."""
    from repro.core.reference_impl import check_index_invariants

    rng = np.random.default_rng(14)
    data = rng.normal(size=(400, 6)).astype(np.float32)
    idx, stats = msa.build_index(data, gl=40, method="kmeans", group_chunk=3)
    assert check_index_invariants(idx) == []
    assert stats.level_sizes[0] == 400
