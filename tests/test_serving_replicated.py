"""Replicated fault-tolerant serving tier (DESIGN.md §3.10): fault-plan
determinism, router parity/retry/hedge/health behaviour, write fan-out and
crash-replay convergence, admission control with graceful degradation."""

import threading
import time

import numpy as np
import pytest

from repro.core.index import PDASCIndex
from repro.query import Query, degraded
from repro.serving import (
    BatchingEngine,
    FaultPlan,
    FaultSpec,
    InjectedFault,
    Overloaded,
    QueryHandler,
    ReplicaDown,
    ReplicaSet,
    Router,
    RouterConfig,
    clone_index,
)
from repro.serving.faults import ReplicaCrashed


@pytest.fixture(scope="module")
def built():
    rng = np.random.default_rng(7)
    X = rng.normal(size=(600, 12)).astype(np.float32)
    idx = PDASCIndex.build(X, gl=64, distance="euclidean")
    return idx, X


QUERY = Query(k=5, execution="beam", beam=16, with_stats=False)


def _tier(idx, *, n_replicas=2, fault_plan=None, cfg=None, **kw):
    rs = ReplicaSet(idx, QUERY, n_replicas=n_replicas, batch_size=4,
                    max_wait_ms=0.5, degraded_query=degraded(QUERY),
                    fault_plan=fault_plan, **kw)
    router = Router(rs, cfg or RouterConfig(
        deadline_s=10.0, eject_failures=2, probe_cooldown_s=0.05,
        probe_interval_s=0.02, seed=0))
    return rs, router


# --------------------------- fault plan --------------------------------------


def test_fault_plan_parse_roundtrip():
    plan = FaultPlan.parse("wedge:r1@20+8; error:r0@5+3 , latency:r2@0+4:0.1")
    kinds = sorted((s.kind, s.replica, s.start, s.duration)
                   for s in plan.specs)
    assert kinds == [("error", 0, 5, 3), ("latency", 2, 0, 4),
                     ("wedge", 1, 20, 8)]
    lat = next(s for s in plan.specs if s.kind == "latency")
    assert lat.delay_s == pytest.approx(0.1)
    with pytest.raises(ValueError):
        FaultPlan.parse("explode:r0@1+1")
    with pytest.raises(ValueError):
        FaultPlan.parse("error:r0@1")


def test_fault_injection_is_dispatch_deterministic():
    """Same plan, same dispatch sequence -> identical fault decisions —
    twice over, with no wall clock involved for error faults."""
    plan = FaultPlan((FaultSpec("error", 0, 3, 2),))

    def run():
        inj = plan.injector(0)
        outcomes = []
        for _ in range(8):
            try:
                inj.on_dispatch()
                outcomes.append("ok")
            except InjectedFault:
                outcomes.append("err")
        return outcomes

    first, second = run(), run()
    assert first == second == ["ok"] * 3 + ["err"] * 2 + ["ok"] * 3


def test_fault_plan_generate_seeded():
    a = FaultPlan.generate(seed=3, n_replicas=4)
    b = FaultPlan.generate(seed=3, n_replicas=4)
    assert a.specs == b.specs
    assert all(s.replica < 4 for s in a.specs)
    assert a.specs != FaultPlan.generate(seed=4, n_replicas=4).specs


# --------------------------- replica set -------------------------------------


def test_clone_index_shares_immutables_rejects_dirty(built):
    idx, X = built
    clone = clone_index(idx)
    assert clone.data is idx.data  # build artifacts shared by reference
    assert clone.delta is None and clone.tombstones is None
    dirty = clone_index(idx)
    dirty.enable_mutations(delta_capacity=64)
    dirty.upsert(X[:1] + 50.0)
    with pytest.raises(ValueError, match="clean online tiers"):
        clone_index(dirty)


def test_router_results_match_direct_plan(built):
    idx, X = built
    rs, router = _tier(idx)
    try:
        ref = idx.plan(QUERY)(X[:8])
        for i in range(8):
            res = router.search(X[i])
            np.testing.assert_array_equal(res.ids, np.asarray(ref.ids)[i])
            np.testing.assert_allclose(res.dists, np.asarray(ref.dists)[i],
                                       rtol=1e-5)
            assert not res.degraded
    finally:
        router.close(close_replicas=True)


def test_write_fanout_converges_and_ids_agree(built):
    idx, X = built
    rs, router = _tier(idx)
    try:
        ids = rs.upsert(X[:3] + 100.0)
        assert len(ids) == 3
        assert rs.delete(np.asarray([ids[1]])) == 1
        # both replicas serve the upserted points (minus the deleted one)
        for probe, want in ((X[0] + 100.0, ids[0]), (X[2] + 100.0, ids[2])):
            seen = set()
            for _ in range(12):
                res = router.search(probe)
                assert res.ids[0] == want
                assert ids[1] not in set(res.ids.tolist())
                seen.add(res.replica)
            assert seen == {0, 1}  # P2C really spread across the fleet
    finally:
        router.close(close_replicas=True)


def test_kill_restart_replays_log_suffix(built):
    idx, X = built
    rs, router = _tier(idx)
    try:
        first = rs.upsert(X[:2] + 100.0)
        rs.kill(1)
        assert not rs.replicas[1].alive
        with pytest.raises(ReplicaDown):
            rs.replicas[1].submit(X[0])
        # writes continue against the survivor; replica 1 misses them
        second = rs.upsert(X[2:4] + 200.0)
        assert rs.replicas[1].applied_seq < rs.log.last_seq
        rs.restart(1)
        assert rs.replicas[1].applied_seq == rs.log.last_seq
        # the restarted replica assigned the SAME ids by ordered replay
        req = rs.replicas[1].submit(X[3] + 200.0)
        dists, ids = req.wait(timeout=30)
        assert ids[0] == second[1]
        req0 = rs.replicas[0].submit(X[3] + 200.0)
        _, ids0 = req0.wait(timeout=30)
        assert ids0[0] == ids[0]
        assert first[0] != second[0]
    finally:
        router.close(close_replicas=True)


def test_write_with_all_replicas_down_raises_and_replays(built):
    idx, X = built
    rs, router = _tier(idx)
    try:
        rs.kill(0)
        rs.kill(1)
        with pytest.raises(ReplicaDown):
            rs.upsert(X[:1] + 300.0)
        # the op stays in the log: a restart replays it
        rs.restart(0)
        res = rs.replicas[0].submit(X[0] + 300.0).wait(timeout=30)
        # first id past the build's points (leaf_ids is slot-padded)
        next_id = int((np.asarray(idx.data.leaf_ids) >= 0).sum())
        assert res[1][0] == next_id
    finally:
        router.close(close_replicas=True)


# --------------------------- router fault handling ---------------------------


def test_retry_rescues_error_burst(built):
    idx, X = built
    plan = FaultPlan.parse("error:r0@1+50")  # r0 errors on every dispatch
    rs, router = _tier(idx, fault_plan=plan, cfg=RouterConfig(
        deadline_s=10.0, max_retries=2, hedge=False, eject_failures=2,
        probe_cooldown_s=10.0, probe_interval_s=0.5, seed=0))
    try:
        ok = 0
        for i in range(20):
            res = router.search(X[i])
            ok += 1
            assert res.replica in (0, 1)
        assert ok == 20  # zero caller-visible errors
        ev = router.event_counts()
        assert ev.get("eject", 0) >= 1  # r0 ejected after consec failures
        assert router.stats["retries"] >= 1
    finally:
        router.close(close_replicas=True)


def test_hedge_rescues_wedged_replica_and_health_readmits(built):
    idx, X = built
    # r1 wedges (0.4s stall per dispatch) for a short window
    plan = FaultPlan.parse("wedge:r1@1+4:0.4")
    rs, router = _tier(idx, fault_plan=plan, cfg=RouterConfig(
        deadline_s=10.0, hedge=True, hedge_min_s=0.02, eject_failures=2,
        probe_cooldown_s=0.05, probe_timeout_s=0.2, probe_interval_s=0.02,
        seed=0))
    try:
        for i in range(30):
            res = router.search(X[i % len(X)])
            assert res.ids.shape == (QUERY.k,)
            time.sleep(0.005)
        deadline = time.time() + 30
        while time.time() < deadline:
            ev = router.event_counts()
            if ev.get("readmit", 0) >= 1:
                break
            router.search(X[0])
            time.sleep(0.05)
        ev = router.event_counts()
        assert ev.get("hedge", 0) >= 1, ev
        assert ev.get("eject", 0) >= 1, ev
        assert ev.get("half_open", 0) >= 1, ev
        assert ev.get("readmit", 0) >= 1, ev
        assert router.stats["successes"] >= 30
    finally:
        router.close(close_replicas=True)


def test_crash_fault_triggers_restart_and_recovery(built):
    idx, X = built
    plan = FaultPlan.parse("crash:r0@2+1")
    rs, router = _tier(idx, fault_plan=plan, cfg=RouterConfig(
        deadline_s=10.0, hedge=False, max_retries=2, eject_failures=1,
        probe_cooldown_s=0.05, probe_timeout_s=1.0, probe_interval_s=0.02,
        seed=0))
    try:
        errs = 0
        for i in range(25):
            try:
                router.search(X[i % len(X)])
            except Exception:  # noqa: BLE001 — the count IS the assertion
                errs += 1
            time.sleep(0.01)
        assert errs == 0
        deadline = time.time() + 30
        while time.time() < deadline and not rs.replicas[0].alive:
            time.sleep(0.05)
        ev = router.event_counts()
        assert ev.get("crash", 0) >= 1, ev
        assert ev.get("restart", 0) >= 1, ev
        assert rs.replicas[0].alive
    finally:
        router.close(close_replicas=True)


def test_deadline_exceeded_when_all_replicas_wedge(built):
    idx, X = built
    plan = FaultPlan.parse("wedge:r0@0+200:0.3;wedge:r1@0+200:0.3")
    rs, router = _tier(idx, fault_plan=plan, cfg=RouterConfig(
        deadline_s=0.15, max_retries=1, hedge=False, eject_failures=50,
        probe_cooldown_s=30.0, probe_interval_s=1.0, seed=0))
    try:
        from repro.serving import DeadlineExceeded

        with pytest.raises(DeadlineExceeded):
            router.search(X[0])
        assert router.stats["deadline_exceeded"] == 1
    finally:
        router.close(close_replicas=True)


# --------------------------- admission + degradation -------------------------


def test_admission_rejects_past_queue_limit(built):
    idx, X = built
    rs, router = _tier(idx, cfg=RouterConfig(
        deadline_s=10.0, queue_limit=4, degrade_at=2.0,  # degrade disabled
        hedge=False, seed=0))
    try:
        with router._lock:
            router._inflight = 4  # saturate the budget directly
        with pytest.raises(Overloaded):
            router.submit(X[0])
        assert router.stats["rejected"] == 1
        with router._lock:
            router._inflight = 0
        assert router.search(X[0]).ids.shape == (QUERY.k,)
    finally:
        router.close(close_replicas=True)


def test_degradation_ladder_serves_cheaper_plan(built):
    idx, X = built
    rs, router = _tier(idx, cfg=RouterConfig(
        deadline_s=10.0, queue_limit=8, degrade_at=0.5, hedge=False, seed=0))
    try:
        with router._lock:
            router._inflight = 4  # past the watermark, under the limit
        res = router.submit(X[0]).wait(timeout=30)
        assert res.degraded
        assert res.ids.shape == (QUERY.k,)
        # degraded results still come from the narrower-beam plan: top-1
        # agrees with the exact plan on this easy query
        ref = idx.plan(QUERY)(X[0])
        assert res.ids[0] == int(np.asarray(ref.ids)[0])
        with router._lock:
            router._inflight -= 4
    finally:
        router.close(close_replicas=True)


def test_degraded_scan_only_plan_skips_exact_rerank(built):
    idx, X = built
    base = PDASCIndex.build(X, gl=64, distance="euclidean", store="int8",
                            store_block=64)
    base.release_dense_payload()
    q = Query(k=5, execution="two_stage", rerank_width=32, with_stats=False)
    dq = degraded(q)
    assert not dq.exact_rerank and dq.rerank_width == q.k
    plan = base.plan(dq)
    assert "scan-only" in plan.explain()
    exact = base.plan(q)(X[0])  # exact pipeline fetches payload rows
    fetches_before = base.store.exact.stats["fetches"]
    res = plan(X[:4])
    assert np.asarray(res.ids).shape == (4, 5)
    # scan-only ranking still lands on the true neighbour for the trivial
    # self-query (quantisation error is tiny relative to the margin)
    res1 = plan(X[0])
    assert int(np.asarray(res1.ids)[0]) == int(np.asarray(exact.ids)[0])
    # ... and never touched the exact payload tier (zero fetch traffic)
    assert base.store.exact.stats["fetches"] == fetches_before


# --------------------------- stress ------------------------------------------


@pytest.mark.stress
def test_long_faulted_schedule_zero_caller_errors(built):
    """Soak: a generated multi-fault schedule over 4 replicas with mixed
    search + write traffic — zero caller-visible search errors, and every
    ejection is eventually followed by recovery events."""
    idx, X = built
    plan = FaultPlan.generate(seed=11, n_replicas=4, n_faults=6,
                              horizon=60, max_duration=5, delay_s=0.2)
    rs = ReplicaSet(idx, QUERY, n_replicas=4, batch_size=4, max_wait_ms=0.5,
                    degraded_query=degraded(QUERY), fault_plan=plan)
    router = Router(rs, RouterConfig(
        deadline_s=15.0, max_retries=3, hedge=True, hedge_min_s=0.02,
        eject_failures=2, probe_cooldown_s=0.05, probe_timeout_s=0.3,
        probe_interval_s=0.02, seed=1))
    rng = np.random.default_rng(0)
    errors = []
    lock = threading.Lock()

    def searcher(w):
        for i in range(60):
            try:
                res = router.search(X[(w * 60 + i) % len(X)])
                assert res.ids.shape == (QUERY.k,)
            except Exception as e:  # noqa: BLE001 — collected for assert
                with lock:
                    errors.append(repr(e))
            time.sleep(0.002)

    try:
        threads = [threading.Thread(target=searcher, args=(w,))
                   for w in range(4)]
        for t in threads:
            t.start()
        for j in range(10):  # interleave writes with the faulted traffic
            rs.upsert(X[rng.integers(len(X))][None] + 100.0 + j)
            time.sleep(0.05)
        for t in threads:
            t.join()
        assert not errors, errors[:5]
        # every replica that went down must be back up (prober restarts)
        # AND readmitted by the router: `alive` flips the moment a wedge
        # fault's dispatch window clears, but an ejected replica only sees
        # probe traffic (on doubling cooldowns), so routing-level recovery
        # lands strictly later — keep traffic flowing until the prober has
        # walked every replica back to healthy.
        deadline = time.time() + 60
        while time.time() < deadline and not (
                all(r.alive for r in rs.replicas)
                and all(s == "healthy"
                        for s in router.health_states().values())):
            router.search(X[0])
            time.sleep(0.05)
        assert all(r.alive for r in rs.replicas)
        assert all(s == "healthy" for s in router.health_states().values())
        # and the fleet converged: replay left every replica at the log head
        assert all(r.applied_seq == rs.log.last_seq for r in rs.replicas)
        # The event log (DESIGN.md §3.11) must show the exact health
        # lifecycle per replica: transitions chain state-to-state (each
        # edge's "from" is the previous edge's "to", starting healthy), and
        # every ejection recovers through eject -> half_open -> readmit.
        transitions = [e for e in router.events() if "from" in e]
        assert transitions, "faulted soak produced no health transitions"
        ejected_rids = {e["replica"] for e in transitions
                        if e["event"] == "eject"}
        assert ejected_rids, "no replica was ever ejected under faults"
        for rid in {e["replica"] for e in transitions}:
            chain = [e for e in transitions if e["replica"] == rid]
            state = "healthy"
            for e in chain:
                assert e["from"] == state, (
                    f"r{rid}: transition {e} does not chain from {state}"
                )
                state = e["to"]
            events = [e["event"] for e in chain]
            for ej in (i for i, ev in enumerate(events) if ev == "eject"):
                rest = events[ej + 1:]
                assert "half_open" in rest and \
                    "readmit" in rest[rest.index("half_open"):], (
                        f"r{rid}: ejection at step {ej} never recovered "
                        f"via half_open -> readmit: {events}"
                    )
            # the soak's convergence loop means nobody ends ejected
            assert state == "healthy", f"r{rid} finished in state {state}"
        # the per-edge transition counters agree with the event log
        counted = sum(v for k, v in router.stats.items()
                      if k.startswith("transition_"))
        assert counted == len(transitions)
    finally:
        router.close(close_replicas=True)
