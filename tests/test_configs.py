"""Config-layer drift guards (configs/pdasc.py).

``PDASCArchConfig.kernel_config()`` is built field-wise from
``KernelConfig._fields`` so a knob added to the kernel layer cannot silently
fall out of the arch config's threading — these tests are the teeth behind
that comment: every tunable KernelConfig field must be mirrored as a
same-named arch-config field, and ``kernel_config()`` must carry every
mirrored value through verbatim.
"""

from __future__ import annotations

import dataclasses

from repro.configs.pdasc import PDASCArchConfig
from repro.kernels.ops import DEFAULT, KernelConfig

# KernelConfig fields that are *not* user-facing arch knobs: force_pallas is
# a test/debug override, tuned_gen is plan-compiler plumbing (the generation
# stamp that invalidates cached plans on retune).
_UNMIRRORED = {"force_pallas", "tuned_gen"}


def test_every_kernel_knob_is_mirrored_in_arch_config():
    cfg_fields = {f.name for f in dataclasses.fields(PDASCArchConfig)}
    missing = set(KernelConfig._fields) - _UNMIRRORED - cfg_fields
    assert not missing, (
        f"KernelConfig knobs {sorted(missing)} have no PDASCArchConfig "
        f"mirror field: kernel_config() would silently drop them"
    )


def test_kernel_config_defaults_round_trip():
    assert PDASCArchConfig().kernel_config() == DEFAULT


def test_kernel_config_carries_every_mirrored_field():
    overrides = dict(bm=32, bn=64, bd=128, bq=16, bg=256, row_chunk=512,
                     group_chunk=4, auto=True)
    kc = PDASCArchConfig(**overrides).kernel_config()
    for name, val in overrides.items():
        assert getattr(kc, name) == val, name
    # unmirrored fields keep their KernelConfig defaults
    assert kc.force_pallas == DEFAULT.force_pallas
    assert kc.tuned_gen == DEFAULT.tuned_gen


def test_kernel_config_auto_flag_reaches_search_query():
    q = PDASCArchConfig(auto=True, bq=16).search_query(execution="beam")
    assert q.kernel.auto is True
    assert q.kernel.bq == 16
