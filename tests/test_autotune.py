"""Autotuner tests (kernels/autotune.py, DESIGN.md §3.9): winner-cache
round-trip determinism, shape bucketing, corrupt/stale cache tolerance, and
the resolution precedence chain at ``ops`` dispatch time.

Timing is injected (``tune(measure=...)``) so the suite never waits on the
interpret-mode kernels; the real timing loop is exercised once on a tiny
shape at the end (and continuously by ``benchmarks/bench_kernels.py
--smoke`` in CI).
"""

from __future__ import annotations

import json
import os

import pytest

from repro.kernels import autotune, tiling
from repro.kernels import ops as kops


@pytest.fixture()
def tuner_cache(tmp_path):
    """Point the tuner at a throwaway cache file; restore the default (and
    drop the in-memory snapshot) afterwards so tests never leak winners."""
    path = str(tmp_path / "tune.json")
    autotune.set_cache_path(path)
    yield path
    autotune.set_cache_path(None)


def _fake_measure(best_knobs, best_us=10.0, other_us=100.0):
    """A deterministic 'timer': ``best_knobs`` is fast, everything else
    slow — makes the sweep winner predictable without wall-clock."""
    def measure(knobs):
        return best_us if knobs == best_knobs else other_us
    return measure


# ---------------------------------------------------------------------------
# Cache round-trip determinism
# ---------------------------------------------------------------------------


def test_tune_caches_winner_and_second_call_never_times(tuner_cache):
    shape = (64, 96, 32)
    fast = dict(bm=32, bn=128, bd=64)
    r1 = autotune.tune("pairwise", form="l2", dtype="float32", shape=shape,
                       measure=_fake_measure(fast))
    assert not r1["cached"]
    assert r1["winner"] == fast
    assert r1["winner_us"] == 10.0
    # hand-set default is always a sweep member (the acceptance baseline)
    assert any(s["knobs"] == dict(tiling.OP_DEFAULTS["pairwise"])
               for s in r1["sweep"])
    gen = autotune.generation()

    def exploding_measure(knobs):  # pragma: no cover - must not run
        raise AssertionError("cache hit must not re-time")

    r2 = autotune.tune("pairwise", form="l2", dtype="float32", shape=shape,
                       measure=exploding_measure)
    assert r2["cached"]
    assert r2["winner"] == fast
    assert autotune.generation() == gen  # a pure read mutates nothing

    # and the winner round-trips the on-disk JSON (fresh in-memory snapshot)
    autotune.set_cache_path(tuner_cache)
    assert autotune.lookup(op="pairwise", form="l2", dtype="float32",
                           shape=shape) == fast
    blob = json.load(open(tuner_cache))
    assert blob["version"] == autotune.CACHE_VERSION


def test_record_bumps_generation(tuner_cache):
    g0 = autotune.generation()
    autotune.record(op="swap", form="none", dtype="float32", shape=(96,),
                    knobs=dict(bg=32), us=5.0)
    assert autotune.generation() == g0 + 1


def test_concurrent_record_never_tears_the_cache_file(tuner_cache):
    """Parallel writers (e.g. two benchmark processes tuning at once) must
    never leave a torn/invalid JSON on disk: every save goes through its own
    unique temp file + atomic rename, last writer wins."""
    import threading

    stop = threading.Event()
    bad: list = []

    def reader():
        while not stop.is_set():
            if not os.path.exists(tuner_cache):
                continue
            try:
                blob = json.load(open(tuner_cache))
                assert blob["version"] == autotune.CACHE_VERSION
            except (ValueError, AssertionError) as e:
                bad.append(repr(e))
                return

    def writer(base):
        for i in range(25):
            # distinct form per write => distinct cache key (shape would
            # bucket to a power of two and collapse keys)
            autotune.record(op="swap", form=f"f{base + i}", dtype="float32",
                            shape=(96,), knobs=dict(bg=32), us=float(i))

    rt = threading.Thread(target=reader)
    writers = [threading.Thread(target=writer, args=(1000 * w,))
               for w in range(4)]
    rt.start()
    for t in writers:
        t.start()
    for t in writers:
        t.join()
    stop.set()
    rt.join()
    assert not bad, f"reader saw a torn cache file: {bad}"
    # no temp-file debris left behind after all writers finished
    leftovers = [f for f in os.listdir(os.path.dirname(tuner_cache))
                 if f.endswith(".tmp")]
    assert not leftovers, leftovers
    blob = json.load(open(tuner_cache))
    assert len(blob["entries"]) == 100  # every writer's keys landed in RAM


# ---------------------------------------------------------------------------
# Shape bucketing
# ---------------------------------------------------------------------------


def test_shape_bucket_power_of_two_boundaries():
    assert autotune.shape_bucket((127, 128, 129)) == (128, 128, 256)
    assert autotune.shape_bucket((1, 2, 3)) == (1, 2, 4)
    assert autotune.shape_bucket((0,)) == (1,)


def test_lookup_hits_any_shape_in_the_bucket(tuner_cache):
    autotune.record(op="knn", form="l2", dtype="float32", shape=(100, 2000, 70),
                    knobs=dict(bq=32, bn=256), us=1.0)
    # (100, 2000, 70) buckets to (128, 2048, 128): neighbours hit ...
    for shape in [(128, 2048, 128), (65, 1025, 65), (100, 2000, 70)]:
        assert autotune.lookup(op="knn", form="l2", dtype="float32",
                               shape=shape) == dict(bq=32, bn=256), shape
    # ... the next bucket up misses
    assert autotune.lookup(op="knn", form="l2", dtype="float32",
                           shape=(129, 2048, 128)) is None


def test_cache_key_is_backend_and_dtype_scoped(tuner_cache):
    autotune.record(op="scan", form="l2", dtype="int8", shape=(16, 64, 16),
                    knobs=dict(bq=8, bn=64), us=1.0)
    assert autotune.lookup(op="scan", form="l2", dtype="int4",
                           shape=(16, 64, 16)) is None
    assert autotune.lookup(op="scan", form="l2", dtype="int8",
                           shape=(16, 64, 16), backend="tpu") is None


# ---------------------------------------------------------------------------
# Corrupt / stale cache files: warn and ignore, never raise
# ---------------------------------------------------------------------------


def test_corrupt_cache_file_warns_and_is_ignored(tmp_path):
    path = str(tmp_path / "corrupt.json")
    with open(path, "w") as f:
        f.write("{not json!!")
    autotune.set_cache_path(path)
    try:
        with pytest.warns(UserWarning, match="corrupt"):
            assert autotune.lookup(op="pairwise", form="l2", dtype="float32",
                                   shape=(64, 96, 32)) is None
        # recording over a corrupt file works (rewrites it wholesale)
        autotune.record(op="pairwise", form="l2", dtype="float32",
                        shape=(64, 96, 32), knobs=dict(bm=32, bn=128, bd=64),
                        us=1.0)
        blob = json.load(open(path))
        assert blob["version"] == autotune.CACHE_VERSION
    finally:
        autotune.set_cache_path(None)


def test_stale_version_cache_warns_and_is_ignored(tmp_path):
    path = str(tmp_path / "stale.json")
    with open(path, "w") as f:
        json.dump({"version": autotune.CACHE_VERSION + 1, "entries": {
            "cpu|pairwise|l2|float32|64x128x32": {
                "knobs": {"bm": 999}, "us": 1.0},
        }}, f)
    autotune.set_cache_path(path)
    try:
        with pytest.warns(UserWarning, match="version"):
            assert autotune.lookup(op="pairwise", form="l2", dtype="float32",
                                   shape=(64, 96, 32)) is None
    finally:
        autotune.set_cache_path(None)


def test_missing_cache_file_is_silently_empty(tmp_path):
    autotune.set_cache_path(str(tmp_path / "nope" / "tune.json"))
    try:
        assert autotune.lookup(op="swap", form="none", dtype="float32",
                               shape=(96,)) is None
    finally:
        autotune.set_cache_path(None)


# ---------------------------------------------------------------------------
# Resolution precedence at ops dispatch time
# ---------------------------------------------------------------------------


def test_resolve_blocks_precedence_chain(tuner_cache):
    shape = (64, 96, 32)
    tuned = dict(bm=32, bn=128, bd=64)
    autotune.record(op="pairwise", form="l2", dtype="float32", shape=shape,
                    knobs=tuned, us=1.0)
    defaults = kops.resolve_blocks("pairwise", "l2", "float32", shape)
    # 1. no config: hand defaults, tuner not consulted
    assert defaults["bm"] == tiling.OP_DEFAULTS["pairwise"]["bm"]
    # 2. auto=True: tuned winner for un-set knobs
    auto = kops.KernelConfig(auto=True)
    assert kops.resolve_blocks("pairwise", "l2", "float32", shape, auto) \
        == tuned
    # 3. explicit call-site knob beats the tuned winner
    r = kops.resolve_blocks("pairwise", "l2", "float32", shape, auto, bm=64)
    assert r["bm"] == 64 and r["bn"] == tuned["bn"]
    # 4. non-default config field beats the tuned winner
    cfg = kops.KernelConfig(auto=True, bn=64)
    r = kops.resolve_blocks("pairwise", "l2", "float32", shape, cfg)
    assert r["bn"] == 64 and r["bm"] == tuned["bm"]
    # 5. auto=False config never consults the tuner
    r = kops.resolve_blocks("pairwise", "l2", "float32", shape,
                            kops.KernelConfig())
    assert r == defaults


def test_candidate_grid_contains_default_and_respects_vmem():
    grid = autotune.candidate_grid("pairwise", "l2", "float32", (64, 96, 32))
    assert grid[0] == dict(tiling.OP_DEFAULTS["pairwise"])
    assert len(grid) >= 2
    dbytes = 4
    for knobs in grid[1:]:
        eff = autotune._effective("pairwise", knobs, (64, 96, 32), dbytes, 8)
        assert autotune._vmem_ok("pairwise", "l2", eff, (64, 96, 32),
                                 dbytes, 8)


def test_tune_real_timing_smoke(tuner_cache):
    """One real (interpret-mode) timed sweep on a tiny swap shape: the
    timing loop runs, a winner lands in the cache, auto=True resolves it."""
    r = autotune.tune("swap", form="none", dtype="float32", shape=(48,),
                      reps=1, warmup=1)
    assert not r["cached"] and r["winner_us"] > 0.0
    resolved = kops.resolve_blocks("swap", "none", "float32", (48,),
                                   kops.KernelConfig(auto=True))
    assert resolved["bg"] == r["winner"]["bg"]
    assert os.path.exists(autotune.cache_path())
