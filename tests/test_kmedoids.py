"""k-medoids: optimality on small instances, masking, FasterPAM semantics."""

import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import distances as dl
from repro.core import kmedoids as km
from repro.core.kmeans import kmeans


def brute_force_td(D, k, valid=None):
    """Exact optimal total deviation by enumeration."""
    n = D.shape[0]
    pts = [i for i in range(n) if valid is None or valid[i]]
    best = np.inf
    for med in itertools.combinations(pts, k):
        td = sum(min(D[o, m] for m in med) for o in pts)
        best = min(best, td)
    return best


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("k", [2, 3])
def test_pam_near_optimal_small(seed, k):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(10, 3)).astype(np.float32)
    D = np.asarray(dl.get("euclidean").pairwise(jnp.asarray(X), jnp.asarray(X)))
    res = km.kmedoids(jnp.asarray(D), k=k)
    opt = brute_force_td(D, k)
    assert float(res.td) <= opt * 1.05 + 1e-5, (float(res.td), opt)


def test_swap_improves_over_build():
    rng = np.random.default_rng(5)
    X = rng.normal(size=(60, 4)).astype(np.float32)
    D = jnp.asarray(dl.get("manhattan").pairwise(jnp.asarray(X), jnp.asarray(X)))
    b = km.kmedoids(D, k=8, method="build")
    p = km.kmedoids(D, k=8, method="pam")
    assert float(p.td) <= float(b.td) + 1e-5


def test_labels_are_nearest_medoid():
    rng = np.random.default_rng(3)
    X = rng.normal(size=(40, 4)).astype(np.float32)
    D = np.asarray(dl.get("euclidean").pairwise(jnp.asarray(X), jnp.asarray(X)))
    res = km.kmedoids(jnp.asarray(D), k=5)
    med = np.asarray(res.medoids)
    lbl = np.asarray(res.labels)
    for i in range(40):
        d_to = D[i, med[med >= 0]]
        assert np.isclose(D[i, med[lbl[i]]], d_to.min(), atol=1e-6)


def test_small_group_promotes_all():
    """Paper §3.1: groups with <= k valid points promote every point."""
    rng = np.random.default_rng(4)
    X = rng.normal(size=(10, 3)).astype(np.float32)
    D = jnp.asarray(dl.get("euclidean").pairwise(jnp.asarray(X), jnp.asarray(X)))
    valid = jnp.asarray([True] * 3 + [False] * 7)
    res = km.kmedoids(D, k=5, valid=valid)
    med = np.asarray(res.medoids)
    assert (med >= 0).sum() == 3
    assert set(med[med >= 0]) == {0, 1, 2}


def test_masked_padding_ignored():
    rng = np.random.default_rng(6)
    X = rng.normal(size=(30, 3)).astype(np.float32)
    Xpad = np.concatenate([X, np.full((10, 3), 1e3, np.float32)])
    dist = dl.get("euclidean")
    D = jnp.asarray(np.asarray(dist.pairwise(jnp.asarray(Xpad), jnp.asarray(Xpad))))
    valid = jnp.asarray([True] * 30 + [False] * 10)
    res = km.kmedoids(D, k=4, valid=valid)
    med = np.asarray(res.medoids)
    assert (med[med >= 0] < 30).all(), "padding never selected as medoid"
    D0 = jnp.asarray(np.asarray(dist.pairwise(jnp.asarray(X), jnp.asarray(X))))
    res0 = km.kmedoids(D0, k=4)
    np.testing.assert_allclose(float(res.td), float(res0.td), rtol=1e-5)


def test_grouped_vmap_matches_loop():
    rng = np.random.default_rng(7)
    Xg = rng.normal(size=(4, 20, 3)).astype(np.float32)
    dist = dl.get("cosine")
    Dg = jnp.stack([dist.pairwise(jnp.asarray(x), jnp.asarray(x)) for x in Xg])
    valid = jnp.ones((4, 20), bool)
    g = km.kmedoids_grouped(Dg, 5, valid)
    for i in range(4):
        s = km.kmedoids(Dg[i], k=5)
        np.testing.assert_allclose(float(g.td[i]), float(s.td), rtol=1e-5)


def test_arbitrary_distance_only_needs_D():
    """k-medoids must work on any dissimilarity matrix (the paper's core
    argument for choosing it) — including a non-metric one."""
    rng = np.random.default_rng(8)
    D = rng.uniform(0, 1, size=(15, 15)).astype(np.float32)
    D = (D + D.T) / 2
    np.fill_diagonal(D, 0.0)
    res = km.kmedoids(jnp.asarray(D), k=3)
    assert float(res.td) >= 0 and (np.asarray(res.medoids) >= 0).all()


def test_kmeans_snap_prototypes_are_points():
    rng = np.random.default_rng(9)
    X = jnp.asarray(rng.normal(size=(50, 4)).astype(np.float32))
    res = kmeans(X, 6, key=jax.random.PRNGKey(0))
    snapped = np.asarray(res.snapped)
    assert ((snapped >= 0) & (snapped < 50)).all()
