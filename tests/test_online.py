"""Online mutability substrate guards (DESIGN.md §3.7).

The three acceptance properties of ISSUE 4:

(a) deleted ids never appear in results, for every search mode
    (dense / beam / two_stage locally, sharded in a fake-device subprocess)
    — seeded sweeps plus a hypothesis property test;
(b) after interleaved upserts/deletes, recall@10 vs a from-scratch rebuild
    on the live set degrades <= 0.02 pre-compaction, and compaction restores
    *identical result sets* with the from-scratch build;
(c) epoch swaps under a concurrent ``BatchingEngine`` search stream never
    produce a torn (mixed-epoch) result — a sentinel point upserted into the
    delta tier must stay visible through every compaction swap, because the
    swap is one atomic reference assignment (the delta is never cleared
    before its points are resident in the new epoch).
"""

import os
import sys

sys.path.insert(0, os.path.dirname(__file__))

import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import hypothesis, st
from conftest import run_in_devices

from repro.core import distances as dist_lib
from repro.core.index import PDASCIndex
from repro.online import EpochHandle, live_dataset, merge_topk
from repro.serving import BatchingEngine

RNG = np.random.default_rng(7)


def _mk_index(n=400, d=8, gl=64, store=None, seed=0, store_block=64, **kw):
    data = np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32)
    idx = PDASCIndex.build(data, gl=gl, distance="euclidean",
                           radius_quantile=0.9, store=store,
                           store_block=store_block, **kw)
    return data, idx


def _ids_of(res):
    return np.asarray(res.ids)


def _brute_topk(Q, vecs, ids, k):
    D = np.linalg.norm(Q[:, None, :] - vecs[None, :, :], axis=-1)
    order = np.argsort(D, axis=1)[:, :k]
    return ids[order]


# ---------------------------------------------------------------------------
# (a) deleted ids vanish from every mode
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["dense", "beam", "two_stage"])
def test_deleted_ids_never_returned(mode):
    data, idx = _mk_index(store="int8" if mode == "two_stage" else None)
    dead = RNG.choice(400, size=60, replace=False)
    removed = idx.delete(dead)
    assert removed == 60
    q = data[RNG.choice(400, size=16, replace=False)]
    res = idx.search(q, k=10, mode=mode, beam=16, rerank_width=16)
    assert not (set(dead.tolist()) & set(_ids_of(res).ravel().tolist()))


def test_masked_dense_equals_bruteforce_over_live_set():
    """With a huge radius the masked dense mode is exact over the live set —
    the strongest form of 'deleted ids vanish'."""
    data, idx = _mk_index()
    dead = RNG.choice(400, size=100, replace=False)
    idx.delete(dead)
    alive = np.setdiff1d(np.arange(400), dead)
    q = RNG.normal(size=(8, 8)).astype(np.float32)
    res = idx.search(q, k=10, mode="dense", r=1e9)
    gt = _brute_topk(q, data[alive], alive, 10)
    np.testing.assert_array_equal(
        np.sort(_ids_of(res), axis=1), np.sort(gt, axis=1)
    )


@hypothesis.given(
    seed=st.integers(0, 2**16),
    n_dead=st.integers(1, 80),
    mode=st.sampled_from(["dense", "beam", "two_stage"]),
)
@hypothesis.settings(max_examples=12, deadline=None)
def test_property_deleted_ids_never_returned(seed, n_dead, mode):
    data, idx = _mk_index(n=256, gl=32,
                          store="int8" if mode == "two_stage" else None)
    rng = np.random.default_rng(seed)
    dead = rng.choice(256, size=n_dead, replace=False)
    idx.delete(dead)
    q = data[rng.choice(256, size=8, replace=False)]
    res = idx.search(q, k=10, mode=mode, beam=8, rerank_width=16)
    assert not (set(dead.tolist()) & set(_ids_of(res).ravel().tolist()))


def test_deleted_ids_never_returned_sharded():
    """(a) for the sharded path: per-shard tombstone masks routed by id."""
    out = run_in_devices("""
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core import distributed as dd

P = 4
n, d, per = 512, 8, 128
data = np.random.default_rng(0).normal(size=(n, d)).astype(np.float32)
mesh = Mesh(np.array(jax.devices()[:P]), ("data",))
sharded = dd.build_sharded(data, mesh, gl=32, distance="euclidean",
                           group_chunk=0)
dead = np.random.default_rng(1).choice(n, size=64, replace=False)
routed = dd.route_writes(dead, P, per)
leaf_ids = np.asarray(sharded.leaf_ids)
sv = np.ones(leaf_ids.shape, bool)
for shard, rows in routed:
    sv[shard] = dd.local_slot_valid(leaf_ids[shard], rows)
q = data[:16]
res = dd.search_sharded(sharded, q, mesh, dist="euclidean", k=10, r=1e9,
                        mode="dense", slot_valid=jnp.asarray(sv))
ids = np.asarray(res.ids)
assert not (set(dead.tolist()) & set(ids.ravel().tolist())), "deleted id returned"
# exactness: big radius ==> brute force over the live rows
alive = np.setdiff1d(np.arange(n), dead)
D = np.linalg.norm(q[:, None, :] - data[None, alive, :], axis=-1)
gt = alive[np.argsort(D, axis=1)[:, :10]]
assert np.array_equal(np.sort(ids, 1), np.sort(gt, 1)), "sharded masked != brute force"
print("SHARDED_OK")
""", n_devices=4)
    assert "SHARDED_OK" in out


def test_route_writes_bounds():
    from repro.core import distributed as dd

    routed = dd.route_writes([0, 127, 128, 300], 4, 128)
    got = {s: rows.tolist() for s, rows in routed}
    assert got == {0: [0, 127], 1: [0], 2: [44]}
    with pytest.raises(ValueError):
        dd.route_writes([512], 4, 128)


# ---------------------------------------------------------------------------
# upsert semantics
# ---------------------------------------------------------------------------


def test_upsert_immediately_visible_all_modes():
    data, idx = _mk_index(store="int8")
    # five well-separated points far from the data cloud
    new = (40.0 + 5.0 * np.arange(5, dtype=np.float32)[:, None]
           + np.zeros((5, 8), np.float32))
    ids = idx.upsert(new)
    assert ids.tolist() == [400, 401, 402, 403, 404]
    for mode in ("dense", "beam", "two_stage"):
        res = idx.search(new, k=3, mode=mode, r=1e9, beam=16, rerank_width=8)
        assert _ids_of(res)[:, 0].tolist() == ids.tolist(), mode
        # delta distances are exact (brute-force scan), self-distance == 0
        assert np.allclose(np.asarray(res.dists)[:, 0], 0.0, atol=1e-5)


def test_upsert_replaces_existing_id():
    data, idx = _mk_index()
    moved = np.full((1, 8), 25.0, np.float32)
    idx.upsert(moved, ids=[7])
    # old location: id 7 must not surface there any more
    res_old = idx.search(data[7][None], k=10, r=1e9)
    assert 7 not in _ids_of(res_old).ravel().tolist()
    res_new = idx.search(moved, k=1, r=1e9)
    assert _ids_of(res_new).ravel()[0] == 7
    # re-upserting the same id again retires the buffered copy too
    moved2 = np.full((1, 8), -25.0, np.float32)
    idx.upsert(moved2, ids=[7])
    res3 = idx.search(moved, k=1, r=1e9)
    assert _ids_of(res3).ravel()[0] != 7
    assert idx.n_points == 400  # replace never grows the live count


def test_delete_then_upsert_and_delta_delete():
    data, idx = _mk_index()
    assert idx.delete([3, 3, 9999]) == 1  # dupes/unknown are no-ops
    ids = idx.upsert(RNG.normal(size=(2, 8)).astype(np.float32))
    assert idx.delete(ids) == 2
    res = idx.search(data[:4], k=10, r=1e9)
    got = set(_ids_of(res).ravel().tolist())
    assert 3 not in got and not (set(ids.tolist()) & got)


def test_merge_topk_pads_small_pools():
    d, i = merge_topk(
        jnp.asarray([[1.0, 3.0]]), jnp.asarray([[10, 30]]),
        jnp.asarray([[2.0]]), jnp.asarray([[20]]), k=5,
    )
    assert np.asarray(i)[0, :3].tolist() == [10, 20, 30]
    assert np.asarray(i)[0, 3:].tolist() == [-1, -1]


# ---------------------------------------------------------------------------
# validation satellites
# ---------------------------------------------------------------------------


def test_build_validates_needs_dim_and_finiteness():
    pts3 = np.zeros((64, 3), np.float32)
    with pytest.raises(ValueError, match="haversine.*d=2"):
        PDASCIndex.build(pts3, gl=16, distance="haversine")
    bad = np.zeros((64, 4), np.float32)
    bad[5, 2] = np.nan
    with pytest.raises(ValueError, match="non-finite"):
        PDASCIndex.build(bad, gl=16, distance="euclidean")


def test_upsert_validates_inputs():
    pts2 = np.random.default_rng(0).uniform(-1, 1, (64, 2)).astype(np.float32)
    idx = PDASCIndex.build(pts2, gl=16, distance="haversine",
                           radius_quantile=0.9)
    with pytest.raises(ValueError, match="haversine.*d=2"):
        idx.upsert(np.zeros((1, 3), np.float32))
    with pytest.raises(ValueError, match="non-finite"):
        idx.upsert(np.array([[np.inf, 0.0]], np.float32))
    with pytest.raises(ValueError, match="duplicate ids"):
        idx.upsert(np.zeros((2, 2), np.float32), ids=[5, 5])


def test_delta_capacity_bound():
    data, idx = _mk_index(n=128, gl=32)
    idx.enable_mutations(delta_capacity=4)
    idx.upsert(RNG.normal(size=(3, 8)).astype(np.float32))
    with pytest.raises(RuntimeError, match="delta buffer full"):
        idx.upsert(RNG.normal(size=(2, 8)).astype(np.float32))
    assert idx.needs_compaction()  # fill ratio crossed the default trigger
    idx2 = idx.compact()
    assert idx2.delta.free == idx2.delta.capacity == 4
    idx2.upsert(RNG.normal(size=(2, 8)).astype(np.float32))  # room again


# ---------------------------------------------------------------------------
# (b) churn recall + compaction parity
# ---------------------------------------------------------------------------


def _interleaved_churn(idx, data, n_ops, rng, upsert_frac=0.65):
    live_extra = []
    for _ in range(n_ops):
        if rng.random() < upsert_frac or idx.n_points < 50:
            v = data[rng.integers(len(data))] + rng.normal(
                0, 0.05, data.shape[1]
            ).astype(np.float32)
            live_extra.extend(idx.upsert(v[None]).tolist())
        else:
            resident = np.asarray(idx.data.leaf_ids)
            resident = resident[resident >= 0]
            victim = (live_extra.pop() if live_extra and rng.random() < 0.5
                      else int(resident[rng.integers(len(resident))]))
            idx.delete([victim])


def test_churn_recall_and_compaction_parity():
    rng = np.random.default_rng(3)
    data = rng.normal(size=(1200, 16)).astype(np.float32)
    queries = rng.normal(size=(64, 16)).astype(np.float32)
    idx = PDASCIndex.build(data, gl=64, distance="euclidean",
                           radius_quantile=0.9)
    idx.enable_mutations(delta_capacity=512)
    _interleaved_churn(idx, data, n_ops=150, rng=rng)

    live_vecs, live_ids = live_dataset(idx)
    gt = _brute_topk(queries, live_vecs, live_ids, 10)
    fresh = PDASCIndex.build(live_vecs, gl=64, distance="euclidean",
                             radius_quantile=0.9)

    def recall(ids, gt):
        return np.mean([
            len(set(r[r >= 0].tolist()) & set(g.tolist())) / 10
            for r, g in zip(ids, gt)
        ])

    r = idx.default_radius
    res_mut = idx.search(queries, k=10, mode="beam", beam=16, r=r)
    res_fresh = fresh.search(queries, k=10, mode="beam", beam=16, r=r)
    rec_mut = recall(_ids_of(res_mut), gt)
    rf = _ids_of(res_fresh)  # rows into live_vecs -> original ids
    rf_mapped = np.where(
        rf >= 0, live_ids[np.clip(rf, 0, len(live_ids) - 1)], -1
    )
    rec_fresh = recall(rf_mapped, gt)
    assert rec_mut >= rec_fresh - 0.02, (rec_mut, rec_fresh)

    # compaction parity: exact (full) search over the compacted index and
    # over the from-scratch build return identical result sets
    comp = idx.compact(scope="affected")
    assert comp.epoch == idx.epoch + 1
    assert comp.delta.n_active == 0 and comp.tombstones.count == 0
    assert comp.n_points == len(live_ids)
    res_c = comp.search(queries, k=10, mode="dense", r=1e9)
    np.testing.assert_array_equal(np.sort(_ids_of(res_c), axis=1),
                                  np.sort(gt, axis=1))
    # and the recall at serving beam does not degrade vs the fresh build
    res_cb = comp.search(queries, k=10, mode="beam", beam=16, r=r)
    assert recall(_ids_of(res_cb), gt) >= rec_fresh - 0.02


def test_compaction_spill_and_empty_groups():
    """Arrivals overflowing their routed group spill into appended groups;
    fully-deleted groups compact away cleanly."""
    rng = np.random.default_rng(5)
    data = rng.normal(size=(128, 8)).astype(np.float32)
    idx = PDASCIndex.build(data, gl=32, distance="euclidean",
                           radius_quantile=0.9)
    idx.enable_mutations(delta_capacity=256)
    # kill group 0 entirely (slots 0..31 hold some padding-free residents)
    slot_ids = np.asarray(idx.data.leaf_ids)[:32]
    idx.delete(slot_ids[slot_ids >= 0])
    # flood one corner of space so one group overflows into spill groups
    flood = rng.normal(0, 0.01, size=(80, 8)).astype(np.float32) + 10.0
    ids = idx.upsert(flood)
    comp = idx.compact(scope="affected")
    lv, li = live_dataset(idx)
    assert comp.n_points == len(li)
    # spill really happened: the leaf level grew beyond the original slots
    assert comp.data.levels[0].points.shape[0] > 128
    # every live point is present exactly once in the compacted leaf level
    leaf_ids_c = np.asarray(comp.data.leaf_ids)
    live_c = leaf_ids_c[np.asarray(comp.data.levels[0].valid)]
    assert sorted(live_c.tolist()) == sorted(li.tolist())
    # queries inside the flood cloud resolve to flood ids only (the flood
    # points are near-coincident, so id-exact comparison against a float64
    # oracle would be a float32 tie-ordering lottery — subset is the stable
    # property), and the killed ids stay gone
    q = flood[:8]
    res = comp.search(q, k=5, mode="dense", r=1e9)
    got = set(_ids_of(res).ravel().tolist())
    assert got <= set(ids.tolist())
    assert not (set(slot_ids.tolist()) & got)


def test_compaction_partial_requant_reuses_frozen_blocks():
    data, idx = _mk_index(n=512, gl=64, store="int8")
    # touch exactly one group: delete a single resident
    idx.delete([int(np.asarray(idx.data.leaf_ids)[0])])
    comp = idx.compact(scope="affected")
    st = comp.store.last_rebuild
    assert st is not None and st["requantized"] < st["blocks"]
    # full scope requantises everything
    idx2 = _mk_index(n=512, gl=64, store="int8")[1]
    idx2.delete([int(np.asarray(idx2.data.leaf_ids)[0])])
    comp2 = idx2.compact(scope="full")
    st2 = comp2.store.last_rebuild
    assert st2["requantized"] == st2["blocks"]


def test_compact_full_matches_affected_result_sets():
    rng = np.random.default_rng(11)
    data = rng.normal(size=(600, 8)).astype(np.float32)
    idx = PDASCIndex.build(data, gl=64, distance="euclidean",
                           radius_quantile=0.9)
    idx.upsert(rng.normal(size=(20, 8)).astype(np.float32))
    idx.delete(rng.choice(600, 40, replace=False))
    a = idx.compact(scope="affected")
    f = idx.compact(scope="full")
    q = rng.normal(size=(16, 8)).astype(np.float32)
    ra = a.search(q, k=10, mode="dense", r=1e9)
    rf = f.search(q, k=10, mode="dense", r=1e9)
    np.testing.assert_array_equal(np.sort(_ids_of(ra), 1),
                                  np.sort(_ids_of(rf), 1))


def test_memory_bytes_reports_online_tiers():
    data, idx = _mk_index()
    m0 = idx.memory_bytes()
    assert m0["delta"] == 0 and m0["tombstones"] == 0
    idx.enable_mutations(delta_capacity=100)
    m1 = idx.memory_bytes()
    assert m1["delta"] >= 100 * 8 * 4  # capacity x d fp32 at minimum
    assert m1["tombstones"] >= idx.data.levels[0].points.shape[0] // 8
    assert m1["total_resident"] == (m1["navigation"] + m1["payload"]
                                    + m1["delta"] + m1["tombstones"])


def test_save_load_v3_roundtrip(tmp_path):
    data, idx = _mk_index()
    new = RNG.normal(size=(4, 8)).astype(np.float32) + 30.0
    ids = idx.upsert(new)
    idx.delete([1, 2, 3])
    p = str(tmp_path / "idx")
    idx.save(p)
    import json
    assert json.load(open(p + ".json"))["version"] == 3
    back = PDASCIndex.load(p)
    assert back.epoch == idx.epoch
    assert back.delta.n_active == idx.delta.n_active
    assert back.tombstones.count == idx.tombstones.count
    q = np.concatenate([data[:4], new], axis=0)
    ra = idx.search(q, k=10, r=1e9)
    rb = back.search(q, k=10, r=1e9)
    np.testing.assert_array_equal(_ids_of(ra), _ids_of(rb))
    comp = back.compact()  # a loaded mid-epoch index compacts fine
    assert comp.n_points == back.n_points


def test_frozen_index_still_saves_v2(tmp_path):
    data, idx = _mk_index(n=128, gl=32)
    p = str(tmp_path / "idx")
    idx.save(p)
    import json
    assert json.load(open(p + ".json"))["version"] == 2


# ---------------------------------------------------------------------------
# (c) epoch swap under a concurrent search stream — no torn results
# ---------------------------------------------------------------------------


def test_epoch_swap_never_tears_under_concurrent_stream():
    import threading

    rng = np.random.default_rng(13)
    data = rng.normal(size=(300, 8)).astype(np.float32)
    idx = PDASCIndex.build(data, gl=32, distance="euclidean",
                           radius_quantile=0.9)
    idx.enable_mutations(delta_capacity=24)
    handle = EpochHandle(idx, delta_fill=0.5, tombstone_ratio=0.1,
                         scope="affected")

    sentinel = np.full((1, 8), 50.0, np.float32)
    sid = int(idx.upsert(sentinel)[0])  # lives in the delta tier initially

    def handler(batch, n_valid):
        cur = handle.current  # ONE snapshot per batch
        res = cur.search(jnp.asarray(batch), k=3, mode="dense", r=1e9)
        return res.dists, res.ids

    engine = BatchingEngine(handler, batch_size=4, max_wait_ms=1.0,
                            pad_payload=np.zeros(8, np.float32),
                            write_handler=handle.apply_writes)
    try:
        engine.submit(sentinel[0]).wait(timeout=120)  # warmup compile

        failures = []
        done = threading.Event()

        def searcher():
            while not done.is_set():
                req = engine.submit(sentinel[0])
                _, ids = req.wait(timeout=60)
                if int(np.asarray(ids)[0]) != sid:
                    failures.append(np.asarray(ids).tolist())
                    return

        threads = [threading.Thread(target=searcher) for _ in range(2)]
        for t in threads:
            t.start()
        # write pressure: repeatedly cross the compaction thresholds so the
        # handle swaps epochs several times mid-stream
        upserted = []
        for i in range(60):
            if upserted and i % 3 == 0:
                engine.submit_delete(np.array([upserted.pop(0)]))
            else:
                v = data[rng.integers(300)] + rng.normal(0, 0.05, 8).astype(
                    np.float32
                )
                r = engine.submit_upsert(v)
                upserted.extend(int(x) for x in r.wait(timeout=60))
        done.set()
        for t in threads:
            t.join(timeout=60)
    finally:
        done.set()
        engine.close()
    assert not failures, f"torn result: sentinel {sid} missing in {failures}"
    assert handle.swaps >= 1, "test never exercised an epoch swap"
    # the sentinel survived every compaction into the resident tier
    final = handle.current
    res = final.search(sentinel, k=1, mode="dense", r=1e9)
    assert int(_ids_of(res).ravel()[0]) == sid


def test_engine_write_ordering_read_your_writes():
    """A search submitted after a write must observe it (FIFO: the write
    batch applies before the later search batch)."""
    rng = np.random.default_rng(17)
    data = rng.normal(size=(128, 8)).astype(np.float32)
    idx = PDASCIndex.build(data, gl=32, distance="euclidean",
                           radius_quantile=0.9)
    idx.enable_mutations(delta_capacity=64)
    handle = EpochHandle(idx)

    def handler(batch, n_valid):
        res = handle.current.search(jnp.asarray(batch), k=1, mode="dense",
                                    r=1e9)
        return res.ids

    engine = BatchingEngine(handler, batch_size=2, max_wait_ms=1.0,
                            pad_payload=np.zeros(8, np.float32),
                            write_handler=handle.apply_writes)
    try:
        target = np.full((8,), -60.0, np.float32)
        engine.submit(target).wait(timeout=120)  # warmup
        w = engine.submit_upsert(target)
        s = engine.submit(target)
        new_id = int(w.wait(timeout=60)[0])
        got = int(np.asarray(s.wait(timeout=60)).ravel()[0])
        assert got == new_id
    finally:
        engine.close()


def test_engine_rejects_writes_without_handler():
    engine = BatchingEngine(lambda b, n: b, batch_size=2)
    try:
        with pytest.raises(RuntimeError, match="write_handler"):
            engine.submit_upsert(np.zeros(4))
        with pytest.raises(RuntimeError, match="write_handler"):
            engine.submit_delete([1])
    finally:
        engine.close()


def test_engine_write_errors_surface_on_wait():
    idx = _mk_index(n=128, gl=32)[1]
    idx.enable_mutations(delta_capacity=64)
    handle = EpochHandle(idx)

    engine = BatchingEngine(lambda b, n: b, batch_size=2,
                            write_handler=handle.apply_writes)
    try:
        bad = engine.submit_upsert(np.array([[np.nan] * 8], np.float32))
        with pytest.raises(ValueError, match="non-finite"):
            bad.wait(timeout=60)
        # the worker survives a failed write: later writes still apply
        ok = engine.submit_upsert(np.ones((1, 8), np.float32))
        assert len(ok.wait(timeout=60)) == 1
    finally:
        engine.close()


def test_compaction_preserves_released_memmap_payload(tmp_path):
    """Epoch swap must not silently rehydrate the out-of-core payload: the
    new epoch gets a fresh per-epoch memmap file (never the old epoch's,
    whose granules RCU readers may still fetch) and stays released."""
    rng = np.random.default_rng(23)
    data = rng.normal(size=(256, 8)).astype(np.float32)
    path = str(tmp_path / "payload.bin")
    idx = PDASCIndex.build(data, gl=32, distance="euclidean",
                           radius_quantile=0.9, store="int8",
                           store_block=64, store_path=path)
    idx.release_dense_payload()
    far = np.stack([np.full(8, 30.0, np.float32),
                    np.full(8, 36.0, np.float32)])
    ids = idx.upsert(far)
    idx.delete([5])
    comp = idx.compact(scope="affected")
    assert comp.store.exact.on_disk
    assert comp.store.exact.path != idx.store.exact.path
    assert comp.store.exact.path.endswith(".epoch1")
    assert comp._payload_released  # memory budget survives the swap
    assert comp.memory_bytes()["out_of_core"] > 0
    res = comp.search(far, k=3, mode="two_stage", beam=16, rerank_width=8)
    assert _ids_of(res)[:, 0].tolist() == ids.tolist()
    assert 5 not in set(_ids_of(res).ravel().tolist())
    # a second swap chains: .epoch2, old file untouched
    comp.upsert(np.full((1, 8), -30.0, np.float32))
    comp2 = comp.compact(scope="affected")
    assert comp2.store.exact.path.endswith(".epoch2")
    assert os.path.exists(idx.store.exact.path)


def test_delta_leg_honours_leaf_radius_filter():
    data, idx = _mk_index()
    far = np.full((1, 8), 35.0, np.float32)
    fid = int(idx.upsert(far)[0])
    q = far[0] + 0.5  # within 1.5 of the upsert, far from everything else
    res = idx.search(q[None], k=3, r=2.0, leaf_radius_filter=True)
    assert _ids_of(res)[0, 0] == fid
    res2 = idx.search(q[None], k=3, r=0.5, leaf_radius_filter=True)
    assert fid not in set(_ids_of(res2).ravel().tolist())


def test_freed_ids_never_reissued_across_compaction(tmp_path):
    data, idx = _mk_index(n=128, gl=32)
    a = int(idx.upsert(np.full((1, 8), 20.0, np.float32))[0])  # id 128
    idx.delete([a])
    comp = idx.compact()
    b = int(comp.upsert(np.full((1, 8), 21.0, np.float32))[0])
    assert b > a, "freed id was re-issued after compaction"
    # and across persistence
    p = str(tmp_path / "idx")
    comp.delete([b])
    comp.save(p)
    back = PDASCIndex.load(p)
    c = int(back.upsert(np.full((1, 8), 22.0, np.float32))[0])
    assert c > b, "freed id was re-issued after save/load"


def test_apply_writes_isolates_per_op_errors():
    """One bad op in a write run must not mask the results of ops already
    durably applied in the same run."""
    _, idx = _mk_index(n=128, gl=32)
    idx.enable_mutations(delta_capacity=64)
    handle = EpochHandle(idx)
    good = np.full((1, 8), 15.0, np.float32)
    bad = np.array([[np.nan] * 8], np.float32)
    out = handle.apply_writes([
        ("upsert", good), ("upsert", bad), ("delete", np.array([0])),
    ])
    assert len(out) == 3
    assert not isinstance(out[0], BaseException) and len(out[0]) == 1
    assert isinstance(out[1], ValueError)
    assert out[2] == 1
    # the good upsert really is live
    res = handle.current.search(good, k=1, r=1e9)
    assert int(_ids_of(res).ravel()[0]) == int(out[0][0])


def test_search_handler_failure_does_not_kill_worker():
    """A handler exception fails that batch (wait() re-raises) and the
    worker keeps serving — it must never die and hang the queue."""
    calls = {"n": 0}

    def handler(batch, n_valid):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("transient handler failure")
        return batch

    engine = BatchingEngine(handler, batch_size=2, max_wait_ms=1.0,
                            pad_payload=np.zeros(4, np.float32))
    try:
        bad = engine.submit(np.ones(4, np.float32))
        with pytest.raises(RuntimeError, match="transient"):
            bad.wait(timeout=60)
        ok = engine.submit(np.full(4, 2.0, np.float32))
        np.testing.assert_array_equal(ok.wait(timeout=60),
                                      np.full(4, 2.0, np.float32))
    finally:
        engine.close()
