"""Query/plan layer (DESIGN.md §3.8).

Covers:
  (a) plan-vs-legacy parity: ``idx.plan(q)(Q)`` is bit-identical to the
      pre-redesign ``search()`` dispatcher (a literal port below is the
      oracle) for every pipeline — dense / beam / beam_vmap / two_stage —
      with and without dirty online tiers, and ``search_sharded`` parity in
      a fake-device subprocess;
  (b) retrace honesty: executing the same plan (and the same legacy
      ``search()`` call) twice triggers zero new jit traces;
  (c) plan caching: equal ``(query, fingerprint)`` returns the same plan
      object; stale plans transparently re-plan after capability changes;
  (d) plan-time capability conflicts, search-time query validation, the
      ``mode=`` back-compat shim (warns DeprecationWarning, still correct),
      the tombstones' cached device mask, and the engine's QueryHandler.
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_in_devices
from repro.core import nsa
from repro.core.distances import BIG
from repro.core.index import PDASCIndex
from repro.online import delta as delta_lib
from repro.query import (
    Query,
    capabilities,
    compile_sharded_plan,
    plan_stats,
    reset_plan_stats,
)
from repro.serving import BatchingEngine, QueryHandler
from repro.store import two_stage as two_stage_lib


# ---------------------------------------------------------------------------
# The parity oracle: a literal port of the pre-plan search() dispatcher
# ---------------------------------------------------------------------------


def legacy_search(idx, queries, *, k=10, r=None, mode="beam", beam=32,
                  rerank_width=128, leaf_radius_filter=False, kernel=None):
    """The pre-redesign ``PDASCIndex.search`` body, verbatim — the oracle
    every plan pipeline must match bit-for-bit."""
    Q = jnp.asarray(queries, jnp.float32)
    r = float(r) if r is not None else idx.default_radius
    squeeze = Q.ndim == 1
    Qb = Q[None, :] if squeeze else Q
    slot_valid = (
        idx.tombstones.valid_mask()
        if idx.tombstones is not None and idx.tombstones.count
        else None
    )
    if mode == "two_stage":
        res = two_stage_lib.search_two_stage(
            idx.data, idx.store, Qb, dist=idx.distance, k=k, r=r, beam=beam,
            max_children=idx.max_children, rerank_width=rerank_width,
            leaf_radius_filter=leaf_radius_filter, kernel=kernel,
            slot_valid=slot_valid,
        )
    elif mode == "dense":
        res = nsa.search_dense(
            idx.data, Qb, dist=idx.distance, k=k, r=r,
            leaf_radius_filter=leaf_radius_filter, kernel=kernel,
            slot_valid=slot_valid,
        )
    elif mode == "beam":
        res = nsa.search_beam(
            idx.data, Qb, dist=idx.distance, k=k, r=r, beam=beam,
            max_children=idx.max_children,
            leaf_radius_filter=leaf_radius_filter, kernel=kernel,
            slot_valid=slot_valid,
        )
    else:
        res = nsa.search_beam_vmap(
            idx.data, Qb, dist=idx.distance, k=k, r=r, beam=beam,
            max_children=idx.max_children,
            leaf_radius_filter=leaf_radius_filter,
        )
    if idx.delta is not None and idx.delta.n_active:
        scan = idx.delta.scan(Qb, idx.distance, k=k, kernel=kernel)
        sd, si = scan.dists, scan.ids
        if leaf_radius_filter:
            keep = sd < r
            sd = jnp.where(keep, sd, BIG)
            si = jnp.where(keep, si, -1)
        d_m, i_m = delta_lib.merge_topk(res.dists, res.ids, sd, si, k)
        res = nsa.SearchResult(
            dists=d_m, ids=i_m,
            n_candidates=res.n_candidates + jnp.int32(idx.delta.n_active),
        )
    if squeeze:
        res = jax.tree.map(lambda a: a[0], res)
    return res


def _build(n=720, d=12, gl=48, store=None, seed=0):
    rng = np.random.default_rng(seed)
    data = rng.normal(size=(n, d)).astype(np.float32)
    idx = PDASCIndex.build(data, gl=gl, distance="euclidean",
                           radius_quantile=0.6, store=store, store_block=64)
    return idx, data


def _dirty(idx, data, seed=1):
    """Make the online tiers dirty: a few upserts + deletes of residents."""
    rng = np.random.default_rng(seed)
    idx.upsert(data[:4] + rng.normal(0, 0.01, (4, data.shape[1]))
               .astype(np.float32))
    resident = np.asarray(idx.data.leaf_ids)
    idx.delete(resident[resident >= 0][:5])
    assert idx.delta.n_active and idx.tombstones.count
    return idx


def _assert_bit_identical(a, b):
    np.testing.assert_array_equal(np.asarray(a.dists), np.asarray(b.dists))
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    np.testing.assert_array_equal(
        np.asarray(a.n_candidates), np.asarray(b.n_candidates)
    )


# ---------------------------------------------------------------------------
# (a) plan-vs-legacy parity, clean + dirty online tiers
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dirty", [False, True], ids=["clean", "dirty"])
@pytest.mark.parametrize("mode", ["dense", "beam", "beam_vmap", "two_stage"])
def test_plan_matches_legacy_pipelines(mode, dirty):
    if mode == "beam_vmap" and dirty:
        pytest.skip("beam_vmap rejects dirty tiers (tested separately)")
    idx, data = _build(store="int8" if mode == "two_stage" else None)
    if dirty:
        _dirty(idx, data)
    Q = data[:9] + 0.05
    kw = dict(rerank_width=32) if mode == "two_stage" else {}
    expect = legacy_search(idx, Q, k=7, mode=mode, beam=16, **kw)
    got = idx.plan(Query(k=7, execution=mode, beam=16, **kw))(Q)
    _assert_bit_identical(got, expect)
    # 1-d query keeps the squeezed-result contract
    e1 = legacy_search(idx, Q[0], k=7, mode=mode, beam=16, **kw)
    g1 = idx.plan(Query(k=7, execution=mode, beam=16, **kw))(Q[0])
    assert g1.dists.shape == e1.dists.shape == (7,)
    _assert_bit_identical(g1, e1)


def test_plan_two_stage_infinite_rerank_matches_beam():
    """∞ rerank through the plan layer keeps the bit-identity guarantee."""
    idx, data = _build(store="int8")
    Q = data[:6]
    inf = idx.plan(Query(k=5, execution="two_stage", rerank_width=None))(Q)
    beam = idx.plan(Query(k=5, execution="beam"))(Q)
    _assert_bit_identical(inf, beam)


def test_mode_shim_warns_and_is_bit_identical():
    idx, data = _build()
    Q = data[:5]
    with pytest.warns(DeprecationWarning, match="mode=.*deprecated"):
        legacy = idx.search(Q, k=4, mode="dense")
    via_plan = idx.plan(Query(k=4, execution="dense"))(Q)
    _assert_bit_identical(legacy, via_plan)


def test_default_search_path_does_not_warn():
    idx, data = _build()
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        res = idx.search(data[:3], k=3)  # no mode= -> no shim warning
        res2 = idx.search(data[:3], k=3, query=Query(k=3))
    _assert_bit_identical(res, res2)


# ---------------------------------------------------------------------------
# (b) retrace honesty
# ---------------------------------------------------------------------------


def _trace_counts():
    """Cache sizes of every module-level jitted search entry point (the
    delta scan included — the dirty-tier merge leg must not retrace)."""
    fns = [nsa.search_dense, nsa.search_beam, nsa.search_beam_vmap,
           nsa.descend_beam, delta_lib._scan]
    return [fn._cache_size() for fn in fns]


@pytest.mark.parametrize("mode", ["dense", "beam", "two_stage"])
@pytest.mark.parametrize("dirty", [False, True], ids=["clean", "dirty"])
def test_repeated_execution_never_retraces(mode, dirty):
    idx, data = _build(store="int8" if mode == "two_stage" else None)
    if dirty:
        _dirty(idx, data)
    Q = data[:8]
    q = Query(k=5, execution=mode, beam=16)
    plan = idx.plan(q)
    plan(Q)  # first execution: traces compile
    before = _trace_counts()
    for _ in range(3):
        plan(Q)  # same plan
        idx.plan(q)(Q)  # re-planned equal query (cache hit)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            idx.search(Q, k=5, mode=mode, beam=16)  # legacy shim
    assert _trace_counts() == before, (
        f"re-executing an unchanged plan retraced: {before} -> "
        f"{_trace_counts()}"
    )


# ---------------------------------------------------------------------------
# (c) plan caching + staleness
# ---------------------------------------------------------------------------


def test_plan_cache_identity_and_stats():
    idx, data = _build()
    reset_plan_stats()
    p1 = idx.plan(Query(k=5))
    p2 = idx.plan(Query(k=5))
    assert p1 is p2
    assert idx.plan(Query(k=6)) is not p1
    stats = plan_stats()["beam"]
    assert stats["compiles"] == 2 and stats["cache_hits"] == 1
    p1(data[:4])
    assert plan_stats()["beam"]["executions"] == 1


def test_stale_plan_transparently_replans():
    idx, data = _build()
    plan = idx.plan(Query(k=5))
    clean = plan(data[:4])
    caps_before = capabilities(idx)
    _dirty(idx, data)
    assert capabilities(idx) != caps_before
    fresh = idx.plan(Query(k=5))
    assert fresh is not plan  # new fingerprint -> new plan
    # the stale plan still answers correctly (it re-resolves through the
    # index's plan cache) — including the new delta entries
    stale_res = plan(data[:4])
    _assert_bit_identical(stale_res, fresh(data[:4]))
    assert not np.array_equal(np.asarray(stale_res.ids),
                              np.asarray(clean.ids))


def test_plan_survives_compaction_epoch_swap():
    idx, data = _build(store="int8")
    _dirty(idx, data)
    new = idx.compact(scope="full")
    assert new.epoch == idx.epoch + 1
    # fresh epoch object: fresh plan cache, plans bind the new fingerprint
    p_old, p_new = idx.plan(Query(k=4)), new.plan(Query(k=4))
    assert p_old is not p_new
    assert p_new.caps.epoch == idx.epoch + 1
    res = p_new(data[:4])
    assert np.asarray(res.ids).shape == (4, 4)


# ---------------------------------------------------------------------------
# (d) plan-time conflicts, validation, explain, mask caching, serving
# ---------------------------------------------------------------------------


def test_capability_conflicts_are_plan_time_errors():
    idx, data = _build()
    with pytest.raises(ValueError, match="two_stage.*leaf store"):
        idx.plan(Query(execution="two_stage"))
    _dirty(idx, data)
    with pytest.raises(ValueError, match="beam_vmap.*online"):
        idx.plan(Query(execution="beam_vmap"))
    with pytest.raises(ValueError, match="mesh"):
        idx.plan(Query(execution="sharded"))

    rel, _ = _build(store="int8", seed=3)
    rel.release_dense_payload()
    for ex in ("dense", "beam", "beam_vmap"):
        with pytest.raises(ValueError, match="dense leaf payload"):
            rel.plan(Query(execution=ex))
    # auto on a released index binds two_stage instead of erroring
    assert rel.plan(Query()).pipeline == "two_stage"


def test_query_spec_validation():
    with pytest.raises(ValueError, match="unknown search mode"):
        Query(execution="bogus")
    with pytest.raises(ValueError, match="k must be >= 1"):
        Query(k=0)
    # schedules normalise to hashable tuples
    q = Query(beam=[4, 8, 16], radius=[1, 2, 3])
    assert q.beam == (4, 8, 16) and q.radius == (1.0, 2.0, 3.0)
    hash(q)


def test_search_time_query_validation():
    idx, data = _build()
    bad = data[:3].copy()
    bad[1, 0] = np.nan
    plan = idx.plan(Query(k=3))
    with pytest.raises(ValueError, match="non-finite"):
        plan(bad)
    with pytest.raises(ValueError, match="non-finite"):
        idx.search(bad, k=3)
    with pytest.raises(ValueError, match="does not match the index"):
        plan(data[:3, :-1])
    with pytest.raises(ValueError, match=r"\[d\] or \[B, d\]"):
        plan(data[:4].reshape(2, 2, -1))
    # device arrays: metadata checks still apply, but the non-finite data
    # scan is host-input-only (it would force a blocking device->host
    # transfer per call on the serving hot path)
    with pytest.raises(ValueError, match="does not match the index"):
        plan(jnp.asarray(data[:3, :-1]))
    plan(jnp.asarray(bad))  # trusted: committed device arrays skip the scan

    # needs_dim distances name themselves in the error
    geo = np.stack([np.random.default_rng(0).uniform(-1, 1, 200),
                    np.random.default_rng(1).uniform(-1, 1, 200)], 1)
    gidx = PDASCIndex.build(geo.astype(np.float32), gl=24,
                            distance="haversine", radius_quantile=0.6)
    with pytest.raises(ValueError, match="haversine.*d=2"):
        gidx.plan(Query(k=3))(np.zeros((2, 5), np.float32))


def test_explain_names_pipeline_and_legs():
    idx, data = _build(store="int8")
    text = idx.plan(Query(k=5, execution="two_stage")).explain()
    assert "two_stage" in text and "scan_quantized" in text
    assert "none (no dead slots)" in text and "delta buffer empty" in text
    _dirty(idx, data)
    text = idx.plan(Query(k=5, execution="beam")).explain()
    assert "rank_gathered" in text
    assert "valid_mask" in text and "merge_topk" in text


def test_describe_is_the_structured_explain():
    """Satellite (DESIGN.md §3.11): ``describe()`` is the machine-readable
    plan record — ``explain()`` is rendered from it, so the two can never
    drift; exporters/tests read the dict instead of parsing the string."""
    idx, data = _build(store="int8")
    plan = idx.plan(Query(k=5, execution="two_stage", rerank_width=32))
    d = plan.describe()
    assert d["pipeline"] == "two_stage"
    assert d["effective_pipeline"] == "two_stage"
    assert d["query"]["k"] == 5 and d["query"]["rerank_width"] == 32
    assert d["capabilities"] == plan.caps._asdict()
    assert d["online_legs"]["tombstone_mask"] is False
    assert d["online_legs"]["delta"] is False
    import json
    json.dumps(d)  # export-ready: plain JSON-serialisable values only
    # a stamped kernel config exports field-wise
    from repro.kernels.ops import KernelConfig
    dk = idx.plan(Query(k=5, execution="two_stage", rerank_width=32,
                        kernel=KernelConfig(bm=64))).describe()
    assert isinstance(dk["kernel"], dict) and dk["kernel"]["bm"] == 64
    # the human string is a pure rendering of the dict
    text = plan.explain()
    assert d["lowering"] in text
    assert f"k={d['query']['k']}" in text
    # the ∞-rerank refinement shows up structurally, not just as prose
    inf = idx.plan(Query(k=5, execution="two_stage", rerank_width=None))
    assert inf.describe()["effective_pipeline"] == "two_stage_inf"
    scan_only = idx.plan(Query(k=5, execution="two_stage", rerank_width=32,
                               exact_rerank=False))
    assert scan_only.describe()["effective_pipeline"] == "two_stage_scan"
    # a dirty index flips the online legs on
    _dirty(idx, data)
    d2 = idx.plan(Query(k=5, execution="beam")).describe()
    assert d2["online_legs"]["tombstone_mask"] is True
    assert d2["online_legs"]["delta"] is True


def test_tombstone_valid_mask_device_cache():
    """Satellite: the unpacked device mask is cached on the TombstoneSet —
    repeated searches between deletes reuse one array; a new delete (and
    only a mutation) invalidates it."""
    idx, data = _build()
    _dirty(idx, data)
    ts = idx.tombstones
    m1 = ts.valid_mask()
    assert ts.valid_mask() is m1  # cached device array, no re-upload
    idx.plan(Query(k=3))(data[:2])
    assert ts.valid_mask() is m1  # searching does not invalidate
    resident = np.asarray(idx.data.leaf_ids)
    idx.delete(resident[resident >= 0][10:11])
    m2 = ts.valid_mask()
    assert m2 is not m1  # mutation invalidated the cache
    assert ts.valid_mask() is m2
    # re-deleting an already-dead slot is a no-op: cache stays valid
    before = ts.count
    idx.delete(resident[resident >= 0][10:11])
    assert ts.count == before and ts.valid_mask() is m2


def test_engine_query_handler_reuses_plans_and_sees_writes():
    from repro.online import EpochHandle

    rng = np.random.default_rng(11)
    data = rng.normal(size=(160, 8)).astype(np.float32)
    idx = PDASCIndex.build(data, gl=32, distance="euclidean",
                           radius_quantile=0.9)
    idx.enable_mutations(delta_capacity=64)
    handle = EpochHandle(idx)
    handler = QueryHandler(handle, Query(k=1, execution="dense", radius=1e9))
    engine = BatchingEngine(handler, batch_size=2, max_wait_ms=1.0,
                            pad_payload=np.zeros(8, np.float32),
                            write_handler=handle.apply_writes)
    try:
        target = np.full((8,), -42.0, np.float32)
        engine.submit(data[0]).wait(timeout=120)  # warmup
        plan_before = handler.plan()
        engine.submit(data[1]).wait(timeout=60)
        # steady state: same capability fingerprint -> the same plan object
        assert handler.plan() is plan_before
        w = engine.submit_upsert(target)
        s = engine.submit(target)
        new_id = int(w.wait(timeout=60)[0])
        ids = np.asarray(s.wait(timeout=60)[1]).ravel()
        assert int(ids[0]) == new_id  # read-your-writes through the plan
        # the write flipped the fingerprint -> the handler re-planned
        assert handler.plan() is not plan_before
    finally:
        engine.close()


# ---------------------------------------------------------------------------
# sharded pipeline (fake-device subprocess)
# ---------------------------------------------------------------------------


def test_sharded_plan_parity_and_retrace():
    out = run_in_devices("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import distributed as dd, distances as dl, msa
from repro.launch.mesh import make_mesh
from repro.query import Query, compile_sharded_plan

mesh = make_mesh((4, 2), ("data", "model"))
rng = np.random.default_rng(2)
db = jnp.asarray(rng.normal(size=(1280, 10)).astype(np.float32))
Q = jnp.asarray(rng.normal(size=(12, 10)).astype(np.float32))
dist = dl.get("euclidean")
sidx = dd.build_sharded(db, mesh, db_axes=("data",), gl=40,
                        distance="euclidean")
mcs = msa.max_children(jax.tree.map(lambda a: a[0], sidx))
r = 6.0

for shard_mode, kw in (("dense", {}), ("beam", dict(max_children=mcs))):
    plan = compile_sharded_plan(
        mesh, Query(k=10, radius=r, execution=shard_mode, beam=16),
        dist="euclidean", db_axes=("data",), **kw)
    res = plan(sidx, Q)
    legacy = dd.search_sharded(
        sidx, Q, mesh, db_axes=("data",), dist=dist, k=10, r=r,
        mode=shard_mode, beam=16, **kw)
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(legacy.ids))
    np.testing.assert_array_equal(np.asarray(res.dists),
                                  np.asarray(legacy.dists))

# dirty-tier (tombstoned) sharded parity: mask the first two rows of shard 0
sv = np.ones((4, sidx.leaf_ids.shape[1]), bool)
leaf0 = np.asarray(sidx.leaf_ids[0])
dead_rows = leaf0[leaf0 >= 0][:2]
sv[0] = dd.local_slot_valid(leaf0, dead_rows)
plan = compile_sharded_plan(mesh, Query(k=10, radius=r, execution="dense"),
                            dist="euclidean", db_axes=("data",))
res_m = plan(sidx, Q, slot_valid=sv)
legacy_m = dd.search_sharded(sidx, Q, mesh, db_axes=("data",), dist=dist,
                             k=10, r=r, mode="dense", slot_valid=sv)
np.testing.assert_array_equal(np.asarray(res_m.ids), np.asarray(legacy_m.ids))
dead_global = set((dead_rows + 0 * sidx.leaf_ids.shape[1]).tolist())
assert not (dead_global & set(np.asarray(res_m.ids).ravel().tolist()))

# retrace honesty: repeated plan execution reuses one cached executor
misses = dd._sharded_search_fn.cache_info().misses
for _ in range(3):
    plan(sidx, Q, slot_valid=sv)
assert dd._sharded_search_fn.cache_info().misses == misses
print("SHARDED_PLAN_OK")
""")
    assert "SHARDED_PLAN_OK" in out
