"""Property tests for the distance registry (hypothesis)."""

from _hypothesis_compat import hnp, hypothesis, st
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import distances as dl

VECS = hnp.arrays(
    np.float32, st.tuples(st.integers(1, 6), st.integers(2, 8)),
    elements=st.floats(-10, 10, width=32),
)

ALL_NAMES = [n for n in dl.names() if n != "haversine"]


@hypothesis.given(X=VECS)
@hypothesis.settings(max_examples=25, deadline=None)
@pytest.mark.parametrize("name", ALL_NAMES)
def test_point_pairwise_consistent(name, X):
    """pairwise(X, X)[i, j] == point(X[i], X[j])."""
    if name == "jaccard":
        X = np.abs(X)
    dist = dl.get(name)
    Xj = jnp.asarray(X)
    D = np.asarray(dist.pairwise(Xj, Xj))
    # The Gram-form pairwise (xx + yy - 2xy) carries an f32 cancellation
    # residual of ~eps * |x|^2; after sqrt that is ~|x| * sqrt(eps) — the
    # tolerance must scale with the input magnitude.
    scale = float(np.abs(X).max()) + 1.0
    for i in range(X.shape[0]):
        for j in range(X.shape[0]):
            p = float(dist.point(Xj[i], Xj[j]))
            assert abs(D[i, j] - p) < 1e-3 * scale + 1e-3 * abs(p)


@hypothesis.given(X=VECS)
@hypothesis.settings(max_examples=25, deadline=None)
@pytest.mark.parametrize("name", ALL_NAMES)
def test_symmetry_nonnegativity(name, X):
    if name == "jaccard":
        X = np.abs(X)
    dist = dl.get(name)
    D = np.asarray(dist.pairwise(jnp.asarray(X), jnp.asarray(X)))
    if name != "dot":  # dot dissimilarity may be negative by design
        assert (D > -1e-5).all(), "non-negative"
    np.testing.assert_allclose(D, D.T, atol=1e-4)


@hypothesis.given(X=VECS)
@hypothesis.settings(max_examples=25, deadline=None)
@pytest.mark.parametrize("name", ["euclidean", "manhattan", "chebyshev"])
def test_triangle_inequality_metrics(name, X):
    dist = dl.get(name)
    D = np.asarray(dist.pairwise(jnp.asarray(X), jnp.asarray(X)))
    n = D.shape[0]
    for i in range(n):
        for j in range(n):
            for k_ in range(n):
                assert D[i, j] <= D[i, k_] + D[k_, j] + 1e-3


def test_identity_of_indiscernibles():
    X = np.random.default_rng(0).normal(size=(5, 4)).astype(np.float32)
    for name in ["euclidean", "manhattan", "chebyshev", "cosine"]:
        D = np.asarray(dl.get(name).pairwise(jnp.asarray(X), jnp.asarray(X)))
        # Gram-form euclidean computes sqrt(xx + yy - 2xy); the f32
        # cancellation leaves an O(sqrt(eps * ||x||^2)) residual on the
        # diagonal, so the tolerance cannot be tighter than ~1e-3 there.
        atol = 2e-3 if dl.get(name).gram_form else 1e-5
        np.testing.assert_allclose(np.diag(D), 0.0, atol=atol)


def test_fractional_not_metric():
    """p=0.5 must violate the triangle inequality somewhere (paper §3.2)."""
    X = jnp.asarray([[0.0, 0.0], [1.0, 0.0], [1.0, 1.0]], jnp.float32)
    D = np.asarray(dl.get("fractional05").pairwise(X, X))
    assert D[0, 2] > D[0, 1] + D[1, 2]


def test_haversine_known_values():
    dist = dl.get("haversine")
    x = jnp.asarray([[0.0, 0.0]])
    y = jnp.asarray([[0.0, np.pi / 2]])  # quarter circle on the equator
    np.testing.assert_allclose(float(dist.pairwise(x, y)[0, 0]), np.pi / 2,
                               rtol=1e-5)
    # antipodal
    y2 = jnp.asarray([[0.0, np.pi]])
    np.testing.assert_allclose(float(dist.pairwise(x, y2)[0, 0]), np.pi,
                               rtol=1e-5)


def test_cosine_bounds_and_scale_invariance():
    rng = np.random.default_rng(1)
    X = jnp.asarray(rng.normal(size=(8, 5)).astype(np.float32))
    D1 = np.asarray(dl.get("cosine").pairwise(X, X))
    D2 = np.asarray(dl.get("cosine").pairwise(X * 7.5, X))
    assert (D1 >= -1e-6).all() and (D1 <= 2 + 1e-6).all()
    np.testing.assert_allclose(D1, D2, atol=1e-5)


def test_minkowski_factory_and_registry_errors():
    d3 = dl.minkowski(3.0)
    X = jnp.asarray(np.random.default_rng(2).normal(size=(4, 3)), jnp.float32)
    D = np.asarray(d3.pairwise(X, X))
    assert D.shape == (4, 4) and d3.is_metric
    assert not dl.minkowski(0.5).is_metric
    with pytest.raises(KeyError):
        dl.get("nope")


def test_pairwise_chunked_matches():
    rng = np.random.default_rng(3)
    X = jnp.asarray(rng.normal(size=(300, 6)), jnp.float32)
    Y = jnp.asarray(rng.normal(size=(50, 6)), jnp.float32)
    for name in ["manhattan", "chebyshev"]:
        full = dl.get(name).pairwise(X, Y)
        chunked = dl.pairwise_chunked(name, X, Y, chunk=128)
        np.testing.assert_allclose(np.asarray(full), np.asarray(chunked),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Registration lifecycle (re-import safety) + persistence identity
# ---------------------------------------------------------------------------


def test_register_identical_entry_is_idempotent():
    """Re-registering a structurally identical entry (module re-import,
    pytest --forked, notebook kernel restarts) must be a no-op."""
    euclid = dl.get("euclidean")
    clone = dl.Distance(
        name="euclidean",
        point=euclid.point,
        pairwise=euclid.pairwise,
        gram_form=True,
    )
    assert dl.register(clone) is euclid  # the registry keeps its entry
    assert dl.get("euclidean") is euclid


def test_register_conflicting_entry_raises_and_overwrite_escapes():
    probe = dl.Distance(
        name="_test_probe", point=lambda x, y: jnp.float32(0.0),
        pairwise=lambda X, Y: jnp.zeros((X.shape[0], Y.shape[0])),
    )
    try:
        dl.register(probe)
        other = dl.Distance(
            name="_test_probe", point=lambda x, y: jnp.float32(1.0),
            pairwise=lambda X, Y: jnp.ones((X.shape[0], Y.shape[0])),
        )
        with pytest.raises(ValueError, match="different definition"):
            dl.register(other)
        assert dl.register(other, overwrite=True) is other
        assert dl.get("_test_probe") is other
    finally:
        dl._REGISTRY.pop("_test_probe", None)


def test_distance_name_roundtrips_through_persistence(tmp_path):
    """save/load carries the distance *name*; the loaded index resolves it
    back to the live registry entry."""
    from repro.core.index import PDASCIndex

    data = np.random.default_rng(0).normal(size=(96, 6)).astype(np.float32)
    idx = PDASCIndex.build(data, gl=16, distance="cosine",
                           radius_quantile=0.9)
    p = str(tmp_path / "cosidx")
    idx.save(p)
    back = PDASCIndex.load(p)
    assert back.distance is dl.get("cosine")
    q = data[:4]
    np.testing.assert_array_equal(
        np.asarray(idx.search(q, k=5).ids), np.asarray(back.search(q, k=5).ids)
    )


def test_adhoc_distance_save_raises_clearly(tmp_path):
    """An unregistered ad-hoc distance must fail at save() with guidance —
    not as a KeyError surprise at load time."""
    from repro.core.index import PDASCIndex

    data = np.random.default_rng(0).normal(size=(96, 6)).astype(np.float32)
    idx = PDASCIndex.build(data, gl=16, distance=dl.minkowski(2.5),
                           radius_quantile=0.9)
    with pytest.raises(ValueError, match="not in the registry"):
        idx.save(str(tmp_path / "adhoc"))
    # registering it makes the same index saveable and round-trippable
    try:
        dl.register(idx.distance)
        idx.save(str(tmp_path / "adhoc"))
        back = PDASCIndex.load(str(tmp_path / "adhoc"))
        assert back.distance.name == "minkowski_2.5"
    finally:
        dl._REGISTRY.pop("minkowski_2.5", None)


def test_register_closure_factory_with_different_captures_raises():
    """Two closures from the same source line capturing different values
    are different distances — structural identity must see the cells."""

    def factory(w):
        return dl.Distance(
            name="_test_weighted",
            point=lambda x, y: w * jnp.sum(jnp.abs(x - y), axis=-1),
            pairwise=lambda X, Y: w * jnp.sum(
                jnp.abs(X[:, None, :] - Y[None, :, :]), axis=-1
            ),
            is_metric=False,
        )

    try:
        first = dl.register(factory(1.0))
        # identical capture: idempotent (the module re-import case)
        assert dl.register(factory(1.0)) is first
        with pytest.raises(ValueError, match="different definition"):
            dl.register(factory(2.0))
    finally:
        dl._REGISTRY.pop("_test_weighted", None)


def test_reimport_of_builtin_registry_is_idempotent():
    """A fresh import of the distances module (new function objects,
    including the closure-based haversine/jaccard pairwise) must re-register
    every builtin without error."""
    import importlib.util

    spec = importlib.util.spec_from_file_location(
        "repro.core.distances", dl.__file__
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)  # module body re-runs every register()
    assert set(mod.names()) == set(dl.names())
