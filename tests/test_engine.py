"""BatchingEngine robustness: shutdown races, deadlines, cancellation,
extra handler kinds, completion callbacks (DESIGN.md §3.10)."""

import threading
import time

import numpy as np
import pytest

from repro.serving import BatchingEngine, Cancelled, DeadlineExceeded


def _double(batch, n_valid):
    return batch * 2.0


def _pad():
    return np.zeros(3, np.float32)


def _row(i):
    return np.full(3, float(i), np.float32)


# --------------------------- shutdown races ---------------------------------


def test_concurrent_submit_vs_close_never_strands_a_request():
    """Every submit() either raises at the call site or its request
    completes — no request may hang forever because close() raced it."""
    for trial in range(10):
        eng = BatchingEngine(_double, batch_size=4, max_wait_ms=1.0,
                             pad_payload=_pad())
        accepted: list = []
        rejected = [0]
        barrier = threading.Barrier(5)

        def submitter(base):
            barrier.wait()
            for i in range(20):
                try:
                    accepted.append(eng.submit(_row(base + i)))
                except RuntimeError:
                    rejected[0] += 1

        def closer():
            barrier.wait()
            time.sleep(0.002 * (trial % 4))
            eng.close()

        threads = [threading.Thread(target=submitter, args=(100 * t,))
                   for t in range(4)] + [threading.Thread(target=closer)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        # accepted requests were enqueued before the shutdown sentinel: the
        # worker drains them all before exiting — a short wait must succeed
        for req in accepted:
            out = req.wait(timeout=10)
            np.testing.assert_allclose(out, req.payload * 2.0)
        assert len(accepted) + rejected[0] == 80


def test_mid_fill_shutdown_still_serves_partial_batch():
    """close() racing a batch fill: the sentinel lands mid-fill, and the
    partial batch must still be served (not dropped)."""
    release = threading.Event()

    def slow_once(batch, n_valid):
        release.wait(5)
        return batch * 2.0

    eng = BatchingEngine(slow_once, batch_size=8, max_wait_ms=200,
                         pad_payload=_pad())
    # first request occupies the worker once it departs; keep the fill open
    # long (max_wait 200ms) so close()'s sentinel arrives mid-fill
    reqs = [eng.submit(_row(i)) for i in range(3)]
    time.sleep(0.03)  # the worker is inside _take_batch's fill loop

    closer = threading.Thread(target=eng.close)
    closer.start()
    time.sleep(0.01)
    release.set()
    closer.join(timeout=10)
    for i, req in enumerate(reqs):
        np.testing.assert_allclose(req.wait(timeout=10), _row(i) * 2.0)
    assert eng.stats["requests"] == 3


# --------------------------- deadlines + cancellation ------------------------


def test_deadline_expired_requests_drop_with_deadline_exceeded():
    gate = threading.Event()

    def gated(batch, n_valid):
        gate.wait(10)
        return batch * 2.0

    eng = BatchingEngine(gated, batch_size=2, max_wait_ms=0.1,
                         pad_payload=_pad())
    blocker = eng.submit(_row(0))  # occupies the worker inside gated()
    time.sleep(0.02)
    doomed = eng.submit(_row(1), deadline_s=0.01)  # expires while queued
    time.sleep(0.05)
    gate.set()
    with pytest.raises(DeadlineExceeded):
        doomed.wait(timeout=10)
    np.testing.assert_allclose(blocker.wait(timeout=10), _row(0) * 2.0)
    eng.close()
    assert eng.stats["deadline_drops"] == 1
    # the dropped request never occupied a batch slot
    assert eng.stats["requests"] == 1


def test_wait_timeout_marks_cancellable_and_worker_skips():
    gate = threading.Event()
    served = []

    def gated(batch, n_valid):
        gate.wait(10)
        served.append(n_valid)
        return batch * 2.0

    eng = BatchingEngine(gated, batch_size=2, max_wait_ms=0.1,
                         pad_payload=_pad())
    blocker = eng.submit(_row(0))
    time.sleep(0.02)
    abandoned = eng.submit(_row(1))
    with pytest.raises(TimeoutError):
        abandoned.wait(timeout=0.01)  # waiter gives up -> marks cancelled
    assert abandoned.cancelled
    gate.set()
    np.testing.assert_allclose(blocker.wait(timeout=10), _row(0) * 2.0)
    eng.close()
    # the abandoned request was skipped at batch assembly, never served
    assert eng.stats["cancelled_skips"] == 1
    assert sum(served) == 1
    with pytest.raises(Cancelled):
        abandoned.wait(timeout=0)


def test_writes_are_never_deadline_dropped():
    applied = []

    def write_handler(ops):
        applied.extend(k for k, _ in ops)
        return [None] * len(ops)

    gate = threading.Event()

    def gated(batch, n_valid):
        gate.wait(10)
        return batch * 2.0

    eng = BatchingEngine(gated, batch_size=2, max_wait_ms=0.1,
                         pad_payload=_pad(), write_handler=write_handler)
    blocker = eng.submit(_row(0))
    time.sleep(0.02)
    w = eng.submit_upsert(_row(1))
    gate.set()
    blocker.wait(timeout=10)
    w.wait(timeout=10)
    eng.close()
    assert applied == ["upsert"]
    assert eng.stats["deadline_drops"] == 0


# --------------------------- occupancy + callbacks ---------------------------


def test_mean_occupancy_zero_batches_is_zero():
    eng = BatchingEngine(_double, batch_size=4, max_wait_ms=1.0,
                         pad_payload=_pad())
    assert eng.mean_occupancy == 0.0  # no division by zero before traffic
    eng.close()
    assert eng.mean_occupancy == 0.0


def test_on_done_fires_exactly_once_for_results_and_drops():
    fired = []
    eng = BatchingEngine(_double, batch_size=2, max_wait_ms=0.5,
                         pad_payload=_pad())
    ok = eng.submit(_row(1), on_done=lambda r: fired.append(("ok", r.id)))
    ok.wait(timeout=10)
    dead = eng.submit(_row(2), on_done=lambda r: fired.append(("dead", r.id)))
    dead.cancel()
    eng.submit(_row(3)).wait(timeout=10)  # flushes the cancelled one through
    eng.close()
    kinds = [k for k, _ in fired]
    assert kinds.count("ok") == 1
    assert kinds.count("dead") == 1


def test_extra_handler_kinds_batch_homogeneously():
    def triple(batch, n_valid):
        return batch * 3.0

    eng = BatchingEngine(_double, batch_size=4, max_wait_ms=5.0,
                         pad_payload=_pad(),
                         extra_handlers={"degraded": triple})
    reqs = [eng.submit(_row(i), kind="degraded" if i % 2 else "search")
            for i in range(8)]
    outs = [r.wait(timeout=10) for r in reqs]
    eng.close()
    for i, out in enumerate(outs):
        np.testing.assert_allclose(out, _row(i) * (3.0 if i % 2 else 2.0))


def test_extra_handlers_validate_kinds():
    with pytest.raises(ValueError, match="shadow"):
        BatchingEngine(_double, batch_size=2, extra_handlers={"search": _double})
    eng = BatchingEngine(_double, batch_size=2, pad_payload=_pad())
    with pytest.raises(ValueError, match="unknown request kind"):
        eng.submit(_row(0), kind="degraded")
    eng.close()
