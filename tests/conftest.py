import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def run_in_devices(script: str, n_devices: int = 8, timeout: int = 480) -> str:
    """Run a python snippet in a subprocess with N fake devices.

    Multi-device tests must not pollute this process (jax locks the device
    count on first init and the main suite runs single-device).
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True,
        text=True, timeout=timeout,
    )
    assert proc.returncode == 0, (
        f"subprocess failed:\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr[-4000:]}"
    )
    return proc.stdout
