import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_between_modules():
    """Drop compiled executables after each test module.

    The full suite compiles thousands of distinct executables; on
    single-core CPU runners the accumulated live LLVM JIT state eventually
    segfaults the XLA compiler mid-`backend_compile` (reproducible at the
    same test with the suite run whole, absent with the module run alone).
    Per-module cache clearing keeps the live-executable population bounded.
    In-module cache-count assertions (tests/test_query.py) are unaffected —
    clearing happens only at module boundaries."""
    yield
    import jax

    jax.clear_caches()


@pytest.fixture(autouse=True)
def _obs_isolation():
    """Reset repro.obs process-wide state after every test.

    The metrics registry and trace context are module-level singletons; a
    test that increments counters, disables the registry, or leaves a span
    activated would otherwise leak into every later test's snapshot.
    Teardown-only (the test runs against whatever it sets up itself), so
    module-scoped fixtures that pre-bind handles inside a test body keep
    them live for that test."""
    yield
    from repro import obs
    from repro.obs import trace as _trace

    obs.set_enabled(True)
    obs.reset()
    _trace._local.spans = ()


def run_in_devices(script: str, n_devices: int = 8, timeout: int = 480) -> str:
    """Run a python snippet in a subprocess with N fake devices.

    Multi-device tests must not pollute this process (jax locks the device
    count on first init and the main suite runs single-device).
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True,
        text=True, timeout=timeout,
    )
    assert proc.returncode == 0, (
        f"subprocess failed:\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr[-4000:]}"
    )
    return proc.stdout
