"""Dry-run machinery on a small 16-device mesh (subprocess): every family
lowers + compiles; collective parsing and probe extrapolation behave."""

import pytest

from conftest import run_in_devices


def test_cells_lower_and_compile_small_mesh():
    out = run_in_devices("""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=16")
import jax
from repro.launch import mesh as mesh_lib

def small_mesh(*, multi_pod=False):
    shape = (2, 2, 4) if multi_pod else (4, 4)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return mesh_lib.make_mesh(shape, axes)

mesh_lib.make_production_mesh = small_mesh
from repro.launch import dryrun

cells = [
    ("granite-3-2b", "train_4k", "single"),
    ("deepseek-moe-16b", "decode_32k", "multi"),
    ("egnn", "minibatch_lg", "single"),
    ("din", "serve_p99", "multi"),
    ("autoint", "train_batch", "single"),
    ("pdasc", "search_1m", "single"),
]
for arch, shape, mk in cells:
    res = dryrun.run_cell(arch, shape, mk)
    assert res["ok"]
    assert res["cost_analysis"].get("flops", 0) > 0, (arch, shape)
    assert res["roofline"]["step_time_lower_bound_s"] > 0
    print("CELL_OK", arch, shape, mk, res["roofline"]["bottleneck"])
print("ALL_CELLS_OK")
""", n_devices=16, timeout=570)
    assert "ALL_CELLS_OK" in out


def test_probe_extrapolation_monotone():
    out = run_in_devices("""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=16")
import jax
from repro.launch import mesh as mesh_lib

def small_mesh(*, multi_pod=False):
    return mesh_lib.make_mesh((4, 4), ("data", "model"))

mesh_lib.make_production_mesh = small_mesh
from repro.launch import dryrun

res = dryrun.run_cell("stablelm-1.6b", "train_4k", "single")
p = res["probe"]
assert p is not None and p["n_layers"] == 24
# two layers cost more than one; corrected >= probe2
assert p["probe2"]["flops"] > p["probe1"]["flops"]
assert p["corrected"]["flops"] >= p["probe2"]["flops"]
# corrected must exceed the raw scan-counted number
assert p["corrected"]["flops"] > res["cost_analysis"]["flops"]
# and land within 3x of the analytic 8*N*D (remat) estimate
model = res["meta"]["model_flops"]
ratio = model / (p["corrected"]["flops"] * res["n_chips"])
assert 0.2 < ratio < 3.0, ratio
print("PROBE_OK", ratio)
""", n_devices=16, timeout=570)
    assert "PROBE_OK" in out


def test_collective_parser():
    from repro.launch.dryrun import parse_collectives

    hlo = """
  %ar = f32[128,256]{1,0} all-reduce(f32[128,256]{1,0} %x), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag.1 = bf16[64,512]{1,0} all-gather(bf16[64,128]{1,0} %y), replica_groups=[4,4]<=[16], dimensions={1}
  %a2a = (f32[8,8]{1,0}) all-to-all(f32[8,8]{1,0} %z), replica_groups={{0,1}}
  %done = f32[128,256]{1,0} all-reduce-done(f32[128,256]{1,0} %ar)
  %cp = u32[4]{0} collective-permute(u32[4]{0} %w), source_target_pairs={{0,1}}
"""
    out = parse_collectives(hlo)
    assert out["all-reduce"]["count"] == 1
    assert out["all-reduce"]["out_bytes"] == 128 * 256 * 4
    # ring factor 2*(g-1)/g with g=4
    assert abs(out["all-reduce"]["traffic_bytes"]
               - 128 * 256 * 4 * 1.5) < 1e-6
    assert out["all-gather"]["count"] == 1
    assert out["all-gather"]["out_bytes"] == 64 * 512 * 2
    assert out["all-to-all"]["count"] == 1
    assert out["collective-permute"]["count"] == 1
    assert out["total_traffic_bytes"] > 0


def test_production_mesh_shapes():
    out = run_in_devices("""
import os
import jax
from repro.launch.mesh import make_production_mesh
m1 = make_production_mesh()
assert m1.axis_names == ("data", "model") and m1.devices.shape == (16, 16)
m2 = make_production_mesh(multi_pod=True)
assert m2.axis_names == ("pod", "data", "model")
assert m2.devices.shape == (2, 16, 16)
print("MESH_OK")
""", n_devices=512, timeout=240)
    assert "MESH_OK" in out
