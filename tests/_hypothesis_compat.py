"""Optional-hypothesis shim.

The property tests use hypothesis when it is installed; without it the whole
module previously died at import, taking every plain test in the file down
with it. Importing ``hypothesis``/``st``/``hnp`` from here keeps the plain
tests collectable everywhere: when hypothesis is missing, ``@hypothesis.given``
replaces the property test with a single skipped test and strategy
construction degrades to inert placeholders.
"""

from __future__ import annotations

import pytest

try:
    # extra.numpy failing must degrade to the stub too: a None hnp would
    # crash module-level strategy definitions and re-break collection.
    import hypothesis
    import hypothesis.extra.numpy as hnp
    import hypothesis.strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Inert placeholder: any call / attribute yields another placeholder,
        so module-level strategy definitions still evaluate."""

        def __call__(self, *args, **kwargs):
            return _Strategy()

        def __getattr__(self, name):
            return _Strategy()

    class _HypothesisStub:
        def given(self, *args, **kwargs):
            def deco(fn):
                # Replace the test outright (given is the outermost decorator
                # in this repo); *args keeps pytest from resolving the
                # strategy parameters as fixtures.
                def skipped(*a, **k):
                    pytest.skip("hypothesis not installed")

                skipped.__name__ = fn.__name__
                skipped.__doc__ = fn.__doc__
                return skipped

            return deco

        def settings(self, *args, **kwargs):
            return lambda fn: fn

        def __getattr__(self, name):
            return _Strategy()

    hypothesis = _HypothesisStub()
    st = _Strategy()
    hnp = _Strategy()

__all__ = ["hypothesis", "st", "hnp", "HAVE_HYPOTHESIS"]
