"""Distributed PDASC + collectives (8 fake devices, subprocess-isolated)."""

from conftest import run_in_devices


def test_exact_merge_and_butterfly():
    out = run_in_devices("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import distributed as dd
from repro.kernels.ref import knn_ref
from repro.launch.mesh import make_mesh

mesh = make_mesh((4, 2), ("data", "model"))
rng = np.random.default_rng(0)
db = jnp.asarray(rng.normal(size=(1600, 16)).astype(np.float32))
Q = jnp.asarray(rng.normal(size=(8, 16)).astype(np.float32))
gd, gi = dd.exact_knn_sharded(db, Q, mesh, db_axes=("data",), distance="l2", k=10)
wd, wi = knn_ref(Q, db, 10, "l2")
assert float(jnp.max(jnp.abs(gd - wd))) < 1e-5
for i in range(8):
    assert set(np.asarray(gi[i]).tolist()) == set(np.asarray(wi[i]).tolist())
gd2, gi2 = dd.exact_knn_sharded(db, Q, mesh, db_axes=("data",), distance="l2",
                                k=10, merge="allgather")
assert bool(jnp.allclose(gd, gd2))
# multi-axis merge (data then model)
gd3, _ = dd.exact_knn_sharded(db, Q, mesh, db_axes=("data", "model"),
                              distance="l2", k=10)
assert bool(jnp.allclose(gd, gd3))
print("MERGE_OK")
""")
    assert "MERGE_OK" in out


def test_sharded_build_search_recall():
    out = run_in_devices("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import distributed as dd, distances as dl, radius as rl
from repro.kernels.ref import knn_ref
from repro.launch.mesh import make_mesh

mesh = make_mesh((4, 2), ("data", "model"))
rng = np.random.default_rng(1)
db = jnp.asarray(rng.normal(size=(1600, 12)).astype(np.float32))
Q = jnp.asarray(rng.normal(size=(16, 12)).astype(np.float32))
dist = dl.get("euclidean")
sidx = dd.build_sharded(db, mesh, db_axes=("data",), gl=50,
                        distance="euclidean")
assert sidx.levels[0].points.shape[0] == 4  # one sub-index per data shard
r = rl.estimate_radius(db, dist, quantile=0.85)
res = dd.search_sharded(sidx, Q, mesh, db_axes=("data",), dist=dist, k=10,
                        r=float(r), mode="dense")
_, gt = knn_ref(Q, db, 10, "l2")
rec = np.mean([len(set(np.asarray(res.ids[i]).tolist())
                   & set(np.asarray(gt[i]).tolist())) / 10 for i in range(16)])
assert rec > 0.9, rec
# ids must be valid global rows
ids = np.asarray(res.ids)
assert ((ids >= -1) & (ids < 1600)).all()
print("SHARDED_OK", rec)
""")
    assert "SHARDED_OK" in out


def test_butterfly_is_permutation_invariant():
    """Global top-k must not depend on which shard holds which rows."""
    out = run_in_devices("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import distributed as dd
from repro.launch.mesh import make_mesh

mesh = make_mesh((8,), ("data",))
rng = np.random.default_rng(2)
db = rng.normal(size=(800, 8)).astype(np.float32)
Q = jnp.asarray(rng.normal(size=(4, 8)).astype(np.float32))
d1, i1 = dd.exact_knn_sharded(jnp.asarray(db), Q, mesh, db_axes=("data",), k=7)
perm = rng.permutation(800)
d2, i2 = dd.exact_knn_sharded(jnp.asarray(db[perm]), Q, mesh,
                              db_axes=("data",), k=7)
assert np.allclose(np.asarray(d1), np.asarray(d2), atol=1e-5)
# map permuted ids back
i2_orig = perm[np.asarray(i2)]
for q in range(4):
    assert set(np.asarray(i1[q]).tolist()) == set(i2_orig[q].tolist())
print("PERM_OK")
""")
    assert "PERM_OK" in out


def test_compressed_dp_step_runs_and_learns():
    out = run_in_devices("""
import numpy as np, jax, jax.numpy as jnp
from repro.launch.mesh import make_mesh, set_mesh
from repro.optim import AdamWConfig, adamw_init
from repro.train.dp_step import make_compressed_dp_step

mesh = make_mesh((2, 4), ("pod", "data"))
rng = np.random.default_rng(3)
W_true = rng.normal(size=(16, 1)).astype(np.float32)

def loss_fn(params, batch):
    pred = batch["x"] @ params["w"]
    return jnp.mean((pred - batch["y"]) ** 2), {}

params = {"w": jnp.zeros((16, 1), jnp.float32)}
opt = adamw_init(params)
step, init_cs = make_compressed_dp_step(
    loss_fn, mesh, AdamWConfig(lr=3e-2, weight_decay=0.0, total_steps=100,
                               warmup_steps=0, schedule="constant"),
    compress_ratio=0.25)
cs = init_cs(params)
losses = []
with set_mesh(mesh):
    for s in range(60):
        x = rng.normal(size=(64, 16)).astype(np.float32)
        y = x @ W_true
        params, opt, cs, m = step(params, opt, cs,
                                  {"x": jnp.asarray(x), "y": jnp.asarray(y)})
        losses.append(float(m["loss"]))
assert losses[-1] < losses[0] * 0.2, (losses[0], losses[-1])
print("DP_OK", losses[0], losses[-1])
""")
    assert "DP_OK" in out
