"""MSA index structure + NSA search vs the literal paper-pseudocode port."""

from _hypothesis_compat import hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import distances as dl
from repro.core import msa, nsa, radius as rl
from repro.core.index import PDASCIndex
from repro.core.reference_impl import check_index_invariants, nsa_reference


def _build(n=240, d=6, gl=32, distance="euclidean", seed=0, **kw):
    rng = np.random.default_rng(seed)
    data = rng.normal(size=(n, d)).astype(np.float32)
    idx, stats = msa.build_index(data, gl=gl, distance=distance,
                                 key=jax.random.PRNGKey(seed), **kw)
    return data, idx, stats


@pytest.mark.parametrize("distance", ["euclidean", "manhattan", "cosine",
                                      "chebyshev", "fractional05", "jaccard"])
def test_invariants_all_distances(distance):
    rng = np.random.default_rng(1)
    data = rng.normal(size=(150, 5)).astype(np.float32)
    if distance == "jaccard":
        data = np.abs(data)
    idx, stats = msa.build_index(data, gl=20, distance=distance)
    assert check_index_invariants(idx) == []
    assert stats.level_sizes[0] == 150


def test_level_structure_follows_2to1_ratio():
    _, idx, stats = _build(n=256, gl=32)
    # 256 -> 8 groups x 16 protos = 128 -> 4x16=64 -> 2x16=32 -> 1x16=16
    assert stats.level_sizes == (256, 128, 64, 32, 16)


def test_uneven_last_group_promotes_all():
    """Paper Fig. 2: a short group (< nPrototypes) promotes every point."""
    _, idx, stats = _build(n=70, gl=32)  # groups: 32, 32, 6
    # level1 = 16 + 16 + 6 = 38
    assert stats.level_sizes[1] == 38
    assert check_index_invariants(idx) == []


@pytest.mark.parametrize("distance", ["euclidean", "cosine", "manhattan"])
@pytest.mark.parametrize("quantile", [0.2, 0.6])
def test_dense_matches_paper_reference(distance, quantile):
    data, idx, _ = _build(distance=distance, seed=3)
    dist = dl.get(distance)
    r = rl.estimate_radius(jnp.asarray(data), dist, quantile=quantile)
    Q = data[:8]
    res = nsa.search_dense(idx, jnp.asarray(Q), dist=dist, k=7, r=float(r))
    for i in range(len(Q)):
        rd, rid = nsa_reference(idx, Q[i], dist=dist, k=7, r=float(r))
        got = set(np.asarray(res.ids[i])[np.asarray(res.ids[i]) >= 0].tolist())
        want = set(rid[rid >= 0].tolist())
        assert got == want, (i, got, want)


def test_leaf_radius_filter_variant_matches():
    data, idx, _ = _build(seed=4)
    dist = dl.get("euclidean")
    r = float(rl.estimate_radius(jnp.asarray(data), dist, quantile=0.4))
    Q = data[:5]
    res = nsa.search_dense(idx, jnp.asarray(Q), dist=dist, k=5, r=r,
                           leaf_radius_filter=True)
    for i in range(5):
        _, rid = nsa_reference(idx, Q[i], dist=dist, k=5, r=r,
                               leaf_radius_filter=True)
        got = set(np.asarray(res.ids[i])[np.asarray(res.ids[i]) >= 0].tolist())
        assert got == set(rid[rid >= 0].tolist())


def test_beam_full_width_equals_dense():
    data, idx, _ = _build(seed=5)
    dist = dl.get("euclidean")
    r = float(rl.estimate_radius(jnp.asarray(data), dist, quantile=0.5))
    mc = msa.max_children(idx)
    d_ = nsa.search_dense(idx, jnp.asarray(data[:10]), dist=dist, k=5, r=r)
    b_ = nsa.search_beam(idx, jnp.asarray(data[:10]), dist=dist, k=5, r=r,
                         beam=10_000, max_children=mc)
    np.testing.assert_array_equal(np.sort(np.asarray(d_.ids), 1),
                                  np.sort(np.asarray(b_.ids), 1))


def test_beam_recall_increases_with_width():
    data, idx, _ = _build(n=400, seed=6)
    dist = dl.get("euclidean")
    r = float(rl.estimate_radius(jnp.asarray(data), dist, quantile=0.6))
    mc = msa.max_children(idx)
    dense = nsa.search_dense(idx, jnp.asarray(data[:20]), dist=dist, k=5, r=r)
    recalls = []
    for beam in (1, 4, 32):
        b = nsa.search_beam(idx, jnp.asarray(data[:20]), dist=dist, k=5, r=r,
                            beam=beam, max_children=mc)
        rec = np.mean([
            len(set(np.asarray(b.ids[i])) & set(np.asarray(dense.ids[i]))) / 5
            for i in range(20)
        ])
        recalls.append(rec)
    assert recalls[0] <= recalls[1] <= recalls[2] + 1e-9
    assert recalls[2] > 0.9


@hypothesis.given(seed=st.integers(0, 10_000))
@hypothesis.settings(max_examples=10, deadline=None)
def test_radius_monotonicity(seed):
    """Larger radius never removes candidates (property over random data)."""
    rng = np.random.default_rng(seed)
    data = rng.normal(size=(120, 4)).astype(np.float32)
    idx, _ = msa.build_index(data, gl=16, key=jax.random.PRNGKey(seed))
    dist = dl.get("euclidean")
    q = jnp.asarray(data[:4])
    r1 = nsa.search_dense(idx, q, dist=dist, k=5, r=1.0)
    r2 = nsa.search_dense(idx, q, dist=dist, k=5, r=2.5)
    assert (np.asarray(r2.n_candidates) >= np.asarray(r1.n_candidates)).all()


def test_self_query_recall_with_generous_radius():
    data, idx, _ = _build(n=300, seed=7)
    dist = dl.get("euclidean")
    r = float(rl.estimate_radius(jnp.asarray(data), dist, quantile=0.9))
    res = nsa.search_dense(idx, jnp.asarray(data[:30]), dist=dist, k=1, r=r)
    ids = np.asarray(res.ids)[:, 0]
    assert (ids == np.arange(30)).mean() >= 0.95  # found itself


def test_index_api_save_load_roundtrip(tmp_path):
    rng = np.random.default_rng(8)
    data = rng.normal(size=(200, 5)).astype(np.float32)
    idx = PDASCIndex.build(data, gl=25, distance="cosine")
    res1 = idx.search(data[:6], k=5)
    path = str(tmp_path / "idx")
    idx.save(path)
    idx2 = PDASCIndex.load(path)
    res2 = idx2.search(data[:6], k=5)
    np.testing.assert_array_equal(np.asarray(res1.ids), np.asarray(res2.ids))
    assert idx2.distance.name == "cosine"


def test_per_level_radii_increase():
    data, idx, _ = _build(n=300, seed=9)
    pidx = PDASCIndex.build(data, gl=32, distance="euclidean")
    radii = pidx.per_level_radii()
    assert len(radii) == pidx.n_levels
    assert all(radii[i] <= radii[i + 1] + 1e-6 for i in range(len(radii) - 1))


def test_kmeans_built_index_valid():
    """k-means clusterer path (paper's §3.3 baseline) still yields a valid
    index (prototypes snapped to data points)."""
    data, idx, _ = _build(n=200, gl=25, method="kmeans")
    assert check_index_invariants(idx) == []
