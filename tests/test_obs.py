"""Unified telemetry layer (DESIGN.md §3.11): metric name lint, registry
thread-safety, histogram percentile fidelity, exporter formats,
deterministic trace sampling, span-tree integrity through a real two_stage
query behind the router, and the instrumentation overhead guard."""

import json
import threading
import time

import numpy as np
import pytest

from repro import obs
from repro.obs import names as mnames
from repro.obs.metrics import (
    MetricsRegistry,
    to_json,
    to_prometheus,
)
from repro.core.index import PDASCIndex
from repro.query import Query
from repro.serving import BatchingEngine, ReplicaSet, Router, RouterConfig


# --------------------------- name catalogue lint -----------------------------


def test_every_catalogue_name_matches_the_convention():
    """The single source of truth (obs/names.py) must itself be clean:
    every documented name parses as subsystem_name_unit with a known
    subsystem and unit, and carries a valid kind + help string."""
    assert len(mnames.CATALOGUE) >= 25
    for name, (kind, help_) in mnames.CATALOGUE.items():
        m = mnames.NAME_RE.match(name)
        assert m is not None, name
        assert m.group("subsystem") in mnames.SUBSYSTEMS
        assert m.group("unit") in mnames.UNITS
        assert kind in ("counter", "gauge", "histogram"), name
        assert help_, f"{name} has no help text"
        assert mnames.subsystem(name) == m.group("subsystem")


def test_strict_registry_rejects_undocumented_and_malformed_names():
    reg = MetricsRegistry(strict=True)
    with pytest.raises(ValueError, match="catalogue"):
        reg.counter("engine_made_up_total")
    with pytest.raises(ValueError, match="convention"):
        reg.counter("Bad-Name")
    with pytest.raises(ValueError, match="documented as a"):
        reg.gauge(mnames.ENGINE_REQUESTS)  # documented as a counter
    # non-strict: regex-checked only
    loose = MetricsRegistry(strict=False)
    loose.counter("engine_made_up_total").inc()
    with pytest.raises(ValueError, match="convention"):
        loose.counter("made_up")
    with pytest.raises(ValueError, match="already registered"):
        loose.gauge("engine_made_up_total")


# --------------------------- registry thread-safety --------------------------


def test_concurrent_writers_lose_no_updates():
    """8 threads hammer one counter, per-thread labelled counters, and one
    histogram; every update must land (per-series locks, no torn sums)."""
    reg = MetricsRegistry(strict=False)
    n_threads, per = 8, 2000
    barrier = threading.Barrier(n_threads)

    def worker(w):
        shared = reg.counter("engine_shared_total")
        mine = reg.counter("engine_mine_total", worker=str(w))
        hist = reg.histogram("engine_lat_seconds")
        barrier.wait()
        for i in range(per):
            shared.inc()
            mine.inc(2.0)
            hist.observe(1e-4 * (i + 1))

    threads = [threading.Thread(target=worker, args=(w,))
               for w in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    snap = reg.snapshot()
    assert snap["engine_shared_total"]["series"][0]["value"] == \
        n_threads * per
    assert all(row["value"] == 2.0 * per
               for row in snap["engine_mine_total"]["series"])
    h = snap["engine_lat_seconds"]["series"][0]["hist"]
    assert h["count"] == n_threads * per
    assert sum(h["counts"]) == n_threads * per
    assert h["sum"] == pytest.approx(
        n_threads * sum(1e-4 * (i + 1) for i in range(per)), rel=1e-9)


def test_disabled_registry_is_a_no_op():
    reg = MetricsRegistry(strict=False)
    c = reg.counter("engine_x_total")
    h = reg.histogram("engine_x_seconds")
    reg.enabled = False
    c.inc()
    h.observe(1.0)
    reg.enabled = True
    c.inc()
    assert c.snapshot() == 1.0
    assert h.snapshot()["count"] == 0


# --------------------------- histogram fidelity ------------------------------


def test_histogram_percentiles_track_numpy_within_one_bucket():
    """Fixed factor-2 log buckets: the interpolated percentile may be off
    by at most one bucket width, i.e. within a factor of 2 of numpy's
    exact answer (and in practice much closer)."""
    rng = np.random.default_rng(3)
    samples = rng.lognormal(mean=-6.0, sigma=1.0, size=20_000)
    reg = MetricsRegistry(strict=False)
    h = reg.histogram("engine_t_seconds")
    for v in samples:
        h.observe(float(v))
    for q in (0.5, 0.9, 0.99):
        exact = float(np.percentile(samples, q * 100))
        est = h.percentile(q)
        assert exact / 2 <= est <= exact * 2, (q, exact, est)
    # the estimate is clamped to the really-seen range
    assert samples.min() <= h.percentile(0.0) <= h.percentile(1.0)
    assert h.percentile(1.0) == pytest.approx(samples.max())


def test_histogram_bucket_boundaries_are_le_semantics():
    """An observation exactly on a bound lands in that bound's bucket
    (Prometheus `le` semantics), and export cumulates correctly."""
    reg = MetricsRegistry(strict=False)
    h = reg.histogram("engine_b_seconds", bounds=(1.0, 2.0, 4.0))
    for v in (0.5, 1.0, 2.0, 3.0, 100.0):
        h.observe(v)
    assert h.counts == [2, 1, 1, 1]  # le=1: {0.5, 1.0}; +Inf: {100.0}
    text = to_prometheus(reg.snapshot())
    assert 'engine_b_seconds_bucket{le="1"} 2' in text
    assert 'engine_b_seconds_bucket{le="2"} 3' in text
    assert 'engine_b_seconds_bucket{le="4"} 4' in text
    assert 'engine_b_seconds_bucket{le="+Inf"} 5' in text
    assert "engine_b_seconds_count 5" in text


# --------------------------- exporters ---------------------------------------


def test_snapshot_exports_in_both_formats():
    reg = MetricsRegistry(strict=False)
    reg.counter("engine_req_total", engine="r0").inc(3)
    reg.gauge("engine_depth_count").set(7)
    reg.histogram("engine_wait_seconds").observe(0.5)
    snap = reg.snapshot()
    # JSON: round-trips to the same plain dict
    assert json.loads(to_json(snap)) == json.loads(json.dumps(snap))
    text = to_prometheus(snap)
    assert '# TYPE engine_req_total counter' in text
    assert 'engine_req_total{engine="r0"} 3' in text
    assert '# TYPE engine_depth_count gauge' in text
    assert 'engine_depth_count 7' in text
    assert '# TYPE engine_wait_seconds histogram' in text
    assert 'engine_wait_seconds_sum 0.5' in text


def test_prometheus_label_values_are_escaped_and_reparse():
    """Exporter hardening: backslashes, quotes and newlines in label
    values must escape per the Prometheus text format — a scraper parsing
    the line back recovers the original value exactly."""
    hostile = {
        "back\\slash": 'v1"quoted"',
        "multi\nline": "tab\tok",
        "plain": 'a\\b"c\nd',
    }
    reg = MetricsRegistry(strict=False)
    for i, (k, v) in enumerate(hostile.items()):
        reg.counter("engine_esc_total", label=k + v).inc(i + 1)
    text = to_prometheus(reg.snapshot())
    assert "\n" == text[-1] or "\n" in text
    # every sample line must be single-line and round-trip-parseable
    import re

    seen = {}
    for line in text.splitlines():
        if line.startswith("#") or not line.strip():
            continue
        m = re.fullmatch(
            r'(?P<name>\w+)(\{label="(?P<val>(?:[^"\\]|\\.)*)"\})? '
            r'(?P<value>\S+)', line)
        assert m is not None, f"unparseable exposition line: {line!r}"
        if m.group("val") is not None:
            unescaped = (m.group("val")
                         .replace("\\n", "\n")
                         .replace('\\"', '"')
                         .replace("\\\\", "\\"))
            seen[unescaped] = float(m.group("value"))
    assert seen == {k + v: float(i + 1)
                    for i, (k, v) in enumerate(hostile.items())}
    # HELP text with newlines/backslashes must stay single-line too
    snap = reg.snapshot()
    snap["engine_esc_total"]["help"] = "line1\nline2 \\ slash"
    text2 = to_prometheus(snap)
    for line in text2.splitlines():
        if line.startswith("# HELP"):
            assert "line1\\nline2 \\\\ slash" in line


def test_histogram_ignores_non_finite_observations():
    """A NaN/inf observation must not poison the sum/min/max (one bad
    latency sample would otherwise wreck every later percentile)."""
    reg = MetricsRegistry(strict=False)
    h = reg.histogram("engine_nf_seconds")
    h.observe(0.5)
    for bad in (float("nan"), float("inf"), float("-inf")):
        h.observe(bad)
    s = h.snapshot()
    assert s["count"] == 1 and s["sum"] == 0.5
    assert np.isfinite(s["min"]) and np.isfinite(s["max"])
    assert h.percentile(0.99) == pytest.approx(0.5)


def test_metrics_dumper_writes_snapshots(tmp_path):
    reg = MetricsRegistry(strict=False)
    reg.counter("engine_d_total").inc(5)
    path = tmp_path / "metrics.json"
    d = obs.MetricsDumper(reg, str(path), period_s=0)  # no thread
    d.dump()
    assert json.loads(path.read_text())["engine_d_total"]["series"][0][
        "value"] == 5
    prom = tmp_path / "metrics.prom"
    dp = obs.MetricsDumper(reg, str(prom), period_s=0)
    dp.close()  # close() always writes a final snapshot
    assert "engine_d_total 5" in prom.read_text()


# --------------------------- trace sampling ----------------------------------


def test_trace_sampling_is_deterministic_by_seq():
    buf = obs.TraceBuffer(maxlen=8)
    sampler = obs.TraceSampler(4, buffer=buf)
    picked = [seq for seq in range(20)
              if sampler.sample("request", seq) is not None]
    assert picked == [0, 4, 8, 12, 16]
    # same workload, fresh sampler -> the same picks, always
    again = obs.TraceSampler(4)
    assert picked == [s for s in range(20) if again.should_sample(s)]
    assert obs.TraceSampler(0).sample("request", 0) is None


def test_trace_buffer_bounds_and_exemplar_selection():
    buf = obs.TraceBuffer(maxlen=4)
    for seq in range(8):
        tr = obs.Trace("request", seq=seq, buffer=buf)
        tr.root.t1 = tr.root.t0 + 0.01 * (seq + 1)  # synthetic duration
        tr.finish()
    kept = buf.traces()
    assert len(kept) == 4 and [t.seq for t in kept] == [4, 5, 6, 7]
    assert buf.exemplar().seq == 7  # no target -> slowest
    assert buf.exemplar(0.05).seq == 4  # closest to 50 ms


def test_span_mirroring_and_nesting():
    """span() mirrors one child into every active parent and nests."""
    t1, t2 = obs.Trace("a"), obs.Trace("b")
    with obs.activate([t1.root, t2.root]):
        assert obs.is_tracing()
        with obs.span("stage", n=1):
            with obs.span("inner"):
                pass
    assert not obs.is_tracing()
    for tr in (t1, t2):
        (stage,) = tr.root.children
        assert stage.name == "stage" and stage.attrs == {"n": 1}
        (inner,) = stage.children
        assert inner.name == "inner" and inner.t1 is not None
    # inactive: the shared no-op, zero allocation
    assert obs.span("whatever") is obs.span("whatever")


# --------------------------- end-to-end span tree ----------------------------


@pytest.fixture(scope="module")
def store_tier():
    rng = np.random.default_rng(5)
    X = rng.normal(size=(500, 16)).astype(np.float32)
    idx = PDASCIndex.build(X, gl=64, distance="euclidean", store="int8",
                           store_block=64)
    idx.release_dense_payload()
    query = Query(k=5, execution="two_stage", beam=16, rerank_width=16,
                  with_stats=False)
    rs = ReplicaSet(idx, query, n_replicas=1, batch_size=4, max_wait_ms=0.5)
    router = Router(rs, RouterConfig(deadline_s=30.0, seed=0, trace_every=1))
    router.search(X[0])  # warmup compile (also traced — that is fine)
    yield rs, router, X
    router.close(close_replicas=True)


def test_two_stage_span_tree_integrity(store_tier):
    """One traced two_stage query through the Router yields the full span
    tree — queue -> dispatch -> batch -> scan -> rerank -> granule fetch —
    with parent/child time containment and self-times partitioning the
    request wall clock (within 10%)."""
    _, router, X = store_tier
    t0 = time.perf_counter()
    router.search(X[7])
    wall = time.perf_counter() - t0
    tr = router.traces.traces()[-1]
    spans = list(tr.root.walk())
    names = [s.name for s in spans]
    for expect in ("request", "attempt", "queue_wait", "batch_wait",
                   "execute", "plan", "descend", "scan", "rerank",
                   "granule_fetch"):
        assert expect in names, (expect, names)
    # every span closed, every child inside its parent's window
    eps = 2e-3
    for s in spans:
        assert s.t1 is not None, s.name
        for c in s.children:
            assert c.t0 >= s.t0 - eps and c.t1 <= s.t1 + eps, (
                s.name, c.name)
    # self-times partition the root: they telescope to the root duration,
    # and the root tracks the externally measured wall clock within 10%
    assert sum(s.self_time for s in spans) == pytest.approx(
        tr.root.duration, rel=1e-6)
    assert tr.root.duration == pytest.approx(wall, rel=0.10)
    # the device stages carry their attribution attrs
    scan = next(s for s in spans if s.name == "scan")
    assert scan.attrs["kind"] == "device" and scan.attrs["backend"] == "int8"
    fetch = next(s for s in spans if s.name == "granule_fetch")
    assert fetch.attrs["kind"] == "host" and fetch.attrs["granules"] >= 1
    # render: one line per span, millisecond-scaled
    text = tr.render()
    assert text.count("\n") == len(spans)
    assert "granule_fetch" in text and "ms" in text


def test_untraced_requests_record_no_spans(store_tier):
    _, router, X = store_tier
    before = len(router.traces)
    every_n, router._sampler.every_n = router._sampler.every_n, 0
    try:
        router.search(X[3])
    finally:
        router._sampler.every_n = every_n
    assert len(router.traces) == before


def test_engine_stats_snapshot_is_atomic_and_isolated():
    """Satellite: the deprecated ``engine.stats`` view is a consistent
    copy taken under the stats lock — mutating it never corrupts the
    engine, and concurrent reads see internally consistent values."""
    eng = BatchingEngine(lambda b, n: b, batch_size=2, max_wait_ms=0.5,
                         pad_payload=np.zeros(3, np.float32))
    try:
        for i in range(8):
            eng.submit(np.full(3, float(i), np.float32)).wait(timeout=10)
        snap = eng.stats
        snap["requests"] = -999  # a copy: the engine must not notice
        assert eng.stats["requests"] == 8
        assert eng.stats is not eng.stats  # fresh copy per read
    finally:
        eng.close()


# --------------------------- overhead guard ----------------------------------


def test_instrumented_engine_throughput_overhead_is_bounded():
    """Instrumented throughput >= 0.95x uninstrumented. The handler is
    compute-dominated (~2 ms per batch, like a real jitted search), so the
    per-batch instrumentation cost (a few lock+add counter bumps) must
    disappear into it. Best-of-3 alternating trials absorb scheduler
    noise."""

    def handler(batch, n_valid):
        time.sleep(0.002)
        return batch

    def throughput() -> float:
        eng = BatchingEngine(handler, batch_size=4, max_wait_ms=0.2,
                             pad_payload=np.zeros(3, np.float32))
        try:
            eng.submit(np.zeros(3, np.float32)).wait(timeout=10)  # warm
            n = 100
            t0 = time.perf_counter()
            reqs = [eng.submit(np.full(3, float(i), np.float32))
                    for i in range(n)]
            for r in reqs:
                r.wait(timeout=30)
            return n / (time.perf_counter() - t0)
        finally:
            eng.close()

    off, on = [], []
    try:
        for _ in range(3):
            obs.set_enabled(False)
            off.append(throughput())
            obs.set_enabled(True)
            on.append(throughput())
    finally:
        obs.set_enabled(True)
    ratio = max(on) / max(off)
    assert ratio >= 0.95, (off, on)
