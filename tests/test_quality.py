"""Quality & SLO observability (DESIGN.md §3.12): Wilson intervals,
shadow recall estimation against exhaustive recall on a seeded workload
(with degraded-leg attribution), multi-rate SLO burn alerts, the
plan-cost recorder round-trip, and the report/dashboard surface."""

import json
import time

import numpy as np
import pytest

from repro import obs
from repro.obs import costlog as costlog_lib
from repro.obs import report as report_lib
from repro.obs.metrics import MetricsRegistry


# --------------------------- wilson interval ---------------------------------


def test_wilson_interval_properties():
    lo, hi = obs.wilson(95, 100)
    assert 0.88 < lo < 0.95 < hi < 1.0
    assert obs.wilson(0, 0) == (0.0, 1.0)  # no trials: trivially [0, 1]
    # degenerate proportions stay inside [0, 1] and keep width
    lo0, hi0 = obs.wilson(0, 20)
    loN, hiN = obs.wilson(20, 20)
    assert lo0 == 0.0 and hi0 > 0.05
    assert hiN == 1.0 and loN < 0.95
    # more trials -> tighter interval around the same proportion
    w_small = np.subtract(*reversed(obs.wilson(9, 10)))
    w_big = np.subtract(*reversed(obs.wilson(900, 1000)))
    assert w_big < w_small


# --------------------------- shadow recall estimation ------------------------


@pytest.fixture(scope="module")
def quality_index():
    from repro.core.index import PDASCIndex

    rng = np.random.default_rng(11)
    data = rng.normal(size=(500, 16)).astype(np.float32)
    queries = rng.normal(size=(64, 16)).astype(np.float32)
    idx = PDASCIndex.build(data, gl=32, distance="euclidean",
                           radius_quantile=0.35)
    return idx, data, queries


def test_online_estimate_matches_exhaustive_recall(quality_index):
    """Serve a seeded workload, shadow-sample 1-in-3: the estimator's
    online recall must sit within its own Wilson interval of the
    exhaustive (every-query) recall over the same served answers."""
    from repro.baselines.exact import exact_knn
    from repro.query import Query

    idx, data, queries = quality_index
    k = 5
    plan = idx.plan(Query(k=k, execution="beam", beam=8))
    served = [np.asarray(plan(q[None]).ids).reshape(-1) for q in queries]
    _, gt = exact_knn(queries, data, distance="euclidean", k=k)
    gt = np.asarray(gt)
    exhaustive = float(np.mean([
        len(set(int(x) for x in served[j] if x >= 0)
            & set(int(x) for x in gt[j])) / k
        for j in range(len(queries))
    ]))

    est = obs.RecallEstimator(idx, every_n=3)
    try:
        n_offered = sum(
            est.observe(j, queries[j], served[j], pipeline="beam")
            for j in range(len(queries)))
        assert n_offered == len([j for j in range(len(queries))
                                 if j % 3 == 0])
        assert est.drain(timeout=120)
        e = est.estimate()
        assert e["queries"] == n_offered
        assert e["trials"] == n_offered * k
        # the sampled estimate brackets the exhaustive recall
        assert e["wilson_lo"] <= exhaustive <= e["wilson_hi"], (e,
                                                                exhaustive)
        assert abs(e["recall"] - exhaustive) <= 0.15
        # the published series carry the (pipeline, leg) labels
        snap = obs.snapshot()
        rows = snap[obs.names.QUALITY_RECALL_MEAN]["series"]
        assert any(r["labels"] == {"pipeline": "beam", "leg": "normal"}
                   for r in rows)
    finally:
        est.close()


def test_degraded_leg_is_attributed_separately(quality_index):
    """A degraded serve (scan-only / halved beam) must land on its own
    (pipeline, leg) stats — a recall dip on the degraded leg is visible
    without polluting the normal leg's estimate."""
    from repro.query import Query, degraded

    idx, data, queries = quality_index
    k = 5
    q = Query(k=k, execution="beam", beam=8)
    plan_n = idx.plan(q)
    plan_d = idx.plan(degraded(q))
    est = obs.RecallEstimator(lambda: idx, every_n=1)  # callable source
    try:
        for j in range(10):
            est.observe(j, queries[j],
                        np.asarray(plan_n(queries[j][None]).ids)[0],
                        pipeline="beam", leg="normal")
        for j in range(10, 16):
            est.observe(j, queries[j],
                        np.asarray(plan_d(queries[j][None]).ids)[0],
                        pipeline="beam", leg="degraded")
        assert est.drain(timeout=120)
        assert est.legs() == [("beam", "degraded"), ("beam", "normal")]
        normal = est.estimate(leg="normal")
        deg = est.estimate(leg="degraded")
        assert normal["queries"] == 10 and deg["queries"] == 6
        # both legs answered something sane; the overall pool is the union
        both = est.estimate()
        assert both["queries"] == 16
        assert both["successes"] == normal["successes"] + deg["successes"]
        # reset_stats drops the estimate but keeps the worker alive
        est.reset_stats()
        assert est.estimate()["queries"] == 0
        est.observe(0, queries[0],
                    np.asarray(plan_n(queries[0][None]).ids)[0],
                    pipeline="beam")
        assert est.drain(timeout=120)
        assert est.estimate()["queries"] == 1
    finally:
        est.close()


def test_estimator_sampling_and_drop_accounting(quality_index):
    idx, data, queries = quality_index
    est = obs.RecallEstimator(idx, every_n=4, queue_max=1)
    try:
        assert [s for s in range(12) if est.should_sample(s)] == [0, 4, 8]
        est.every_n = 0  # disabled: observe becomes a no-op
        assert not est.observe(0, queries[0], np.arange(5))
    finally:
        est.close()


# --------------------------- SLO burn alerts ---------------------------------


def _latency_spec(**over):
    kw = dict(latency_p99_s=0.1, availability=None, window_s=1.0,
              fast_window_frac=0.5, min_samples=4, burn_threshold=2.0)
    kw.update(over)
    return obs.SLOSpec(**kw)


def test_slo_no_alert_when_clean():
    slo = obs.SLOTracker(_latency_spec())
    for _ in range(30):
        slo.record_request(0.01, ok=True)
    st = slo.evaluate()
    assert st["latency"]["sli"] == 1.0
    assert st["latency"]["burn_slow"] == 0.0
    assert not st["latency"]["alerting"]
    assert slo.alert_counts() == {} and slo.events() == []


def test_slo_burn_alert_fires_and_clears():
    slo = obs.SLOTracker(_latency_spec())
    for _ in range(10):
        slo.record_request(0.5, ok=True)  # all past the latency target
    st = slo.evaluate()
    assert st["latency"]["alerting"]
    assert slo.alert_counts() == {"latency": 1}
    # still burning: the alert edge does not re-fire
    slo.record_request(0.5, ok=True)
    slo.evaluate()
    assert slo.alert_counts() == {"latency": 1}
    # burn stops; once the bad samples age out of the window it clears
    time.sleep(1.1)
    for _ in range(10):
        slo.record_request(0.01, ok=True)
    st = slo.evaluate()
    assert not st["latency"]["alerting"]
    events = slo.events()
    assert [e["event"] for e in events] == ["burn_alert", "burn_clear"]
    assert events[0]["objective"] == "latency"
    # the alert counter series is published
    snap = obs.snapshot()
    assert any(r["labels"] == {"objective": "latency"} and r["value"] == 1
               for r in snap[obs.names.SLO_ALERTS]["series"])


def test_slo_multirate_rule_needs_both_windows():
    """Old badness only in the slow window must NOT alert: the fast
    window's recovery is exactly what the multi-rate rule listens to."""
    slo = obs.SLOTracker(_latency_spec(window_s=2.0, fast_window_frac=0.25))
    for _ in range(10):
        slo.record_request(0.5, ok=True)
    time.sleep(0.6)  # bad burst ages past the 0.5s fast window
    for _ in range(10):
        slo.record_request(0.01, ok=True)
    st = slo.evaluate()
    assert st["latency"]["burn_slow"] > 2.0  # slow window still burning
    assert not st["latency"]["alerting"]  # but the fast window recovered
    assert slo.alert_counts() == {}


def test_slo_availability_and_recall_objectives():
    spec = obs.SLOSpec(latency_p99_s=None, availability=0.9,
                       recall_floor=0.8, recall_budget=0.1,
                       window_s=5.0, min_samples=2)
    assert set(spec.budgets()) == {"availability", "recall"}
    slo = obs.SLOTracker(spec)
    for _ in range(8):
        slo.record_request(0.01, ok=False)  # every request fails
        slo.record_recall(0.5)  # every shadow sample under the floor
    slo.evaluate()
    counts = slo.alert_counts()
    assert counts.get("availability") == 1 and counts.get("recall") == 1


# --------------------------- plan-cost recorder ------------------------------


def _make_trace():
    tr = obs.TraceSampler(1).sample("request", 8, kind="search")
    attempt = tr.root.child("attempt", replica=0)
    scan = attempt.child("scan", candidates=64)
    scan.end()
    rerank = attempt.child("rerank", rows=32)
    rerank.end()
    attempt.end(outcome="won")
    tr.finish(outcome="ok")
    return tr


_DESCRIBE = dict(
    pipeline="two_stage", effective_pipeline="two_stage",
    query=dict(k=10, beam=32, rerank_width=64),
    capabilities=dict(n_levels=2, store="int8", payload_released=True),
    index=dict(n_points=500, code_format="int8"),
    kernel=dict(bm=64),
)


def test_build_record_joins_plan_features_with_span_costs():
    rec = costlog_lib.build_record(_make_trace(), _DESCRIBE,
                                   dict(replica=0))
    assert rec["v"] == costlog_lib.SCHEMA_VERSION
    assert rec["seq"] == 8 and rec["outcome"] == "ok"
    assert rec["latency_s"] > 0
    assert set(rec["spans"]) == {"request", "attempt", "scan", "rerank"}
    assert rec["spans"]["scan"]["count"] == 1
    assert rec["counts"] == dict(candidates=64, rows=32)
    assert rec["pipeline"] == "two_stage"
    assert rec["index"] == dict(n_points=500, n_levels=2,
                                code_format="int8", store="int8",
                                payload_released=True)
    assert rec["kernel"] == dict(bm=64)
    assert rec["replica"] == 0
    # works on the exported dict form too (the offline path)
    rec2 = costlog_lib.build_record(_make_trace().to_dict(), _DESCRIBE)
    assert rec2["counts"] == rec["counts"]


def test_costlog_roundtrips_through_jsonl(tmp_path):
    path = tmp_path / "cost.jsonl"
    log = obs.CostLog(str(path))
    assert len(log) == 0 and not path.exists()  # lazy open
    for _ in range(3):
        log.record(_make_trace(), _DESCRIBE, degraded=False)
    log.close()
    recs = costlog_lib.load(str(path))
    assert len(recs) == len(log) == 3
    for rec in recs:
        for key in ("v", "seq", "latency_s", "outcome", "pipeline",
                    "effective_pipeline", "query", "index", "kernel",
                    "spans", "counts", "degraded"):
            assert key in rec, key
        json.dumps(rec)  # every line is plain JSON
    # the records counter tracked every append
    snap = obs.snapshot()
    assert snap[obs.names.PLAN_COST_RECORDS]["series"][0]["value"] == 3


# --------------------------- report CLI + dashboard --------------------------


def _dump_registry(tmp_path):
    reg = MetricsRegistry(strict=False)
    reg.counter("router_req_total").inc(100)
    reg.histogram("router_lat_seconds").observe(0.05)
    path = tmp_path / "metrics.json"
    obs.MetricsDumper(reg, str(path), period_s=0).dump()
    return path


def test_report_cli_renders_text_and_html(tmp_path, capsys):
    path = _dump_registry(tmp_path)
    assert report_lib.main(["--metrics", str(path)]) == 0
    out = capsys.readouterr().out
    assert "observability report" in out and "router_req_total" in out
    html = tmp_path / "report.html"
    assert report_lib.main(["--metrics", str(path),
                            "--out", str(html)]) == 0
    text = html.read_text()
    assert text.startswith("<!doctype html>") and "router_req_total" in text


def test_report_cli_fails_on_missing_empty_or_malformed(tmp_path):
    assert report_lib.main(["--metrics", str(tmp_path / "nope.json")]) == 2
    empty = tmp_path / "empty.json"
    empty.write_text("{}")
    assert report_lib.main(["--metrics", str(empty)]) == 2
    bad = tmp_path / "bad.json"
    bad.write_text('{"router_x_total": {"oops": 1}}')
    assert report_lib.main(["--metrics", str(bad)]) == 2
    noise = tmp_path / "noise.json"
    noise.write_text("not json at all")
    assert report_lib.main(["--metrics", str(noise)]) == 2
    with pytest.raises(report_lib.ReportError):
        report_lib.validate_snapshot([1, 2, 3])


def test_report_includes_trace_dump(tmp_path, capsys):
    path = _dump_registry(tmp_path)
    buf = obs.TraceBuffer(maxlen=4)
    sampler = obs.TraceSampler(1, buffer=buf)
    for seq in range(3):
        t = sampler.sample("request", seq)
        t.root.child("attempt").end()
        t.finish(outcome="ok")
    tpath = tmp_path / "traces.json"
    tpath.write_text(buf.to_json())
    assert report_lib.main(["--metrics", str(path),
                            "--trace", str(tpath)]) == 0
    out = capsys.readouterr().out
    assert "retained=3" in out and "attempt" in out


def test_dashboard_frame_renders_live_state(tmp_path):
    import io

    reg = MetricsRegistry(strict=False)
    reg.counter("router_requests_total").inc(42)
    slo = obs.SLOTracker(_latency_spec())
    slo.record_request(0.01, ok=True)
    slo.evaluate()
    stream = io.StringIO()
    dash = report_lib.Dashboard(reg, period_s=30.0, slo=slo,
                                stream=stream, clear=False)
    try:
        first = dash.frame()
        assert "served=42" in first and "slo[latency]" in first
        reg.counter("router_requests_total").inc(8)
        time.sleep(0.01)
        second = dash.frame()
        assert "served=50" in second and "qps=" in second
    finally:
        dash.close()
    assert "served=50" in stream.getvalue()  # close() emits a final frame
