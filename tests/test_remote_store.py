"""Out-of-core remote storage subsystem (DESIGN.md §3.13): granule cache
LRU / in-flight dedup semantics, prefetch pool behaviour under faults,
remote store backends, the streaming shard-by-shard build, bounded
resident memory, format-v5 persistence and the plan capability bit."""

import threading
import time

import numpy as np
import pytest

from repro.core.distributed import payload_placement
from repro.core.index import PDASCIndex
from repro.query.plan import capabilities
from repro.store import (
    ExactSource,
    GranuleCache,
    LocalFSStore,
    PrefetchPool,
    RemoteSource,
    RemoteStoreError,
    SimulatedObjectStore,
    build_streaming,
    make_remote,
    open_store,
    upload_payload,
)
from repro.store.remote import granule_key


def _points(n=300, d=9, seed=7):
    return np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32)


def _remote_source(n=256, d=8, block=64, seed=0, **kw):
    pts = _points(n, d, seed)
    store = SimulatedObjectStore()
    upload_payload(store, pts, block)
    return pts, store, RemoteSource(store, n=n, d=d, block=block, **kw)


# ---------------------------------------------------------------------------
# GranuleCache: LRU order, dedup, error release
# ---------------------------------------------------------------------------


def test_cache_lru_eviction_order():
    cache = GranuleCache(3)
    fetch = lambda k: np.full((4,), k, np.float32)
    for k in (0, 1, 2):
        cache.get(k, fetch)
    assert cache.keys() == [0, 1, 2]
    cache.get(0, fetch)  # hit bumps recency: 1 is now the LRU victim
    cache.get(3, fetch)  # evicts 1
    assert cache.keys() == [2, 0, 3]
    assert not cache.peek(1)
    assert cache.stats["evictions"] == 1
    cache.get(1, fetch)  # evicts 2 (the new LRU head)
    assert cache.keys() == [0, 3, 1]
    assert cache.stats["evictions"] == 2


def test_cache_resident_bytes_tracks_eviction():
    cache = GranuleCache(2)
    fetch = lambda k: np.zeros((10,), np.float32)
    cache.get(0, fetch)
    cache.get(1, fetch)
    assert cache.resident_bytes == 80
    cache.get(2, fetch)
    assert cache.resident_bytes == 80  # bounded: eviction freed one granule
    cache.clear()
    assert cache.resident_bytes == 0 and len(cache) == 0


def test_cache_concurrent_get_fetches_once():
    """Many threads racing on one cold key -> exactly one backing fetch."""
    cache = GranuleCache(8)
    calls = []
    gate = threading.Event()

    def fetch(k):
        gate.wait(5)
        calls.append(k)
        time.sleep(0.01)
        return np.full((4,), k, np.float32)

    out = []
    threads = [threading.Thread(target=lambda: out.append(
        cache.get(7, fetch))) for _ in range(8)]
    for t in threads:
        t.start()
    time.sleep(0.05)  # let every thread reach the claim/wait point
    gate.set()
    for t in threads:
        t.join(5)
    assert len(calls) == 1
    assert len(out) == 8
    for v in out:
        np.testing.assert_array_equal(v, out[0])
    assert cache.stats["misses"] == 1
    assert cache.stats["inflight_waits"] >= 1


def test_cache_failed_fetch_releases_claim_and_raises():
    cache = GranuleCache(4)
    boom = lambda k: (_ for _ in ()).throw(RuntimeError("backing store down"))
    with pytest.raises(RuntimeError):
        cache.get(0, boom)
    # the claim is released: a later fetch of the same key succeeds
    val = cache.get(0, lambda k: np.ones((2,), np.float32))
    np.testing.assert_array_equal(val, 1.0)
    assert not cache.claimed(1) or True  # key never wedged in-flight
    assert cache.stats["misses"] == 1  # the failed attempt is not a miss


def test_cache_waiter_survives_owner_fetch_error():
    """Owner's fetch raises -> the waiter retries and fetches itself."""
    cache = GranuleCache(4)
    entered = threading.Event()
    release = threading.Event()
    errors, values = [], []

    def failing(k):
        entered.set()
        release.wait(5)
        raise RuntimeError("injected")

    def owner():
        try:
            cache.get(0, failing)
        except RuntimeError as e:
            errors.append(e)

    def waiter():
        entered.wait(5)
        values.append(cache.get(0, lambda k: np.full((2,), 9, np.float32)))

    t1 = threading.Thread(target=owner)
    t2 = threading.Thread(target=waiter)
    t1.start()
    entered.wait(5)
    t2.start()
    time.sleep(0.05)
    release.set()
    t1.join(5)
    t2.join(5)
    assert len(errors) == 1  # the owner saw the failure
    assert len(values) == 1  # the waiter recovered with its own fetch
    np.testing.assert_array_equal(values[0], 9.0)


# ---------------------------------------------------------------------------
# PrefetchPool: dedup vs fetch, depth bound, fault tolerance
# ---------------------------------------------------------------------------


def test_prefetch_pool_warms_and_dedups():
    cache = GranuleCache(16)
    calls = []

    def fetch(k):
        calls.append(k)
        return np.full((4,), k, np.float32)

    pool = PrefetchPool(cache, fetch, workers=2, depth=16)
    h = pool.submit([0, 1, 2, 2, 1])
    assert h.wait(5)
    assert sorted(set(calls)) == [0, 1, 2]
    assert len(calls) == 3  # duplicates deduped at submit
    # resubmitting resident keys accepts nothing
    h2 = pool.submit([0, 1, 2])
    assert h2.done
    assert len(calls) == 3
    pool.close()


def test_prefetch_vs_fetch_never_double_fetches():
    """Concurrent sync fetch + prefetch of the same granule: one read."""
    cache = GranuleCache(16)
    calls = []
    slow = threading.Event()

    def fetch(k):
        calls.append(k)
        slow.wait(1)
        return np.full((4,), k, np.float32)

    pool = PrefetchPool(cache, fetch, workers=2, depth=16)
    h = pool.submit([5])
    time.sleep(0.05)  # worker claims key 5 and blocks in fetch
    got = []
    t = threading.Thread(target=lambda: got.append(cache.get(5, fetch)))
    t.start()
    time.sleep(0.05)
    slow.set()
    t.join(5)
    h.wait(5)
    assert calls == [5]  # the sync path waited on the in-flight prefetch
    np.testing.assert_array_equal(got[0], 5.0)
    assert cache.stats["inflight_waits"] >= 1
    pool.close()


def test_prefetch_pool_depth_bound_drops():
    cache = GranuleCache(64)
    gate = threading.Event()

    def fetch(k):
        gate.wait(5)
        return np.full((1,), k, np.float32)

    pool = PrefetchPool(cache, fetch, workers=1, depth=2)
    h = pool.submit(list(range(20)))
    assert pool.stats["dropped"] > 0
    gate.set()
    assert h.wait(5)
    pool.close()
    assert pool.stats["accepted"] + pool.stats["dropped"] == 20


def test_prefetch_pool_survives_fetch_errors():
    """A faulty backing store leaves granules cold but never wedges the
    pool; the sync path surfaces the error to the caller."""
    cache = GranuleCache(16)
    healthy = threading.Event()

    def fetch(k):
        if not healthy.is_set():
            raise RemoteStoreError("window outage")
        return np.full((2,), k, np.float32)

    pool = PrefetchPool(cache, fetch, workers=2, depth=16)
    h = pool.submit([0, 1, 2])
    assert h.wait(5)  # errors swallowed, handle still completes
    assert pool.stats["errors"] == 3
    with pytest.raises(RemoteStoreError):
        cache.get(0, fetch)  # sync caller sees the real error
    healthy.set()
    h2 = pool.submit([0, 1])  # pool still alive after the outage
    assert h2.wait(5)
    np.testing.assert_array_equal(cache.get(0, fetch), 0.0)
    pool.close()


# ---------------------------------------------------------------------------
# Remote store backends
# ---------------------------------------------------------------------------


def test_localfs_store_roundtrip(tmp_path):
    store = LocalFSStore(str(tmp_path / "objs"))
    store.put("granule/00000000", b"abc")
    store.put("granule/00000001", b"defg")
    assert store.get("granule/00000000") == b"abc"
    assert store.list_keys("granule/") == ["granule/00000000",
                                           "granule/00000001"]
    assert store.get_batch(["granule/00000001", "granule/00000000"]) == \
        [b"defg", b"abc"]
    store.delete("granule/00000000")
    store.delete("granule/00000000")  # absent: no-op
    with pytest.raises(KeyError):
        store.get("granule/00000000")
    assert store.exists("granule/00000001")
    # a reopened store sees the same objects (the durable v5 form)
    again = open_store(store.manifest())
    assert again.get("granule/00000001") == b"defg"


def test_localfs_store_rejects_escaping_keys(tmp_path):
    store = LocalFSStore(str(tmp_path / "objs"))
    with pytest.raises(ValueError):
        store.put("../outside", b"x")


def test_simulated_store_latency_and_counts():
    store = SimulatedObjectStore(latency_ms=5.0)
    store.put("k", b"1234")
    t0 = time.perf_counter()
    assert store.get("k") == b"1234"
    assert time.perf_counter() - t0 >= 0.004
    assert store.op_counts["get"] == 1 and store.op_counts["put"] == 1
    assert store.total_bytes == 4
    with pytest.raises(KeyError):
        store.get("missing")


def test_simulated_store_fault_seam():
    """A FaultInjector-protocol object drives remote outages: errors in
    its window surface as RemoteStoreError, ops count the failure."""

    class Injector:
        def __init__(self):
            self.n = 0

        def on_dispatch(self):
            self.n += 1
            if self.n <= 2:
                raise RuntimeError("window error")

    store = SimulatedObjectStore(faults=Injector())
    with pytest.raises(RemoteStoreError):
        store.put("k", b"x")
    with pytest.raises(RemoteStoreError):
        store.put("k", b"x")
    store.put("k", b"x")  # window passed
    assert store.op_counts["errors"] == 2


def test_open_store_refuses_sim_manifest():
    with pytest.raises(ValueError, match="cannot be reopened"):
        open_store(dict(kind="sim"))


# ---------------------------------------------------------------------------
# RemoteSource
# ---------------------------------------------------------------------------


def test_remote_source_fetch_rows_matches_payload():
    pts, _, src = _remote_source(n=250, d=8, block=64)  # short last granule
    idx = np.array([[0, 1, 249], [64, 128, 192]])
    np.testing.assert_allclose(src.fetch_rows(idx), pts[idx])
    np.testing.assert_allclose(src.read_all(), pts)
    assert src.nbytes == 250 * 8 * 4
    assert src.remote and src.wants_prefetch and not src.on_disk
    src.close()


def test_remote_source_cache_and_stats_surface():
    pts, store, src = _remote_source(cache_granules=2)
    src.fetch_rows([0])      # granule 0: miss
    src.fetch_rows([1])      # granule 0: hit
    assert src.stats == dict(fetches=1, hits=1)
    src.fetch_rows([64, 128])  # granules 1, 2: cache (cap 2) evicts 0
    src.fetch_rows([0])      # miss again
    assert src.stats["fetches"] == 4
    assert src.cache_resident_bytes <= 2 * 64 * 8 * 4
    src.close()


def test_remote_source_fault_errors_surface_without_wedging():
    pts = _points(128, 4)
    times = dict(n=0)

    class Injector:
        def on_dispatch(self):
            times["n"] += 1
            # window opens after the 3 upload puts (2 granules + manifest)
            if times["n"] > 3 and times["n"] <= 5:
                raise RuntimeError("outage")

    store = SimulatedObjectStore(faults=Injector())
    upload_payload(store, pts, 64)
    src = RemoteSource(store, n=128, d=4, block=64)
    with pytest.raises(RemoteStoreError):
        src.fetch_rows([0])
    with pytest.raises(RemoteStoreError):
        src.fetch_rows([64])
    # outage over: the same granules fetch fine (claims were released)
    np.testing.assert_allclose(src.fetch_rows([0, 64]),
                               pts[[0, 64]])
    src.close()


def test_remote_source_corrupt_granule_detected():
    pts, store, src = _remote_source(n=128, d=4, block=64)
    store.put(granule_key(0), b"\x00" * 12)  # wrong payload size
    with pytest.raises(RemoteStoreError, match="corrupt|expected"):
        src.fetch_rows([0])
    src.close()


# ---------------------------------------------------------------------------
# make_remote migration + memory accounting + capability bit
# ---------------------------------------------------------------------------


def _built_index(n=512, d=8, gl=32, block=64, **kw):
    pts = _points(n, d)
    return pts, PDASCIndex.build(pts, gl=gl, distance="euclidean",
                                 store="int8", store_block=block, **kw)


def test_make_remote_bounded_resident_while_remote_grows():
    """The satellite acceptance: resident bytes stay bounded (codes +
    host cache) while remote_bytes carries the growing payload."""
    pts, idx = _built_index(n=512, d=8)
    before = idx.memory_bytes()
    assert before["remote_bytes"] == 0
    store = SimulatedObjectStore()
    make_remote(idx, store, cache_granules=2)
    mem = idx.memory_bytes()
    assert mem["remote_bytes"] == 512 * 8 * 4
    assert mem["out_of_core"] == 0
    # serve a few queries: the host cache fills but stays bounded by its
    # 2-granule capacity; resident accounting includes it
    from repro.query import Query

    plan = idx.plan(Query(k=5, execution="two_stage", beam=8,
                          rerank_width=16))
    for i in range(4):
        plan(pts[i * 7:i * 7 + 2])
    mem2 = idx.memory_bytes()
    assert 0 < mem2["host_cache"] <= 2 * 64 * 8 * 4
    assert mem2["total_resident"] <= before["total_resident"]
    assert mem2["remote_bytes"] == 512 * 8 * 4  # unchanged: still remote
    idx.store.exact.close()


def test_capabilities_remote_bit_and_plan_recompile():
    pts, idx = _built_index()
    idx.release_dense_payload()
    assert capabilities(idx).remote is False
    make_remote(idx, SimulatedObjectStore())
    caps = capabilities(idx)
    assert caps.remote is True
    assert caps.store == "int8"
    from repro.query import Query

    plan = idx.plan(Query(k=5, execution="two_stage", beam=8))
    assert "remote exact tier" in plan.explain()
    idx.store.exact.close()


def test_make_remote_requires_quantised_store():
    pts = _points(128, 4)
    idx = PDASCIndex.build(pts, gl=16, distance="euclidean")
    with pytest.raises(ValueError, match="quantised"):
        make_remote(idx, SimulatedObjectStore())


def test_make_remote_two_stage_matches_local_two_stage():
    from repro.query import Query

    pts, idx = _built_index(n=512, d=8)
    q = Query(k=5, execution="two_stage", beam=8, rerank_width=32)
    local = idx.plan(q)(pts[:16])
    make_remote(idx, SimulatedObjectStore())
    remote = idx.plan(q)(pts[:16])
    np.testing.assert_array_equal(np.asarray(local.ids),
                                  np.asarray(remote.ids))
    np.testing.assert_allclose(np.asarray(local.dists),
                               np.asarray(remote.dists), rtol=1e-6)
    idx.store.exact.close()


# ---------------------------------------------------------------------------
# Streaming build
# ---------------------------------------------------------------------------


def _stream_build(train, n_shards, **kw):
    m = len(train) // n_shards
    store = SimulatedObjectStore()
    kw.setdefault("gl", 32)
    kw.setdefault("block", 32)
    kw.setdefault("method", "kmeans")
    kw.setdefault("distance", "euclidean")
    idx = build_streaming(
        (train[s * m:(s + 1) * m] for s in range(n_shards)),
        remote=store, **kw)
    return store, idx


def test_build_streaming_layout_and_payload_roundtrip():
    train = _points(512, 8, seed=1)
    store, idx = _stream_build(train, 4)
    leaf = idx.data.levels[0]
    valid = np.asarray(leaf.valid)
    ids = np.asarray(idx.data.leaf_ids)
    assert valid.all() and idx.n_points == 512
    rows = idx.store.exact.read_all()
    np.testing.assert_allclose(rows[valid], train[ids[valid]], rtol=1e-6)
    np.testing.assert_allclose(np.asarray(leaf.sq_norm),
                               (train[ids] ** 2).sum(1), rtol=1e-5)
    # parent/child bookkeeping is consistent through the upper levels
    for lv_i in range(1, idx.n_levels):
        lv = idx.data.levels[lv_i]
        lo = idx.data.levels[lv_i - 1]
        lv_valid = np.asarray(lv.valid)
        cs, cc = np.asarray(lv.child_start), np.asarray(lv.child_count)
        par = np.asarray(lo.parent)
        for s in np.nonzero(lv_valid)[0]:
            assert (par[cs[s]:cs[s] + cc[s]] == s).all()
    assert idx._payload_released
    idx.store.exact.close()


def test_build_streaming_search_end_to_end():
    from repro.baselines import exact_knn
    from repro.query import Query

    rng = np.random.default_rng(3)
    # clustered data (ANN-friendly): 16 Gaussian blobs in 8-d
    centers = rng.normal(0, 3.0, size=(16, 8))
    comp = rng.integers(0, 16, 1024 + 24)
    x = (centers[comp] + rng.normal(size=(1024 + 24, 8))).astype(np.float32)
    train, test = x[:1024], x[1024:]
    store, idx = _stream_build(train, 2, gl=64, block=64,
                               radius_quantile=0.35)
    res = idx.plan(Query(k=10, execution="two_stage", beam=32,
                         rerank_width=128))(test)
    _, gt = exact_knn(test, train, distance="euclidean", k=10)
    gt = np.asarray(gt)
    ids = np.asarray(res.ids)
    rec = np.mean([len(set(r[r >= 0]) & set(g)) / 10
                   for r, g in zip(ids, gt)])
    assert rec >= 0.5  # sane retrieval through the full remote path
    # reported distances are exact (fetched fp32 rows, not code-space)
    d0 = np.linalg.norm(train[ids[0, 0]] - test[0])
    np.testing.assert_allclose(float(np.asarray(res.dists)[0, 0]), d0,
                               rtol=1e-4)
    idx.store.exact.close()


def test_build_streaming_rejects_misaligned_shards():
    train = _points(64, 4)
    store = SimulatedObjectStore()
    # shard of 32 rows at gl=32 pads to 32 slots — not a 64-row granule
    with pytest.raises(ValueError, match="multiple of block"):
        build_streaming((train[s * 32:(s + 1) * 32] for s in range(2)),
                        gl=32, block=64, remote=store, method="kmeans")


def test_build_streaming_rejects_fp32_and_empty():
    store = SimulatedObjectStore()
    with pytest.raises(ValueError, match="quantised"):
        build_streaming(iter([]), gl=32, remote=store, store="fp32")
    with pytest.raises(ValueError, match="empty"):
        build_streaming(iter([]), gl=32, block=32, remote=store,
                        method="kmeans")


def test_build_streaming_ragged_last_shard():
    """Last shard shorter than the others (still block-aligned padding)."""
    train = _points(320, 6, seed=5)
    store = SimulatedObjectStore()
    parts = [train[:128], train[128:256], train[256:]]  # 128,128,64
    idx = build_streaming(iter(parts), gl=32, block=32, remote=store,
                          method="kmeans", distance="euclidean")
    assert idx.n_points == 320
    rows = idx.store.exact.read_all()
    ids = np.asarray(idx.data.leaf_ids)
    valid = np.asarray(idx.data.levels[0].valid)
    np.testing.assert_allclose(rows[valid], train[ids[valid]], rtol=1e-6)
    idx.store.exact.close()


# ---------------------------------------------------------------------------
# v5 persistence
# ---------------------------------------------------------------------------


def test_save_load_v5_roundtrip_localfs(tmp_path):
    from repro.query import Query

    train = _points(256, 8, seed=2)
    obj = LocalFSStore(str(tmp_path / "objs"))
    idx = build_streaming((train[s * 128:(s + 1) * 128] for s in range(2)),
                          gl=32, block=32, remote=obj, method="kmeans",
                          distance="euclidean")
    q = Query(k=5, execution="two_stage", beam=8, rerank_width=32)
    want = idx.plan(q)(train[:8])
    path = str(tmp_path / "idx")
    idx.save(path)
    import json as _json

    with open(path + ".json") as f:
        meta = _json.load(f)
    assert meta["version"] == 5
    assert meta["store"]["remote"]["kind"] == "localfs"
    # the artifact must NOT embed the exact payload (that is the point)
    z = np.load(path + ".npz")
    assert z["level0_points"].shape[1] == 0

    loaded = PDASCIndex.load(path)  # reopens localfs from the manifest
    assert loaded._payload_released
    assert capabilities(loaded).remote
    got = loaded.plan(q)(train[:8])
    np.testing.assert_array_equal(np.asarray(want.ids),
                                  np.asarray(got.ids))
    np.testing.assert_allclose(np.asarray(want.dists),
                               np.asarray(got.dists), rtol=1e-6)
    idx.store.exact.close()
    loaded.store.exact.close()


def test_save_load_v5_sim_requires_live_store(tmp_path):
    train = _points(128, 4, seed=2)
    store, idx = _stream_build(train, 2)
    path = str(tmp_path / "idx")
    idx.save(path)
    with pytest.raises(ValueError, match="cannot be reopened"):
        PDASCIndex.load(path)
    loaded = PDASCIndex.load(path, remote=store)  # rebind the live store
    np.testing.assert_allclose(loaded.store.exact.read_all(),
                               idx.store.exact.read_all())
    idx.store.exact.close()
    loaded.store.exact.close()


# ---------------------------------------------------------------------------
# Co-placement + prefetch integration
# ---------------------------------------------------------------------------


def test_payload_placement_granule_alignment():
    plc = payload_placement(1024, 64, 4)
    assert [p["shard"] for p in plc] == [0, 1, 2, 3]
    assert plc[0]["rows"] == (0, 256) and plc[0]["granules"] == (0, 4)
    assert plc[3]["rows"] == (768, 1024) and plc[3]["granules"] == (12, 16)
    with pytest.raises(ValueError, match="divisible"):
        payload_placement(100, 10, 3)
    with pytest.raises(ValueError, match="granule-aligned"):
        payload_placement(120, 16, 3)


def test_exact_source_async_prefetch_matches_sync():
    pts = _points(256, 6)
    src = ExactSource(pts, 32, cache_granules=8)
    h = src.prefetch_async(np.array([0, 1, 2]))
    assert h.wait(5)
    before = src.stats["fetches"]
    src.fetch_rows(np.arange(96))  # granules 0..2: all warm
    assert src.stats["fetches"] == before
    assert src.stats["hits"] >= 3
