"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode
(the kernel body executes on CPU; BlockSpecs are the TPU contract)."""

from _hypothesis_compat import hypothesis, st
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.ref import FORMS, knn_ref, pairwise_ref

SHAPES = [(3, 5, 4), (17, 33, 7), (64, 64, 64), (130, 70, 129), (1, 300, 2)]
DTYPES = [np.float32, np.float16]


@pytest.mark.parametrize("form", FORMS)
@pytest.mark.parametrize("m,n,d", SHAPES)
def test_pairwise_shape_sweep(form, m, n, d):
    rng = np.random.default_rng(m * 1000 + n)
    X = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    Y = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    got = ops.pairwise_distance(X, Y, form, force_pallas=True, bm=32, bn=32,
                                bd=32)
    want = pairwise_ref(X, Y, form)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("form", ["l2", "cosine", "l1"])
@pytest.mark.parametrize("dtype", DTYPES)
def test_pairwise_dtype_sweep(form, dtype):
    rng = np.random.default_rng(11)
    X = jnp.asarray(rng.normal(size=(40, 19)).astype(dtype))
    Y = jnp.asarray(rng.normal(size=(50, 19)).astype(dtype))
    got = ops.pairwise_distance(X, Y, form, force_pallas=True, bm=16, bn=16,
                                bd=16)
    want = pairwise_ref(X.astype(jnp.float32), Y.astype(jnp.float32), form)
    tol = 5e-3 if dtype != np.float32 else 2e-4
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


def test_pairwise_bf16():
    rng = np.random.default_rng(12)
    X = jnp.asarray(rng.normal(size=(33, 20)), jnp.bfloat16)
    Y = jnp.asarray(rng.normal(size=(21, 20)), jnp.bfloat16)
    got = ops.pairwise_distance(X, Y, "l2", force_pallas=True, bm=16, bn=16,
                                bd=16)
    want = pairwise_ref(X.astype(jnp.float32), Y.astype(jnp.float32), "l2")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0.05,
                               rtol=0.05)


@pytest.mark.parametrize("form", FORMS)
def test_knn_fused_vs_ref(form):
    rng = np.random.default_rng(13)
    Q = jnp.asarray(rng.normal(size=(37, 12)).astype(np.float32))
    DB = jnp.asarray(rng.normal(size=(301, 12)).astype(np.float32))
    gd, gi = ops.knn(Q, DB, form, k=9, force_pallas=True, bq=16, bn=64)
    wd, wi = knn_ref(Q, DB, 9, form)
    np.testing.assert_allclose(np.asarray(gd), np.asarray(wd), rtol=1e-4,
                               atol=1e-4)
    for i in range(Q.shape[0]):  # id sets equal modulo ties
        assert set(np.asarray(gi[i]).tolist()) == set(np.asarray(wi[i]).tolist())


@hypothesis.given(
    m=st.integers(1, 40), n=st.integers(2, 80), d=st.integers(1, 24),
    k=st.integers(1, 8),
    form=st.sampled_from(["l2", "cosine", "l1", "dot"]),
)
@hypothesis.settings(max_examples=20, deadline=None)
def test_knn_property_sweep(m, n, d, k, form):
    k = min(k, n)
    rng = np.random.default_rng(m * 77 + n)
    Q = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    DB = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    gd, gi = ops.knn(Q, DB, form, k=k, force_pallas=True, bq=8, bn=32)
    wd, _ = knn_ref(Q, DB, k, form)
    np.testing.assert_allclose(np.asarray(gd), np.asarray(wd), rtol=1e-3,
                               atol=1e-3)
    # ascending + ids valid
    gd = np.asarray(gd)
    assert (np.diff(gd, axis=1) >= -1e-6).all()
    gi = np.asarray(gi)
    assert ((gi >= 0) & (gi < n)).all()


def test_padding_rows_never_returned():
    """DB padding (masked by n_valid) must not appear in results even when
    the padding would be the nearest point."""
    Q = jnp.zeros((4, 8), jnp.float32)
    DB = jnp.ones((10, 8), jnp.float32) * 5.0
    gd, gi = ops.knn(Q, DB, "l2", k=3, force_pallas=True, bq=4, bn=16)
    assert (np.asarray(gi) < 10).all()


def test_dispatch_fallback_nonkernel_distance():
    """haversine has no kernel form -> registry fallback still works."""
    rng = np.random.default_rng(14)
    X = jnp.asarray(rng.uniform(-1, 1, size=(6, 2)).astype(np.float32))
    D = ops.pairwise_distance(X, X, "haversine")
    assert np.asarray(D).shape == (6, 6)
    d_, i_ = ops.knn(X, X, "haversine", k=2)
    assert (np.asarray(i_)[:, 0] == np.arange(6)).all()


def test_resolve_form():
    from repro.core import distances as dl

    assert ops.resolve_form("euclidean") == "l2"
    assert ops.resolve_form(dl.get("manhattan")) == "l1"
    assert ops.resolve_form("sqeuclidean") == "sqeuclidean"
    assert ops.resolve_form("haversine") is None
