"""Model zoo behaviour: LM forward/decode consistency, EGNN equivariance,
recsys learning + EmbeddingBag equivalences."""

import dataclasses

from _hypothesis_compat import hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import gnn, recsys, transformer as tfm
from repro.optim import AdamWConfig, adamw_init, adamw_update


def _tiny_cfg(moe=False):
    m = tfm.MoEConfig(n_experts=4, top_k=2, d_ff_expert=32, n_shared=1,
                      capacity_factor=2.0) if moe else None
    return tfm.TransformerConfig(
        name="t", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
        vocab=101, seq_chunk=8, kv_chunk=8, moe=m)


@pytest.mark.parametrize("moe", [False, True])
def test_lm_decode_matches_forward(moe):
    cfg = _tiny_cfg(moe)
    sh = tfm.ShardingConfig()
    p = tfm.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 9), 0, cfg.vocab)
    hidden, _ = tfm.forward(p, toks, cfg, sh)
    ref = hidden[:, -1].astype(jnp.float32) @ p["lm_head"].astype(jnp.float32)
    cache = {k: jnp.zeros(v.shape, v.dtype)
             for k, v in tfm.cache_shapes(cfg, 2, 16).items()}
    for t in range(9):
        logits, cache = tfm.decode_step(p, cache, toks[:, t:t + 1],
                                        jnp.int32(t), cfg, sh)
    V = cfg.vocab
    np.testing.assert_allclose(np.asarray(logits[:, :V]), np.asarray(ref[:, :V]),
                               atol=1e-2, rtol=1e-2)


def test_lm_prefill_matches_decode():
    cfg = _tiny_cfg()
    sh = tfm.ShardingConfig()
    p = tfm.init_params(cfg, jax.random.PRNGKey(2))
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 8), 0, cfg.vocab)
    logits_p, cache_p = tfm.prefill_step(p, toks, cfg, sh)
    cache = {k: jnp.zeros(v.shape, v.dtype)
             for k, v in tfm.cache_shapes(cfg, 2, 16).items()}
    for t in range(8):
        logits_d, cache = tfm.decode_step(p, cache, toks[:, t:t + 1],
                                          jnp.int32(t), cfg, sh)
    V = cfg.vocab
    np.testing.assert_allclose(np.asarray(logits_p[:, :V]),
                               np.asarray(logits_d[:, :V]), atol=1e-4)
    for key in ("k", "v"):
        np.testing.assert_allclose(
            np.asarray(cache_p[key], np.float32),
            np.asarray(cache[key][:, :, :8], np.float32), atol=1e-5)


def test_lm_scan_equals_unrolled():
    cfg = _tiny_cfg()
    sh = tfm.ShardingConfig()
    p = tfm.init_params(cfg, jax.random.PRNGKey(4))
    toks = jax.random.randint(jax.random.PRNGKey(5), (2, 8), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    l1, _ = tfm.loss_fn(p, batch, cfg, sh)
    cfg2 = dataclasses.replace(cfg, scan_layers=False, unroll_inner=True)
    l2, _ = tfm.loss_fn(p, batch, cfg2, sh)
    assert abs(float(l1) - float(l2)) < 0.02  # bf16 fusion-order noise


def test_lm_training_reduces_loss():
    cfg = _tiny_cfg()
    sh = tfm.ShardingConfig()
    p = tfm.init_params(cfg, jax.random.PRNGKey(6))
    opt = adamw_init(p)
    ocfg = AdamWConfig(lr=1e-2, total_steps=30, warmup_steps=0,
                       weight_decay=0.0, schedule="constant")
    toks = jax.random.randint(jax.random.PRNGKey(7), (4, 16), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}

    @jax.jit
    def step(p, opt, b):
        (loss, _), g = jax.value_and_grad(
            lambda pp, bb: tfm.loss_fn(pp, bb, cfg, sh), has_aux=True)(p, b)
        p, opt, _ = adamw_update(g, opt, p, ocfg)
        return p, opt, loss

    losses = []
    for _ in range(25):
        p, opt, loss = step(p, opt, batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 1.0, (losses[0], losses[-1])


def test_moe_capacity_drops_are_bounded():
    """Dispatch math sanity: output shape preserved, aux loss ~1 for
    near-uniform routing (single-vs-mesh loss agreement is covered in
    test_distributed)."""
    cfg = _tiny_cfg(moe=True)
    x = jax.random.normal(jax.random.PRNGKey(8), (64, 32))
    lw = tfm.init_params(cfg, jax.random.PRNGKey(9))["layers"]
    lw0 = {k: v[0] for k, v in lw.items()}
    y, aux = tfm._moe_local(
        x, lw0["router"], lw0["we_gate"], lw0["we_up"], lw0["we_down"],
        moe=cfg.moe, model_axis="model", ep=1, dtype=jnp.float32)
    assert y.shape == x.shape
    assert float(aux) > 0.5  # load-balance loss near 1 for near-uniform


# ---------------------------------------------------------------------------
# EGNN
# ---------------------------------------------------------------------------


@hypothesis.given(seed=st.integers(0, 1000))
@hypothesis.settings(max_examples=8, deadline=None)
def test_egnn_equivariance_property(seed):
    rng = np.random.default_rng(seed)
    cfg = gnn.EGNNConfig(name="t", n_layers=2, d_hidden=16, d_feat=8,
                         n_classes=4)
    p = gnn.init_params(cfg, jax.random.PRNGKey(seed))
    N, E = 30, 90
    feats = jnp.asarray(rng.normal(size=(N, 8)), jnp.float32)
    coords = jnp.asarray(rng.normal(size=(N, 3)), jnp.float32)
    edges = jnp.asarray(rng.integers(0, N, size=(2, E)), jnp.int32)
    Q, _ = np.linalg.qr(rng.normal(size=(3, 3)))
    if np.linalg.det(Q) < 0:
        Q[:, 0] *= -1  # proper rotation
    Q = jnp.asarray(Q, jnp.float32)
    t = jnp.asarray(rng.normal(size=(3,)), jnp.float32)
    h1, x1 = gnn.forward(p, feats, coords, edges, cfg)
    h2, x2 = gnn.forward(p, feats, coords @ Q.T + t, edges, cfg)
    # fp32 noise: (x_i + t) - (x_j + t) cancels t only approximately, so the
    # tolerance is loose in absolute terms but far below any equivariance
    # violation (a non-equivariant layer errs at O(|x|) ~ 1).
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=2e-3)
    np.testing.assert_allclose(np.asarray(x1 @ Q.T + t), np.asarray(x2),
                               atol=1e-2)


def test_egnn_edge_mask_blocks_messages():
    rng = np.random.default_rng(1)
    cfg = gnn.EGNNConfig(name="t", n_layers=1, d_hidden=8, d_feat=4,
                         n_classes=3)
    p = gnn.init_params(cfg, jax.random.PRNGKey(1))
    feats = jnp.asarray(rng.normal(size=(10, 4)), jnp.float32)
    coords = jnp.asarray(rng.normal(size=(10, 3)), jnp.float32)
    edges = jnp.asarray(rng.integers(0, 10, size=(2, 20)), jnp.int32)
    h_all, _ = gnn.forward(p, feats, coords, edges, cfg,
                           edge_mask=jnp.zeros((20,), bool))
    # all edges masked == empty graph: only the self-path contributes
    h_empty, _ = gnn.forward(p, feats, coords,
                             jnp.zeros((2, 1), jnp.int32), cfg,
                             edge_mask=jnp.zeros((1,), bool))
    np.testing.assert_allclose(np.asarray(h_all), np.asarray(h_empty),
                               atol=1e-5)


def test_graph_sampler_budget_and_validity():
    from repro.models.graph_sampler import CSRGraph, sample_subgraph, subgraph_budget

    rng = np.random.default_rng(2)
    src = rng.integers(0, 200, 3000)
    dst = rng.integers(0, 200, 3000)
    g = CSRGraph.from_edge_list(src, dst, 200)
    sub = sample_subgraph(g, np.arange(16), [5, 3], rng,
                          feats=rng.normal(size=(200, 6)).astype(np.float32),
                          labels=rng.integers(0, 4, 200))
    n_max, e_max = subgraph_budget(16, [5, 3])
    assert sub["edges"].shape == (2, e_max)
    assert sub["n_nodes"] <= n_max and sub["n_edges"] <= e_max
    # every real edge endpoint is a real node
    e = sub["n_edges"]
    assert (sub["edges"][:, :e] < sub["n_nodes"]).all()
    # sampled edges exist in the original graph
    ids = sub["node_ids"]
    for s_, d_ in zip(sub["edges"][0, :20], sub["edges"][1, :20]):
        assert ids[s_] in g.neighbours(int(ids[d_]))


def test_knn_graph_pdasc_close_to_exact():
    from repro.models.graph_sampler import knn_graph

    rng = np.random.default_rng(3)
    coords = rng.normal(size=(60, 3)).astype(np.float32)
    e_exact = knn_graph(coords, 4, method="exact")
    e_pdasc = knn_graph(coords, 4, method="pdasc")
    exact_set = set(map(tuple, e_exact.T.tolist()))
    pdasc_set = set(map(tuple, e_pdasc.T.tolist()))
    overlap = len(exact_set & pdasc_set) / len(exact_set)
    assert overlap > 0.7, overlap


# ---------------------------------------------------------------------------
# Recsys
# ---------------------------------------------------------------------------


def test_embedding_bag_ragged_equals_fixed():
    rng = np.random.default_rng(4)
    table = jnp.asarray(rng.normal(size=(50, 8)), jnp.float32)
    ids = rng.integers(0, 50, (6, 5))
    lens = rng.integers(1, 6, 6)
    mask = (np.arange(5)[None] < lens[:, None])
    fixed = recsys.embedding_bag(table, jnp.asarray(ids), jnp.asarray(mask))
    flat_ids, seg = [], []
    for b in range(6):
        flat_ids += ids[b, :lens[b]].tolist()
        seg += [b] * lens[b]
    ragged = recsys.embedding_bag_ragged(
        table, jnp.asarray(flat_ids), jnp.asarray(seg), 6)
    np.testing.assert_allclose(np.asarray(fixed), np.asarray(ragged),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("arch_id", ["wide-deep", "xdeepfm", "din", "autoint"])
def test_recsys_learns_planted_signal(arch_id):
    from repro.configs import get_arch
    from repro.data import recsys_batch

    cfg = get_arch(arch_id).smoke_fn()
    p = recsys.init_params(cfg, jax.random.PRNGKey(5))
    opt = adamw_init(p)
    ocfg = AdamWConfig(lr=3e-3, total_steps=60, warmup_steps=0,
                       weight_decay=0.0, schedule="constant")

    @jax.jit
    def step(p, opt, b):
        (loss, _), g = jax.value_and_grad(
            lambda pp, bb: recsys.loss_fn(pp, bb, cfg), has_aux=True)(p, b)
        p, opt, _ = adamw_update(g, opt, p, ocfg)
        return p, opt, loss

    losses = []
    for s in range(50):
        b = jax.tree.map(jnp.asarray, recsys_batch(s, 256, cfg, seed=7))
        p, opt, loss = step(p, opt, b)
        losses.append(float(loss))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.01, (
        arch_id, losses[:3], losses[-3:])


def test_retrieval_topk_correct():
    from repro.configs import get_arch
    from repro.data import recsys_batch

    cfg = get_arch("wide-deep").smoke_fn()
    p = recsys.init_params(cfg, jax.random.PRNGKey(6))
    batch = jax.tree.map(jnp.asarray, recsys_batch(0, 3, cfg, seed=8))
    cand = jax.random.normal(jax.random.PRNGKey(7), (200, cfg.retrieval_dim))
    top, ids = recsys.retrieval_step(p, batch, cand, cfg, k=10)
    u = recsys.user_vector(p, batch, cfg)
    full = np.asarray(u @ cand.T)
    want = np.sort(full, axis=1)[:, -10:][:, ::-1]
    np.testing.assert_allclose(np.asarray(top), want, rtol=1e-5, atol=1e-5)
