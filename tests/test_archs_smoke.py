"""Deliverable (f): per-arch smoke tests — a REDUCED config of the same
family runs one forward/train step on CPU; output shapes + no NaNs."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_cells, arch_ids, get_arch
from repro.models import gnn, recsys, transformer as tfm
from repro.optim import AdamWConfig, adamw_init, adamw_update

LM_ARCHS = [a for a in arch_ids() if get_arch(a).family == "lm"]
RECSYS_ARCHS = [a for a in arch_ids() if get_arch(a).family == "recsys"]

_OCFG = AdamWConfig(lr=1e-3, total_steps=10)


def _finite(tree) -> bool:
    return all(bool(jnp.isfinite(x.astype(jnp.float32)).all())
               for x in jax.tree.leaves(tree))


def test_registry_has_all_assigned_archs():
    expected = {
        "deepseek-moe-16b", "qwen3-moe-235b-a22b", "minitron-8b",
        "stablelm-1.6b", "granite-3-2b", "egnn", "wide-deep", "xdeepfm",
        "din", "autoint", "pdasc",
    }
    assert expected == set(arch_ids())
    # 10 assigned archs x 4 shapes + 2 pdasc cells
    assert len(all_cells()) == 42


def test_full_configs_match_assignment():
    """Exact numbers from the assignment table."""
    c = get_arch("deepseek-moe-16b").config_fn()
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.vocab) == \
        (28, 2048, 16, 16, 102400)
    assert (c.moe.n_experts, c.moe.top_k, c.moe.n_shared) == (64, 6, 2)
    c = get_arch("qwen3-moe-235b-a22b").config_fn()
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.vocab) == \
        (94, 4096, 64, 4, 151936)
    assert (c.moe.n_experts, c.moe.top_k) == (128, 8)
    c = get_arch("minitron-8b").config_fn()
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab) == (32, 4096, 16384, 256000)
    c = get_arch("stablelm-1.6b").config_fn()
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab) == (24, 2048, 5632, 100352)
    c = get_arch("granite-3-2b").config_fn()
    assert (c.n_layers, c.d_ff, c.vocab) == (40, 8192, 49155)
    assert c.vocab_padded % 256 == 0
    c = get_arch("egnn").config_fn()
    assert (c.n_layers, c.d_hidden) == (4, 64)
    c = get_arch("wide-deep").config_fn()
    assert (c.n_sparse, c.embed_dim, c.mlp) == (40, 32, (1024, 512, 256))
    c = get_arch("xdeepfm").config_fn()
    assert (c.n_sparse, c.embed_dim, c.cin_layers) == (39, 10, (200, 200, 200))
    c = get_arch("din").config_fn()
    assert (c.embed_dim, c.seq_len, c.attn_mlp, c.mlp) == \
        (18, 100, (80, 40), (200, 80))
    c = get_arch("autoint").config_fn()
    assert (c.n_sparse, c.embed_dim, c.n_attn_layers, c.n_attn_heads,
            c.d_attn) == (39, 16, 3, 2, 32)


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_lm_smoke_train_step(arch_id):
    cfg = get_arch(arch_id).smoke_fn()
    sh = tfm.ShardingConfig()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    (loss, _), grads = jax.value_and_grad(
        lambda p, b: tfm.loss_fn(p, b, cfg, sh), has_aux=True)(params, batch)
    params2, opt2, m = adamw_update(grads, opt, params, _OCFG)
    assert np.isfinite(float(loss)) and float(loss) > 0
    assert _finite(params2) and _finite(grads)
    assert jax.tree.structure(params2) == jax.tree.structure(params)


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_lm_smoke_decode_step(arch_id):
    cfg = get_arch(arch_id).smoke_fn()
    sh = tfm.ShardingConfig()
    params = tfm.init_params(cfg, jax.random.PRNGKey(2))
    B, S = 2, 16
    cache = {k: jnp.zeros(v.shape, v.dtype)
             for k, v in tfm.cache_shapes(cfg, B, S).items()}
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, 1), 0, cfg.vocab)
    logits, cache = tfm.decode_step(params, cache, toks, jnp.int32(0), cfg, sh)
    assert logits.shape == (B, cfg.vocab_padded)
    assert bool(jnp.isfinite(logits[:, :cfg.vocab]).all())


def test_egnn_smoke_all_shapes():
    from repro.configs import egnn as egnn_mod

    rng = np.random.default_rng(0)
    base = get_arch("egnn").smoke_fn()
    # flat-graph regime
    cfg = base
    p = gnn.init_params(cfg, jax.random.PRNGKey(0))
    N, E = 40, 120
    batch = dict(
        feats=jnp.asarray(rng.normal(size=(N, cfg.d_feat)), jnp.float32),
        coords=jnp.asarray(rng.normal(size=(N, 3)), jnp.float32),
        edges=jnp.asarray(rng.integers(0, N, (2, E)), jnp.int32),
        edge_mask=jnp.ones((E,), bool),
        labels=jnp.asarray(rng.integers(0, cfg.n_classes, N), jnp.int32),
        label_mask=jnp.ones((N,), bool),
    )
    loss, _ = gnn.loss_fn(p, batch, cfg)
    assert np.isfinite(float(loss))
    # molecule regime
    mcfg = dataclasses.replace(base, task="graph_reg")
    mp = gnn.init_params(mcfg, jax.random.PRNGKey(1))
    mb = dict(
        feats=jnp.asarray(rng.normal(size=(4, 10, mcfg.d_feat)), jnp.float32),
        coords=jnp.asarray(rng.normal(size=(4, 10, 3)), jnp.float32),
        edges=jnp.asarray(rng.integers(0, 10, (4, 2, 16)), jnp.int32),
        targets=jnp.asarray(rng.normal(size=(4,)), jnp.float32),
    )
    ml, _ = gnn.loss_fn(mp, mb, mcfg)
    assert np.isfinite(float(ml))
    # per-shape specialisation binds dims
    full = egnn_mod.specialise(get_arch("egnn").config_fn(), "full_graph_sm")
    assert full.d_feat == 1433 and full.n_classes == 7
    mol = egnn_mod.specialise(get_arch("egnn").config_fn(), "molecule")
    assert mol.task == "graph_reg"


@pytest.mark.parametrize("arch_id", RECSYS_ARCHS)
def test_recsys_smoke_train_and_serve(arch_id):
    from repro.data import recsys_batch

    cfg = get_arch(arch_id).smoke_fn()
    p = recsys.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(p)
    batch = jax.tree.map(jnp.asarray, recsys_batch(0, 16, cfg, seed=0))
    (loss, _), grads = jax.value_and_grad(
        lambda pp, b: recsys.loss_fn(pp, b, cfg), has_aux=True)(p, batch)
    p2, _, _ = adamw_update(grads, opt, p, _OCFG)
    assert np.isfinite(float(loss)) and _finite(p2)
    logits, penult = recsys.forward(p, batch, cfg)
    assert logits.shape == (16,)
    assert bool(jnp.isfinite(logits).all())


def test_pdasc_smoke_build_search():
    from repro.core.index import PDASCIndex

    cfg = get_arch("pdasc").smoke_fn()
    rng = np.random.default_rng(0)
    data = rng.normal(size=(cfg.n, cfg.d)).astype(np.float32)
    idx = PDASCIndex.build(data, gl=cfg.gl, distance=cfg.distance)
    res = idx.search(data[:cfg.n_queries], k=cfg.k)
    assert res.ids.shape == (cfg.n_queries, cfg.k)
    assert bool(jnp.isfinite(res.dists[res.ids >= 0]).all())
    # storage-aware config: the same cell served from the tiered leaf store
    idx.attach_store(cfg.store, block=cfg.store_block)
    res2 = idx.search(data[:cfg.n_queries], k=cfg.k, mode="two_stage",
                      rerank_width=cfg.rerank_width)
    assert res2.ids.shape == (cfg.n_queries, cfg.k)
