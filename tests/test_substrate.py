"""Substrate: optimizer, accumulation, compression, checkpoint, train loop,
data pipeline, serving engine."""

import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, latest_step, load_checkpoint,
                              save_checkpoint)
from repro.data import BatchPipeline, lm_tokens, make_dataset
from repro.optim import (AdamWConfig, accumulate_gradients, adamw_init,
                         adamw_update, clip_by_global_norm, cosine_schedule,
                         global_norm)
from repro.optim import compression as comp
from repro.serving import BatchingEngine
from repro.train import TrainLoopConfig, train_loop


# --------------------------- optimizer -------------------------------------


def test_adamw_matches_manual_reference():
    cfg = AdamWConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                      grad_clip=0.0, schedule="constant", warmup_steps=0)
    p = {"w": jnp.asarray([1.0, -2.0])}
    g = {"w": jnp.asarray([0.5, 0.5])}
    st = adamw_init(p)
    p2, st2, _ = adamw_update(g, st, p, cfg)
    m = 0.1 * 0.5
    v = 0.01 * 0.25
    mhat, vhat = m / 0.1, v / 0.01
    want = np.array([1.0, -2.0]) - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(p2["w"]), want, rtol=1e-5)
    assert int(st2.step) == 1


def test_weight_decay_decoupled():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.5, grad_clip=0.0,
                      schedule="constant", warmup_steps=0)
    p = {"w": jnp.asarray([2.0])}
    g = {"w": jnp.asarray([0.0])}
    p2, _, _ = adamw_update(g, adamw_init(p), p, cfg)
    np.testing.assert_allclose(np.asarray(p2["w"]), [2.0 - 0.1 * 0.5 * 2.0],
                               rtol=1e-5)


def test_clip_by_global_norm():
    g = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert abs(float(gn) - 5.0) < 1e-5
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    assert float(cosine_schedule(cfg, jnp.int32(0))) == 0.0
    assert abs(float(cosine_schedule(cfg, jnp.int32(10))) - 1.0) < 1e-5
    assert abs(float(cosine_schedule(cfg, jnp.int32(100))) - 0.1) < 1e-3


def test_grad_accumulation_matches_full_batch():
    rng = np.random.default_rng(0)
    W = jnp.asarray(rng.normal(size=(6, 3)), jnp.float32)
    X = jnp.asarray(rng.normal(size=(8, 6)), jnp.float32)
    Y = jnp.asarray(rng.normal(size=(8, 3)), jnp.float32)

    def loss_fn(p, b):
        return jnp.mean((b["x"] @ p["w"] - b["y"]) ** 2), {}

    p = {"w": W}
    batch = {"x": X, "y": Y}
    _, _, g_full = accumulate_gradients(loss_fn, p, batch, 1)
    _, _, g_acc = accumulate_gradients(loss_fn, p, batch, 4)
    np.testing.assert_allclose(np.asarray(g_full["w"]), np.asarray(g_acc["w"]),
                               rtol=1e-5, atol=1e-6)


# --------------------------- compression -----------------------------------


def test_topk_compression_error_feedback():
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.normal(size=(32, 8)), jnp.float32)
    state = comp.topk_init(g)
    (vals, idx), state2 = comp.topk_compress(g, state, k=16)
    recon = comp.topk_decompress(vals, idx, g.shape)
    # error buffer holds exactly the residual
    np.testing.assert_allclose(np.asarray(recon + state2.error),
                               np.asarray(g), rtol=1e-5, atol=1e-6)
    # next round re-injects the residual
    (v2, i2), state3 = comp.topk_compress(jnp.zeros_like(g), state2, k=256)
    recon2 = comp.topk_decompress(v2, i2, g.shape)
    np.testing.assert_allclose(np.asarray(recon + recon2), np.asarray(g),
                               atol=1e-5)


def test_powersgd_rank_and_convergence():
    rng = np.random.default_rng(2)
    lowrank = rng.normal(size=(20, 3)) @ rng.normal(size=(3, 15))
    g = jnp.asarray(lowrank, jnp.float32)
    state = comp.powersgd_init(g.shape, rank=3)
    for _ in range(3):  # warm-started Q converges on a fixed matrix
        (p_, q_), state = comp.powersgd_compress(g, state)
    err = np.linalg.norm(np.asarray(comp.powersgd_decompress(p_, q_) - g))
    assert err < 1e-2 * np.linalg.norm(lowrank)


# --------------------------- checkpoint ------------------------------------


def _tree(seed):
    rng = np.random.default_rng(seed)
    return {"a": jnp.asarray(rng.normal(size=(4, 3)), jnp.float32),
            "nested": {"b": jnp.asarray(rng.integers(0, 9, 5), jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree(0)
    save_checkpoint(str(tmp_path), 7, t)
    assert latest_step(str(tmp_path)) == 7
    restored, step = load_checkpoint(str(tmp_path), t)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(t["a"]))
    np.testing.assert_array_equal(np.asarray(restored["nested"]["b"]),
                                  np.asarray(t["nested"]["b"]))


def test_checkpoint_prune_keeps_newest(tmp_path):
    t = _tree(1)
    for s in (1, 2, 3, 4):
        save_checkpoint(str(tmp_path), s, t, keep=2)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == ["step_000000003", "step_000000004"]


def test_checkpoint_async_manager(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree(2)
    mgr.save_async(3, t)
    mgr.wait()
    assert mgr.last_saved == 3
    restored, step = mgr.restore_or_none(t)
    assert step == 3


def test_checkpoint_atomic_no_partial(tmp_path):
    """A crashed (simulated) write must not become ``latest``."""
    t = _tree(3)
    save_checkpoint(str(tmp_path), 1, t)
    # simulate a partial write
    os.makedirs(tmp_path / "step_000000002.tmp-999", exist_ok=True)
    assert latest_step(str(tmp_path)) == 1
    restored, step = load_checkpoint(str(tmp_path), t)
    assert step == 1


# --------------------------- train loop ------------------------------------


def _quad_setup():
    target = jnp.asarray([1.0, -2.0, 3.0])

    def loss_fn(p, b):
        return jnp.sum((p["w"] - target) ** 2) * b["scale"], {}

    ocfg = AdamWConfig(lr=0.05, weight_decay=0.0, grad_clip=0.0,
                       schedule="constant", warmup_steps=0, total_steps=100)

    @jax.jit
    def step(params, opt, batch):
        (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        params, opt, m = adamw_update(g, opt, params, ocfg)
        return params, opt, {"loss": loss, **m}

    make_batch = lambda s: {"scale": jnp.float32(1.0)}
    return step, make_batch


def test_train_loop_restart_is_exact(tmp_path):
    """Interrupted-then-resumed run ends with the same params as an
    uninterrupted one (stateless data + checkpoint/restart)."""
    step, make_batch = _quad_setup()

    def fresh():
        p = {"w": jnp.zeros(3)}
        return p, adamw_init(p)

    # uninterrupted 20 steps
    p, o = fresh()
    p_ref, o_ref, _ = train_loop(step, p, o, make_batch,
                                 TrainLoopConfig(total_steps=20))
    # interrupted at 10 (ckpt every 5), then resumed
    ck = str(tmp_path / "ck")
    p, o = fresh()
    p1, o1, _ = train_loop(step, p, o, make_batch,
                           TrainLoopConfig(total_steps=10, ckpt_dir=ck,
                                           ckpt_every=5))
    p2, o2, _ = train_loop(step, *fresh(), make_batch,
                           TrainLoopConfig(total_steps=20, ckpt_dir=ck,
                                           ckpt_every=5))
    np.testing.assert_allclose(np.asarray(p_ref["w"]), np.asarray(p2["w"]),
                               rtol=1e-6)


def test_train_loop_nan_sentinel(tmp_path):
    ocfg = AdamWConfig(lr=0.1, schedule="constant", warmup_steps=0,
                       grad_clip=0.0, weight_decay=0.0, total_steps=10)

    @jax.jit
    def step(params, opt, batch):
        loss = jnp.float32(jnp.nan) * batch["x"]
        return params, opt, {"loss": loss}

    p = {"w": jnp.zeros(2)}
    with pytest.raises(FloatingPointError):
        train_loop(step, p, adamw_init(p), lambda s: {"x": jnp.float32(1.0)},
                   TrainLoopConfig(total_steps=5))


# --------------------------- data ------------------------------------------


def test_datasets_deterministic():
    for name in ("geo_clusters", "sparse_highdim", "dense_embed", "tfidf_like"):
        a = make_dataset(name, n=500, seed=3)
        b = make_dataset(name, n=500, seed=3)
        np.testing.assert_array_equal(a, b)
        assert np.isfinite(a).all()


def test_geo_clusters_has_outlier_islands():
    x = make_dataset("geo_clusters", n=2000, seed=0) * 180 / np.pi
    lat = x[:, 0]
    assert (lat < 32).sum() > 20  # Canary cluster far from the mainland
    assert (lat > 35).sum() > 1500


def test_lm_tokens_stateless():
    a = lm_tokens(5, 4, 16, 100)
    b = lm_tokens(5, 4, 16, 100)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = lm_tokens(6, 4, 16, 100)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_batch_pipeline_order_and_prefetch():
    seen = []
    pipe = BatchPipeline(lambda s: {"step": np.asarray(s)}, prefetch=3)
    for _ in range(5):
        s, b = pipe.get()
        seen.append(int(b["step"]))
    pipe.close()
    assert seen == [0, 1, 2, 3, 4]


# --------------------------- serving ---------------------------------------


def test_batching_engine_results_match_direct():
    def handler(batch, n_valid):
        return {"y": batch["x"] * 2.0}

    eng = BatchingEngine(handler, batch_size=4, max_wait_ms=20,
                         pad_payload={"x": np.zeros(3, np.float32)})
    reqs = [eng.submit({"x": np.full(3, i, np.float32)}) for i in range(10)]
    outs = [r.wait(timeout=10) for r in reqs]
    eng.close()
    for i, o in enumerate(outs):
        np.testing.assert_allclose(o["y"], np.full(3, 2.0 * i), rtol=1e-6)
    assert eng.stats["requests"] == 10
    assert eng.stats["batches"] >= 3
