"""Batched beam search through the kernel layer: exact equivalence with the
dense path, parity with the legacy vmap beam, and interpret-mode execution of
the fused rank kernel (gather -> distance -> top-k)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import distances as dl
from repro.core import msa, nsa, radius as rl
from repro.kernels import ops, ref as kref

# Every registry distance with a kernelised form (ops.resolve_form != None).
KERNEL_DISTANCES = ["euclidean", "manhattan", "chebyshev", "cosine", "dot"]


def _build(distance, n=240, d=6, gl=32, seed=3):
    rng = np.random.default_rng(seed)
    data = rng.normal(size=(n, d)).astype(np.float32)
    idx, _ = msa.build_index(data, gl=gl, distance=distance,
                             key=jax.random.PRNGKey(seed))
    return data, idx


def _gap_radius(idx, dist, Q, quantile=0.6, min_gap=5e-3):
    """A radius sitting in a wide gap of the query-to-prototype distance
    distribution. Cross-implementation comparisons need this: two f32
    arithmetics that differ in the last ulps may disagree on ``d < r`` when
    some distance lands within that error of ``r``; a gapped radius makes
    the radius predicate implementation-independent."""
    ds = []
    for lv in idx.levels:
        D = np.asarray(dl.get(dist).pairwise(Q, lv.points))
        ds.append(D[:, np.asarray(lv.valid)].ravel())
    ds = np.unique(np.concatenate(ds))
    gaps = np.diff(ds)
    start = int(len(ds) * quantile)
    for j in range(start, len(gaps)):
        if gaps[j] > min_gap:
            return float((ds[j] + ds[j + 1]) / 2)
    return float(ds[-1] + 1.0)


# ---------------------------------------------------------------------------
# Batched beam == dense (exact) at full beam width
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("distance", KERNEL_DISTANCES)
def test_full_beam_bit_identical_to_dense(distance):
    """beam >= level size must reproduce search_dense *bit-identically* on
    every kernelised form: the rowwise (gathered) kernel arithmetic matches
    the pairwise kernel element-for-element, and the candidate sets
    coincide, so dists, ids and the candidate counts are equal arrays."""
    data, idx = _build(distance)
    dist = dl.get(distance)
    r = float(rl.estimate_radius(jnp.asarray(data), dist, quantile=0.6))
    mc = msa.max_children(idx)
    Q = jnp.asarray(data[:12])
    dense = nsa.search_dense(idx, Q, dist=dist, k=7, r=r)
    beam = nsa.search_beam(idx, Q, dist=dist, k=7, r=r, beam=10_000,
                           max_children=mc)
    np.testing.assert_array_equal(np.asarray(dense.dists),
                                  np.asarray(beam.dists))
    np.testing.assert_array_equal(np.asarray(dense.ids), np.asarray(beam.ids))
    np.testing.assert_array_equal(np.asarray(dense.n_candidates),
                                  np.asarray(beam.n_candidates))


@pytest.mark.parametrize("distance", ["euclidean", "cosine"])
def test_full_beam_bit_identical_with_leaf_filter(distance):
    data, idx = _build(distance, seed=5)
    dist = dl.get(distance)
    r = float(rl.estimate_radius(jnp.asarray(data), dist, quantile=0.4))
    mc = msa.max_children(idx)
    Q = jnp.asarray(data[:8])
    dense = nsa.search_dense(idx, Q, dist=dist, k=5, r=r,
                             leaf_radius_filter=True)
    beam = nsa.search_beam(idx, Q, dist=dist, k=5, r=r, beam=10_000,
                           max_children=mc, leaf_radius_filter=True)
    np.testing.assert_array_equal(np.asarray(dense.dists),
                                  np.asarray(beam.dists))
    np.testing.assert_array_equal(np.asarray(dense.ids), np.asarray(beam.ids))


def test_full_beam_matches_dense_nonkernel_form():
    """Forms without a kernel (jaccard) fall back to the registry inside
    rank_candidates; full-width beam must still return the dense id set."""
    rng = np.random.default_rng(7)
    data = np.abs(rng.normal(size=(200, 4)).astype(np.float32))
    idx, _ = msa.build_index(data, gl=25, distance="jaccard",
                             key=jax.random.PRNGKey(7))
    dist = dl.get("jaccard")
    r = float(rl.estimate_radius(jnp.asarray(data), dist, quantile=0.7))
    mc = msa.max_children(idx)
    Q = jnp.asarray(data[:6])
    dense = nsa.search_dense(idx, Q, dist=dist, k=5, r=r)
    beam = nsa.search_beam(idx, Q, dist=dist, k=5, r=r, beam=10_000,
                           max_children=mc)
    for i in range(6):
        assert (set(np.asarray(beam.ids[i]).tolist())
                == set(np.asarray(dense.ids[i]).tolist()))


# ---------------------------------------------------------------------------
# Batched beam == legacy vmap beam (pruned widths)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("beam", [1, 4, 16])
def test_batched_beam_matches_vmap_beam(beam):
    """The kernel-layer batched beam and the seed per-query vmap beam visit
    the same candidates, so their result id sets coincide (distances agree
    to f32 tolerance — the two paths use different but equivalent
    arithmetic: rowwise Gram vs per-point subtraction)."""
    data, idx = _build("euclidean", n=400, d=8, seed=11)
    dist = dl.get("euclidean")
    mc = msa.max_children(idx)
    Q = jnp.asarray(data[:20])
    r = _gap_radius(idx, "euclidean", Q)
    new = nsa.search_beam(idx, Q, dist=dist, k=5, r=r, beam=beam,
                          max_children=mc)
    old = nsa.search_beam_vmap(idx, Q, dist=dist, k=5, r=r, beam=beam,
                               max_children=mc)
    np.testing.assert_allclose(np.asarray(new.dists), np.asarray(old.dists),
                               rtol=1e-3, atol=3e-3)
    np.testing.assert_array_equal(np.asarray(new.n_candidates),
                                  np.asarray(old.n_candidates))
    for i in range(20):
        assert (set(np.asarray(new.ids[i]).tolist())
                == set(np.asarray(old.ids[i]).tolist())), i


def test_single_query_squeeze():
    data, idx = _build("euclidean", seed=13)
    dist = dl.get("euclidean")
    r = float(rl.estimate_radius(jnp.asarray(data), dist, quantile=0.5))
    mc = msa.max_children(idx)
    res = nsa.search_beam(idx, jnp.asarray(data[0]), dist=dist, k=3, r=r,
                          beam=8, max_children=mc)
    assert res.dists.shape == (3,) and res.ids.shape == (3,)
    assert int(res.ids[0]) == 0  # finds itself


# ---------------------------------------------------------------------------
# Fused rank kernel: interpret-mode Pallas vs reference oracle
# ---------------------------------------------------------------------------

RANK_SHAPES = [(3, 17, 5, 4), (9, 130, 12, 7), (1, 300, 2, 1), (16, 64, 24, 9)]


@pytest.mark.parametrize("form", kref.FORMS)
@pytest.mark.parametrize("b,w,d,k", RANK_SHAPES)
def test_rank_kernel_interpret_parity(form, b, w, d, k):
    rng = np.random.default_rng(b * 100 + w)
    Q = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    C = jnp.asarray(rng.normal(size=(b, w, d)).astype(np.float32))
    ok = jnp.asarray(rng.random((b, w)) > 0.3)
    gd, gi = ops.rank_candidates(Q, C, ok, form, k=k, force_pallas=True,
                                 bq=4, bn=32)
    wd, wi = kref.rank_ref(Q, C, ok, k, form)
    np.testing.assert_allclose(np.asarray(gd), np.asarray(wd), rtol=1e-4,
                               atol=1e-4)
    # id sets equal modulo ties among equal (incl. masked BIG) distances
    gd_, wd_ = np.asarray(gd), np.asarray(wd)
    for i in range(b):
        real = gd_[i] < kref.BIG / 2
        assert (set(np.asarray(gi[i])[real].tolist())
                == set(np.asarray(wi[i])[real].tolist()))


def test_rank_kernel_all_masked():
    Q = jnp.zeros((2, 4), jnp.float32)
    C = jnp.zeros((2, 10, 4), jnp.float32)
    ok = jnp.zeros((2, 10), bool)
    gd, gi = ops.rank_candidates(Q, C, ok, "l2", k=3, force_pallas=True,
                                 bq=2, bn=8)
    assert (np.asarray(gd) > kref.BIG / 2).all()


def test_rank_padding_never_selected():
    """Candidate-axis padding (w not a bn multiple) ranks as BIG."""
    rng = np.random.default_rng(5)
    Q = jnp.asarray(rng.normal(size=(3, 6)).astype(np.float32))
    C = jnp.asarray(rng.normal(size=(3, 13, 6)).astype(np.float32))
    ok = jnp.ones((3, 13), bool)
    gd, gi = ops.rank_candidates(Q, C, ok, "l2", k=13, force_pallas=True,
                                 bq=2, bn=8)
    assert ((np.asarray(gi) >= 0) & (np.asarray(gi) < 13)).all()


def test_search_end_to_end_force_pallas():
    """Both search modes run the Pallas kernel bodies (interpret) end to end
    and agree with the reference dispatch."""
    data, idx = _build("euclidean", n=200, d=8, seed=17)
    dist = dl.get("euclidean")
    mc = msa.max_children(idx)
    Q = jnp.asarray(data[:6])
    r = _gap_radius(idx, "euclidean", Q, quantile=0.5)
    kc = ops.KernelConfig(bm=32, bn=32, bd=32, bq=4, force_pallas=True)
    for mode_kw in (dict(), dict(leaf_radius_filter=True)):
        d_ref = nsa.search_dense(idx, Q, dist=dist, k=5, r=r, **mode_kw)
        d_pl = nsa.search_dense(idx, Q, dist=dist, k=5, r=r, kernel=kc,
                                **mode_kw)
        np.testing.assert_allclose(np.asarray(d_pl.dists),
                                   np.asarray(d_ref.dists), rtol=1e-3,
                                   atol=3e-3)
        b_ref = nsa.search_beam(idx, Q, dist=dist, k=5, r=r, beam=16,
                                max_children=mc, **mode_kw)
        b_pl = nsa.search_beam(idx, Q, dist=dist, k=5, r=r, beam=16,
                               max_children=mc, kernel=kc, **mode_kw)
        np.testing.assert_allclose(np.asarray(b_pl.dists),
                                   np.asarray(b_ref.dists), rtol=1e-3,
                                   atol=3e-3)


# ---------------------------------------------------------------------------
# Memory honesty: the dense path builds no [B, n, d] broadcast cube
# ---------------------------------------------------------------------------


def test_dense_l1_never_materialises_cube():
    """With row_chunk streaming, no intermediate of the traced dense search
    reaches [B, n_leaf, d] elements for a broadcast (l1) distance."""
    data, idx = _build("manhattan", n=512, d=16, gl=64, seed=19)
    dist = dl.get("manhattan")
    B, n0, d = 8, idx.levels[0].points.shape[0], 16
    kc = ops.KernelConfig(row_chunk=64)
    closed = jax.make_jaxpr(
        lambda q: nsa.search_dense(idx, q, dist=dist, k=5, r=2.0, kernel=kc)
    )(jnp.zeros((B, d), jnp.float32))

    cube = B * n0 * d
    seen = [0]

    def scan(jaxpr):
        for eqn in jaxpr.eqns:
            for v in eqn.outvars:
                aval = getattr(v, "aval", None)
                if aval is not None and hasattr(aval, "shape"):
                    elems = 1
                    for s in aval.shape:
                        elems *= int(s)
                    seen[0] = max(seen[0], elems)
            for val in eqn.params.values():
                if isinstance(val, jax.core.ClosedJaxpr):
                    scan(val.jaxpr)
                elif isinstance(val, jax.core.Jaxpr):
                    scan(val)
                elif isinstance(val, (tuple, list)):
                    for x in val:
                        if isinstance(x, jax.core.ClosedJaxpr):
                            scan(x.jaxpr)
    scan(closed.jaxpr)
    assert seen[0] < cube, (seen[0], cube)
