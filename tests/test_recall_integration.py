"""Integration: the paper's recall protocol end-to-end on small surrogates.

PDASC (k-medoids, generous radius) must reach high 10-NN recall across
distances, including distances the tree baselines cannot support — the
paper's core claim, at test-suite scale (full protocol: benchmarks/).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.baselines import exact_knn
from repro.core.index import PDASCIndex
from repro.data import make_dataset


def _recall(ids, gt):
    k = gt.shape[1]
    return float(np.mean([
        len(set(ids[i][ids[i] >= 0].tolist()) & set(gt[i].tolist())) / k
        for i in range(len(gt))
    ]))


@pytest.mark.parametrize("distance", ["euclidean", "manhattan", "cosine"])
def test_pdasc_recall_dense_embed(distance):
    data = make_dataset("dense_embed", n=3000, seed=0)
    train, test = data[:2800], data[2800:2850]
    idx = PDASCIndex.build(train, gl=128, distance=distance,
                           radius_quantile=0.35)
    res = idx.search(test, k=10, mode="dense")
    _, gt = exact_knn(test, train, distance=distance, k=10)
    rec = _recall(np.asarray(res.ids), np.asarray(gt))
    assert rec >= 0.9, (distance, rec)


def test_pdasc_recall_haversine_geo():
    """Municipalities surrogate + Haversine — the outlier-robustness case."""
    data = make_dataset("geo_clusters", n=2000, seed=1)
    train, test = data[:1900], data[1900:1940]
    idx = PDASCIndex.build(train, gl=64, distance="haversine",
                           radius_quantile=0.5)
    res = idx.search(test, k=10, mode="dense")
    _, gt = exact_knn(test, train, distance="haversine", k=10)
    rec = _recall(np.asarray(res.ids), np.asarray(gt))
    assert rec >= 0.9, rec


def test_pdasc_beam_vs_dense_tradeoff():
    """Beam search trades candidates for recall monotonically."""
    data = make_dataset("dense_embed", n=2000, seed=2)
    train, test = data[:1900], data[1900:1930]
    idx = PDASCIndex.build(train, gl=128, distance="euclidean",
                           radius_quantile=0.4)
    _, gt = exact_knn(test, train, distance="euclidean", k=10)
    dense = idx.search(test, k=10, mode="dense")
    beam = idx.search(test, k=10, mode="beam", beam=48)
    r_dense = _recall(np.asarray(dense.ids), np.asarray(gt))
    r_beam = _recall(np.asarray(beam.ids), np.asarray(gt))
    n_dense = int(np.asarray(dense.n_candidates).mean())
    n_beam = int(np.asarray(beam.n_candidates).mean())
    assert r_dense >= 0.9
    assert r_beam >= r_dense - 0.15
    assert n_beam <= n_dense  # beam prunes


def test_cosine_more_efficient_than_euclidean_on_tfidf():
    """The paper's NYtimes finding (Fig. 5d): distance choice matters.
    On tf-idf geometry a cosine-built index reaches comparable recall while
    scanning a small fraction of the candidates the euclidean index needs
    (euclidean radii are dominated by document length, so the frontier is
    indiscriminate)."""
    data = make_dataset("tfidf_like", n=3000, seed=3)
    train, test = data[:2900], data[2900:2950]
    stats = {}
    for distance in ("euclidean", "cosine"):
        idx = PDASCIndex.build(train, gl=128, distance=distance,
                               radius_quantile=0.1)
        res = idx.search(test, k=10, mode="dense")
        _, gt = exact_knn(test, train, distance=distance, k=10)
        stats[distance] = (
            _recall(np.asarray(res.ids), np.asarray(gt)),
            float(np.asarray(res.n_candidates).mean()),
        )
    (r_e, c_e), (r_c, c_c) = stats["euclidean"], stats["cosine"]
    assert r_c >= r_e - 0.05, stats
    assert c_c < 0.5 * c_e, stats
