"""Train a small LM end-to-end with the fault-tolerant loop.

    PYTHONPATH=src python examples/train_small_lm.py [--steps 300]

Uses the stablelm reduced config (a few M params — CPU-friendly stand-in
for the ~100M driver; pass --big for a ~100M-param config if you have the
cycles), the stateless zipf data pipeline, AdamW with cosine schedule, and
checkpoint/restart: interrupt it and re-run — it resumes exactly.
"""

import argparse
import sys

from repro.launch import train


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=300)
    p.add_argument("--big", action="store_true",
                   help="~100M-param config (slow on CPU)")
    p.add_argument("--ckpt", default="/tmp/repro_lm_ckpt")
    args = p.parse_args()

    if args.big:
        # ~100M params: 12L x d512 x ffn2048, 32k vocab
        import jax

        import repro.models.transformer as tfm
        from repro.configs import base, register_arch
        from repro.configs.base import ArchDef, LM_SHAPES

        cfg = tfm.TransformerConfig(
            name="lm-100m", n_layers=12, d_model=512, n_heads=8,
            n_kv_heads=8, d_ff=2048, vocab=32000, seq_chunk=128, kv_chunk=128)
        print(f"params: {cfg.n_params() / 1e6:.1f}M")
        register_arch(ArchDef(id="lm-100m", family="lm",
                              config_fn=lambda: cfg, smoke_fn=lambda: cfg,
                              shapes=LM_SHAPES))
        arch = "lm-100m"
        extra = ["--batch", "8", "--seq", "512"]
    else:
        arch = "stablelm-1.6b"
        extra = ["--smoke", "--batch", "8", "--seq", "128"]

    sys.argv = ["train", "--arch", arch, "--steps", str(args.steps),
                "--ckpt", args.ckpt, "--ckpt-every", "50", *extra]
    train.main()


if __name__ == "__main__":
    main()
