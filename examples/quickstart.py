"""PDASC quickstart: build a multilevel index, search with arbitrary
distances through the declarative Query API, measure recall against exact
ground truth.

    PYTHONPATH=src python examples/quickstart.py

One index, one query surface: a ``repro.query.Query`` says *what* to
retrieve; ``idx.plan(query)`` binds *how* (which pipeline, which kernel
ops) from the index's capabilities — ``plan.explain()`` shows the choice.

Kernel block sizes default to hand-set per-op tiles. After a one-off
autotune sweep (``PYTHONPATH=src python -m benchmarks.bench_kernels``,
which persists per-shape winners to ``~/.cache/repro/kernel_tune.json``),
pass ``Query(k=10, kernel=ops.KernelConfig(auto=True))`` and every plan
resolves the tuned blocks instead — explicitly-set knobs still win, and
plans re-compile automatically when the cache is retuned (DESIGN.md §3.9).

To serve an index behind the batching engine, see ``examples/serve_ann.py``
/ ``python -m repro.launch.serve``; add ``--replicas 4`` for the replicated
fault-tolerant tier (health-checked replica pool + retry/hedge router,
DESIGN.md §3.10) and ``--faults "wedge:r1@20+8"`` to watch it route around
a deterministically injected fault. For the observability surface add
``--shadow-sample 8`` (online recall estimate with a Wilson interval,
re-answered exactly off the hot path), ``--trace-sample 16 --cost-log
experiments/costlog.jsonl`` (one JSONL plan-cost record per traced
request), ``--slo-p99-ms 50`` (multi-rate error-budget burn alerts) and
``--dash`` (live terminal dashboard); ``python -m repro.obs.report
--metrics experiments/serve_metrics.json`` renders a dump offline
(DESIGN.md §3.12).
"""

import numpy as np

from repro.baselines import exact_knn
from repro.core.index import PDASCIndex
from repro.data import make_dataset
from repro.query import Query


def recall(ids, gt):
    k = gt.shape[1]
    return np.mean([
        len(set(ids[i][ids[i] >= 0].tolist()) & set(gt[i].tolist())) / k
        for i in range(len(gt))
    ])


def main():
    # --- a dense-embedding dataset (GLOVE surrogate) -------------------------
    data = make_dataset("dense_embed", n=6000, seed=0)
    train, test = data[:5900], data[5900:5950]

    query = Query(k=10)  # execution="auto": the batched beam hot path
    for distance in ("euclidean", "manhattan", "chebyshev", "cosine"):
        idx = PDASCIndex.build(train, gl=256, distance=distance,
                               radius_quantile=0.35)
        res = idx.plan(query)(test)  # plans cache on the index: re-running
        # an equal query reuses the compiled pipeline, zero retraces
        _, gt = exact_knn(test, train, distance=distance, k=10)
        print(f"{distance:10s} recall@10 = {recall(np.asarray(res.ids), np.asarray(gt)):.3f} "
              f"(mean candidates scanned: {int(np.asarray(res.n_candidates).mean())} "
              f"of {len(train)})")

    # --- the same API on geospatial data with the Haversine metric ----------
    geo = make_dataset("geo_clusters", n=3000, seed=1)
    g_train, g_test = geo[:2900], geo[2900:2950]
    idx = PDASCIndex.build(g_train, gl=60, distance="haversine",
                           radius_quantile=0.5)
    print("\nindex structure (Municipalities surrogate):")
    print(idx.describe())
    plan = idx.plan(Query(k=10, execution="dense"))  # the faithful pipeline
    print("\nwhat the planner bound (plan.explain()):")
    print(plan.explain())
    res = plan(g_test)
    _, gt = exact_knn(g_test, g_train, distance="haversine", k=10)
    print(f"\nhaversine  recall@10 = {recall(np.asarray(res.ids), np.asarray(gt)):.3f}")

    # --- non-metric dissimilarity (paper future work: Jaccard) --------------
    # (weighted Jaccard on the MNIST-like surrogate: overlapping supports —
    # on near-disjoint tf-idf vectors the prototype frontier saturates at
    # d=1.0 and prunes structurally, a known Jaccard-on-sparse caveat)
    docs = np.abs(make_dataset("sparse_highdim", n=3000, seed=2))
    d_train, d_test = docs[:2900], docs[2900:2950]
    idx = PDASCIndex.build(d_train, gl=128, distance="jaccard",
                           radius_quantile=0.6)
    res = idx.plan(Query(k=10, execution="dense"))(d_test)
    _, gt = exact_knn(d_test, d_train, distance="jaccard", k=10)
    rec = recall(np.asarray(res.ids), np.asarray(gt))
    print(f"jaccard    recall@10 = {rec:.3f}")

    # --- observability tour (DESIGN.md §3.11/§3.12) -------------------------
    # Everything above also reported into the process-wide repro.obs
    # registry; recall@k is k Bernoulli trials per query, so an estimate
    # over a sample carries a Wilson score interval (what the serving
    # tier's --shadow-sample online estimator publishes live).
    from repro import obs

    trials = int(np.asarray(gt).size)
    lo, hi = obs.wilson(rec * trials, trials)
    print(f"           95% Wilson interval over {trials} trials: "
          f"[{lo:.3f}, {hi:.3f}]")
    snap = obs.snapshot()
    print("\nplan executions by pipeline (obs.snapshot()):")
    for row in snap[obs.names.PLAN_EXECUTIONS]["series"]:
        print(f"  {row['labels']}: {int(row['value'])}")


if __name__ == "__main__":
    main()
