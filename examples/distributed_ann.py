"""Distributed PDASC on a (data, model) device mesh.

Runs with 8 simulated devices (the same code drives the 512-chip production
mesh in the dry-run):

    PYTHONPATH=src python examples/distributed_ann.py

  1. shard the database over the ``data`` axis — each device builds its own
     sub-index (the paper's "groups distributed across nodes"),
  2. fan queries out, search every shard, and merge the per-shard top-k with
     the log2(P) butterfly collective,
  3. compare the merged result with exact brute force,
  4. out-of-core tour: stream a dataset shard-by-shard through the builder,
     flushing the exact fp32 payload to a (simulated) remote object store —
     the node serves two-stage queries holding only int8 codes + a bounded
     granule cache (DESIGN.md §3.13).
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import distances as dl  # noqa: E402
from repro.core import distributed as dd  # noqa: E402
from repro.core import radius as rl  # noqa: E402
from repro.data import make_dataset  # noqa: E402
from repro.kernels.ref import knn_ref  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402
from repro.query import Query, compile_sharded_plan  # noqa: E402


def out_of_core_tour():
    """Streaming build + remote exact tier: the dataset never sits here."""
    from repro.core.distributed import payload_placement
    from repro.core.index import PDASCIndex
    from repro.store import SimulatedObjectStore

    shard_rows, n_shards, block = 2048, 3, 64
    data = make_dataset("dense_embed", n=shard_rows * n_shards, seed=7)

    def shards():
        # stand-in for a reader that yields one shard at a time from disk /
        # network — the full array exists here only to score recall below
        for s in range(n_shards):
            yield data[s * shard_rows:(s + 1) * shard_rows]

    print("\nout-of-core: streaming build, exact payload -> object store ...")
    store = SimulatedObjectStore(latency_ms=0.05)
    idx = PDASCIndex.build_streaming(
        shards(), gl=64, remote=store, block=block, store="int8",
        method="kmeans", radius_quantile=0.35, cache_granules=16)

    mem = idx.memory_bytes()
    print(f"  remote bytes       {mem['remote_bytes']:>10,}  (object store)")
    print(f"  resident payload   {mem['payload']:>10,}  (int8 codes)")
    print(f"  host granule cache {mem['host_cache']:>10,}  "
          f"(LRU, 16 granules max)")
    print(f"  total resident     {mem['total_resident']:>10,}")

    # two-stage search: quantised scan on the codes, exact rerank fetching
    # only the candidate granules through the cache
    q = jnp.asarray(data[:32])
    res = idx.search(q, k=10, rerank_width=64)
    _, gt = knn_ref(q, jnp.asarray(data), 10, "l2")
    rec = np.mean([
        len(set(np.asarray(res.ids[i]).tolist())
            & set(np.asarray(gt[i]).tolist())) / 10
        for i in range(len(q))
    ])
    st = idx.store.exact.stats
    print(f"  recall@10={rec:.3f}  cache: {st['hits']} hits / "
          f"{st['fetches']} remote fetches  "
          f"(store ops: {store.op_counts})")

    # co-placement: each serving node owns a granule-aligned payload range,
    # so its rerank fetches never leave its own slice of the object store
    for e in payload_placement(idx.n_points, block, n_shards):
        print(f"  node {e['shard']}: rows {e['rows']}  "
              f"granules {e['granules']}")


def main():
    mesh = make_mesh((4, 2), ("data", "model"))
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}")

    data = make_dataset("dense_embed", n=16000, seed=0)
    queries = jnp.asarray(data[:64])
    db = jnp.asarray(data)
    dist = dl.get("cosine")

    print("building one PDASC sub-index per data shard ...")
    sidx = dd.build_sharded(db, mesh, db_axes=("data",), gl=256,
                            distance="cosine")
    print(f"  stacked index: {sidx.levels[0].points.shape[0]} shards x "
          f"{sidx.levels[0].points.shape[1]} leaf slots, "
          f"{len(sidx.levels)} levels")

    r = float(rl.estimate_radius(db, dist, quantile=0.4))
    for merge in ("butterfly", "allgather"):
        # one declarative Query, lowered onto the mesh by the plan compiler
        plan = compile_sharded_plan(mesh, Query(k=10, radius=r),
                                    dist=dist, db_axes=("data",), merge=merge)
        if merge == "butterfly":
            print(plan.explain())
        res = plan(sidx, queries)
        _, gt = knn_ref(queries, db, 10, "cosine")
        rec = np.mean([
            len(set(np.asarray(res.ids[i]).tolist())
                & set(np.asarray(gt[i]).tolist())) / 10
            for i in range(len(queries))
        ])
        print(f"  merge={merge:10s} recall@10={rec:.3f} "
              f"(candidates/query: {int(np.asarray(res.n_candidates).mean())})")

    # distributed exact search (the ground-truth path at scale)
    gd, gi = dd.exact_knn_sharded(db, queries, mesh, db_axes=("data", "model"),
                                  distance="l2", k=10)
    wd, _ = knn_ref(queries, db, 10, "l2")
    print(f"  distributed exact == single-host exact: "
          f"{bool(jnp.allclose(gd, wd, atol=1e-5))}")

    out_of_core_tour()


if __name__ == "__main__":
    main()
