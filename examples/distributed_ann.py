"""Distributed PDASC on a (data, model) device mesh.

Runs with 8 simulated devices (the same code drives the 512-chip production
mesh in the dry-run):

    PYTHONPATH=src python examples/distributed_ann.py

  1. shard the database over the ``data`` axis — each device builds its own
     sub-index (the paper's "groups distributed across nodes"),
  2. fan queries out, search every shard, and merge the per-shard top-k with
     the log2(P) butterfly collective,
  3. compare the merged result with exact brute force.
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import distances as dl  # noqa: E402
from repro.core import distributed as dd  # noqa: E402
from repro.core import radius as rl  # noqa: E402
from repro.data import make_dataset  # noqa: E402
from repro.kernels.ref import knn_ref  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402
from repro.query import Query, compile_sharded_plan  # noqa: E402


def main():
    mesh = make_mesh((4, 2), ("data", "model"))
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}")

    data = make_dataset("dense_embed", n=16000, seed=0)
    queries = jnp.asarray(data[:64])
    db = jnp.asarray(data)
    dist = dl.get("cosine")

    print("building one PDASC sub-index per data shard ...")
    sidx = dd.build_sharded(db, mesh, db_axes=("data",), gl=256,
                            distance="cosine")
    print(f"  stacked index: {sidx.levels[0].points.shape[0]} shards x "
          f"{sidx.levels[0].points.shape[1]} leaf slots, "
          f"{len(sidx.levels)} levels")

    r = float(rl.estimate_radius(db, dist, quantile=0.4))
    for merge in ("butterfly", "allgather"):
        # one declarative Query, lowered onto the mesh by the plan compiler
        plan = compile_sharded_plan(mesh, Query(k=10, radius=r),
                                    dist=dist, db_axes=("data",), merge=merge)
        if merge == "butterfly":
            print(plan.explain())
        res = plan(sidx, queries)
        _, gt = knn_ref(queries, db, 10, "cosine")
        rec = np.mean([
            len(set(np.asarray(res.ids[i]).tolist())
                & set(np.asarray(gt[i]).tolist())) / 10
            for i in range(len(queries))
        ])
        print(f"  merge={merge:10s} recall@10={rec:.3f} "
              f"(candidates/query: {int(np.asarray(res.n_candidates).mean())})")

    # distributed exact search (the ground-truth path at scale)
    gd, gi = dd.exact_knn_sharded(db, queries, mesh, db_axes=("data", "model"),
                                  distance="l2", k=10)
    wd, _ = knn_ref(queries, db, 10, "l2")
    print(f"  distributed exact == single-host exact: "
          f"{bool(jnp.allclose(gd, wd, atol=1e-5))}")


if __name__ == "__main__":
    main()
