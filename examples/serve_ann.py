"""End-to-end serving driver (the paper's deployment scenario): a PDASC
index served behind the batching engine with live request traffic.

    PYTHONPATH=src python examples/serve_ann.py

Thin wrapper over ``repro.launch.serve`` with a cosine text-embedding
workload — reports p50/p99 latency, batch occupancy and recall. The driver
serves a declarative ``repro.query.Query`` through the engine's
``QueryHandler``: one plan per index epoch, reused across batches (the
printed ``[serve] plan:`` block is that plan's ``explain()``).
"""

import sys

from repro.launch import serve


def main():
    sys.argv = [
        "serve", "--dataset", "tfidf_like", "--n", "12000", "--gl", "256",
        "--distance", "cosine", "--queries", "256", "--batch", "32",
        "--max-wait-ms", "4",
    ]
    serve.main()


if __name__ == "__main__":
    main()
