"""The `retrieval_cand` scenario end-to-end: train a Wide&Deep CTR model,
then retrieve top candidates for a user — exact distributed-style scoring
vs PDASC-pruned retrieval over the candidate embeddings.

    PYTHONPATH=src python examples/recsys_retrieval.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.index import PDASCIndex
from repro.data import recsys_batch
from repro.models import recsys
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.query import Query


def main():
    cfg = get_arch("wide-deep").smoke_fn()
    params = recsys.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    ocfg = AdamWConfig(lr=3e-3, total_steps=80, warmup_steps=0,
                       schedule="constant", weight_decay=0.0)

    @jax.jit
    def step(p, o, b):
        (loss, _), g = jax.value_and_grad(
            lambda pp, bb: recsys.loss_fn(pp, bb, cfg), has_aux=True)(p, b)
        p, o, _ = adamw_update(g, o, p, ocfg)
        return p, o, loss

    print("training wide-deep (smoke config) on planted CTR data ...")
    for s in range(80):
        batch = jax.tree.map(jnp.asarray, recsys_batch(s, 256, cfg))
        params, opt, loss = step(params, opt, batch)
        if s % 20 == 0:
            print(f"  step {s:3d} loss {float(loss):.4f}")

    # candidate corpus: item embeddings projected into the retrieval space
    rng = np.random.default_rng(1)
    n_cand = 50_000
    cand = jnp.asarray(rng.normal(size=(n_cand, cfg.retrieval_dim)),
                       jnp.float32)
    user_batch = jax.tree.map(jnp.asarray, recsys_batch(999, 4, cfg))

    # exact top-100 (dot product)
    t0 = time.perf_counter()
    top, ids = recsys.retrieval_step(params, user_batch, cand, cfg, k=100)
    jax.block_until_ready(top)
    t_exact = time.perf_counter() - t0
    print(f"\nexact retrieval over {n_cand} candidates: "
          f"{t_exact * 1e3:.1f}ms for 4 users")

    # PDASC-pruned retrieval: index candidates once, search per user vector
    print("building PDASC index over candidates (dot dissimilarity) ...")
    idx = PDASCIndex.build(np.asarray(cand), gl=512, distance="cosine",
                           radius_quantile=0.25)
    u = recsys.user_vector(params, user_batch, cfg)
    t0 = time.perf_counter()
    res = idx.plan(Query(k=100, execution="dense"))(np.asarray(u))
    jax.block_until_ready(res.dists)
    t_pdasc = time.perf_counter() - t0
    overlap = np.mean([
        len(set(np.asarray(res.ids[i]).tolist())
            & set(np.asarray(ids[i]).tolist())) / 100
        for i in range(4)
    ])
    print(f"PDASC retrieval: {t_pdasc * 1e3:.1f}ms, "
          f"candidates scanned {int(np.asarray(res.n_candidates).mean())}"
          f"/{n_cand}, top-100 overlap with exact-dot: {overlap:.2f}")
    print("(cosine index vs dot scores — overlap is the angular/metric gap; "
          "see benchmarks/bench_retrieval.py for the full comparison)")


if __name__ == "__main__":
    main()
