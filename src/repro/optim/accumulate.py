"""Microbatch gradient accumulation.

Splits a global batch into ``n_micro`` slices along axis 0 and scans a
value_and_grad over them, summing gradients in fp32. Memory: one microbatch
of activations at a time; the optimizer sees the mean gradient, so training
semantics are identical to the unaccumulated step (linearity of grad).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def accumulate_gradients(loss_fn, params, batch, n_micro: int):
    """Returns (loss, aux_of_last_micro, grads) with grads averaged.

    loss_fn(params, microbatch) -> (loss, aux). Every array in ``batch`` must
    have a leading axis divisible by ``n_micro``.
    """
    if n_micro <= 1:
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch
        )
        return loss, aux, grads

    def split(x):
        return x.reshape(n_micro, x.shape[0] // n_micro, *x.shape[1:])

    micro = jax.tree.map(split, batch)
    gfn = jax.value_and_grad(loss_fn, has_aux=True)

    def step(carry, mb):
        loss_sum, g_sum = carry
        (loss, aux), g = gfn(params, mb)
        g_sum = jax.tree.map(
            lambda a, b: a + b.astype(jnp.float32), g_sum, g
        )
        return (loss_sum + loss, g_sum), aux

    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (loss_sum, g_sum), auxes = jax.lax.scan(
        step, (jnp.float32(0.0), g0), micro
    )
    grads = jax.tree.map(
        lambda g, p: (g / n_micro).astype(p.dtype), g_sum, params
    )
    aux = jax.tree.map(lambda a: a[-1], auxes)
    return loss_sum / n_micro, aux, grads
