"""Gradient compression for slow (inter-pod) links.

Two schemes, both with error feedback so compression error is re-injected
next step (convergence-preserving):

* :func:`topk_compress` / :func:`topk_decompress` — per-tensor magnitude
  top-k sparsification. Compression ratio ``k / n``; wire format is
  (values[k], indices[k]).
* :class:`PowerSGD` — rank-r low-rank approximation of 2D gradients
  (G ~= P Q^T) with a warm-started Q and one orthogonalisation per step.
  Wire bytes drop from ``m*n`` to ``r*(m+n)``.

Usage pattern (see ``repro.train.dp_step``): gradients are psum'd over the
fast intra-pod axes at full precision, compressed, summed over the ``pod``
axis, then decompressed + error-fed-back. The collective saving is measured
in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class TopKState(NamedTuple):
    error: Array  # residual feedback buffer, same shape as the tensor


def topk_init(x: Array) -> TopKState:
    return TopKState(error=jnp.zeros(x.shape, jnp.float32))


def topk_compress(g: Array, state: TopKState, k: int):
    """Returns ((values[k], idx[k]), new_state). Error feedback included."""
    flat = g.astype(jnp.float32).reshape(-1) + state.error.reshape(-1)
    mag = jnp.abs(flat)
    _, idx = jax.lax.top_k(mag, k)
    vals = flat[idx]
    kept = jnp.zeros_like(flat).at[idx].set(vals)
    err = (flat - kept).reshape(g.shape)
    return (vals, idx.astype(jnp.int32)), TopKState(error=err)


def topk_decompress(vals: Array, idx: Array, shape) -> Array:
    n = 1
    for s in shape:
        n *= s
    return jnp.zeros((n,), jnp.float32).at[idx].add(vals).reshape(shape)


class PowerSGDState(NamedTuple):
    q: Array  # [n, r] warm-started right factor
    error: Array  # [m, n] feedback


def powersgd_init(shape, rank: int, key=None) -> PowerSGDState:
    m, n = shape
    key = key if key is not None else jax.random.PRNGKey(17)
    q = jax.random.normal(key, (n, rank), jnp.float32)
    return PowerSGDState(q=q, error=jnp.zeros((m, n), jnp.float32))


def _orthonormalise(m: Array) -> Array:
    q, _ = jnp.linalg.qr(m)
    return q


def powersgd_compress(g: Array, state: PowerSGDState):
    """One PowerSGD round. Returns ((P [m,r], Q [n,r]), new_state).

    The caller all-reduces P (and optionally Q) over the slow axis; the
    reconstruction is ``P @ Q^T``.
    """
    gf = g.astype(jnp.float32) + state.error
    p = gf @ state.q  # [m, r]
    p = _orthonormalise(p)
    q = gf.T @ p  # [n, r]
    recon = p @ q.T
    return (p, q), PowerSGDState(q=q, error=gf - recon)


def powersgd_decompress(p: Array, q: Array) -> Array:
    return p @ q.T
