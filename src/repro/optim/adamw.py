"""AdamW (decoupled weight decay) + schedules + clipping, pure JAX.

Moments live in fp32 regardless of the parameter compute dtype; the update
runs in fp32 and is cast back to the parameter dtype (mixed-precision
master-weight pattern). The optimizer state is a pytree matching the param
tree, so any GSPMD sharding on the params carries straight over to the
moments (pass the same specs).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0  # 0 disables
    schedule: str = "cosine"  # "cosine" | "linear" | "constant"
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    step: Array  # int32[]
    mu: Any  # pytree like params (fp32)
    nu: Any  # pytree like params (fp32)


def adamw_init(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(step=jnp.int32(0), mu=zeros,
                    nu=jax.tree.map(jnp.copy, zeros))


def opt_state_shapes(param_shapes) -> OptState:
    """ShapeDtypeStruct mirror (dry-run: no allocation)."""
    z = jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), param_shapes
    )
    return OptState(step=jax.ShapeDtypeStruct((), jnp.int32), mu=z,
                    nu=jax.tree.map(lambda s: s, z))


def opt_state_specs(param_specs) -> OptState:
    from jax.sharding import PartitionSpec as P

    return OptState(step=P(), mu=param_specs,
                    nu=jax.tree.map(lambda s: s, param_specs))


def global_norm(tree) -> Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


def cosine_schedule(cfg: AdamWConfig, step: Array) -> Array:
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (s - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    if cfg.schedule == "cosine":
        decay = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
            1 + jnp.cos(math.pi * prog)
        )
    elif cfg.schedule == "linear":
        decay = 1.0 - (1 - cfg.min_lr_frac) * prog
    else:
        decay = jnp.float32(1.0)
    return cfg.lr * jnp.where(s < cfg.warmup_steps, warm, decay)


def adamw_update(
    grads, state: OptState, params, cfg: AdamWConfig,
    *, skip_decay: Optional[Callable[[str], bool]] = None,
):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    if cfg.grad_clip > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    lr = cosine_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * g32 * g32
        mhat = m / c1
        vhat = v / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_g, tdef = jax.tree.flatten(grads)
    flat_m = tdef.flatten_up_to(state.mu)
    flat_v = tdef.flatten_up_to(state.nu)
    flat_p = tdef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, OptState(step=step, mu=new_m, nu=new_v), {
        "grad_norm": gnorm, "lr": lr,
    }
