"""Optimisation substrate (no optax in this environment — built from scratch).

  adamw.py       — AdamW + LR schedules + global-norm clipping
  accumulate.py  — microbatch gradient accumulation (scan)
  compression.py — gradient compression for slow links: top-k sparsification
                   with error feedback, PowerSGD low-rank
"""

from repro.optim.adamw import (
    AdamWConfig,
    OptState,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    cosine_schedule,
    global_norm,
)
from repro.optim.accumulate import accumulate_gradients

__all__ = [
    "AdamWConfig",
    "OptState",
    "adamw_init",
    "adamw_update",
    "clip_by_global_norm",
    "cosine_schedule",
    "global_norm",
    "accumulate_gradients",
]
