"""Comparison baselines for the paper's recall protocol (§4.4).

  exact.py      — brute-force ground truth (any distance)
  ivf_flat.py   — k-means inverted-file index (FLANN stand-in: tree/partition
                  family, Euclidean-rooted clustering)
  nndescent.py  — NN-Descent k-NN graph search (PyNNDescent stand-in:
                  graph family, arbitrary distances)
"""

from repro.baselines.exact import exact_knn
from repro.baselines.ivf_flat import IVFFlatIndex
from repro.baselines.nndescent import NNDescentIndex

__all__ = ["exact_knn", "IVFFlatIndex", "NNDescentIndex"]
