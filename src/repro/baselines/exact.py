"""Exact brute-force k-NN — the ground truth every recall is measured
against (paper §4.3: KD-tree where the distance allows, else brute force;
on TPU brute force with the fused distance+top-k kernel IS the fast path,
so it is the only exact method needed)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import distances as dist_lib
from repro.kernels import ops as kops


def exact_knn(queries, database, *, distance="euclidean", k: int = 10,
              chunk: int = 2048):
    """(dists [q, k] ascending, ids [q, k]) under any registered distance."""
    Q = jnp.asarray(queries, jnp.float32)
    DB = jnp.asarray(database, jnp.float32)
    form = kops.resolve_form(distance)
    if form is not None:
        return kops.knn(Q, DB, distance, k=k)
    # registry fallback for non-kernel distances (haversine, jaccard, ...)
    import jax

    dist = dist_lib.get(distance)
    outs_d, outs_i = [], []
    for i in range(0, Q.shape[0], chunk):
        D = dist_lib.pairwise_chunked(dist, Q[i:i + chunk], DB)
        neg, idx = jax.lax.top_k(-D, k)
        outs_d.append(-neg)
        outs_i.append(idx.astype(jnp.int32))
    return jnp.concatenate(outs_d), jnp.concatenate(outs_i)
