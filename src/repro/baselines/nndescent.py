"""NN-Descent: graph-based ANN (the PyNNDescent stand-in).

Builds an approximate k-NN graph by iterative neighbour-of-neighbour
refinement (Dong et al., 2011), then answers queries by greedy best-first
graph walk from random seeds. Like PyNNDescent it accepts arbitrary
distances (only pairwise evaluations are used) but has no distributed story
— exactly the comparison point the paper draws in §4.4.

Host-side numpy driver with jnp distance batches: graph construction is
pointer-chasing (not an accelerator workload); distance blocks go through
the registry.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import distances as dist_lib


def _pair_dists(dist, A, B):
    return np.asarray(dist.pairwise(jnp.asarray(A), jnp.asarray(B)))


@dataclasses.dataclass
class NNDescentIndex:
    data: np.ndarray
    graph: np.ndarray  # [n, g] neighbour ids
    distance: str

    @classmethod
    def build(cls, data, *, n_neighbors: int = 15, distance: str = "euclidean",
              iters: int = 6, sample: int = 8, seed: int = 0):
        X = np.asarray(data, np.float32)
        n = len(X)
        g = min(n_neighbors, n - 1)
        dist = dist_lib.get(distance)
        rng = np.random.default_rng(seed)
        # random init
        graph = np.stack([
            rng.choice(np.delete(np.arange(n), i), g, replace=False)
            if n <= 10000 else
            (lambda c: np.where(c == i, (i + 1) % n, c))(rng.integers(0, n, g))
            for i in range(n)
        ])
        gd = np.stack([
            _pair_dists(dist, X[i:i + 1], X[graph[i]])[0] for i in range(n)
        ]) if n <= 2048 else None
        if gd is None:
            gd = np.empty((n, g), np.float32)
            for s in range(0, n, 1024):
                e = min(s + 1024, n)
                block = X[graph[s:e].reshape(-1)].reshape(e - s, g, -1)
                for j in range(s, e):
                    gd[j] = _pair_dists(dist, X[j:j + 1], block[j - s])[0]

        for _ in range(iters):
            changed = 0
            # candidate pool: sampled neighbours-of-neighbours
            cand = graph[graph[:, rng.integers(0, g, sample)].reshape(n, -1)]
            cand = cand.reshape(n, -1)
            for s in range(0, n, 512):
                e = min(s + 512, n)
                for i in range(s, e):
                    cs = np.unique(cand[i])
                    cs = cs[(cs != i)]
                    if cs.size == 0:
                        continue
                    d = _pair_dists(dist, X[i:i + 1], X[cs])[0]
                    allc = np.concatenate([graph[i], cs])
                    alld = np.concatenate([gd[i], d])
                    _, keep = np.unique(allc, return_index=True)
                    allc, alld = allc[keep], alld[keep]
                    sel = np.argsort(alld, kind="stable")[:g]
                    new = allc[sel]
                    changed += int((new != graph[i]).any())
                    graph[i], gd[i] = new, alld[sel]
            if changed == 0:
                break
        return cls(data=X, graph=graph, distance=distance)

    def search(self, queries, *, k: int = 10, n_seeds: int = 10,
               max_steps: int = 30, seed: int = 0):
        Q = np.asarray(queries, np.float32)
        dist = dist_lib.get(self.distance)
        rng = np.random.default_rng(seed)
        n = len(self.data)
        out_d = np.full((len(Q), k), np.inf, np.float32)
        out_i = np.full((len(Q), k), -1, np.int64)
        for qi in range(len(Q)):
            visited = set()
            frontier = list(rng.integers(0, n, n_seeds))
            best: list[tuple[float, int]] = []
            for _ in range(max_steps):
                fresh = [i for i in frontier if i not in visited]
                if not fresh:
                    break
                visited.update(fresh)
                d = _pair_dists(dist, Q[qi:qi + 1],
                                self.data[np.asarray(fresh)])[0]
                best.extend(zip(d.tolist(), fresh))
                best = sorted(set(best))[:k]
                # expand from the current best unexpanded nodes
                frontier = list(self.graph[[i for _, i in best]].reshape(-1))
            for j, (d_, i_) in enumerate(best[:k]):
                out_d[qi, j], out_i[qi, j] = d_, i_
        return out_d, out_i
