"""IVF-Flat: k-means inverted-file index (the FLANN stand-in).

The classic partition baseline: k-means coarse quantiser (Euclidean-rooted,
like FLANN's trees), search probes the ``n_probe`` nearest cells and scans
them exactly. Like FLANN it *supports* only centroid-meaningful metrics —
running it with cosine/chebyshev mirrors FLANN's gaps in the paper's Fig. 5
(we evaluate it anyway where the distance permits a mean).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distances as dist_lib
from repro.core.kmeans import kmeans

SUPPORTED = ("euclidean", "manhattan")  # FLANN-like coverage


@dataclasses.dataclass
class IVFFlatIndex:
    centroids: jax.Array  # [C, d]
    lists: np.ndarray  # [n] point -> cell
    order: np.ndarray  # points sorted by cell
    offsets: np.ndarray  # [C+1]
    data: jax.Array  # [n, d] (reordered)
    ids: np.ndarray  # [n] original rows (reordered)
    distance: str

    @classmethod
    def build(cls, data, *, n_cells: int = 64, distance: str = "euclidean",
              iters: int = 25, key=None) -> "IVFFlatIndex":
        X = jnp.asarray(data, jnp.float32)
        res = kmeans(X, n_cells, key=key or jax.random.PRNGKey(0),
                     iters=iters)
        labels = np.asarray(res.labels)
        order = np.argsort(labels, kind="stable")
        counts = np.bincount(labels, minlength=n_cells)
        offsets = np.concatenate([[0], np.cumsum(counts)])
        return cls(
            centroids=res.centroids, lists=labels, order=order,
            offsets=offsets.astype(np.int64),
            data=jnp.asarray(np.asarray(X)[order]),
            ids=order, distance=distance,
        )

    def search(self, queries, *, k: int = 10, n_probe: int = 8):
        dist = dist_lib.get(self.distance)
        Q = jnp.asarray(queries, jnp.float32)
        Dc = dist_lib.get("euclidean").pairwise(Q, self.centroids)
        probe = np.asarray(jax.lax.top_k(-Dc, min(n_probe,
                                                  self.centroids.shape[0]))[1])
        out_d = np.full((Q.shape[0], k), np.inf, np.float32)
        out_i = np.full((Q.shape[0], k), -1, np.int64)
        data_np = np.asarray(self.data)
        for qi in range(Q.shape[0]):
            rows = np.concatenate([
                np.arange(self.offsets[c], self.offsets[c + 1])
                for c in probe[qi]
            ]) if len(probe[qi]) else np.array([], np.int64)
            if rows.size == 0:
                continue
            d = np.asarray(dist.pairwise(Q[qi:qi + 1],
                                         jnp.asarray(data_np[rows])))[0]
            sel = np.argsort(d, kind="stable")[:k]
            out_d[qi, :len(sel)] = d[sel]
            out_i[qi, :len(sel)] = self.ids[rows[sel]]
        return out_d, out_i
