import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above run before ANY other import — jax locks the device count
on first init, and the dry-run needs 512 placeholder host devices to build
the production meshes ((16,16) single-pod, (2,16,16) multi-pod).

Per cell this driver records, to ``experiments/dryrun/<arch>__<shape>__<mesh>.json``:

  * ``memory_analysis``  — per-device argument/output/temp/peak bytes
    (proves the cell fits 16 GiB HBM),
  * ``cost_analysis``    — per-device HLO FLOPs + bytes accessed,
  * collective breakdown — parsed from the post-SPMD HLO
    (``compiled.as_text()``): per-op-kind payload bytes using ring-traffic
    factors (all-reduce 2(g-1)/g, all-gather/all-to-all (g-1)/g,
    reduce-scatter (g-1), permute 1) with the group size ``g`` parsed from
    ``replica_groups``,
  * roofline terms       — compute / memory / collective seconds per step on
    TPU v5e constants (launch.mesh), dominant term, MODEL_FLOPS ratio.

Usage:
  python -m repro.launch.dryrun --all                      # full 40-cell x 2-mesh matrix
  python -m repro.launch.dryrun --arch qwen3-moe-235b-a22b --shape train_4k --mesh multi
  python -m repro.launch.dryrun --list
"""

import argparse
import json
import re
import time
import traceback


def _parse_args():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None)
    p.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    p.add_argument("--all", action="store_true")
    p.add_argument("--list", action="store_true")
    p.add_argument("--out", default="experiments/dryrun")
    p.add_argument("--skip-existing", action="store_true")
    p.add_argument("--variant", default="base",
                   choices=["base", "opt", "opt-beam"],
                   help="'opt' lowers the beyond-paper-optimised step where "
                        "one exists (suffixes the JSON)")
    return p.parse_args()


_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 0


def _traffic_factor(kind: str, g: int) -> float:
    """Per-device ring-traffic bytes as a multiple of the op's output bytes."""
    if g <= 1:
        g = 2  # unknown group -> conservative small-group factors
    if kind == "all-reduce":
        return 2.0 * (g - 1) / g
    if kind == "all-gather":
        return (g - 1) / g
    if kind == "reduce-scatter":
        return float(g - 1)
    if kind == "all-to-all":
        return (g - 1) / g
    return 1.0  # collective-permute


def parse_collectives(hlo_text: str) -> dict:
    """Per-kind payload/traffic bytes from a post-SPMD (per-device) HLO."""
    out = {k: dict(count=0, out_bytes=0, traffic_bytes=0.0)
           for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        for kind in _COLLECTIVES:
            # match ` = <shape> <kind>(` and `<kind>-start(`; skip -done (no
            # new traffic) and convert-fusions mentioning the name.
            if f" {kind}(" in s or f" {kind}-start(" in s:
                lhs = s.split("=", 1)[1]
                op_pos = lhs.find(kind)
                shape_txt = lhs[:op_pos]
                b = _shape_bytes(shape_txt)
                g = _group_size(s)
                out[kind]["count"] += 1
                out[kind]["out_bytes"] += b
                out[kind]["traffic_bytes"] += b * _traffic_factor(kind, g)
                break
    out["total_traffic_bytes"] = sum(
        v["traffic_bytes"] for k, v in out.items() if isinstance(v, dict)
    )
    return out


def _memory_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    keys = (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "alias_size_in_bytes",
        "generated_code_size_in_bytes", "peak_memory_in_bytes",
    )
    out = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    if out and "peak_memory_in_bytes" not in out:
        out["peak_memory_in_bytes"] = (
            out.get("argument_size_in_bytes", 0)
            - out.get("alias_size_in_bytes", 0)
            + out.get("output_size_in_bytes", 0)
            + out.get("temp_size_in_bytes", 0)
        )
    return out


def _compile_cell(cell, mesh):
    import jax

    from repro.launch.mesh import set_mesh

    jitted = jax.jit(
        cell.step,
        in_shardings=cell.in_shardings(mesh),
        out_shardings=cell.out_shardings(mesh),
        donate_argnums=cell.donate,
    )
    with set_mesh(mesh):
        lowered = jitted.lower(*cell.args)
        compiled = lowered.compile()
    return compiled


def _measure(compiled):
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # jax < 0.6 returns [dict]
        cost = cost[0] if cost else {}
    cost = dict(cost)
    cost = {k: float(v) for k, v in cost.items()
            if isinstance(v, (int, float)) and k in
            ("flops", "bytes accessed", "transcendentals", "optimal_seconds")}
    coll = parse_collectives(compiled.as_text())
    return cost, coll


def run_cell(arch: str, shape: str, mesh_kind: str,
             variant: str = "base") -> dict:
    import jax

    from repro.launch import mesh as mesh_lib
    from repro.launch.steps import build_cell, needs_probe, probe_trip_count

    mesh = mesh_lib.make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    t0 = time.time()
    cell = build_cell(arch, shape, mesh, variant=variant)
    t_build = time.time() - t0
    compiled = _compile_cell(cell, mesh)
    t_compile = time.time() - t0 - t_build
    t_lower = t_build

    cost, coll = _measure(compiled)
    mem = _memory_dict(compiled)

    flops_dev = cost.get("flops", 0.0)
    bytes_dev = cost.get("bytes accessed", 0.0)
    coll_dev = coll["total_traffic_bytes"]
    probe = None

    if needs_probe(arch):
        # XLA cost analysis counts the layer-scan body once; probe with 1 and
        # 2 UNROLLED layers and extrapolate: F(L) = F1 + (L-1) * (F2 - F1).
        L = probe_trip_count(arch)
        c1, k1 = _measure(_compile_cell(build_cell(arch, shape, mesh, 1), mesh))
        c2, k2 = _measure(_compile_cell(build_cell(arch, shape, mesh, 2), mesh))

        def extr(a1, a2):
            return max(a1, a1 + (L - 1) * (a2 - a1))

        flops_dev = extr(c1.get("flops", 0.0), c2.get("flops", 0.0))
        bytes_dev = extr(c1.get("bytes accessed", 0.0),
                         c2.get("bytes accessed", 0.0))
        coll_dev = extr(k1["total_traffic_bytes"], k2["total_traffic_bytes"])
        probe = dict(
            n_layers=L,
            probe1=dict(flops=c1.get("flops"), bytes=c1.get("bytes accessed"),
                        coll=k1["total_traffic_bytes"]),
            probe2=dict(flops=c2.get("flops"), bytes=c2.get("bytes accessed"),
                        coll=k2["total_traffic_bytes"]),
            corrected=dict(flops=flops_dev, bytes=bytes_dev, coll=coll_dev),
        )
    elif arch == "pdasc" and shape.startswith("build"):
        # MSA build runs PAM inside fori/while loops (bodies counted once);
        # use the analytic distance-matrix count (meta) as the compute term.
        flops_dev = float(cell.meta["model_flops"]) / n_chips
        probe = dict(analytic=True)
    compute_s = flops_dev / mesh_lib.PEAK_FLOPS_BF16
    memory_s = bytes_dev / mesh_lib.HBM_BW
    collective_s = coll_dev / mesh_lib.ICI_BW
    terms = dict(compute_s=compute_s, memory_s=memory_s,
                 collective_s=collective_s)
    bottleneck = max(terms, key=terms.get)

    model_flops = float(cell.meta.get("model_flops", 0.0))
    hlo_flops_total = flops_dev * n_chips
    result = dict(
        arch=arch, shape=shape, mesh=mesh_kind, kind=cell.kind,
        n_chips=int(n_chips),
        ok=True,
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        cost_analysis=cost,
        memory_analysis=mem,
        collectives=coll,
        probe=probe,
        roofline=dict(
            **{k: float(v) for k, v in terms.items()},
            bottleneck=bottleneck,
            model_flops=model_flops,
            hlo_flops_per_device=flops_dev,
            hlo_flops_total=hlo_flops_total,
            useful_flops_ratio=(model_flops / hlo_flops_total
                                if hlo_flops_total else None),
            step_time_lower_bound_s=max(terms.values()),
        ),
        meta={k: (float(v) if isinstance(v, (int, float)) else v)
              for k, v in cell.meta.items()},
    )
    return result


def main():
    args = _parse_args()
    import jax  # after XLA_FLAGS

    from repro.configs import all_cells

    if args.list:
        for a, s in all_cells():
            print(f"{a:24s} {s}")
        return

    cells = all_cells()
    if args.arch:
        cells = [(a, s) for a, s in cells if a == args.arch]
    if args.shape:
        cells = [(a, s) for a, s in cells if s == args.shape]
    if not cells:
        raise SystemExit("no matching cells")
    meshes = (["single", "multi"] if args.mesh == "both" else [args.mesh])

    os.makedirs(args.out, exist_ok=True)
    n_ok = n_fail = 0
    suffix = "" if args.variant == "base" else f"__{args.variant}"
    for arch, shape in cells:
        for mk in meshes:
            path = os.path.join(args.out, f"{arch}__{shape}__{mk}{suffix}.json")
            if args.skip_existing and os.path.exists(path):
                print(f"[skip] {arch} x {shape} x {mk}")
                continue
            print(f"[dryrun] {arch} x {shape} x {mk} ...", flush=True)
            try:
                res = run_cell(arch, shape, mk, variant=args.variant)
                n_ok += 1
                r = res["roofline"]
                print(
                    f"  ok: compile={res['compile_s']:.1f}s "
                    f"flops/dev={res['cost_analysis'].get('flops', 0):.3e} "
                    f"bottleneck={r['bottleneck']} "
                    f"lb={r['step_time_lower_bound_s']*1e3:.2f}ms",
                    flush=True,
                )
                if res["memory_analysis"]:
                    print("  memory:", json.dumps(res["memory_analysis"]))
            except Exception as e:
                n_fail += 1
                res = dict(arch=arch, shape=shape, mesh=mk, ok=False,
                           error=f"{type(e).__name__}: {e}",
                           traceback=traceback.format_exc()[-4000:])
                print(f"  FAIL: {type(e).__name__}: {e}", flush=True)
            with open(path, "w") as f:
                json.dump(res, f, indent=1)
    print(f"\ndry-run complete: {n_ok} ok, {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
