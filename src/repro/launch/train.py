"""Training driver.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
        --steps 200 --batch 8 --seq 256 --mesh 1x1 --ckpt /tmp/run1

Wires together: config registry -> model step (launch.steps semantics at
reduced scale) -> stateless data pipeline -> fault-tolerant train loop with
checkpoint/restart. ``--smoke`` uses the arch's reduced config so the whole
thing runs on CPU (the examples and integration tests drive this path).

``--heartbeat <sec>`` demonstrates the straggler/failure policy: the loop
touches a heartbeat file every step; the (external) supervisor relaunches
the rank when the file goes stale — restart resumes from ``latest`` with an
identical data stream (stateless pipeline), so a recomputed step is bitwise
the step the dead rank would have produced.
"""

from __future__ import annotations

import argparse
import dataclasses
import functools
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.data import lm_tokens, recsys_batch
from repro.launch.mesh import batch_axes_of, make_mesh, set_mesh
from repro.models import recsys as rec_lib
from repro.models import transformer as tfm
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.train import TrainLoopConfig, train_loop


def _parse():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=256)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--mesh", default="1x1", help="DATAxMODEL, e.g. 2x4")
    p.add_argument("--ckpt", default=None)
    p.add_argument("--ckpt-every", type=int, default=50)
    p.add_argument("--smoke", action="store_true",
                   help="use the arch's reduced config (CPU-friendly)")
    p.add_argument("--heartbeat", default=None,
                   help="path to touch every step (supervisor watchdog)")
    p.add_argument("--seed", type=int, default=0)
    return p.parse_args()


def main():
    args = _parse()
    arch = get_arch(args.arch)
    cfg = arch.smoke_fn() if args.smoke else arch.config_fn()
    dshape = tuple(int(x) for x in args.mesh.split("x"))
    mesh = make_mesh(dshape, ("data", "model")) if np.prod(dshape) > 1 else None
    bA = ("data",) if mesh is not None else ()
    ocfg = AdamWConfig(lr=args.lr, total_steps=args.steps)
    key = jax.random.PRNGKey(args.seed)

    if arch.family == "lm":
        sh = tfm.ShardingConfig(batch_axes=bA or ("data",))
        params = tfm.init_params(cfg, key)
        loss_fn = lambda p, b: tfm.loss_fn(p, b, cfg, sh, mesh)
        make_batch = lambda s: jax.tree.map(
            jnp.asarray, lm_tokens(s, args.batch, args.seq, cfg.vocab,
                                   seed=args.seed))
    elif arch.family == "recsys":
        params = rec_lib.init_params(cfg, key)
        loss_fn = lambda p, b: rec_lib.loss_fn(p, b, cfg)
        make_batch = lambda s: jax.tree.map(
            jnp.asarray, recsys_batch(s, args.batch, cfg, seed=args.seed))
    else:
        raise SystemExit(f"launch.train drives lm/recsys archs; "
                         f"{args.arch} is {arch.family} — see examples/")

    opt_state = adamw_init(params)

    @jax.jit
    def step_fn(params, opt_state, batch):
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        new_p, new_o, m = adamw_update(grads, opt_state, params, ocfg)
        return new_p, new_o, {"loss": loss, **m}

    hb = args.heartbeat

    def log_fn(step, msg):
        print(f"[train] {msg}", flush=True)

    def make_batch_hb(s):
        if hb:
            with open(hb, "w") as f:
                f.write(str(time.time()))
        return make_batch(s)

    tl_cfg = TrainLoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt,
                             ckpt_every=args.ckpt_every)
    ctx = set_mesh(mesh) if mesh is not None else _null()
    with ctx:
        params, opt_state, hist = train_loop(
            step_fn, params, opt_state, make_batch_hb, tl_cfg, log_fn=log_fn
        )
    if hist:
        print(f"[train] done: step {hist[-1][0]} loss {hist[-1][1]:.4f} "
              f"(first {hist[0][1]:.4f})")


class _null:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()
