"""Serving driver: PDASC ANN search behind the batching engine.

    PYTHONPATH=src python -m repro.launch.serve --dataset dense_embed \
        --n 20000 --gl 256 --distance cosine --queries 512 --batch 64

Builds (or loads) a PDASC index, wraps the distributed NSA search in
``repro.serving.BatchingEngine`` (fixed compiled batch, max-wait batching),
fires synthetic query traffic at it, and reports latency percentiles +
recall against exact ground truth.
"""

from __future__ import annotations

import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.core.index import PDASCIndex
from repro.data import make_dataset
from repro.kernels.ops import KernelConfig, knn
from repro.serving import BatchingEngine


def _parse():
    p = argparse.ArgumentParser()
    p.add_argument("--dataset", default="dense_embed")
    p.add_argument("--n", type=int, default=20000)
    p.add_argument("--gl", type=int, default=256)
    p.add_argument("--distance", default="euclidean")
    p.add_argument("--k", type=int, default=10)
    p.add_argument("--queries", type=int, default=256)
    p.add_argument("--batch", type=int, default=32)
    p.add_argument("--max-wait-ms", type=float, default=4.0)
    p.add_argument("--radius-quantile", type=float, default=0.5)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--mode", default="beam",
                   choices=["beam", "dense", "beam_vmap", "two_stage"])
    p.add_argument("--beam", type=int, default=32)
    # Storage substrate (DESIGN.md §3.6): mode=two_stage serves from the
    # tiered leaf store — quantised payload resident, exact fp32 out of core
    # (memmapped at --store-path if given), dense leaf array released.
    p.add_argument("--store", default="int8", choices=["int8", "fp16"])
    p.add_argument("--store-block", type=int, default=1024)
    p.add_argument("--store-path", default=None)
    p.add_argument("--rerank-width", type=int, default=128)
    # Kernel-layer block knobs (forwarded as a KernelConfig to the search).
    kd = KernelConfig()
    p.add_argument("--bm", type=int, default=kd.bm)
    p.add_argument("--bn", type=int, default=kd.bn)
    p.add_argument("--bd", type=int, default=kd.bd)
    p.add_argument("--bq", type=int, default=kd.bq)
    p.add_argument("--row-chunk", type=int, default=kd.row_chunk)
    return p.parse_args()


def main():
    args = _parse()
    data = make_dataset(args.dataset, n=args.n, seed=args.seed)
    n_train = int(args.n * 0.95)
    train, test = data[:n_train], data[n_train:]
    print(f"[serve] building PDASC index on {train.shape} "
          f"({args.distance}, gl={args.gl})", flush=True)
    t0 = time.time()
    store_kw = {}
    if args.mode == "two_stage":
        store_kw = dict(store=args.store, store_block=args.store_block,
                        store_path=args.store_path)
    idx = PDASCIndex.build(train, gl=args.gl, distance=args.distance,
                           radius_quantile=args.radius_quantile, **store_kw)
    if args.mode == "two_stage":
        idx.release_dense_payload()  # serve within the tiered memory budget
    print(f"[serve] built in {time.time()-t0:.1f}s\n{idx.describe()}")
    print(f"[serve] memory: {idx.memory_bytes()}")

    kernel = KernelConfig(bm=args.bm, bn=args.bn, bd=args.bd, bq=args.bq,
                          row_chunk=args.row_chunk)

    def handler(batch, n_valid):
        res = idx.search(jnp.asarray(batch), k=args.k, mode=args.mode,
                         beam=args.beam, rerank_width=args.rerank_width,
                         kernel=kernel)
        return res.dists, res.ids

    prefetch_fn = None
    if args.mode == "two_stage" and idx.store.exact.on_disk:
        from repro.core import nsa

        def prefetch_fn(payloads):
            # Between-batch granule warming: run the (cheap, jitted) descent
            # for the queued queries and prefetch their candidate granules —
            # a superset of the rows the next batch's rerank will fetch.
            # Padded to the compiled batch size so no new executable compiles.
            rows = np.stack(payloads[:args.batch])
            pad = args.batch - len(rows)
            if pad:
                rows = np.concatenate([rows, np.repeat(rows[-1:], pad, 0)])
            ci, _ = nsa.descend_beam(
                idx.data, jnp.asarray(rows), dist=idx.distance,
                r=idx.default_radius, beam=args.beam,
                max_children=idx.max_children, kernel=kernel,
            )
            idx.store.prefetch_rows(np.asarray(ci[:len(payloads)]))

    engine = BatchingEngine(handler, batch_size=args.batch,
                            max_wait_ms=args.max_wait_ms,
                            pad_payload=np.zeros(train.shape[1], np.float32),
                            prefetch_fn=prefetch_fn)
    # warmup compile
    engine.submit(test[0]).wait(timeout=120)

    rng = np.random.default_rng(args.seed)
    q_rows = rng.integers(0, len(test), args.queries)
    lat, results = [], []
    for i in q_rows:
        t0 = time.time()
        req = engine.submit(test[i])
        _, ids = req.wait(timeout=60)
        lat.append(time.time() - t0)
        results.append(ids)
    engine.close()

    # recall vs exact
    _, gt = knn(jnp.asarray(test[q_rows]), jnp.asarray(train),
                args.distance, k=args.k)
    gt = np.asarray(gt)
    rec = np.mean([
        len(set(r[r >= 0]) & set(g)) / args.k for r, g in zip(results, gt)
    ])
    lat = np.array(lat) * 1e3
    print(f"[serve] {args.queries} queries: recall@{args.k}={rec:.3f} "
          f"p50={np.percentile(lat, 50):.1f}ms p99={np.percentile(lat, 99):.1f}ms "
          f"mean_batch_occupancy={engine.mean_occupancy:.2f}")


if __name__ == "__main__":
    main()
