"""Serving driver: PDASC ANN search behind the batching engine.

    PYTHONPATH=src python -m repro.launch.serve --dataset dense_embed \
        --n 20000 --gl 256 --distance cosine --queries 512 --batch 64

Builds (or loads) a PDASC index, wraps the distributed NSA search in
``repro.serving.BatchingEngine`` (fixed compiled batch, max-wait batching),
fires synthetic query traffic at it, and reports latency percentiles +
recall against exact ground truth.

``--churn N`` interleaves N live writes (upserts + deletes through
``submit_upsert`` / ``submit_delete``) into the query stream — the online
substrate demo (DESIGN.md §3.7): writes apply between batches via an
``online.EpochHandle``, compaction swaps epochs under traffic, and the
final recall is measured against exact ground truth over the *post-churn*
live point set.

``--replicas N`` (N > 1) serves through the replicated fault-tolerant tier
instead (DESIGN.md §3.10): N replicas behind the retry/hedge/backoff
``Router``, writes fanned out through the shared write log. ``--faults``
takes a deterministic fault plan (``kind:rR@START+DURATION[:DELAY]``,
``;``-separated — e.g. ``"wedge:r1@20+8;error:r2@40+5"``) injected into the
replica batch handlers; the run reports caller-visible errors (expected:
zero), retries, hedges and the health event log alongside the latency
percentiles.

Quality & SLO observability (DESIGN.md §3.12): ``--shadow-sample N``
re-answers 1 served query in N exactly on a background worker and prints
the online recall estimate (with its Wilson interval) at exit;
``--cost-log PATH`` appends one JSONL cost record per traced request
(requires ``--trace-sample``); ``--slo-p99-ms`` / ``--slo-recall-floor``
attach an SLO tracker with multi-rate burn alerts (replicated path);
``--dash`` renders a live terminal dashboard while serving; and
``--trace-dump PATH`` writes the retained sampled traces as JSON at exit
(both serve paths — feed it to ``python -m repro.obs.report``).
"""

from __future__ import annotations

import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.index import PDASCIndex
from repro.data import make_dataset
from repro.kernels.ops import KernelConfig, knn
from repro.online import EpochHandle, live_dataset
from repro.query import Query
from repro.serving import BatchingEngine, QueryHandler


def _parse():
    p = argparse.ArgumentParser()
    p.add_argument("--dataset", default="dense_embed")
    p.add_argument("--n", type=int, default=20000)
    p.add_argument("--gl", type=int, default=256)
    p.add_argument("--distance", default="euclidean")
    p.add_argument("--k", type=int, default=10)
    p.add_argument("--queries", type=int, default=256)
    p.add_argument("--batch", type=int, default=32)
    p.add_argument("--max-wait-ms", type=float, default=4.0)
    p.add_argument("--radius-quantile", type=float, default=0.5)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--mode", default="beam",
                   choices=["beam", "dense", "beam_vmap", "two_stage"])
    p.add_argument("--beam", type=int, default=32)
    # Storage substrate (DESIGN.md §3.6): mode=two_stage serves from the
    # tiered leaf store — quantised payload resident, exact fp32 out of core
    # (memmapped at --store-path if given), dense leaf array released.
    p.add_argument("--store", default="int8",
                   choices=["int8", "fp16", "remote"],
                   help="payload tier: int8/fp16 quantised resident codes "
                        "with a host/memmap exact tier, or 'remote' — int8 "
                        "codes resident, exact fp32 granules behind a "
                        "simulated object store (DESIGN.md §3.13)")
    p.add_argument("--store-block", type=int, default=1024)
    p.add_argument("--store-path", default=None)
    p.add_argument("--rerank-width", type=int, default=128)
    # Remote payload tier (DESIGN.md §3.13): the simulated object store's
    # performance envelope and the host LRU / prefetch pool in front of it.
    p.add_argument("--remote-latency-ms", type=float, default=0.0,
                   help="simulated object store per-op latency "
                        "(--store remote)")
    p.add_argument("--remote-bandwidth-mbps", type=float, default=None,
                   help="simulated object store transfer bandwidth "
                        "(--store remote; default: unlimited)")
    p.add_argument("--remote-cache-granules", type=int, default=256,
                   help="host LRU capacity in decoded granules "
                        "(--store remote)")
    p.add_argument("--remote-prefetch-workers", type=int, default=2,
                   help="async prefetch pool size (--store remote)")
    # Online substrate (DESIGN.md §3.7): interleave live writes with search
    # traffic; the EpochHandle compacts + swaps epochs between batches.
    p.add_argument("--churn", type=int, default=0,
                   help="number of upsert/delete writes interleaved into "
                        "the query stream (0 = frozen index)")
    p.add_argument("--churn-delete-frac", type=float, default=0.3)
    p.add_argument("--delta-capacity", type=int, default=1024)
    p.add_argument("--compact-delta-fill", type=float, default=0.5)
    p.add_argument("--compact-tombstone-ratio", type=float, default=0.2)
    # Replicated serving tier (DESIGN.md §3.10).
    p.add_argument("--replicas", type=int, default=1,
                   help="serve through N replicas behind the fault-tolerant "
                        "router (1 = the single-engine path)")
    p.add_argument("--faults", default=None,
                   help="deterministic fault plan, e.g. "
                        "'wedge:r1@20+8;error:r2@40+5' "
                        "(kind:rR@START+DURATION[:DELAY_S], kinds: "
                        "latency/error/wedge/crash; windows in per-replica "
                        "handler dispatches)")
    p.add_argument("--deadline-ms", type=float, default=2000.0,
                   help="router per-request deadline (replicated path)")
    # Telemetry (DESIGN.md §3.11).
    p.add_argument("--metrics-dump", default=None, metavar="PATH",
                   help="periodically dump the repro.obs metrics snapshot "
                        "to PATH ('-' = stdout at exit; .prom extension = "
                        "Prometheus text, anything else JSON)")
    p.add_argument("--trace-sample", type=int, default=0, metavar="N",
                   help="trace 1 request in N (deterministic by request "
                        "seq; 0 = off) and print the slowest sampled "
                        "trace as a text flamegraph at exit")
    p.add_argument("--trace-dump", default=None, metavar="PATH",
                   help="write every retained sampled trace as JSON to "
                        "PATH at exit (needs --trace-sample; readable by "
                        "python -m repro.obs.report --trace PATH)")
    # Quality & SLO observability (DESIGN.md §3.12).
    p.add_argument("--shadow-sample", type=int, default=0, metavar="N",
                   help="shadow-sample 1 served query in N and re-answer "
                        "it exactly off the hot path; prints the online "
                        "recall estimate with its Wilson interval at exit "
                        "(0 = off)")
    p.add_argument("--cost-log", default=None, metavar="PATH",
                   help="append one JSONL plan-cost record per traced "
                        "request to PATH (needs --trace-sample)")
    p.add_argument("--slo-p99-ms", type=float, default=None,
                   help="SLO latency target: at most 1%% of requests may "
                        "take longer (replicated path)")
    p.add_argument("--slo-recall-floor", type=float, default=None,
                   help="SLO recall floor for shadow-sampled estimates "
                        "(needs --shadow-sample; replicated path)")
    p.add_argument("--slo-window-s", type=float, default=30.0,
                   help="SLO rolling-window length in seconds")
    p.add_argument("--dash", action="store_true",
                   help="render a live terminal dashboard (QPS, latency, "
                        "recall estimate, SLO budget, replica health) "
                        "while serving")
    # Kernel-layer block knobs (forwarded as a KernelConfig to the search).
    kd = KernelConfig()
    p.add_argument("--bm", type=int, default=kd.bm)
    p.add_argument("--bn", type=int, default=kd.bn)
    p.add_argument("--bd", type=int, default=kd.bd)
    p.add_argument("--bq", type=int, default=kd.bq)
    p.add_argument("--row-chunk", type=int, default=kd.row_chunk)
    return p.parse_args()


def _serve_replicated(args, idx, kernel, train, test):
    """The --replicas path: N replicas behind the fault-tolerant router."""
    from repro.query import degraded
    from repro.serving import FaultPlan, ReplicaSet, Router, RouterConfig

    query = Query(k=args.k, execution=args.mode, beam=args.beam,
                  rerank_width=args.rerank_width, with_stats=False,
                  kernel=kernel)
    plan = FaultPlan.parse(args.faults) if args.faults else None
    replica_set = ReplicaSet(
        idx, query, n_replicas=args.replicas, batch_size=args.batch,
        max_wait_ms=args.max_wait_ms, degraded_query=degraded(query),
        fault_plan=plan, delta_capacity=args.delta_capacity,
        epoch_kwargs=dict(delta_fill=args.compact_delta_fill,
                          tombstone_ratio=args.compact_tombstone_ratio),
    )
    slo = None
    if args.slo_p99_ms is not None or args.slo_recall_floor is not None:
        slo = obs.SLOTracker(obs.SLOSpec(
            latency_p99_s=(args.slo_p99_ms / 1e3
                           if args.slo_p99_ms is not None else None),
            recall_floor=args.slo_recall_floor,
            window_s=args.slo_window_s,
        ))
    costlog = obs.CostLog(args.cost_log) if args.cost_log else None
    router = Router(replica_set, RouterConfig(
        deadline_s=args.deadline_ms / 1e3, seed=args.seed,
        trace_every=args.trace_sample, shadow_every=args.shadow_sample),
        slo=slo, costlog=costlog)
    print(f"[serve] replicated tier: {args.replicas} replicas"
          + (f", faults={args.faults}" if plan else ", fault-free"))
    router.search(test[0])  # warmup compile (every replica shares the jits)
    dash = None
    if args.dash:
        dash = obs.Dashboard(quality=router.quality, slo=slo, router=router)

    rng = np.random.default_rng(args.seed)
    q_rows = rng.integers(0, len(test), args.queries)
    write_every = (args.queries // args.churn) if args.churn else 0
    upserted: list[int] = []
    lat, errors, retries, hedges, degraded_n = [], 0, 0, 0, 0
    for j, i in enumerate(q_rows):
        if write_every and j % write_every == 0 and j // write_every < \
                args.churn:
            if upserted and rng.random() < args.churn_delete_frac:
                replica_set.delete(
                    np.array([upserted.pop(rng.integers(len(upserted)))]))
            else:
                vec = train[rng.integers(len(train))] + rng.normal(
                    0, 0.01, train.shape[1]).astype(np.float32)
                upserted.extend(int(x) for x in replica_set.upsert(vec))
        t0 = time.time()
        try:
            res = router.search(test[i])
        except Exception as e:  # noqa: BLE001 — counted, run continues
            errors += 1
            print(f"[serve] query {j} failed: {type(e).__name__}: {e}")
            continue
        lat.append(time.time() - t0)
        retries += res.retries
        hedges += int(res.hedged)
        degraded_n += int(res.degraded)
    est = None
    if router.quality is not None:
        router.quality.drain()
        est = router.quality.estimate()
    if slo is not None:
        slo.evaluate()
    if dash is not None:
        dash.close()
    if args.trace_dump:
        with open(args.trace_dump, "w") as f:
            f.write(router.traces.to_json(indent=1))
        print(f"[serve] wrote {len(router.traces)} traces "
              f"to {args.trace_dump}")
    router.close(close_replicas=True)

    lat_ms = np.array(lat) * 1e3
    counts = router.event_counts()
    print(f"[serve] {args.queries} queries over {args.replicas} replicas: "
          f"errors={errors} p50={np.percentile(lat_ms, 50):.1f}ms "
          f"p99={np.percentile(lat_ms, 99):.1f}ms "
          f"retries={retries} hedges={hedges} degraded={degraded_n}")
    print(f"[serve] health events: {counts or '{}'}")
    if est is not None:
        rec = est["recall"]
        print(f"[serve] online recall estimate: "
              + (f"{rec:.3f} [{est['wilson_lo']:.3f}, "
                 f"{est['wilson_hi']:.3f}] over {est['queries']} shadow "
                 f"samples" if rec is not None else "no samples answered"))
    if slo is not None:
        print(f"[serve] SLO status: {slo.status()}")
        for ev in slo.events():
            print(f"[serve]   slo event: {ev}")
    if costlog is not None:
        costlog.close()
        print(f"[serve] wrote {len(costlog)} cost records "
              f"to {args.cost_log}")
    if args.trace_sample:
        ex = router.traces.exemplar()
        if ex is not None:
            print(f"[serve] slowest sampled trace "
                  f"({len(router.traces)} retained):")
            print(ex.render())


def main():
    args = _parse()
    # Periodic metrics dumper (DESIGN.md §3.11): rewrites PATH whole every
    # few seconds while serving; closed (with a final snapshot) at exit.
    dumper = None
    if args.metrics_dump:
        dumper = obs.MetricsDumper(obs.registry(), args.metrics_dump,
                                   period_s=5.0)
    data = make_dataset(args.dataset, n=args.n, seed=args.seed)
    n_train = int(args.n * 0.95)
    train, test = data[:n_train], data[n_train:]
    print(f"[serve] building PDASC index on {train.shape} "
          f"({args.distance}, gl={args.gl})", flush=True)
    t0 = time.time()
    store_kw = {}
    remote = args.mode == "two_stage" and args.store == "remote"
    if args.mode == "two_stage":
        # --store remote keeps int8 codes resident; the exact tier moves to
        # the object store after the build (make_remote below)
        store_kw = dict(store="int8" if remote else args.store,
                        store_block=args.store_block,
                        store_path=None if remote else args.store_path)
    idx = PDASCIndex.build(train, gl=args.gl, distance=args.distance,
                           radius_quantile=args.radius_quantile, **store_kw)
    if remote:
        from repro.store import SimulatedObjectStore, make_remote

        obj = SimulatedObjectStore(
            latency_ms=args.remote_latency_ms,
            bandwidth_mbps=args.remote_bandwidth_mbps,
        )
        make_remote(idx, obj,
                    cache_granules=args.remote_cache_granules,
                    prefetch_workers=args.remote_prefetch_workers)
        print(f"[serve] remote exact tier: {obj.total_bytes} bytes in "
              f"object store, latency={args.remote_latency_ms}ms, "
              f"host cache={args.remote_cache_granules} granules")
    elif args.mode == "two_stage":
        idx.release_dense_payload()  # serve within the tiered memory budget
    print(f"[serve] built in {time.time()-t0:.1f}s\n{idx.describe()}")
    print(f"[serve] memory: {idx.memory_bytes()}")

    kernel = KernelConfig(bm=args.bm, bn=args.bn, bd=args.bd, bq=args.bq,
                          row_chunk=args.row_chunk)

    if args.replicas > 1:
        try:
            _serve_replicated(args, idx, kernel, train, test)
        finally:
            if dumper is not None:
                dumper.close()
        return

    handle = None
    if args.churn > 0:
        idx.enable_mutations(delta_capacity=args.delta_capacity)
        handle = EpochHandle(
            idx, delta_fill=args.compact_delta_fill,
            tombstone_ratio=args.compact_tombstone_ratio,
        )

    # The declarative surface (DESIGN.md §3.8): the whole serving config is
    # one Query; the engine handler resolves the epoch snapshot per batch
    # and reuses the cached plan until the capability fingerprint changes.
    query = Query(k=args.k, execution=args.mode, beam=args.beam,
                  rerank_width=args.rerank_width, kernel=kernel)
    handler = QueryHandler(handle if handle is not None else idx, query)
    print(f"[serve] plan:\n{handler.plan().explain()}")

    prefetch_fn = None
    if args.mode == "two_stage" and idx.store.exact.wants_prefetch:
        from repro.core import nsa

        def prefetch_fn(payloads):
            # Between-batch granule warming: run the (cheap, jitted) descent
            # for the queued queries and prefetch their candidate granules —
            # a superset of the rows the next batch's rerank will fetch.
            # Padded to the compiled batch size so no new executable compiles.
            cur = handle.current if handle is not None else idx
            rows = np.stack(payloads[:args.batch])
            pad = args.batch - len(rows)
            if pad:
                rows = np.concatenate([rows, np.repeat(rows[-1:], pad, 0)])
            ci, _ = nsa.descend_beam(
                cur.data, jnp.asarray(rows), dist=cur.distance,
                r=cur.default_radius, beam=args.beam,
                max_children=cur.max_children, kernel=kernel,
            )
            # async handle: the engine's prefetch thread waits on it with a
            # bounded timeout (overlaps the current batch's handler call)
            return cur.store.prefetch_rows_async(
                np.asarray(ci[:len(payloads)]))

    engine = BatchingEngine(
        handler, batch_size=args.batch, max_wait_ms=args.max_wait_ms,
        pad_payload=np.zeros(train.shape[1], np.float32),
        prefetch_fn=prefetch_fn,
        write_handler=handle.apply_writes if handle is not None else None,
    )
    # warmup compile
    engine.submit(test[0]).wait(timeout=120)

    # Deterministic 1-in-N tracing on the single-engine path: the Trace is
    # created at submit time (there is no router in front), the engine
    # records queue/batch/execute spans under its root.
    sampler = obs.TraceSampler(args.trace_sample)
    # Shadow recall estimation + cost recording (DESIGN.md §3.12): no
    # router here, so the driver feeds both directly from the query loop.
    est = None
    if args.shadow_sample:
        est = obs.RecallEstimator(handle if handle is not None else idx,
                                  every_n=args.shadow_sample)
    costlog = obs.CostLog(args.cost_log) if args.cost_log else None
    dash = obs.Dashboard(quality=est) if args.dash else None

    rng = np.random.default_rng(args.seed)
    q_rows = rng.integers(0, len(test), args.queries)
    # writes interleave only with the head of the stream: the tail quarter
    # is scored against the final live set, so it must see no further
    # mutations (and at most one write per head query slot)
    tail = max(args.queries // 4, 1)
    head = args.queries - tail
    churn = min(args.churn, head)
    if churn < args.churn:
        print(f"[serve] clamping --churn {args.churn} -> {churn} "
              f"(one write per query slot ahead of the scored tail)")
    write_every = (head // churn) if churn else 0
    upserted_ids: list[int] = []
    lat, results = [], []
    for j, i in enumerate(q_rows):
        if (write_every and j < head and j % write_every == 0
                and j // write_every < churn):
            # interleave one write: mostly upserts (train-like vectors),
            # a fraction deletes of previously upserted ids
            if upserted_ids and rng.random() < args.churn_delete_frac:
                victim = upserted_ids.pop(rng.integers(len(upserted_ids)))
                # wait like the upsert path does: a dropped write error here
                # would silently leave the victim live while still counting
                # in the writes stat
                engine.submit_delete(np.array([victim])).wait(timeout=60)
            else:
                vec = train[rng.integers(len(train))] + rng.normal(
                    0, 0.01, train.shape[1]).astype(np.float32)
                req_w = engine.submit_upsert(vec)
                upserted_ids.extend(int(x) for x in req_w.wait(timeout=60))
        tr = sampler.sample("request", j, kind="search")
        t0 = time.time()
        req = engine.submit(test[i], span=tr.root if tr else None)
        _, ids = req.wait(timeout=60)
        lat.append(time.time() - t0)
        results.append(ids)
        if est is not None and est.should_sample(j):
            est.observe(j, test[i], ids,
                        pipeline=handler.describe()["effective_pipeline"])
        if tr is not None:
            tr.finish(outcome="ok")
            if costlog is not None:
                costlog.record(tr, handler.describe())
    engine.close()
    if dash is not None:
        dash.close()

    # recall vs exact — over the *live* post-churn point set when churning
    if handle is not None:
        base_vecs, base_ids = live_dataset(handle.current)
    else:
        base_vecs, base_ids = train, np.arange(len(train))
    _, gt = knn(jnp.asarray(test[q_rows]), jnp.asarray(base_vecs),
                args.distance, k=args.k)
    gt = base_ids[np.asarray(gt)]
    lat = np.array(lat) * 1e3
    if handle is not None:
        # churned stream: score recall on the tail queries — all writes were
        # scheduled ahead of the tail, so these really were served against
        # the final live set the ground truth was computed over
        pairs = list(zip(results[-tail:], gt[-tail:]))
    else:
        pairs = list(zip(results, gt))
    rec = np.mean([
        len(set(r[r >= 0]) & set(g)) / args.k for r, g in pairs
    ])
    line = (f"[serve] {args.queries} queries: recall@{args.k}={rec:.3f} "
            f"p50={np.percentile(lat, 50):.1f}ms "
            f"p99={np.percentile(lat, 99):.1f}ms "
            f"mean_batch_occupancy={engine.mean_occupancy:.2f}")
    if handle is not None:
        line += (f" writes={engine.stats['writes']} "
                 f"epoch_swaps={handle.swaps} "
                 f"epoch={handle.current.epoch}")
    print(line)
    if est is not None:
        est.drain()
        e = est.estimate()
        print(f"[serve] online recall estimate: "
              + (f"{e['recall']:.3f} [{e['wilson_lo']:.3f}, "
                 f"{e['wilson_hi']:.3f}] over {e['queries']} shadow "
                 f"samples" if e["recall"] is not None
                 else "no samples answered"))
        est.close()
    if costlog is not None:
        costlog.close()
        print(f"[serve] wrote {len(costlog)} cost records "
              f"to {args.cost_log}")
    if args.trace_sample:
        ex = sampler.buffer.exemplar()
        if ex is not None:
            print(f"[serve] slowest sampled trace "
                  f"({len(sampler.buffer)} retained):")
            print(ex.render())
    if args.trace_dump:
        with open(args.trace_dump, "w") as f:
            f.write(sampler.buffer.to_json(indent=1))
        print(f"[serve] wrote {len(sampler.buffer)} traces "
              f"to {args.trace_dump}")
    if dumper is not None:
        dumper.close()


if __name__ == "__main__":
    main()
