"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never touches
jax device state (device count is locked on first jax init; the dry-run needs
to set XLA_FLAGS first).

Axes:
  pod    — slow inter-pod links (DCN); gradient sync / pod-DP / PDASC merge
  data   — intra-pod DP + FSDP shard axis + PDASC database shards
  model  — TP (heads/ffn/vocab), EP (experts), sequence sharding for decode,
           embedding-table rows (recsys), PDASC query fan-out
"""

from __future__ import annotations

import contextlib

import jax

# jax >= 0.6 exposes explicit axis types; on older jax every mesh axis is
# implicitly Auto, so the kwarg is simply omitted.
_AXIS_TYPE = getattr(jax.sharding, "AxisType", None)


def _axis_types_kw(n_axes: int) -> dict:
    if _AXIS_TYPE is None:
        return {}
    return {"axis_types": (_AXIS_TYPE.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devs = jax.devices()
    if len(devs) == n:
        return jax.make_mesh(shape, axes, **_axis_types_kw(len(axes)))
    # The dry-run process holds 512 placeholder devices; the single-pod mesh
    # uses the first 256.
    from jax.experimental import mesh_utils

    dm = mesh_utils.create_device_mesh(shape, devices=devs[:n])
    return jax.sharding.Mesh(dm, axes, **_axis_types_kw(len(axes)))


def make_mesh(shape, axes):
    """Arbitrary mesh (tests / small-device runs)."""
    return jax.make_mesh(tuple(shape), tuple(axes), **_axis_types_kw(len(axes)))


def set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    ``jax.set_mesh`` where available (jax >= 0.6); on older jax nothing needs
    installing (shard_map receives the mesh explicitly), so this is a no-op
    context.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return contextlib.nullcontext(mesh)


def batch_axes_of(mesh) -> tuple:
    """DP/FSDP axes: every axis except ``model``."""
    return tuple(a for a in mesh.axis_names if a != "model")


def all_axes_of(mesh) -> tuple:
    return tuple(mesh.axis_names)


# TPU v5e hardware constants for the roofline model (per chip).
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link (~4 links usable; we model 1-link worst case)
HBM_BYTES = 16 * 2 ** 30  # 16 GiB
