"""Cell builders: (arch x shape x mesh) -> a loweable step.

``build_cell`` returns a :class:`Cell` carrying the jitted-able step function,
its example arguments as ShapeDtypeStructs (never allocated — the dry-run
pattern), and in/out shardings. ``launch.dryrun`` lowers + compiles these;
``launch.train`` / ``launch.serve`` feed them real arrays.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_arch
from repro.configs.base import ShapeSpec
from repro.launch.mesh import all_axes_of, batch_axes_of
from repro.models import gnn as gnn_lib
from repro.models import recsys as rec_lib
from repro.models import transformer as tfm
from repro.models.graph_sampler import subgraph_budget
from repro.optim import adamw as opt_lib

SDS = jax.ShapeDtypeStruct
f32, bf16, i32 = jnp.float32, jnp.bfloat16, jnp.int32


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    kind: str
    step: Callable  # the function to jit
    args: tuple  # ShapeDtypeStructs (pytrees)
    in_specs: tuple  # PartitionSpec pytrees matching args
    out_specs: Any  # PartitionSpec pytrees (None = auto)
    donate: tuple = ()
    meta: dict = dataclasses.field(default_factory=dict)

    def in_shardings(self, mesh):
        return jax.tree.map(lambda s: NamedSharding(mesh, s), self.in_specs,
                            is_leaf=lambda x: isinstance(x, P))

    def out_shardings(self, mesh):
        if self.out_specs is None:
            return None
        return jax.tree.map(
            lambda s: NamedSharding(mesh, s) if isinstance(s, P) else None,
            self.out_specs,
            is_leaf=lambda x: isinstance(x, P) or x is None,
        )


def _ns_tree(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _opt_cfg(total_steps=10_000):
    return opt_lib.AdamWConfig(total_steps=total_steps)


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------


def _lm_flops_model(cfg: tfm.TransformerConfig, tokens: int, kind: str) -> float:
    """MODEL_FLOPS: 6*N_active*D for training, 2*N_active*D for inference."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * cfg.n_active_params() * tokens


def _lm_cell(arch_id: str, spec: ShapeSpec, mesh,
             probe_layers: Optional[int] = None) -> Cell:
    arch = get_arch(arch_id)
    cfg = arch.config_fn()
    if probe_layers is not None:
        # Roofline probe: 1-2 UNROLLED layers so XLA's cost analysis (which
        # counts while-loop bodies once) yields exact per-layer numbers.
        cfg = dataclasses.replace(
            cfg, n_layers=probe_layers, scan_layers=False, unroll_inner=True
        )
    bA = batch_axes_of(mesh)
    allA = all_axes_of(mesh)
    B = spec.dims["global_batch"]
    S = spec.dims["seq_len"]

    if spec.kind == "train":
        sh = tfm.ShardingConfig(batch_axes=bA)
        pshapes = tfm.param_shapes(cfg)
        pspecs = tfm.param_specs(cfg, sh)
        oshapes = opt_lib.opt_state_shapes(pshapes)
        ospecs = opt_lib.opt_state_specs(pspecs)
        ocfg = _opt_cfg()
        # Microbatch accumulation bounds activation memory (§Perf H1b);
        # probes run unaccumulated so per-step flop extrapolation is exact
        # (accumulation only re-reads params n_micro times).
        n_micro = 1 if probe_layers is not None else spec.dims.get("n_micro", 4)

        def step(params, opt_state, batch):
            def lfn(p, b):
                return tfm.loss_fn(p, b, cfg, sh, mesh)

            from repro.optim import accumulate_gradients

            loss, aux, grads = accumulate_gradients(
                lfn, params, batch, n_micro
            )
            new_p, new_o, m = opt_lib.adamw_update(grads, opt_state, params, ocfg)
            return new_p, new_o, {"loss": loss, **m}

        batch_sds = dict(tokens=SDS((B, S), i32), labels=SDS((B, S), i32))
        batch_spec = dict(tokens=P(sh.b, None), labels=P(sh.b, None))
        return Cell(
            arch_id, spec.name, "train", step,
            args=(pshapes, oshapes, batch_sds),
            in_specs=(pspecs, ospecs, batch_spec),
            out_specs=(pspecs, ospecs, None),
            donate=(0, 1),
            meta=dict(
                tokens=B * S,
                model_flops=_lm_flops_model(cfg, B * S, "train"),
                n_params=cfg.n_params(), n_active=cfg.n_active_params(),
            ),
        )

    if spec.kind == "prefill":
        sh = tfm.ShardingConfig(batch_axes=bA, cache_seq_axes=("model",),
                                cache_batch_axes=bA)
        pshapes = tfm.param_shapes(cfg)
        pspecs = tfm.param_specs(cfg, sh)

        def step(params, tokens):
            return tfm.prefill_step(params, tokens, cfg, sh, mesh)

        cspec = tfm.cache_specs(sh)
        return Cell(
            arch_id, spec.name, "prefill", step,
            args=(pshapes, SDS((B, S), i32)),
            in_specs=(pspecs, P(sh.b, None)),
            out_specs=(None, cspec),
            meta=dict(
                tokens=B * S,
                model_flops=_lm_flops_model(cfg, B * S, "prefill"),
                n_params=cfg.n_params(), n_active=cfg.n_active_params(),
            ),
        )

    # decode: decode_32k shards cache S over model; long_500k over every axis.
    if spec.name == "long_500k":
        sh = tfm.ShardingConfig(batch_axes=bA, cache_seq_axes=allA,
                                cache_batch_axes=())
    else:
        sh = tfm.ShardingConfig(batch_axes=bA, cache_seq_axes=("model",),
                                cache_batch_axes=bA)
    pshapes = tfm.param_shapes(cfg)
    pspecs = tfm.param_specs(cfg, sh)
    cshapes = tfm.cache_shapes(cfg, B, S)
    cspecs = tfm.cache_specs(sh)

    def step(params, cache, tokens, pos):
        logits, cache = tfm.decode_step(params, cache, tokens, pos, cfg, sh,
                                        mesh)
        next_tok = jnp.argmax(logits, axis=-1).astype(i32)[:, None]
        return next_tok, cache

    return Cell(
        arch_id, spec.name, "decode", step,
        args=(pshapes, cshapes, SDS((B, 1), i32), SDS((), i32)),
        in_specs=(pspecs, cspecs,
                  P(sh.cache_batch_axes or None, None), P()),
        out_specs=(None, cspecs),
        donate=(1,),
        meta=dict(
            tokens=B,
            model_flops=_lm_flops_model(cfg, B, "decode"),
            kv_bytes=2 * cfg.n_layers * B * S * cfg.n_kv_heads * cfg.hd * 2,
            n_params=cfg.n_params(), n_active=cfg.n_active_params(),
        ),
    )


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------


def _gnn_train_cell(arch_id, spec: ShapeSpec, mesh) -> Cell:
    from repro.configs import egnn as egnn_cfg_mod

    arch = get_arch(arch_id)
    cfg = egnn_cfg_mod.specialise(arch.config_fn(), spec.name)
    bA = batch_axes_of(mesh)
    allA = all_axes_of(mesh)
    b = bA if len(bA) > 1 else bA[0]

    pshapes = gnn_lib.param_shapes(cfg)
    pspecs = jax.tree.map(lambda _: P(), pshapes)
    oshapes = opt_lib.opt_state_shapes(pshapes)
    ospecs = opt_lib.opt_state_specs(pspecs)
    ocfg = _opt_cfg()

    if spec.name == "molecule":
        B, n, e = spec.dims["batch"], spec.dims["n_nodes"], spec.dims["n_edges"]
        batch_sds = dict(
            feats=SDS((B, n, cfg.d_feat), f32),
            coords=SDS((B, n, 3), f32),
            edges=SDS((B, 2, e), i32),
            targets=SDS((B,), f32),
        )
        batch_spec = dict(feats=P(b, None, None), coords=P(b, None, None),
                          edges=P(b, None, None), targets=P(b))
        lfn = lambda p, bt: gnn_lib.loss_fn(p, bt, cfg)
        n_edges_total = B * e
    elif spec.name == "minibatch_lg":
        G = spec.dims["n_subgraphs"]
        n_max, e_max = subgraph_budget(spec.dims["batch_nodes"],
                                       spec.dims["fanouts"])
        batch_sds = dict(
            feats=SDS((G, n_max, cfg.d_feat), f32),
            coords=SDS((G, n_max, 3), f32),
            edges=SDS((G, 2, e_max), i32),
            edge_mask=SDS((G, e_max), jnp.bool_),
            labels=SDS((G, n_max), i32),
            label_mask=SDS((G, n_max), jnp.bool_),
        )
        batch_spec = dict(
            feats=P(b, None, None), coords=P(b, None, None),
            edges=P(b, None, None), edge_mask=P(b, None),
            labels=P(b, None), label_mask=P(b, None),
        )

        def lfn(p, bt):
            def one(feats, coords, edges, edge_mask, labels, label_mask):
                return gnn_lib.node_class_loss(
                    p, dict(feats=feats, coords=coords, edges=edges,
                            edge_mask=edge_mask, labels=labels,
                            label_mask=label_mask), cfg)[0]

            losses = jax.vmap(one)(bt["feats"], bt["coords"], bt["edges"],
                                   bt["edge_mask"], bt["labels"],
                                   bt["label_mask"])
            return jnp.mean(losses), {}

        n_edges_total = G * e_max
    else:  # full_graph_sm / ogb_products: flat graph, edges sharded
        N = spec.dims["n_nodes"]
        Ep = spec.dims["n_edges_padded"]
        batch_sds = dict(
            feats=SDS((N, cfg.d_feat), f32),
            coords=SDS((N, 3), f32),
            edges=SDS((2, Ep), i32),
            edge_mask=SDS((Ep,), jnp.bool_),
            labels=SDS((N,), i32),
            label_mask=SDS((N,), jnp.bool_),
        )
        batch_spec = dict(
            feats=P(None, None), coords=P(None, None),
            edges=P(None, allA), edge_mask=P(allA),
            labels=P(None), label_mask=P(None),
        )
        lfn = lambda p, bt: gnn_lib.loss_fn(p, bt, cfg)
        n_edges_total = spec.dims["n_edges"]

    def step(params, opt_state, batch):
        (loss, _), grads = jax.value_and_grad(lfn, has_aux=True)(params, batch)
        new_p, new_o, m = opt_lib.adamw_update(grads, opt_state, params, ocfg)
        return new_p, new_o, {"loss": loss, **m}

    # MODEL_FLOPS per step ~ 6 * (edge MLP work + node MLP work).
    h = cfg.d_hidden
    per_edge = 2 * ((2 * h + 1) * h + h * h + h)  # phi_e + phi_x fwd
    per_node = 2 * (cfg.d_feat * h + 2 * h * h + h * h)
    n_nodes_total = spec.dims.get("n_nodes", 0) * spec.dims.get("batch", 1)
    model_flops = 3.0 * cfg.n_layers * (
        per_edge * n_edges_total + per_node * max(n_nodes_total, 1)
    )
    return Cell(
        arch_id, spec.name, "train", step,
        args=(pshapes, oshapes, batch_sds),
        in_specs=(pspecs, ospecs, batch_spec),
        out_specs=(pspecs, ospecs, None),
        donate=(0, 1),
        meta=dict(model_flops=model_flops, n_params=cfg.n_params()),
    )


# ---------------------------------------------------------------------------
# Recsys cells
# ---------------------------------------------------------------------------


def _recsys_batch_sds(cfg: rec_lib.RecsysConfig, B: int, with_labels: bool):
    sds, spec = {}, {}
    if cfg.kind == "din":
        sds.update(
            target=SDS((B,), i32), seq=SDS((B, cfg.seq_len), i32),
            seq_mask=SDS((B, cfg.seq_len), f32),
        )
    else:
        sds["sparse"] = SDS((B, cfg.n_sparse), i32)
        if cfg.n_dense:
            sds["dense"] = SDS((B, cfg.n_dense), f32)
    if with_labels:
        sds["labels"] = SDS((B,), f32)
    return sds


def _recsys_batch_spec(cfg, sds, b):
    return {k: P(b, *([None] * (len(v.shape) - 1))) for k, v in sds.items()}


def _recsys_cell(arch_id, spec: ShapeSpec, mesh) -> Cell:
    arch = get_arch(arch_id)
    cfg = arch.config_fn()
    bA = batch_axes_of(mesh)
    allA = all_axes_of(mesh)
    b = bA if len(bA) > 1 else bA[0]
    pshapes = {k: v for k, v in rec_lib.param_shapes(cfg).items()}
    pspecs = rec_lib.param_specs(cfg, batch_axes=bA)
    # embedding FLOPs are negligible; interactions + MLP dominate
    dense_params = sum(
        int(jnp.prod(jnp.array(s.shape))) for k, s in pshapes.items()
        if k not in ("tables", "wide", "lin")
    )

    if spec.kind == "train":
        B = spec.dims["batch"]
        oshapes = opt_lib.opt_state_shapes(pshapes)
        ospecs = opt_lib.opt_state_specs(pspecs)
        ocfg = _opt_cfg()
        batch_sds = _recsys_batch_sds(cfg, B, True)
        batch_spec = _recsys_batch_spec(cfg, batch_sds, b)

        def step(params, opt_state, batch):
            (loss, _), grads = jax.value_and_grad(
                lambda p, bt: rec_lib.loss_fn(p, bt, cfg), has_aux=True
            )(params, batch)
            new_p, new_o, m = opt_lib.adamw_update(grads, opt_state, params, ocfg)
            return new_p, new_o, {"loss": loss, **m}

        return Cell(
            arch_id, spec.name, "train", step,
            args=(pshapes, oshapes, batch_sds),
            in_specs=(pspecs, ospecs, batch_spec),
            out_specs=(pspecs, ospecs, None),
            donate=(0, 1),
            meta=dict(model_flops=6.0 * dense_params * B,
                      n_params=cfg.n_params()),
        )

    if spec.kind == "serve":
        B = spec.dims["batch"]
        batch_sds = _recsys_batch_sds(cfg, B, False)
        batch_spec = _recsys_batch_spec(cfg, batch_sds, b)

        def step(params, batch):
            logits, _ = rec_lib.forward(params, batch, cfg)
            return jax.nn.sigmoid(logits.astype(f32))

        return Cell(
            arch_id, spec.name, "serve", step,
            args=(pshapes, batch_sds),
            in_specs=(pspecs, batch_spec),
            out_specs=None,
            meta=dict(model_flops=2.0 * dense_params * B,
                      n_params=cfg.n_params()),
        )

    # retrieval_cand: one user vs padded candidate rows, distributed top-k.
    B = spec.dims["batch"]
    n_pad = spec.dims["n_candidates_padded"]
    batch_sds = _recsys_batch_sds(cfg, B, False)
    batch_spec = _recsys_batch_spec(cfg, batch_sds, None)  # B=1: replicated
    cand_sds = SDS((n_pad, cfg.retrieval_dim), f32)
    cand_spec = P(allA, None)

    def step(params, batch, candidates):
        return rec_lib.retrieval_step(params, batch, candidates, cfg, mesh,
                                      k=100, cand_axes=allA)

    return Cell(
        arch_id, spec.name, "retrieval", step,
        args=(pshapes, batch_sds, cand_sds),
        in_specs=(pspecs, batch_spec, cand_spec),
        out_specs=(P(), P()),
        meta=dict(model_flops=2.0 * n_pad * cfg.retrieval_dim * B,
                  n_params=cfg.n_params()),
    )


# ---------------------------------------------------------------------------
# PDASC cells (the paper's own architecture)
# ---------------------------------------------------------------------------


def _pdasc_cell(arch_id, spec: ShapeSpec, mesh, variant: str = "base") -> Cell:
    from repro.core import distributed as dd
    from repro.core import msa
    from repro.query import compile_sharded_plan

    arch = get_arch(arch_id)
    cfg = arch.config_fn()
    allA = all_axes_of(mesh)
    Pn = 1
    for a in allA:
        Pn *= mesh.shape[a]
    n, d = cfg.n, cfg.d
    per = n // Pn

    if spec.kind == "build":
        def step(data):
            return dd.build_sharded(
                data, mesh, db_axes=allA, gl=cfg.gl, distance=cfg.distance,
                method=cfg.method, row_chunk=cfg.row_chunk,
                group_chunk=cfg.group_chunk, bg=cfg.bg,
                swap_tol=cfg.swap_tol,
            )

        # Distance-matrix FLOPs of every level's clustering (dominant term):
        # level sizes n, n/2, ... per shard; pairwise cost ~ 2 g^2 d per group.
        flops, level_n = 0.0, per
        while True:
            G = -(-level_n // cfg.gl)
            flops += 2.0 * G * (cfg.gl ** 2) * d
            level_n = G * (cfg.gl // 2)
            if G == 1:
                break
        return Cell(
            arch_id, spec.name, "build", step,
            args=(SDS((n, d), f32),),
            in_specs=(P(allA, None),),
            out_specs=None,
            meta=dict(model_flops=flops * Pn, n_points=n),
        )

    # search: per-shard dense NSA + butterfly merge.
    def _index_sds():
        def build_one(x):
            idx, _ = msa.build_index_arrays(
                x, gl=cfg.gl, distance=cfg.distance, method="build",
                key=jax.random.PRNGKey(0),
            )
            return jax.tree.map(lambda a: a[None], idx)

        one = jax.eval_shape(build_one, SDS((per, d), f32))
        return jax.tree.map(
            lambda s: SDS((Pn,) + s.shape[1:], s.dtype), one
        )

    idx_sds = _index_sds()
    idx_specs = jax.tree.map(lambda _: P(allA), idx_sds)
    Q = cfg.n_queries
    n_levels = len(idx_sds.levels)

    # The three search variants are one declarative Query each, lowered onto
    # the mesh by the plan compiler — the plan binds every static knob, so
    # the step is just "execute the plan on the (traced) stacked index".
    if variant == "opt-beam":
        # §Perf H3: beam-pruned NSA gathers only the top-`beam` in-radius
        # prototypes' sibling-contiguous child blocks. Batched through the
        # fused rank kernel (one gather + one VMEM-streamed rank per level),
        # so the [Q, cand] distance matrix that attempt 1 materialised in
        # HBM never leaves VMEM.
        beam, mc = 32, 8
        plan = compile_sharded_plan(
            mesh, cfg.search_query(execution="beam", beam=beam),
            dist=cfg.distance, db_axes=allA,
            max_children=(0,) + (mc,) * (n_levels - 1),
        )
    elif variant == "opt":
        # §Perf H3 (attempt 2): keep the faithful dense-masked search but
        # compute distances in bf16 — halves every [Q, n_level] matrix and
        # the point reads (ANN ranking tolerates bf16; recall checked in
        # tests/benches). Index points stored bf16.
        idx_sds = jax.tree.map(
            lambda s: SDS(s.shape, bf16) if s.dtype == jnp.float32 else s,
            idx_sds,
        )
        plan = compile_sharded_plan(
            mesh,
            cfg.search_query(execution="dense", with_stats=False,
                             kernel=None),
            dist=cfg.distance, db_axes=allA,
        )
    else:
        plan = compile_sharded_plan(
            mesh, cfg.search_query(execution="dense", kernel=None),
            dist=cfg.distance, db_axes=allA,
        )

    def step(index, queries):
        return plan(index, queries)

    # Dense NSA evaluates every level's distances: sum_l n_l * d * 2 per query.
    level_sizes, level_n = [], per
    while True:
        G = -(-level_n // cfg.gl)
        level_sizes.append(level_n)
        level_n = G * (cfg.gl // 2)
        if G == 1:
            level_sizes.append(level_n)
            break
    flops = 2.0 * Q * d * sum(level_sizes) * Pn
    q_dtype = bf16 if variant == "opt" else f32
    return Cell(
        arch_id, spec.name, "search", step,
        args=(idx_sds, SDS((Q, d), q_dtype)),
        in_specs=(idx_specs, P(None, None)),
        out_specs=None,
        meta=dict(model_flops=flops, n_points=n, n_queries=Q),
    )


# ---------------------------------------------------------------------------


def build_cell(arch_id: str, shape_name: str, mesh,
               probe_layers: Optional[int] = None,
               variant: str = "base") -> Cell:
    arch = get_arch(arch_id)
    spec = arch.shapes[shape_name]
    if arch.family == "lm":
        return _lm_cell(arch_id, spec, mesh, probe_layers)
    if arch.family == "gnn":
        return _gnn_train_cell(arch_id, spec, mesh)
    if arch.family == "recsys":
        return _recsys_cell(arch_id, spec, mesh)
    if arch.family == "pdasc":
        return _pdasc_cell(arch_id, spec, mesh, variant)
    raise ValueError(arch.family)


def needs_probe(arch_id: str) -> bool:
    """LM cells scan over layers (undercounted by cost analysis)."""
    return get_arch(arch_id).family == "lm"


def probe_trip_count(arch_id: str) -> int:
    return get_arch(arch_id).config_fn().n_layers
