"""Online recall estimation by shadow sampling (DESIGN.md §3.12).

The serving tier reports latency but is blind to the quality it delivers:
degraded scan-only answers, tombstone churn and int4/binary payloads all
silently move recall. :class:`RecallEstimator` measures it continuously,
on live traffic:

* **Deterministic 1-in-N sampling** — ``observe(seq, ...)`` picks exactly
  the requests with ``seq % every_n == 0``, the same seq-keyed scheme the
  tracer uses, so a replayed workload shadows the same queries.
* **Off the hot path** — a sampled query (payload + the ids the tier
  served) is copied onto a bounded queue; when the queue is full the
  sample is *dropped* (and counted), never blocking the serving thread.
  A single daemon worker re-answers each sample exactly: the reference
  point set comes from ``online.live_dataset`` — which reads the store's
  ``ExactSource`` payload when the dense copy has been released — and the
  exact answer from the ``baselines.exact`` brute-force k-NN over it.
* **Wilson intervals** — recall@k is k Bernoulli trials per sample
  (each true neighbour either was or was not in the served ids), so the
  estimate carries a 95% Wilson score interval. Published per
  ``(pipeline, leg)``: ``quality_recall_ratio`` (per-sample histogram),
  ``quality_recall_mean_ratio`` and the ``_wilson_lo/_wilson_hi`` bounds,
  plus shadow accounting (sampled/answered/dropped/errors/pending/lag).

The ``leg`` label separates degraded-mode serves from normal ones — a
wedged tier answering on the scan-only plan shows up as a recall dip on
the ``degraded`` leg, not just a latency blip.
"""

from __future__ import annotations

import math
import queue
import threading
import time
from typing import Callable, Optional

import numpy as np

from repro.obs import metrics as metrics_lib
from repro.obs import names as names_lib

# Linear buckets suit a [0, 1] ratio far better than the default
# microseconds-to-minutes log spacing.
RECALL_BUCKETS = tuple(round(i / 20, 2) for i in range(1, 21))


def wilson(successes: float, trials: float, z: float = 1.96
           ) -> tuple[float, float]:
    """Wilson score interval for a binomial proportion.

    Unlike the normal approximation it behaves at p near 0/1 and small n
    (recall estimates live exactly there: p close to 1, tens of samples).
    Returns the trivial ``(0, 1)`` when there are no trials yet.
    """
    if trials <= 0:
        return (0.0, 1.0)
    p = successes / trials
    z2 = z * z
    denom = 1.0 + z2 / trials
    centre = (p + z2 / (2.0 * trials)) / denom
    half = z * math.sqrt(
        p * (1.0 - p) / trials + z2 / (4.0 * trials * trials)) / denom
    return (max(0.0, centre - half), min(1.0, centre + half))


def _resolve_index(source):
    """The live index behind ``source``: a bare index, an
    ``online.EpochHandle`` (``.current``), a ``serving.ReplicaSet``
    (``.live_index()``), or a zero-arg callable returning any of those."""
    if callable(source) and not hasattr(source, "current") \
            and not hasattr(source, "live_index"):
        source = source()
    if hasattr(source, "live_index"):
        source = source.live_index()
    if hasattr(source, "current"):
        source = source.current
    return source


class _LegStats:
    __slots__ = ("queries", "trials", "successes")

    def __init__(self):
        self.queries = 0
        self.trials = 0
        self.successes = 0


class RecallEstimator:
    """Shadow-sample served queries and estimate online recall@k.

    ``source`` names the live index (see :func:`_resolve_index`);
    ``every_n`` is the deterministic sampling rate (0 disables —
    ``observe`` becomes a cheap no-op); ``on_sample`` is an optional
    callback ``(recall, pipeline, leg)`` invoked from the worker thread
    for each answered sample (the router wires the SLO tracker's recall
    feed through it).
    """

    def __init__(self, source, *, every_n: int = 16,
                 queue_max: int = 512,
                 on_sample: Optional[Callable] = None):
        self.source = source
        self.every_n = int(every_n)
        self.on_sample = on_sample
        self._q: queue.Queue = queue.Queue(maxsize=max(1, int(queue_max)))
        self._lock = threading.Lock()
        self._stats: dict[tuple[str, str], _LegStats] = {}
        self._pending = 0
        self._ref_key = None
        self._ref = None  # (vectors [m, d] f32, ids [m] i32)
        self._m_sampled = metrics_lib.counter(names_lib.QUALITY_SAMPLED)
        self._m_answered = metrics_lib.counter(names_lib.QUALITY_ANSWERED)
        self._m_dropped = metrics_lib.counter(names_lib.QUALITY_DROPPED)
        self._m_errors = metrics_lib.counter(names_lib.QUALITY_ERRORS)
        self._m_pending = metrics_lib.gauge(names_lib.QUALITY_PENDING)
        self._m_lag = metrics_lib.histogram(names_lib.QUALITY_LAG)
        self._stop = threading.Event()
        self._worker = threading.Thread(target=self._run, daemon=True,
                                        name="recall-shadow")
        self._worker.start()

    # -- hot path --------------------------------------------------------------

    def should_sample(self, seq: int) -> bool:
        return self.every_n > 0 and seq % self.every_n == 0

    def observe(self, seq: int, payload, served_ids, *,
                pipeline: str = "", leg: str = "normal") -> bool:
        """Offer one served query. Returns True when it was enqueued for
        shadow re-answering. The payload and ids are copied (the caller's
        arrays may be reused); a full queue drops the sample."""
        if not self.should_sample(seq):
            return False
        self._m_sampled.inc()
        item = (
            np.array(payload, np.float32, copy=True),
            np.asarray(served_ids).reshape(-1).copy(),
            str(pipeline), str(leg), time.perf_counter(),
        )
        try:
            self._q.put_nowait(item)
        except queue.Full:
            self._m_dropped.inc()
            return False
        with self._lock:
            self._pending += 1
            self._m_pending.set(self._pending)
        return True

    # -- worker ----------------------------------------------------------------

    def _reference(self):
        """The exact reference set ``(vectors, ids)``, cached until the
        live set changes (epoch swap, delta write, delete)."""
        idx = _resolve_index(self.source)
        key = (id(idx), getattr(idx, "epoch", 0), idx.n_points)
        if key != self._ref_key:
            from repro.online import live_dataset

            self._ref = live_dataset(idx)
            self._ref_key = key
        return idx, self._ref

    def _answer(self, payload, served_ids, pipeline, leg, t_enq) -> None:
        from repro.baselines.exact import exact_knn

        k = int(served_ids.shape[0])
        idx, (ref_vecs, ref_ids) = self._reference()
        _, gt = exact_knn(payload[None], ref_vecs,
                          distance=idx.distance, k=k)
        gt_ids = set(int(x) for x in ref_ids[np.asarray(gt)[0]])
        served = set(int(x) for x in served_ids if x >= 0)
        recall = len(served & gt_ids) / max(k, 1)
        with self._lock:
            st = self._stats.setdefault((pipeline, leg), _LegStats())
            st.queries += 1
            st.trials += k
            st.successes += len(served & gt_ids)
            successes, trials = st.successes, st.trials
        labels = dict(pipeline=pipeline, leg=leg)
        metrics_lib.histogram(names_lib.QUALITY_RECALL,
                              RECALL_BUCKETS, **labels).observe(recall)
        lo, hi = wilson(successes, trials)
        metrics_lib.gauge(names_lib.QUALITY_RECALL_MEAN,
                          **labels).set(successes / trials)
        metrics_lib.gauge(names_lib.QUALITY_RECALL_LO, **labels).set(lo)
        metrics_lib.gauge(names_lib.QUALITY_RECALL_HI, **labels).set(hi)
        self._m_lag.observe(time.perf_counter() - t_enq)
        self._m_answered.inc()
        if self.on_sample is not None:
            self.on_sample(recall, pipeline, leg)

    def _run(self) -> None:
        while True:
            try:
                item = self._q.get(timeout=0.1)
            except queue.Empty:
                if self._stop.is_set():
                    return
                continue
            if item is None:
                return
            try:
                self._answer(*item)
            except Exception:  # noqa: BLE001 — telemetry never kills serving
                self._m_errors.inc()
            finally:
                with self._lock:
                    self._pending -= 1
                    self._m_pending.set(self._pending)

    # -- read side -------------------------------------------------------------

    def estimate(self, *, pipeline: Optional[str] = None,
                 leg: Optional[str] = None) -> dict:
        """The aggregated estimate over every ``(pipeline, leg)`` matching
        the filters: ``{"queries", "trials", "successes", "recall",
        "wilson_lo", "wilson_hi"}`` (``recall`` is None with no samples).
        """
        queries = trials = successes = 0
        with self._lock:
            for (p, lg), st in self._stats.items():
                if pipeline is not None and p != pipeline:
                    continue
                if leg is not None and lg != leg:
                    continue
                queries += st.queries
                trials += st.trials
                successes += st.successes
        lo, hi = wilson(successes, trials)
        return dict(
            queries=queries, trials=trials, successes=successes,
            recall=(successes / trials if trials else None),
            wilson_lo=lo, wilson_hi=hi,
        )

    def legs(self) -> list[tuple[str, str]]:
        with self._lock:
            return sorted(self._stats)

    def reset_stats(self) -> None:
        """Drop the accumulated estimate (keep the worker running) — used
        between a calibration pass and the measured pass."""
        with self._lock:
            self._stats.clear()

    def drain(self, timeout: float = 30.0) -> bool:
        """Block until every enqueued sample has been answered (True) or
        the timeout passed (False)."""
        deadline = time.perf_counter() + timeout
        while time.perf_counter() < deadline:
            with self._lock:
                if self._pending == 0:
                    return True
            time.sleep(0.005)
        with self._lock:
            return self._pending == 0

    def close(self, timeout: float = 10.0) -> None:
        self._stop.set()
        try:
            self._q.put_nowait(None)
        except queue.Full:
            pass
        self._worker.join(timeout=timeout)
