"""Dashboard + offline observability report (DESIGN.md §3.12).

Two surfaces over the same snapshot math:

* :class:`Dashboard` — a live terminal view for ``launch/serve.py
  --dash``: a background thread redraws QPS, latency percentiles, engine
  occupancy/queue depth, the online recall estimate, SLO budget state and
  per-replica health every period.
* ``python -m repro.obs.report`` — an offline CLI turning a
  ``MetricsDumper`` JSON dump (plus, optionally, a ``--trace-dump`` JSON
  export) into a static text or HTML report. Exits non-zero on an empty
  or malformed dump — CI runs it against the bench_serve smoke's metrics
  dump as a freshness check on the whole telemetry pipeline.

Everything here consumes plain snapshot/trace *dicts* (never live
registry objects), so the offline and live paths share the renderers.
"""

from __future__ import annotations

import argparse
import html as html_lib
import json
import math
import sys
import threading
import time
from typing import Optional, TextIO

from repro.obs import metrics as metrics_lib
from repro.obs import names as names_lib


class ReportError(ValueError):
    """The metrics/trace input is empty or malformed."""


# ---------------------------------------------------------------------------
# Snapshot math (dict-side mirrors of the Histogram helpers)
# ---------------------------------------------------------------------------


def percentile_from_hist(hist: dict, q: float) -> float:
    """``Histogram.percentile`` over a snapshot's ``hist`` dict."""
    counts = hist["counts"]
    bounds = hist["buckets"]
    total = hist["count"]
    if not total:
        return math.nan
    lo_seen = hist.get("min") or 0.0
    hi_seen = hist.get("max") or 0.0
    target = q * total
    acc = 0.0
    for i, c in enumerate(counts):
        if c == 0:
            continue
        lo = bounds[i - 1] if i > 0 else 0.0
        hi = bounds[i] if i < len(bounds) else hi_seen
        lo = max(lo, lo_seen if acc == 0.0 else lo)
        hi = min(hi, hi_seen)
        if hi < lo:
            lo = hi
        if acc + c >= target:
            frac = (target - acc) / c
            return lo + (hi - lo) * min(max(frac, 0.0), 1.0)
        acc += c
    return hi_seen


def hist_summary(hist: dict) -> dict:
    n = hist["count"]
    return dict(
        count=n,
        mean=(hist["sum"] / n if n else None),
        p50=(percentile_from_hist(hist, 0.50) if n else None),
        p99=(percentile_from_hist(hist, 0.99) if n else None),
        max=hist.get("max"),
    )


def _fmt_labels(labels: dict) -> str:
    if not labels:
        return ""
    return "{" + ",".join(f"{k}={v}" for k, v in sorted(labels.items())) \
        + "}"


def _fmt_num(v) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        if v != v:
            return "nan"
        if v and (abs(v) < 1e-3 or abs(v) >= 1e6):
            return f"{v:.3e}"
        return f"{v:.4g}"
    return str(v)


# ---------------------------------------------------------------------------
# Report building (offline + dashboard share this)
# ---------------------------------------------------------------------------


def validate_snapshot(snapshot) -> dict:
    """Check the loaded dump looks like a registry snapshot with at least
    one series; raises :class:`ReportError` otherwise."""
    if not isinstance(snapshot, dict) or not snapshot:
        raise ReportError("metrics dump is empty or not a JSON object")
    n = 0
    for name, entry in snapshot.items():
        if not isinstance(entry, dict) or "kind" not in entry \
                or "series" not in entry:
            raise ReportError(
                f"metrics dump entry {name!r} is not a snapshot series "
                f"(missing kind/series)")
        n += len(entry["series"])
    if n == 0:
        raise ReportError("metrics dump contains no series")
    return snapshot


def _tier_values(snapshot: dict, name: str) -> dict:
    """``tier`` label -> summed value for one counter/gauge family."""
    entry = snapshot.get(name)
    out: dict = {}
    if entry is None or entry["kind"] == "histogram":
        return out
    for row in entry["series"]:
        tier = row["labels"].get("tier", "")
        out[tier] = out.get(tier, 0.0) + row["value"]
    return out


def store_cache_summary(snapshot: dict) -> dict:
    """Per-tier cache effectiveness derived from the ``store_cache_*``
    series (DESIGN.md §3.13): hit ratio, resident bytes, in-flight dedup
    hits, plus the prefetch pool's drop count. Empty when the snapshot has
    no cache traffic."""
    hits = _tier_values(snapshot, names_lib.STORE_CACHE_HITS)
    misses = _tier_values(snapshot, names_lib.STORE_CACHE_MISSES)
    resident = _tier_values(snapshot, names_lib.STORE_CACHE_RESIDENT)
    dedup = _tier_values(snapshot, names_lib.STORE_CACHE_INFLIGHT_DEDUP)
    tiers: dict = {}
    for tier in sorted(set(hits) | set(misses)):
        h = hits.get(tier, 0.0)
        m = misses.get(tier, 0.0)
        if not h and not m:
            continue
        tiers[tier] = dict(
            hits=int(h), misses=int(m),
            hit_ratio=h / (h + m),
            resident_bytes=int(resident.get(tier, 0.0)),
            inflight_dedup=int(dedup.get(tier, 0.0)),
        )
    if not tiers:
        return {}
    return dict(
        tiers=tiers,
        prefetch_drops=int(_series_value(
            snapshot, names_lib.STORE_PREFETCH_DROPS)),
    )


def build_report(snapshot: dict, traces: Optional[list] = None) -> dict:
    """Structured report dict from a snapshot (+ optional trace dicts):
    per-subsystem series tables, histogram summaries, and trace stats."""
    validate_snapshot(snapshot)
    subsystems: dict = {}
    for name in sorted(snapshot):
        entry = snapshot[name]
        sub = names_lib.subsystem(name)
        bucket = subsystems.setdefault(sub, [])
        for row in entry["series"]:
            item = dict(name=name, kind=entry["kind"],
                        labels=row["labels"])
            if entry["kind"] == "histogram":
                item["summary"] = hist_summary(row["hist"])
            else:
                item["value"] = row["value"]
            bucket.append(item)
    report = dict(
        n_names=len(snapshot),
        n_series=sum(len(v["series"]) for v in snapshot.values()),
        subsystems=subsystems,
    )
    cache = store_cache_summary(snapshot)
    if cache:
        report["store_cache"] = cache
    if traces is not None:
        durations = [t["root"]["duration"] for t in traces]
        slowest = max(traces, key=lambda t: t["root"]["duration"]) \
            if traces else None
        report["traces"] = dict(
            n=len(traces),
            slowest_ms=(round(max(durations) * 1e3, 3) if durations
                        else None),
            slowest=slowest,
        )
    return report


def render_trace_dict(td: dict) -> str:
    """Text flamegraph from a ``Trace.to_dict()`` export (the offline
    twin of ``Trace.render``)."""
    root = td["root"]
    total = max(root["duration"], 1e-12)
    lines = [f"trace #{td.get('trace_id', '?')} seq={td.get('seq', '?')} "
             f"({root['duration'] * 1e3:.2f} ms)"]

    def emit(span: dict, depth: int) -> None:
        attrs = " ".join(f"{k}={v}" for k, v in
                         sorted(span.get("attrs", {}).items()))
        bar = "#" * max(1, int(round(20 * span["duration"] / total)))
        lines.append(
            f"{'  ' * depth}{span['name']:<{max(1, 28 - 2 * depth)}} "
            f"{span['duration'] * 1e3:9.3f}ms "
            f"self={span['self_time'] * 1e3:8.3f}ms "
            f"|{bar:<20}| {attrs}".rstrip())
        for c in span.get("children", ()):
            emit(c, depth + 1)

    emit(root, 0)
    return "\n".join(lines)


def render_text(report: dict) -> str:
    lines = [f"observability report — {report['n_names']} metric names, "
             f"{report['n_series']} series",
             "=" * 64]
    for sub in sorted(report["subsystems"]):
        lines.append(f"\n[{sub}]")
        for item in report["subsystems"][sub]:
            label = f"{item['name']}{_fmt_labels(item['labels'])}"
            if item["kind"] == "histogram":
                s = item["summary"]
                lines.append(
                    f"  {label:<58} n={s['count']:<7} "
                    f"mean={_fmt_num(s['mean'])} p50={_fmt_num(s['p50'])} "
                    f"p99={_fmt_num(s['p99'])} max={_fmt_num(s['max'])}")
            else:
                lines.append(
                    f"  {label:<58} {_fmt_num(item['value'])}")
    cache = report.get("store_cache")
    if cache:
        lines.append("\n[store cache]")
        for tier, t in sorted(cache["tiers"].items()):
            lines.append(
                f"  tier={tier or '-'}: hit_ratio={t['hit_ratio']:.3f} "
                f"({t['hits']} hits / {t['misses']} misses) "
                f"resident={t['resident_bytes']}B "
                f"dedup={t['inflight_dedup']}")
        lines.append(f"  prefetch drops={cache['prefetch_drops']}")
    tr = report.get("traces")
    if tr:
        lines.append(f"\n[traces] retained={tr['n']} "
                     f"slowest={_fmt_num(tr['slowest_ms'])}ms")
        if tr.get("slowest"):
            lines.append(render_trace_dict(tr["slowest"]))
    return "\n".join(lines) + "\n"


def render_html(report: dict) -> str:
    esc = html_lib.escape
    parts = [
        "<!doctype html><html><head><meta charset='utf-8'>",
        "<title>observability report</title>",
        "<style>body{font-family:monospace;margin:2em;}"
        "table{border-collapse:collapse;margin-bottom:1.5em;}"
        "td,th{border:1px solid #999;padding:2px 8px;text-align:left;}"
        "th{background:#eee;}h2{margin-bottom:4px;}</style></head><body>",
        f"<h1>observability report</h1>"
        f"<p>{report['n_names']} metric names, {report['n_series']} "
        f"series</p>",
    ]
    for sub in sorted(report["subsystems"]):
        parts.append(f"<h2>{esc(sub)}</h2><table>"
                     "<tr><th>series</th><th>kind</th><th>value</th>"
                     "<th>n</th><th>mean</th><th>p50</th><th>p99</th>"
                     "<th>max</th></tr>")
        for item in report["subsystems"][sub]:
            label = f"{item['name']}{_fmt_labels(item['labels'])}"
            if item["kind"] == "histogram":
                s = item["summary"]
                cells = ["", str(s["count"]), _fmt_num(s["mean"]),
                         _fmt_num(s["p50"]), _fmt_num(s["p99"]),
                         _fmt_num(s["max"])]
            else:
                cells = [_fmt_num(item["value"]), "", "", "", "", ""]
            parts.append(
                f"<tr><td>{esc(label)}</td><td>{esc(item['kind'])}</td>"
                + "".join(f"<td>{esc(c)}</td>" for c in cells) + "</tr>")
        parts.append("</table>")
    cache = report.get("store_cache")
    if cache:
        parts.append("<h2>store cache</h2><table>"
                     "<tr><th>tier</th><th>hit ratio</th><th>hits</th>"
                     "<th>misses</th><th>resident bytes</th>"
                     "<th>dedup</th></tr>")
        for tier, t in sorted(cache["tiers"].items()):
            parts.append(
                f"<tr><td>{esc(tier or '-')}</td>"
                f"<td>{t['hit_ratio']:.3f}</td><td>{t['hits']}</td>"
                f"<td>{t['misses']}</td><td>{t['resident_bytes']}</td>"
                f"<td>{t['inflight_dedup']}</td></tr>")
        parts.append(f"</table><p>prefetch drops="
                     f"{cache['prefetch_drops']}</p>")
    tr = report.get("traces")
    if tr:
        parts.append(f"<h2>traces</h2><p>retained={tr['n']} "
                     f"slowest={_fmt_num(tr['slowest_ms'])}ms</p>")
        if tr.get("slowest"):
            parts.append(
                f"<pre>{esc(render_trace_dict(tr['slowest']))}</pre>")
    parts.append("</body></html>")
    return "".join(parts)


# ---------------------------------------------------------------------------
# Live terminal dashboard (launch/serve.py --dash)
# ---------------------------------------------------------------------------


def _series_value(snap: dict, name: str) -> float:
    entry = snap.get(name)
    if entry is None:
        return 0.0
    if entry["kind"] == "histogram":
        return float(sum(r["hist"]["count"] for r in entry["series"]))
    return float(sum(r["value"] for r in entry["series"]))


def _hist_merged(snap: dict, name: str) -> Optional[dict]:
    """Across-label merge of one histogram family (same bounds)."""
    entry = snap.get(name)
    if entry is None or entry["kind"] != "histogram" \
            or not entry["series"]:
        return None
    rows = [r["hist"] for r in entry["series"]]
    base = rows[0]
    merged = dict(
        buckets=list(base["buckets"]),
        counts=[sum(r["counts"][i] for r in rows
                    if len(r["counts"]) == len(base["counts"]))
                for i in range(len(base["counts"]))],
        sum=sum(r["sum"] for r in rows),
        count=sum(r["count"] for r in rows),
        min=min((r["min"] for r in rows if r["min"] is not None),
                default=None),
        max=max((r["max"] for r in rows if r["max"] is not None),
                default=None),
    )
    return merged if merged["count"] else None


def render_dashboard(snap: dict, *, prev: Optional[dict] = None,
                     dt: Optional[float] = None, quality=None, slo=None,
                     router=None, width: int = 78) -> str:
    """One dashboard frame from a registry snapshot (+ optional live
    helpers: a RecallEstimator, an SLOTracker, a Router)."""
    lines = [f"── serve dashboard {'─' * max(0, width - 19)}"]
    served = _series_value(snap, names_lib.ROUTER_REQUESTS) \
        or _series_value(snap, names_lib.ENGINE_REQUESTS)
    qps = None
    if prev is not None and dt:
        prev_served = _series_value(prev, names_lib.ROUTER_REQUESTS) \
            or _series_value(prev, names_lib.ENGINE_REQUESTS)
        qps = max(0.0, served - prev_served) / dt
    lat = _hist_merged(snap, names_lib.ROUTER_LATENCY) \
        or _hist_merged(snap, names_lib.ENGINE_HANDLER_TIME)
    parts = [f"served={int(served)}"]
    if qps is not None:
        parts.append(f"qps={qps:.1f}")
    if lat:
        parts.append(
            f"p50={percentile_from_hist(lat, 0.5) * 1e3:.1f}ms "
            f"p99={percentile_from_hist(lat, 0.99) * 1e3:.1f}ms")
    occ = _hist_merged(snap, names_lib.ENGINE_BATCH_OCCUPANCY)
    if occ:
        parts.append(f"occupancy={occ['sum'] / occ['count']:.2f}")
    depth = _series_value(snap, names_lib.ENGINE_QUEUE_DEPTH)
    parts.append(f"queue={int(depth)}")
    lines.append("  " + "  ".join(parts))
    lines.append(
        "  " + "  ".join(
            f"{label}={int(_series_value(snap, cname))}"
            for cname, label in (
                (names_lib.ROUTER_RETRIES, "retries"),
                (names_lib.ROUTER_HEDGES, "hedges"),
                (names_lib.ROUTER_DEGRADED, "degraded"),
                (names_lib.ROUTER_REJECTS, "rejects"),
                (names_lib.QUALITY_SAMPLED, "shadowed"),
            )))
    cache = store_cache_summary(snap)
    if cache:
        lines.append("  cache: " + "  ".join(
            f"{tier or '-'}={t['hit_ratio']:.2f} "
            f"({t['resident_bytes'] // 1024}KiB)"
            for tier, t in sorted(cache["tiers"].items()))
            + f"  prefetch_drops={cache['prefetch_drops']}")
    if quality is not None:
        est = quality.estimate()
        if est["queries"]:
            lines.append(
                f"  recall@k≈{est['recall']:.3f} "
                f"[{est['wilson_lo']:.3f}, {est['wilson_hi']:.3f}] "
                f"over {est['queries']} shadow samples")
        else:
            lines.append("  recall@k: no shadow samples yet")
    if slo is not None:
        for obj, st in sorted(slo.status().items()):
            flag = " ALERT" if st["alerting"] else ""
            lines.append(
                f"  slo[{obj}] sli={_fmt_num(st['sli'])} "
                f"burn={st['burn_slow']:.2f}/{st['burn_fast']:.2f} "
                f"budget_left={st['budget_remaining']:.2f} "
                f"n={st['n']}{flag}")
    if router is not None:
        states = router.health_states()
        lines.append("  replicas: " + "  ".join(
            f"r{rid}={state}" for rid, state in sorted(states.items())))
    lines.append("─" * width)
    return "\n".join(lines)


class Dashboard:
    """Background thread redrawing :func:`render_dashboard` every period.

    Writes ANSI home+clear before each frame when ``clear=True`` (the
    interactive default); with ``clear=False`` frames are appended —
    usable on dumb pipes and in tests.
    """

    def __init__(self, registry=None, *, period_s: float = 1.0,
                 quality=None, slo=None, router=None,
                 stream: Optional[TextIO] = None, clear: bool = True):
        self.reg = registry if registry is not None \
            else metrics_lib.registry()
        self.period_s = float(period_s)
        self.quality = quality
        self.slo = slo
        self.router = router
        self.stream = stream if stream is not None else sys.stdout
        self.clear = clear
        self._prev: Optional[dict] = None
        self._prev_t: Optional[float] = None
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="obs-dashboard")
        self._thread.start()

    def frame(self) -> str:
        snap = self.reg.snapshot()
        now = time.perf_counter()
        dt = (now - self._prev_t) if self._prev_t is not None else None
        text = render_dashboard(snap, prev=self._prev, dt=dt,
                                quality=self.quality, slo=self.slo,
                                router=self.router)
        self._prev, self._prev_t = snap, now
        return text

    def _loop(self) -> None:
        while not self._stop.wait(self.period_s):
            try:
                text = self.frame()
                if self.clear:
                    self.stream.write("\x1b[H\x1b[2J")
                self.stream.write(text + "\n")
                self.stream.flush()
            except Exception:  # noqa: BLE001 — telemetry never kills serving
                pass

    def close(self, *, final_frame: bool = True) -> None:
        self._stop.set()
        self._thread.join(timeout=5.0)
        if final_frame:
            try:
                self.stream.write(self.frame() + "\n")
                self.stream.flush()
            except Exception:  # noqa: BLE001
                pass


# ---------------------------------------------------------------------------
# CLI: python -m repro.obs.report
# ---------------------------------------------------------------------------


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Render a MetricsDumper JSON dump (+ optional trace "
                    "JSON) as a static text/HTML observability report.")
    p.add_argument("--metrics", required=True, metavar="PATH",
                   help="MetricsDumper JSON output (a registry snapshot)")
    p.add_argument("--trace", default=None, metavar="PATH",
                   help="a --trace-dump JSON export "
                        '({"traces": [...]}) to include')
    p.add_argument("--format", choices=["text", "html"], default=None,
                   help="output format (default: by --out extension, "
                        "else text)")
    p.add_argument("--out", default="-", metavar="PATH",
                   help="output path ('-' = stdout)")
    args = p.parse_args(argv)

    try:
        with open(args.metrics) as f:
            snapshot = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"report: cannot read metrics dump {args.metrics}: {e}",
              file=sys.stderr)
        return 2
    traces = None
    if args.trace:
        try:
            with open(args.trace) as f:
                tr = json.load(f)
            traces = tr["traces"] if isinstance(tr, dict) else tr
        except (OSError, json.JSONDecodeError, KeyError) as e:
            print(f"report: cannot read trace dump {args.trace}: {e}",
                  file=sys.stderr)
            return 2
    try:
        report = build_report(snapshot, traces)
    except ReportError as e:
        print(f"report: {e}", file=sys.stderr)
        return 2
    fmt = args.format or ("html" if args.out.endswith(".html") else "text")
    text = render_html(report) if fmt == "html" else render_text(report)
    if args.out == "-":
        sys.stdout.write(text)
    else:
        with open(args.out, "w") as f:
            f.write(text)
        print(f"report: wrote {fmt} report ({report['n_series']} series) "
              f"to {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
