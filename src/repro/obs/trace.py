"""Per-request distributed tracing (DESIGN.md §3.11).

A :class:`Trace` is created at the serving edge (``Router.search`` or a
bare ``engine.submit``) for a deterministic 1-in-N sample of requests
(:class:`TraceSampler` keys on the request *sequence number*, so a given
workload samples the same requests on every run). It records a tree of
:class:`Span` nodes — queue wait, batch wait, hedge/retry attempt legs,
plan execution, scan/rerank stages, granule fetches — each with a wall
duration (``time.perf_counter``), free-form attributes, and a *self time*
(duration minus direct children) so the tree's self-times partition the
request's wall clock.

Deeper layers never see the Trace itself. They cooperate through two
decoupled mechanisms:

* an explicit ``span=`` argument on the request path (router attempt →
  ``Replica.submit`` → ``engine.submit``) carrying the parent span for
  *per-request* children (queue wait, batch wait);
* a **thread-local active span set** for *shared* work: one executed
  batch serves many requests, of which several may be sampled, so the
  engine worker activates the set of their execute-spans around the
  handler call and :func:`span` mirrors every child into each of them.
  When no trace is active, :func:`span` returns a shared no-op context
  manager — the unsampled hot path costs one thread-local read.

Export: ``trace.to_dict()`` (JSON-ready) and ``trace.render()`` (a text
flamegraph: one line per span, indented, with duration/self-time and
attrs). Completed traces land in a bounded :class:`TraceBuffer`;
``buffer.exemplar(latency)`` picks the retained trace closest to a target
latency (bench_serve uses the measured p99).
"""

from __future__ import annotations

import itertools
import json
import threading
import time
from typing import Iterable, List, Optional

from repro.obs import names as names_lib
from repro.obs import metrics as metrics_lib

_now = time.perf_counter

_trace_ids = itertools.count(1)


class Span:
    """One timed node in a trace tree. Not thread-safe per-instance —
    a span is owned by the thread that created it (the tree as a whole is
    assembled from per-thread owned spans; the Trace is read only after
    ``finish``)."""

    __slots__ = ("name", "attrs", "t0", "t1", "children")

    def __init__(self, name: str, **attrs):
        self.name = name
        self.attrs = attrs
        self.t0 = _now()
        self.t1: Optional[float] = None
        self.children: List["Span"] = []

    def child(self, name: str, **attrs) -> "Span":
        s = Span(name, **attrs)
        self.children.append(s)
        return s

    def end(self, **attrs) -> None:
        if self.t1 is None:
            self.t1 = _now()
        if attrs:
            self.attrs.update(attrs)

    @property
    def duration(self) -> float:
        return ((self.t1 if self.t1 is not None else _now()) - self.t0)

    @property
    def self_time(self) -> float:
        return self.duration - sum(c.duration for c in self.children)

    def to_dict(self) -> dict:
        return dict(
            name=self.name,
            t0=self.t0,
            duration=self.duration,
            self_time=self.self_time,
            attrs={k: _jsonable(v) for k, v in self.attrs.items()},
            children=[c.to_dict() for c in self.children],
        )

    def walk(self) -> Iterable["Span"]:
        yield self
        for c in self.children:
            yield from c.walk()


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return str(v)


class Trace:
    """A sampled request: one root span + identity. ``finish()`` closes the
    root and hands the trace to its buffer (if any)."""

    __slots__ = ("trace_id", "seq", "root", "_buffer", "_finished")

    def __init__(self, name: str, *, seq: int = 0,
                 buffer: Optional["TraceBuffer"] = None, **attrs):
        self.trace_id = next(_trace_ids)
        self.seq = seq
        self.root = Span(name, **attrs)
        self._buffer = buffer
        self._finished = False

    def finish(self, **attrs) -> None:
        if self._finished:
            return
        self._finished = True
        self.root.end(**attrs)
        if self._buffer is not None:
            self._buffer.add(self)

    @property
    def duration(self) -> float:
        return self.root.duration

    def to_dict(self) -> dict:
        return dict(trace_id=self.trace_id, seq=self.seq,
                    root=self.root.to_dict())

    def to_json(self, *, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    def render(self) -> str:
        """Text flamegraph: one line per span, indented by depth, with
        total/self millisecond times and the span's attributes."""
        lines = [f"trace #{self.trace_id} seq={self.seq} "
                 f"({self.duration * 1e3:.2f} ms)"]
        total = max(self.duration, 1e-12)

        def emit(span: Span, depth: int) -> None:
            attrs = " ".join(f"{k}={_jsonable(v)}"
                             for k, v in sorted(span.attrs.items()))
            bar = "#" * max(1, int(round(20 * span.duration / total)))
            lines.append(
                f"{'  ' * depth}{span.name:<{max(1, 28 - 2 * depth)}} "
                f"{span.duration * 1e3:9.3f}ms "
                f"self={span.self_time * 1e3:8.3f}ms "
                f"|{bar:<20}| {attrs}".rstrip()
            )
            for c in span.children:
                emit(c, depth + 1)

        emit(self.root, 0)
        return "\n".join(lines)


class TraceBuffer:
    """Bounded ring of completed traces (newest kept)."""

    def __init__(self, maxlen: int = 64):
        self.maxlen = int(maxlen)
        self._lock = threading.Lock()
        self._traces: List[Trace] = []

    def add(self, trace: Trace) -> None:
        with self._lock:
            self._traces.append(trace)
            if len(self._traces) > self.maxlen:
                del self._traces[: len(self._traces) - self.maxlen]
        metrics_lib.counter(names_lib.TRACE_FINISHED).inc()

    def traces(self) -> List[Trace]:
        with self._lock:
            return list(self._traces)

    def to_dicts(self) -> List[dict]:
        """Every retained trace as a plain dict (oldest first)."""
        return [t.to_dict() for t in self.traces()]

    def to_json(self, *, indent: Optional[int] = None) -> str:
        """The full buffer as a JSON document — the ``--trace-dump``
        format, and the ``repro.obs.report`` CLI's trace input:
        ``{"traces": [trace.to_dict(), ...]}``."""
        return json.dumps({"traces": self.to_dicts()}, indent=indent)

    def __len__(self) -> int:
        with self._lock:
            return len(self._traces)

    def exemplar(self, latency_s: Optional[float] = None) -> Optional[Trace]:
        """The retained trace whose duration is closest to ``latency_s``
        (e.g. a measured p99); the slowest trace when no target is given."""
        with self._lock:
            if not self._traces:
                return None
            if latency_s is None:
                return max(self._traces, key=lambda t: t.duration)
            return min(self._traces,
                       key=lambda t: abs(t.duration - latency_s))


class TraceSampler:
    """Deterministic 1-in-N sampling by request sequence number.

    ``every_n <= 0`` disables sampling entirely. ``sample(seq)`` returns a
    new Trace exactly when ``seq % every_n == 0`` — reruns of the same
    workload sample the same requests, so tests reproduce span trees
    exactly.
    """

    def __init__(self, every_n: int = 0, *,
                 buffer: Optional[TraceBuffer] = None):
        self.every_n = int(every_n)
        self.buffer = buffer if buffer is not None else TraceBuffer()

    def should_sample(self, seq: int) -> bool:
        return self.every_n > 0 and seq % self.every_n == 0

    def sample(self, name: str, seq: int, **attrs) -> Optional[Trace]:
        if not self.should_sample(seq):
            return None
        metrics_lib.counter(names_lib.TRACE_SAMPLED).inc()
        return Trace(name, seq=seq, buffer=self.buffer, **attrs)


# ---------------------------------------------------------------------------
# Thread-local active span set + the `span()` helper
# ---------------------------------------------------------------------------

_local = threading.local()


def active_spans() -> tuple:
    """The spans mirrored by :func:`span` on this thread (empty = off)."""
    return getattr(_local, "spans", ())


class _ActiveCM:
    """Context manager installing a set of parent spans as this thread's
    active set (restoring the previous set on exit)."""

    __slots__ = ("spans", "_prev")

    def __init__(self, spans: tuple):
        self.spans = spans

    def __enter__(self):
        self._prev = getattr(_local, "spans", ())
        _local.spans = self.spans
        return self.spans

    def __exit__(self, *exc):
        _local.spans = self._prev
        return False


def activate(spans) -> _ActiveCM:
    """Install ``spans`` (an iterable of Span) as the thread's active set
    for the duration of the ``with`` block. The engine worker wraps each
    handler call in ``activate([...execute spans...])`` so stage spans
    recorded by the handler mirror into every sampled request of the batch.
    """
    return _ActiveCM(tuple(spans))


class _NullSpanCM:
    """Shared no-op for the unsampled path: no allocation, no timing."""

    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False

    def end(self, **attrs):  # duck-types Span enough for call sites
        pass


_NULL = _NullSpanCM()


class _SpanCM:
    """Context manager that opens one mirrored child per active parent
    span, re-activates the children as the nested set (so spans opened
    inside nest correctly), and ends them on exit."""

    __slots__ = ("name", "attrs", "children", "_prev")

    def __init__(self, name: str, attrs: dict):
        self.name = name
        self.attrs = attrs

    def __enter__(self):
        parents = getattr(_local, "spans", ())
        self.children = tuple(p.child(self.name, **self.attrs)
                              for p in parents)
        self._prev = parents
        _local.spans = self.children
        return self.children[0] if self.children else None

    def __exit__(self, *exc):
        for c in self.children:
            c.end()
        _local.spans = self._prev
        return False


def span(name: str, **attrs):
    """Open a child span under every active parent on this thread.

    Usage at an instrumented stage::

        with obs.span("scan", rows=n, kind="device"):
            ... stage work ...

    Returns the no-op manager when nothing is active, so the unsampled
    hot path pays a single thread-local read.
    """
    if not getattr(_local, "spans", ()):
        return _NULL
    return _SpanCM(name, attrs)


def is_tracing() -> bool:
    """True when the current thread has an active span set — use to gate
    trace-only work (e.g. ``block_until_ready`` for device timings)."""
    return bool(getattr(_local, "spans", ()))
