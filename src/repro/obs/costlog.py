"""Plan-execution cost recorder (DESIGN.md §3.12).

For traced (sampled) executions the serving tier appends one JSONL record
joining the *plan features* (``SearchPlan.describe()``: pipeline, beam
schedule, rerank width, index code format / point count, kernel config)
with the *measured costs* from the request's span tree (per-stage wall
and self times, candidate/survivor/granule counts) — this file IS the
calibration dataset for the ``execution="auto"`` cost model (ROADMAP
open item): each line is one (features, costs) training example.

Record schema (``"v": 1``) — every line is a JSON object with:

  ``v``            schema version (int, currently 1)
  ``seq``          request sequence number of the traced request
  ``latency_s``    end-to-end traced duration (root span)
  ``outcome``      root-span outcome attr ("ok" / "error" / ...)
  ``pipeline``, ``effective_pipeline``
                   from ``plan.describe()``
  ``query``        resolved execution-relevant Query fields (k, beam,
                   rerank_width, exact_rerank, ...)
  ``index``        ``{"n_points", "n_levels", "code_format", "store",
                   "payload_released"}`` — the capability-side features
  ``kernel``       the stamped kernel config dict (or None)
  ``spans``        ``{span_name: {"total_s", "self_s", "count"}}``
                   aggregated over the span tree
  ``counts``       summed numeric span attrs that carry work sizes
                   (``candidates``, ``survivors``, ``granules``,
                   ``rows``, ``batch``)
  plus any extra key the caller passes (``replica``, ``degraded``, ...).

``load(path)`` reads the file back into a list of dicts, skipping blank
lines, so the calibration consumer and the bench can assert on it.
"""

from __future__ import annotations

import json
import threading
from typing import Optional

from repro.obs import metrics as metrics_lib
from repro.obs import names as names_lib

SCHEMA_VERSION = 1

# Span attrs that carry per-stage work sizes worth summing into features.
_COUNT_ATTRS = ("candidates", "survivors", "granules", "rows", "batch")


def _walk(span_dict: dict):
    yield span_dict
    for c in span_dict.get("children", ()):
        yield from _walk(c)


def build_record(trace, describe: Optional[dict] = None,
                 extra: Optional[dict] = None) -> dict:
    """One cost record from a finished trace (``obs.Trace`` or its
    ``to_dict()`` form) plus the served plan's ``describe()`` dict."""
    td = trace if isinstance(trace, dict) else trace.to_dict()
    root = td["root"]
    spans: dict = {}
    counts: dict = {}
    for s in _walk(root):
        agg = spans.setdefault(
            s["name"], {"total_s": 0.0, "self_s": 0.0, "count": 0})
        agg["total_s"] += float(s["duration"])
        agg["self_s"] += float(s["self_time"])
        agg["count"] += 1
        for key in _COUNT_ATTRS:
            v = s.get("attrs", {}).get(key)
            if isinstance(v, (int, float)) and not isinstance(v, bool):
                counts[key] = counts.get(key, 0) + v
    for agg in spans.values():
        agg["total_s"] = round(agg["total_s"], 9)
        agg["self_s"] = round(agg["self_s"], 9)
    rec = dict(
        v=SCHEMA_VERSION,
        seq=td.get("seq"),
        latency_s=round(float(root["duration"]), 9),
        outcome=root.get("attrs", {}).get("outcome"),
        spans=spans,
        counts=counts,
    )
    if describe:
        caps = describe.get("capabilities", {}) or {}
        rec.update(
            pipeline=describe.get("pipeline"),
            effective_pipeline=describe.get("effective_pipeline"),
            query=describe.get("query"),
            kernel=describe.get("kernel"),
            index=dict(
                n_points=describe.get("index", {}).get("n_points"),
                n_levels=caps.get("n_levels"),
                code_format=describe.get("index", {}).get("code_format"),
                store=caps.get("store"),
                payload_released=caps.get("payload_released"),
            ),
        )
    if extra:
        rec.update(extra)
    return rec


class CostLog:
    """Append-only JSONL writer for plan-execution cost records.

    Thread-safe; one line per :meth:`record` call, flushed per record so a
    crashed process loses at most the in-flight line. Open lazily — a
    CostLog constructed but never fed creates no file.
    """

    def __init__(self, path: str):
        self.path = str(path)
        self._lock = threading.Lock()
        self._f = None
        self._n = 0
        self._m_records = metrics_lib.counter(names_lib.PLAN_COST_RECORDS)

    def record(self, trace, describe: Optional[dict] = None,
               **extra) -> dict:
        rec = build_record(trace, describe, extra)
        line = json.dumps(rec, sort_keys=True)
        with self._lock:
            if self._f is None:
                self._f = open(self.path, "a")
            self._f.write(line + "\n")
            self._f.flush()
            self._n += 1
        self._m_records.inc()
        return rec

    def __len__(self) -> int:
        with self._lock:
            return self._n

    def close(self) -> None:
        with self._lock:
            if self._f is not None:
                self._f.close()
                self._f = None


def load(path: str) -> list[dict]:
    """Read a cost log back: one dict per non-blank line."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


# the package-level (repro.obs) export name — "load" is too generic there
load_costlog = load
