"""Metric name catalogue — the single source of truth (DESIGN.md §3.11).

Every series the repo exports is named here, following the
``subsystem_name_unit`` convention:

* ``subsystem`` — one of :data:`SUBSYSTEMS` (the layer that owns the
  series: ``engine``, ``router``, ``plan``, ``store``, ``online``,
  ``autotune``, ``trace``, ``quality``, ``slo``);
* ``name`` — one or more snake_case words describing the quantity;
* ``unit`` — the trailing token, one of :data:`UNITS`: ``total``
  (monotonic counter), ``seconds`` / ``bytes`` (histogram, counter or
  gauge in that unit), ``ratio`` (0..1 gauge or histogram), ``count``
  (instantaneous gauge).

The default registry is *strict*: creating a series whose name is not in
:data:`CATALOGUE` raises, so an instrumented call site cannot invent an
undocumented name (``tests/test_obs.py`` lint-checks the catalogue itself
against :data:`NAME_RE`). Ad-hoc registries (tests, experiments) pass
``strict=False`` and are held only to the regex.
"""

from __future__ import annotations

import re

SUBSYSTEMS = (
    "engine", "router", "plan", "store", "online", "autotune", "trace",
    "quality", "slo",
)

UNITS = ("total", "seconds", "bytes", "ratio", "count")

# subsystem_name_unit: subsystem prefix, >= 1 snake_case middle word, unit
# suffix. The middle words are [a-z0-9]+ tokens (no leading/trailing/_ _).
NAME_RE = re.compile(
    r"^(?P<subsystem>" + "|".join(SUBSYSTEMS) + r")"
    r"(?:_[a-z0-9]+)+"
    r"_(?P<unit>" + "|".join(UNITS) + r")$"
)

# --------------------------------------------------------------------------
# engine — the batched request engine (serving/engine.py)
# --------------------------------------------------------------------------
ENGINE_REQUESTS = "engine_requests_total"
ENGINE_BATCHES = "engine_batches_total"
ENGINE_WRITES = "engine_writes_total"
ENGINE_WRITE_BATCHES = "engine_write_batches_total"
ENGINE_PREFETCHES = "engine_prefetches_total"
ENGINE_DEADLINE_DROPS = "engine_deadline_drops_total"
ENGINE_CANCELLED_SKIPS = "engine_cancelled_skips_total"
ENGINE_HANDLER_ERRORS = "engine_handler_errors_total"
ENGINE_BATCH_OCCUPANCY = "engine_batch_occupancy_ratio"
ENGINE_QUEUE_DEPTH = "engine_queue_depth_count"
ENGINE_QUEUE_WAIT = "engine_queue_wait_seconds"
ENGINE_HANDLER_TIME = "engine_handler_seconds"

# --------------------------------------------------------------------------
# router — the fault-tolerant replicated front (serving/router.py)
# --------------------------------------------------------------------------
ROUTER_REQUESTS = "router_requests_total"
ROUTER_DISPATCHES = "router_dispatches_total"
ROUTER_RETRIES = "router_retries_total"
ROUTER_HEDGES = "router_hedges_total"
ROUTER_HEDGE_WINS = "router_hedge_wins_total"
ROUTER_REJECTS = "router_admission_rejects_total"
ROUTER_DEGRADED = "router_degraded_total"
ROUTER_FAILURES = "router_failures_total"
ROUTER_DEADLINE_EXCEEDED = "router_deadline_exceeded_total"
ROUTER_HEALTH_TRANSITIONS = "router_health_transitions_total"
ROUTER_LATENCY = "router_request_seconds"

# --------------------------------------------------------------------------
# plan — the query/plan compiler (query/plan.py)
# --------------------------------------------------------------------------
PLAN_COMPILES = "plan_compiles_total"
PLAN_CACHE_HITS = "plan_cache_hits_total"
PLAN_REPLANS = "plan_replans_total"
PLAN_EXECUTIONS = "plan_executions_total"
PLAN_COST_RECORDS = "plan_cost_records_total"

# --------------------------------------------------------------------------
# store — the tiered leaf store's out-of-core payload (store/leaf_store.py)
# --------------------------------------------------------------------------
STORE_FETCHES = "store_granule_fetches_total"
STORE_HITS = "store_granule_hits_total"
STORE_FETCH_BYTES = "store_granule_fetch_bytes"
STORE_PREFETCHED = "store_prefetch_granules_total"
STORE_PREFETCH_USEFUL = "store_prefetch_useful_total"
STORE_CACHE_GRANULES = "store_granule_cache_count"
# The cache hierarchy in front of a remote payload tier (store/cache.py):
# per-tier hit/miss accounting (labelled ``tier=``), eviction counts, the
# decoded bytes resident in the host LRU, in-flight fetch dedup, and the
# async prefetch pool's queue depth / overflow drops.
STORE_CACHE_HITS = "store_cache_hits_total"
STORE_CACHE_MISSES = "store_cache_misses_total"
STORE_CACHE_EVICTIONS = "store_cache_evictions_total"
STORE_CACHE_RESIDENT = "store_cache_resident_bytes"
STORE_CACHE_HIT_RATIO = "store_cache_hit_ratio"
STORE_CACHE_INFLIGHT_DEDUP = "store_cache_inflight_dedup_total"
STORE_PREFETCH_QUEUE = "store_prefetch_queue_count"
STORE_PREFETCH_DROPS = "store_prefetch_drops_total"
# The remote object-store tier itself (store/remote.py): op counts, error
# counts (fault seam included), and the fetch latency/byte volume of
# granule reads against the backing store.
STORE_REMOTE_GETS = "store_remote_gets_total"
STORE_REMOTE_PUTS = "store_remote_puts_total"
STORE_REMOTE_ERRORS = "store_remote_errors_total"
STORE_REMOTE_FETCH_TIME = "store_remote_fetch_seconds"
STORE_REMOTE_FETCH_BYTES = "store_remote_fetch_bytes"

# --------------------------------------------------------------------------
# online — live writes / epoch swaps (online/epoch.py)
# --------------------------------------------------------------------------
ONLINE_WRITES = "online_writes_applied_total"
ONLINE_WRITE_ERRORS = "online_write_errors_total"
ONLINE_EPOCH_SWAPS = "online_epoch_swaps_total"
ONLINE_COMPACTION_TIME = "online_compaction_seconds"
ONLINE_DELTA_FILL = "online_delta_fill_ratio"
ONLINE_TOMBSTONES = "online_tombstones_count"

# --------------------------------------------------------------------------
# autotune — the block-size winner cache (kernels/autotune.py)
# --------------------------------------------------------------------------
AUTOTUNE_HITS = "autotune_lookup_hits_total"
AUTOTUNE_MISSES = "autotune_lookup_misses_total"
AUTOTUNE_RETUNES = "autotune_retunes_total"

# --------------------------------------------------------------------------
# trace — the tracer's own accounting (obs/trace.py)
# --------------------------------------------------------------------------
TRACE_SAMPLED = "trace_sampled_total"
TRACE_FINISHED = "trace_finished_total"

# --------------------------------------------------------------------------
# quality — the online recall estimator (obs/quality.py)
# --------------------------------------------------------------------------
QUALITY_RECALL = "quality_recall_ratio"
QUALITY_RECALL_MEAN = "quality_recall_mean_ratio"
QUALITY_RECALL_LO = "quality_recall_wilson_lo_ratio"
QUALITY_RECALL_HI = "quality_recall_wilson_hi_ratio"
QUALITY_SAMPLED = "quality_shadow_sampled_total"
QUALITY_ANSWERED = "quality_shadow_answered_total"
QUALITY_DROPPED = "quality_shadow_dropped_total"
QUALITY_ERRORS = "quality_shadow_errors_total"
QUALITY_PENDING = "quality_shadow_pending_count"
QUALITY_LAG = "quality_shadow_lag_seconds"

# --------------------------------------------------------------------------
# slo — the declarative SLO tracker (obs/slo.py)
# --------------------------------------------------------------------------
SLO_SLI = "slo_sli_ratio"
SLO_BURN = "slo_burn_rate_ratio"
SLO_BUDGET = "slo_budget_remaining_ratio"
SLO_ALERTS = "slo_alerts_total"
SLO_EVALUATIONS = "slo_evaluations_total"

CATALOGUE: dict[str, tuple[str, str]] = {
    # name -> (kind, help)
    ENGINE_REQUESTS: ("counter", "search-like requests served per engine"),
    ENGINE_BATCHES: ("counter", "search-like batches dispatched"),
    ENGINE_WRITES: ("counter", "write ops applied between batches"),
    ENGINE_WRITE_BATCHES: ("counter", "write runs handed to the handler"),
    ENGINE_PREFETCHES: ("counter", "between-batch prefetch snapshots run"),
    ENGINE_DEADLINE_DROPS: ("counter", "requests dropped past their deadline"),
    ENGINE_CANCELLED_SKIPS: ("counter", "cancelled requests skipped at "
                                        "batch assembly"),
    ENGINE_HANDLER_ERRORS: ("counter", "batches failed by a handler error"),
    ENGINE_BATCH_OCCUPANCY: ("histogram", "valid rows / batch_size per batch"),
    ENGINE_QUEUE_DEPTH: ("gauge", "requests queued when a batch was taken"),
    ENGINE_QUEUE_WAIT: ("histogram", "enqueue -> taken-into-batch wait"),
    ENGINE_HANDLER_TIME: ("histogram", "handler call duration per batch"),
    ROUTER_REQUESTS: ("counter", "requests admitted by the router"),
    ROUTER_DISPATCHES: ("counter", "attempts dispatched, by replica"),
    ROUTER_RETRIES: ("counter", "re-dispatches after a failed attempt"),
    ROUTER_HEDGES: ("counter", "hedge twin attempts fired"),
    ROUTER_HEDGE_WINS: ("counter", "requests won by the hedge twin"),
    ROUTER_REJECTS: ("counter", "admission-control rejects (Overloaded)"),
    ROUTER_DEGRADED: ("counter", "requests rewritten onto the degraded plan"),
    ROUTER_FAILURES: ("counter", "failed attempts, by replica"),
    ROUTER_DEADLINE_EXCEEDED: ("counter", "requests that missed their "
                                          "deadline"),
    ROUTER_HEALTH_TRANSITIONS: ("counter", "health state machine edges, "
                                           "labelled from/to"),
    ROUTER_LATENCY: ("histogram", "end-to-end router request latency"),
    PLAN_COMPILES: ("counter", "plans compiled, by pipeline"),
    PLAN_CACHE_HITS: ("counter", "plan-cache hits, by pipeline"),
    PLAN_REPLANS: ("counter", "stale-fingerprint transparent replans"),
    PLAN_EXECUTIONS: ("counter", "plan executions, by pipeline"),
    PLAN_COST_RECORDS: ("counter", "plan-execution cost records appended "
                                   "to the cost log"),
    STORE_FETCHES: ("counter", "granules fetched from the exact payload"),
    STORE_HITS: ("counter", "granule requests served from the LRU"),
    STORE_FETCH_BYTES: ("counter", "bytes fetched from the exact payload"),
    STORE_PREFETCHED: ("counter", "granules warmed by prefetch"),
    STORE_PREFETCH_USEFUL: ("counter", "prefetched granules later hit by a "
                                       "real fetch"),
    STORE_CACHE_GRANULES: ("gauge", "granules resident in the exact-payload "
                                    "LRU"),
    STORE_CACHE_HITS: ("counter", "granule cache hits, by tier"),
    STORE_CACHE_MISSES: ("counter", "granule cache misses, by tier"),
    STORE_CACHE_EVICTIONS: ("counter", "granules evicted from the host LRU, "
                                       "by tier"),
    STORE_CACHE_RESIDENT: ("gauge", "decoded granule bytes resident in the "
                                    "host LRU, by tier"),
    STORE_CACHE_HIT_RATIO: ("gauge", "lifetime hit ratio of the granule "
                                     "cache, by tier"),
    STORE_CACHE_INFLIGHT_DEDUP: ("counter", "fetches coalesced onto an "
                                            "in-flight fetch of the same "
                                            "granule"),
    STORE_PREFETCH_QUEUE: ("gauge", "granule keys queued in the async "
                                    "prefetch pool"),
    STORE_PREFETCH_DROPS: ("counter", "prefetch keys dropped (queue at "
                                      "depth bound)"),
    STORE_REMOTE_GETS: ("counter", "objects fetched from the remote store"),
    STORE_REMOTE_PUTS: ("counter", "objects written to the remote store"),
    STORE_REMOTE_ERRORS: ("counter", "remote-store ops that raised "
                                     "(injected faults included)"),
    STORE_REMOTE_FETCH_TIME: ("histogram", "remote granule fetch latency"),
    STORE_REMOTE_FETCH_BYTES: ("counter", "bytes fetched from the remote "
                                          "store"),
    ONLINE_WRITES: ("counter", "upsert/delete ops applied, by op"),
    ONLINE_WRITE_ERRORS: ("counter", "write ops that failed per-op"),
    ONLINE_EPOCH_SWAPS: ("counter", "compaction epoch swaps published"),
    ONLINE_COMPACTION_TIME: ("histogram", "compact-and-swap duration"),
    ONLINE_DELTA_FILL: ("gauge", "delta buffer fill ratio after last write"),
    ONLINE_TOMBSTONES: ("gauge", "tombstoned slots after last write"),
    AUTOTUNE_HITS: ("counter", "winner-cache lookups that found knobs"),
    AUTOTUNE_MISSES: ("counter", "winner-cache lookups that missed"),
    AUTOTUNE_RETUNES: ("counter", "winners recorded (cache mutations)"),
    TRACE_SAMPLED: ("counter", "requests picked by the 1-in-N sampler"),
    TRACE_FINISHED: ("counter", "sampled traces finished and retained"),
    QUALITY_RECALL: ("histogram", "per-shadow-sample recall@k, by pipeline "
                                  "and leg"),
    QUALITY_RECALL_MEAN: ("gauge", "running recall@k estimate, by pipeline "
                                   "and leg"),
    QUALITY_RECALL_LO: ("gauge", "Wilson 95% lower bound on the recall "
                                 "estimate"),
    QUALITY_RECALL_HI: ("gauge", "Wilson 95% upper bound on the recall "
                                 "estimate"),
    QUALITY_SAMPLED: ("counter", "served queries picked for shadow "
                                 "re-answering"),
    QUALITY_ANSWERED: ("counter", "shadow samples answered exactly by the "
                                  "worker"),
    QUALITY_DROPPED: ("counter", "shadow samples dropped (queue full)"),
    QUALITY_ERRORS: ("counter", "shadow re-answers that raised"),
    QUALITY_PENDING: ("gauge", "shadow samples queued awaiting the worker"),
    QUALITY_LAG: ("histogram", "serve -> shadow-answer lag per sample"),
    SLO_SLI: ("gauge", "rolling-window SLI value, by objective"),
    SLO_BURN: ("gauge", "error-budget burn rate, by objective and window"),
    SLO_BUDGET: ("gauge", "fraction of the window's error budget left, by "
                          "objective"),
    SLO_ALERTS: ("counter", "multi-rate burn alerts fired, by objective"),
    SLO_EVALUATIONS: ("counter", "SLO evaluation passes run"),
}


def check(name: str) -> None:
    """Raise ValueError unless ``name`` follows ``subsystem_name_unit``."""
    if NAME_RE.match(name) is None:
        raise ValueError(
            f"metric name {name!r} does not match the subsystem_name_unit "
            f"convention (subsystems: {SUBSYSTEMS}; units: {UNITS})"
        )


def subsystem(name: str) -> str:
    """The owning subsystem of a conventional metric name."""
    return name.split("_", 1)[0]
