"""repro.obs — process-wide telemetry: metrics registry + request tracing.

See DESIGN.md §3.11. Quick taste::

    from repro import obs

    obs.counter(obs.names.ENGINE_REQUESTS, engine="r0").inc()
    snap = obs.snapshot()            # plain nested dict
    print(obs.to_prometheus(snap))   # Prometheus text exposition

    sampler = obs.TraceSampler(every_n=8)
    t = sampler.sample("request", seq=16)   # deterministic 1-in-N
    ...
    t.finish(); print(t.render())           # text flamegraph

Only stdlib is imported here — every layer (including kernels/autotune,
which loads at import time) can depend on obs without cycles.
"""

from repro.obs import names
from repro.obs.metrics import (
    DEFAULT_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsDumper,
    MetricsRegistry,
    counter,
    gauge,
    histogram,
    registry,
    reset,
    set_enabled,
    snapshot,
    timed,
    to_json,
    to_prometheus,
)
from repro.obs.trace import (
    Span,
    Trace,
    TraceBuffer,
    TraceSampler,
    activate,
    active_spans,
    is_tracing,
    span,
)

__all__ = [
    "names",
    # metrics
    "DEFAULT_BUCKETS",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsDumper",
    "MetricsRegistry",
    "counter",
    "gauge",
    "histogram",
    "registry",
    "reset",
    "set_enabled",
    "snapshot",
    "timed",
    "to_json",
    "to_prometheus",
    # tracing
    "Span",
    "Trace",
    "TraceBuffer",
    "TraceSampler",
    "activate",
    "active_spans",
    "is_tracing",
    "span",
]
