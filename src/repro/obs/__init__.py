"""repro.obs — process-wide telemetry: metrics registry + request tracing.

See DESIGN.md §3.11. Quick taste::

    from repro import obs

    obs.counter(obs.names.ENGINE_REQUESTS, engine="r0").inc()
    snap = obs.snapshot()            # plain nested dict
    print(obs.to_prometheus(snap))   # Prometheus text exposition

    sampler = obs.TraceSampler(every_n=8)
    t = sampler.sample("request", seq=16)   # deterministic 1-in-N
    ...
    t.finish(); print(t.render())           # text flamegraph

Only stdlib (+numpy) is imported here — every layer (including
kernels/autotune, which loads at import time) can depend on obs without
cycles; the recall estimator's jax-side work (``baselines.exact``,
``online.live_dataset``) is imported lazily inside its worker.
"""

from repro.obs import names
from repro.obs.metrics import (
    DEFAULT_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsDumper,
    MetricsRegistry,
    counter,
    gauge,
    histogram,
    registry,
    reset,
    set_enabled,
    snapshot,
    timed,
    to_json,
    to_prometheus,
)
from repro.obs.trace import (
    Span,
    Trace,
    TraceBuffer,
    TraceSampler,
    activate,
    active_spans,
    is_tracing,
    span,
)
from repro.obs.quality import RecallEstimator, wilson
from repro.obs.costlog import CostLog, build_record, load_costlog
from repro.obs.slo import SLOSpec, SLOTracker
from repro.obs.report import Dashboard, build_report, render_dashboard

__all__ = [
    "names",
    # metrics
    "DEFAULT_BUCKETS",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsDumper",
    "MetricsRegistry",
    "counter",
    "gauge",
    "histogram",
    "registry",
    "reset",
    "set_enabled",
    "snapshot",
    "timed",
    "to_json",
    "to_prometheus",
    # tracing
    "Span",
    "Trace",
    "TraceBuffer",
    "TraceSampler",
    "activate",
    "active_spans",
    "is_tracing",
    "span",
    # quality / cost / SLO / report (DESIGN.md §3.12)
    "RecallEstimator",
    "wilson",
    "CostLog",
    "build_record",
    "load_costlog",
    "SLOSpec",
    "SLOTracker",
    "Dashboard",
    "build_report",
    "render_dashboard",
]
