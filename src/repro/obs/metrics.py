"""Process-wide metrics registry (DESIGN.md §3.11).

Three instrument kinds, all thread-safe and lock-light (one small lock per
series, held only for the arithmetic — no lock spans an export):

* **Counter** — monotonic float, ``inc(v)``;
* **Gauge** — instantaneous float, ``set(v)`` / ``inc`` / ``dec``;
* **Histogram** — fixed log-spaced buckets (factor 2 by default), counts +
  sum + min/max, with a ``percentile(q)`` estimate that interpolates inside
  the winning bucket. Fixed buckets keep ``observe`` allocation-free and
  make concurrent snapshots trivially consistent-enough (a snapshot may
  straddle one in-flight observation; it can never be torn mid-bucket).

Series are labelled: ``registry.counter(name, replica="r0")`` — each
distinct ``(name, labels)`` pair is one series, created on first touch and
cached by the caller-facing handle lookup. The **default registry**
(:func:`registry`) is strict: names must come from the documented catalogue
(``obs/names.py``) — instrumented call sites cannot invent undocumented
names. ``MetricsRegistry(strict=False)`` relaxes that to the naming regex
(tests, experiments).

``snapshot()`` returns a plain nested dict (JSON-ready);
:func:`to_prometheus` / :func:`to_json` render it; :class:`MetricsDumper`
writes it periodically to a file or stdout. ``set_enabled(False)`` turns
every instrument into a no-op (the overhead-guard baseline).
"""

from __future__ import annotations

import bisect
import json
import math
import sys
import threading
import time
from typing import Optional, TextIO, Union

from repro.obs import names as names_lib

# Default histogram bucket upper bounds: factor-2 log spacing from 1 µs to
# ~137 s (28 finite buckets + the +Inf overflow). Wide enough for
# microsecond kernel stages and multi-second compactions alike.
DEFAULT_BUCKETS = tuple(1e-6 * 2 ** i for i in range(28))


class _Series:
    """Base: one labelled time series. ``kind``/``name``/``labels`` are
    frozen at creation; the value side is guarded by a per-series lock."""

    __slots__ = ("name", "labels", "_lock", "_registry")

    kind = "abstract"

    def __init__(self, name: str, labels: tuple, registry: "MetricsRegistry"):
        self.name = name
        self.labels = labels  # sorted tuple of (key, value) strings
        self._lock = threading.Lock()
        self._registry = registry


class Counter(_Series):
    __slots__ = ("value",)

    kind = "counter"

    def __init__(self, name, labels, registry):
        super().__init__(name, labels, registry)
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        if not self._registry.enabled:
            return
        with self._lock:
            self.value += v

    def snapshot(self):
        with self._lock:
            return self.value


class Gauge(_Series):
    __slots__ = ("value",)

    kind = "gauge"

    def __init__(self, name, labels, registry):
        super().__init__(name, labels, registry)
        self.value = 0.0

    def set(self, v: float) -> None:
        if not self._registry.enabled:
            return
        with self._lock:
            self.value = float(v)

    def inc(self, v: float = 1.0) -> None:
        if not self._registry.enabled:
            return
        with self._lock:
            self.value += v

    def dec(self, v: float = 1.0) -> None:
        self.inc(-v)

    def snapshot(self):
        with self._lock:
            return self.value


class Histogram(_Series):
    __slots__ = ("bounds", "counts", "sum", "count", "min", "max")

    kind = "histogram"

    def __init__(self, name, labels, registry, bounds=DEFAULT_BUCKETS):
        super().__init__(name, labels, registry)
        self.bounds = tuple(float(b) for b in bounds)
        if list(self.bounds) != sorted(set(self.bounds)):
            raise ValueError("histogram bounds must be strictly increasing")
        # counts[i] = observations with v <= bounds[i] (non-cumulative per
        # bucket here; cumulated at export); counts[-1] is the +Inf bucket.
        self.counts = [0] * (len(self.bounds) + 1)
        self.sum = 0.0
        self.count = 0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        if not self._registry.enabled:
            return
        v = float(v)
        if not math.isfinite(v):
            # A NaN/inf observation would poison sum/min/max (and NaN
            # compares false everywhere, so it would land in bucket 0).
            # Swallow it: a broken caller must not corrupt the series.
            return
        i = bisect.bisect_left(self.bounds, v)
        with self._lock:
            self.counts[i] += 1
            self.sum += v
            self.count += 1
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v

    def percentile(self, q: float) -> float:
        """Estimate the q-quantile (q in [0, 1]) from the bucket counts:
        find the bucket holding the q-th observation and interpolate
        linearly inside it (the estimate is off by at most one bucket
        width — a factor of the log spacing; tests compare against numpy).
        """
        with self._lock:
            counts = list(self.counts)
            total = self.count
            lo_seen, hi_seen = self.min, self.max
        if total == 0:
            return math.nan
        target = q * total
        acc = 0.0
        for i, c in enumerate(counts):
            if c == 0:
                continue
            lo = self.bounds[i - 1] if i > 0 else 0.0
            hi = self.bounds[i] if i < len(self.bounds) else hi_seen
            # clamp the edge buckets to the really-seen range
            lo = max(lo, lo_seen if acc == 0.0 else lo)
            hi = min(hi, hi_seen)
            if hi < lo:
                lo = hi
            if acc + c >= target:
                frac = (target - acc) / c
                return lo + (hi - lo) * min(max(frac, 0.0), 1.0)
            acc += c
        return hi_seen

    def snapshot(self):
        with self._lock:
            return dict(
                buckets=list(self.bounds),
                counts=list(self.counts),
                sum=self.sum,
                count=self.count,
                min=(None if self.count == 0 else self.min),
                max=(None if self.count == 0 else self.max),
            )


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


def _label_key(labels: dict) -> tuple:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class MetricsRegistry:
    """Series factory + snapshot surface. See the module docstring."""

    def __init__(self, *, strict: bool = True):
        self.strict = strict
        self.enabled = True
        self._lock = threading.Lock()  # guards series *creation* only
        self._series: dict = {}  # (name, label_key) -> series
        self._kinds: dict = {}  # name -> kind (one kind per name)

    # -- instrument factories ------------------------------------------------

    def counter(self, name: str, **labels) -> Counter:
        return self._get(name, "counter", labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(name, "gauge", labels)

    def histogram(self, name: str, bounds=DEFAULT_BUCKETS,
                  **labels) -> Histogram:
        return self._get(name, "histogram", labels, bounds=bounds)

    def _get(self, name: str, kind: str, labels: dict, **kw):
        key = (name, _label_key(labels))
        s = self._series.get(key)  # racy fast path: dicts never lose keys
        if s is not None:
            if s.kind != kind:
                raise ValueError(
                    f"metric {name!r} already registered as a {s.kind}, "
                    f"requested as a {kind}"
                )
            return s
        with self._lock:
            s = self._series.get(key)
            if s is not None:
                return s
            names_lib.check(name)
            if self.strict:
                cat = names_lib.CATALOGUE.get(name)
                if cat is None:
                    raise ValueError(
                        f"metric {name!r} is not in the documented catalogue "
                        f"(obs/names.py) — add it there, or use a "
                        f"strict=False registry"
                    )
                if cat[0] != kind:
                    raise ValueError(
                        f"metric {name!r} is documented as a {cat[0]}, "
                        f"requested as a {kind}"
                    )
            seen = self._kinds.get(name)
            if seen is not None and seen != kind:
                raise ValueError(
                    f"metric {name!r} already registered as a {seen}, "
                    f"requested as a {kind}"
                )
            self._kinds[name] = kind
            s = _KINDS[kind](name, key[1], self, **kw)
            self._series[key] = s
            return s

    # -- export --------------------------------------------------------------

    def snapshot(self) -> dict:
        """Plain nested dict: ``{name: {"kind": ..., "help": ...,
        "series": [{"labels": {...}, "value"| "hist": ...}, ...]}}``.
        Values are consistent per series (each is read under its lock)."""
        with self._lock:
            series = list(self._series.values())
        out: dict = {}
        for s in sorted(series, key=lambda s: (s.name, s.labels)):
            entry = out.setdefault(s.name, dict(
                kind=s.kind,
                help=names_lib.CATALOGUE.get(s.name, ("", ""))[1],
                series=[],
            ))
            row: dict = {"labels": dict(s.labels)}
            if s.kind == "histogram":
                row["hist"] = s.snapshot()
            else:
                row["value"] = s.snapshot()
            entry["series"].append(row)
        return out

    def n_series(self) -> int:
        with self._lock:
            return len(self._series)

    def reset(self) -> None:
        """Drop every series (tests; a fresh process-equivalent state)."""
        with self._lock:
            self._series.clear()
            self._kinds.clear()


# ---------------------------------------------------------------------------
# Exporters
# ---------------------------------------------------------------------------


def _escape_label_value(v) -> str:
    """Escape a label value per the Prometheus text exposition format:
    backslash, double-quote and newline must be backslash-escaped inside
    the quoted value."""
    return (str(v).replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def _escape_help(text: str) -> str:
    # HELP lines escape backslash and newline (quotes are legal there).
    return str(text).replace("\\", "\\\\").replace("\n", "\\n")


def _fmt_labels(labels: dict, extra: Optional[dict] = None) -> str:
    items = dict(labels)
    if extra:
        items.update(extra)
    if not items:
        return ""
    body = ",".join(f'{k}="{_escape_label_value(v)}"'
                    for k, v in sorted(items.items()))
    return "{" + body + "}"


def _fmt_val(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    f = float(v)
    return str(int(f)) if f == int(f) else repr(f)


def to_prometheus(snapshot: dict) -> str:
    """Render a :meth:`MetricsRegistry.snapshot` in the Prometheus text
    exposition format (histograms as cumulative ``_bucket``/``_sum``/
    ``_count`` families)."""
    lines = []
    for name, entry in sorted(snapshot.items()):
        lines.append(
            f"# HELP {name} {_escape_help(entry.get('help', ''))}".rstrip())
        lines.append(f"# TYPE {name} {entry['kind']}")
        for row in entry["series"]:
            labels = row["labels"]
            if entry["kind"] != "histogram":
                lines.append(
                    f"{name}{_fmt_labels(labels)} {_fmt_val(row['value'])}"
                )
                continue
            h = row["hist"]
            acc = 0
            for bound, c in zip(
                list(h["buckets"]) + [math.inf], h["counts"]
            ):
                acc += c
                lines.append(
                    f"{name}_bucket"
                    f"{_fmt_labels(labels, {'le': _fmt_val(bound)})} {acc}"
                )
            lines.append(f"{name}_sum{_fmt_labels(labels)} "
                         f"{_fmt_val(h['sum'])}")
            lines.append(f"{name}_count{_fmt_labels(labels)} {h['count']}")
    return "\n".join(lines) + "\n"


def to_json(snapshot: dict, *, indent: Optional[int] = None) -> str:
    """Render a snapshot as JSON (the snapshot is already a plain dict)."""
    return json.dumps(snapshot, indent=indent, sort_keys=True)


class MetricsDumper:
    """Periodically write the registry snapshot to a file or stream.

    ``path`` of ``"-"`` dumps Prometheus text to stdout; a ``.prom`` path
    writes Prometheus text, anything else JSON. The file is rewritten whole
    each period (the node-exporter textfile pattern). ``dump()`` forces one
    write; ``close()`` stops the thread and writes a final snapshot.
    """

    def __init__(self, reg: MetricsRegistry, path: str = "-",
                 period_s: float = 10.0):
        self.reg = reg
        self.path = path
        self.period_s = float(period_s)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        if self.period_s > 0:
            self._thread = threading.Thread(target=self._loop, daemon=True)
            self._thread.start()

    def _render(self) -> str:
        snap = self.reg.snapshot()
        if self.path == "-" or self.path.endswith(".prom"):
            return to_prometheus(snap)
        return to_json(snap, indent=1)

    def dump(self, stream: Optional[TextIO] = None) -> None:
        text = self._render()
        if stream is not None:
            stream.write(text)
        elif self.path == "-":
            sys.stdout.write(text)
            sys.stdout.flush()
        else:
            tmp = self.path + ".tmp"
            with open(tmp, "w") as f:
                f.write(text)
            import os

            os.replace(tmp, self.path)

    def _loop(self) -> None:
        while not self._stop.wait(self.period_s):
            try:
                self.dump()
            except Exception:  # noqa: BLE001 — telemetry must never kill
                pass  # the process it observes

    def close(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
        try:
            self.dump()
        except Exception:  # noqa: BLE001
            pass


# ---------------------------------------------------------------------------
# The process-wide default registry
# ---------------------------------------------------------------------------

_DEFAULT = MetricsRegistry(strict=True)


def registry() -> MetricsRegistry:
    """The process-wide default registry every instrumented layer uses."""
    return _DEFAULT


def counter(name: str, **labels) -> Counter:
    return _DEFAULT.counter(name, **labels)


def gauge(name: str, **labels) -> Gauge:
    return _DEFAULT.gauge(name, **labels)


def histogram(name: str, bounds=DEFAULT_BUCKETS, **labels) -> Histogram:
    return _DEFAULT.histogram(name, bounds, **labels)


def snapshot() -> dict:
    return _DEFAULT.snapshot()


def reset() -> None:
    _DEFAULT.reset()


def set_enabled(on: bool) -> None:
    """Globally enable/disable the default registry's instruments (the
    overhead-guard baseline: disabled instruments return immediately)."""
    _DEFAULT.enabled = bool(on)


def timed(hist: Histogram):
    """Context manager observing its block's wall duration into ``hist``."""
    return _Timed(hist)


class _Timed:
    __slots__ = ("hist", "t0")

    def __init__(self, hist: Histogram):
        self.hist = hist

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.hist.observe(time.perf_counter() - self.t0)
        return False
