"""Declarative SLO tracking with multi-rate error-budget burn alerts
(DESIGN.md §3.12).

An :class:`SLOSpec` names up to three objectives over a rolling window:

* **latency** — a p99 target: at most ``latency_budget`` (default 1%) of
  requests may exceed ``latency_p99_s``;
* **availability** — at most ``1 - availability`` of requests may fail
  (caller-visible error, deadline, admission reject);
* **recall** — at most ``recall_budget`` (default 10%) of shadow-sampled
  recall estimates (``obs.quality``) may fall below ``recall_floor``.

:class:`SLOTracker` keeps a bounded per-objective ring of (timestamp,
good/bad) events and, on :meth:`evaluate`, computes the SLI and the
*burn rate* — the fraction of the error budget consumed, per unit budget
— over two windows: the full ``window_s`` (slow, confident) and a short
``window_s * fast_window_frac`` (fast, reactive). The multi-rate rule
(the SRE-workbook shape): alert only when BOTH windows burn faster than
``burn_threshold`` — the slow window stops one latency spike from
paging, the fast window clears the alert promptly once the burn stops.

Alert edges are surfaced the same way the router's health transitions
are: a counter (``slo_alerts_total``, labelled objective), gauge series
for SLI / burn / budget-remaining per objective, and a bounded
:meth:`events` log with the numbers that fired the edge.

The tracker is wired into the router (``Router(..., slo=...)``): every
request completion records latency + success, the shadow recall
estimator feeds ``record_recall``, and the router's prober thread calls
``maybe_evaluate`` so evaluation never costs the request path anything.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Optional

from repro.obs import metrics as metrics_lib
from repro.obs import names as names_lib


@dataclasses.dataclass(frozen=True)
class SLOSpec:
    """Declarative SLO: targets + windowing + the alert rule. Objectives
    whose target is None are not tracked."""

    name: str = "serve"
    latency_p99_s: Optional[float] = None   # p99 latency target
    latency_budget: float = 0.01            # p99 => 1% may exceed it
    availability: Optional[float] = 0.999   # fraction of requests that
    recall_floor: Optional[float] = None    # must succeed / clear floor
    recall_budget: float = 0.10
    window_s: float = 60.0                  # slow (confident) window
    fast_window_frac: float = 1.0 / 12.0    # fast window = window_s/12
    burn_threshold: float = 2.0             # alert when BOTH windows
    min_samples: int = 8                    # exceed this burn rate
    events_maxlen: int = 1024

    def budgets(self) -> dict:
        """objective -> error budget (allowed bad fraction per window)."""
        out = {}
        if self.latency_p99_s is not None:
            out["latency"] = max(self.latency_budget, 1e-9)
        if self.availability is not None:
            out["availability"] = max(1.0 - self.availability, 1e-9)
        if self.recall_floor is not None:
            out["recall"] = max(self.recall_budget, 1e-9)
        return out


class SLOTracker:
    """See the module docstring. Thread-safe; all methods are O(window)."""

    def __init__(self, spec: SLOSpec):
        self.spec = spec
        self._lock = threading.Lock()
        # objective -> deque[(t, ok: bool)]
        self._rings: dict = {obj: collections.deque()
                             for obj in spec.budgets()}
        self._active: dict = {obj: False for obj in self._rings}
        self._events: collections.deque = collections.deque(
            maxlen=spec.events_maxlen)
        self._t0 = time.time()
        self._last_eval = 0.0
        self._m_alerts = {
            obj: metrics_lib.counter(names_lib.SLO_ALERTS, objective=obj)
            for obj in self._rings
        }
        self._m_evals = metrics_lib.counter(names_lib.SLO_EVALUATIONS)

    # -- feeds (hot path: one deque append per objective) ---------------------

    def record_request(self, latency_s: float, ok: bool) -> None:
        now = time.time()
        with self._lock:
            if "availability" in self._rings:
                self._rings["availability"].append((now, ok))
            if "latency" in self._rings:
                good = ok and latency_s <= self.spec.latency_p99_s
                self._rings["latency"].append((now, good))
            self._prune(now)

    def record_recall(self, recall: float) -> None:
        if "recall" not in self._rings:
            return
        now = time.time()
        with self._lock:
            self._rings["recall"].append(
                (now, recall >= self.spec.recall_floor))
            self._prune(now)

    def _prune(self, now: float) -> None:
        horizon = now - self.spec.window_s
        for ring in self._rings.values():
            while ring and ring[0][0] < horizon:
                ring.popleft()

    # -- evaluation ------------------------------------------------------------

    def _window_stats(self, ring, now: float, window: float):
        horizon = now - window
        n = bad = 0
        for t, good in ring:
            if t >= horizon:
                n += 1
                bad += not good
        return n, bad

    def evaluate(self, now: Optional[float] = None) -> dict:
        """One evaluation pass: recompute every objective's SLI and burn
        rates, update the gauge series, and fire/clear multi-rate alerts.
        Returns :meth:`status`."""
        spec = self.spec
        now = time.time() if now is None else now
        fast_w = spec.window_s * spec.fast_window_frac
        fired = []
        with self._lock:
            self._prune(now)
            for obj, budget in spec.budgets().items():
                ring = self._rings[obj]
                n_slow, bad_slow = self._window_stats(ring, now,
                                                      spec.window_s)
                n_fast, bad_fast = self._window_stats(ring, now, fast_w)
                sli = 1.0 - (bad_slow / n_slow) if n_slow else 1.0
                burn_slow = ((bad_slow / n_slow) / budget) if n_slow \
                    else 0.0
                burn_fast = ((bad_fast / n_fast) / budget) if n_fast \
                    else 0.0
                metrics_lib.gauge(names_lib.SLO_SLI, objective=obj
                                  ).set(sli)
                metrics_lib.gauge(names_lib.SLO_BURN, objective=obj,
                                  window="slow").set(burn_slow)
                metrics_lib.gauge(names_lib.SLO_BURN, objective=obj,
                                  window="fast").set(burn_fast)
                metrics_lib.gauge(names_lib.SLO_BUDGET, objective=obj
                                  ).set(max(0.0, 1.0 - burn_slow))
                burning = (burn_slow > spec.burn_threshold
                           and burn_fast > spec.burn_threshold
                           and n_fast >= spec.min_samples)
                if burning and not self._active[obj]:
                    self._active[obj] = True
                    self._m_alerts[obj].inc()
                    self._events.append(dict(
                        t=round(now - self._t0, 4), event="burn_alert",
                        objective=obj, burn_slow=round(burn_slow, 3),
                        burn_fast=round(burn_fast, 3), sli=round(sli, 4),
                        n=n_slow,
                    ))
                    fired.append(obj)
                elif not burning and self._active[obj]:
                    self._active[obj] = False
                    self._events.append(dict(
                        t=round(now - self._t0, 4), event="burn_clear",
                        objective=obj, burn_slow=round(burn_slow, 3),
                        burn_fast=round(burn_fast, 3), sli=round(sli, 4),
                        n=n_slow,
                    ))
            self._last_eval = now
        self._m_evals.inc()
        return self.status()

    def maybe_evaluate(self, min_interval_s: float = 0.25
                       ) -> Optional[dict]:
        """Rate-limited :meth:`evaluate` — the prober-thread entry point."""
        with self._lock:
            if time.time() - self._last_eval < min_interval_s:
                return None
        return self.evaluate()

    # -- read side -------------------------------------------------------------

    def events(self) -> list:
        """Snapshot of the bounded alert/clear event log (oldest first)."""
        with self._lock:
            return list(self._events)

    def alert_counts(self) -> dict:
        """objective -> number of burn alerts fired so far."""
        with self._lock:
            c = collections.Counter(
                e["objective"] for e in self._events
                if e["event"] == "burn_alert")
        return dict(c)

    def status(self) -> dict:
        """Per-objective summary for dashboards/benches: samples in
        window, SLI, slow/fast burn, budget remaining, alert active."""
        spec = self.spec
        now = time.time()
        fast_w = spec.window_s * spec.fast_window_frac
        out = {}
        with self._lock:
            for obj, budget in spec.budgets().items():
                ring = self._rings[obj]
                n_slow, bad_slow = self._window_stats(ring, now,
                                                      spec.window_s)
                n_fast, bad_fast = self._window_stats(ring, now, fast_w)
                burn_slow = ((bad_slow / n_slow) / budget) if n_slow \
                    else 0.0
                burn_fast = ((bad_fast / n_fast) / budget) if n_fast \
                    else 0.0
                out[obj] = dict(
                    n=n_slow,
                    sli=round(1.0 - (bad_slow / n_slow), 4) if n_slow
                    else None,
                    burn_slow=round(burn_slow, 3),
                    burn_fast=round(burn_fast, 3),
                    budget_remaining=round(max(0.0, 1.0 - burn_slow), 3),
                    alerting=self._active[obj],
                )
        return out
