"""Stateless, restart-exact batch pipeline with host prefetch.

``BatchPipeline`` wraps a pure ``make_batch(step) -> pytree`` function:

* **stateless** — the batch for step ``s`` depends only on ``(seed, s)``.
  Restarting from a checkpoint at step ``s`` replays the identical data
  stream (bitwise), which is what makes checkpoint/restart and straggler
  re-execution exact. No iterator state to snapshot.
* **prefetch** — a daemon thread keeps ``prefetch`` batches ahead of the
  consumer; generation overlaps the device step.
* **sharding** — batches are placed with ``jax.device_put`` against the
  step's input shardings so the host never materialises more than its own
  slice per device (single-process here; the multi-host variant would slice
  ``make_batch`` output by ``jax.process_index()`` — hook provided).
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Optional

import jax


class BatchPipeline:
    def __init__(
        self,
        make_batch: Callable[[int], dict],
        *,
        start_step: int = 0,
        prefetch: int = 2,
        shardings=None,
        process_slice: Optional[Callable[[dict, int, int], dict]] = None,
    ):
        self._make = make_batch
        self._shardings = shardings
        self._slice = process_slice
        self._q: queue.Queue = queue.Queue(maxsize=max(prefetch, 1))
        self._next = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._next
        while not self._stop.is_set():
            batch = self._make(step)
            if self._slice is not None:
                batch = self._slice(batch, jax.process_index(),
                                    jax.process_count())
            if self._shardings is not None:
                batch = jax.device_put(batch, self._shardings)
            # block until the consumer drains; bounded queue = bounded memory
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def get(self) -> tuple[int, dict]:
        """(step, batch) in order."""
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
