"""Seeded synthetic datasets.

The container is offline, so the paper's four public datasets are replaced by
statistically-matched surrogates (DESIGN.md §5). Every generator is a pure
function of its seed — regenerating a dataset is bitwise reproducible, which
is what makes the fault-tolerant training loop's restart semantics exact.

  geo_clusters    — Municipalities surrogate: mainland blob + two far island
                    blobs in (lat, lon) radians; outlier structure + Haversine
  sparse_highdim  — MNIST surrogate: 10-class blobs in 784-d, ~80% zeros
  dense_embed     — GLOVE surrogate: anisotropic Gaussian mixture in 100-d
  tfidf_like      — NYtimes surrogate: sparse non-negative log-normal, a
                    geometry where cosine >> euclidean (validates Fig. 5d)
"""

from __future__ import annotations

import numpy as np


def geo_clusters(n: int = 8130, seed: int = 0) -> np.ndarray:
    """[n, 2] (lat, lon) in radians: Spain-like mainland + 2 island outliers."""
    rng = np.random.default_rng(seed)
    n_main = int(n * 0.9)
    n_bal = int(n * 0.04)
    n_can = n - n_main - n_bal
    deg = np.pi / 180.0
    main = rng.normal([40.0, -3.5], [2.2, 2.8], size=(n_main, 2))
    bal = rng.normal([39.5, 2.9], [0.35, 0.45], size=(n_bal, 2))
    can = rng.normal([28.3, -16.5], [0.5, 1.2], size=(n_can, 2))
    out = np.concatenate([main, bal, can]) * deg
    rng.shuffle(out)
    return out.astype(np.float32)


def sparse_highdim(n: int = 69000, d: int = 784, n_classes: int = 10,
                   density: float = 0.2, seed: int = 0) -> np.ndarray:
    """[n, d] non-negative, ~(1-density) zeros, 10 class blobs (MNIST-like)."""
    rng = np.random.default_rng(seed)
    centers = rng.uniform(0, 255, size=(n_classes, d))
    # Per-class support pattern: each class activates a different subset.
    support = rng.random((n_classes, d)) < density
    labels = rng.integers(0, n_classes, n)
    x = np.abs(centers[labels] + rng.normal(0, 40, size=(n, d)))
    x = np.clip(x, 0, 255) * support[labels]
    return x.astype(np.float32)


def dense_embed(n: int = 200_000, d: int = 100, n_comp: int = 64,
                seed: int = 0) -> np.ndarray:
    """[n, d] anisotropic Gaussian mixture (GLOVE-embedding-like)."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(0, 2.0, size=(n_comp, d))
    scales = rng.uniform(0.3, 1.2, size=(n_comp, d))
    comp = rng.integers(0, n_comp, n)
    x = centers[comp] + rng.normal(size=(n, d)) * scales[comp]
    return x.astype(np.float32)


def tfidf_like(n: int = 50_000, d: int = 256, density: float = 0.15,
               seed: int = 0) -> np.ndarray:
    """[n, d] sparse non-negative log-normal doc vectors (NYtimes-like).

    Document length varies over two orders of magnitude, so euclidean
    distance is dominated by length while the topical direction carries the
    signal — the cosine >> euclidean geometry of Fig. 5d.
    """
    rng = np.random.default_rng(seed)
    n_topics = 24
    topics = rng.dirichlet(np.full(d, 0.05), size=n_topics)
    doc_topic = rng.integers(0, n_topics, n)
    length = np.exp(rng.normal(3.0, 1.0, size=(n, 1)))
    x = rng.poisson(topics[doc_topic] * length * d).astype(np.float32)
    mask = rng.random((n, d)) < density
    x = x * mask
    idf = np.log((n + 1) / (1.0 + (x > 0).sum(0)))
    return (x * idf).astype(np.float32)


_DATASETS = {
    "geo_clusters": geo_clusters,
    "sparse_highdim": sparse_highdim,
    "dense_embed": dense_embed,
    "tfidf_like": tfidf_like,
}


def make_dataset(name: str, n: int | None = None, seed: int = 0) -> np.ndarray:
    fn = _DATASETS[name]
    return fn(n=n, seed=seed) if n else fn(seed=seed)


def dataset_names() -> list[str]:
    return sorted(_DATASETS)


# ---------------------------------------------------------------------------
# Model-zoo training data
# ---------------------------------------------------------------------------


def lm_tokens(step: int, batch: int, seq: int, vocab: int, seed: int = 0):
    """Zipf-distributed token batch for LM training; pure fn of step."""
    rng = np.random.default_rng((seed, step))
    toks = rng.zipf(1.3, size=(batch, seq + 1)) % vocab
    return dict(tokens=toks[:, :-1].astype(np.int32),
                labels=toks[:, 1:].astype(np.int32))


def recsys_batch(step: int, batch: int, cfg, seed: int = 0) -> dict:
    """Synthetic CTR batch with a planted logistic structure (learnable)."""
    rng = np.random.default_rng((seed, step))
    out: dict = {}
    if cfg.kind == "din":
        target = rng.integers(0, cfg.table_rows, batch)
        seq = rng.integers(0, cfg.table_rows, (batch, cfg.seq_len))
        lens = rng.integers(1, cfg.seq_len + 1, batch)
        mask = (np.arange(cfg.seq_len)[None, :] < lens[:, None])
        # clicks carry a deterministic per-item component (learnable via the
        # item embedding) — the history/attention path stays exercised in
        # the forward pass.
        y = (target % 2).astype(np.float32)
        out.update(target=target.astype(np.int32), seq=seq.astype(np.int32),
                   seq_mask=mask.astype(np.float32))
    else:
        sparse = rng.integers(0, cfg.table_rows, (batch, cfg.n_sparse))
        w = np.sin(np.arange(cfg.n_sparse) + 1.0)
        z = ((sparse % 5 - 2) * w).sum(1) / np.sqrt(cfg.n_sparse)
        if cfg.n_dense:
            dense = rng.normal(size=(batch, cfg.n_dense)).astype(np.float32)
            z = z + dense[:, 0]
            out["dense"] = dense
        y = (rng.random(batch) < 1.0 / (1.0 + np.exp(-z))).astype(np.float32)
        out["sparse"] = sparse.astype(np.int32)
    out["labels"] = y
    return out
