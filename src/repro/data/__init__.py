"""Data substrate: synthetic dataset generators + stateless sharded batching.

  synthetic.py — seeded surrogates for the paper's four datasets (offline
                 container; DESIGN.md §5) + LM / recsys / graph generators
  pipeline.py  — stateless step->batch pipeline (restart-reproducible) with
                 host prefetch and per-shard slicing
"""

from repro.data.synthetic import (
    dense_embed,
    geo_clusters,
    lm_tokens,
    make_dataset,
    recsys_batch,
    sparse_highdim,
    tfidf_like,
)
from repro.data.pipeline import BatchPipeline

__all__ = [
    "BatchPipeline",
    "dense_embed",
    "geo_clusters",
    "lm_tokens",
    "make_dataset",
    "recsys_batch",
    "sparse_highdim",
    "tfidf_like",
]
