"""Tombstones — packed deletion bitmask over leaf slots (DESIGN.md §3.7).

Deletes never touch the index arrays: the leaf level, the payload codes and
the navigation prototypes all stay frozen (and jit-compiled executables stay
valid). A delete flips one bit here; at search time the unpacked validity
mask threads into the leaf ranking of every mode — ``ops.rank_gathered``
(dense/beam), ``ops.scan_quantized`` (two-stage scan) and the sharded scan —
via ``ref.fold_slot_valid``, so masked slots price at ``distances.BIG`` and
deleted ids vanish from all results.

Storage is 1 bit per leaf slot (``uint8`` words on host). The device-side
bool mask (1 byte/slot — XLA has no packed bool) is materialised lazily and
cached; any mutation invalidates the cache, so a serving epoch re-uploads
the mask at most once per write batch, not per query.

A prototype at levels >= 1 may be a *copy* of a deleted point — that is by
design: prototypes are navigation structure, not results, and keeping them
is exactly what lets the hot tier stay frozen. Compaction
(``online.compact``) eventually rebuilds the affected groups and retires
the tombstones.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np


class TombstoneSet:
    """Packed per-slot deletion bits + a cached device validity mask."""

    def __init__(self, n_slots: int, bits: Optional[np.ndarray] = None):
        self.n_slots = int(n_slots)
        n_words = -(-self.n_slots // 8)
        if bits is None:
            bits = np.zeros(n_words, np.uint8)
        else:
            bits = np.asarray(bits, np.uint8)
            if bits.shape != (n_words,):
                raise ValueError(
                    f"tombstone bitmap shape {bits.shape} != ({n_words},) "
                    f"for {self.n_slots} slots"
                )
        self._bits = bits
        self.count = int(np.unpackbits(bits, count=self.n_slots, bitorder="little").sum())
        self._mask_cache = None  # jnp bool[n_slots], True = live

    # -- mutation -------------------------------------------------------------

    def add(self, slots) -> int:
        """Mark leaf slots deleted. Returns the number of *newly* dead
        slots (re-deleting is a no-op, not an error)."""
        slots = np.unique(np.asarray(slots, np.int64).reshape(-1))
        if slots.size == 0:
            return 0
        if slots.min() < 0 or slots.max() >= self.n_slots:
            raise IndexError(
                f"tombstone slot out of range [0, {self.n_slots})"
            )
        words, bit = slots >> 3, (slots & 7).astype(np.uint8)
        masks = np.left_shift(np.uint8(1), bit)
        already = (self._bits[words] & masks) != 0
        fresh = int((~already).sum())
        if fresh:
            np.bitwise_or.at(self._bits, words, masks)
            self.count += fresh
            self._mask_cache = None
        return fresh

    # -- queries --------------------------------------------------------------

    def contains(self, slots) -> np.ndarray:
        slots = np.asarray(slots, np.int64)
        return (self._bits[slots >> 3] >> (slots & 7).astype(np.uint8)) & 1 != 0

    def ratio(self, n_valid: int) -> float:
        """Dead fraction of the (originally valid) leaf population — the
        compaction trigger metric."""
        return self.count / max(int(n_valid), 1)

    def valid_mask(self):
        """Device bool[n_slots] validity mask (True = live), cached until
        the next mutation. This is the array threaded as ``slot_valid``
        through the search modes."""
        if self._mask_cache is None:
            dead = np.unpackbits(self._bits, count=self.n_slots,
                                  bitorder="little").astype(bool)
            self._mask_cache = jnp.asarray(~dead)
        return self._mask_cache

    def dead_slots(self) -> np.ndarray:
        """All tombstoned slot indices (compaction input)."""
        return np.nonzero(
            np.unpackbits(self._bits, count=self.n_slots, bitorder="little")
        )[0]

    # -- persistence ----------------------------------------------------------

    @property
    def bits(self) -> np.ndarray:
        """The packed bitmap (index save format v3)."""
        return self._bits

    @property
    def nbytes(self) -> int:
        return int(self._bits.nbytes)

    def __repr__(self):
        return f"TombstoneSet(n_slots={self.n_slots}, dead={self.count})"
