"""Delta buffer — capacity-bounded fp32 append tier for recent upserts
(DESIGN.md §3.7).

Writes never touch the frozen index either: an upsert appends the vector
here, and at insert time the point is *leaf-routed* through the already-jitted
``nsa.descend_beam`` at beam=1 (plus one fused ``ops.rank_gathered`` k=1) so
its destination group is known before compaction ever runs — routing costs
one navigation descent per write, amortised over write batches, and makes
compaction a per-group (not whole-index) rebuild.

Search over the buffer is a brute-force kernel scan: one
``ops.pairwise_distance`` call over the fixed-capacity array (inactive slots
mask to ``distances.BIG``) streamed in ``row_chunk`` column slabs, followed
by a top-k — exact by construction, so a fresh upsert is immediately and
perfectly visible. The buffer's ``[B, k]`` result merges with the main
index's through :func:`merge_topk` — the same concat + select a single
butterfly round performs between shard partners, which is exactly how the
delta leg folds into the sharded merge tree.

The arrays live host-side (writes are cheap row stores) with a lazily
refreshed device mirror, so the scan hits a stable jit cache: capacity is
static, mutations only change array *values*.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distances as dist_lib
from repro.core.distances import BIG
from repro.kernels import ops as kops

Array = jax.Array


class DeltaScan(NamedTuple):
    dists: Array  # f32[B, k'] ascending; BIG for missing
    ids: Array  # int32[B, k']; -1 for missing


@functools.partial(jax.jit, static_argnames=("dist", "k", "kernel"))
def _scan(Q, vectors, ids, active, *, dist, k, kernel):
    D = kops.pairwise_distance(Q, vectors, dist, config=kernel)
    D = jnp.where(active[None, :], D, BIG)
    neg, pos = jax.lax.top_k(-D, k)
    d = -neg
    out_ids = jnp.where(d < BIG / 2, jnp.take(ids, pos), -1)
    return DeltaScan(dists=d, ids=out_ids)


def merge_topk(d_a, i_a, d_b, i_b, k: int):
    """Two-way top-k merge of ``[..., k_a]`` / ``[..., k_b]`` result legs —
    one concat + select, the per-round primitive of the butterfly merge
    collective (``distributed.topk_merge_butterfly``) applied locally."""
    cd = jnp.concatenate([d_a, d_b], axis=-1)
    ci = jnp.concatenate([i_a, i_b], axis=-1)
    if cd.shape[-1] <= k:
        order = jnp.argsort(cd, axis=-1)
        pad = k - cd.shape[-1]
        d = jnp.take_along_axis(cd, order, axis=-1)
        i = jnp.take_along_axis(ci, order, axis=-1)
        if pad:
            widths = [(0, 0)] * (cd.ndim - 1) + [(0, pad)]
            d = jnp.pad(d, widths, constant_values=BIG)
            i = jnp.pad(i, widths, constant_values=-1)
        return d, i
    neg, idx = jax.lax.top_k(-cd, k)
    return -neg, jnp.take_along_axis(ci, idx, axis=-1)


class DeltaBuffer:
    """Fixed-capacity append buffer: vectors + ids + routed leaf slots."""

    def __init__(self, capacity: int, d: int):
        if capacity < 1:
            raise ValueError(f"delta capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.d = int(d)
        self.vectors = np.zeros((self.capacity, self.d), np.float32)
        self.ids = np.full(self.capacity, -1, np.int32)
        self.leaf_slot = np.full(self.capacity, -1, np.int32)  # routed dest
        self.active = np.zeros(self.capacity, bool)
        self.size = 0  # append cursor (monotone until compaction resets)
        self._dev = None  # cached (vectors, ids, active) device mirror

    # -- mutation -------------------------------------------------------------

    @property
    def n_active(self) -> int:
        return int(self.active[: self.size].sum())

    @property
    def free(self) -> int:
        return self.capacity - self.size

    def fill_ratio(self) -> float:
        """Append-cursor fill fraction (the compaction trigger metric —
        deactivated slots still consume capacity until compaction)."""
        return self.size / self.capacity

    def append(self, vectors, ids, leaf_slots) -> np.ndarray:
        """Append routed rows; returns their buffer positions. Raises when
        the remaining capacity cannot hold the batch (callers compact)."""
        vectors = np.asarray(vectors, np.float32)
        ids = np.asarray(ids, np.int32).reshape(-1)
        leaf_slots = np.asarray(leaf_slots, np.int32).reshape(-1)
        m = vectors.shape[0]
        if vectors.shape != (m, self.d):
            raise ValueError(
                f"delta append expects [m, {self.d}] vectors, got "
                f"{vectors.shape}"
            )
        if not (m == ids.shape[0] == leaf_slots.shape[0]):
            raise ValueError("vectors / ids / leaf_slots length mismatch")
        if m > self.free:
            raise RuntimeError(
                f"delta buffer full ({self.size}/{self.capacity} used, "
                f"{m} requested); compact the index to drain it"
            )
        pos = np.arange(self.size, self.size + m)
        self.vectors[pos] = vectors
        self.ids[pos] = ids
        self.leaf_slot[pos] = leaf_slots
        self.active[pos] = True
        self.size += m
        self._dev = None
        return pos

    def deactivate_ids(self, ids) -> int:
        """Mask out live entries whose id is in ``ids`` (delete / re-upsert
        of a buffered point). Returns the number deactivated."""
        ids = np.asarray(ids, np.int32).reshape(-1)
        if self.size == 0 or ids.size == 0:
            return 0
        hit = self.active[: self.size] & np.isin(self.ids[: self.size], ids)
        n = int(hit.sum())
        if n:
            self.active[: self.size][hit] = False
            self._dev = None
        return n

    def contains_id(self, id_) -> bool:
        return bool(
            (self.active[: self.size] & (self.ids[: self.size] == id_)).any()
        )

    def live_entries(self):
        """(vectors, ids, leaf_slots) of the active rows, insertion order —
        the compaction input."""
        live = self.active[: self.size]
        return (
            self.vectors[: self.size][live],
            self.ids[: self.size][live],
            self.leaf_slot[: self.size][live],
        )

    # -- search ---------------------------------------------------------------

    def scan(
        self,
        Q: Array,  # [B, d]
        dist,
        *,
        k: int,
        kernel: Optional[kops.KernelConfig] = None,
    ) -> DeltaScan:
        """Exact brute-force scan of the buffer: ``[B, min(k, capacity)]``
        ascending (dists, ids); inactive slots rank ``BIG`` / -1."""
        dist = dist_lib.get(dist)
        if self._dev is None:
            self._dev = (
                jnp.asarray(self.vectors),
                jnp.asarray(self.ids),
                jnp.asarray(self.active),
            )
        vecs, ids, active = self._dev
        return _scan(
            jnp.asarray(Q, jnp.float32), vecs, ids, active,
            dist=dist, k=min(k, self.capacity),
            kernel=kernel or kops.DEFAULT,
        )

    @property
    def nbytes(self) -> int:
        return int(
            self.vectors.nbytes + self.ids.nbytes + self.leaf_slot.nbytes
            + self.active.nbytes
        )

    def __repr__(self):
        return (
            f"DeltaBuffer(capacity={self.capacity}, d={self.d}, "
            f"size={self.size}, active={self.n_active})"
        )
