"""Epoch-swap compaction: fold the delta buffer and tombstones back into a
frozen PDASC index (DESIGN.md §3.7).

Read-copy-update at the index level: compaction never mutates the serving
epoch. It materialises the live point set (leaf residents − tombstones +
routed delta points), rebuilds, and returns a *new* ``PDASCIndex`` with
``epoch + 1``, empty delta / tombstone tiers and a freshly (partially)
re-quantised payload store. In-flight searches keep reading the old epoch;
the serving layer (``online.epoch.EpochHandle`` + ``BatchingEngine``) swaps
the reference between batches, so no query ever observes a half-built index.

Two scopes:

``scope="affected"`` (default)
    Group-granular rebuild, the reason delta points are leaf-routed at
    insert time. Only the leaf groups that lost residents (tombstones) or
    gained arrivals (delta routing / spill) are re-clustered — through the
    same PR 2 build substrate (``msa._cluster_groups``, streamed in
    ``group_chunk`` slabs). Untouched groups keep their rows bit-identical
    and their clustering recovered from the frozen level-1 structure (labels
    are run-length decodes of the sibling-contiguous parent pointers). The
    hierarchy above the leaf is regrown by the shared bottom-up loop
    (``msa._cluster_levels(prev_levels=[leaf])``) — upper levels hold ~n/2
    points total, so the rebuild cost is dominated by the affected leaf
    fraction. Payload codes re-quantise only for blocks overlapping changed
    rows (``LeafStore.rebuild``).

``scope="full"``
    From-scratch rebuild over the live set (the parity oracle for tests and
    the fallback when nearly every group is dirty anyway).

Arrivals route to their insert-time group while it has room (a group holds
``gl`` slots; deletions free slots); overflow spills into fresh groups
appended after the existing ones, clustered like any other affected group.
"""

from __future__ import annotations

import functools
import re as _re
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import msa


def live_dataset(idx) -> tuple[np.ndarray, np.ndarray]:
    """The current live point set of a mutable index.

    Returns ``(vectors [m, d] f32, ids [m] int32)`` — surviving leaf
    residents in slot order, then active delta entries in insertion order.
    This is the dataset a from-scratch rebuild would be built on (the
    parity baseline of ``tests/test_online.py``).
    """
    leaf = idx.data.levels[0]
    pts = _leaf_points(idx)
    valid = np.asarray(leaf.valid)
    ids = np.asarray(idx.data.leaf_ids)
    alive = valid.copy()
    if idx.tombstones is not None and idx.tombstones.count:
        alive[idx.tombstones.dead_slots()] = False
    vecs = [pts[alive]]
    out_ids = [ids[alive]]
    if idx.delta is not None and idx.delta.n_active:
        d_vecs, d_ids, _ = idx.delta.live_entries()
        vecs.append(d_vecs)
        out_ids.append(d_ids)
    return (
        np.concatenate(vecs, axis=0).astype(np.float32),
        np.concatenate(out_ids, axis=0).astype(np.int32),
    )


def _leaf_points(idx) -> np.ndarray:
    """Exact fp32 leaf vectors in slot layout, whether the dense copy is
    resident or released to the out-of-core payload tier."""
    leaf = idx.data.levels[0]
    pts = np.asarray(leaf.points, np.float32)
    if idx.store is not None and pts.shape[1] != idx.store.d:
        # dense payload released: the exact source is the payload of record
        return idx.store.exact.read_all()
    return pts


def _recover_group_clustering(parent, valid, G, gl, k, level1_pts):
    """Decode each group's frozen clustering from the sibling-contiguous
    leaf layout: labels are run indices of the parent pointer within the
    group's valid prefix, and medoid ``l`` of group ``g`` is the level-1
    point those runs point at. Exact inverse of ``msa._build_level``'s
    reorder (every valid medoid has >= 1 child — itself)."""
    pg = parent.reshape(G, gl)
    vg = valid.reshape(G, gl)
    change = np.ones((G, gl), bool)
    change[:, 1:] = pg[:, 1:] != pg[:, :-1]
    change &= vg
    labels = np.cumsum(change, axis=1) - 1
    labels = np.where(vg, labels, -1).astype(np.int32)

    med_parent = np.full((G, k), -1, np.int64)
    gi, ji = np.nonzero(change)
    li = labels[gi, ji]
    keep = li < k  # defensive: malformed layouts would overflow the slots
    med_parent[gi[keep], li[keep]] = pg[gi[keep], ji[keep]]
    med_valid = med_parent >= 0
    safe = np.clip(med_parent, 0, level1_pts.shape[0] - 1)
    med_pts = level1_pts[safe]
    med_pts[~med_valid] = 0.0
    return labels, med_pts.astype(np.float32), med_valid


@functools.partial(
    jax.jit,
    static_argnames=("dist", "k", "method", "max_swaps", "swap_tol",
                     "row_chunk", "bg", "force_pallas"),
)
def _cluster_slab(gpts, gvld, keys, *, dist, k, method, max_swaps, swap_tol,
                  row_chunk, bg, force_pallas):
    return msa._cluster_groups(
        dist, gpts, gvld, keys, k=k, method=method, max_swaps=max_swaps,
        swap_tol=swap_tol, row_chunk=row_chunk, bg=bg,
        force_pallas=force_pallas,
    )


def _cluster_affected(idx, gpts, gvld, *, method, max_swaps, swap_tol,
                      row_chunk, group_chunk, bg, force_pallas, key):
    """Re-cluster the affected groups through the PR 2 build substrate,
    streamed in ``group_chunk`` slabs (host loop; each slab is one jitted
    kernel-path call). Slabs pad to the chunk size with invalid groups so
    every compaction of the same index shape hits one compiled executable.
    """
    A = gpts.shape[0]
    k = idx.n_prototypes
    keys = jax.random.split(key, A)
    chunk = min(group_chunk, A) if group_chunk and group_chunk > 0 else A
    med, lab = [], []
    for lo in range(0, A, chunk):
        hi = min(lo + chunk, A)
        gp, gv, ks = gpts[lo:hi], gvld[lo:hi], keys[lo:hi]
        pad = chunk - (hi - lo)
        if pad:
            gp = np.concatenate([gp, np.zeros((pad,) + gp.shape[1:],
                                              gp.dtype)])
            gv = np.concatenate([gv, np.zeros((pad, gv.shape[1]), bool)])
            ks = jnp.concatenate([ks, jnp.zeros((pad, ks.shape[1]),
                                                ks.dtype)])
        m, l, _ = _cluster_slab(
            jnp.asarray(gp), jnp.asarray(gv), ks, dist=idx.distance, k=k,
            method=method, max_swaps=max_swaps, swap_tol=swap_tol,
            row_chunk=row_chunk, bg=bg, force_pallas=force_pallas,
        )
        med.append(np.asarray(m)[: hi - lo])
        lab.append(np.asarray(l)[: hi - lo])
    return np.concatenate(med, axis=0), np.concatenate(lab, axis=0)


def compact_index(
    idx,
    *,
    scope: str = "affected",
    method: str = "pam",
    max_swaps: int = 64,
    swap_tol: float = 1e-3,
    row_chunk: int = 512,
    group_chunk: int = 8,
    bg: int = 128,
    force_pallas: bool = False,
    key=None,
    store_path: Optional[str] = None,
):
    """Compact a mutable index into a fresh epoch (never mutates ``idx``).

    Returns a new ``PDASCIndex``: live points only, empty delta/tombstone
    tiers, ``epoch = idx.epoch + 1``, payload store re-created with
    unchanged quantisation blocks reused. A memmapped exact payload gets a
    *fresh* per-epoch file (``<base>.epoch<N>``; ``store_path`` overrides) —
    never the old epoch's file, whose granules RCU readers may still be
    fetching; retired epoch files are the operator's to garbage-collect
    once no reader holds the old index. A released dense payload stays
    released on the new epoch (the out-of-core memory budget survives
    compaction).
    """
    from repro.core.index import PDASCIndex  # deferred: index imports us

    if scope not in ("affected", "full"):
        raise ValueError(f"unknown compaction scope {scope!r}")
    key = key if key is not None else jax.random.fold_in(
        jax.random.PRNGKey(0xC0), idx.epoch + 1
    )

    if scope == "full":
        data, stats, leaf_ids_live = _rebuild_full(
            idx, key, method=method, max_swaps=max_swaps, swap_tol=swap_tol,
            row_chunk=row_chunk, group_chunk=group_chunk, bg=bg,
            force_pallas=force_pallas,
        )
        changed = np.ones(data.levels[0].points.shape[0], bool)
    else:
        data, stats, changed = _rebuild_affected(
            idx, key, method=method, max_swaps=max_swaps, swap_tol=swap_tol,
            row_chunk=row_chunk, group_chunk=group_chunk, bg=bg,
            force_pallas=force_pallas,
        )

    new_idx = PDASCIndex(
        data=data,
        stats=stats,
        distance=idx.distance,
        gl=idx.gl,
        n_prototypes=idx.n_prototypes,
        max_children=msa.max_children(data),
        default_radius=idx.default_radius,
        epoch=idx.epoch + 1,
        # freed ids (deleted / deactivated) must never be re-issued: carry
        # the id ceiling across the epoch, not just the surviving ids
        _next_id=idx._seen_id_ceiling(),
    )
    if idx.store is not None:
        if store_path is None and idx.store.exact.on_disk:
            base = _re.sub(r"\.epoch\d+$", "", idx.store.exact.path)
            store_path = f"{base}.epoch{idx.epoch + 1}"
        new_idx.store = idx.store.rebuild(
            np.asarray(data.levels[0].points), changed, path=store_path
        )
        if idx._payload_released:
            new_idx.release_dense_payload()
    return new_idx


def _rebuild_full(idx, key, *, method, max_swaps, swap_tol, row_chunk,
                  group_chunk, bg, force_pallas):
    vecs, ids = live_dataset(idx)
    data, stats = msa.build_index(
        vecs, gl=idx.gl, n_prototypes=idx.n_prototypes,
        distance=idx.distance, method=method, max_swaps=max_swaps, key=key,
        row_chunk=row_chunk, group_chunk=group_chunk, swap_tol=swap_tol,
        bg=bg, force_pallas=force_pallas,
    )
    # build() numbers leaves by row into `vecs`; lift back to original ids.
    rows = np.asarray(data.leaf_ids)
    leaf_ids = np.where(rows >= 0, ids[np.clip(rows, 0, len(ids) - 1)], -1)
    data = data._replace(leaf_ids=jnp.asarray(leaf_ids, dtype=jnp.int32))
    return data, stats, ids


def _rebuild_affected(idx, key, *, method, max_swaps, swap_tol, row_chunk,
                      group_chunk, bg, force_pallas):
    gl, k = idx.gl, idx.n_prototypes
    dist = idx.distance
    leaf = idx.data.levels[0]
    pts = _leaf_points(idx)
    n_pad, d = pts.shape
    G = n_pad // gl
    valid = np.asarray(leaf.valid)
    parent = np.asarray(leaf.parent)
    leaf_ids = np.asarray(idx.data.leaf_ids)

    dead = np.zeros(n_pad, bool)
    if idx.tombstones is not None and idx.tombstones.count:
        dead[idx.tombstones.dead_slots()] = True
    alive = valid & ~dead

    if idx.delta is not None and idx.delta.n_active:
        d_vecs, d_ids, d_slots = idx.delta.live_entries()
    else:
        d_vecs = np.zeros((0, d), np.float32)
        d_ids = d_slots = np.zeros((0,), np.int32)

    # --- route arrivals: insert-time group while it has room, else spill ----
    alive_cnt = alive.reshape(G, gl).sum(axis=1)
    room = gl - alive_cnt
    target_g = np.clip(np.asarray(d_slots, np.int64) // gl, 0, max(G - 1, 0))
    arrivals: list[list[int]] = [[] for _ in range(G)]
    spill: list[int] = []
    for i, g in enumerate(target_g):
        g = int(g)
        if G and room[g] > 0:
            arrivals[g].append(i)
            room[g] -= 1
        else:
            spill.append(i)
    n_spill_groups = -(-len(spill) // gl) if spill else 0
    G_new = G + n_spill_groups
    n_new = G_new * gl

    # --- assemble the new leaf groups ---------------------------------------
    new_pts = np.zeros((G_new, gl, d), np.float32)
    new_valid = np.zeros((G_new, gl), bool)
    new_ids = np.full((G_new, gl), -1, np.int32)
    affected = np.zeros(G_new, bool)
    new_pts[:G] = pts.reshape(G, gl, d)
    new_valid[:G] = alive.reshape(G, gl)
    new_ids[:G] = np.where(alive, leaf_ids, -1).reshape(G, gl)
    had_dead = (dead & valid).reshape(G, gl).any(axis=1)
    for g in range(G):
        arr = arrivals[g]
        if not arr and not had_dead[g]:
            continue  # frozen group: rows stay bit-identical
        affected[g] = True
        sel = new_valid[g]
        m = int(sel.sum())
        packed = np.zeros((gl, d), np.float32)
        packed_ids = np.full(gl, -1, np.int32)
        packed[:m] = new_pts[g][sel]
        packed_ids[:m] = new_ids[g][sel]
        if arr:
            packed[m:m + len(arr)] = d_vecs[arr]
            packed_ids[m:m + len(arr)] = d_ids[arr]
            m += len(arr)
        new_pts[g] = packed
        new_ids[g] = packed_ids
        new_valid[g] = np.arange(gl) < m
    for s in range(n_spill_groups):
        g = G + s
        affected[g] = True
        rows = spill[s * gl:(s + 1) * gl]
        new_pts[g, : len(rows)] = d_vecs[rows]
        new_ids[g, : len(rows)] = d_ids[rows]
        new_valid[g, : len(rows)] = True

    # --- per-group clustering: recover frozen groups, re-cluster the rest ---
    labels = np.full((G_new, gl), -1, np.int32)
    med_pts = np.zeros((G_new, k, d), np.float32)
    med_valid = np.zeros((G_new, k), bool)
    if G and not affected[:G].all():
        keep_lab, keep_mp, keep_mv = _recover_group_clustering(
            parent, valid, G, gl, k, np.asarray(idx.data.levels[1].points)
        )
        frozen = ~affected[:G]
        labels[:G][frozen] = keep_lab[frozen]
        med_pts[:G][frozen] = keep_mp[frozen]
        med_valid[:G][frozen] = keep_mv[frozen]
    aff = np.nonzero(affected)[0]
    if aff.size:
        key, sub = jax.random.split(key)
        med_idx, aff_lab = _cluster_affected(
            idx, new_pts[aff], new_valid[aff], method=method,
            max_swaps=max_swaps, swap_tol=swap_tol, row_chunk=row_chunk,
            group_chunk=group_chunk, bg=bg, force_pallas=force_pallas,
            key=sub,
        )
        labels[aff] = aff_lab
        safe = np.clip(med_idx, 0, gl - 1)
        mp = np.take_along_axis(new_pts[aff], safe[:, :, None], axis=1)
        mv = med_idx >= 0
        mp[~mv] = 0.0
        med_pts[aff] = mp
        med_valid[aff] = mv

    # --- sibling-contiguous reorder + child bookkeeping (all groups) --------
    sort_key = np.where(labels >= 0, labels, k)
    order = np.argsort(sort_key, axis=1, kind="stable")  # identity if frozen
    labels_f = np.take_along_axis(labels, order, axis=1)
    pts_f = np.take_along_axis(new_pts, order[:, :, None], axis=1)
    valid_f = np.take_along_axis(new_valid, order, axis=1)
    ids_f = np.take_along_axis(new_ids, order, axis=1)

    counts = np.zeros((G_new, k), np.int64)
    gi, ji = np.nonzero(labels_f >= 0)
    np.add.at(counts, (gi, labels_f[gi, ji]), 1)
    bounds = np.concatenate(
        [np.zeros((G_new, 1), np.int64), np.cumsum(counts, axis=1)], axis=1
    )
    starts = bounds[:, :k] + (np.arange(G_new) * gl)[:, None]
    parent_f = np.where(
        labels_f >= 0, (np.arange(G_new) * k)[:, None] + labels_f, -1
    ).astype(np.int32)

    leaf_dict = dict(
        points=jnp.asarray(pts_f.reshape(n_new, d)),
        valid=jnp.asarray(valid_f.reshape(n_new)),
        parent=jnp.asarray(parent_f.reshape(n_new)),
        child_start=jnp.full((n_new,), -1, jnp.int32),
        child_count=jnp.zeros((n_new,), jnp.int32),
        leaf_ids=jnp.asarray(ids_f.reshape(n_new)),
    )
    med_flat = jnp.asarray(med_pts.reshape(G_new * k, d))
    mv_flat = jnp.asarray(med_valid.reshape(G_new * k))
    cs_flat = jnp.asarray(starts.reshape(G_new * k).astype(np.int32))
    cc_flat = jnp.asarray(counts.reshape(G_new * k).astype(np.int32))

    # --- regrow the hierarchy above the leaf --------------------------------
    if G_new == 1:  # the medoids of the single group *are* the top level
        raw_levels = [leaf_dict]
        top = dict(
            points=med_flat, valid=mv_flat,
            parent=jnp.full((G_new * k,), -1, jnp.int32),
            child_start=cs_flat, child_count=cc_flat,
        )
        upper_td: list = []
    else:
        key, sub = jax.random.split(key)
        raw_levels, upper_td, top = msa._cluster_levels(
            med_flat, mv_flat, cs_flat, cc_flat, sub,
            dist=dist, gl=gl, k=k, method=method, max_swaps=max_swaps,
            swap_tol=swap_tol, row_chunk=row_chunk, group_chunk=group_chunk,
            bg=bg, force_pallas=force_pallas, prev_levels=[leaf_dict],
        )
    data = msa.finalize_index(raw_levels, top)

    # Exact leaf TD (sum of point -> own-medoid distances): one rowwise pass
    # instead of trusting stale per-group numbers through the reshuffle.
    leaf_new = data.levels[0]
    l1_pts = data.levels[1].points
    safe_par = jnp.clip(leaf_new.parent, 0, l1_pts.shape[0] - 1)
    td0 = jnp.sum(
        jnp.where(
            leaf_new.valid,
            dist.point(leaf_new.points, jnp.take(l1_pts, safe_par, axis=0)),
            0.0,
        )
    )
    sizes = [int(np.asarray(lv.valid).sum()) for lv in data.levels]
    tds = [float(td0)] + [float(np.asarray(t)) for t in upper_td] + [0.0]
    stats = msa.BuildStats(
        level_sizes=tuple(sizes), level_td=tuple(tds), n_levels=len(sizes)
    )
    changed_rows = np.repeat(affected, gl)
    return data, stats, changed_rows
