"""Epoch handle — the read-copy-update glue between a mutable PDASC index
and the serving engine (DESIGN.md §3.7).

The handle owns one atomic reference to the current index epoch. Readers
(the engine's search handler) grab ``handle.current`` once per batch and run
the whole batch against that snapshot; writers go through
``handle.apply_writes`` — wired as ``BatchingEngine(write_handler=...)``, so
the engine only ever calls it *between* batches on the single worker thread.
That serialisation is the entire consistency story:

* no torn batches — a batch's queries all see one epoch (the snapshot),
* no write/search races — upsert/delete mutate only the delta/tombstone
  tiers, and only while no handler is running,
* epoch swaps are one reference assignment — in-flight results computed on
  the old epoch stay valid (the old index object is immutable once
  published and is garbage-collected when the last reader drops it).

Compaction policy lives here too: after a write batch, if the delta fill or
tombstone ratio crossed its threshold, the handle compacts into a new epoch
and swaps.

Replicated serving (DESIGN.md §3.10) extends the same story across N
independent epoch timelines: writes append to one shared :class:`WriteLog`
(a monotonically sequenced, append-only op record) and fan out to every
replica's engine; each replica applies them through its own ``EpochHandle``
and swaps epochs independently (a replica is *allowed* to lag epochs — RCU
means its readers just see a slightly older, still-consistent snapshot).
A replica that was down (crashed / restarting) replays the log suffix past
its last applied sequence number on readmission, so identically-ordered
replay over identically-seeded clones keeps id assignment deterministic
across the fleet.
"""

from __future__ import annotations

import threading
import time
from typing import Any, Optional

from repro import obs
from repro.obs import names as mnames


class WriteLog:
    """Shared, append-only, monotonically sequenced write record.

    The replica set appends each accepted write once (``append`` returns its
    sequence number) and fans the op out to every live replica; a replica
    that missed ops (down at fan-out time) catches up with ``since(seq)``.
    Entries are immutable tuples ``(seq, kind, payload)``; the log is the
    durability fiction of this tier — in a real deployment it is the
    replicated commit log, here it is the deterministic replay source the
    fault harness restores crashed replicas from.
    """

    def __init__(self):
        self._ops: list = []
        self._lock = threading.Lock()

    def append(self, kind: str, payload: Any) -> int:
        """Record one write; returns its sequence number (0-based)."""
        with self._lock:
            seq = len(self._ops)
            self._ops.append((seq, kind, payload))
            return seq

    def since(self, seq: int) -> list:
        """All entries with sequence number > ``seq`` (pass -1 for all)."""
        with self._lock:
            # seqs are dense indices, so the suffix is a slice
            return self._ops[seq + 1:]

    @property
    def last_seq(self) -> int:
        with self._lock:
            return len(self._ops) - 1

    def __len__(self) -> int:
        with self._lock:
            return len(self._ops)


class EpochHandle:
    """RCU reference to the live index + write application + swap policy."""

    def __init__(
        self,
        idx,
        *,
        delta_fill: float = 0.5,
        tombstone_ratio: float = 0.2,
        scope: str = "affected",
        compact_kwargs: Optional[dict] = None,
    ):
        self._current = idx
        self.delta_fill = float(delta_fill)
        self.tombstone_ratio = float(tombstone_ratio)
        self.scope = scope
        self.compact_kwargs = dict(compact_kwargs or {})
        self.swaps = 0
        # Guards the reference swap itself (reads of self._current are
        # single assignments — atomic under the GIL — but tests / multiple
        # writers may drive apply_writes concurrently).
        self._write_lock = threading.Lock()

    @property
    def current(self):
        """The live epoch. Read it ONCE per batch and keep the snapshot."""
        return self._current

    # -- engine glue ----------------------------------------------------------

    def apply_writes(self, ops):
        """``BatchingEngine`` write handler: ``ops`` is ``[(kind, payload),
        ...]`` in arrival order (kind "upsert" -> payload ``(vectors, ids)``
        or bare vectors; kind "delete" -> payload ids). Applied to the live
        epoch, then the swap policy runs once. Returns one result per op
        (assigned ids for upserts, deleted counts for deletes) — a failing
        op contributes its *exception* instead, so ops already durably
        applied earlier in the run are never reported as failed (the engine
        raises the per-op error from that request's ``wait()``)."""
        with self._write_lock:
            idx = self._current
            out = []
            for kind, payload in ops:
                try:
                    if kind == "upsert":
                        if isinstance(payload, tuple):
                            vectors, ids = payload
                        else:
                            vectors, ids = payload, None
                        if idx.delta is not None and idx.delta.free < len(
                            _rows(vectors)
                        ):
                            idx = self._swap(idx)  # pre-emptive: make room
                        out.append(idx.upsert(vectors, ids=ids))
                    elif kind == "delete":
                        out.append(idx.delete(payload))
                    else:
                        raise ValueError(f"unknown write kind {kind!r}")
                except Exception as e:  # per-op isolation
                    out.append(e)
                    obs.counter(mnames.ONLINE_WRITE_ERRORS, op=kind).inc()
                else:
                    obs.counter(mnames.ONLINE_WRITES, op=kind).inc()
            if idx.needs_compaction(
                delta_fill=self.delta_fill,
                tombstone_ratio=self.tombstone_ratio,
            ):
                idx = self._swap(idx)
            self._observe_tiers(idx)
            return out

    def maybe_compact(self) -> bool:
        """Run the swap policy outside the engine (tests / manual drains)."""
        with self._write_lock:
            idx = self._current
            if idx.needs_compaction(
                delta_fill=self.delta_fill,
                tombstone_ratio=self.tombstone_ratio,
            ):
                self._swap(idx)
                return True
            return False

    def _swap(self, idx):
        t0 = time.perf_counter()
        new = idx.compact(scope=self.scope, **self.compact_kwargs)
        self._current = new  # the RCU publish: one reference assignment
        self.swaps += 1
        obs.counter(mnames.ONLINE_EPOCH_SWAPS).inc()
        obs.histogram(mnames.ONLINE_COMPACTION_TIME).observe(
            time.perf_counter() - t0)
        return new

    def _observe_tiers(self, idx) -> None:
        """Gauge the online tiers after a write run (delta fill ratio,
        tombstoned slots) — the feedback the compaction policy acts on."""
        if idx.delta is not None and idx.delta.capacity:
            obs.gauge(mnames.ONLINE_DELTA_FILL).set(
                idx.delta.n_active / idx.delta.capacity)
        if idx.tombstones is not None:
            obs.gauge(mnames.ONLINE_TOMBSTONES).set(idx.tombstones.count)


def _rows(vectors):
    import numpy as np

    v = np.asarray(vectors)
    return v.reshape(1, -1) if v.ndim == 1 else v
