"""Online mutability substrate (DESIGN.md §3.7) — the fourth substrate after
search, build and storage: live upserts, deletes and epoch-swap compaction
over an otherwise frozen PDASC index.

* ``delta``      — capacity-bounded fp32 append tier for recent upserts,
                   leaf-routed at insert time, searched by an exact kernel
                   scan merged into every mode's results.
* ``tombstones`` — packed deletion bitmask threaded into the leaf ranking of
                   every search mode as a validity mask.
* ``compact``    — group-granular epoch-swap rebuild folding both tiers back
                   into a fresh immutable index.
* ``epoch``      — the RCU handle wiring it all into ``BatchingEngine``.
"""

from repro.online.compact import compact_index, live_dataset
from repro.online.delta import DeltaBuffer, merge_topk
from repro.online.epoch import EpochHandle, WriteLog
from repro.online.tombstones import TombstoneSet

__all__ = [
    "DeltaBuffer",
    "EpochHandle",
    "TombstoneSet",
    "WriteLog",
    "compact_index",
    "live_dataset",
    "merge_topk",
]
