"""Checkpoint store: atomic, async, elastic.

Format: one directory per step —

    <dir>/step_000123/
        manifest.json   # tree structure, shapes, dtypes, format version
        arrays.npz      # flat {path -> ndarray}, full logical arrays
    <dir>/latest        # text file naming the newest complete step

Properties:

* **atomic** — written into ``step_X.tmp-<pid>`` then ``os.replace``d; the
  ``latest`` pointer is updated only after the directory rename, so a crash
  mid-write never corrupts a restorable checkpoint.
* **async**  — ``CheckpointManager.save_async`` snapshots to host memory
  (device->host copy) synchronously, then serialises on a writer thread;
  the training step resumes immediately.
* **elastic** — arrays are stored as *full logical* values; ``load`` places
  them against whatever sharding the *restoring* mesh requests. Restoring a
  512-chip checkpoint onto 256 chips (or 8) is the same code path —
  re-sharding happens in ``jax.device_put``. (At true scale this would be a
  per-shard format + resharding service; single-process here, same API.)
* **self-pruning** — keeps the newest ``keep`` checkpoints.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np

_FORMAT = 2
_SEP = "/"


def _flatten(tree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save_checkpoint(directory: str, step: int, tree, *, keep: int = 3) -> str:
    """Blocking atomic save of a pytree of (device or host) arrays."""
    os.makedirs(directory, exist_ok=True)
    flat = {k: np.asarray(v) for k, v in _flatten(tree).items()}
    treedef = jax.tree.structure(tree)
    final = os.path.join(directory, f"step_{step:09d}")
    tmp = final + f".tmp-{os.getpid()}-{threading.get_ident()}"
    os.makedirs(tmp, exist_ok=True)
    manifest = dict(
        version=_FORMAT,
        step=step,
        treedef=str(treedef),
        keys={k: dict(shape=list(v.shape), dtype=str(v.dtype))
              for k, v in flat.items()},
        written_at=time.time(),
    )
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    # latest pointer (atomic via temp + replace)
    lp = os.path.join(directory, "latest")
    with open(lp + ".tmp", "w") as f:
        f.write(f"step_{step:09d}")
    os.replace(lp + ".tmp", lp)
    _prune(directory, keep)
    return final


def _prune(directory: str, keep: int):
    steps = sorted(
        d for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
        and "tmp-" not in d
    )
    for d in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    lp = os.path.join(directory, "latest")
    if not os.path.exists(lp):
        return None
    with open(lp) as f:
        name = f.read().strip()
    path = os.path.join(directory, name)
    if not os.path.exists(os.path.join(path, "manifest.json")):
        return None
    return int(name.split("_")[1])


def load_checkpoint(directory: str, template, *, step: Optional[int] = None,
                    shardings=None):
    """Restore into ``template``'s tree structure; optionally re-shard.

    ``shardings``: pytree of Shardings (same structure) — the elastic path:
    the stored full arrays are placed against the *current* mesh, whatever
    its size.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:09d}")
    z = np.load(os.path.join(path, "arrays.npz"))
    flat_template = _flatten(template)
    missing = set(flat_template) - set(z.files)
    if missing:
        raise ValueError(f"checkpoint missing keys: {sorted(missing)[:5]} ...")
    flat = {k: z[k] for k in flat_template}
    leaves = [flat[k] for k in flat_template]  # template order
    tree = jax.tree.unflatten(jax.tree.structure(template), leaves)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree, step


class CheckpointManager:
    """Async wrapper: snapshot synchronously, serialise on a worker thread."""

    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        self.last_saved: Optional[int] = None
        self._error: Optional[BaseException] = None

    def save_async(self, step: int, tree):
        self.wait()  # one in-flight save at a time
        host = jax.tree.map(lambda a: np.asarray(a), tree)  # snapshot now

        def work():
            try:
                save_checkpoint(self.directory, step, host, keep=self.keep)
                with self._lock:
                    self.last_saved = step
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    def restore_or_none(self, template, shardings=None):
        step = latest_step(self.directory)
        if step is None:
            return None, None
        return load_checkpoint(self.directory, template, step=step,
                               shardings=shardings)
