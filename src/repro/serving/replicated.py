"""Replicated serving substrate: N independent replicas of one PDASC index
(DESIGN.md §3.10).

A :class:`Replica` is one full serving stack — its own
:class:`~repro.serving.engine.BatchingEngine` worker, its own
:class:`~repro.serving.engine.QueryHandler`, and its own
:class:`~repro.online.EpochHandle` over an independently epoch-swapping
index copy. Replicas share the *immutable* build artifacts (level arrays,
payload store — read-only, so one host copy serves the fleet) but never a
mutable tier: each clone gets fresh delta/tombstone tiers and applies
writes through its own handle, swapping epochs on its own schedule. A
replica lagging an epoch behind its peers is fine by construction — RCU
means its readers see a slightly older, still-consistent snapshot.

Writes fan out through a shared :class:`~repro.online.WriteLog`: the set
appends each accepted write once, then submits it to every live replica's
engine (FIFO per replica preserves apply order). Because every clone starts
from the same state and applies the same ordered log, id assignment is
deterministic and identical fleet-wide — which is what lets a crashed
replica *replay* the log suffix past its last applied sequence number on
restart and converge exactly.

Fault injection (``faults.FaultPlan``) wraps each replica's batch handler:
the injector decides per handler dispatch — deterministically, in dispatch
counts — whether the batch runs clean, slow, or dies. The
:class:`~repro.serving.router.Router` above this layer turns those faults
into retries, hedges, ejections and readmissions.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Optional

import numpy as np

from repro.online import EpochHandle, WriteLog
from repro.serving import faults as faults_lib
from repro.serving.engine import BatchingEngine, QueryHandler, Request


class ReplicaDown(RuntimeError):
    """The replica's engine is not accepting requests (crashed / closed)."""


def clone_index(idx):
    """An independent serving copy of ``idx``.

    Immutable build artifacts (level arrays, payload store, radii) are
    shared by reference — they are read-only on every search path, so N
    replicas cost one resident copy. Mutable state is NOT shared: the clone
    starts with fresh (empty) online tiers and its own plan cache / id-slot
    table, so per-replica writes and epoch swaps never alias. The source
    index must have clean online tiers (compact first) — cloning a dirty
    index would silently drop its buffered writes from the clones.
    """
    if (idx.delta is not None and idx.delta.n_active) or (
            idx.tombstones is not None and idx.tombstones.count):
        raise ValueError(
            "clone_index needs clean online tiers (active delta entries or "
            "tombstones would not be replicated); compact() first"
        )
    return dataclasses.replace(
        idx, delta=None, tombstones=None,
        _id_slot=None, _plan_cache=None,
    )


class Replica:
    """One replica: engine + query handler + epoch handle + fault injector.

    ``applied_seq`` is the last :class:`WriteLog` sequence number whose
    write was submitted to this replica's engine (FIFO ⇒ it will be applied
    in order before any later submit). The set advances it under its write
    lock; a restart replays ``log.since(applied_seq)``.
    """

    def __init__(self, rid: int, index, query, *,
                 batch_size: int, max_wait_ms: float,
                 degraded_query=None,
                 injector: Optional[faults_lib.FaultInjector] = None,
                 delta_capacity: int = 4096,
                 epoch_kwargs: Optional[dict] = None):
        self.id = rid
        self.query = query
        self.degraded_query = degraded_query
        self.injector = injector
        self.batch_size = batch_size
        self.max_wait_ms = max_wait_ms
        idx = clone_index(index)
        idx.enable_mutations(delta_capacity=delta_capacity)
        self.handle = EpochHandle(idx, **(epoch_kwargs or {}))
        self.applied_seq = -1
        self.engine: Optional[BatchingEngine] = None
        self._dead_engine: Optional[BatchingEngine] = None
        self._out_lock = threading.Lock()
        self._outstanding = 0
        self._pad = np.zeros(idx._dim(), np.float32)
        self.start()

    # -- lifecycle ------------------------------------------------------------

    @property
    def alive(self) -> bool:
        return self.engine is not None

    def _wrap(self, handler):
        """Fault-inject ahead of the real handler: one injector dispatch per
        batch (probes included — they ride the same path)."""
        if self.injector is None:
            return handler

        def faulty(batch, n_valid):
            self.injector.on_dispatch()
            return handler(batch, n_valid)

        return faulty

    def start(self) -> None:
        if self.engine is not None:
            return
        if self._dead_engine is not None:
            # A restart must not overlap the old worker's drain: two workers
            # applying writes to the same handle could reorder ops across
            # the replay boundary. The queue is finite and wedge windows are
            # bounded, so this join terminates.
            self._dead_engine._thread.join(timeout=30.0)
            self._dead_engine = None
        extra = {}
        if self.degraded_query is not None:
            extra["degraded"] = self._wrap(
                QueryHandler(self.handle, self.degraded_query))
        self.engine = BatchingEngine(
            self._wrap(QueryHandler(self.handle, self.query)),
            batch_size=self.batch_size, max_wait_ms=self.max_wait_ms,
            pad_payload=self._pad,
            write_handler=self.handle.apply_writes,
            extra_handlers=extra or None,
            name=f"r{self.id}",  # labels this replica's series in repro.obs
        )

    def kill(self) -> None:
        """Simulated process death: stop accepting, drain what's queued
        (writes already submitted stay durable — ``applied_seq`` was
        advanced for them), tear the engine down."""
        eng, self.engine = self.engine, None
        if eng is not None:
            eng.close()
            self._dead_engine = eng

    def close(self) -> None:
        self.kill()
        self._dead_engine = None

    # -- dispatch -------------------------------------------------------------

    @property
    def outstanding(self) -> int:
        return self._outstanding

    def _done(self, _req: Request) -> None:
        with self._out_lock:
            self._outstanding -= 1

    def submit(self, payload, *, kind: str = "search",
               deadline_s: Optional[float] = None,
               on_done=None, span=None) -> Request:
        """Submit a search-like request; raises :class:`ReplicaDown` when
        the replica is not serving. ``outstanding`` counts requests between
        here and their completion callback (the router's least-loaded
        signal); ``on_done`` chains the caller's completion hook after it;
        ``span`` is the tracing parent forwarded to the engine (a router
        attempt leg)."""
        eng = self.engine
        if eng is None:
            raise ReplicaDown(f"replica r{self.id} is down")
        with self._out_lock:
            self._outstanding += 1
        if on_done is None:
            cb = self._done
        else:
            def cb(req, _extra=on_done):
                self._done(req)
                _extra(req)
        try:
            return eng.submit(payload, kind=kind, deadline_s=deadline_s,
                              on_done=cb, span=span)
        except RuntimeError as e:  # closed between the check and the submit
            with self._out_lock:
                self._outstanding -= 1
            raise ReplicaDown(f"replica r{self.id} is down") from e

    def probe_payload(self):
        return self._pad


class ReplicaSet:
    """N replicas behind one write log.

    Searches go through the :class:`~repro.serving.router.Router` (which
    picks replicas); writes go through :meth:`upsert` / :meth:`delete` here
    — appended to the shared log once, fanned out to every live replica's
    engine. ``restart()`` brings a dead replica back and replays the log
    suffix it missed before any new fan-out can interleave.
    """

    def __init__(self, index, query, *, n_replicas: int,
                 batch_size: int = 16, max_wait_ms: float = 2.0,
                 degraded_query=None,
                 fault_plan: Optional[faults_lib.FaultPlan] = None,
                 delta_capacity: int = 4096,
                 epoch_kwargs: Optional[dict] = None):
        if n_replicas < 1:
            raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
        self.query = query
        self.degraded_query = degraded_query
        self.log = WriteLog()
        self._write_lock = threading.Lock()
        self.replicas = [
            Replica(
                rid, index, query,
                batch_size=batch_size, max_wait_ms=max_wait_ms,
                degraded_query=degraded_query,
                injector=(fault_plan.injector(rid)
                          if fault_plan is not None else None),
                delta_capacity=delta_capacity,
                epoch_kwargs=epoch_kwargs,
            )
            for rid in range(n_replicas)
        ]

    def __len__(self) -> int:
        return len(self.replicas)

    def live_index(self):
        """The current epoch's index from the first live replica (falling
        back to replica 0 if none is up) — the exact-reference source for
        the shadow recall estimator and the plan-describe resolver."""
        for r in self.replicas:
            if r.alive:
                return r.handle.current
        return self.replicas[0].handle.current

    # -- write fan-out --------------------------------------------------------

    def upsert(self, vectors, ids=None, *, timeout: float = 60.0):
        """Fan an upsert out to every live replica; returns the assigned ids
        (identical on every replica — same clone state, same ordered log).
        Raises if no replica could durably accept the write."""
        payload = (np.asarray(vectors, np.float32), ids) if ids is not None \
            else np.asarray(vectors, np.float32)
        return self._write("upsert", payload, timeout)

    def delete(self, ids, *, timeout: float = 60.0):
        """Fan a delete-by-ids out to every live replica; returns the
        deleted count (from the first replica to apply it)."""
        return self._write("delete", np.asarray(ids), timeout)

    def _write(self, kind: str, payload, timeout: float):
        with self._write_lock:
            seq = self.log.append(kind, payload)
            submitted = []
            for r in self.replicas:
                if r.engine is None:
                    continue  # down: will replay this seq on restart
                try:
                    if kind == "upsert":
                        req = r.engine.submit_upsert(payload)
                    else:
                        req = r.engine.submit_delete(payload)
                except RuntimeError:
                    continue  # died between the check and the submit
                # FIFO per engine: once submitted, this write applies before
                # any later one — safe to advance the replay cursor now.
                r.applied_seq = seq
                submitted.append(req)
        if not submitted:
            raise ReplicaDown(
                f"write seq={seq} accepted by no replica (all down); it "
                f"stays in the log and applies on the next restart"
            )
        # The write is applied per replica; surface the first result (ids /
        # deleted count agree fleet-wide by construction). Waiting on one
        # replica keeps write latency at min-replica, not max-replica — the
        # rest apply asynchronously but in order.
        first_err = None
        for req in submitted:
            try:
                return req.wait(timeout=timeout)
            except Exception as e:  # noqa: BLE001 — try the next replica
                first_err = e
        raise first_err

    # -- replica lifecycle (the router's prober drives these) ----------------

    def restart(self, rid: int) -> None:
        """Bring a dead replica back and replay the log suffix it missed.
        Holding the write lock across replay means no new fan-out write can
        land between the replayed backlog and live traffic — order is the
        log order, exactly."""
        r = self.replicas[rid]
        with self._write_lock:
            r.start()
            for seq, kind, payload in self.log.since(r.applied_seq):
                if kind == "upsert":
                    r.engine.submit_upsert(payload)
                else:
                    r.engine.submit_delete(payload)
                r.applied_seq = seq

    def kill(self, rid: int) -> None:
        self.replicas[rid].kill()

    def close(self) -> None:
        for r in self.replicas:
            r.close()
