"""Fault-tolerant request router over a :class:`~repro.serving.replicated
.ReplicaSet` (DESIGN.md §3.10).

The router is the caller-facing front of the replicated serving tier. Per
request it runs a small state machine:

    ADMIT ──▶ DISPATCH ──▶ WAIT ──▶ done
      │          │           ├─ attempt failed ──▶ backoff ──▶ DISPATCH
      │          │           └─ hedge timer ──▶ second DISPATCH, first wins
      └─ over the queue limit: degrade (cheaper Query) or reject (Overloaded)

* **Admission control** — a bounded in-flight budget (``queue_limit``).
  Past the degradation watermark requests are rewritten onto the *degraded*
  query plan (``repro.query.degraded`` — narrower beam, scan-only two-stage
  — compiled through the same plan layer, served by the engine's
  ``extra_handlers`` lane) and tagged ``degraded=True``; past the hard
  limit they are rejected with :class:`Overloaded`. Shedding early keeps
  queues short, so accepted requests keep meeting their deadlines.
* **Load-aware dispatch** — least-outstanding-requests with
  power-of-two-choices: sample two healthy replicas (seeded RNG), send to
  the one with fewer requests in flight. P2C gets most of the balance of
  full least-loaded without a global scan or herding on stale signals.
* **Deadlines** — every request carries a budget; the remaining budget is
  threaded into the engine (``submit(deadline_s=...)``) so an expired
  request is dropped from the queue instead of wasting a batch slot, and
  the router raises :class:`~repro.serving.engine.DeadlineExceeded` to the
  caller only when retries and hedges could not beat the clock.
* **Bounded retries, exponential backoff + jitter** — a failed attempt
  (injected error, crash, replica down, queue drop) retries on another
  replica up to ``max_retries`` times, waiting ``backoff_base_s * 2^i``
  (capped, ± seeded jitter) so a recovering replica is not stampeded.
* **Tail-latency hedging** — when the primary attempt is still running
  after a p99-derived delay (estimated online from completed latencies),
  the request is re-issued to a second replica; the first result wins and
  the loser is cancelled (the engine skips it at batch assembly). The
  loser, if still incomplete, counts a health failure — that is exactly
  the signal that ejects a wedged replica that never errors, only stalls.
* **Health checking** — consecutive failures eject a replica from the
  dispatch pool (a crash ejects immediately and tears its engine down). A
  background prober revisits ejected replicas after an exponentially
  growing cooldown: half-open state admits one probe (restarting a dead
  engine first, which replays the write log it missed); success readmits,
  failure re-ejects. The full lifecycle — eject, half-open probes,
  readmission — lands in the bounded event log (``router.events()``)
  with from/to states and per-edge transition counters in ``repro.obs``,
  for the fault harness to assert on.
* **Telemetry** (DESIGN.md §3.11) — counters/histograms for every decision
  above land in the process-wide ``repro.obs`` registry, and with
  ``RouterConfig.trace_every = N`` every N-th request (deterministic by
  request seq) records a full span tree — attempt legs, queue/batch waits,
  plan stages, granule fetches — retained in ``router.traces``.
"""

from __future__ import annotations

import collections
import dataclasses
import itertools
import random
import threading
import time
from typing import NamedTuple, Optional

import numpy as np

from repro import obs
from repro.obs import names as mnames
from repro.serving.engine import Cancelled, DeadlineExceeded
from repro.serving.faults import ReplicaCrashed
from repro.serving.replicated import ReplicaDown, ReplicaSet


class Overloaded(RuntimeError):
    """Admission control rejected the request (in-flight budget exhausted)."""


class ReplicaUnavailable(RuntimeError):
    """No replica could accept the request (all down)."""


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    """Router knobs. All time budgets in seconds; ``seed`` drives every
    random draw (replica sampling, backoff jitter) — the router never
    consults wall-clock randomness."""

    deadline_s: float = 1.0          # per-request end-to-end budget
    max_retries: int = 2             # re-dispatches after the first attempt
    backoff_base_s: float = 0.01     # retry i waits base * 2^i ...
    backoff_cap_s: float = 0.25      # ... capped here ...
    backoff_jitter: float = 0.5      # ... +/- this fraction, seeded
    hedge: bool = True               # tail-latency hedging on/off
    hedge_min_s: float = 0.02        # floor (and cold-start value) for the
    hedge_quantile: float = 0.99     # p99-derived hedge delay
    queue_limit: int = 256           # hard admission limit (in-flight)
    degrade_at: float = 0.75         # degrade past this fraction of limit
    eject_failures: int = 3          # consecutive failures -> ejection
    probe_cooldown_s: float = 0.2    # half-open cooldown (doubles per fail)
    probe_timeout_s: float = 0.3     # a probe slower than this failed
    probe_interval_s: float = 0.05   # prober thread wake period
    seed: int = 0
    # Telemetry (DESIGN.md §3.11): trace 1 request in N, keyed on the
    # router's request sequence number (deterministic; 0 disables), and
    # bound the in-memory event log (oldest entries evicted).
    trace_every: int = 0
    events_maxlen: int = 4096
    # Quality observability (DESIGN.md §3.12): shadow-sample 1 served
    # request in N (same seq-keyed scheme as trace_every; 0 disables) and
    # re-answer it exactly off the hot path — the router builds its own
    # ``obs.RecallEstimator`` over the replica set unless one is passed in.
    shadow_every: int = 0


class RouterResult(NamedTuple):
    dists: np.ndarray
    ids: np.ndarray
    replica: int        # replica that produced the winning result
    degraded: bool      # served on the degraded (cheaper) plan
    retries: int        # re-dispatches this request needed
    hedged: bool        # a hedge twin was issued
    latency_s: float


class _Health:
    __slots__ = ("state", "consec", "ejected_at", "probe_attempts")

    def __init__(self):
        self.state = "healthy"  # "healthy" | "ejected" | "half_open"
        self.consec = 0
        self.ejected_at = 0.0
        self.probe_attempts = 0


class RouterRequest:
    """One admitted request: holds the live engine attempts and drives the
    retry/hedge state machine from the caller's :meth:`wait`."""

    def __init__(self, router: "Router", payload, kind: str,
                 deadline: float, *, seq: int = 0, trace=None):
        self.router = router
        self.payload = payload
        self.kind = kind
        self.t0 = time.time()
        self.deadline = deadline
        self.attempts: list = []  # live (replica, engine Request) pairs
        self.retries = 0
        self.hedged = False
        self.seq = seq
        self.trace = trace  # obs.Trace for the sampled 1-in-N, else None
        self._evt = threading.Event()  # poked by any attempt completing
        self._released = False

    def _notify(self, _req) -> None:
        self._evt.set()

    def wait(self, timeout: Optional[float] = None) -> RouterResult:
        try:
            return self.router._drive(self, timeout)
        finally:
            self.router._release(self)

    # engine-side completion check helpers -----------------------------------

    def live(self):
        return [(r, q) for r, q in self.attempts if not q._event.is_set()]

    def finished(self):
        return [(r, q) for r, q in self.attempts if q._event.is_set()]


class Router:
    """See the module docstring. Construct over a :class:`ReplicaSet`;
    callers use :meth:`search` (sync) or :meth:`submit` + ``wait()``."""

    def __init__(self, replica_set: ReplicaSet,
                 config: Optional[RouterConfig] = None, *,
                 quality=None, slo=None, costlog=None):
        self.set = replica_set
        self.cfg = config or RouterConfig()
        # Quality/SLO/cost observability (DESIGN.md §3.12), all optional:
        # ``quality`` is an obs.RecallEstimator (built here when
        # cfg.shadow_every > 0 and none is passed), ``slo`` an
        # obs.SLOTracker fed from every request completion and evaluated
        # by the prober thread, ``costlog`` an obs.CostLog appended for
        # each traced (sampled) request.
        self.slo = slo
        self.costlog = costlog
        self._own_quality = False
        if quality is None and self.cfg.shadow_every > 0:
            from repro.obs.quality import RecallEstimator

            quality = RecallEstimator(replica_set,
                                      every_n=self.cfg.shadow_every)
            self._own_quality = True
        self.quality = quality
        if (self.quality is not None and self.slo is not None
                and self.quality.on_sample is None):
            # the shadow worker feeds the SLO recall objective
            self.quality.on_sample = \
                lambda recall, pipeline, leg: self.slo.record_recall(recall)
        self._pipelines: dict = {}  # kind -> effective_pipeline label
        self._rng = random.Random(self.cfg.seed)
        self._lock = threading.Lock()
        self._health = {r.id: _Health() for r in replica_set.replicas}
        self._inflight = 0
        self._t0 = time.time()
        # Bounded event log: deque drops the oldest entries, so a long-
        # lived router cannot grow without bound; read via events().
        self._events: collections.deque = collections.deque(
            maxlen=self.cfg.events_maxlen)
        self.stats = collections.Counter()
        self._lat = collections.deque(maxlen=512)
        self._seq = itertools.count()
        # Deterministic 1-in-N request tracing; completed traces land in
        # self.traces (bounded), exemplar via self.traces.exemplar(p99).
        self._sampler = obs.TraceSampler(self.cfg.trace_every)
        self.traces = self._sampler.buffer
        self._m_requests = obs.counter(mnames.ROUTER_REQUESTS)
        self._m_rejects = obs.counter(mnames.ROUTER_REJECTS)
        self._m_degraded = obs.counter(mnames.ROUTER_DEGRADED)
        self._m_retries = obs.counter(mnames.ROUTER_RETRIES)
        self._m_hedges = obs.counter(mnames.ROUTER_HEDGES)
        self._m_hedge_wins = obs.counter(mnames.ROUTER_HEDGE_WINS)
        self._m_deadline = obs.counter(mnames.ROUTER_DEADLINE_EXCEEDED)
        self._m_latency = obs.histogram(mnames.ROUTER_LATENCY)
        self._stop = threading.Event()
        self._prober = threading.Thread(target=self._probe_loop, daemon=True)
        self._prober.start()

    # -- public surface -------------------------------------------------------

    def search(self, payload, *, deadline_s: Optional[float] = None,
               timeout: Optional[float] = None) -> RouterResult:
        return self.submit(payload, deadline_s=deadline_s).wait(timeout)

    def submit(self, payload, *,
               deadline_s: Optional[float] = None) -> RouterRequest:
        """Admit + first dispatch. Raises :class:`Overloaded` past the hard
        in-flight limit; past the degradation watermark (and with a
        degraded query configured) the request is served on the cheaper
        plan instead and tagged."""
        cfg = self.cfg
        kind = "search"
        with self._lock:
            if self._inflight >= cfg.queue_limit:
                self.stats["rejected"] += 1
                self._m_rejects.inc()
                self._log("reject", None, f"inflight={self._inflight}")
                if self.slo is not None:
                    self.slo.record_request(0.0, ok=False)
                raise Overloaded(
                    f"router over capacity ({self._inflight} in flight >= "
                    f"queue_limit={cfg.queue_limit})"
                )
            if (self.set.degraded_query is not None
                    and self._inflight >= cfg.degrade_at * cfg.queue_limit):
                kind = "degraded"
                self.stats["degraded"] += 1
                self._m_degraded.inc()
                self._log("degrade", None, f"inflight={self._inflight}")
            self._inflight += 1
            self.stats["requests"] += 1
            seq = next(self._seq)
        self._m_requests.inc()
        budget = cfg.deadline_s if deadline_s is None else deadline_s
        trace = self._sampler.sample("request", seq, kind=kind)
        rr = RouterRequest(self, payload, kind, time.time() + budget,
                           seq=seq, trace=trace)
        try:
            self._dispatch(rr, leg="primary")
        except BaseException:
            self._release(rr)
            raise
        return rr

    def close(self, *, close_replicas: bool = False) -> None:
        self._stop.set()
        self._prober.join(timeout=5.0)
        if self._own_quality and self.quality is not None:
            self.quality.close()
        if close_replicas:
            self.set.close()

    def health_states(self) -> dict:
        """replica id -> current health state ("healthy" | "ejected" |
        "half_open") — the dashboard's per-replica view."""
        with self._lock:
            return {rid: h.state for rid, h in self._health.items()}

    def events(self) -> list:
        """Snapshot of the bounded in-memory event log (oldest first).
        Each entry: ``{"t": ..., "event": ..., "replica": ..., "detail":
        ...}``; ejections/readmissions also carry ``from``/``to`` health
        states so the fault harness can assert exact sequences."""
        with self._lock:
            return list(self._events)

    def event_counts(self) -> dict:
        with self._lock:
            c = collections.Counter(e["event"] for e in self._events)
        return dict(c)

    def hedge_delay(self) -> float:
        """The p99-derived hedge delay (estimated online; floor/cold-start
        value ``hedge_min_s``)."""
        with self._lock:
            lat = list(self._lat)
        if len(lat) < 20:
            return self.cfg.hedge_min_s
        return max(self.cfg.hedge_min_s,
                   float(np.quantile(lat, self.cfg.hedge_quantile)))

    # -- dispatch + health ----------------------------------------------------

    def _log(self, event: str, replica: Optional[int], detail: str = "",
             **extra):
        # callers hold self._lock
        self._events.append(dict(
            t=round(time.time() - self._t0, 4), event=event,
            replica=replica, detail=detail, **extra,
        ))

    def _transition(self, rid: int, frm: str, to: str, event: str,
                    detail: str = "") -> None:
        """Record one health state-machine edge: the per-edge counter
        (labelled from/to) plus an event-log entry carrying the states.
        Callers hold self._lock and have already set ``h.state = to``."""
        self.stats[f"transition_{frm}_{to}"] += 1
        obs.counter(mnames.ROUTER_HEALTH_TRANSITIONS,
                    **{"replica": str(rid), "from": frm, "to": to}).inc()
        self._log(event, rid, detail, **{"from": frm, "to": to})

    def _pick(self, exclude: set):
        """Least-outstanding with power-of-two-choices over healthy
        replicas; falls back to any alive replica (better a long shot than
        a guaranteed error), None when nothing is alive."""
        with self._lock:
            healthy = [r for r in self.set.replicas
                       if r.id not in exclude and r.alive
                       and self._health[r.id].state == "healthy"]
            if not healthy:
                healthy = [r for r in self.set.replicas
                           if r.id not in exclude and r.alive]
            if not healthy:
                healthy = [r for r in self.set.replicas if r.alive]
            if not healthy:
                return None
            if len(healthy) == 1:
                return healthy[0]
            a, b = self._rng.sample(healthy, 2)
        return a if a.outstanding <= b.outstanding else b

    def _dispatch(self, rr: RouterRequest, *, leg: str = "primary") -> None:
        """Submit one attempt for ``rr``; walks picks past dead replicas.
        ``leg`` tags the attempt ("primary" | "retry" | "hedge") for the
        dispatch counters, the hedge-win accounting and the trace span."""
        exclude = {r.id for r, _ in rr.attempts}
        for _ in range(max(len(self.set.replicas), 1)):
            rep = self._pick(exclude)
            if rep is None:
                raise ReplicaUnavailable("no live replica to dispatch to")
            remaining = rr.deadline - time.time()
            if remaining <= 0:
                raise DeadlineExceeded("request deadline exhausted before "
                                       "dispatch")
            span = None
            if rr.trace is not None:
                span = rr.trace.root.child(
                    "attempt", replica=rep.id, leg=leg)
            try:
                req = rep.submit(rr.payload, kind=rr.kind,
                                 deadline_s=remaining, on_done=rr._notify,
                                 span=span)
            except ReplicaDown:
                if span is not None:
                    span.end(error="ReplicaDown")
                self._on_failure(rep.id, "down")
                exclude.add(rep.id)
                continue
            req._leg = leg
            obs.counter(mnames.ROUTER_DISPATCHES,
                        replica=str(rep.id), leg=leg).inc()
            rr.attempts.append((rep, req))
            return
        raise ReplicaUnavailable("every dispatch candidate refused the "
                                 "request")

    def _on_success(self, rid: int) -> None:
        with self._lock:
            h = self._health[rid]
            h.consec = 0
            if h.state == "half_open":
                h.state = "healthy"
                h.probe_attempts = 0
                self._transition(rid, "half_open", "healthy", "readmit")

    def _on_failure(self, rid: int, reason: str, *,
                    crashed: bool = False) -> None:
        obs.counter(mnames.ROUTER_FAILURES, replica=str(rid)).inc()
        with self._lock:
            h = self._health[rid]
            h.consec += 1
            self.stats["failures"] += 1
            if h.state == "half_open":
                h.state = "ejected"
                h.ejected_at = time.time()
                h.probe_attempts += 1
                self._transition(rid, "half_open", "ejected", "probe_fail",
                                 reason)
            elif h.state == "healthy" and (
                    crashed or h.consec >= self.cfg.eject_failures):
                h.state = "ejected"
                h.ejected_at = time.time()
                self._transition(rid, "healthy", "ejected", "eject", reason)

    def _handle_error(self, rr: RouterRequest, rep, err) -> None:
        """Health bookkeeping for one failed attempt."""
        if isinstance(err, ReplicaCrashed):
            # simulated process death: tear the engine down so subsequent
            # dispatches see the replica as down, eject immediately
            self.set.kill(rep.id)
            self._on_failure(rep.id, "crash", crashed=True)
            with self._lock:
                self._log("crash", rep.id, str(err))
        else:
            self._on_failure(rep.id, type(err).__name__)

    # -- the per-request state machine (caller thread) ------------------------

    def _drive(self, rr: RouterRequest, timeout: Optional[float]
               ) -> RouterResult:
        cfg = self.cfg
        hard_stop = None if timeout is None else time.time() + timeout
        hedge_at = (rr.t0 + self.hedge_delay()
                    if cfg.hedge and len(self.set.replicas) > 1 else None)
        backoff_until = None
        last_err: Optional[BaseException] = None
        while True:
            # 1) collect finished attempts
            for rep, req in rr.finished():
                rr.attempts.remove((rep, req))
                if req.error is None:
                    if req.span is not None:
                        req.span.end(outcome="won")
                    self._on_success(rep.id)
                    if getattr(req, "_leg", "primary") == "hedge":
                        self.stats["hedge_wins"] += 1
                        self._m_hedge_wins.inc()
                    # winner: cancel the losers; a loser still incomplete is
                    # the stall signal that ejects wedged replicas
                    for lrep, lreq in list(rr.attempts):
                        if not lreq._event.is_set():
                            lreq.cancel()
                            if lreq.span is not None:
                                lreq.span.end(outcome="cancelled")
                            self._on_failure(lrep.id, "hedge_loss")
                    lat = time.time() - rr.t0
                    with self._lock:
                        self._lat.append(lat)
                        self.stats["successes"] += 1
                    self._m_latency.observe(lat)
                    if rr.trace is not None:
                        rr.trace.finish(
                            outcome="ok", replica=rep.id,
                            degraded=(rr.kind == "degraded"),
                            retries=rr.retries, hedged=rr.hedged)
                    dists, ids = req.result
                    ids = np.asarray(ids)
                    self._observe_success(rr, rep, lat, ids)
                    return RouterResult(
                        dists=np.asarray(dists), ids=ids,
                        replica=rep.id, degraded=(rr.kind == "degraded"),
                        retries=rr.retries, hedged=rr.hedged, latency_s=lat,
                    )
                if req.span is not None:
                    req.span.end(error=type(req.error).__name__)
                if isinstance(req.error, Cancelled):
                    continue  # our own cancel racing the worker: not a fault
                last_err = req.error
                self._handle_error(rr, rep, req.error)
                if rr.retries < cfg.max_retries and backoff_until is None:
                    # schedule a jittered exponential backoff, then retry
                    base = min(cfg.backoff_cap_s,
                               cfg.backoff_base_s * (2 ** rr.retries))
                    with self._lock:
                        jit = 1.0 + cfg.backoff_jitter * (
                            2.0 * self._rng.random() - 1.0)
                    backoff_until = time.time() + base * jit
            now = time.time()
            # 2) deadline / caller-timeout checks
            if now >= rr.deadline or (hard_stop is not None
                                      and now >= hard_stop):
                for rep, req in rr.live():
                    req.cancel()
                    if req.span is not None:
                        req.span.end(outcome="deadline")
                    self._on_failure(rep.id, "deadline")
                with self._lock:
                    self.stats["deadline_exceeded"] += 1
                self._m_deadline.inc()
                if self.slo is not None:
                    self.slo.record_request(now - rr.t0, ok=False)
                if now >= rr.deadline:
                    raise DeadlineExceeded(
                        f"request missed its {cfg.deadline_s * 1e3:.0f}ms "
                        f"deadline after {rr.retries} retries"
                    ) from last_err
                raise TimeoutError("router wait() timeout") from last_err
            # 3) retry when its backoff matured
            if backoff_until is not None and now >= backoff_until:
                backoff_until = None
                rr.retries += 1
                with self._lock:
                    self.stats["retries"] += 1
                    self._log("retry", None, f"n={rr.retries}")
                self._m_retries.inc()
                try:
                    self._dispatch(rr, leg="retry")
                except (ReplicaUnavailable, DeadlineExceeded) as e:
                    last_err = e
                    if not rr.live():
                        raise
            # 4) no live attempt and no retry pending -> the error is final
            if not rr.live() and backoff_until is None:
                if self.slo is not None:
                    self.slo.record_request(time.time() - rr.t0, ok=False)
                if last_err is not None:
                    raise last_err
                raise ReplicaUnavailable("request has no live attempts")
            # 5) hedge when the primary stalls past the p99-derived delay
            if (hedge_at is not None and not rr.hedged and now >= hedge_at
                    and len(rr.live()) == 1):
                rr.hedged = True
                with self._lock:
                    self.stats["hedges"] += 1
                    self._log("hedge", rr.live()[0][0].id,
                              f"after {now - rr.t0:.3f}s")
                self._m_hedges.inc()
                try:
                    self._dispatch(rr, leg="hedge")
                except (ReplicaUnavailable, DeadlineExceeded):
                    pass  # hedging is opportunistic, never fatal
            # 6) sleep until the next actionable moment
            wake = [rr.deadline]
            if hard_stop is not None:
                wake.append(hard_stop)
            if backoff_until is not None:
                wake.append(backoff_until)
            if hedge_at is not None and not rr.hedged:
                wake.append(hedge_at)
            rr._evt.clear()
            rr._evt.wait(max(0.0, min(wake) - time.time()))

    # -- quality / SLO / cost hooks (DESIGN.md §3.12) --------------------------

    def _observe_success(self, rr: RouterRequest, rep, lat: float,
                         ids) -> None:
        """Feed a served request into the SLO tracker, the shadow recall
        estimator, and (when traced) the cost log. Telemetry never kills a
        request: failures here are swallowed, not raised."""
        try:
            if self.slo is not None:
                self.slo.record_request(lat, ok=True)
            if self.quality is not None:
                self.quality.observe(
                    rr.seq, rr.payload, ids,
                    pipeline=self._pipeline_label(rr.kind),
                    leg="degraded" if rr.kind == "degraded" else "normal")
            if self.costlog is not None and rr.trace is not None:
                self.costlog.record(
                    rr.trace, self._describe_for(rr.kind),
                    replica=rep.id, degraded=(rr.kind == "degraded"),
                    retries=rr.retries, hedged=rr.hedged)
        except Exception:
            pass

    def _describe_for(self, kind: str):
        """The served plan's ``describe()`` for a request kind, resolved
        against the live epoch; None when no replica can answer."""
        try:
            q = (self.set.degraded_query if kind == "degraded"
                 else self.set.query)
            if q is None:
                return None
            idx = self.set.live_index()
            return idx.plan(q).describe()
        except Exception:
            return None

    def _pipeline_label(self, kind: str) -> str:
        label = self._pipelines.get(kind)
        if label is None:
            d = self._describe_for(kind)
            label = (d or {}).get("effective_pipeline") or "unknown"
            self._pipelines[kind] = label
        return label

    def _release(self, rr: RouterRequest) -> None:
        if rr._released:
            return
        rr._released = True
        if rr.trace is not None:
            # idempotent: the winner path already finished it with
            # outcome="ok"; error/deadline exits finish it here
            rr.trace.finish(outcome="error")
        with self._lock:
            self._inflight -= 1

    # -- health prober (background thread) ------------------------------------

    def _probe_loop(self) -> None:
        while not self._stop.wait(self.cfg.probe_interval_s):
            try:
                self._probe_once()
            except Exception:
                pass  # the prober must survive anything a probe throws
            if self.slo is not None:
                try:
                    self.slo.maybe_evaluate()
                except Exception:
                    pass  # SLO evaluation must never kill the prober

    def _probe_once(self) -> None:
        """Half-open probing: for each ejected replica past its cooldown,
        restart it if dead (replaying the write log it missed), send one
        probe, readmit on success / re-eject with a doubled cooldown on
        failure. Called by the prober thread (and directly by tests)."""
        cfg = self.cfg
        now = time.time()
        for rep in self.set.replicas:
            with self._lock:
                h = self._health[rep.id]
                if h.state != "ejected":
                    continue
                cooldown = cfg.probe_cooldown_s * (
                    2 ** min(h.probe_attempts, 6))
                if now - h.ejected_at < cooldown:
                    continue
                h.state = "half_open"
                self._transition(rep.id, "ejected", "half_open", "half_open",
                                 f"probe #{h.probe_attempts + 1}")
            if not rep.alive:
                try:
                    self.set.restart(rep.id)
                    with self._lock:
                        self._log("restart", rep.id,
                                  f"replayed to seq={rep.applied_seq}")
                except Exception as e:  # noqa: BLE001 — restart failed
                    self._on_failure(rep.id, f"restart: {e}")
                    continue
            try:
                req = rep.submit(rep.probe_payload(),
                                 deadline_s=cfg.probe_timeout_s)
            except ReplicaDown:
                self._on_failure(rep.id, "down")
                continue
            if req.done(cfg.probe_timeout_s) and req.error is None:
                self._on_success(rep.id)
            else:
                req.cancel()
                err = req.error
                if isinstance(err, ReplicaCrashed):
                    self.set.kill(rep.id)
                self._on_failure(
                    rep.id,
                    type(err).__name__ if err is not None else "probe_timeout",
                )
