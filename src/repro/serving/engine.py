"""Batched request engine.

Requests are queued and served in fixed-size batches (padded to the
compiled batch size so every call hits the same executable — no recompiles
on the serving path). A worker thread drains the queue with a max-wait
deadline: a batch departs when full OR when the oldest request has waited
``max_wait_ms`` (p99-friendly batching).

``prefetch_fn`` hooks storage-aware serving (DESIGN.md §3.6): while the
worker runs the current batch, a helper thread receives a snapshot of the
payloads still queued — a tiered-store handler uses it to warm the leaf
store's granule cache so the next batch's exact-rerank fetches hit memory
instead of disk (or, behind a remote tier, instead of the network: a
``prefetch_fn`` may return an async ``PrefetchHandle``, which the helper
waits on with a bounded timeout). Prefetching is best-effort: snapshots
that arrive while the helper is busy are coalesced to the latest one, and
exceptions are swallowed (a cold cache is a latency miss, not an error).

``write_handler`` hooks the online substrate (DESIGN.md §3.7):
``submit_upsert`` / ``submit_delete`` enqueue *write* requests into the
same FIFO, and the worker hands consecutive runs of them to the handler
**between** search batches — writes and searches never interleave inside a
batch, and a search submitted after a write is batched after it (read-your-
writes). Because the single worker applies writes while no handler call is
in flight, an ``online.EpochHandle`` write handler can mutate the delta /
tombstone tiers and swap index epochs with no torn (mixed-epoch) batch ever
observable.

``QueryHandler`` adapts a declarative ``repro.query.Query`` into a search
handler (DESIGN.md §3.8): it resolves the live index epoch once per batch
and executes the index's cached plan, so re-planning happens only when the
capability fingerprint changes (e.g. an epoch swap).

Robust serving hooks (DESIGN.md §3.10):

* **per-request deadlines** — ``submit(payload, deadline_s=...)`` stamps an
  absolute deadline from ``Request.enqueued_at``; ``_take_batch`` drops an
  expired request with :class:`DeadlineExceeded` instead of wasting a batch
  slot on a result nobody will read (writes are never dropped — they are
  durable once enqueued);
* **cancellation** — a ``Request.wait(timeout)`` that times out marks the
  request cancelled (so does an explicit ``cancel()``, e.g. a hedged
  router attempt losing the race); the worker skips cancelled requests at
  batch assembly, and a batch whose members all died is never dispatched;
* **extra handler kinds** — ``extra_handlers={"degraded": handler}`` adds
  search-like request kinds batched homogeneously with the same deadline
  logic but served by their own handler: the router's graceful-degradation
  ladder serves a cheaper plan through the same engine without mixing
  plans inside one batch;
* **completion callbacks** — ``Request.on_done`` fires exactly once when a
  request finishes (result, error, or drop); the replicated router uses it
  for least-outstanding load accounting.

Used by ``launch/serve.py`` for two endpoints:
  * PDASC k-NN queries  (handler = QueryHandler over the live index)
  * recsys CTR scoring  (handler = recsys serve step)
"""

from __future__ import annotations

import collections
import dataclasses
import itertools
import queue
import threading
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro import obs
from repro.obs import names as mnames

# Sentinel pushed by close() to wake a worker blocked on the request queue.
_SHUTDOWN = object()

# Write kinds are durable once enqueued: never deadline-dropped or skipped.
_WRITE_KINDS = ("upsert", "delete")


class DeadlineExceeded(TimeoutError):
    """The request's deadline passed before a worker picked it up."""


class Cancelled(RuntimeError):
    """The request was cancelled (waiter timed out / hedge twin won)."""


@dataclasses.dataclass
class Request:
    payload: Any  # one query row (pytree of arrays, leading dim absent)
    id: int = 0
    kind: str = "search"  # "search" | extra handler kinds | "upsert" | "delete"
    enqueued_at: float = 0.0
    # Absolute deadline (time.time()); None = no deadline. Search-kind
    # requests past it are dropped by _take_batch with DeadlineExceeded.
    deadline: Optional[float] = None
    _event: threading.Event = dataclasses.field(default_factory=threading.Event)
    result: Any = None
    error: Optional[BaseException] = None
    # Fired exactly once when the request finishes (result, error or drop).
    # Must be cheap and never raise (exceptions are swallowed) — the worker
    # thread calls it.
    on_done: Optional[Callable[["Request"], None]] = None
    _cancelled: bool = False
    # Tracing (DESIGN.md §3.11): the sampled request's parent span (a
    # router attempt leg, or a Trace root for bare submits). The worker
    # hangs queue_wait / batch_wait / execute children off it. None for
    # the unsampled 1-(1/N) of traffic.
    span: Optional[Any] = None
    _enqueued_pc: float = 0.0  # perf_counter twin of enqueued_at
    _taken_pc: float = 0.0  # stamped when the worker takes it into a batch

    def cancel(self) -> None:
        """Mark the request dead: a worker that has not yet taken it skips
        it instead of computing a result nobody will read. Best-effort — a
        request already inside a batch still computes (its result is simply
        never waited on)."""
        self._cancelled = True

    @property
    def cancelled(self) -> bool:
        return self._cancelled

    def done(self, timeout: Optional[float] = None) -> bool:
        """Wait up to ``timeout`` for completion WITHOUT cancelling on
        expiry (the router's hedge loop polls this while keeping both
        attempts alive)."""
        return self._event.wait(timeout)

    def wait(self, timeout: Optional[float] = None):
        if not self._event.wait(timeout):
            if self.kind not in _WRITE_KINDS:
                # nobody is left to read the result: let the worker skip it
                self.cancel()
            raise TimeoutError(f"request {self.id} timed out")
        if self.error is not None:
            raise self.error
        return self.result

    def _finish(self, *, result=None, error=None) -> None:
        """Worker-side completion: set outcome, fire the event, run the
        callback exactly once."""
        if error is not None:
            self.error = error
        else:
            self.result = result
        self._event.set()
        if self.on_done is not None:
            try:
                self.on_done(self)
            except Exception:
                pass  # accounting hook, never the worker's problem


class BatchingEngine:
    """handler(batch_pytree [B, ...], n_valid) -> batch results [B, ...]."""

    def __init__(
        self,
        handler: Callable[[Any, int], Any],
        *,
        batch_size: int,
        max_wait_ms: float = 5.0,
        pad_payload: Optional[Any] = None,
        prefetch_fn: Optional[Callable[[list], None]] = None,
        write_handler: Optional[Callable[[list], None]] = None,
        extra_handlers: Optional[dict] = None,
        name: str = "engine",
    ):
        self.handler = handler
        self.name = name  # the registry's `engine` label (replica id)
        self.batch_size = batch_size
        self.max_wait = max_wait_ms / 1e3
        self.pad_payload = pad_payload
        self.prefetch_fn = prefetch_fn
        self.write_handler = write_handler
        # Search-like kinds beyond "search": batched homogeneously (one kind
        # per batch, same deadline batching) but served by their own handler
        # — e.g. the router's degraded-plan ladder (DESIGN.md §3.10).
        self.extra_handlers = dict(extra_handlers or {})
        bad = set(self.extra_handlers) & ({"search"} | set(_WRITE_KINDS))
        if bad:
            raise ValueError(f"extra_handlers may not shadow builtin "
                             f"request kinds: {sorted(bad)}")
        self._q: queue.Queue = queue.Queue()
        # Lookahead buffer: _take_batch stops a batch at a kind boundary and
        # parks the first request of the next batch here (worker-only).
        self._pending: collections.deque = collections.deque()
        self._ids = itertools.count()
        self._stop = threading.Event()
        # Serialises submit()'s closed-check+enqueue against close()'s
        # stop+sentinel: without it a submit could land in the queue after
        # the worker drained it, leaving a request whose wait() never fires.
        self._submit_lock = threading.Lock()
        # Worker-mutated counters live behind _stats_lock; the public
        # `stats` property returns an atomic copy (the bare-dict attribute
        # it replaces was read torn while the worker mutated it).
        self._stats_lock = threading.Lock()
        self._stats = dict(batches=0, requests=0, occupancy_sum=0.0,
                           prefetches=0, writes=0, write_batches=0,
                           deadline_drops=0, cancelled_skips=0)
        # Registry handles, pre-bound so the hot path pays one lock+add
        # per increment (no name/label lookup per event).
        self._m_requests = obs.counter(mnames.ENGINE_REQUESTS, engine=name)
        self._m_batches = obs.counter(mnames.ENGINE_BATCHES, engine=name)
        self._m_writes = obs.counter(mnames.ENGINE_WRITES, engine=name)
        self._m_write_batches = obs.counter(
            mnames.ENGINE_WRITE_BATCHES, engine=name)
        self._m_prefetches = obs.counter(
            mnames.ENGINE_PREFETCHES, engine=name)
        self._m_deadline_drops = obs.counter(
            mnames.ENGINE_DEADLINE_DROPS, engine=name)
        self._m_cancelled = obs.counter(
            mnames.ENGINE_CANCELLED_SKIPS, engine=name)
        self._m_handler_errors = obs.counter(
            mnames.ENGINE_HANDLER_ERRORS, engine=name)
        self._m_occupancy = obs.histogram(
            mnames.ENGINE_BATCH_OCCUPANCY, engine=name)
        self._m_queue_depth = obs.gauge(
            mnames.ENGINE_QUEUE_DEPTH, engine=name)
        self._m_queue_wait = obs.histogram(
            mnames.ENGINE_QUEUE_WAIT, engine=name)
        self._m_handler_time = obs.histogram(
            mnames.ENGINE_HANDLER_TIME, engine=name)
        self._prefetch_q: Optional[queue.Queue] = None
        self._prefetch_thread = None
        if prefetch_fn is not None:
            # maxsize=1 + drop-and-replace: only the freshest queue snapshot
            # is worth warming the cache for.
            self._prefetch_q = queue.Queue(maxsize=1)
            self._prefetch_thread = threading.Thread(
                target=self._prefetch_worker, daemon=True
            )
            self._prefetch_thread.start()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    @property
    def stats(self) -> dict:
        """Deprecated view (use ``repro.obs``): an atomic snapshot of the
        legacy counter dict. Kept for callers that read e.g.
        ``engine.stats["writes"]``; unlike the bare dict it replaces, the
        copy is taken under the stats lock so a reader can never observe a
        torn multi-key update."""
        with self._stats_lock:
            return dict(self._stats)

    def _bump(self, **deltas) -> None:
        with self._stats_lock:
            for k, v in deltas.items():
                self._stats[k] += v

    def submit(self, payload, *, kind: str = "search",
               deadline_s: Optional[float] = None,
               on_done: Optional[Callable[[Request], None]] = None,
               span=None) -> Request:
        """Enqueue a search-like request. ``kind`` picks the handler
        ("search", or a key of ``extra_handlers``); ``deadline_s`` is a
        per-request budget from enqueue time — a request still queued when
        it expires is dropped with :class:`DeadlineExceeded` instead of
        occupying a batch slot. ``on_done`` must be attached here (not
        after) so a fast worker can never complete the request first.
        ``span`` is an optional tracing parent (an ``obs.Span``): the
        worker records queue_wait / batch_wait / execute children under
        it for this request."""
        if kind != "search" and kind not in self.extra_handlers:
            raise ValueError(
                f"unknown request kind {kind!r}; registered extra kinds: "
                f"{sorted(self.extra_handlers)}"
            )
        return self._enqueue(payload, kind, deadline_s=deadline_s,
                             on_done=on_done, span=span)

    def submit_upsert(self, payload) -> Request:
        """Enqueue an upsert (payload: vectors, or ``(vectors, ids)``).
        Applied by ``write_handler`` between batches; ``wait()`` returns the
        handler's per-op result (the assigned ids for an ``EpochHandle``)."""
        return self._enqueue_write(payload, "upsert")

    def submit_delete(self, ids) -> Request:
        """Enqueue a delete-by-ids write (see :meth:`submit_upsert`)."""
        return self._enqueue_write(ids, "delete")

    def _enqueue_write(self, payload, kind: str) -> Request:
        if self.write_handler is None:
            raise RuntimeError(
                f"submit_{kind}() needs a write_handler (e.g. "
                f"online.EpochHandle.apply_writes)"
            )
        return self._enqueue(payload, kind)

    def _enqueue(self, payload, kind: str,
                 deadline_s: Optional[float] = None,
                 on_done=None, span=None) -> Request:
        with self._submit_lock:
            if self._stop.is_set():
                # Raise at the call site instead of enqueueing a request
                # whose event can never fire (the worker drains requests
                # enqueued before the shutdown sentinel, then exits).
                raise RuntimeError(
                    "BatchingEngine is closed; submit() rejected"
                )
            now = time.time()
            req = Request(payload=payload, id=next(self._ids), kind=kind,
                          enqueued_at=now,
                          deadline=(now + deadline_s
                                    if deadline_s is not None else None),
                          on_done=on_done, span=span,
                          _enqueued_pc=time.perf_counter())
            self._q.put(req)
        return req

    def _drop_dead(self, req: Request, now: Optional[float] = None) -> bool:
        """Drop a cancelled / deadline-expired search-kind request (its
        wait() fires with the drop error). Returns True when dropped.
        Writes are durable once enqueued and never dropped."""
        if req.kind in _WRITE_KINDS:
            return False
        if req.cancelled:
            self._bump(cancelled_skips=1)
            self._m_cancelled.inc()
            req._finish(error=Cancelled(f"request {req.id} cancelled"))
            return True
        if req.deadline is not None and (now or time.time()) > req.deadline:
            self._bump(deadline_drops=1)
            self._m_deadline_drops.inc()
            req._finish(error=DeadlineExceeded(
                f"request {req.id} missed its deadline before a worker "
                f"took it"))
            return True
        return False

    def _take_batch(self) -> list[Request]:
        # Block until traffic arrives — an idle worker parks on the queue
        # instead of spinning a poll loop; close() unblocks it via a
        # sentinel. Batches are kind-homogeneous: a batch ends at a
        # search/write boundary and the boundary request parks in _pending
        # (FIFO preserved — a search enqueued after a write runs after it).
        while True:  # loop past requests that died while queued
            if self._pending:
                first = self._pending.popleft()
            else:
                first = self._q.get()
            if first is _SHUTDOWN:
                return []
            if not self._drop_dead(first):
                break
        first._taken_pc = time.perf_counter()
        self._m_queue_depth.set(self._q.qsize())
        batch = [first]
        if first.kind in _WRITE_KINDS:
            # Writes batch without a deadline: take whatever writes are
            # already queued (arrival order) and apply them immediately.
            while True:
                try:
                    item = self._q.get_nowait()
                except queue.Empty:
                    break
                if item is _SHUTDOWN or item.kind not in _WRITE_KINDS:
                    self._pending.append(item)
                    break
                batch.append(item)
            return batch
        deadline = first.enqueued_at + self.max_wait
        while len(batch) < self.batch_size:
            remaining = deadline - time.time()
            if remaining <= 0:
                # deadline already expired (a backlog piled up behind a slow
                # write run / compaction swap): still drain what is already
                # queued — those requests cost nothing to include, and
                # serving the backlog as single-query batches would crater
                # throughput exactly when batching matters most
                try:
                    item = self._q.get_nowait()
                except queue.Empty:
                    break
            else:
                try:
                    item = self._q.get(timeout=remaining)
                except queue.Empty:
                    break
            if item is _SHUTDOWN:
                # close() raced the fill: serve what we have; the worker
                # loop re-checks _stop (already set) and exits after.
                break
            if self._drop_dead(item):
                continue  # expired while queued: its slot goes to a live one
            if item.kind != first.kind:
                # kind boundary (a write, or a different search handler):
                # close this batch, the boundary request opens the next one
                self._pending.append(item)
                break
            item._taken_pc = time.perf_counter()
            batch.append(item)
        return batch

    def _prefetch_worker(self):
        while True:
            snapshot = self._prefetch_q.get()
            if snapshot is _SHUTDOWN:
                return
            try:
                handle = self.prefetch_fn(snapshot)
                if hasattr(handle, "wait"):
                    # async warm-up (store.cache.PrefetchHandle, the remote
                    # tier): bound the wait so a slow/faulted remote only
                    # coalesces snapshots, never wedges this thread
                    handle.wait(timeout=30.0)
                self._bump(prefetches=1)
                self._m_prefetches.inc()
            except Exception:
                pass  # best-effort: a cold cache costs latency, not errors

    def _kick_prefetch(self):
        """Hand the still-queued payloads to the prefetch thread (so cache
        warming overlaps the handler call for the batch just taken)."""
        if self._stop.is_set():  # shutting down: nothing left worth warming
            return
        with self._q.mutex:
            snapshot = [r.payload for r in self._q.queue
                        if r is not _SHUTDOWN and r.kind not in _WRITE_KINDS
                        and not r.cancelled]
        if not snapshot:
            return
        try:
            self._prefetch_q.put_nowait(snapshot)
        except queue.Full:  # helper busy: drop the stale snapshot
            try:
                dropped = self._prefetch_q.get_nowait()
            except queue.Empty:
                dropped = None
            if dropped is _SHUTDOWN:
                # close() raced us: restore the sentinel, never swallow it
                # (the prefetch thread must still terminate).
                self._prefetch_q.put(dropped)
                return
            try:
                self._prefetch_q.put_nowait(snapshot)
            except queue.Full:
                pass

    def _apply_writes(self, batch: list[Request]) -> None:
        """Hand a run of write requests to the handler *between* batches —
        the only place the index may mutate or swap epochs, so no search
        batch ever straddles one. Per-op results may be exceptions (a
        handler like ``EpochHandle.apply_writes`` isolates op failures so an
        already-applied write is never reported as failed); a handler-level
        exception fails the whole run. Either way the worker survives and
        each request's wait() returns or re-raises accordingly."""
        ops = [(r.kind, r.payload) for r in batch]
        results = None
        err = None
        try:
            results = self.write_handler(ops)
            if results is not None:
                # normalise inside the try: a generator / wrong-length
                # return is a handler bug to report, never a dead worker
                # or a silent result=None for every waiter
                results = list(results)
                if len(results) != len(batch):
                    raise RuntimeError(
                        f"write_handler returned {len(results)} results "
                        f"for {len(batch)} ops"
                    )
        except BaseException as e:  # noqa: BLE001 — reported via wait()
            err = e
        for i, r in enumerate(batch):
            if err is not None:
                r._finish(error=err)
            elif results is not None and isinstance(results[i], BaseException):
                r._finish(error=results[i])
            else:
                r._finish(result=results[i] if results is not None else None)
        self._bump(writes=len(batch), write_batches=1)
        self._m_writes.inc(len(batch))
        self._m_write_batches.inc()

    def _worker(self):
        # After close() the worker drains requests already enqueued (they
        # hold waiting callers) before exiting; _take_batch cannot block
        # here because a non-empty queue returns promptly.
        while (not self._stop.is_set() or not self._q.empty()
               or self._pending):
            batch = self._take_batch()
            if not batch:
                continue
            if batch[0].kind in _WRITE_KINDS:
                self._apply_writes(batch)
                continue
            # last-moment skip: a waiter may have timed out / a hedge twin
            # won between batch assembly and here — don't burn a handler
            # call on a batch nobody is waiting for
            batch = [r for r in batch if not self._drop_dead(r)]
            if not batch:
                continue
            if self._prefetch_q is not None:
                self._kick_prefetch()
            n = len(batch)
            handler = (self.handler if batch[0].kind == "search"
                       else self.extra_handlers[batch[0].kind])
            pad = self.pad_payload if self.pad_payload is not None else batch[0].payload
            rows = [r.payload for r in batch] + [pad] * (self.batch_size - n)
            stacked = jax.tree.map(lambda *xs: np.stack(xs), *rows)
            # Tracing: a batch serves many requests, several of which may
            # be sampled. Each traced request gets queue_wait / batch_wait
            # children (backdated from its own stamps) plus an execute
            # span; the execute spans form the thread's active set around
            # the handler call, so stage spans recorded inside (plan,
            # scan, rerank, granule fetches) mirror into every sampled
            # request of the batch.
            exec_spans = []
            t_exec = time.perf_counter()
            for r in batch:
                if r.span is None:
                    continue
                qw = r.span.child("queue_wait")
                qw.t0, qw.t1 = r._enqueued_pc, r._taken_pc
                bw = r.span.child("batch_wait")
                bw.t0, bw.t1 = r._taken_pc, t_exec
                exec_spans.append(r.span.child(
                    "execute", kind=batch[0].kind, batch=n,
                    engine=self.name))
            try:
                if exec_spans:
                    with obs.activate(exec_spans):
                        results = handler(stacked, n)
                else:
                    results = handler(stacked, n)
            except BaseException as e:  # noqa: BLE001 — a handler failure
                # fails this batch (each wait() re-raises), never the worker:
                # a dead worker would silently hang every queued and future
                # request until TimeoutError
                for s in exec_spans:
                    s.end(error=type(e).__name__)
                for r in batch:
                    r._finish(error=e)
                self._bump(batches=1, requests=n,
                           occupancy_sum=n / self.batch_size)
                self._m_handler_errors.inc()
                self._finish_batch_metrics(batch, n, t_exec)
                continue
            for s in exec_spans:
                s.end()
            for i, r in enumerate(batch):
                r._finish(result=jax.tree.map(
                    lambda a: np.asarray(a)[i], results))
            self._bump(batches=1, requests=n,
                       occupancy_sum=n / self.batch_size)
            self._finish_batch_metrics(batch, n, t_exec)

    def _finish_batch_metrics(self, batch, n, t_exec):
        self._m_batches.inc()
        self._m_requests.inc(n)
        self._m_occupancy.observe(n / self.batch_size)
        self._m_handler_time.observe(time.perf_counter() - t_exec)
        for r in batch:
            self._m_queue_wait.observe(r._taken_pc - r._enqueued_pc)

    def close(self):
        with self._submit_lock:
            self._stop.set()
            self._q.put(_SHUTDOWN)  # wake a worker parked on get(); any
            # request enqueued before the sentinel still gets served.
        self._thread.join(timeout=2.0)
        if self._prefetch_q is not None:
            try:  # drop any pending snapshot so the sentinel never blocks
                self._prefetch_q.get_nowait()
            except queue.Empty:
                pass
            self._prefetch_q.put(_SHUTDOWN)
            self._prefetch_thread.join(timeout=2.0)

    @property
    def mean_occupancy(self) -> float:
        snap = self.stats  # one atomic snapshot (not two racing reads)
        b = snap["batches"]
        return snap["occupancy_sum"] / b if b else 0.0


class QueryHandler:
    """Serve a declarative ``repro.query.Query`` as the engine's search
    handler (DESIGN.md §3.8).

    ``source`` is where the live index comes from: a ``PDASCIndex``, an
    ``online.EpochHandle`` (anything with a ``.current`` epoch reference),
    or a zero-arg callable returning the index. Each batch resolves the
    epoch snapshot **once** and executes ``idx.plan(query)`` — the
    per-index plan cache keys on the capability fingerprint, so the plan is
    reused across batches and re-planning happens only when capabilities
    actually change (an epoch swap publishes a new index object with a
    fresh cache; a write dirtying a tier flips the fingerprint). Steady
    state is one cached plan, zero retraces.
    """

    def __init__(self, source, query):
        self.query = query
        if hasattr(source, "current"):  # EpochHandle-like (RCU reference)
            self._resolve = lambda: source.current
        elif callable(source) and not hasattr(source, "plan"):
            self._resolve = source
        else:  # a bare (frozen or manually-mutated) index
            self._resolve = lambda: source

    @property
    def current(self):
        """The index snapshot the next batch would serve against."""
        return self._resolve()

    def plan(self):
        """The plan the next batch would execute (for ``explain()``)."""
        return self.current.plan(self.query)

    def describe(self) -> dict:
        """Plan features for the next batch (``SearchPlan.describe()``) —
        what the cost log joins against measured span timings."""
        return self.plan().describe()

    def __call__(self, batch, n_valid):
        res = self.current.plan(self.query)(batch)
        return res.dists, res.ids
