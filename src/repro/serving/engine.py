"""Batched request engine.

Requests are queued and served in fixed-size batches (padded to the
compiled batch size so every call hits the same executable — no recompiles
on the serving path). A worker thread drains the queue with a max-wait
deadline: a batch departs when full OR when the oldest request has waited
``max_wait_ms`` (p99-friendly batching).

Used by ``launch/serve.py`` for two endpoints:
  * PDASC k-NN queries  (handler = distributed NSA search)
  * recsys CTR scoring  (handler = recsys serve step)
"""

from __future__ import annotations

import dataclasses
import itertools
import queue
import threading
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

# Sentinel pushed by close() to wake a worker blocked on the request queue.
_SHUTDOWN = object()


@dataclasses.dataclass
class Request:
    payload: Any  # one query row (pytree of arrays, leading dim absent)
    id: int = 0
    enqueued_at: float = 0.0
    _event: threading.Event = dataclasses.field(default_factory=threading.Event)
    result: Any = None

    def wait(self, timeout: Optional[float] = None):
        if not self._event.wait(timeout):
            raise TimeoutError(f"request {self.id} timed out")
        return self.result


class BatchingEngine:
    """handler(batch_pytree [B, ...], n_valid) -> batch results [B, ...]."""

    def __init__(
        self,
        handler: Callable[[Any, int], Any],
        *,
        batch_size: int,
        max_wait_ms: float = 5.0,
        pad_payload: Optional[Any] = None,
    ):
        self.handler = handler
        self.batch_size = batch_size
        self.max_wait = max_wait_ms / 1e3
        self.pad_payload = pad_payload
        self._q: queue.Queue = queue.Queue()
        self._ids = itertools.count()
        self._stop = threading.Event()
        self.stats = dict(batches=0, requests=0, occupancy_sum=0.0)
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def submit(self, payload) -> Request:
        req = Request(payload=payload, id=next(self._ids),
                      enqueued_at=time.time())
        self._q.put(req)
        return req

    def _take_batch(self) -> list[Request]:
        # Block until traffic arrives — an idle worker parks on the queue
        # instead of spinning a poll loop; close() unblocks it via a sentinel.
        first = self._q.get()
        if first is _SHUTDOWN:
            return []
        batch = [first]
        deadline = first.enqueued_at + self.max_wait
        while len(batch) < self.batch_size:
            remaining = deadline - time.time()
            if remaining <= 0:
                break
            try:
                item = self._q.get(timeout=remaining)
            except queue.Empty:
                break
            if item is _SHUTDOWN:
                # close() raced the fill: serve what we have; the worker
                # loop re-checks _stop (already set) and exits after.
                break
            batch.append(item)
        return batch

    def _worker(self):
        # After close() the worker drains requests already enqueued (they
        # hold waiting callers) before exiting; _take_batch cannot block
        # here because a non-empty queue returns promptly.
        while not self._stop.is_set() or not self._q.empty():
            batch = self._take_batch()
            if not batch:
                continue
            n = len(batch)
            pad = self.pad_payload if self.pad_payload is not None else batch[0].payload
            rows = [r.payload for r in batch] + [pad] * (self.batch_size - n)
            stacked = jax.tree.map(lambda *xs: np.stack(xs), *rows)
            results = self.handler(stacked, n)
            for i, r in enumerate(batch):
                r.result = jax.tree.map(lambda a: np.asarray(a)[i], results)
                r._event.set()
            self.stats["batches"] += 1
            self.stats["requests"] += n
            self.stats["occupancy_sum"] += n / self.batch_size

    def close(self):
        self._stop.set()
        self._q.put(_SHUTDOWN)  # wake the worker if it is parked on get()
        self._thread.join(timeout=2.0)

    @property
    def mean_occupancy(self) -> float:
        b = self.stats["batches"]
        return self.stats["occupancy_sum"] / b if b else 0.0
