"""Batched request engine.

Requests are queued and served in fixed-size batches (padded to the
compiled batch size so every call hits the same executable — no recompiles
on the serving path). A worker thread drains the queue with a max-wait
deadline: a batch departs when full OR when the oldest request has waited
``max_wait_ms`` (p99-friendly batching).

``prefetch_fn`` hooks storage-aware serving (DESIGN.md §3.6): while the
worker runs the current batch, a helper thread receives a snapshot of the
payloads still queued — a tiered-store handler uses it to warm the leaf
store's granule cache so the next batch's exact-rerank fetches hit memory
instead of disk. Prefetching is best-effort: snapshots that arrive while
the helper is busy are coalesced to the latest one, and exceptions are
swallowed (a cold cache is a latency miss, not an error).

Used by ``launch/serve.py`` for two endpoints:
  * PDASC k-NN queries  (handler = distributed NSA search)
  * recsys CTR scoring  (handler = recsys serve step)
"""

from __future__ import annotations

import dataclasses
import itertools
import queue
import threading
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

# Sentinel pushed by close() to wake a worker blocked on the request queue.
_SHUTDOWN = object()


@dataclasses.dataclass
class Request:
    payload: Any  # one query row (pytree of arrays, leading dim absent)
    id: int = 0
    enqueued_at: float = 0.0
    _event: threading.Event = dataclasses.field(default_factory=threading.Event)
    result: Any = None

    def wait(self, timeout: Optional[float] = None):
        if not self._event.wait(timeout):
            raise TimeoutError(f"request {self.id} timed out")
        return self.result


class BatchingEngine:
    """handler(batch_pytree [B, ...], n_valid) -> batch results [B, ...]."""

    def __init__(
        self,
        handler: Callable[[Any, int], Any],
        *,
        batch_size: int,
        max_wait_ms: float = 5.0,
        pad_payload: Optional[Any] = None,
        prefetch_fn: Optional[Callable[[list], None]] = None,
    ):
        self.handler = handler
        self.batch_size = batch_size
        self.max_wait = max_wait_ms / 1e3
        self.pad_payload = pad_payload
        self.prefetch_fn = prefetch_fn
        self._q: queue.Queue = queue.Queue()
        self._ids = itertools.count()
        self._stop = threading.Event()
        # Serialises submit()'s closed-check+enqueue against close()'s
        # stop+sentinel: without it a submit could land in the queue after
        # the worker drained it, leaving a request whose wait() never fires.
        self._submit_lock = threading.Lock()
        self.stats = dict(batches=0, requests=0, occupancy_sum=0.0,
                          prefetches=0)
        self._prefetch_q: Optional[queue.Queue] = None
        self._prefetch_thread = None
        if prefetch_fn is not None:
            # maxsize=1 + drop-and-replace: only the freshest queue snapshot
            # is worth warming the cache for.
            self._prefetch_q = queue.Queue(maxsize=1)
            self._prefetch_thread = threading.Thread(
                target=self._prefetch_worker, daemon=True
            )
            self._prefetch_thread.start()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def submit(self, payload) -> Request:
        with self._submit_lock:
            if self._stop.is_set():
                # Raise at the call site instead of enqueueing a request
                # whose event can never fire (the worker drains requests
                # enqueued before the shutdown sentinel, then exits).
                raise RuntimeError(
                    "BatchingEngine is closed; submit() rejected"
                )
            req = Request(payload=payload, id=next(self._ids),
                          enqueued_at=time.time())
            self._q.put(req)
        return req

    def _take_batch(self) -> list[Request]:
        # Block until traffic arrives — an idle worker parks on the queue
        # instead of spinning a poll loop; close() unblocks it via a sentinel.
        first = self._q.get()
        if first is _SHUTDOWN:
            return []
        batch = [first]
        deadline = first.enqueued_at + self.max_wait
        while len(batch) < self.batch_size:
            remaining = deadline - time.time()
            if remaining <= 0:
                break
            try:
                item = self._q.get(timeout=remaining)
            except queue.Empty:
                break
            if item is _SHUTDOWN:
                # close() raced the fill: serve what we have; the worker
                # loop re-checks _stop (already set) and exits after.
                break
            batch.append(item)
        return batch

    def _prefetch_worker(self):
        while True:
            snapshot = self._prefetch_q.get()
            if snapshot is _SHUTDOWN:
                return
            try:
                self.prefetch_fn(snapshot)
                self.stats["prefetches"] += 1
            except Exception:
                pass  # best-effort: a cold cache costs latency, not errors

    def _kick_prefetch(self):
        """Hand the still-queued payloads to the prefetch thread (so cache
        warming overlaps the handler call for the batch just taken)."""
        if self._stop.is_set():  # shutting down: nothing left worth warming
            return
        with self._q.mutex:
            snapshot = [r.payload for r in self._q.queue
                        if r is not _SHUTDOWN]
        if not snapshot:
            return
        try:
            self._prefetch_q.put_nowait(snapshot)
        except queue.Full:  # helper busy: drop the stale snapshot
            try:
                dropped = self._prefetch_q.get_nowait()
            except queue.Empty:
                dropped = None
            if dropped is _SHUTDOWN:
                # close() raced us: restore the sentinel, never swallow it
                # (the prefetch thread must still terminate).
                self._prefetch_q.put(dropped)
                return
            try:
                self._prefetch_q.put_nowait(snapshot)
            except queue.Full:
                pass

    def _worker(self):
        # After close() the worker drains requests already enqueued (they
        # hold waiting callers) before exiting; _take_batch cannot block
        # here because a non-empty queue returns promptly.
        while not self._stop.is_set() or not self._q.empty():
            batch = self._take_batch()
            if not batch:
                continue
            if self._prefetch_q is not None:
                self._kick_prefetch()
            n = len(batch)
            pad = self.pad_payload if self.pad_payload is not None else batch[0].payload
            rows = [r.payload for r in batch] + [pad] * (self.batch_size - n)
            stacked = jax.tree.map(lambda *xs: np.stack(xs), *rows)
            results = self.handler(stacked, n)
            for i, r in enumerate(batch):
                r.result = jax.tree.map(lambda a: np.asarray(a)[i], results)
                r._event.set()
            self.stats["batches"] += 1
            self.stats["requests"] += n
            self.stats["occupancy_sum"] += n / self.batch_size

    def close(self):
        with self._submit_lock:
            self._stop.set()
            self._q.put(_SHUTDOWN)  # wake a worker parked on get(); any
            # request enqueued before the sentinel still gets served.
        self._thread.join(timeout=2.0)
        if self._prefetch_q is not None:
            try:  # drop any pending snapshot so the sentinel never blocks
                self._prefetch_q.get_nowait()
            except queue.Empty:
                pass
            self._prefetch_q.put(_SHUTDOWN)
            self._prefetch_thread.join(timeout=2.0)

    @property
    def mean_occupancy(self) -> float:
        b = self.stats["batches"]
        return self.stats["occupancy_sum"] / b if b else 0.0
