"""Serving layer: batched request engine for ANN search and LM decode."""

from repro.serving.engine import BatchingEngine, Request

__all__ = ["BatchingEngine", "Request"]
