"""Serving layer (DESIGN.md §3.9–3.10): the batched request engine, and the
replicated fault-tolerant tier above it — health-checked replica pool,
retry/hedge/backoff router, admission control with graceful degradation,
and the deterministic fault-injection harness."""

from repro.serving.engine import (
    BatchingEngine,
    Cancelled,
    DeadlineExceeded,
    QueryHandler,
    Request,
)
from repro.serving.faults import FaultPlan, FaultSpec, InjectedFault, \
    ReplicaCrashed
from repro.serving.replicated import Replica, ReplicaDown, ReplicaSet, \
    clone_index
from repro.serving.router import (
    Overloaded,
    ReplicaUnavailable,
    Router,
    RouterConfig,
    RouterResult,
)

__all__ = [
    "BatchingEngine",
    "Cancelled",
    "DeadlineExceeded",
    "FaultPlan",
    "FaultSpec",
    "InjectedFault",
    "Overloaded",
    "QueryHandler",
    "Replica",
    "ReplicaCrashed",
    "ReplicaDown",
    "ReplicaSet",
    "ReplicaUnavailable",
    "Request",
    "Router",
    "RouterConfig",
    "RouterResult",
    "clone_index",
]
