"""Serving layer: batched request engine for ANN search and LM decode."""

from repro.serving.engine import BatchingEngine, QueryHandler, Request

__all__ = ["BatchingEngine", "QueryHandler", "Request"]
