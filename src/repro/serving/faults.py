"""Deterministic fault-injection harness for the replicated serving tier
(DESIGN.md §3.10).

Every fault a replica can exhibit is described by a :class:`FaultSpec`
window in **per-replica dispatch-count space**, not wall-clock time: the
N-th handler dispatch on replica ``r`` either runs clean or hits the fault,
regardless of machine speed or scheduling jitter. A :class:`FaultPlan` is a
frozen set of specs; ``plan.injector(replica_id)`` hands each replica its
own :class:`FaultInjector`, which the :class:`~repro.serving.replicated
.Replica` wraps around its batch handler. Health probes dispatch through
the same handler, so they advance the same counter — a wedged replica
"recovers" after a deterministic number of (failed) probe dispatches, which
is what makes ejection → half-open → readmission testable without sleeping
through real outage clocks.

Fault kinds:

``latency``
    every dispatch in the window sleeps ``delay_s`` before serving — a slow
    replica (tail-latency spike); requests still succeed.
``error``
    every dispatch in the window raises :class:`InjectedFault` — an error
    burst (bad deploy, poisoned shard); the router's retry path absorbs it.
``wedge``
    every dispatch in the window sleeps ``delay_s`` (default far past any
    caller deadline) before serving — a wedged worker: callers hedge away,
    queued requests miss their deadlines, health probes time out until the
    window's dispatches are spent.
``crash``
    the first dispatch in the window raises :class:`ReplicaCrashed`; the
    replica set tears the engine down (simulated process death) and every
    dispatch until the window closes keeps crashing on restart attempts.
    After the window the replica restarts clean and catches up on the
    write log.

Seeded generation: :meth:`FaultPlan.generate` derives a reproducible random
schedule from a seed (``numpy.random.default_rng`` — no wall-clock
randomness anywhere), and :meth:`FaultPlan.parse` builds one from a compact
CLI string (``launch/serve.py --faults``, ``benchmarks/bench_serve.py``).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Optional

import numpy as np

KINDS = ("latency", "error", "wedge", "crash")

# Default sleep for a wedged dispatch: far past any sane caller deadline.
DEFAULT_WEDGE_S = 0.75


class InjectedFault(RuntimeError):
    """A fault-plan error burst (the injected analogue of a handler bug)."""


class ReplicaCrashed(RuntimeError):
    """A fault-plan crash: the replica's engine must be torn down."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One fault window on one replica.

    ``start`` / ``duration`` are in per-replica handler *dispatches* (batch
    calls, probes included): dispatches ``start <= i < start + duration``
    hit the fault. ``delay_s`` is the injected latency for ``latency`` /
    ``wedge`` kinds.
    """

    kind: str
    replica: int
    start: int
    duration: int
    delay_s: float = 0.0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; one of {KINDS}")
        if self.start < 0 or self.duration < 1:
            raise ValueError(
                f"fault window needs start >= 0, duration >= 1 "
                f"(got start={self.start}, duration={self.duration})"
            )
        if self.kind == "wedge" and self.delay_s == 0.0:
            object.__setattr__(self, "delay_s", DEFAULT_WEDGE_S)

    @property
    def end(self) -> int:
        return self.start + self.duration

    def covers(self, dispatch: int) -> bool:
        return self.start <= dispatch < self.end


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A frozen, deterministic schedule of :class:`FaultSpec` windows."""

    specs: tuple = ()

    def __post_init__(self):
        object.__setattr__(self, "specs", tuple(self.specs))

    def for_replica(self, replica: int) -> tuple:
        return tuple(s for s in self.specs if s.replica == replica)

    def injector(self, replica: int) -> "FaultInjector":
        return FaultInjector(self.for_replica(replica))

    def max_dispatch(self) -> int:
        """The dispatch count after which every window has closed."""
        return max((s.end for s in self.specs), default=0)

    # -- construction ---------------------------------------------------------

    @classmethod
    def parse(cls, text: str) -> "FaultPlan":
        """Compact CLI syntax: ``kind:rR@START+DURATION[:DELAY_S]``, ``;``
        or ``,`` separated, e.g. ``wedge:r1@20+8`` or
        ``latency:r0@10+30:0.05;error:r2@40+5``."""
        specs = []
        for part in text.replace(",", ";").split(";"):
            part = part.strip()
            if not part:
                continue
            try:
                kind, rest = part.split(":", 1)
                fields = rest.split(":")
                loc = fields[0]
                delay = float(fields[1]) if len(fields) > 1 else 0.0
                rep, window = loc.split("@")
                rep = int(rep.lstrip("r"))
                start, duration = (int(v) for v in window.split("+"))
            except (ValueError, IndexError) as e:
                raise ValueError(
                    f"bad fault spec {part!r} (want kind:rR@START+DURATION"
                    f"[:DELAY_S], e.g. wedge:r1@20+8): {e}"
                ) from None
            specs.append(FaultSpec(kind=kind.strip(), replica=rep,
                                   start=start, duration=duration,
                                   delay_s=delay))
        return cls(specs=tuple(specs))

    @classmethod
    def generate(cls, *, seed: int, n_replicas: int, n_faults: int = 4,
                 horizon: int = 200, kinds: tuple = KINDS,
                 max_duration: int = 12,
                 delay_s: float = 0.05) -> "FaultPlan":
        """A reproducible random schedule: ``n_faults`` windows drawn from a
        seeded generator. Same seed, same plan — never wall-clock random."""
        rng = np.random.default_rng(seed)
        specs = []
        for _ in range(n_faults):
            kind = kinds[int(rng.integers(len(kinds)))]
            specs.append(FaultSpec(
                kind=kind,
                replica=int(rng.integers(n_replicas)),
                start=int(rng.integers(horizon)),
                duration=int(rng.integers(1, max_duration + 1)),
                delay_s=float(delay_s),
            ))
        return cls(specs=tuple(specs))


class FaultInjector:
    """Per-replica fault application: call :meth:`on_dispatch` at the top of
    every handler dispatch. Thread-safe (the replica's engine worker and the
    router's probe path may race on restart)."""

    def __init__(self, specs: tuple):
        self.specs = tuple(specs)
        self._lock = threading.Lock()
        self._dispatch = 0

    @property
    def dispatches(self) -> int:
        return self._dispatch

    def active(self, dispatch: Optional[int] = None) -> Optional[FaultSpec]:
        """The spec covering a dispatch index (default: the next one)."""
        d = self._dispatch if dispatch is None else dispatch
        for s in self.specs:
            if s.covers(d):
                return s
        return None

    def on_dispatch(self) -> None:
        """Advance the dispatch counter and apply whatever fault covers it:
        sleep (latency / wedge) or raise (error / crash)."""
        with self._lock:
            d = self._dispatch
            self._dispatch += 1
            spec = self.active(d)
        if spec is None:
            return
        if spec.kind in ("latency", "wedge"):
            time.sleep(spec.delay_s)
        elif spec.kind == "error":
            raise InjectedFault(
                f"injected error (replica r{spec.replica}, dispatch {d}, "
                f"window {spec.start}+{spec.duration})"
            )
        else:  # crash
            raise ReplicaCrashed(
                f"injected crash (replica r{spec.replica}, dispatch {d}, "
                f"window {spec.start}+{spec.duration})"
            )
