"""The declarative :class:`Query` spec — *what* to retrieve, never *how*.

A query names the result contract (``k``, ``radius``), the quality/cost
knobs (``beam`` schedule, ``rerank_width``, ``leaf_radius_filter``) and at
most a *preference* for the execution pipeline (``execution``, default
``"auto"``). Everything else — which pipeline actually runs, which kernel
ops it lowers onto, whether a tombstone mask or delta-scan leg folds into
the result — is decided by the planner (``repro.query.plan``) from the
index's capabilities at plan time.

Queries are frozen and hashable: a ``Query`` is a cache key. The plan cache
(``PDASCIndex.plan``) keys on ``(query, capability fingerprint)``, and the
jit caches underneath key on the query's static fields — two calls with an
equal ``Query`` hit the same compiled executable.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Union

import numpy as np

from repro.core import distances as dist_lib
from repro.kernels import ops as kops

# Execution preferences a Query may name. "auto" lets the planner choose
# from the index's capabilities; the rest force a pipeline (and fail at plan
# time when the index cannot serve it). "beam_vmap" is the seed per-query
# baseline, kept for benchmarking.
EXECUTIONS = ("auto", "dense", "beam", "beam_vmap", "two_stage", "sharded")

Radius = Union[None, float, tuple]
Beam = Union[int, tuple]


def _freeze_schedule(value, *, numeric=float):
    """Normalise a scalar-or-per-level schedule to a hashable static value."""
    if value is None:
        return None
    if isinstance(value, (list, tuple)):
        return tuple(numeric(v) for v in value)
    return numeric(value)


@dataclasses.dataclass(frozen=True)
class Query:
    """Declarative k-ANN query spec (hashable; every field is jit-static).

    Attributes:
      k: neighbours to return.
      radius: search radius — scalar, per-level tuple indexed by level
        (``radius[0]`` = leaf, ``radius[-1]`` = top, matching
        ``nsa._per_level_radii``), or None for the index's
        ``default_radius`` (resolved at plan time).
      execution: pipeline preference, one of :data:`EXECUTIONS`. ``"auto"``
        picks from the index capabilities: ``two_stage`` once the dense
        payload was released, the batched ``beam`` hot path otherwise.
      beam: surviving prototypes per level — scalar or per-level schedule
        (same leaf-first level indexing as ``radius``).
      rerank_width: two-stage only — survivors of the quantised scan that
        advance to the exact rerank (None / <= 0 = ∞, bit-identical to
        ``beam``).
      exact_rerank: two-stage only — when False, skip stage 2 entirely and
        rank on quantised-scan distances alone (the graceful-degradation
        plan: cheapest possible serve, recall bounded by the code
        resolution). Ignored by pipelines with no rerank stage.
      leaf_radius_filter: apply the radius at the leaf ranking too (paper
        Algorithm 2 does not; this is the stricter variant).
      with_stats: include the candidate-count reduction (serving sets False).
      kernel: kernel-layer block knobs (None = defaults). With
        ``KernelConfig(auto=True)`` the planner resolves knobs left at their
        defaults from the persisted block-size tuner cache
        (``repro.kernels.autotune``) and re-plans — retracing the jitted
        pipelines — when the cached winners change; explicitly set fields
        still win.
    """

    k: int = 10
    radius: Radius = None
    execution: str = "auto"
    beam: Beam = 32
    rerank_width: Optional[int] = 128
    exact_rerank: bool = True
    leaf_radius_filter: bool = False
    with_stats: bool = True
    kernel: Optional[kops.KernelConfig] = None

    def __post_init__(self):
        if int(self.k) < 1:
            raise ValueError(f"query k must be >= 1, got {self.k}")
        object.__setattr__(self, "k", int(self.k))
        if self.execution not in EXECUTIONS:
            raise ValueError(
                f"unknown search mode {self.execution!r}; valid executions: "
                f"{EXECUTIONS}"
            )
        object.__setattr__(self, "radius", _freeze_schedule(self.radius))
        object.__setattr__(
            self, "beam", _freeze_schedule(self.beam, numeric=int)
        )
        if self.rerank_width is not None:
            object.__setattr__(self, "rerank_width", int(self.rerank_width))


def degraded(query: Query) -> Query:
    """The graceful-degradation rewrite of ``query`` (DESIGN.md §3.10).

    Under admission-control pressure the router serves this cheaper spec
    instead of rejecting: beam narrowed (halved, floor 8 per level), the
    exact rerank stage dropped (``exact_rerank=False`` — rank on quantised
    scan distances alone where the index stores codes; indices serving the
    exact payload just run the narrower beam), rerank width collapsed to
    ``k``, and stats off. Same ``k`` and radius — the result contract
    holds, only the quality/cost knobs move. Deterministic and frozen, so
    the degraded plan compiles once and caches like any other.
    """
    beam = query.beam
    if isinstance(beam, tuple):
        beam = tuple(max(8, b // 2) for b in beam)
    elif beam is not None:
        beam = max(8, int(beam) // 2)
    return dataclasses.replace(
        query,
        beam=beam,
        rerank_width=query.k,
        exact_rerank=False,
        with_stats=False,
    )


def is_concrete(Q) -> bool:
    """False inside a jit/shard_map trace (validation must be skipped there:
    a plan may be executed inside a lowered step, e.g. the dry-run cells)."""
    try:
        from jax.core import Tracer
    except ImportError:  # pragma: no cover - future jax relocations
        return True
    return not isinstance(Q, Tracer)


def validate_query_batch(
    Q, dist: dist_lib.Distance, *, expect_dim: Optional[int] = None
) -> None:
    """Search-time query validation (the build/upsert counterpart of
    ``index._validate_points``): ``needs_dim`` distances reject wrong widths
    and non-finite rows fail loudly instead of silently poisoning every
    distance they touch. No-op on tracers (plans run inside jit too).

    Shape / dimensionality checks are metadata-only and always run. The
    non-finite data scan runs for *host* inputs only (numpy arrays, lists —
    what users and the serving engine's stacked batches pass): for an array
    already committed to a device it would force a blocking device->host
    transfer per call, stalling async dispatch on the serving hot path, so
    device arrays are trusted to have been validated when they were built.
    """
    if not is_concrete(Q):
        return
    import jax

    on_device = isinstance(Q, jax.Array)
    arr = None if on_device else np.asarray(Q)
    shape = Q.shape if on_device else arr.shape
    if len(shape) not in (1, 2):
        raise ValueError(f"queries must be [d] or [B, d], got shape {shape}")
    d = shape[-1]
    if dist.needs_dim is not None and d != dist.needs_dim:
        raise ValueError(
            f"distance {dist.name!r} needs d={dist.needs_dim} queries, got "
            f"d={d} at search time"
        )
    if expect_dim is not None and d != expect_dim:
        raise ValueError(
            f"query dimensionality d={d} does not match the index (d="
            f"{expect_dim})"
        )
    if arr is None:
        return
    finite = np.isfinite(np.asarray(arr, np.float32))
    if not finite.all():
        rows = finite.all(axis=-1)
        bad = int((~np.atleast_1d(rows)).sum())
        raise ValueError(
            f"queries contain non-finite values ({bad} rows with NaN/inf); "
            f"clean the queries before searching"
        )
