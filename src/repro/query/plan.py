"""The SearchPlan compiler — lower a :class:`~repro.query.spec.Query` onto
the execution substrate an index actually has (DESIGN.md §3.8).

``compile_plan(index, query)`` inspects the index's *capabilities* at plan
time — store attached? dense payload released? online tiers dirty? — and
binds the one pipeline that serves the query:

=============  ==============================================================
pipeline       kernel-layer lowering
=============  ==============================================================
``dense``      per level one ``ops.pairwise_distance`` matrix + masked top-k
``beam``       beam descent (``ops.pairwise_distance`` top level,
               ``ops.rank_gathered`` per inner level) + one fused
               ``ops.rank_gathered`` leaf rank
``two_stage``  beam descent -> ``ops.scan_quantized`` over the payload codes
               -> exact ``ops.rank_candidates`` rerank of the survivors
               (∞ rerank width: the same jitted ``search_beam`` over the
               exact payload — bit-identical to ``beam``)
``beam_vmap``  the seed per-query vmap baseline (benchmarks only)
``sharded``    per-shard dense/beam + butterfly/allgather top-k merge over a
               mesh (:func:`compile_sharded_plan`)
=============  ==============================================================

The online legs are resolved ONCE, at plan time: a plan compiled against a
tombstoned index threads ``TombstoneSet.valid_mask()`` (a cached device
array) into the leaf ranking, and a plan compiled against an active delta
buffer appends the exact delta scan + ``merge_topk`` leg. Capability
conflicts — ``two_stage`` without a store, ``beam_vmap`` with dirty online
tiers, ``dense``/``beam`` after ``release_dense_payload`` — raise at plan
time, not mid-search.

Plans never retrace on re-execution: the jitted callables underneath key on
the query's static fields, and the plan cache (``PDASCIndex.plan``) keys on
``(query, capability fingerprint)`` so an equal query on an unchanged index
returns the *same* plan object. A plan executed after an *in-place* tier
mutation on its index (an upsert activating the delta buffer, a delete
dirtying the tombstones, a released payload) detects the stale fingerprint
and transparently re-plans through the index's cache — correctness never
depends on the caller re-planning, it is only faster. Epoch swaps are
different by design: compaction is RCU and publishes a NEW index object, so
a plan bound to the old object keeps serving its (immutable, still-valid)
old epoch; epoch currency comes from resolving the live index before
planning, which is exactly what ``serving.QueryHandler`` /
``online.EpochHandle`` do per batch.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from repro import obs
from repro.core import distances as dist_lib
from repro.core import nsa
from repro.core.distances import BIG
from repro.kernels import autotune as _autotune
from repro.obs import names as mnames
from repro.query.spec import Query, validate_query_batch

Array = jax.Array

# Stale-fingerprint execution outcome recorded in plan_stats() (a replanned
# execution also counts a cache hit/compile under the index's plan cache).
STALENESS_REPLAN = "replans"

# Per-pipeline planner counters: how often a plan was compiled, served from
# the index plan cache, re-planned because its fingerprint went stale, and
# executed. bench_search.py records these into BENCH_search.json so a
# retracing regression (compiles growing with executions) shows up in the
# perf trajectory.
_STATS: dict = collections.defaultdict(
    lambda: dict(compiles=0, cache_hits=0, replans=0, executions=0)
)


def plan_stats() -> dict:
    """Snapshot of the per-pipeline planner counters."""
    return {p: dict(v) for p, v in sorted(_STATS.items())}


def reset_plan_stats() -> None:
    _STATS.clear()


def record_cache_hit(pipeline: str) -> None:
    _STATS[pipeline]["cache_hits"] += 1
    obs.counter(mnames.PLAN_CACHE_HITS, pipeline=pipeline).inc()


# ---------------------------------------------------------------------------
# Capabilities
# ---------------------------------------------------------------------------


class Capabilities(NamedTuple):
    """The index capability fingerprint a plan binds against.

    Structural facts only — things that change *which program* runs (the
    pipeline choice, the presence of the mask / delta legs), never array
    values (those flow in at execution time: a new delete updates the cached
    mask array without changing the fingerprint).
    """

    epoch: int
    n_levels: int
    store: Optional[str]  # payload-tier backend, None = dense seed path
    payload_released: bool
    remote: bool  # exact payload behind a remote store (fetch = network op)
    delta_dirty: bool  # active delta entries -> the exact-scan merge leg
    tombstones_dirty: bool  # dead slots -> the slot_valid mask threading
    tuned_gen: int  # autotune winner-cache generation (auto=True kernels)


def capabilities(index) -> Capabilities:
    """Fingerprint an index's current capabilities (cheap host-side reads)."""
    return Capabilities(
        epoch=index.epoch,
        n_levels=len(index.data.levels),
        store=index.store.backend if index.store is not None else None,
        payload_released=bool(index._payload_released),
        remote=bool(
            index.store is not None
            and getattr(index.store.exact, "remote", False)
        ),
        delta_dirty=bool(index.delta is not None and index.delta.n_active),
        tombstones_dirty=bool(
            index.tombstones is not None and index.tombstones.count
        ),
        tuned_gen=_autotune.generation(),
    )


def _stamped_kernel(kernel, caps: Optional[Capabilities] = None):
    """Stamp an ``auto=True`` kernel config with the tuner generation.

    The stamped config is what the jitted pipelines receive as their static
    kernel argument: a retune bumps the generation, the stamp changes, and
    the search retraces picking up the new winners (``ops.resolve_blocks``
    reads the cache at trace time). Non-auto configs pass through untouched
    — their knobs never depend on the cache, so retunes must not retrace
    them.
    """
    if kernel is None or not getattr(kernel, "auto", False):
        return kernel
    gen = caps.tuned_gen if caps is not None else _autotune.generation()
    return kernel._replace(tuned_gen=gen)


_LOWERING = {
    "dense": "per level one ops.pairwise_distance [B, n_l] matrix + masked "
             "jax.lax.top_k",
    "beam": "nsa.descend_beam (ops.pairwise_distance top level + fused "
            "ops.rank_gathered per inner level) -> fused ops.rank_gathered "
            "leaf rank",
    "beam_vmap": "seed baseline: per-query vmap of dist.point gathers + "
                 "per-level top_k",
    "two_stage": "nsa.descend_beam -> ops.scan_quantized (native-dtype "
                 "payload scan) -> exact ops.rank_candidates rerank of the "
                 "top-R survivors",
    "two_stage_inf": "∞ rerank: the same jitted nsa.search_beam over the "
                     "exact fp32 payload (bit-identical to 'beam')",
    "two_stage_scan": "degraded scan-only: nsa.descend_beam -> "
                      "ops.scan_quantized ranked on code distances alone "
                      "(no exact rerank stage)",
    "sharded": "per-shard nsa.search_{mode} under shard_map -> "
               "distributed.topk_merge global top-k",
}


def _resolve_pipeline(query: Query, caps: Capabilities) -> str:
    """Choose + validate the pipeline. Conflicts raise here — at plan time."""
    execution = query.execution
    if execution == "sharded":
        raise ValueError(
            "execution='sharded' needs a mesh layout: compile with "
            "repro.query.compile_sharded_plan(mesh, query, ...)"
        )
    if execution == "auto":
        execution = "two_stage" if caps.payload_released else "beam"
    if execution == "two_stage":
        if caps.store is None:
            raise ValueError(
                "mode='two_stage' needs a leaf store: build with "
                "store='int8' or call attach_store()"
            )
    elif execution in ("dense", "beam", "beam_vmap"):
        if caps.payload_released:
            raise ValueError(
                f"mode={execution!r} needs the dense leaf payload, which was "
                "released (release_dense_payload); use mode='two_stage'"
            )
        if execution == "beam_vmap" and (
            caps.delta_dirty or caps.tombstones_dirty
        ):
            raise ValueError(
                "mode='beam_vmap' (the seed benchmark baseline) does not "
                "support the online tiers; use 'beam'/'dense'/'two_stage' "
                "or compact() first"
            )
    return execution


# ---------------------------------------------------------------------------
# SearchPlan (local pipelines)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)
class SearchPlan:
    """An executable binding of (query, index capabilities) -> pipeline.

    Call it with a query batch: ``plan(Q) -> nsa.SearchResult`` (``Q``:
    [B, d] or [d]; a 1-d query returns squeezed results, matching the
    legacy ``search()`` contract bit-for-bit). Execution validates concrete
    queries (``needs_dim`` / non-finite -> ValueError), threads the cached
    tombstone mask and merges the delta leg exactly as bound at plan time,
    and dispatches the same module-level jitted callables every time — an
    equal plan executed twice triggers zero new traces.
    """

    index: "object"  # PDASCIndex (duck-typed; no import cycle)
    query: Query
    caps: Capabilities
    pipeline: str
    radius: object  # resolved: query.radius or the index default
    # The kernel config the pipelines actually receive: ``query.kernel``
    # stamped with the autotune generation when ``auto=True``. The stamp
    # makes the config (a jit-static argument) differ after a retune, so
    # the jitted search retraces with the new winners; ``caps.tuned_gen``
    # going stale is what routes execution back through ``compile_plan`` to
    # re-stamp.
    kernel: object = None

    # -- execution ------------------------------------------------------------

    def __call__(self, queries) -> nsa.SearchResult:
        caps = capabilities(self.index)
        if caps != self.caps:
            # Stale plan: this index mutated in place under us (a write
            # dirtied / a compaction-reset cleaned a tier, the payload was
            # released). Re-resolve through the index plan cache — a
            # conflict with the *new* capabilities raises the same
            # plan-time error a fresh plan() would. (An epoch *swap* never
            # lands here: it publishes a new index object — RCU — and this
            # plan keeps serving its still-valid old epoch.)
            _STATS[self.pipeline][STALENESS_REPLAN] += 1
            obs.counter(mnames.PLAN_REPLANS, pipeline=self.pipeline).inc()
            return self.index.plan(self.query)(queries)
        _STATS[self.pipeline]["executions"] += 1
        obs.counter(mnames.PLAN_EXECUTIONS, pipeline=self.pipeline).inc()
        validate_query_batch(
            queries, self.index.distance, expect_dim=self.index._dim()
        )
        with obs.span("plan", pipeline=self.pipeline):
            return self._execute(queries)

    def _execute(self, queries) -> nsa.SearchResult:
        idx = self.index
        q = self.query
        Q = jnp.asarray(queries, jnp.float32)
        squeeze = Q.ndim == 1
        Qb = Q[None, :] if squeeze else Q
        # The mask *leg* is bound at plan time (fingerprint), the mask
        # *array* is fetched per call — TombstoneSet caches the device
        # array, so no rebuild/re-upload happens unless a delete landed.
        slot_valid = (
            idx.tombstones.valid_mask() if self.caps.tombstones_dirty
            else None
        )
        r = self.radius

        if self.pipeline == "two_stage":
            from repro.store import two_stage as two_stage_lib

            res = two_stage_lib.search_two_stage(
                idx.data, idx.store, Qb, dist=idx.distance, k=q.k, r=r,
                beam=q.beam, max_children=idx.max_children,
                rerank_width=q.rerank_width, exact_rerank=q.exact_rerank,
                leaf_radius_filter=q.leaf_radius_filter, kernel=self.kernel,
                slot_valid=slot_valid,
            )
        elif self.pipeline == "dense":
            res = nsa.search_dense(
                idx.data, Qb, dist=idx.distance, k=q.k, r=r,
                leaf_radius_filter=q.leaf_radius_filter,
                with_stats=q.with_stats, kernel=self.kernel,
                slot_valid=slot_valid,
            )
        elif self.pipeline == "beam":
            res = nsa.search_beam(
                idx.data, Qb, dist=idx.distance, k=q.k, r=r, beam=q.beam,
                max_children=idx.max_children,
                leaf_radius_filter=q.leaf_radius_filter, kernel=self.kernel,
                slot_valid=slot_valid,
            )
        else:  # beam_vmap: the frozen seed baseline (clean tiers, by plan)
            res = nsa.search_beam_vmap(
                idx.data, Qb, dist=idx.distance, k=q.k, r=r, beam=q.beam,
                max_children=idx.max_children,
                leaf_radius_filter=q.leaf_radius_filter,
            )

        if self.caps.delta_dirty:
            with obs.span("delta_leg", n_active=int(idx.delta.n_active)):
                res = self._merge_delta_leg(Qb, res)
        if squeeze:
            res = jax.tree.map(lambda a: a[0], res)
        return res

    def _merge_delta_leg(self, Qb: Array, res: nsa.SearchResult):
        """The delta buffer's exact-scan leg, folded through the same local
        two-way merge a butterfly round performs between shard partners."""
        from repro.online import delta as delta_lib

        idx = self.index
        q = self.query
        scan = idx.delta.scan(Qb, idx.distance, k=q.k, kernel=self.kernel)
        sd, si = scan.dists, scan.ids
        if q.leaf_radius_filter:
            # same leaf radius rule the resident ranking applies, so a point
            # filters identically whether it is buffered or (post
            # compaction) resident
            r0 = self.radius[0] if isinstance(self.radius, tuple) \
                else self.radius
            keep = sd < r0
            sd = jnp.where(keep, sd, BIG)
            si = jnp.where(keep, si, -1)
        d_m, i_m = delta_lib.merge_topk(res.dists, res.ids, sd, si, q.k)
        return nsa.SearchResult(
            dists=d_m, ids=i_m,
            n_candidates=res.n_candidates + jnp.int32(idx.delta.n_active),
        )

    # -- debuggability --------------------------------------------------------

    def describe(self) -> dict:
        """Structured counterpart of :meth:`explain` — a plain dict so
        exporters/tests stop parsing the human string. Keys: ``pipeline``,
        ``effective_pipeline`` (the ∞-rerank / scan-only refinement),
        ``lowering``, ``query`` (the resolved execution-relevant fields),
        ``capabilities`` (the fingerprint this plan bound against),
        ``index`` (size + code-format features for the cost recorder),
        ``online_legs`` (tombstone mask / delta leg booleans + lowering
        text) and ``kernel`` (the stamped kernel config, or None)."""
        q = self.query
        effective = self.pipeline
        if self.pipeline == "two_stage" and (
            q.rerank_width is None or q.rerank_width <= 0
            or self.caps.store == "fp32"
        ):
            effective = "two_stage_inf"
        elif self.pipeline == "two_stage" and not q.exact_rerank:
            effective = "two_stage_scan"
        kernel = self.kernel
        return dict(
            pipeline=self.pipeline,
            effective_pipeline=effective,
            lowering=_LOWERING[effective],
            query=dict(
                k=q.k, radius=self.radius, beam=q.beam,
                rerank_width=q.rerank_width, exact_rerank=q.exact_rerank,
                leaf_radius_filter=q.leaf_radius_filter,
                execution=q.execution,
            ),
            capabilities=self.caps._asdict(),
            index=dict(
                n_points=getattr(self.index, "n_points", None),
                code_format=getattr(
                    getattr(self.index, "store", None), "code_format", None),
            ),
            online_legs=dict(
                tombstone_mask=self.caps.tombstones_dirty,
                tombstone_lowering=(
                    "TombstoneSet.valid_mask() (cached device bool[n_0]) "
                    "folded into the leaf ranking via ref.fold_slot_valid"
                    if self.caps.tombstones_dirty
                    else "none (no dead slots)"),
                delta=self.caps.delta_dirty,
                delta_lowering=(
                    "exact ops.pairwise_distance scan over the delta "
                    "buffer + merge_topk into the result"
                    if self.caps.delta_dirty
                    else "none (delta buffer empty)"),
            ),
            kernel=(kernel._asdict() if hasattr(kernel, "_asdict")
                    else kernel),
        )

    def explain(self) -> str:
        """Human-readable plan: pipeline, kernel lowering, online legs.
        Formats :meth:`describe` — the dict is the source of truth."""
        d = self.describe()
        q, caps, legs = d["query"], d["capabilities"], d["online_legs"]
        lines = [
            f"SearchPlan[{d['pipeline']}] epoch={caps['epoch']} "
            f"levels={caps['n_levels']} "
            f"store={caps['store'] or 'dense-resident'}"
            + (" (payload released)" if caps["payload_released"] else "")
            + (" (remote exact tier)" if caps.get("remote") else ""),
            f"  query: k={q['k']} radius={q['radius']} beam={q['beam']}"
            + (f" rerank_width={q['rerank_width']}"
               if d["pipeline"] == "two_stage" else "")
            + f" leaf_radius_filter={q['leaf_radius_filter']}",
            f"  lowering: {d['lowering']}",
            f"  tombstone mask: {legs['tombstone_lowering']}",
            f"  delta leg: {legs['delta_lowering']}",
        ]
        return "\n".join(lines)


def compile_plan(index, query: Query) -> SearchPlan:
    """Bind ``query`` to ``index``'s current capabilities. Raises ValueError
    on capability conflicts (see :func:`_resolve_pipeline`). Callers usually
    go through ``PDASCIndex.plan`` (the cached surface)."""
    caps = capabilities(index)
    pipeline = _resolve_pipeline(query, caps)
    radius = query.radius if query.radius is not None else index.default_radius
    plan = SearchPlan(
        index=index, query=query, caps=caps, pipeline=pipeline, radius=radius,
        kernel=_stamped_kernel(query.kernel, caps),
    )
    _STATS[pipeline]["compiles"] += 1
    obs.counter(mnames.PLAN_COMPILES, pipeline=pipeline).inc()
    return plan


# ---------------------------------------------------------------------------
# Sharded pipeline (plans over a mesh)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)
class ShardedPlan:
    """A :class:`Query` lowered onto a device mesh.

    The sharded layout carries no ``PDASCIndex`` object — the stacked
    per-shard index arrays are runtime inputs (they may be traced, e.g.
    inside a dry-run cell) — so the plan binds everything *static*: mesh,
    database axes, distance, radius, per-shard mode, merge collective and
    kernel knobs. Call with the stacked index:

        plan = compile_sharded_plan(mesh, query, dist="cosine", ...)
        res = plan(sharded_index, Q)                 # replicated [B, k]
        res = plan(sharded_index, Q, slot_valid=sv)  # + per-shard tombstones

    Execution is one ``distributed.search_sharded`` dispatch — per-shard
    search under ``shard_map`` plus the global top-k merge collective.
    """

    query: Query
    mesh: object
    db_axes: tuple
    dist: dist_lib.Distance
    radius: object
    shard_mode: str  # per-shard pipeline: "dense" | "beam"
    max_children: Optional[tuple]
    merge: str
    pipeline: str = "sharded"
    kernel: object = None  # generation-stamped query.kernel (see SearchPlan)

    def __call__(self, sharded_index, Q, *, slot_valid=None):
        _STATS[self.pipeline]["executions"] += 1
        obs.counter(mnames.PLAN_EXECUTIONS, pipeline=self.pipeline).inc()
        validate_query_batch(Q, self.dist)
        q = self.query
        from repro.core import distributed as dd

        return dd.search_sharded(
            sharded_index, Q, self.mesh, db_axes=self.db_axes,
            dist=self.dist, k=q.k, r=self.radius, mode=self.shard_mode,
            beam=q.beam, max_children=self.max_children, merge=self.merge,
            leaf_radius_filter=q.leaf_radius_filter,
            with_stats=q.with_stats, kernel=self.kernel,
            slot_valid=slot_valid,
        )

    def describe(self) -> dict:
        """Structured counterpart of :meth:`explain` (cf.
        :meth:`SearchPlan.describe`)."""
        q = self.query
        kernel = self.kernel
        return dict(
            pipeline=self.pipeline,
            effective_pipeline=f"sharded/{self.shard_mode}",
            lowering=_LOWERING[self.shard_mode],
            query=dict(
                k=q.k, radius=self.radius, beam=q.beam,
                leaf_radius_filter=q.leaf_radius_filter,
                execution=q.execution,
            ),
            mesh=dict(
                axes={a: int(self.mesh.shape[a]) for a in self.db_axes},
                merge=self.merge,
            ),
            online_legs=dict(
                tombstone_mask=None,  # per-shard slot_valid at call time
                tombstone_lowering=(
                    "per-shard slot_valid slices (passed at call time; "
                    "route_writes/local_slot_valid build them)"),
                delta=False,
                delta_lowering="none (sharded plans serve compacted tiers)",
            ),
            kernel=(kernel._asdict() if hasattr(kernel, "_asdict")
                    else kernel),
        )

    def explain(self) -> str:
        d = self.describe()
        q = d["query"]
        axes = "x".join(f"{a}={n}" for a, n in d["mesh"]["axes"].items())
        lines = [
            f"ShardedPlan[sharded/{self.shard_mode}] mesh axes ({axes}), "
            f"merge={self.merge}",
            f"  query: k={q['k']} radius={q['radius']} "
            f"beam={q['beam']} "
            f"leaf_radius_filter={q['leaf_radius_filter']}",
            f"  per-shard lowering: {d['lowering']}",
            f"  merge: distributed.topk_merge_{self.merge} over "
            f"{tuple(self.db_axes)} (global ids = shard offset + local rows)",
            f"  tombstone mask: {d['online_legs']['tombstone_lowering']}",
        ]
        return "\n".join(lines)


def compile_sharded_plan(
    mesh,
    query: Query,
    *,
    dist,
    db_axes: Sequence[str] = ("data",),
    max_children: Optional[tuple] = None,
    merge: str = "butterfly",
    default_radius: Optional[float] = None,
) -> ShardedPlan:
    """Compile a :class:`Query` into a plan over a sharded deployment.

    ``query.execution`` selects the per-shard pipeline: ``"dense"`` or
    ``"beam"`` (``"auto"``/``"sharded"`` default to dense — the faithful
    per-shard mode). ``"beam"`` requires ``max_children`` (the static
    per-level child bound of the stacked sub-indexes). ``query.radius=None``
    falls back to ``default_radius``; a plan must know its radius statically.
    """
    shard_mode = query.execution
    if shard_mode in ("auto", "sharded"):
        shard_mode = "dense"
    if shard_mode not in ("dense", "beam"):
        raise ValueError(
            f"sharded plans run per-shard 'dense' or 'beam', not "
            f"{query.execution!r} (two_stage shards through "
            f"distributed.scan_quantized_sharded)"
        )
    if shard_mode == "beam" and max_children is None:
        raise ValueError(
            "per-shard 'beam' needs max_children (the static per-level "
            "child bound of the stacked sub-indexes)"
        )
    radius = query.radius if query.radius is not None else default_radius
    if radius is None:
        raise ValueError(
            "sharded plans need a radius: set Query.radius or pass "
            "default_radius="
        )
    plan = ShardedPlan(
        query=query, mesh=mesh, db_axes=tuple(db_axes),
        dist=dist_lib.get(dist), radius=radius, shard_mode=shard_mode,
        max_children=tuple(max_children) if max_children is not None
        else None, merge=merge, kernel=_stamped_kernel(query.kernel),
    )
    _STATS[plan.pipeline]["compiles"] += 1
    obs.counter(mnames.PLAN_COMPILES, pipeline=plan.pipeline).inc()
    return plan
