"""Declarative query surface + compiled search plans (DESIGN.md §3.8).

One index, arbitrary distances, tunable recall/cost/memory trade-offs —
PDASC's parametrizability claim — needs exactly one query surface. A
:class:`Query` is the *what* (k, radius, beam schedule, rerank width,
execution preference); ``idx.plan(query)`` compiles it into the *how*: a
:class:`SearchPlan` bound to whichever pipeline the index's capabilities
admit (dense / beam / two_stage — or sharded over a mesh via
:func:`compile_sharded_plan`), with the tombstone-mask threading and the
delta-scan merge leg resolved once at plan time. Capability conflicts are
plan-time errors; ``plan.explain()`` names the chosen pipeline, kernel ops
and online legs; repeated execution of a plan never retraces.
"""

from repro.query.plan import (
    Capabilities,
    STALENESS_REPLAN,
    SearchPlan,
    ShardedPlan,
    capabilities,
    compile_plan,
    compile_sharded_plan,
    plan_stats,
    reset_plan_stats,
)
from repro.query.spec import EXECUTIONS, Query, degraded, validate_query_batch

__all__ = [
    "Capabilities",
    "EXECUTIONS",
    "Query",
    "degraded",
    "SearchPlan",
    "ShardedPlan",
    "STALENESS_REPLAN",
    "capabilities",
    "compile_plan",
    "compile_sharded_plan",
    "plan_stats",
    "reset_plan_stats",
    "validate_query_batch",
]
