"""Literal (numpy, recursive) port of the paper's Algorithm 2 — NSA.

This is the *faithfulness oracle*: a direct transcription of the paper's
pseudocode — ragged candidate lists, Python recursion, per-level radius
filtering, unfiltered leaf expansion — operating on the same built index as
the JAX searchers. ``tests/test_msa_nsa.py`` asserts that
``repro.core.nsa.search_dense`` returns identical neighbour sets.

Intentionally slow and simple; never used in the hot path.
"""

from __future__ import annotations

import numpy as np

from repro.core import distances as dist_lib
from repro.core.msa import PDASCIndexData


def _dist_np(dist: dist_lib.Distance, q: np.ndarray, pts: np.ndarray) -> np.ndarray:
    import jax.numpy as jnp

    return np.asarray(dist.point(jnp.asarray(q)[None, :], jnp.asarray(pts)))


def nsa_reference(
    index: PDASCIndexData,
    q,
    *,
    dist,
    k: int = 10,
    r: float,
    leaf_radius_filter: bool = False,
):
    """Paper Algorithm 2 (NSA + ExploreCandidates), literally.

    Returns (dists[k], ids[k]) ascending, padded with (inf, -1).
    """
    dist = dist_lib.get(dist)
    q = np.asarray(q, np.float32)
    levels = [
        dict(
            points=np.asarray(lv.points),
            valid=np.asarray(lv.valid),
            child_start=np.asarray(lv.child_start),
            child_count=np.asarray(lv.child_count),
        )
        for lv in index.levels
    ]
    leaf_ids = np.asarray(index.leaf_ids)
    L = len(levels) - 1

    # --- top level: prototypes within the search radius ---------------------
    top = levels[L]
    d_top = _dist_np(dist, q, top["points"])
    id_candidates = [
        int(i) for i in np.nonzero(top["valid"] & (d_top < r))[0]
    ]

    # --- ExploreCandidates: recursive descent --------------------------------
    def explore(id_candidates, level):
        """Returns leaf slot indices mapped by the selected prototypes."""
        out = []
        for pid in id_candidates:
            start = int(levels[level]["child_start"][pid])
            count = int(levels[level]["child_count"][pid])
            children = list(range(start, start + count))
            if level - 1 == 0:
                # "At the lowest level, return only the specific points mapped
                # by idCandidates" — no radius re-check on leaf data points.
                if leaf_radius_filter:
                    pts = levels[0]["points"][children]
                    dd = _dist_np(dist, q, pts)
                    children = [c for c, d_ in zip(children, dd) if d_ < r]
                out.extend(children)
            else:
                pts = levels[level - 1]["points"][children]
                dd = _dist_np(dist, q, pts)
                filtered = [c for c, d_ in zip(children, dd) if d_ < r]
                if filtered:
                    out.extend(explore(filtered, level - 1))
        return out

    if L == 0:
        candidates = [int(i) for i in np.nonzero(top["valid"])[0]]
    else:
        candidates = explore(id_candidates, L)

    # --- rank candidates, return k nearest -----------------------------------
    candidates = sorted(set(candidates))
    if not candidates:
        return np.full((k,), np.inf, np.float32), np.full((k,), -1, np.int64)
    pts = levels[0]["points"][candidates]
    dd = _dist_np(dist, q, pts)
    order = np.argsort(dd, kind="stable")[:k]
    dists = dd[order]
    ids = leaf_ids[np.asarray(candidates)[order]]
    if len(order) < k:
        pad = k - len(order)
        dists = np.concatenate([dists, np.full((pad,), np.inf, np.float32)])
        ids = np.concatenate([ids, np.full((pad,), -1, ids.dtype)])
    return dists, ids


def check_index_invariants(index: PDASCIndexData) -> list[str]:
    """Structural invariants of an MSA index; returns a list of violations."""
    errs = []
    levels = index.levels
    for l, lv in enumerate(levels):
        valid = np.asarray(lv.valid)
        parent = np.asarray(lv.parent)
        if l < len(levels) - 1:
            n_up = levels[l + 1].points.shape[0]
            up_valid = np.asarray(levels[l + 1].valid)
            bad = valid & ((parent < 0) | (parent >= n_up))
            if bad.any():
                errs.append(f"level {l}: {bad.sum()} valid items without parent")
            elif not up_valid[parent[valid]].all():
                errs.append(f"level {l}: some parents are invalid slots")
        if l > 0:
            cs = np.asarray(lv.child_start)
            cc = np.asarray(lv.child_count)
            n_dn = levels[l - 1].points.shape[0]
            dn_valid = np.asarray(levels[l - 1].valid)
            dn_parent = np.asarray(levels[l - 1].parent)
            seen = np.zeros(n_dn, np.int64)
            for p in np.nonzero(valid)[0]:
                sl = slice(int(cs[p]), int(cs[p]) + int(cc[p]))
                if cs[p] < 0 or cs[p] + cc[p] > n_dn:
                    errs.append(f"level {l}: slot {p} child range out of bounds")
                    continue
                seen[sl] += 1
                if not dn_valid[sl].all():
                    errs.append(f"level {l}: slot {p} has invalid children")
                if not (dn_parent[sl] == p).all():
                    errs.append(f"level {l}: slot {p} children disagree on parent")
            missing = dn_valid & (seen == 0)
            dup = seen > 1
            if missing.any():
                errs.append(f"level {l-1}: {missing.sum()} valid items unclaimed")
            if dup.any():
                errs.append(f"level {l-1}: {dup.sum()} items claimed twice")
    # Leaf ids form a permutation of the dataset rows.
    ids = np.asarray(index.leaf_ids)[np.asarray(levels[0].valid)]
    if len(np.unique(ids)) != len(ids):
        errs.append("leaf ids are not unique")
    return errs
