"""Search-radius estimation for NSA.

The paper selects ``r`` per (dataset, distance) "based on measures that
provide insight into the distribution of the dataset, such as the Cumulative
Distribution Function or the maximum distance between elements" (§3.1), and
lists *dynamic per-level adjustment* as future work (§5). Both are
implemented here:

* :func:`estimate_radius` — the CDF approach: sample pairwise distances, take
  a quantile. Higher quantile => less restrictive => higher recall, more
  candidates.
* :func:`per_level_radii`  — the future-work item: prototypes at higher
  levels summarise wider regions, so the radius that keeps the *expected
  candidate frontier* constant grows with level. We scale the base radius by
  the quantile of *prototype* distances at each level, estimated from the
  built index itself.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import distances as dist_lib
from repro.core.msa import PDASCIndexData

Array = jax.Array


def sample_pairwise(
    data: Array,
    dist,
    *,
    n_pairs: int = 4096,
    key: Optional[Array] = None,
) -> Array:
    """Distances of ``n_pairs`` random (i, j) pairs — a CDF sample."""
    dist = dist_lib.get(dist)
    key = key if key is not None else jax.random.PRNGKey(0)
    n = data.shape[0]
    ka, kb = jax.random.split(key)
    i = jax.random.randint(ka, (n_pairs,), 0, n)
    j = jax.random.randint(kb, (n_pairs,), 0, n)
    return dist.point(jnp.take(data, i, axis=0), jnp.take(data, j, axis=0))


def estimate_radius(
    data: Array,
    dist,
    *,
    quantile: float = 0.05,
    n_pairs: int = 4096,
    key: Optional[Array] = None,
) -> float:
    """CDF-quantile radius (paper §3.1). ``quantile=0.05`` keeps roughly the
    closest 5% of pairwise distances inside the search frontier."""
    d = sample_pairwise(data, dist, n_pairs=n_pairs, key=key)
    return float(jnp.quantile(d, quantile))


def per_level_radii(
    index: PDASCIndexData,
    dist,
    *,
    base_radius: float,
    quantile: float = 0.5,
    key: Optional[Array] = None,
) -> tuple[float, ...]:
    """Dynamic per-level radii (paper future work).

    Level l's radius is ``base_radius + q_l`` where ``q_l`` is the
    ``quantile`` of each level-l prototype's distance to its parent prototype
    — i.e. how far a true neighbour can drift from the representative that
    summarises it. The leaf entry equals ``base_radius``.
    """
    dist = dist_lib.get(dist)
    radii = [float(base_radius)]
    for l in range(1, len(index.levels)):
        lv = index.levels[l - 1]
        up = index.levels[l]
        parent = jnp.clip(lv.parent, 0, up.points.shape[0] - 1)
        d = dist.point(lv.points, jnp.take(up.points, parent, axis=0))
        d = jnp.where(lv.valid & (lv.parent >= 0), d, jnp.nan)
        q = jnp.nanquantile(d, quantile)
        radii.append(float(base_radius + q))
    return tuple(radii)
