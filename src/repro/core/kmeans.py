"""Masked, vmappable k-means (Lloyd) — the Euclidean-only baseline clusterer.

The paper's §3.3 argues k-means is intrinsically tied to squared-Euclidean
minimisation and therefore unsuitable for arbitrary-distance indexing; we ship
it (a) as the clusterer for the IVF-Flat comparison baseline and (b) so the
recall benchmarks can demonstrate that claim empirically (k-means-built PDASC
index vs k-medoids-built under non-Euclidean distances).

Centroids are means, not data points — after clustering, callers that need
*prototypes that are data points* (MSA does) snap each centroid to the nearest
valid in-group point.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.distances import BIG

Array = jax.Array


class KMeansResult(NamedTuple):
    centroids: Array  # f32[k, d]
    labels: Array  # int32[g]  (-1 for invalid points)
    inertia: Array  # f32[]
    snapped: Array  # int32[k] index of nearest valid point per centroid (-1 unused)


def _plus_plus_init(X: Array, k: int, valid: Array, key: Array) -> Array:
    """k-means++ seeding restricted to valid points."""
    g = X.shape[0]

    def body(i, carry):
        centroids, d2, key = carry
        key, sub = jax.random.split(key)
        probs = jnp.where(valid, d2, 0.0)
        total = jnp.sum(probs)
        # Degenerate (all zero) -> uniform over valid.
        probs = jnp.where(total > 0, probs / jnp.maximum(total, 1e-30),
                          valid / jnp.maximum(jnp.sum(valid), 1))
        idx = jax.random.choice(sub, g, p=probs)
        c = X[idx]
        centroids = centroids.at[i].set(c)
        nd2 = jnp.sum((X - c[None, :]) ** 2, axis=-1)
        return centroids, jnp.minimum(d2, nd2), key

    key, sub = jax.random.split(key)
    first = jax.random.choice(sub, g, p=valid / jnp.maximum(jnp.sum(valid), 1))
    c0 = jnp.zeros((k, X.shape[1]), X.dtype).at[0].set(X[first])
    d2_0 = jnp.sum((X - X[first][None, :]) ** 2, axis=-1)
    centroids, _, _ = jax.lax.fori_loop(1, k, body, (c0, d2_0, key))
    return centroids


@functools.partial(jax.jit, static_argnames=("k", "iters"))
def kmeans(
    X: Array,
    k: int,
    valid: Array | None = None,
    *,
    key: Array | None = None,
    iters: int = 25,
) -> KMeansResult:
    """Lloyd's algorithm on one (padded) group."""
    g, d = X.shape
    if valid is None:
        valid = jnp.ones((g,), bool)
    if key is None:
        key = jax.random.PRNGKey(0)
    X = X.astype(jnp.float32)
    vf = valid.astype(jnp.float32)

    centroids = _plus_plus_init(X, k, valid, key)

    def body(_, centroids):
        d2 = (
            jnp.sum(X * X, axis=1)[:, None]
            + jnp.sum(centroids * centroids, axis=1)[None, :]
            - 2.0 * X @ centroids.T
        )
        labels = jnp.argmin(d2, axis=1)
        onehot = jax.nn.one_hot(labels, k, dtype=jnp.float32) * vf[:, None]
        counts = jnp.sum(onehot, axis=0)  # [k]
        sums = onehot.T @ X  # [k, d]
        new = sums / jnp.maximum(counts[:, None], 1.0)
        # Empty clusters keep their previous centroid.
        return jnp.where(counts[:, None] > 0, new, centroids)

    centroids = jax.lax.fori_loop(0, iters, body, centroids)

    d2 = (
        jnp.sum(X * X, axis=1)[:, None]
        + jnp.sum(centroids * centroids, axis=1)[None, :]
        - 2.0 * X @ centroids.T
    )
    labels = jnp.argmin(d2, axis=1).astype(jnp.int32)
    inertia = jnp.sum(jnp.where(valid, jnp.min(d2, axis=1), 0.0))
    labels = jnp.where(valid, labels, -1)

    # Snap each centroid to its nearest valid data point (prototype-as-point).
    d2p = jnp.where(valid[:, None], d2, BIG)  # [g, k]
    snapped = jnp.argmin(d2p, axis=0).astype(jnp.int32)
    return KMeansResult(centroids=centroids, labels=labels, inertia=inertia,
                        snapped=snapped)


def kmeans_grouped(Xg: Array, k: int, valid: Array, *, key: Array, iters: int = 25):
    """vmap of :func:`kmeans` over a leading groups axis."""
    keys = jax.random.split(key, Xg.shape[0])
    fn = functools.partial(kmeans, k=k, iters=iters)
    return jax.vmap(lambda x, v, kk: fn(x, v, key=kk))(Xg, valid, keys)
