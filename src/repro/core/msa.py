"""MSA — Multilevel Structure Algorithm (paper Algorithm 1), in JAX.

Bottom-up index construction:

  1. Randomly permute the dataset and split it into groups of ``gl`` points
     (one group == one worker shard in the paper's distributed deployment).
  2. Cluster every group into ``nPrototypes = gl // 2`` medoids (2:1 ratio,
     paper §3.1) with an arbitrary-distance clusterer (k-medoids by default).
  3. The medoids become the next level's points; regroup and repeat until a
     single group remains. Its medoids form the top level.

Groups holding ``<= nPrototypes`` valid points promote *all* their points
(the paper's outlier-preservation rule) — this falls out of the masked
k-medoids (`build` fills only ``n_valid`` slots).

TPU adaptation (DESIGN.md §3): every level is a *static-shape* array with a
validity mask; groups are padded, never ragged. After clustering, each level
is reordered **sibling-contiguous** (points sorted by their cluster slot within
each group) so that the children of any prototype occupy one contiguous slice
``[child_start, child_start + child_count)`` of the level below — this is what
lets the beam searcher gather candidate blocks with static shapes instead of
chasing ragged lists.

The per-level work is one jitted function; the host only loops over the
(statically known) level count. Under pjit with the groups axis sharded, each
device clusters its own groups — MSA's distributed build.

Memory model (DESIGN.md §3.5): a level's groups are *streamed* in
``group_chunk``-sized slabs (``lax.map``), so the clustering working set —
the per-group ``[g, g]`` dissimilarity matrices plus the k-medoids
intermediates — peaks at ``O(group_chunk · g²)`` regardless of the level's
group count G. ``group_chunk=0`` disables streaming (the seed whole-level
layout, kept as the benchmark baseline).
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distances as dist_lib
from repro.core import kmedoids as km
from repro.core import kmeans as kmeans_lib
from repro.kernels import ops as kops

Array = jax.Array


class PDASCLevel(NamedTuple):
    """One level of the multilevel index (leaf = level 0).

    All arrays are in the level's *final* (sibling-contiguous) layout.
    """

    points: Array  # f32[n_l, d]
    valid: Array  # bool[n_l]
    parent: Array  # int32[n_l] — slot in level l+1 (-1 at the top level)
    child_start: Array  # int32[n_l] — slice start into level l-1 (-1 at leaf)
    child_count: Array  # int32[n_l]
    # Cached ||p||^2 per point (4 bytes/point, a 1/d overhead). The batched
    # beam search gathers these alongside the points so the Gram-form rank
    # kernels never re-reduce the [B, W, d] candidate cube for norms; the
    # arithmetic (sum of p*p over d) matches the pairwise kernels' norm
    # computation bit-for-bit.
    sq_norm: Array  # f32[n_l]


class PDASCIndexData(NamedTuple):
    """The full index: levels[0] is the leaf (data) level, levels[-1] the top."""

    levels: tuple[PDASCLevel, ...]
    leaf_ids: Array  # int32[n_0] — original dataset row of each leaf slot


class BuildStats(NamedTuple):
    level_sizes: tuple[int, ...]  # valid item count per level
    level_td: tuple[float, ...]  # summed clustering TD per level
    n_levels: int


def _pad_to(x: Array, n: int, fill=0):
    pad = n - x.shape[0]
    if pad == 0:
        return x
    widths = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, widths, constant_values=fill)


def _group_pairwise_dense(dist: dist_lib.Distance, grp_pts: Array,
                          grp_valid: Array, row_chunk: int) -> Array:
    """Masked per-group distance matrices [B, g, g] for one batch of groups.

    Dispatched through the kernel layer (vmapped over the group axis; on TPU
    the Pallas pairwise kernel lifts the vmap into its grid), so the MSA
    build shares the exact distance arithmetic of the search path. "Dense"
    because the whole batch's matrices are live at once — callers bound B
    (the ``group_chunk`` streaming in :func:`_build_level`); passing a full
    level is the seed behaviour, kept as the benchmark baseline.
    """

    def one(pts, vld):
        D = kops.pairwise_distance(pts, pts, dist, row_chunk=row_chunk)
        return dist_lib.mask_invalid(D, vld, vld)

    return jax.vmap(one)(grp_pts, grp_valid)


def _cluster_groups(dist: dist_lib.Distance, gpts: Array, gvld: Array,
                    keys: Array, *, k: int, method: str, max_swaps: int,
                    swap_tol: float, row_chunk: int, bg: int,
                    force_pallas: bool):
    """Cluster one batch of groups -> (medoids [B,k], labels [B,g], td [B])."""
    B, gl = gpts.shape[0], gpts.shape[1]
    if method == "kmeans":
        res = jax.vmap(lambda x, v, kk: kmeans_lib.kmeans(x, k, v, key=kk))(
            gpts, gvld, keys
        )
        medoids = jnp.where(
            jnp.arange(k)[None, :]
            < jnp.sum(gvld, axis=1, dtype=jnp.int32)[:, None].clip(max=k),
            res.snapped,
            -1,
        )

        # Re-derive labels against the snapped medoids so labels index medoid
        # slots (k-means labels index centroids, which we replaced). [g, k]
        # distances against the k snapped points via the kernel layer — not a
        # full [g, g] matrix with medoid columns gathered out.
        def relabel(pts_g, vld_g, med_g):
            mpts = jnp.take(pts_g, jnp.clip(med_g, 0, gl - 1), axis=0)
            cols = kops.pairwise_distance(pts_g, mpts, dist,
                                          row_chunk=row_chunk)
            cols = jnp.where(
                vld_g[:, None] & (med_g[None, :] >= 0), cols, dist_lib.BIG
            )
            lbl = jnp.argmin(cols, axis=1).astype(jnp.int32)
            return jnp.where(vld_g, lbl, -1)

        labels = jax.vmap(relabel)(gpts, gvld, medoids)
        return medoids, labels, jnp.zeros((B,), jnp.float32)

    Dg = _group_pairwise_dense(dist, gpts, gvld, row_chunk)
    res = km.kmedoids_grouped(
        Dg, k, gvld, method=method, max_swaps=max_swaps, rel_tol=swap_tol,
        bg=bg, force_pallas=force_pallas,
    )
    return res.medoids, res.labels, res.td


@functools.partial(
    jax.jit,
    static_argnames=(
        "dist", "gl", "k", "method", "max_swaps", "row_chunk", "group_chunk",
        "bg", "force_pallas",
    ),
)
def _build_level(
    points: Array,  # [n, d] current level items, initial layout
    valid: Array,  # [n]
    carry_a: Array,  # [n] int32 — leaf: original ids; upper: child_start
    carry_b: Array,  # [n] int32 — leaf: unused(-1);   upper: child_count
    key: Array,
    *,
    dist: dist_lib.Distance,
    gl: int,
    k: int,
    method: str,
    max_swaps: int,
    swap_tol: float,
    row_chunk: int,
    group_chunk: int,
    bg: int,
    force_pallas: bool,
):
    """Cluster one level. Returns the level's final-layout arrays, the
    remap (initial->final) for fixing the lower level's parents, and the next
    level's items in initial layout.

    Execution is *chunked over groups*: the level's G groups are processed in
    ``group_chunk``-sized slabs under ``lax.map``, each slab computing its
    own [group_chunk, g, g] dissimilarity batch and clustering it, so peak
    live memory is O(group_chunk · g²) however large G grows (the paper's
    per-node memory budget, applied to the build). ``group_chunk=0`` (or
    >= G) processes the whole level at once — the seed layout, kept as the
    dense benchmark baseline. Only the per-group [k]/[g]-sized results
    (medoids, labels, TD) persist across slabs; the sibling-contiguous
    reorder below is whole-level but touches nothing larger than [G, gl].
    """
    n, d = points.shape
    G = -(-n // gl)
    n_pad = G * gl

    pts = _pad_to(points, n_pad)
    vld = _pad_to(valid, n_pad, fill=False)
    ca = _pad_to(carry_a, n_pad, fill=-1)
    cb = _pad_to(carry_b, n_pad, fill=0)

    gpts = pts.reshape(G, gl, d)
    gvld = vld.reshape(G, gl)

    cluster = functools.partial(
        _cluster_groups, dist, k=k, method=method, max_swaps=max_swaps,
        swap_tol=swap_tol, row_chunk=row_chunk, bg=bg,
        force_pallas=force_pallas,
    )
    if 0 < group_chunk < G:
        nc = -(-G // group_chunk)
        Gp = nc * group_chunk

        def pad_groups(a, fill):
            widths = [(0, Gp - G)] + [(0, 0)] * (a.ndim - 1)
            return jnp.pad(a, widths, constant_values=fill)

        # Split to exactly G keys and pad (split is not prefix-stable across
        # counts, and the dense path uses split(key, G) — chunking must only
        # change the execution schedule, never the per-group keys).
        keys = pad_groups(jax.random.split(key, G), 0)
        chunks = (
            pad_groups(gpts, 0.0).reshape(nc, group_chunk, gl, d),
            pad_groups(gvld, False).reshape(nc, group_chunk, gl),
            keys.reshape(nc, group_chunk, -1),
        )
        medoids, labels, td = jax.lax.map(
            lambda c: cluster(c[0], c[1], c[2]), chunks
        )
        medoids = medoids.reshape(Gp, k)[:G]
        labels = labels.reshape(Gp, gl)[:G]
        td = td.reshape(Gp)[:G]
    else:
        medoids, labels, td = cluster(gpts, gvld, jax.random.split(key, G))

    # --- sibling-contiguous reorder within each group -----------------------
    sort_key = jnp.where(labels >= 0, labels, k)  # invalid slots last
    order = jnp.argsort(sort_key, axis=1, stable=True)  # [G, gl]
    take = lambda a: jnp.take_along_axis(a, order, axis=1)

    labels_f = take(labels)
    gpts_f = jnp.take_along_axis(gpts, order[:, :, None], axis=1)
    gvld_f = take(gvld)
    ca_f = take(ca.reshape(G, gl))
    cb_f = take(cb.reshape(G, gl))

    # initial->final remap: item at (g, j) moved to (g, pos) where
    # order[g, pos] = j.
    inv = jnp.argsort(order, axis=1)  # [G, gl]; inv[g, j] = new pos of j
    base = (jnp.arange(G) * gl)[:, None]
    remap = (base + inv).reshape(-1)  # [n_pad] initial slot -> final slot

    # parent slot (into next level's initial layout) of each final-layout item
    parent = jnp.where(
        labels_f >= 0, base * 0 + (jnp.arange(G) * k)[:, None] + labels_f, -1
    ).astype(jnp.int32)

    # --- children bookkeeping for the next level's items --------------------
    # labels_f is label-sorted within each group (invalid last), so per-slot
    # child counts/starts are searchsorted bounds — no [G, gl, k+1] one-hot.
    sk_f = jnp.where(labels_f >= 0, labels_f, k)  # [G, gl] ascending per row
    bounds = jax.vmap(
        lambda row: jnp.searchsorted(row, jnp.arange(k + 1))
    )(sk_f)  # [G, k+1]; bounds[:, s] = #children in slots < s
    counts = (bounds[:, 1:] - bounds[:, :-1]).astype(jnp.int32)  # [G, k]
    starts = (bounds[:, :k] + (jnp.arange(G) * gl)[:, None]).astype(jnp.int32)

    # --- next level items: the medoid points (initial layout) ---------------
    med_safe = jnp.clip(medoids, 0, gl - 1)
    # medoids index the *initial* within-group layout; map through inv.
    med_final = jnp.take_along_axis(inv, med_safe, axis=1)
    next_pts = jnp.take_along_axis(gpts_f, med_final[:, :, None], axis=1)
    next_valid = medoids >= 0

    level_arrays = dict(
        points=gpts_f.reshape(n_pad, d),
        valid=gvld_f.reshape(n_pad),
        parent=parent.reshape(n_pad),
        carry_a=ca_f.reshape(n_pad),
        carry_b=cb_f.reshape(n_pad),
    )
    next_arrays = dict(
        points=next_pts.reshape(G * k, d),
        valid=next_valid.reshape(G * k),
        child_start=starts.reshape(G * k),
        child_count=counts.reshape(G * k).astype(jnp.int32),
    )
    return level_arrays, next_arrays, remap, jnp.sum(td)


def _check_level_convergence(n: int, gl: int, k: int) -> None:
    """Reject (gl, k) pairs whose level recursion never reaches one group.

    Each level maps G groups to ``ceil(G*k/gl)`` groups; that map has a
    fixed point >= 2 whenever ``2*k > gl`` (at G=2 it yields ``2k > gl``
    points, i.e. 2 groups again), so the build loop would never terminate.
    The paper's 2:1 ratio (``k = gl // 2``) always converges.
    """
    if n > gl and 2 * k > gl:
        raise ValueError(
            f"n_prototypes={k} with gl={gl} never reduces n={n} points to a "
            f"single group: each level maps G groups to ceil(G*{k}/{gl}) "
            f"groups, which is stuck at >= 2 groups whenever 2*n_prototypes "
            f"> gl. Use n_prototypes <= gl // 2 (the paper's 2:1 ratio)."
        )


def n_levels_for(n: int, gl: int, k: Optional[int] = None) -> int:
    """Number of clustered levels MSA will produce for ``n`` points."""
    k = k or gl // 2
    _check_level_convergence(n, gl, k)
    levels = 0
    while True:
        G = -(-n // gl)
        levels += 1
        n = G * k
        if G == 1:
            return levels


def _cluster_levels(
    points: Array,
    valid: Array,
    carry_a: Array,
    carry_b: Array,
    key: Array,
    *,
    dist: dist_lib.Distance,
    gl: int,
    k: int,
    method: str,
    max_swaps: int,
    swap_tol: float,
    row_chunk: int,
    group_chunk: int,
    bg: int,
    force_pallas: bool,
    prev_levels: Optional[list] = None,
):
    """Bottom-up level loop shared by the from-scratch build and the online
    compaction (``repro.online.compact``, DESIGN.md §3.7).

    Clusters the given items into groups of ``gl`` repeatedly until one
    group remains. ``prev_levels`` (final-layout level dicts, leaf first)
    seeds the loop with already-built lower levels: the first clustered
    level is then an *upper* level — its items are medoids carrying
    child_start / child_count in carry_a / carry_b — and its reorder remap
    fixes ``prev_levels[-1]``'s parent pointers, exactly as every later
    level fixes its predecessor. Compaction uses this to re-cluster only
    affected leaf groups and let the standard loop regrow the (much
    smaller) hierarchy above them.

    Returns ``(raw_levels, level_td, top)`` — the final-layout level dicts
    (including ``prev_levels``), one TD scalar per level clustered here, and
    the never-clustered top level dict.
    """
    raw_levels: list[dict] = list(prev_levels) if prev_levels else []
    first_is_leaf = not raw_levels
    level_td: list[Array] = []
    next_cs = next_cc = None  # child_start/count travelling with items
    if not first_is_leaf:
        next_cs, next_cc = carry_a, carry_b

    while True:
        G = -(-points.shape[0] // gl)
        key, sub = jax.random.split(key)
        level_arrays, next_arrays, remap, td = _build_level(
            points,
            valid,
            carry_a,
            carry_b,
            sub,
            dist=dist,
            gl=gl,
            k=k,
            method=method,
            max_swaps=max_swaps,
            swap_tol=swap_tol,
            row_chunk=row_chunk,
            group_chunk=group_chunk,
            bg=bg,
            force_pallas=force_pallas,
        )
        # Fix the lower level's parent pointers through this level's reorder.
        if raw_levels:
            prev = raw_levels[-1]
            p = prev["parent"]
            prev["parent"] = jnp.where(
                p >= 0, remap[jnp.clip(p, 0, remap.shape[0] - 1)], -1
            )
        if next_cs is None:  # leaf level: ids in carry_a, no children
            level_arrays["child_start"] = jnp.full_like(level_arrays["carry_a"], -1)
            level_arrays["child_count"] = jnp.zeros_like(level_arrays["carry_a"])
            level_arrays["leaf_ids"] = level_arrays["carry_a"]
        else:
            level_arrays["child_start"] = level_arrays["carry_a"]
            level_arrays["child_count"] = level_arrays["carry_b"]
        raw_levels.append(level_arrays)
        level_td.append(td)

        points = next_arrays["points"]
        valid = next_arrays["valid"]
        carry_a = next_arrays["child_start"]
        carry_b = next_arrays["child_count"]
        next_cs, next_cc = carry_a, carry_b
        if G == 1:
            break

    # Top level: the medoids of the final single group; never clustered.
    top = dict(
        points=points,
        valid=valid,
        parent=jnp.full((points.shape[0],), -1, jnp.int32),
        child_start=next_cs,
        child_count=next_cc,
    )
    return raw_levels, level_td, top


def finalize_index(raw_levels: list, top: dict) -> PDASCIndexData:
    """Assemble final-layout level dicts (+ the top dict) into the
    ``PDASCIndexData`` pytree, computing the per-point norm cache."""
    levels = []
    for lv in list(raw_levels) + [top]:
        pts = lv["points"]
        levels.append(
            PDASCLevel(
                points=pts,
                valid=lv["valid"],
                parent=jnp.asarray(lv["parent"]).astype(jnp.int32),
                child_start=jnp.asarray(lv["child_start"]).astype(jnp.int32),
                child_count=jnp.asarray(lv["child_count"]).astype(jnp.int32),
                sq_norm=jnp.sum(pts * pts, axis=-1),
            )
        )
    return PDASCIndexData(
        levels=tuple(levels),
        leaf_ids=jnp.asarray(raw_levels[0]["leaf_ids"]).astype(jnp.int32),
    )


def build_index_arrays(
    data,
    *,
    gl: int,
    n_prototypes: Optional[int] = None,
    distance="euclidean",
    method: str = "pam",
    max_swaps: int = 64,
    key: Optional[Array] = None,
    row_chunk: int = 512,
    group_chunk: int = 8,
    swap_tol: float = 1e-3,
    bg: int = 128,
    force_pallas: bool = False,
    shuffle: bool = True,
) -> tuple[PDASCIndexData, tuple[Array, ...]]:
    """Traceable MSA build: returns the index pytree + per-level TD scalars.

    Contains no host-side array reads, so it can run inside ``jit`` /
    ``shard_map`` (the distributed per-shard build). The level loop trips a
    statically known number of times (a function of ``n``/``gl`` only).
    ``group_chunk`` bounds per-level live memory at O(group_chunk · gl²)
    (0 = dense whole-level clustering, the seed baseline). ``swap_tol`` is
    the eager-swap per-sweep relative-improvement cutoff (0 = run every
    group to full single-swap local optimality; the default trades the last
    ~0.1% of clustering TD for skipping the slowest convergence tail —
    recall-neutral, see DESIGN.md §3.5).
    """
    dist = dist_lib.get(distance)
    k = n_prototypes or gl // 2
    if k < 1 or k > gl:
        raise ValueError(f"need 1 <= n_prototypes <= gl, got {k} vs gl={gl}")
    _check_level_convergence(data.shape[0], gl, k)
    if dist.needs_dim is not None and data.shape[1] != dist.needs_dim:
        raise ValueError(
            f"distance {dist.name!r} needs d={dist.needs_dim}, got {data.shape[1]}"
        )
    key = key if key is not None else jax.random.PRNGKey(0)
    n, d = data.shape

    data = jnp.asarray(data, jnp.float32)
    if shuffle:
        key, sub = jax.random.split(key)
        perm = jax.random.permutation(sub, n)
    else:
        perm = jnp.arange(n)
    points = jnp.take(data, perm, axis=0)
    valid = jnp.ones((n,), bool)
    carry_a = perm.astype(jnp.int32)  # leaf: original row ids
    carry_b = jnp.full((n,), -1, jnp.int32)

    raw_levels, level_td, top = _cluster_levels(
        points, valid, carry_a, carry_b, key,
        dist=dist, gl=gl, k=k, method=method, max_swaps=max_swaps,
        swap_tol=swap_tol, row_chunk=row_chunk, group_chunk=group_chunk,
        bg=bg, force_pallas=force_pallas,
    )
    index = finalize_index(raw_levels, top)
    return index, tuple(level_td) + (jnp.float32(0.0),)


def build_index(
    data,
    *,
    gl: int,
    n_prototypes: Optional[int] = None,
    distance="euclidean",
    method: str = "pam",
    max_swaps: int = 64,
    key: Optional[Array] = None,
    row_chunk: int = 512,
    group_chunk: int = 8,
    swap_tol: float = 1e-3,
    bg: int = 128,
    force_pallas: bool = False,
    shuffle: bool = True,
) -> tuple[PDASCIndexData, BuildStats]:
    """Build the PDASC multilevel index (MSA, Algorithm 1).

    Args:
      data: [n, d] dataset.
      gl: group length (points per partition at each level).
      n_prototypes: medoids per group; defaults to ``gl // 2`` (paper's 2:1).
      distance: registered distance name or a ``Distance``.
      method: "pam" | "pam_reference" | "alternate" | "build" | "kmeans".
      row_chunk: row chunking for non-Gram pairwise matrices.
      group_chunk: groups clustered per streamed slab (0 = whole level).
      swap_tol: eager-swap per-sweep relative improvement cutoff (0 = full
        convergence; see :func:`build_index_arrays`).
      bg: row tile of the fused Pallas swap-sweep kernel.
      force_pallas: run the sweep kernel interpret-mode off-TPU (tests).
    """
    index, level_td = build_index_arrays(
        data,
        gl=gl,
        n_prototypes=n_prototypes,
        distance=distance,
        method=method,
        max_swaps=max_swaps,
        key=key,
        row_chunk=row_chunk,
        group_chunk=group_chunk,
        swap_tol=swap_tol,
        bg=bg,
        force_pallas=force_pallas,
        shuffle=shuffle,
    )
    # One host round-trip for all build stats (per-level float()/int() reads
    # would each force a device sync).
    sizes = [jnp.sum(lv.valid, dtype=jnp.int32) for lv in index.levels]
    sizes, tds = jax.device_get((sizes, level_td))
    stats = BuildStats(
        level_sizes=tuple(int(s) for s in sizes),
        level_td=tuple(float(t) for t in tds),
        n_levels=len(index.levels),
    )
    return index, stats


def max_children(index: PDASCIndexData) -> tuple[int, ...]:
    """Per-level max cluster size (static gather width for beam search).

    Entry ``l`` bounds the children (at level l-1) of any level-l prototype;
    entry 0 is 0 (leaves have no children).
    """
    out = [0]
    for lv in index.levels[1:]:
        out.append(int(jnp.max(lv.child_count)))
    return tuple(out)
