"""MSA — Multilevel Structure Algorithm (paper Algorithm 1), in JAX.

Bottom-up index construction:

  1. Randomly permute the dataset and split it into groups of ``gl`` points
     (one group == one worker shard in the paper's distributed deployment).
  2. Cluster every group into ``nPrototypes = gl // 2`` medoids (2:1 ratio,
     paper §3.1) with an arbitrary-distance clusterer (k-medoids by default).
  3. The medoids become the next level's points; regroup and repeat until a
     single group remains. Its medoids form the top level.

Groups holding ``<= nPrototypes`` valid points promote *all* their points
(the paper's outlier-preservation rule) — this falls out of the masked
k-medoids (`build` fills only ``n_valid`` slots).

TPU adaptation (DESIGN.md §3): every level is a *static-shape* array with a
validity mask; groups are padded, never ragged. After clustering, each level
is reordered **sibling-contiguous** (points sorted by their cluster slot within
each group) so that the children of any prototype occupy one contiguous slice
``[child_start, child_start + child_count)`` of the level below — this is what
lets the beam searcher gather candidate blocks with static shapes instead of
chasing ragged lists.

The per-level work is one jitted function; the host only loops over the
(statically known) level count. Under pjit with the groups axis sharded, each
device clusters its own groups — MSA's distributed build.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distances as dist_lib
from repro.core import kmedoids as km
from repro.core import kmeans as kmeans_lib
from repro.kernels import ops as kops

Array = jax.Array


class PDASCLevel(NamedTuple):
    """One level of the multilevel index (leaf = level 0).

    All arrays are in the level's *final* (sibling-contiguous) layout.
    """

    points: Array  # f32[n_l, d]
    valid: Array  # bool[n_l]
    parent: Array  # int32[n_l] — slot in level l+1 (-1 at the top level)
    child_start: Array  # int32[n_l] — slice start into level l-1 (-1 at leaf)
    child_count: Array  # int32[n_l]
    # Cached ||p||^2 per point (4 bytes/point, a 1/d overhead). The batched
    # beam search gathers these alongside the points so the Gram-form rank
    # kernels never re-reduce the [B, W, d] candidate cube for norms; the
    # arithmetic (sum of p*p over d) matches the pairwise kernels' norm
    # computation bit-for-bit.
    sq_norm: Array  # f32[n_l]


class PDASCIndexData(NamedTuple):
    """The full index: levels[0] is the leaf (data) level, levels[-1] the top."""

    levels: tuple[PDASCLevel, ...]
    leaf_ids: Array  # int32[n_0] — original dataset row of each leaf slot


class BuildStats(NamedTuple):
    level_sizes: tuple[int, ...]  # valid item count per level
    level_td: tuple[float, ...]  # summed clustering TD per level
    n_levels: int


def _pad_to(x: Array, n: int, fill=0):
    pad = n - x.shape[0]
    if pad == 0:
        return x
    widths = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, widths, constant_values=fill)


def _group_pairwise(dist: dist_lib.Distance, grp_pts: Array, grp_valid: Array,
                    row_chunk: int) -> Array:
    """Masked per-group distance matrix [G, g, g] with bounded peak memory.

    Dispatched through the kernel layer (vmapped over the group axis; on TPU
    the Pallas pairwise kernel lifts the vmap into its grid), so the MSA
    build shares the exact distance arithmetic of the search path.
    """

    def one(pts, vld):
        D = kops.pairwise_distance(pts, pts, dist, row_chunk=row_chunk)
        return dist_lib.mask_invalid(D, vld, vld)

    return jax.vmap(one)(grp_pts, grp_valid)


@functools.partial(
    jax.jit,
    static_argnames=("dist", "gl", "k", "method", "max_swaps", "row_chunk"),
)
def _build_level(
    points: Array,  # [n, d] current level items, initial layout
    valid: Array,  # [n]
    carry_a: Array,  # [n] int32 — leaf: original ids; upper: child_start
    carry_b: Array,  # [n] int32 — leaf: unused(-1);   upper: child_count
    key: Array,
    *,
    dist: dist_lib.Distance,
    gl: int,
    k: int,
    method: str,
    max_swaps: int,
    row_chunk: int,
):
    """Cluster one level. Returns the level's final-layout arrays, the
    remap (initial->final) for fixing the lower level's parents, and the next
    level's items in initial layout."""
    n, d = points.shape
    G = -(-n // gl)
    n_pad = G * gl

    pts = _pad_to(points, n_pad)
    vld = _pad_to(valid, n_pad, fill=False)
    ca = _pad_to(carry_a, n_pad, fill=-1)
    cb = _pad_to(carry_b, n_pad, fill=0)

    gpts = pts.reshape(G, gl, d)
    gvld = vld.reshape(G, gl)

    if method == "kmeans":
        keys = jax.random.split(key, G)
        res = jax.vmap(lambda x, v, kk: kmeans_lib.kmeans(x, k, v, key=kk))(
            gpts, gvld, keys
        )
        medoids = jnp.where(
            jnp.arange(k)[None, :]
            < jnp.sum(gvld, axis=1, dtype=jnp.int32)[:, None].clip(max=k),
            res.snapped,
            -1,
        )
        # Re-derive labels against the snapped medoids so labels index medoid
        # slots (k-means labels index centroids, which we replaced).
        def relabel(pts_g, vld_g, med_g):
            D = dist.pairwise(pts_g, pts_g)
            D = dist_lib.mask_invalid(D, vld_g, vld_g)
            cols = jnp.where(
                med_g[None, :] >= 0,
                jnp.take(D, jnp.clip(med_g, 0, gl - 1), axis=1),
                dist_lib.BIG,
            )
            lbl = jnp.argmin(cols, axis=1).astype(jnp.int32)
            return jnp.where(vld_g, lbl, -1)

        labels = jax.vmap(relabel)(gpts, gvld, medoids)
        td = jnp.zeros((G,), jnp.float32)
    else:
        Dg = _group_pairwise(dist, gpts, gvld, row_chunk)
        res = km.kmedoids_grouped(Dg, k, gvld, method=method, max_swaps=max_swaps)
        medoids, labels, td = res.medoids, res.labels, res.td

    # --- sibling-contiguous reorder within each group -----------------------
    sort_key = jnp.where(labels >= 0, labels, k)  # invalid slots last
    order = jnp.argsort(sort_key, axis=1, stable=True)  # [G, gl]
    take = lambda a: jnp.take_along_axis(a, order, axis=1)

    labels_f = take(labels)
    gpts_f = jnp.take_along_axis(gpts, order[:, :, None], axis=1)
    gvld_f = take(gvld)
    ca_f = take(ca.reshape(G, gl))
    cb_f = take(cb.reshape(G, gl))

    # initial->final remap: item at (g, j) moved to (g, pos) where
    # order[g, pos] = j.
    inv = jnp.argsort(order, axis=1)  # [G, gl]; inv[g, j] = new pos of j
    base = (jnp.arange(G) * gl)[:, None]
    remap = (base + inv).reshape(-1)  # [n_pad] initial slot -> final slot

    # parent slot (into next level's initial layout) of each final-layout item
    parent = jnp.where(
        labels_f >= 0, base * 0 + (jnp.arange(G) * k)[:, None] + labels_f, -1
    ).astype(jnp.int32)

    # --- children bookkeeping for the next level's items --------------------
    onehot = jax.nn.one_hot(jnp.where(labels_f >= 0, labels_f, k), k + 1,
                            dtype=jnp.int32)
    counts = jnp.sum(onehot, axis=1)[:, :k]  # [G, k] valid children per slot
    starts = (
        jnp.cumsum(counts, axis=1) - counts + (jnp.arange(G) * gl)[:, None]
    ).astype(jnp.int32)

    # --- next level items: the medoid points (initial layout) ---------------
    med_safe = jnp.clip(medoids, 0, gl - 1)
    # medoids index the *initial* within-group layout; map through inv.
    med_final = jnp.take_along_axis(inv, med_safe, axis=1)
    next_pts = jnp.take_along_axis(gpts_f, med_final[:, :, None], axis=1)
    next_valid = medoids >= 0

    level_arrays = dict(
        points=gpts_f.reshape(n_pad, d),
        valid=gvld_f.reshape(n_pad),
        parent=parent.reshape(n_pad),
        carry_a=ca_f.reshape(n_pad),
        carry_b=cb_f.reshape(n_pad),
    )
    next_arrays = dict(
        points=next_pts.reshape(G * k, d),
        valid=next_valid.reshape(G * k),
        child_start=starts.reshape(G * k),
        child_count=counts.reshape(G * k).astype(jnp.int32),
    )
    return level_arrays, next_arrays, remap, jnp.sum(td)


def n_levels_for(n: int, gl: int, k: Optional[int] = None) -> int:
    """Number of clustered levels MSA will produce for ``n`` points."""
    k = k or gl // 2
    levels = 0
    while True:
        G = -(-n // gl)
        levels += 1
        n = G * k
        if G == 1:
            return levels


def build_index_arrays(
    data,
    *,
    gl: int,
    n_prototypes: Optional[int] = None,
    distance="euclidean",
    method: str = "pam",
    max_swaps: int = 64,
    key: Optional[Array] = None,
    row_chunk: int = 512,
    shuffle: bool = True,
) -> tuple[PDASCIndexData, tuple[Array, ...]]:
    """Traceable MSA build: returns the index pytree + per-level TD scalars.

    Contains no host-side array reads, so it can run inside ``jit`` /
    ``shard_map`` (the distributed per-shard build). The level loop trips a
    statically known number of times (a function of ``n``/``gl`` only).
    """
    dist = dist_lib.get(distance)
    k = n_prototypes or gl // 2
    if k < 1 or k > gl:
        raise ValueError(f"need 1 <= n_prototypes <= gl, got {k} vs gl={gl}")
    if dist.needs_dim is not None and data.shape[1] != dist.needs_dim:
        raise ValueError(
            f"distance {dist.name!r} needs d={dist.needs_dim}, got {data.shape[1]}"
        )
    key = key if key is not None else jax.random.PRNGKey(0)
    n, d = data.shape

    data = jnp.asarray(data, jnp.float32)
    if shuffle:
        key, sub = jax.random.split(key)
        perm = jax.random.permutation(sub, n)
    else:
        perm = jnp.arange(n)
    points = jnp.take(data, perm, axis=0)
    valid = jnp.ones((n,), bool)
    carry_a = perm.astype(jnp.int32)  # leaf: original row ids
    carry_b = jnp.full((n,), -1, jnp.int32)

    raw_levels: list[dict] = []  # final-layout arrays per level (leaf first)
    level_td: list[Array] = []
    next_cs = next_cc = None  # child_start/count travelling with items

    while True:
        G = -(-points.shape[0] // gl)
        key, sub = jax.random.split(key)
        level_arrays, next_arrays, remap, td = _build_level(
            points,
            valid,
            carry_a,
            carry_b,
            sub,
            dist=dist,
            gl=gl,
            k=k,
            method=method,
            max_swaps=max_swaps,
            row_chunk=row_chunk,
        )
        # Fix the lower level's parent pointers through this level's reorder.
        if raw_levels:
            prev = raw_levels[-1]
            p = prev["parent"]
            prev["parent"] = jnp.where(p >= 0, remap[jnp.clip(p, 0, remap.shape[0] - 1)], -1)
        if next_cs is None:  # leaf level: ids in carry_a, no children
            level_arrays["child_start"] = jnp.full_like(level_arrays["carry_a"], -1)
            level_arrays["child_count"] = jnp.zeros_like(level_arrays["carry_a"])
            level_arrays["leaf_ids"] = level_arrays["carry_a"]
        else:
            level_arrays["child_start"] = level_arrays["carry_a"]
            level_arrays["child_count"] = level_arrays["carry_b"]
        raw_levels.append(level_arrays)
        level_td.append(td)

        points = next_arrays["points"]
        valid = next_arrays["valid"]
        carry_a = next_arrays["child_start"]
        carry_b = next_arrays["child_count"]
        next_cs, next_cc = carry_a, carry_b
        if G == 1:
            break

    # Top level: the medoids of the final single group; never clustered.
    top = dict(
        points=points,
        valid=valid,
        parent=jnp.full((points.shape[0],), -1, jnp.int32),
        child_start=next_cs,
        child_count=next_cc,
    )
    raw_levels.append(top)

    levels = []
    for lv in raw_levels:
        pts = lv["points"]
        levels.append(
            PDASCLevel(
                points=pts,
                valid=lv["valid"],
                parent=lv["parent"].astype(jnp.int32),
                child_start=lv["child_start"].astype(jnp.int32),
                child_count=lv["child_count"].astype(jnp.int32),
                sq_norm=jnp.sum(pts * pts, axis=-1),
            )
        )
    index = PDASCIndexData(levels=tuple(levels), leaf_ids=raw_levels[0]["leaf_ids"])
    return index, tuple(level_td) + (jnp.float32(0.0),)


def build_index(
    data,
    *,
    gl: int,
    n_prototypes: Optional[int] = None,
    distance="euclidean",
    method: str = "pam",
    max_swaps: int = 64,
    key: Optional[Array] = None,
    row_chunk: int = 512,
    shuffle: bool = True,
) -> tuple[PDASCIndexData, BuildStats]:
    """Build the PDASC multilevel index (MSA, Algorithm 1).

    Args:
      data: [n, d] dataset.
      gl: group length (points per partition at each level).
      n_prototypes: medoids per group; defaults to ``gl // 2`` (paper's 2:1).
      distance: registered distance name or a ``Distance``.
      method: "pam" | "alternate" | "build" | "kmeans".
      row_chunk: row chunking for non-Gram pairwise matrices.
    """
    index, level_td = build_index_arrays(
        data,
        gl=gl,
        n_prototypes=n_prototypes,
        distance=distance,
        method=method,
        max_swaps=max_swaps,
        key=key,
        row_chunk=row_chunk,
        shuffle=shuffle,
    )
    stats = BuildStats(
        level_sizes=tuple(int(jnp.sum(lv.valid)) for lv in index.levels),
        level_td=tuple(float(t) for t in level_td),
        n_levels=len(index.levels),
    )
    return index, stats


def max_children(index: PDASCIndexData) -> tuple[int, ...]:
    """Per-level max cluster size (static gather width for beam search).

    Entry ``l`` bounds the children (at level l-1) of any level-l prototype;
    entry 0 is 0 (leaves have no children).
    """
    out = [0]
    for lv in index.levels[1:]:
        out.append(int(jnp.max(lv.child_count)))
    return tuple(out)
