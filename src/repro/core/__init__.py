"""PDASC core — the paper's contribution as a composable JAX module.

Public surface:
  distances   — arbitrary-dissimilarity registry (paper §3.2)
  kmedoids    — vectorised PAM / FasterPAM-style clustering (paper §3.3.1)
  kmeans      — Euclidean baseline clusterer (paper §3.3)
  msa         — Multilevel Structure Algorithm (paper Algorithm 1)
  nsa         — Neighbours Search Algorithm (paper Algorithm 2)
  index       — PDASCIndex user-facing API
  radius      — CDF radius estimation + per-level dynamic radii
  distributed — sharded build / search / global top-k merge
"""

from repro.core import distances
from repro.core.index import PDASCIndex
from repro.core.msa import PDASCIndexData, PDASCLevel, build_index
from repro.core.nsa import SearchResult, search_beam, search_dense

__all__ = [
    "distances",
    "PDASCIndex",
    "PDASCIndexData",
    "PDASCLevel",
    "build_index",
    "SearchResult",
    "search_beam",
    "search_dense",
]
