"""k-medoids clustering in pure JAX (vectorised PAM / FasterPAM-style swap).

The paper builds its index with k-medoids (FasterPAM via the ``kmedoids`` Rust
package) because medoids — unlike k-means centroids — are *actual data points*
selected purely from pairwise dissimilarities, so any distance function works.
This module is the in-JAX substrate replacement: it must be ``jit``-able and
``vmap``-able over many groups at once (MSA clusters every group of a level in
parallel, one group per mesh shard), which rules out the classic pointer-chasing
implementations.

Everything operates on a *precomputed* dissimilarity matrix ``D[g, g]`` plus a
validity mask (groups are padded to a static size). Distance evaluation is kept
outside (``repro.core.distances`` / the Pallas kernels) so the clusterer is
distance-agnostic, exactly like PAM itself.

Algorithms
----------
* ``build``      — vectorised greedy PAM BUILD: k passes, each choosing the
  point whose addition minimises total deviation (TD). O(k g^2), all matmul/
  reduction shaped.
* ``swap``       — FasterPAM-decomposed swap phase. Each sweep evaluates *all*
  (candidate j, medoid i) swap deltas at once:

      dTD(i, j) = S[j] + T[i, j]
      S[j]    = sum_o min(D[o,j] - d1[o], 0)                (shared term)
      T[i, j] = sum_{o: n1[o]=i, D[o,j] >= d1[o]}
                   min(d2[o], D[o,j]) - d1[o]               (removal term)

  with ``d1/d2/n1`` the cached nearest / second-nearest medoid distances and
  nearest-medoid slot (the FasterPAM caches). ``T`` is a one-hot matmul
  (``[k,g] = onehot(n1)^T @ t``) so a sweep costs O(g^2 + g k) — the same
  complexity class as FasterPAM, fully vectorised. Best improving swap is
  applied per sweep inside ``lax.while_loop`` until no swap improves TD (or
  ``max_swaps`` is hit).
* ``alternate``  — Voronoi iteration (assign to nearest medoid, re-pick the
  in-cluster point minimising within-cluster TD). Cheaper per sweep, weaker
  optima; used for very large groups.

Small-group rule (paper §3.1): when a group holds ``<= k`` valid points, *all*
points are promoted as medoids (slots beyond ``n_valid`` are -1 / invalid).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.distances import BIG

Array = jax.Array


class KMedoidsResult(NamedTuple):
    """Pytree result; all fields have static shapes (vmap-friendly)."""

    medoids: Array  # int32[k]   — indices into the group, -1 for unused slots
    labels: Array  # int32[g]   — medoid *slot* (0..k-1) per point, -1 invalid
    td: Array  # f32[]      — total deviation over valid points
    n_swaps: Array  # int32[]    — swap iterations executed (diagnostics)


def _medoid_distance_columns(D: Array, medoids: Array) -> Array:
    """D[:, medoids] with invalid (-1) medoid slots replaced by BIG columns."""
    g = D.shape[0]
    safe = jnp.clip(medoids, 0, g - 1)
    cols = jnp.take(D, safe, axis=1)  # [g, k]
    return jnp.where(medoids[None, :] >= 0, cols, BIG)


def _nearest_caches(D: Array, medoids: Array, valid: Array):
    """Return (d1, n1, d2): nearest/second-nearest medoid info per point."""
    cols = _medoid_distance_columns(D, medoids)  # [g, k]
    n1 = jnp.argmin(cols, axis=1)
    d1 = jnp.take_along_axis(cols, n1[:, None], axis=1)[:, 0]
    cols2 = cols.at[jnp.arange(cols.shape[0]), n1].set(BIG)
    d2 = jnp.min(cols2, axis=1)
    d1 = jnp.where(valid, d1, 0.0)
    d2 = jnp.where(valid, d2, 0.0)
    return d1, n1.astype(jnp.int32), d2


def build(D: Array, k: int, valid: Array) -> Array:
    """Greedy PAM BUILD. Returns int32[k] medoid indices (-1 unused)."""
    g = D.shape[0]
    n_valid = jnp.sum(valid.astype(jnp.int32))
    Dm = jnp.where(valid[:, None] & valid[None, :], D, 0.0)  # invalid rows: no cost

    def body(i, carry):
        medoids, d_nearest, chosen = carry
        # TD if candidate j became a medoid: sum_o min(d_nearest[o], D[o, j]).
        cand_td = jnp.sum(
            jnp.minimum(d_nearest[:, None], Dm), axis=0, where=valid[:, None]
        )
        cand_td = jnp.where(valid & ~chosen, cand_td, jnp.inf)
        j = jnp.argmin(cand_td)
        ok = i < n_valid  # only fill as many slots as there are valid points
        medoids = medoids.at[i].set(jnp.where(ok, j.astype(jnp.int32), -1))
        d_new = jnp.where(ok, jnp.minimum(d_nearest, Dm[:, j]), d_nearest)
        chosen = chosen.at[j].set(chosen[j] | ok)
        return medoids, d_new, chosen

    medoids0 = jnp.full((k,), -1, dtype=jnp.int32)
    d0 = jnp.full((g,), BIG, dtype=D.dtype)
    chosen0 = jnp.zeros((g,), dtype=bool)
    medoids, _, _ = jax.lax.fori_loop(0, k, body, (medoids0, d0, chosen0))
    return medoids


def _swap_once(D: Array, valid: Array, medoids: Array):
    """One FasterPAM-decomposed sweep: best (i, j) swap and its dTD."""
    g, k = D.shape[0], medoids.shape[0]
    d1, n1, d2 = _nearest_caches(D, medoids, valid)
    vf = valid.astype(D.dtype)

    # Shared term S[j]: points that would defect to j no matter which medoid
    # is removed (D[o,j] < d1[o]) — always an improvement contribution.
    gain = jnp.minimum(D - d1[:, None], 0.0) * vf[:, None]  # [g, g]
    S = jnp.sum(gain, axis=0)  # [g]

    # Removal term T[i, j]: points whose nearest medoid i is removed and that
    # do NOT defect to j — they pay min(d2, D[o,j]) - d1.
    t = jnp.where(D >= d1[:, None], jnp.minimum(d2[:, None], D) - d1[:, None], 0.0)
    t = t * vf[:, None]  # [g, g]
    onehot = jax.nn.one_hot(n1, k, dtype=D.dtype) * vf[:, None]  # [g, k]
    T = onehot.T @ t  # [k, g]

    dTD = S[None, :] + T  # [k, g]

    # Mask: candidate j must be a valid non-medoid point; slot i must hold a
    # real medoid.
    is_medoid = jnp.zeros((g,), bool).at[jnp.clip(medoids, 0, g - 1)].set(
        medoids >= 0
    )
    col_ok = valid & ~is_medoid
    row_ok = medoids >= 0
    dTD = jnp.where(col_ok[None, :], dTD, jnp.inf)
    dTD = jnp.where(row_ok[:, None], dTD, jnp.inf)

    flat = jnp.argmin(dTD)
    i_best = (flat // g).astype(jnp.int32)
    j_best = (flat % g).astype(jnp.int32)
    return dTD[i_best, j_best], i_best, j_best


def swap(
    D: Array,
    valid: Array,
    medoids: Array,
    *,
    max_swaps: int = 64,
    tol: float = 1e-6,
) -> tuple[Array, Array]:
    """FasterPAM-style swap loop. Returns (medoids, n_swaps)."""

    def cond(carry):
        _, n, improving = carry
        return improving & (n < max_swaps)

    def body(carry):
        medoids, n, _ = carry
        delta, i, j = _swap_once(D, valid, medoids)
        do = delta < -tol
        medoids = medoids.at[i].set(jnp.where(do, j, medoids[i]))
        return medoids, n + do.astype(jnp.int32), do

    medoids, n_swaps, _ = jax.lax.while_loop(
        cond, body, (medoids, jnp.int32(0), jnp.bool_(True))
    )
    return medoids, n_swaps


def _labels_and_td(D: Array, medoids: Array, valid: Array):
    cols = _medoid_distance_columns(D, medoids)
    labels = jnp.argmin(cols, axis=1).astype(jnp.int32)
    d1 = jnp.take_along_axis(cols, labels[:, None], axis=1)[:, 0]
    labels = jnp.where(valid, labels, -1)
    td = jnp.sum(jnp.where(valid, d1, 0.0))
    return labels, td


def alternate(
    D: Array,
    valid: Array,
    medoids: Array,
    *,
    max_sweeps: int = 16,
) -> Array:
    """Voronoi-iteration k-medoids (assign / in-cluster re-pick)."""
    g, k = D.shape[0], medoids.shape[0]

    def body(_, medoids):
        cols = _medoid_distance_columns(D, medoids)
        labels = jnp.argmin(cols, axis=1)
        onehot = jax.nn.one_hot(labels, k, dtype=D.dtype)
        onehot = onehot * valid[:, None].astype(D.dtype)
        # cost[x, c] = sum_{y in cluster c} D[x, y]
        cost = jnp.where(valid[:, None] & valid[None, :], D, 0.0) @ onehot  # [g,k]
        in_cluster = onehot > 0.5
        cost = jnp.where(in_cluster, cost, jnp.inf)
        new = jnp.argmin(cost, axis=0).astype(jnp.int32)
        # Empty clusters / unused slots keep their previous medoid (incl. -1).
        nonempty = jnp.any(in_cluster, axis=0)
        return jnp.where(nonempty & (medoids >= 0), new, medoids)

    return jax.lax.fori_loop(0, max_sweeps, body, medoids)


@functools.partial(jax.jit, static_argnames=("k", "method", "max_swaps"))
def kmedoids(
    D: Array,
    k: int,
    valid: Array | None = None,
    *,
    method: str = "pam",
    max_swaps: int = 64,
) -> KMedoidsResult:
    """Cluster one (padded) group given its dissimilarity matrix.

    Args:
      D:      [g, g] pairwise dissimilarities (any registered distance).
      k:      number of medoids (static).
      valid:  [g] bool mask of real (non-padding) points.
      method: "pam" (BUILD + FasterPAM swap), "alternate", or "build"
              (BUILD only — cheap, used for upper index levels).
    """
    g = D.shape[0]
    if valid is None:
        valid = jnp.ones((g,), bool)
    D = D.astype(jnp.float32)

    medoids = build(D, k, valid)
    n_swaps = jnp.int32(0)
    if method == "pam":
        medoids, n_swaps = swap(D, valid, medoids, max_swaps=max_swaps)
    elif method == "alternate":
        medoids = alternate(D, valid, medoids, max_sweeps=max_swaps)
    elif method != "build":
        raise ValueError(f"unknown k-medoids method {method!r}")

    labels, td = _labels_and_td(D, medoids, valid)
    return KMedoidsResult(medoids=medoids, labels=labels, td=td, n_swaps=n_swaps)


def kmedoids_grouped(
    Dg: Array,
    k: int,
    valid: Array,
    *,
    method: str = "pam",
    max_swaps: int = 64,
) -> KMedoidsResult:
    """vmap of :func:`kmedoids` over a leading groups axis.

    Args: Dg [G, g, g], valid [G, g]. Under pjit with the groups axis sharded,
    every device clusters only its own groups — this is MSA's distributed
    build.
    """
    fn = lambda D, v: kmedoids(D, k=k, valid=v, method=method, max_swaps=max_swaps)
    return jax.vmap(fn)(Dg, valid)
