"""k-medoids clustering in pure JAX (vectorised PAM / FasterPAM-style swap).

The paper builds its index with k-medoids (FasterPAM via the ``kmedoids`` Rust
package) because medoids — unlike k-means centroids — are *actual data points*
selected purely from pairwise dissimilarities, so any distance function works.
This module is the in-JAX substrate replacement: it must be ``jit``-able and
``vmap``-able over many groups at once (MSA clusters every group of a level in
parallel, one group per mesh shard), which rules out the classic pointer-chasing
implementations.

Everything operates on a *precomputed* dissimilarity matrix ``D[g, g]`` plus a
validity mask (groups are padded to a static size). Distance evaluation is kept
outside (``repro.core.distances`` / the Pallas kernels) so the clusterer is
distance-agnostic, exactly like PAM itself.

Algorithms
----------
* ``build`` / ``build_grouped`` — vectorised greedy PAM BUILD: k passes, each
  choosing the point whose addition minimises total deviation (TD).
  O(k g^2), all matmul/reduction shaped. ``build_grouped`` runs every pass as
  one batched ``[G, g, g]`` contraction shared across the group axis (the MSA
  level layout) instead of a vmapped scalar loop.
  ``build_grouped_pruned`` is the lazy-greedy variant seeding the swap phase:
  BUILD's gain function is submodular (facility location), so stale gains
  upper-bound current ones and each pass only re-evaluates the top-``C``
  stale candidates — O(k g C) total instead of O(k g^2).
* ``swap``       — *eager multi-swap* FasterPAM. Each sweep evaluates all
  (candidate j, medoid i) swap deltas at once:

      dTD(i, j) = S[j] + T[i, j]
      S[j]    = sum_o min(D[o,j] - d1[o], 0)                (shared term)
      T[i, j] = sum_{o: n1[o]=i, D[o,j] >= d1[o]}
                   min(d2[o], D[o,j]) - d1[o]               (removal term)

  with ``d1/d2/n1`` the cached nearest / second-nearest medoid distances and
  nearest-medoid slot (the FasterPAM caches). The ``[k, g]`` delta matrix is
  computed through the kernel layer (``kernels.ops.swap_deltas`` — streamed
  Pallas sweep on TPU, jnp oracle on CPU), then *every* medoid slot greedily
  accepts its best improving candidate, best-delta-first, a candidate column
  going dark once an earlier slot claims it. Because the deltas were priced
  against the pre-sweep medoid set, the batch of accepted swaps is kept only
  if its exactly recomputed TD beats the best single swap (whose delta *is*
  exact); otherwise the sweep falls back to that single swap — TD is
  monotonically non-increasing either way, and a sweep retires up to k swaps
  instead of one, cutting the sweep count by ~k on large groups. The seed
  one-swap-per-sweep loop is kept as ``swap_reference`` (benchmark baseline).
* ``alternate``  — Voronoi iteration (assign to nearest medoid, re-pick the
  in-cluster point minimising within-cluster TD). Cheaper per sweep, weaker
  optima; used for very large groups.

Small-group rule (paper §3.1): when a group holds ``<= k`` valid points, *all*
points are promoted as medoids (slots beyond ``n_valid`` are -1 / invalid).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.distances import BIG
from repro.kernels import ops as kops

Array = jax.Array


class KMedoidsResult(NamedTuple):
    """Pytree result; all fields have static shapes (vmap-friendly)."""

    medoids: Array  # int32[k]   — indices into the group, -1 for unused slots
    labels: Array  # int32[g]   — medoid *slot* (0..k-1) per point, -1 invalid
    td: Array  # f32[]      — total deviation over valid points
    n_swaps: Array  # int32[]    — swap iterations executed (diagnostics)


def _medoid_distance_columns(D: Array, medoids: Array) -> Array:
    """D[:, medoids] with invalid (-1) medoid slots replaced by BIG columns."""
    g = D.shape[0]
    safe = jnp.clip(medoids, 0, g - 1)
    cols = jnp.take(D, safe, axis=1)  # [g, k]
    return jnp.where(medoids[None, :] >= 0, cols, BIG)


def _nearest_caches(D: Array, medoids: Array, valid: Array):
    """Return (d1, n1, d2): nearest/second-nearest medoid info per point."""
    cols = _medoid_distance_columns(D, medoids)  # [g, k]
    n1 = jnp.argmin(cols, axis=1)
    d1 = jnp.take_along_axis(cols, n1[:, None], axis=1)[:, 0]
    cols2 = cols.at[jnp.arange(cols.shape[0]), n1].set(BIG)
    d2 = jnp.min(cols2, axis=1)
    d1 = jnp.where(valid, d1, 0.0)
    d2 = jnp.where(valid, d2, 0.0)
    return d1, n1.astype(jnp.int32), d2


def build(D: Array, k: int, valid: Array) -> Array:
    """Greedy PAM BUILD for one group: int32[k] medoid indices (-1 unused).

    A batch-of-one view over :func:`build_grouped` (one algorithm, one
    implementation)."""
    return build_grouped(D[None], k, valid[None])[0]


def build_grouped(Dg: Array, k: int, valid: Array) -> Array:
    """Greedy PAM BUILD over a whole batch of groups at once.

    ``Dg``: [G, g, g]; ``valid``: [G, g]. Returns int32[G, k] medoid indices
    (-1 unused). Each of the k passes is one batched [G, g, g] contraction —
    the group axis rides the batched matmul/reduction instead of a vmapped
    scalar loop, which is what lets XLA fuse the whole pass.
    """
    G, g = Dg.shape[0], Dg.shape[1]
    n_valid = jnp.sum(valid.astype(jnp.int32), axis=1)  # [G]
    both = valid[:, :, None] & valid[:, None, :]
    Dm = jnp.where(both, Dg, 0.0)  # invalid rows: no cost

    def body(i, carry):
        medoids, d_nearest, chosen = carry
        # TD if candidate j became a medoid: sum_o min(d_nearest[o], D[o, j]).
        cand_td = jnp.sum(
            jnp.minimum(d_nearest[:, :, None], Dm),
            axis=1,
            where=valid[:, :, None],
        )  # [G, g]
        cand_td = jnp.where(valid & ~chosen, cand_td, jnp.inf)
        j = jnp.argmin(cand_td, axis=1)  # [G]
        ok = i < n_valid  # only fill as many slots as there are valid points
        medoids = medoids.at[:, i].set(jnp.where(ok, j.astype(jnp.int32), -1))
        dj = jnp.take_along_axis(Dm, j[:, None, None], axis=2)[:, :, 0]
        d_nearest = jnp.where(ok[:, None], jnp.minimum(d_nearest, dj), d_nearest)
        hit = (jnp.arange(g)[None, :] == j[:, None]) & ok[:, None]
        return medoids, d_nearest, chosen | hit

    medoids0 = jnp.full((G, k), -1, dtype=jnp.int32)
    d0 = jnp.full((G, g), BIG, dtype=Dg.dtype)
    chosen0 = jnp.zeros((G, g), dtype=bool)
    medoids, _, _ = jax.lax.fori_loop(0, k, body, (medoids0, d0, chosen0))
    return medoids


def build_grouped_pruned(
    Dg: Array, k: int, valid: Array, *, n_cands: int = 16
) -> Array:
    """Candidate-pruned greedy BUILD (init for the swap phase).

    The greedy BUILD objective — TD reduction from adding a medoid — is a
    facility-location function: monotone submodular in the chosen set. Gains
    therefore only shrink as medoids are added, so a gain computed in an
    earlier pass is a valid *upper bound* later (the lazy-greedy argument).
    Each pass evaluates exact gains only for the ``n_cands`` candidates with
    the best stale bounds — one [G, g, n_cands] contraction instead of the
    full [G, g, g] pass — and refreshes their bounds. With ``n_cands >= g``
    this is exact greedy BUILD; at the default it is near-exact (the true
    argmax is almost always inside the stale top-16), and the eager swap
    phase absorbs the rare mis-ordered pick. Used only as swap init
    (``method="pam"``); ``method="build"`` keeps the exact
    :func:`build_grouped`.
    """
    G, g = Dg.shape[0], Dg.shape[1]
    C = min(n_cands, g)
    n_valid = jnp.sum(valid.astype(jnp.int32), axis=1)
    both = valid[:, :, None] & valid[:, None, :]
    Dm = jnp.where(both, Dg, 0.0)
    NEG = jnp.float32(-BIG)

    # Pass 0 exactly: with no medoids the best first pick minimises the
    # column sum (identical to pass 0 of the exact BUILD).
    ct0 = jnp.where(valid, jnp.sum(Dm, axis=1), jnp.inf)
    j0 = jnp.argmin(ct0, axis=1)
    ok0 = n_valid > 0
    medoids = jnp.full((G, k), -1, jnp.int32).at[:, 0].set(
        jnp.where(ok0, j0.astype(jnp.int32), -1)
    )
    dn = jnp.take_along_axis(Dm, j0[:, None, None], axis=2)[:, :, 0]
    dn = jnp.where(valid & ok0[:, None], dn, jnp.where(valid, BIG, 0.0))
    chosen = (jnp.arange(g)[None, :] == j0[:, None]) & ok0[:, None]
    # Exact gains once (one full pass): gain_j = sum_o relu(dn_o - D_oj).
    ub = jnp.sum(jnp.maximum(dn[:, :, None] - Dm, 0.0), axis=1)  # [G, g]

    def body(i, carry):
        medoids, dn, chosen, ub = carry
        mask = valid & ~chosen
        ubm = jnp.where(mask, ub, NEG)
        _, top = jax.lax.top_k(ubm, C)  # [G, C] best stale bounds
        cols = jnp.take_along_axis(Dm, top[:, None, :], axis=2)  # [G, g, C]
        e = jnp.sum(jnp.maximum(dn[:, :, None] - cols, 0.0), axis=1)  # exact
        e = jnp.where(jnp.take_along_axis(mask, top, axis=1), e, NEG)
        c = jnp.argmax(e, axis=1)
        j = jnp.take_along_axis(top, c[:, None], axis=1)[:, 0]
        ok = i < n_valid
        medoids = medoids.at[:, i].set(jnp.where(ok, j.astype(jnp.int32), -1))
        dj = jnp.take_along_axis(Dm, j[:, None, None], axis=2)[:, :, 0]
        dn = jnp.where(ok[:, None], jnp.minimum(dn, dj), dn)
        chosen = chosen | ((jnp.arange(g)[None, :] == j[:, None]) & ok[:, None])
        ub = ub.at[jnp.arange(G)[:, None], top].set(e)  # refresh evaluated
        return medoids, dn, chosen, ub

    medoids, _, _, _ = jax.lax.fori_loop(
        1, k, body, (medoids, dn, chosen, ub)
    )
    return medoids


def _swap_once(D: Array, valid: Array, medoids: Array):
    """One FasterPAM-decomposed sweep: best (i, j) swap and its dTD."""
    g, k = D.shape[0], medoids.shape[0]
    d1, n1, d2 = _nearest_caches(D, medoids, valid)
    vf = valid.astype(D.dtype)

    # Shared term S[j]: points that would defect to j no matter which medoid
    # is removed (D[o,j] < d1[o]) — always an improvement contribution.
    gain = jnp.minimum(D - d1[:, None], 0.0) * vf[:, None]  # [g, g]
    S = jnp.sum(gain, axis=0)  # [g]

    # Removal term T[i, j]: points whose nearest medoid i is removed and that
    # do NOT defect to j — they pay min(d2, D[o,j]) - d1.
    t = jnp.where(D >= d1[:, None], jnp.minimum(d2[:, None], D) - d1[:, None], 0.0)
    t = t * vf[:, None]  # [g, g]
    onehot = jax.nn.one_hot(n1, k, dtype=D.dtype) * vf[:, None]  # [g, k]
    T = onehot.T @ t  # [k, g]

    dTD = S[None, :] + T  # [k, g]

    # Mask: candidate j must be a valid non-medoid point; slot i must hold a
    # real medoid.
    is_medoid = jnp.zeros((g,), bool).at[jnp.clip(medoids, 0, g - 1)].set(
        medoids >= 0
    )
    col_ok = valid & ~is_medoid
    row_ok = medoids >= 0
    dTD = jnp.where(col_ok[None, :], dTD, jnp.inf)
    dTD = jnp.where(row_ok[:, None], dTD, jnp.inf)

    flat = jnp.argmin(dTD)
    i_best = (flat // g).astype(jnp.int32)
    j_best = (flat % g).astype(jnp.int32)
    return dTD[i_best, j_best], i_best, j_best


def swap_reference(
    D: Array,
    valid: Array,
    medoids: Array,
    *,
    max_swaps: int = 64,
    tol: float = 1e-6,
) -> tuple[Array, Array]:
    """Seed FasterPAM swap loop: one swap per sweep (benchmark baseline).

    Returns (medoids, n_swaps). Superseded by the eager multi-swap
    :func:`swap` on the build hot path; kept for the seed-vs-new
    ``benchmarks/bench_build.py`` comparison and as a property-test oracle.
    """

    def cond(carry):
        _, n, improving = carry
        return improving & (n < max_swaps)

    def body(carry):
        medoids, n, _ = carry
        delta, i, j = _swap_once(D, valid, medoids)
        do = delta < -tol
        medoids = medoids.at[i].set(jnp.where(do, j, medoids[i]))
        return medoids, n + do.astype(jnp.int32), do

    medoids, n_swaps, _ = jax.lax.while_loop(
        cond, body, (medoids, jnp.int32(0), jnp.bool_(True))
    )
    return medoids, n_swaps


def _masked_swap_deltas(
    D: Array, valid: Array, medoids: Array, *, bg: int = 128,
    force_pallas: bool = False,
) -> Array:
    """[k, g] swap deltas with medoid rows/columns masked to +inf.

    The delta matrix itself comes from the kernel layer
    (``kernels.ops.swap_deltas`` — streamed Pallas sweep on TPU, jnp oracle
    on CPU); this wrapper derives the FasterPAM caches and applies the
    candidate/slot validity masks. ``bg`` is the sweep kernel's row tile.
    """
    g, k = D.shape[0], medoids.shape[0]
    d1, n1, d2 = _nearest_caches(D, medoids, valid)
    dTD = kops.swap_deltas(
        D, d1, d2, n1, valid, k=k, bg=bg, force_pallas=force_pallas
    )

    # Candidate j must be a valid non-medoid point; slot i a real medoid.
    is_medoid = jnp.zeros((g,), bool).at[jnp.clip(medoids, 0, g - 1)].set(
        medoids >= 0
    )
    ok = (valid & ~is_medoid)[None, :] & (medoids >= 0)[:, None]
    return jnp.where(ok, dTD, jnp.inf)


def _eager_accept(dTD: Array, medoids: Array, tol: float):
    """Greedy conflict-free multi-swap: every slot takes its best improving
    candidate, best-delta-first; a candidate column goes dark once claimed.

    Returns (medoids, n_accepted). Deltas are priced against the pre-sweep
    medoid set, so the caller must re-validate the batch's TD (see
    :func:`sweep_once`).

    Implementation notes: slots are visited in order of their *pre-sweep*
    best delta via repeated [k]-argmin over a mins vector, not an argsort —
    XLA partitions ``sort`` with cross-device collectives, which deadlocks
    inside a ``while_loop`` whose trip count is data-dependent per shard
    (the distributed build); argmin is a plain reduce. Each iteration
    touches only the selected slot's [g] row (claimed candidates masked to
    +inf), and the pass stops as soon as the best remaining pre-sweep delta
    is non-improving — ``best0[i]`` lower-bounds slot i's masked row min, so
    no later slot could accept. A pass therefore costs O(a(k + g)) for a
    accepted swaps, not O(k^2 g).
    """
    k, g = dTD.shape
    best0 = jnp.min(dTD, axis=1)  # [k] pre-sweep per-slot bests

    def cond(carry):
        _, _, done, _, s = carry
        more = jnp.min(jnp.where(done, jnp.inf, best0)) < -tol
        return more & (s < k)

    def body(carry):
        medoids, taken, done, n_acc, s = carry
        i = jnp.argmin(jnp.where(done, jnp.inf, best0))
        row = jnp.where(taken, jnp.inf, dTD[i])  # earlier accepts masked out
        j = jnp.argmin(row)
        do = row[j] < -tol
        medoids = medoids.at[i].set(
            jnp.where(do, j.astype(jnp.int32), medoids[i])
        )
        taken = taken.at[j].set(taken[j] | do)
        done = done.at[i].set(True)  # each slot swaps at most once per sweep
        return medoids, taken, done, n_acc + do.astype(jnp.int32), s + 1

    medoids, _, _, n_acc, _ = jax.lax.while_loop(
        cond,
        body,
        (
            medoids,
            jnp.zeros((g,), bool),
            jnp.zeros((k,), bool),
            jnp.int32(0),
            jnp.int32(0),
        ),
    )
    return medoids, n_acc


def sweep_once(
    D: Array,
    valid: Array,
    medoids: Array,
    td: Array,
    *,
    tol: float = 1e-6,
    bg: int = 128,
    force_pallas: bool = False,
):
    """One eager multi-swap sweep. Returns (medoids, td, n_accepted,
    improving); TD is guaranteed non-increasing.

    The batched accept is kept only if its exactly recomputed TD beats the
    best single swap (whose FasterPAM delta is exact); otherwise the sweep
    falls back to that single swap. ``improving`` is False iff no single
    swap improves — the same convergence criterion as the seed loop, so the
    final medoid set is single-swap locally optimal in both.
    """
    g = D.shape[0]
    dTD = _masked_swap_deltas(
        D, valid, medoids, bg=bg, force_pallas=force_pallas
    )

    flat = jnp.argmin(dTD)
    i1 = (flat // g).astype(jnp.int32)
    j1 = (flat % g).astype(jnp.int32)
    delta1 = dTD[i1, j1]
    improving = delta1 < -tol

    batch_m, n_acc = _eager_accept(dTD, medoids, tol)
    _, batch_td = _labels_and_td(D, batch_m, valid)
    single_m = medoids.at[i1].set(jnp.where(improving, j1, medoids[i1]))
    single_td = td + delta1
    use_batch = improving & (batch_td <= single_td)

    medoids = jnp.where(use_batch, batch_m, jnp.where(improving, single_m, medoids))
    td = jnp.where(use_batch, batch_td, jnp.where(improving, single_td, td))
    n_acc = jnp.where(use_batch, n_acc, improving.astype(jnp.int32))
    return medoids, td, n_acc, improving


def swap(
    D: Array,
    valid: Array,
    medoids: Array,
    *,
    max_swaps: int = 64,
    tol: float = 1e-6,
    rel_tol: float = 0.0,
    bg: int = 128,
    force_pallas: bool = False,
) -> tuple[Array, Array]:
    """Eager multi-swap FasterPAM loop. Returns (medoids, n_swaps).

    Sweeps :func:`sweep_once` until no single swap improves TD (or
    ``max_swaps`` sweeps ran) — up to k swaps retire per O(g^2) sweep
    instead of one, with TD monotonically non-increasing. ``n_swaps``
    counts accepted swaps (comparable with :func:`swap_reference`).

    ``rel_tol`` is a convergence knob: stop as soon as a sweep improves TD
    by less than ``rel_tol * TD``. 0 (default) converges to the same
    single-swap local optimality criterion as :func:`swap_reference`; the
    MSA build uses a small positive value (``swap_tol``, default 1e-3)
    because the last few sweeps chase ~0.1%-of-TD refinements at full
    O(g^2) sweep cost — recall-neutral for an ANN index, and the dominant
    build-time lever after the multi-swap batching itself.
    """
    _, td0 = _labels_and_td(D, medoids, valid)

    def cond(carry):
        _, _, sweeps, _, keep_going = carry
        return keep_going & (sweeps < max_swaps)

    def body(carry):
        medoids, td, sweeps, n, _ = carry
        medoids, new_td, n_acc, improving = sweep_once(
            D, valid, medoids, td, tol=tol, bg=bg, force_pallas=force_pallas
        )
        keep_going = improving & (td - new_td > rel_tol * jnp.abs(new_td))
        return medoids, new_td, sweeps + 1, n + n_acc, keep_going

    medoids, _, _, n_swaps, _ = jax.lax.while_loop(
        cond,
        body,
        (medoids, td0, jnp.int32(0), jnp.int32(0), jnp.bool_(True)),
    )
    return medoids, n_swaps


def _labels_and_td(D: Array, medoids: Array, valid: Array):
    cols = _medoid_distance_columns(D, medoids)
    labels = jnp.argmin(cols, axis=1).astype(jnp.int32)
    d1 = jnp.take_along_axis(cols, labels[:, None], axis=1)[:, 0]
    labels = jnp.where(valid, labels, -1)
    td = jnp.sum(jnp.where(valid, d1, 0.0))
    return labels, td


def alternate(
    D: Array,
    valid: Array,
    medoids: Array,
    *,
    max_sweeps: int = 16,
) -> Array:
    """Voronoi-iteration k-medoids (assign / in-cluster re-pick)."""
    g, k = D.shape[0], medoids.shape[0]

    def body(_, medoids):
        cols = _medoid_distance_columns(D, medoids)
        labels = jnp.argmin(cols, axis=1)
        onehot = jax.nn.one_hot(labels, k, dtype=D.dtype)
        onehot = onehot * valid[:, None].astype(D.dtype)
        # cost[x, c] = sum_{y in cluster c} D[x, y]
        cost = jnp.where(valid[:, None] & valid[None, :], D, 0.0) @ onehot  # [g,k]
        in_cluster = onehot > 0.5
        cost = jnp.where(in_cluster, cost, jnp.inf)
        new = jnp.argmin(cost, axis=0).astype(jnp.int32)
        # Empty clusters / unused slots keep their previous medoid (incl. -1).
        nonempty = jnp.any(in_cluster, axis=0)
        return jnp.where(nonempty & (medoids >= 0), new, medoids)

    return jax.lax.fori_loop(0, max_sweeps, body, medoids)


@functools.partial(
    jax.jit, static_argnames=("k", "method", "max_swaps", "bg", "force_pallas")
)
def kmedoids(
    D: Array,
    k: int,
    valid: Array | None = None,
    *,
    method: str = "pam",
    max_swaps: int = 64,
    rel_tol: float = 0.0,
    bg: int = 128,
    force_pallas: bool = False,
) -> KMedoidsResult:
    """Cluster one (padded) group given its dissimilarity matrix.

    Args:
      D:      [g, g] pairwise dissimilarities (any registered distance).
      k:      number of medoids (static).
      valid:  [g] bool mask of real (non-padding) points.
      method: "pam" (BUILD + eager multi-swap FasterPAM), "pam_reference"
              (BUILD + the seed one-swap-per-sweep loop — benchmark
              baseline), "alternate", or "build" (BUILD only — cheap, used
              for upper index levels).
      rel_tol: eager-swap per-sweep relative improvement cutoff (see
              :func:`swap`); 0 = full single-swap local optimality.
    """
    g = D.shape[0]
    if valid is None:
        valid = jnp.ones((g,), bool)
    D = D.astype(jnp.float32)

    # pam seeds from the pruned BUILD (same arithmetic as the grouped path,
    # batch of one); the other methods keep the exact greedy BUILD.
    if method == "pam":
        medoids = build_grouped_pruned(D[None], k, valid[None])[0]
    else:
        medoids = build(D, k, valid)
    n_swaps = jnp.int32(0)
    if method == "pam":
        medoids, n_swaps = swap(
            D, valid, medoids, max_swaps=max_swaps, rel_tol=rel_tol, bg=bg,
            force_pallas=force_pallas,
        )
    elif method == "pam_reference":
        medoids, n_swaps = swap_reference(D, valid, medoids, max_swaps=max_swaps)
    elif method == "alternate":
        medoids = alternate(D, valid, medoids, max_sweeps=max_swaps)
    elif method != "build":
        raise ValueError(f"unknown k-medoids method {method!r}")

    labels, td = _labels_and_td(D, medoids, valid)
    return KMedoidsResult(medoids=medoids, labels=labels, td=td, n_swaps=n_swaps)


@functools.partial(
    jax.jit, static_argnames=("k", "method", "max_swaps", "bg", "force_pallas")
)
def kmedoids_grouped(
    Dg: Array,
    k: int,
    valid: Array,
    *,
    method: str = "pam",
    max_swaps: int = 64,
    rel_tol: float = 0.0,
    bg: int = 128,
    force_pallas: bool = False,
) -> KMedoidsResult:
    """Batched :func:`kmedoids` over a leading groups axis.

    Args: Dg [G, g, g], valid [G, g]. The BUILD phase runs as whole-batch
    [G, g, g] contractions (:func:`build_grouped`); the swap/alternate
    phases vmap over groups (their while-loops carry per-group trip counts).
    Under pjit with the groups axis sharded, every device clusters only its
    own groups — this is MSA's distributed build. ``method="pam_reference"``
    reproduces the seed per-group path exactly.
    """
    if method == "pam_reference":
        fn = lambda D, v: kmedoids(
            D, k=k, valid=v, method=method, max_swaps=max_swaps
        )
        return jax.vmap(fn)(Dg, valid)

    Dg = Dg.astype(jnp.float32)
    n_swaps = jnp.zeros((Dg.shape[0],), jnp.int32)
    if method == "pam":
        medoids = build_grouped_pruned(Dg, k, valid)
        medoids, n_swaps = jax.vmap(
            lambda D, v, m: swap(
                D, v, m, max_swaps=max_swaps, rel_tol=rel_tol, bg=bg,
                force_pallas=force_pallas,
            )
        )(Dg, valid, medoids)
    elif method == "alternate":
        medoids = build_grouped(Dg, k, valid)
        medoids = jax.vmap(
            lambda D, v, m: alternate(D, v, m, max_sweeps=max_swaps)
        )(Dg, valid, medoids)
    elif method == "build":
        medoids = build_grouped(Dg, k, valid)
    else:
        raise ValueError(f"unknown k-medoids method {method!r}")

    labels, td = jax.vmap(_labels_and_td)(Dg, medoids, valid)
    return KMedoidsResult(medoids=medoids, labels=labels, td=td, n_swaps=n_swaps)
