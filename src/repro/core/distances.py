"""Distance-function registry for PDASC.

The paper's central flexibility claim is that the index builder (MSA) and the
searcher (NSA) are parameterised by an *arbitrary* dissimilarity function: any
``delta: X x X -> R`` that is non-negative, symmetric and zero on identical
points (a metric is *not* required — k-medoids only consumes pairwise
dissimilarities).

Every distance here is exposed in two forms:

* ``point(x, y)``     — single-pair dissimilarity, ``[d] x [d] -> scalar``.
* ``pairwise(X, Y)``  — batched cross matrix, ``[m, d] x [n, d] -> [m, n]``.

All functions are pure ``jnp`` (jit / vmap / grad safe).  ``pairwise`` for the
Gram-form distances (l2 / cosine / dot) is written as a matmul so that XLA maps
it onto the MXU; the Pallas kernels in ``repro.kernels`` implement the same
contracts with explicit VMEM tiling for the TPU hot path and are validated
against these references.

Registry entries carry structural traits used elsewhere:

* ``gram_form``   — pairwise distance reducible to a Gram matrix (MXU-friendly).
* ``is_metric``   — satisfies the triangle inequality (p>=1 Minkowski,
  Haversine). PDASC does *not* rely on this — it is metadata used by tests and
  by baselines that do require a metric (e.g. KD-tree-style pruning).
* ``needs_dim``   — fixed input dimensionality (Haversine: d == 2).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

Array = jax.Array

_EPS = 1e-12


# ---------------------------------------------------------------------------
# Point-wise definitions
# ---------------------------------------------------------------------------


def _minkowski_point(x: Array, y: Array, p: float) -> Array:
    diff = jnp.abs(x - y)
    if p == jnp.inf:
        return jnp.max(diff, axis=-1)
    if p == 1.0:
        return jnp.sum(diff, axis=-1)
    if p == 2.0:
        return jnp.sqrt(jnp.maximum(jnp.sum(diff * diff, axis=-1), 0.0))
    # Generic (includes fractional p < 1 — not a metric, but PDASC supports it;
    # the paper cites Aggarwal et al. on fractional distances improving
    # clustering in high dimension).
    return jnp.power(jnp.sum(jnp.power(diff, p), axis=-1), 1.0 / p)


def _cosine_point(x: Array, y: Array) -> Array:
    xn = jnp.sqrt(jnp.maximum(jnp.sum(x * x, axis=-1), _EPS))
    yn = jnp.sqrt(jnp.maximum(jnp.sum(y * y, axis=-1), _EPS))
    cos = jnp.sum(x * y, axis=-1) / (xn * yn)
    return 1.0 - jnp.clip(cos, -1.0, 1.0)


def _haversine_point(x: Array, y: Array) -> Array:
    # x, y: [..., 2] = (lat, lon) in radians.  Unit-sphere great-circle angle;
    # multiply by the sphere radius externally if a length is needed (the paper
    # uses the raw value — their Municipalities radii are in these units).
    lat1, lon1 = x[..., 0], x[..., 1]
    lat2, lon2 = y[..., 0], y[..., 1]
    dlat = lat2 - lat1
    dlon = lon2 - lon1
    a = (
        jnp.sin(dlat / 2.0) ** 2
        + jnp.cos(lat1) * jnp.cos(lat2) * jnp.sin(dlon / 2.0) ** 2
    )
    return 2.0 * jnp.arcsin(jnp.sqrt(jnp.clip(a, 0.0, 1.0)))


def _jaccard_point(x: Array, y: Array) -> Array:
    # Weighted (Ruzicka) Jaccard for non-negative vectors; reduces to the set
    # Jaccard distance on binary data.  The paper lists Jaccard as future work;
    # k-medoids accommodates it unchanged, so we ship it.
    mn = jnp.sum(jnp.minimum(x, y), axis=-1)
    mx = jnp.sum(jnp.maximum(x, y), axis=-1)
    return 1.0 - mn / jnp.maximum(mx, _EPS)


def _dot_point(x: Array, y: Array) -> Array:
    # Negative inner product ("maximum inner product search" as a
    # dissimilarity). Not a metric and can be negative; PDASC only needs an
    # ordering, radii just shift.
    return -jnp.sum(x * y, axis=-1)


# ---------------------------------------------------------------------------
# Pairwise (cross-matrix) definitions
# ---------------------------------------------------------------------------


def _broadcast_pairwise(point_fn: Callable[[Array, Array], Array]):
    def pairwise(X: Array, Y: Array) -> Array:
        return point_fn(X[:, None, :], Y[None, :, :])

    return pairwise


def _sqeuclidean_gram(X: Array, Y: Array) -> Array:
    # ||x-y||^2 = ||x||^2 + ||y||^2 - 2 x.y — one [m,n] matmul on the MXU
    # instead of an [m,n,d] broadcast. Accumulates in f32 even for bf16
    # inputs (the cancellation in xx+yy-2g destroys ranking in bf16), and
    # clamps for the residual cancellation.
    xx = jnp.sum(X.astype(jnp.float32) ** 2, axis=-1)
    yy = jnp.sum(Y.astype(jnp.float32) ** 2, axis=-1)
    g = jnp.einsum("md,nd->mn", X, Y, preferred_element_type=jnp.float32)
    return jnp.maximum(xx[:, None] + yy[None, :] - 2.0 * g, 0.0)


def _euclidean_pairwise(X: Array, Y: Array) -> Array:
    return jnp.sqrt(_sqeuclidean_gram(X, Y))


def _cosine_pairwise(X: Array, Y: Array) -> Array:
    xn = jnp.sqrt(jnp.maximum(jnp.sum(X.astype(jnp.float32) ** 2, axis=-1), _EPS))
    yn = jnp.sqrt(jnp.maximum(jnp.sum(Y.astype(jnp.float32) ** 2, axis=-1), _EPS))
    cos = jnp.einsum("md,nd->mn", X, Y,
                     preferred_element_type=jnp.float32) / (xn[:, None] * yn[None, :])
    return 1.0 - jnp.clip(cos, -1.0, 1.0)


def _dot_pairwise(X: Array, Y: Array) -> Array:
    return -(X @ Y.T)


def _minkowski_pairwise(p: float):
    def pairwise(X: Array, Y: Array) -> Array:
        if p == 2.0:
            return _euclidean_pairwise(X, Y)
        return _minkowski_point(X[:, None, :], Y[None, :, :], p)

    return pairwise


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Distance:
    """A registered dissimilarity function."""

    name: str
    point: Callable[[Array, Array], Array]
    pairwise: Callable[[Array, Array], Array]
    gram_form: bool = False
    is_metric: bool = True
    needs_dim: Optional[int] = None
    # Upper bound of the distance range if bounded (used by radius estimation
    # and by masking: masked slots get `big = 10 * bound` or 1e30).
    bound: Optional[float] = None

    def __call__(self, X: Array, Y: Array) -> Array:
        return self.pairwise(X, Y)


_REGISTRY: dict[str, Distance] = {}


def _state_eq(a, b) -> bool:
    """Equality for bound state (partial args, closure cells) that never
    lies towards True: captured callables compare structurally (re-imports
    recreate them), array-valued or failing comparisons count as unequal."""
    if a is b:
        return True
    if callable(a) and callable(b):
        return _fns_match(a, b)
    try:
        return bool(a == b)
    except Exception:
        return False


def _fns_match(f, g) -> bool:
    """Structural callable identity: same code location and the same bound
    state — ``functools.partial`` arguments AND closure cell values (two
    factory-made closures from the same source line differ exactly in what
    they captured; a captured *function* recurses structurally)."""
    fb = gb = ()
    if isinstance(f, functools.partial):
        fb = f.args + tuple(sorted(f.keywords.items()))
        f = f.func
    if isinstance(g, functools.partial):
        gb = g.args + tuple(sorted(g.keywords.items()))
        g = g.func

    def _loc(fn):
        code = getattr(fn, "__code__", None)
        where = (code.co_filename, code.co_firstlineno) if code else None
        return (getattr(fn, "__module__", None),
                getattr(fn, "__qualname__", None), where)

    if _loc(f) != _loc(g):
        return False
    fc = tuple(c.cell_contents for c in (getattr(f, "__closure__", None) or ()))
    gc = tuple(c.cell_contents for c in (getattr(g, "__closure__", None) or ()))
    state_f, state_g = fb + fc, gb + gc
    return len(state_f) == len(state_g) and all(
        _state_eq(x, y) for x, y in zip(state_f, state_g)
    )


def _same_entry(a: Distance, b: Distance) -> bool:
    """Structural identity for re-registration: same name, same traits, and
    the point/pairwise callables match structurally (:func:`_fns_match`).
    Function *objects* differ across module re-imports (fresh notebook
    kernels, pytest ``--forked``), so object equality is the wrong test."""
    return (
        a.name == b.name
        and (a.gram_form, a.is_metric, a.needs_dim, a.bound)
        == (b.gram_form, b.is_metric, b.needs_dim, b.bound)
        and _fns_match(a.point, b.point)
        and _fns_match(a.pairwise, b.pairwise)
    )


def register(dist: Distance, *, overwrite: bool = False) -> Distance:
    """Register ``dist`` under its name.

    Re-registering a structurally identical entry is a no-op (module
    re-import safe); a *different* entry under an existing name raises
    unless ``overwrite=True`` replaces it explicitly.
    """
    prev = _REGISTRY.get(dist.name)
    if prev is not None and not overwrite:
        if _same_entry(prev, dist):
            return prev
        raise ValueError(
            f"distance {dist.name!r} already registered with a different "
            f"definition; pass overwrite=True to replace it"
        )
    _REGISTRY[dist.name] = dist
    return dist


def get(name_or_dist) -> Distance:
    if isinstance(name_or_dist, Distance):
        return name_or_dist
    try:
        return _REGISTRY[name_or_dist]
    except KeyError:
        raise KeyError(
            f"unknown distance {name_or_dist!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def names() -> list[str]:
    return sorted(_REGISTRY)


register(
    Distance(
        name="manhattan",
        point=functools.partial(_minkowski_point, p=1.0),
        pairwise=_minkowski_pairwise(1.0),
    )
)
register(
    Distance(
        name="euclidean",
        point=functools.partial(_minkowski_point, p=2.0),
        pairwise=_euclidean_pairwise,
        gram_form=True,
    )
)
register(
    Distance(
        name="chebyshev",
        point=functools.partial(_minkowski_point, p=jnp.inf),
        pairwise=_minkowski_pairwise(jnp.inf),
    )
)
register(
    Distance(
        name="fractional05",
        point=functools.partial(_minkowski_point, p=0.5),
        pairwise=_minkowski_pairwise(0.5),
        is_metric=False,
    )
)
register(
    Distance(
        name="cosine",
        point=_cosine_point,
        pairwise=_cosine_pairwise,
        gram_form=True,
        is_metric=False,
        bound=2.0,
    )
)
register(
    Distance(
        name="haversine",
        point=_haversine_point,
        pairwise=_broadcast_pairwise(_haversine_point),
        needs_dim=2,
        bound=float(jnp.pi),
    )
)
register(
    Distance(
        name="jaccard",
        point=_jaccard_point,
        pairwise=_broadcast_pairwise(_jaccard_point),
        is_metric=False,
        bound=1.0,
    )
)
register(
    Distance(
        name="dot",
        point=_dot_point,
        pairwise=_dot_pairwise,
        gram_form=True,
        is_metric=False,
    )
)


def minkowski(p: float) -> Distance:
    """Ad-hoc (unregistered) Minkowski distance for arbitrary ``p``."""
    return Distance(
        name=f"minkowski_{p}",
        point=functools.partial(_minkowski_point, p=p),
        pairwise=_minkowski_pairwise(p),
        is_metric=p >= 1.0,
    )


# ---------------------------------------------------------------------------
# Chunked pairwise — bounded peak memory for the non-Gram distances
# ---------------------------------------------------------------------------


def pairwise_chunked(
    dist, X: Array, Y: Array, *, chunk: int = 4096
) -> Array:
    """``dist.pairwise`` computed in bounded-memory chunks.

    The broadcast form of the non-Gram distances materialises ``[m, n, d]``;
    chunking streams it as ``[chunk, n, d]`` slabs (many rows) or
    ``[m, chunk, d]`` slabs (few rows against a large ``Y`` — the search-path
    shape, where a small query batch meets a big level). Gram-form distances
    never materialise the cube and are dispatched directly.
    """
    dist = get(dist)
    m, n = X.shape[0], Y.shape[0]
    if dist.gram_form or (m <= chunk and n <= chunk):
        return dist.pairwise(X, Y)
    from repro.kernels.ref import stream_cols, stream_rows  # lazy: acyclic

    if m > chunk:
        return stream_rows(
            lambda xc, Yf: pairwise_chunked(dist, xc, Yf, chunk=chunk), X, Y, chunk
        )
    return stream_cols(dist.pairwise, X, Y, chunk)


BIG = 1e30  # sentinel for masked / invalid slots; larger than any real distance


def mask_invalid(D: Array, row_valid: Array | None, col_valid: Array | None) -> Array:
    """Replace distances involving invalid (padding) points with ``BIG``."""
    if row_valid is not None:
        D = jnp.where(row_valid[:, None], D, BIG)
    if col_valid is not None:
        D = jnp.where(col_valid[None, :], D, BIG)
    return D
