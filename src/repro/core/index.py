"""PDASCIndex — the user-facing index API.

Wraps MSA build, the declarative query/plan search surface, radius
estimation, the tiered leaf store, the online mutability substrate and
save / load. This is the object the examples, benchmarks and the serving
engine hold.

    idx = PDASCIndex.build(data, gl=1000, distance="cosine")
    res = idx.search(queries, k=10, r=idx.default_radius)

    # the declarative surface (DESIGN.md §3.8): a Query says *what*, the
    # planner binds *how* — and plan.explain() shows the lowering
    from repro.query import Query
    plan = idx.plan(Query(k=10, beam=64))     # cached by (query, caps)
    res = plan(queries)                       # repeated calls: zero retraces
    print(plan.explain())

    # storage-aware serving: quantised payload tier + two-stage search
    idx = PDASCIndex.build(data, gl=1000, distance="cosine", store="int8")
    res = idx.plan(Query(execution="two_stage", rerank_width=128))(queries)
    idx.memory_bytes()   # per-tier (navigation vs payload) accounting

    # online mutability (DESIGN.md §3.7): delta-buffer upserts, tombstoned
    # deletes, epoch-swap compaction — the frozen hot path stays frozen
    ids = idx.upsert(new_vectors)        # visible to the next search
    idx.delete(ids[:3])                  # vanishes from every search mode
    idx = idx.compact()                  # new epoch: tiers folded back in

``search(..., mode="beam")`` remains as a back-compat shim over the plan
layer (an explicit ``mode=`` warns ``DeprecationWarning``); new code should
hold a ``Query`` and a plan.
"""

from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distances as dist_lib
from repro.core import msa, nsa, radius as radius_lib
from repro.core.distances import BIG
from repro.kernels import ops as kops
from repro.online import compact as compact_lib
from repro.online import delta as delta_lib
from repro.online import tombstones as tomb_lib
from repro.query import plan as query_plan
from repro.query import spec as query_spec
from repro.store import leaf_store as store_lib

Array = jax.Array

_FORMAT_VERSION = 2  # v2: tiered leaf store (payload codes + scales)
_MUTABLE_VERSION = 3  # v3: v2 + online tiers (delta buffer, tombstones)
_PACKED_VERSION = 4  # v4: packed payload codes (int4 / binary backends)
# v5: remote payload — the exact fp32 tier stays in the remote object store;
# the artifact carries a manifest referencing the granules instead of
# embedding level0_points (DESIGN.md §3.13).
_REMOTE_VERSION = 5
# v1 artifacts load with a dense fp32 payload; older versions load unchanged.
_SUPPORTED_VERSIONS = (1, 2, 3, 4, 5)

DEFAULT_DELTA_CAPACITY = 4096


def _validate_points(x, dist: dist_lib.Distance, *, what: str) -> np.ndarray:
    """Shape / dimensionality / finiteness validation shared by build and
    upsert: ``needs_dim`` distances (e.g. haversine, d == 2) reject wrong
    widths up front, and non-finite rows fail loudly instead of silently
    poisoning every distance they touch."""
    x = np.asarray(x, np.float32)
    if x.ndim != 2:
        raise ValueError(f"{what} input must be [n, d], got shape {x.shape}")
    if dist.needs_dim is not None and x.shape[1] != dist.needs_dim:
        raise ValueError(
            f"distance {dist.name!r} needs d={dist.needs_dim} inputs, got "
            f"d={x.shape[1]} at {what} time"
        )
    if not np.isfinite(x).all():
        bad = int((~np.isfinite(x).all(axis=1)).sum())
        raise ValueError(
            f"{what} input contains non-finite values ({bad} rows with "
            f"NaN/inf); clean the data before indexing"
        )
    return x


@dataclasses.dataclass
class PDASCIndex:
    data: msa.PDASCIndexData
    stats: msa.BuildStats
    distance: dist_lib.Distance
    gl: int
    n_prototypes: int
    max_children: tuple[int, ...]
    default_radius: float
    # Payload tier (DESIGN.md §3.6). None = the seed path: leaf vectors stay
    # a dense fp32 device array inside ``data.levels[0]``.
    store: Optional[store_lib.LeafStore] = None
    # Online tiers (DESIGN.md §3.7). None until the first upsert/delete (or
    # enable_mutations); compaction folds them back and resets them.
    delta: Optional[delta_lib.DeltaBuffer] = None
    tombstones: Optional[tomb_lib.TombstoneSet] = None
    epoch: int = 0
    _payload_released: bool = dataclasses.field(default=False, repr=False)
    # sorted (ids, slots) arrays for the id -> live-slot lookup (lazy)
    _id_slot: Optional[tuple] = dataclasses.field(default=None, repr=False)
    _next_id: Optional[int] = dataclasses.field(default=None, repr=False)
    # plan cache: (Query, capability fingerprint) -> SearchPlan (lazy; an
    # epoch swap produces a new index object and therefore a fresh cache)
    _plan_cache: Optional[dict] = dataclasses.field(default=None, repr=False)

    # -- construction --------------------------------------------------------

    @classmethod
    def build(
        cls,
        dataset,
        *,
        gl: int,
        n_prototypes: Optional[int] = None,
        distance="euclidean",
        method: str = "pam",
        max_swaps: int = 64,
        key: Optional[Array] = None,
        radius_quantile: float = 0.05,
        row_chunk: int = 512,
        group_chunk: int = 8,
        swap_tol: float = 1e-3,
        bg: int = 128,
        shuffle: bool = True,
        store: Optional[str] = None,
        store_block: int = 1024,
        store_path: Optional[str] = None,
    ) -> "PDASCIndex":
        """Build the index. ``store`` ("int8" / "fp16" / "fp32") additionally
        attaches the tiered payload store over the leaf vectors
        (:meth:`attach_store`); ``store_path`` puts the exact fp32 payload on
        disk (memmap) instead of host memory."""
        dist = dist_lib.get(distance)
        dataset = _validate_points(dataset, dist, what="build")
        k_protos = n_prototypes or gl // 2
        data, stats = msa.build_index(
            dataset,
            gl=gl,
            n_prototypes=k_protos,
            distance=dist,
            method=method,
            max_swaps=max_swaps,
            key=key,
            row_chunk=row_chunk,
            group_chunk=group_chunk,
            swap_tol=swap_tol,
            bg=bg,
            shuffle=shuffle,
        )
        default_r = radius_lib.estimate_radius(
            jnp.asarray(dataset, jnp.float32), dist, quantile=radius_quantile
        )
        idx = cls(
            data=data,
            stats=stats,
            distance=dist,
            gl=gl,
            n_prototypes=k_protos,
            max_children=msa.max_children(data),
            default_radius=default_r,
        )
        if store is not None:
            idx.attach_store(store, block=store_block, path=store_path)
        return idx

    @classmethod
    def build_streaming(cls, shards, **kwargs) -> "PDASCIndex":
        """Build shard-by-shard over a remote payload tier (DESIGN.md
        §3.13): consumes an iterator of ``[m, d]`` shards that never fit in
        memory together, clusters and quantises one shard at a time, and
        flushes the exact fp32 granules to ``remote=`` as it goes. Returns
        the released, two-stage-served form (``store.exact`` is a
        :class:`~repro.store.remote.RemoteSource`). See
        :func:`repro.store.streaming.build_streaming` for the knobs."""
        from repro.store import streaming as streaming_lib

        return streaming_lib.build_streaming(shards, **kwargs)

    def attach_store(
        self,
        backend: str = "int8",
        *,
        block: int = 1024,
        path: Optional[str] = None,
        cache_granules: int = 256,
    ) -> store_lib.LeafStore:
        """Create the payload tier from the leaf vectors (index slot layout).

        ``path`` backs the exact fp32 payload with an on-disk memmap fetched
        in ``block``-row granules; None keeps a host copy. Returns the store
        (also set on ``self.store``).
        """
        if self._payload_released:
            raise ValueError(
                "leaf payload already released; rebuild or load the index "
                "before attaching a new store"
            )
        self.store = store_lib.LeafStore.create(
            np.asarray(self.data.levels[0].points), backend,
            block=block, path=path, cache_granules=cache_granules,
        )
        return self.store

    def release_dense_payload(self) -> None:
        """Drop the resident fp32 leaf vectors (storage-aware serving).

        Requires a quantised store: the beam descent never touches leaf
        points and the leaf ranking moves to the store's scan -> rerank, so
        only ``mode="two_stage"`` remains servable. The leaf level keeps its
        row count (a ``[n_0, 0]`` placeholder) and bookkeeping arrays.
        """
        if self.store is None or self.store.backend == "fp32":
            raise ValueError(
                "release_dense_payload needs a quantised store "
                "(attach_store('int8'|'fp16'|'int4'|'binary') first)"
            )
        if self._payload_released:
            return
        leaf = self.data.levels[0]
        placeholder = jnp.zeros((leaf.points.shape[0], 0), jnp.float32)
        self.data = self.data._replace(
            levels=(leaf._replace(points=placeholder),) + self.data.levels[1:]
        )
        self._payload_released = True

    # -- online mutability (DESIGN.md §3.7) -----------------------------------

    def enable_mutations(
        self, *, delta_capacity: int = DEFAULT_DELTA_CAPACITY
    ) -> None:
        """Attach the online tiers (delta buffer + tombstones). Implicit on
        the first :meth:`upsert` / :meth:`delete`; call explicitly to pick
        the delta capacity. Mutation methods are not thread-safe against
        concurrent searches on the same object — the serving engine
        serialises writes between batches (``online.EpochHandle``)."""
        d = self._dim()
        if self.delta is None:
            self.delta = delta_lib.DeltaBuffer(delta_capacity, d)
        if self.tombstones is None:
            self.tombstones = tomb_lib.TombstoneSet(
                self.data.levels[0].points.shape[0]
            )

    def _dim(self) -> int:
        if self.store is not None:
            return self.store.d
        lv = self.data.levels
        return lv[-1].points.shape[1] if len(lv) > 1 else lv[0].points.shape[1]

    def _slots_for_ids(self, ids) -> np.ndarray:
        """Vectorized id -> leaf slot (-1 when not a live resident).

        The lazy lookup table is a pair of sorted arrays (ids, slots) —
        O(n log n) once, then O(m log n) per batch via ``searchsorted``;
        a Python dict at this size would cost ~100 bytes/entry and a
        multi-second build pause on multi-million-point indexes."""
        if self._id_slot is None:
            leaf_ids = np.asarray(self.data.leaf_ids)
            valid = np.asarray(self.data.levels[0].valid)
            live = valid & (leaf_ids >= 0)
            slots = np.nonzero(live)[0].astype(np.int64)
            keys = leaf_ids[live].astype(np.int64)
            order = np.argsort(keys)
            self._id_slot = (keys[order], slots[order])
        keys, slots = self._id_slot
        ids = np.asarray(ids, np.int64).reshape(-1)
        if keys.size == 0:
            return np.full(ids.shape, -1, np.int64)
        pos = np.clip(np.searchsorted(keys, ids), 0, keys.size - 1)
        return np.where(keys[pos] == ids, slots[pos], -1)

    def _route_to_leaf(
        self, V: np.ndarray, kernel: Optional[kops.KernelConfig] = None
    ) -> np.ndarray:
        """Nearest leaf slot per row via the jitted beam descent at beam=1
        (+ one fused k=1 rank) — the insert-time routing that tells
        compaction each arrival's destination group."""
        kernel = kernel or kops.DEFAULT
        Qb = jnp.asarray(V, jnp.float32)
        cand_idx, cand_ok = nsa.descend_beam(
            self.data, Qb, dist=self.distance, r=float("inf"), beam=1,
            max_children=self.max_children, kernel=kernel,
        )
        if not self._payload_released:
            leaf = self.data.levels[0]
            d, slot = kops.rank_gathered(
                Qb, leaf.points, leaf.sq_norm, cand_idx, cand_ok,
                self.distance, k=1, config=kernel,
            )
        else:  # payload released: route against the quantised codes
            d, slot = kops.scan_quantized(
                Qb, self.store.codes, self.store.scales, cand_idx, cand_ok,
                self.distance, k=1, block=self.store.block,
                code_format=self.store.code_format, config=kernel,
            )
        slots = np.asarray(jnp.take_along_axis(cand_idx, slot, axis=1)[:, 0])
        found = np.asarray(d[:, 0]) < BIG / 2
        return np.where(found, slots, 0).astype(np.int32)

    def upsert(self, vectors, ids=None, *,
               kernel: Optional[kops.KernelConfig] = None) -> np.ndarray:
        """Insert (or replace) points; visible to the very next search.

        ``ids``: optional int ids. Omitted -> fresh ids above every id the
        index has seen. An existing id is *replaced*: its old occurrence
        (resident slot or earlier delta entry) is tombstoned / deactivated
        and the new vector appended. Returns the assigned ids. Raises when
        the delta buffer cannot hold the batch — compact first (the serving
        handle does this automatically).
        """
        if self.delta is None:
            self.enable_mutations()
        V = np.atleast_2d(np.asarray(vectors, np.float32))
        V = _validate_points(V, self.distance, what="upsert")
        m = V.shape[0]
        if ids is None:
            ids = self._fresh_ids(m)
        ids = np.asarray(ids, np.int32).reshape(-1)
        if ids.shape[0] != m:
            raise ValueError(f"{m} vectors but {ids.shape[0]} ids")
        if np.unique(ids).shape[0] != m:
            raise ValueError("duplicate ids within one upsert batch")
        if self.delta.free < m:
            raise RuntimeError(
                f"delta buffer full ({self.delta.size}/{self.delta.capacity}"
                f" used, {m} requested); call compact() to fold it in"
            )
        # replace semantics: retire any older occurrence of these ids
        self.delta.deactivate_ids(ids)
        stale = self._slots_for_ids(ids)
        stale = stale[stale >= 0]
        if stale.size:
            self.tombstones.add(stale)
        slots = self._route_to_leaf(V, kernel)
        self.delta.append(V, ids, slots)
        self._bump_next_id(ids)
        return ids

    def delete(self, ids) -> int:
        """Delete by id: flips tombstone bits / deactivates delta entries —
        the index arrays stay frozen. Returns the number of live points
        removed (unknown ids are ignored, not an error)."""
        if self.delta is None:
            self.enable_mutations()
        ids = np.asarray(ids, np.int32).reshape(-1)
        n = self.delta.deactivate_ids(ids)
        slots = self._slots_for_ids(ids)
        slots = slots[slots >= 0]
        if slots.size:
            n += self.tombstones.add(slots)
        return n

    def _seen_id_ceiling(self) -> int:
        """One above every id this index has ever seen — including ids whose
        points were deleted or whose delta entries were deactivated, so a
        freed id is never re-issued (compaction and save/load carry this)."""
        if self._next_id is not None:
            return self._next_id
        hi = int(np.asarray(self.data.leaf_ids).max(initial=-1))
        if self.delta is not None and self.delta.size:
            hi = max(hi, int(self.delta.ids[: self.delta.size].max()))
        return hi + 1

    def _fresh_ids(self, m: int) -> np.ndarray:
        self._next_id = self._seen_id_ceiling()
        out = np.arange(self._next_id, self._next_id + m, dtype=np.int32)
        self._next_id += m
        return out

    def _bump_next_id(self, ids: np.ndarray) -> None:
        if self._next_id is not None and ids.size:
            self._next_id = max(self._next_id, int(ids.max()) + 1)

    def needs_compaction(
        self, *, delta_fill: float = 0.5, tombstone_ratio: float = 0.2
    ) -> bool:
        """Compaction trigger: delta append cursor past ``delta_fill`` of
        capacity, or tombstones past ``tombstone_ratio`` of the resident
        population."""
        if self.delta is not None and self.delta.fill_ratio() >= delta_fill:
            return True
        if self.tombstones is not None and self.tombstones.count:
            # resident count is frozen per epoch — stats.level_sizes[0]
            # (set at build / compaction / load) avoids an O(n) device
            # readback on every write batch
            return (self.tombstones.ratio(self.stats.level_sizes[0])
                    >= tombstone_ratio)
        return False

    def compact(self, *, scope: str = "affected", **kwargs) -> "PDASCIndex":
        """Fold the online tiers into a fresh epoch (``online.compact``).

        Never mutates ``self`` — returns a new index with ``epoch + 1``,
        empty tiers (same delta capacity) and a (partially) re-quantised
        payload store. Read-copy-update: keep serving the old epoch until
        the swap."""
        new = compact_lib.compact_index(self, scope=scope, **kwargs)
        new.enable_mutations(
            delta_capacity=self.delta.capacity
            if self.delta is not None else DEFAULT_DELTA_CAPACITY
        )
        return new

    # -- search (the declarative query/plan surface, DESIGN.md §3.8) ----------

    def plan(self, query=None, **overrides) -> "query_plan.SearchPlan":
        """Compile a :class:`repro.query.Query` into an executable
        :class:`~repro.query.plan.SearchPlan` bound to this index's current
        capabilities (store attached? payload released? online tiers
        dirty?). Plans are cached by ``(query, capability fingerprint)`` —
        an equal query on an unchanged index returns the same plan object,
        and repeated plan execution never retraces. Capability conflicts
        (e.g. ``execution="two_stage"`` without a store) raise ValueError
        here, at plan time.

        Accepts a ``Query``, keyword overrides on top of one, or bare
        keywords: ``idx.plan(k=5, execution="dense")``.
        """
        if query is None:
            query = query_spec.Query(**overrides)
        elif overrides:
            query = dataclasses.replace(query, **overrides)
        if self._plan_cache is None:
            self._plan_cache = {}
        caps = query_plan.capabilities(self)
        key = (query, caps)
        plan = self._plan_cache.get(key)
        if plan is not None:
            query_plan.record_cache_hit(plan.pipeline)
            return plan
        plan = query_plan.compile_plan(self, query)
        self._plan_cache[key] = plan
        return plan

    def search(
        self,
        queries,
        *,
        k: int = 10,
        r: Optional[float] = None,
        query: Optional["query_spec.Query"] = None,
        mode: Optional[str] = None,
        beam: int | tuple = 32,
        rerank_width: Optional[int] = 128,
        leaf_radius_filter: bool = False,
        kernel: Optional[kops.KernelConfig] = None,
    ) -> nsa.SearchResult:
        """k-ANN search — a thin build-plan-and-run wrapper over
        :meth:`plan`. Prefer holding a :class:`repro.query.Query` (and a
        plan) directly; this wrapper exists so ``idx.search(Q, k=10)`` stays
        a one-liner.

        ``query``: run an explicit Query spec (all other knobs ignored).
        ``mode``: **deprecated** back-compat shim for the pre-plan string
        dispatcher ("beam" / "dense" / "two_stage" / "beam_vmap") — still
        honoured, with a ``DeprecationWarning``; use
        ``Query(execution=...)`` instead. Omitted, the planner chooses from
        the index capabilities (the batched beam hot path, or two_stage
        once the dense payload was released).

        With online tiers attached (DESIGN.md §3.7) every pipeline threads
        the tombstone mask into its leaf ranking (deleted ids never appear)
        and merges the delta buffer's exact scan into the result.
        """
        if query is None:
            execution = "auto"
            if mode is not None:
                warnings.warn(
                    "PDASCIndex.search(mode=...) is deprecated; use "
                    "repro.query.Query(execution=...) with idx.plan() / "
                    "idx.search(query=...)",
                    DeprecationWarning,
                    stacklevel=2,
                )
                execution = mode
            query = query_spec.Query(
                k=k,
                radius=float(r) if r is not None else None,
                execution=execution,
                beam=beam,
                rerank_width=rerank_width,
                leaf_radius_filter=leaf_radius_filter,
                kernel=kernel,
            )
        return self.plan(query)(queries)

    def per_level_radii(self, *, quantile: float = 0.5) -> tuple[float, ...]:
        return radius_lib.per_level_radii(
            self.data, self.distance,
            base_radius=self.default_radius, quantile=quantile,
        )

    # -- stats ----------------------------------------------------------------

    @property
    def n_levels(self) -> int:
        return len(self.data.levels)

    @property
    def n_points(self) -> int:
        """Live point count: resident − tombstoned + active delta."""
        n = int(np.asarray(self.data.levels[0].valid).sum())
        if self.tombstones is not None:
            n -= self.tombstones.count
        if self.delta is not None:
            n += self.delta.n_active
        return n

    def memory_bytes(self) -> dict:
        """Per-tier resident-memory accounting (DESIGN.md §3.6/§3.7).

        ``navigation``: the prototype levels 1..L plus the leaf bookkeeping
        arrays (valid / parent / child / sq_norm / leaf_ids) — always
        device-resident. ``payload``: the leaf vectors' resident bytes — the
        dense fp32 array on the seed path, the quantised codes + scales once
        a store is attached (both until :meth:`release_dense_payload` drops
        the dense copy). ``out_of_core``: exact fp32 payload bytes living on
        host / disk (0 without a quantised store). ``delta`` /
        ``tombstones``: the online tiers (0 until mutations are enabled) —
        the delta is a fixed ``capacity x d`` fp32 buffer + bookkeeping, the
        tombstones 1 bit per leaf slot.

        Remote payload tiers (DESIGN.md §3.13) split the out-of-core story:
        ``remote_bytes`` is the exact payload living in the remote object
        store (grows with the dataset, resident nowhere on this node) and
        ``host_cache`` the decoded granules currently held by the bounded
        host LRU (counted into ``total_resident`` — it is real node
        memory). ``out_of_core`` keeps meaning local host/disk bytes, so it
        is 0 for a remote tier.
        """
        nav = 0
        for lv in self.data.levels[1:]:
            nav += sum(getattr(lv, f).nbytes for f in lv._fields)
        leaf = self.data.levels[0]
        nav += sum(getattr(leaf, f).nbytes for f in leaf._fields
                   if f != "points")
        nav += self.data.leaf_ids.nbytes
        payload = 0 if self._payload_released else int(leaf.points.nbytes)
        out_of_core = remote_b = host_cache = 0
        if self.store is not None and self.store.backend != "fp32":
            payload += self.store.resident_bytes
            exact = self.store.exact
            if getattr(exact, "remote", False):
                remote_b = exact.nbytes
            else:
                out_of_core = self.store.out_of_core_bytes
            if getattr(exact, "remote", False) or getattr(exact, "on_disk",
                                                          False):
                # cached granules are decoded copies of bytes that live
                # outside host RAM — real node memory; a host-array source's
                # cache holds views of the (already-counted) backing array
                host_cache = int(getattr(exact, "cache_resident_bytes", 0))
        delta_b = self.delta.nbytes if self.delta is not None else 0
        tomb_b = self.tombstones.nbytes if self.tombstones is not None else 0
        n = max(self.n_points, 1)
        total = nav + payload + host_cache + delta_b + tomb_b
        return dict(
            navigation=int(nav),
            payload=int(payload),
            out_of_core=int(out_of_core),
            remote_bytes=int(remote_b),
            host_cache=int(host_cache),
            delta=int(delta_b),
            tombstones=int(tomb_b),
            total_resident=int(total),
            payload_bytes_per_vector=round(payload / n, 2),
            total_bytes_per_vector=round(total / n, 2),
        )

    def describe(self) -> str:
        lines = [
            f"PDASCIndex(distance={self.distance.name}, gl={self.gl}, "
            f"nPrototypes={self.n_prototypes}, levels={self.n_levels}, "
            f"epoch={self.epoch})"
        ]
        for l, (size, td) in enumerate(
            zip(self.stats.level_sizes, self.stats.level_td)
        ):
            slots = self.data.levels[l].points.shape[0]
            lines.append(f"  level {l}: {size} valid / {slots} slots, TD={td:.4g}")
        if self.delta is not None or self.tombstones is not None:
            nd = self.delta.n_active if self.delta is not None else 0
            nt = self.tombstones.count if self.tombstones is not None else 0
            lines.append(f"  online: {nd} delta, {nt} tombstoned")
        return "\n".join(lines)

    # -- persistence ----------------------------------------------------------

    def save(self, path: str) -> None:
        """Atomic save: ``<path>.npz`` (arrays) + ``<path>.json`` (metadata).

        Format v2: a quantised store saves its codes / scales alongside the
        levels; the exact fp32 payload is always saved as ``level0_points``
        (restored from the out-of-core source if the dense copy was
        released), so every artifact reloads self-contained. Format v3
        (written only when online tiers are attached) additionally persists
        the delta buffer and the tombstone bitmap, so a loaded index resumes
        with the same live point set mid-epoch.

        Distances persist by *name*: ad-hoc ``Distance`` objects (e.g.
        ``distances.minkowski(p)``) must be registered first or save()
        refuses — a clear error now beats a pickle surprise at load time.

        Note the residency consequence: saving streams the whole exact
        payload through host memory, and a loaded index starts with the
        dense fp32 leaf array resident again. To resume out-of-core serving
        after a load, re-attach a memmapped store and release:
        ``idx.attach_store("int8", path=...); idx.release_dense_payload()``.

        Format v5 (remote payload tier, DESIGN.md §3.13) is the exception
        to self-containment: the exact payload stays in the remote object
        store and only its *manifest* is persisted — the artifact holds
        navigation + quantised codes and reloads in served (released) form.
        """
        try:
            registered = dist_lib.get(self.distance.name)
        except KeyError:
            registered = None
        if registered is None:
            raise ValueError(
                f"distance {self.distance.name!r} is not in the registry; "
                f"save() persists distances by name only. Register it first "
                f"(repro.core.distances.register) — ad-hoc instances like "
                f"distances.minkowski(p) cannot round-trip otherwise."
            )
        if registered is not self.distance and not dist_lib._same_entry(
            registered, self.distance
        ):
            # name collision: load() would silently bind the registry's
            # entry, changing distance semantics — refuse up front
            raise ValueError(
                f"this index's distance {self.distance.name!r} differs from "
                f"the registry entry of the same name; save() would "
                f"round-trip to the registered one. Register the index's "
                f"distance under a distinct name (or overwrite=True) first."
            )
        arrays = {"leaf_ids": np.asarray(self.data.leaf_ids)}
        for l, lv in enumerate(self.data.levels):
            for field in lv._fields:
                arrays[f"level{l}_{field}"] = np.asarray(getattr(lv, field))
        store_meta = None
        remote_exact = (
            self.store is not None
            and getattr(self.store.exact, "remote", False)
        )
        if self.store is not None:
            if self._payload_released and not remote_exact:
                arrays["level0_points"] = self.store.exact.read_all()
            store_meta = dict(backend=self.store.backend,
                              block=self.store.block)
            if remote_exact:
                # v5: the exact payload stays remote — persist the manifest,
                # not the bytes (the artifact is navigation + codes only)
                store_meta["remote"] = self.store.exact.manifest()
            if self.store.backend != "fp32":
                arrays["store_codes"] = np.asarray(self.store.codes)
                arrays["store_scales"] = np.asarray(self.store.scales)
        mutable_meta = None
        version = _FORMAT_VERSION
        if self.delta is not None or self.tombstones is not None:
            version = _MUTABLE_VERSION
            delta = self.delta
            mutable_meta = dict(
                delta_capacity=delta.capacity if delta is not None else
                DEFAULT_DELTA_CAPACITY,
                delta_size=delta.size if delta is not None else 0,
                next_id=self._seen_id_ceiling(),
            )
            if delta is not None:
                arrays["delta_vectors"] = delta.vectors[: delta.size]
                arrays["delta_ids"] = delta.ids[: delta.size]
                arrays["delta_slots"] = delta.leaf_slot[: delta.size]
                arrays["delta_active"] = delta.active[: delta.size]
            if self.tombstones is not None:
                arrays["tombstone_bits"] = self.tombstones.bits
        if store_meta is not None and store_meta["backend"] in (
            "int4", "binary",
        ):
            # packed containers ([n, ceil(d/2)] int8 / [n, ceil(d/8)] uint8)
            # are unreadable by pre-v4 builds, which expect dc == d
            version = _PACKED_VERSION
        if remote_exact:
            # remote manifest + missing level0_points: pre-v5 builds cannot
            # reconstruct the exact tier at all
            version = _REMOTE_VERSION
        meta = dict(
            version=version,
            distance=self.distance.name,
            gl=self.gl,
            n_prototypes=self.n_prototypes,
            n_levels=self.n_levels,
            max_children=list(self.max_children),
            default_radius=self.default_radius,
            level_sizes=list(self.stats.level_sizes),
            level_td=list(self.stats.level_td),
            store=store_meta,
            epoch=self.epoch,
            mutable=mutable_meta,
        )
        d = os.path.dirname(os.path.abspath(path)) or "."
        os.makedirs(d, exist_ok=True)
        with tempfile.NamedTemporaryFile(dir=d, suffix=".npz", delete=False) as f:
            np.savez_compressed(f, **arrays)
            tmp = f.name
        os.replace(tmp, path + ".npz")
        with tempfile.NamedTemporaryFile(
            "w", dir=d, suffix=".json", delete=False
        ) as f:
            json.dump(meta, f)
            tmp = f.name
        os.replace(tmp, path + ".json")

    @classmethod
    def load(cls, path: str, *, remote=None, cache_granules: int = 256,
             prefetch_workers: int = 2) -> "PDASCIndex":
        """Load a saved index.

        ``remote`` (v5 artifacts only): a live
        :class:`~repro.store.remote.RemoteStore` holding the exact payload
        granules the artifact's manifest describes. When omitted, the store
        is reopened from the manifest itself (``store.remote.open_store``) —
        which works for ``localfs`` manifests and raises for simulated /
        non-reopenable kinds. ``cache_granules`` / ``prefetch_workers``
        size the host LRU + prefetch pool in front of the remote tier.
        """
        with open(path + ".json") as f:
            meta = json.load(f)
        version = meta.get("version")
        if version not in _SUPPORTED_VERSIONS:
            raise ValueError(
                f"unsupported index format version {version!r} in "
                f"{path + '.json'}; this build reads versions "
                f"{_SUPPORTED_VERSIONS} (1 = dense fp32 payload, 2 = tiered "
                f"leaf store, 3 = + online tiers, 4 = packed int4/binary "
                f"payload codes, 5 = remote payload manifest)"
            )
        z = np.load(path + ".npz")
        levels = []
        for l in range(meta["n_levels"]):
            fields = {
                f: jnp.asarray(z[f"level{l}_{f}"])
                for f in msa.PDASCLevel._fields
                if f"level{l}_{f}" in z
            }
            if "sq_norm" not in fields:  # index saved before the norm cache
                pts = fields["points"]
                fields["sq_norm"] = jnp.sum(pts * pts, axis=-1)
            levels.append(msa.PDASCLevel(**fields))
        data = msa.PDASCIndexData(
            levels=tuple(levels), leaf_ids=jnp.asarray(z["leaf_ids"])
        )
        stats = msa.BuildStats(
            level_sizes=tuple(meta["level_sizes"]),
            level_td=tuple(meta["level_td"]),
            n_levels=meta["n_levels"],
        )
        idx = cls(
            data=data,
            stats=stats,
            distance=dist_lib.get(meta["distance"]),
            gl=meta["gl"],
            n_prototypes=meta["n_prototypes"],
            max_children=tuple(meta["max_children"]),
            default_radius=meta["default_radius"],
            epoch=int(meta.get("epoch", 0)),
        )
        # v1 artifacts carry no store: the payload tier defaults to the
        # dense fp32 leaf array already loaded above.
        store_meta = meta.get("store")
        if store_meta is not None:
            manifest = store_meta.get("remote")
            if manifest is not None:
                # v5: reconstruct the remote tier from the manifest — the
                # exact payload was never in the artifact. Loads straight
                # into served (released) form.
                from repro.store import remote as remote_lib

                store = remote if remote is not None else \
                    remote_lib.open_store(manifest)
                exact = remote_lib.RemoteSource(
                    store,
                    n=int(manifest["n"]), d=int(manifest["d"]),
                    block=int(manifest["block"]),
                    prefix=manifest.get("prefix", ""),
                    cache_granules=cache_granules,
                    prefetch_workers=prefetch_workers,
                )
                idx._payload_released = True
            else:
                exact = store_lib.ExactSource(
                    np.asarray(z["level0_points"], np.float32),
                    store_meta["block"],
                )
            codes = scales = None
            if store_meta["backend"] != "fp32":
                codes = jnp.asarray(z["store_codes"])
                scales = jnp.asarray(z["store_scales"])
            idx.store = store_lib.LeafStore(
                backend=store_meta["backend"], block=store_meta["block"],
                codes=codes, scales=scales, exact=exact,
            )
        mut = meta.get("mutable")
        if mut is not None:
            size = int(mut["delta_size"])
            delta = delta_lib.DeltaBuffer(int(mut["delta_capacity"]),
                                          idx._dim())
            if size:
                delta.vectors[:size] = np.asarray(z["delta_vectors"])
                delta.ids[:size] = np.asarray(z["delta_ids"])
                delta.leaf_slot[:size] = np.asarray(z["delta_slots"])
                delta.active[:size] = np.asarray(z["delta_active"])
                delta.size = size
            idx.delta = delta
            if mut.get("next_id") is not None:
                idx._next_id = int(mut["next_id"])
            if "tombstone_bits" in z:
                idx.tombstones = tomb_lib.TombstoneSet(
                    data.levels[0].points.shape[0],
                    bits=np.asarray(z["tombstone_bits"]),
                )
            else:
                idx.tombstones = tomb_lib.TombstoneSet(
                    data.levels[0].points.shape[0]
                )
        return idx
