"""PDASCIndex — the user-facing index API.

Wraps MSA build, NSA search (dense / beam / two-stage), radius estimation,
the tiered leaf store and save / load. This is the object the examples,
benchmarks and the serving engine hold.

    idx = PDASCIndex.build(data, gl=1000, distance="cosine")
    res = idx.search(queries, k=10, r=idx.default_radius)

    # storage-aware serving: quantised payload tier + two-stage search
    idx = PDASCIndex.build(data, gl=1000, distance="cosine", store="int8")
    res = idx.search(queries, k=10, mode="two_stage", rerank_width=128)
    idx.memory_bytes()   # per-tier (navigation vs payload) accounting
"""

from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distances as dist_lib
from repro.core import msa, nsa, radius as radius_lib
from repro.kernels import ops as kops
from repro.store import leaf_store as store_lib
from repro.store import two_stage as two_stage_lib

Array = jax.Array

_FORMAT_VERSION = 2  # v2: tiered leaf store (payload codes + scales)
_SUPPORTED_VERSIONS = (1, 2)  # v1 artifacts load with a dense fp32 payload


@dataclasses.dataclass
class PDASCIndex:
    data: msa.PDASCIndexData
    stats: msa.BuildStats
    distance: dist_lib.Distance
    gl: int
    n_prototypes: int
    max_children: tuple[int, ...]
    default_radius: float
    # Payload tier (DESIGN.md §3.6). None = the seed path: leaf vectors stay
    # a dense fp32 device array inside ``data.levels[0]``.
    store: Optional[store_lib.LeafStore] = None
    _payload_released: bool = dataclasses.field(default=False, repr=False)

    # -- construction --------------------------------------------------------

    @classmethod
    def build(
        cls,
        dataset,
        *,
        gl: int,
        n_prototypes: Optional[int] = None,
        distance="euclidean",
        method: str = "pam",
        max_swaps: int = 64,
        key: Optional[Array] = None,
        radius_quantile: float = 0.05,
        row_chunk: int = 512,
        group_chunk: int = 8,
        swap_tol: float = 1e-3,
        bg: int = 128,
        shuffle: bool = True,
        store: Optional[str] = None,
        store_block: int = 1024,
        store_path: Optional[str] = None,
    ) -> "PDASCIndex":
        """Build the index. ``store`` ("int8" / "fp16" / "fp32") additionally
        attaches the tiered payload store over the leaf vectors
        (:meth:`attach_store`); ``store_path`` puts the exact fp32 payload on
        disk (memmap) instead of host memory."""
        dist = dist_lib.get(distance)
        k_protos = n_prototypes or gl // 2
        data, stats = msa.build_index(
            dataset,
            gl=gl,
            n_prototypes=k_protos,
            distance=dist,
            method=method,
            max_swaps=max_swaps,
            key=key,
            row_chunk=row_chunk,
            group_chunk=group_chunk,
            swap_tol=swap_tol,
            bg=bg,
            shuffle=shuffle,
        )
        default_r = radius_lib.estimate_radius(
            jnp.asarray(dataset, jnp.float32), dist, quantile=radius_quantile
        )
        idx = cls(
            data=data,
            stats=stats,
            distance=dist,
            gl=gl,
            n_prototypes=k_protos,
            max_children=msa.max_children(data),
            default_radius=default_r,
        )
        if store is not None:
            idx.attach_store(store, block=store_block, path=store_path)
        return idx

    def attach_store(
        self,
        backend: str = "int8",
        *,
        block: int = 1024,
        path: Optional[str] = None,
        cache_granules: int = 256,
    ) -> store_lib.LeafStore:
        """Create the payload tier from the leaf vectors (index slot layout).

        ``path`` backs the exact fp32 payload with an on-disk memmap fetched
        in ``block``-row granules; None keeps a host copy. Returns the store
        (also set on ``self.store``).
        """
        if self._payload_released:
            raise ValueError(
                "leaf payload already released; rebuild or load the index "
                "before attaching a new store"
            )
        self.store = store_lib.LeafStore.create(
            np.asarray(self.data.levels[0].points), backend,
            block=block, path=path, cache_granules=cache_granules,
        )
        return self.store

    def release_dense_payload(self) -> None:
        """Drop the resident fp32 leaf vectors (storage-aware serving).

        Requires a quantised store: the beam descent never touches leaf
        points and the leaf ranking moves to the store's scan -> rerank, so
        only ``mode="two_stage"`` remains servable. The leaf level keeps its
        row count (a ``[n_0, 0]`` placeholder) and bookkeeping arrays.
        """
        if self.store is None or self.store.backend == "fp32":
            raise ValueError(
                "release_dense_payload needs a quantised store "
                "(attach_store('int8'|'fp16') first)"
            )
        if self._payload_released:
            return
        leaf = self.data.levels[0]
        placeholder = jnp.zeros((leaf.points.shape[0], 0), jnp.float32)
        self.data = self.data._replace(
            levels=(leaf._replace(points=placeholder),) + self.data.levels[1:]
        )
        self._payload_released = True

    # -- search ---------------------------------------------------------------

    def search(
        self,
        queries,
        *,
        k: int = 10,
        r: Optional[float] = None,
        mode: str = "beam",
        beam: int | tuple = 32,
        rerank_width: Optional[int] = 128,
        leaf_radius_filter: bool = False,
        kernel: Optional[kops.KernelConfig] = None,
    ) -> nsa.SearchResult:
        """k-ANN search. ``mode``: "beam" (batched, pruned), "dense"
        (faithful), "two_stage" (tiered store: quantised scan -> exact
        rerank over the top-``rerank_width``; None = ∞, bit-identical to
        "beam") or "beam_vmap" (the seed per-query baseline, kept for
        benchmarking). ``kernel`` carries the kernel-layer block knobs."""
        Q = jnp.asarray(queries, jnp.float32)
        r = float(r) if r is not None else self.default_radius
        if mode == "two_stage":
            if self.store is None:
                raise ValueError(
                    "mode='two_stage' needs a leaf store: build with "
                    "store='int8' or call attach_store()"
                )
            return two_stage_lib.search_two_stage(
                self.data,
                self.store,
                Q,
                dist=self.distance,
                k=k,
                r=r,
                beam=beam,
                max_children=self.max_children,
                rerank_width=rerank_width,
                leaf_radius_filter=leaf_radius_filter,
                kernel=kernel,
            )
        if self._payload_released:
            raise ValueError(
                f"mode={mode!r} needs the dense leaf payload, which was "
                "released (release_dense_payload); use mode='two_stage'"
            )
        if mode == "dense":
            return nsa.search_dense(
                self.data,
                Q,
                dist=self.distance,
                k=k,
                r=r,
                leaf_radius_filter=leaf_radius_filter,
                kernel=kernel,
            )
        if mode == "beam":
            return nsa.search_beam(
                self.data,
                Q,
                dist=self.distance,
                k=k,
                r=r,
                beam=beam,
                max_children=self.max_children,
                leaf_radius_filter=leaf_radius_filter,
                kernel=kernel,
            )
        if mode == "beam_vmap":
            return nsa.search_beam_vmap(
                self.data,
                Q,
                dist=self.distance,
                k=k,
                r=r,
                beam=beam,
                max_children=self.max_children,
                leaf_radius_filter=leaf_radius_filter,
            )
        raise ValueError(f"unknown search mode {mode!r}")

    def per_level_radii(self, *, quantile: float = 0.5) -> tuple[float, ...]:
        return radius_lib.per_level_radii(
            self.data, self.distance,
            base_radius=self.default_radius, quantile=quantile,
        )

    # -- stats ----------------------------------------------------------------

    @property
    def n_levels(self) -> int:
        return len(self.data.levels)

    @property
    def n_points(self) -> int:
        return int(np.asarray(self.data.levels[0].valid).sum())

    def memory_bytes(self) -> dict:
        """Per-tier resident-memory accounting (DESIGN.md §3.6).

        ``navigation``: the prototype levels 1..L plus the leaf bookkeeping
        arrays (valid / parent / child / sq_norm / leaf_ids) — always
        device-resident. ``payload``: the leaf vectors' resident bytes — the
        dense fp32 array on the seed path, the quantised codes + scales once
        a store is attached (both until :meth:`release_dense_payload` drops
        the dense copy). ``out_of_core``: exact fp32 payload bytes living on
        host / disk (0 without a quantised store).
        """
        nav = 0
        for lv in self.data.levels[1:]:
            nav += sum(getattr(lv, f).nbytes for f in lv._fields)
        leaf = self.data.levels[0]
        nav += sum(getattr(leaf, f).nbytes for f in leaf._fields
                   if f != "points")
        nav += self.data.leaf_ids.nbytes
        payload = 0 if self._payload_released else int(leaf.points.nbytes)
        out_of_core = 0
        if self.store is not None and self.store.backend != "fp32":
            payload += self.store.resident_bytes
            out_of_core = self.store.out_of_core_bytes
        n = max(self.n_points, 1)
        return dict(
            navigation=int(nav),
            payload=int(payload),
            out_of_core=int(out_of_core),
            total_resident=int(nav + payload),
            payload_bytes_per_vector=round(payload / n, 2),
            total_bytes_per_vector=round((nav + payload) / n, 2),
        )

    def describe(self) -> str:
        lines = [
            f"PDASCIndex(distance={self.distance.name}, gl={self.gl}, "
            f"nPrototypes={self.n_prototypes}, levels={self.n_levels})"
        ]
        for l, (size, td) in enumerate(
            zip(self.stats.level_sizes, self.stats.level_td)
        ):
            slots = self.data.levels[l].points.shape[0]
            lines.append(f"  level {l}: {size} valid / {slots} slots, TD={td:.4g}")
        return "\n".join(lines)

    # -- persistence ----------------------------------------------------------

    def save(self, path: str) -> None:
        """Atomic save: ``<path>.npz`` (arrays) + ``<path>.json`` (metadata).

        Format v2: a quantised store saves its codes / scales alongside the
        levels; the exact fp32 payload is always saved as ``level0_points``
        (restored from the out-of-core source if the dense copy was
        released), so every artifact reloads self-contained.

        Note the residency consequence: saving streams the whole exact
        payload through host memory, and a loaded index starts with the
        dense fp32 leaf array resident again. To resume out-of-core serving
        after a load, re-attach a memmapped store and release:
        ``idx.attach_store("int8", path=...); idx.release_dense_payload()``.
        """
        arrays = {"leaf_ids": np.asarray(self.data.leaf_ids)}
        for l, lv in enumerate(self.data.levels):
            for field in lv._fields:
                arrays[f"level{l}_{field}"] = np.asarray(getattr(lv, field))
        store_meta = None
        if self.store is not None:
            if self._payload_released:
                arrays["level0_points"] = self.store.exact.read_all()
            store_meta = dict(backend=self.store.backend,
                              block=self.store.block)
            if self.store.backend != "fp32":
                arrays["store_codes"] = np.asarray(self.store.codes)
                arrays["store_scales"] = np.asarray(self.store.scales)
        meta = dict(
            version=_FORMAT_VERSION,
            distance=self.distance.name,
            gl=self.gl,
            n_prototypes=self.n_prototypes,
            n_levels=self.n_levels,
            max_children=list(self.max_children),
            default_radius=self.default_radius,
            level_sizes=list(self.stats.level_sizes),
            level_td=list(self.stats.level_td),
            store=store_meta,
        )
        d = os.path.dirname(os.path.abspath(path)) or "."
        os.makedirs(d, exist_ok=True)
        with tempfile.NamedTemporaryFile(dir=d, suffix=".npz", delete=False) as f:
            np.savez_compressed(f, **arrays)
            tmp = f.name
        os.replace(tmp, path + ".npz")
        with tempfile.NamedTemporaryFile(
            "w", dir=d, suffix=".json", delete=False
        ) as f:
            json.dump(meta, f)
            tmp = f.name
        os.replace(tmp, path + ".json")

    @classmethod
    def load(cls, path: str) -> "PDASCIndex":
        with open(path + ".json") as f:
            meta = json.load(f)
        version = meta.get("version")
        if version not in _SUPPORTED_VERSIONS:
            raise ValueError(
                f"unsupported index format version {version!r} in "
                f"{path + '.json'}; this build reads versions "
                f"{_SUPPORTED_VERSIONS} (1 = dense fp32 payload, 2 = tiered "
                f"leaf store)"
            )
        z = np.load(path + ".npz")
        levels = []
        for l in range(meta["n_levels"]):
            fields = {
                f: jnp.asarray(z[f"level{l}_{f}"])
                for f in msa.PDASCLevel._fields
                if f"level{l}_{f}" in z
            }
            if "sq_norm" not in fields:  # index saved before the norm cache
                pts = fields["points"]
                fields["sq_norm"] = jnp.sum(pts * pts, axis=-1)
            levels.append(msa.PDASCLevel(**fields))
        data = msa.PDASCIndexData(
            levels=tuple(levels), leaf_ids=jnp.asarray(z["leaf_ids"])
        )
        stats = msa.BuildStats(
            level_sizes=tuple(meta["level_sizes"]),
            level_td=tuple(meta["level_td"]),
            n_levels=meta["n_levels"],
        )
        idx = cls(
            data=data,
            stats=stats,
            distance=dist_lib.get(meta["distance"]),
            gl=meta["gl"],
            n_prototypes=meta["n_prototypes"],
            max_children=tuple(meta["max_children"]),
            default_radius=meta["default_radius"],
        )
        # v1 artifacts carry no store: the payload tier defaults to the
        # dense fp32 leaf array already loaded above.
        store_meta = meta.get("store")
        if store_meta is not None:
            exact = store_lib.ExactSource(
                np.asarray(z["level0_points"], np.float32),
                store_meta["block"],
            )
            codes = scales = None
            if store_meta["backend"] != "fp32":
                codes = jnp.asarray(z["store_codes"])
                scales = jnp.asarray(z["store_scales"])
            idx.store = store_lib.LeafStore(
                backend=store_meta["backend"], block=store_meta["block"],
                codes=codes, scales=scales, exact=exact,
            )
        return idx
