"""PDASCIndex — the user-facing index API.

Wraps MSA build, NSA search (dense / beam), radius estimation and
save / load. This is the object the examples, benchmarks and the serving
engine hold.

    idx = PDASCIndex.build(data, gl=1000, distance="cosine")
    res = idx.search(queries, k=10, r=idx.default_radius)
"""

from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distances as dist_lib
from repro.core import msa, nsa, radius as radius_lib
from repro.kernels import ops as kops

Array = jax.Array

_FORMAT_VERSION = 1


@dataclasses.dataclass
class PDASCIndex:
    data: msa.PDASCIndexData
    stats: msa.BuildStats
    distance: dist_lib.Distance
    gl: int
    n_prototypes: int
    max_children: tuple[int, ...]
    default_radius: float

    # -- construction --------------------------------------------------------

    @classmethod
    def build(
        cls,
        dataset,
        *,
        gl: int,
        n_prototypes: Optional[int] = None,
        distance="euclidean",
        method: str = "pam",
        max_swaps: int = 64,
        key: Optional[Array] = None,
        radius_quantile: float = 0.05,
        row_chunk: int = 512,
        group_chunk: int = 8,
        swap_tol: float = 1e-3,
        bg: int = 128,
        shuffle: bool = True,
    ) -> "PDASCIndex":
        dist = dist_lib.get(distance)
        k_protos = n_prototypes or gl // 2
        data, stats = msa.build_index(
            dataset,
            gl=gl,
            n_prototypes=k_protos,
            distance=dist,
            method=method,
            max_swaps=max_swaps,
            key=key,
            row_chunk=row_chunk,
            group_chunk=group_chunk,
            swap_tol=swap_tol,
            bg=bg,
            shuffle=shuffle,
        )
        default_r = radius_lib.estimate_radius(
            jnp.asarray(dataset, jnp.float32), dist, quantile=radius_quantile
        )
        return cls(
            data=data,
            stats=stats,
            distance=dist,
            gl=gl,
            n_prototypes=k_protos,
            max_children=msa.max_children(data),
            default_radius=default_r,
        )

    # -- search ---------------------------------------------------------------

    def search(
        self,
        queries,
        *,
        k: int = 10,
        r: Optional[float] = None,
        mode: str = "beam",
        beam: int | tuple = 32,
        leaf_radius_filter: bool = False,
        kernel: Optional[kops.KernelConfig] = None,
    ) -> nsa.SearchResult:
        """k-ANN search. ``mode``: "beam" (batched, pruned), "dense"
        (faithful) or "beam_vmap" (the seed per-query baseline, kept for
        benchmarking). ``kernel`` carries the kernel-layer block knobs."""
        Q = jnp.asarray(queries, jnp.float32)
        r = float(r) if r is not None else self.default_radius
        if mode == "dense":
            return nsa.search_dense(
                self.data,
                Q,
                dist=self.distance,
                k=k,
                r=r,
                leaf_radius_filter=leaf_radius_filter,
                kernel=kernel,
            )
        if mode == "beam":
            return nsa.search_beam(
                self.data,
                Q,
                dist=self.distance,
                k=k,
                r=r,
                beam=beam,
                max_children=self.max_children,
                leaf_radius_filter=leaf_radius_filter,
                kernel=kernel,
            )
        if mode == "beam_vmap":
            return nsa.search_beam_vmap(
                self.data,
                Q,
                dist=self.distance,
                k=k,
                r=r,
                beam=beam,
                max_children=self.max_children,
                leaf_radius_filter=leaf_radius_filter,
            )
        raise ValueError(f"unknown search mode {mode!r}")

    def per_level_radii(self, *, quantile: float = 0.5) -> tuple[float, ...]:
        return radius_lib.per_level_radii(
            self.data, self.distance,
            base_radius=self.default_radius, quantile=quantile,
        )

    # -- stats ----------------------------------------------------------------

    @property
    def n_levels(self) -> int:
        return len(self.data.levels)

    @property
    def n_points(self) -> int:
        return int(np.asarray(self.data.levels[0].valid).sum())

    def describe(self) -> str:
        lines = [
            f"PDASCIndex(distance={self.distance.name}, gl={self.gl}, "
            f"nPrototypes={self.n_prototypes}, levels={self.n_levels})"
        ]
        for l, (size, td) in enumerate(
            zip(self.stats.level_sizes, self.stats.level_td)
        ):
            slots = self.data.levels[l].points.shape[0]
            lines.append(f"  level {l}: {size} valid / {slots} slots, TD={td:.4g}")
        return "\n".join(lines)

    # -- persistence ----------------------------------------------------------

    def save(self, path: str) -> None:
        """Atomic save: ``<path>.npz`` (arrays) + ``<path>.json`` (metadata)."""
        arrays = {"leaf_ids": np.asarray(self.data.leaf_ids)}
        for l, lv in enumerate(self.data.levels):
            for field in lv._fields:
                arrays[f"level{l}_{field}"] = np.asarray(getattr(lv, field))
        meta = dict(
            version=_FORMAT_VERSION,
            distance=self.distance.name,
            gl=self.gl,
            n_prototypes=self.n_prototypes,
            n_levels=self.n_levels,
            max_children=list(self.max_children),
            default_radius=self.default_radius,
            level_sizes=list(self.stats.level_sizes),
            level_td=list(self.stats.level_td),
        )
        d = os.path.dirname(os.path.abspath(path)) or "."
        os.makedirs(d, exist_ok=True)
        with tempfile.NamedTemporaryFile(dir=d, suffix=".npz", delete=False) as f:
            np.savez_compressed(f, **arrays)
            tmp = f.name
        os.replace(tmp, path + ".npz")
        with tempfile.NamedTemporaryFile(
            "w", dir=d, suffix=".json", delete=False
        ) as f:
            json.dump(meta, f)
            tmp = f.name
        os.replace(tmp, path + ".json")

    @classmethod
    def load(cls, path: str) -> "PDASCIndex":
        with open(path + ".json") as f:
            meta = json.load(f)
        if meta["version"] != _FORMAT_VERSION:
            raise ValueError(f"unsupported index version {meta['version']}")
        z = np.load(path + ".npz")
        levels = []
        for l in range(meta["n_levels"]):
            fields = {
                f: jnp.asarray(z[f"level{l}_{f}"])
                for f in msa.PDASCLevel._fields
                if f"level{l}_{f}" in z
            }
            if "sq_norm" not in fields:  # index saved before the norm cache
                pts = fields["points"]
                fields["sq_norm"] = jnp.sum(pts * pts, axis=-1)
            levels.append(msa.PDASCLevel(**fields))
        data = msa.PDASCIndexData(
            levels=tuple(levels), leaf_ids=jnp.asarray(z["leaf_ids"])
        )
        stats = msa.BuildStats(
            level_sizes=tuple(meta["level_sizes"]),
            level_td=tuple(meta["level_td"]),
            n_levels=meta["n_levels"],
        )
        return cls(
            data=data,
            stats=stats,
            distance=dist_lib.get(meta["distance"]),
            gl=meta["gl"],
            n_prototypes=meta["n_prototypes"],
            max_children=tuple(meta["max_children"]),
            default_radius=meta["default_radius"],
        )
