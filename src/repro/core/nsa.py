"""NSA — Neighbours Search Algorithm (paper Algorithm 2), in JAX.

Two execution modes over the same :class:`~repro.core.msa.PDASCIndexData`,
both dispatching every distance evaluation and ranking step through the
kernel layer (``repro.kernels.ops`` — DESIGN.md §3.3):

``search_dense``
    Faithful masked translation of Algorithm 2. The per-level candidate set
    becomes a boolean mask over the whole level:

        active[L]   = valid & (d(q, p) < r)                    (top level)
        active[l]   = active[l+1][parent] & valid & (d < r)    (inner levels)
        candidates  = active[1][parent_0] & valid              (leaf level)

    Note the leaf level is *not* radius-filtered by default — Algorithm 2
    returns ``levelPoints[0][idCandidates]`` without re-checking ``r``
    (``leaf_radius_filter`` exposes the stricter variant). Finally candidates
    are ranked by distance and the k nearest returned. Semantically identical
    to the paper's recursion (tests check this against a literal Python port),
    but every leaf distance is *computed* then masked — the TPU-idiomatic
    form, used for validation and small indexes. Per level it costs one
    ``ops.pairwise_distance`` call (MXU Gram matmul / tiled VPU kernel on
    TPU; streamed reference on CPU) — never an ``[B, n, d]`` broadcast cube.

``search_beam``
    The TPU-native pruned search (DESIGN.md §3.2), *batched over the query
    axis*: per level the whole batch performs one ``[B, W]`` candidate gather
    and one fused ``ops.rank_candidates`` call (gather -> distance -> top-k
    streamed through VMEM), which yields the per-query beam directly; only
    the sibling-contiguous child blocks of the beam survive to the next
    level — static shapes, real FLOP pruning, no per-query vmap.
    ``beam >= level size`` at every level reproduces ``search_dense``
    results exactly (the candidate set is then complete, and the rowwise
    kernel arithmetic matches the pairwise kernel element-for-element).

Both are jit-friendly over a query batch. Results are ``(dists[k], ids[k])``
sorted ascending; empty slots hold ``BIG`` / -1.

``search_beam_vmap`` preserves the pre-kernel-layer per-query scalar search
(a ``vmap`` of ``dist.point`` gathers). It exists as the benchmark baseline
for the batched path (``benchmarks/bench_search.py --mode beam``) and as an
independent semantic oracle in the tests.

``descend_beam`` exposes the beam descent (levels L..1) without the leaf
ranking — stage 0 of the tiered-store two-stage search
(``repro.store.two_stage``, DESIGN.md §3.6), which replaces the fused fp32
leaf rank with a quantised payload scan + exact out-of-core rerank.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import distances as dist_lib
from repro.core.distances import BIG
from repro.core.msa import PDASCIndexData
from repro.kernels import ops as kops
from repro.kernels import ref as kref

Array = jax.Array


class SearchResult(NamedTuple):
    dists: Array  # f32[..., k] ascending; BIG for missing
    ids: Array  # int32[..., k] original dataset rows; -1 for missing
    n_candidates: Array  # int32[...] leaf candidates examined (pruning metric)


def _per_level_radii(r, n_levels: int) -> tuple:
    """Broadcast a scalar radius to per-level radii, indexed by level —
    ``radii[0]`` applies at the leaf, ``radii[-1]`` at the top. A sequence
    enables the paper's future-work dynamic radius."""
    if isinstance(r, (list, tuple)):
        if len(r) != n_levels:
            raise ValueError(f"need {n_levels} radii, got {len(r)}")
        return tuple(r)
    return tuple([r] * n_levels)


# ---------------------------------------------------------------------------
# Dense-masked (faithful) mode
# ---------------------------------------------------------------------------


def _search_dense_batch(
    index: PDASCIndexData,
    dist: dist_lib.Distance,
    Q: Array,  # [B, d]
    k: int,
    radii: tuple,
    leaf_radius_filter: bool,
    kernel: kops.KernelConfig,
    with_stats: bool = True,
    slot_valid: Optional[Array] = None,
) -> SearchResult:
    """Batched masked NSA: per level one [B, n_l] distance matrix.

    Every level is one ``ops.pairwise_distance`` dispatch: Gram-form
    distances (l2/cosine/dot) become a single MXU matmul per level, the
    broadcast forms stream ``row_chunk`` column slabs — never the [B, n, d]
    broadcast cube (the Pallas ``pairwise`` kernel implements the identical
    tiling on real TPU).
    """
    levels = index.levels
    L = len(levels) - 1

    def pw(pts):
        return kops.pairwise_distance(Q, pts, dist, config=kernel)

    top = levels[L]
    D = pw(top.points)  # [B, n_L]
    active = top.valid[None, :] & (D < radii[L])

    for l in range(L - 1, 0, -1):
        lv = levels[l]
        D = pw(lv.points)
        up_n = levels[l + 1].points.shape[0]
        parent_ok = jnp.where(
            (lv.parent >= 0)[None, :],
            jnp.take(active, jnp.clip(lv.parent, 0, up_n - 1), axis=1),
            False,
        )
        active = parent_ok & lv.valid[None, :] & (D < radii[l])

    leaf = levels[0]
    D = pw(leaf.points)  # [B, n_0]
    up_n = levels[1].points.shape[0] if L >= 1 else 1
    if L >= 1:
        parent_ok = jnp.where(
            (leaf.parent >= 0)[None, :],
            jnp.take(active, jnp.clip(leaf.parent, 0, up_n - 1), axis=1),
            False,
        )
        cand = parent_ok & leaf.valid[None, :]
    else:
        cand = jnp.broadcast_to(leaf.valid[None, :], D.shape)
    if slot_valid is not None:  # tombstone mask: deleted leaf slots drop out
        cand = cand & slot_valid[None, :]
    if leaf_radius_filter:
        cand = cand & (D < radii[0])

    d_masked = jnp.where(cand, D, BIG)
    dists, slots = jax.lax.top_k(-d_masked, k)
    dists = -dists
    ids = jnp.where(dists < BIG / 2, jnp.take(index.leaf_ids, slots), -1)
    n_cand = (jnp.sum(cand, axis=1, dtype=jnp.int32) if with_stats
              else jnp.zeros((D.shape[0],), jnp.int32))
    return SearchResult(dists=dists, ids=ids, n_candidates=n_cand)


@functools.partial(
    jax.jit,
    static_argnames=(
        "dist", "k", "r", "leaf_radius_filter", "with_stats", "kernel",
    ),
)
def search_dense(
    index: PDASCIndexData,
    Q: Array,
    *,
    dist: dist_lib.Distance,
    k: int = 10,
    r,
    leaf_radius_filter: bool = False,
    with_stats: bool = True,
    kernel: Optional[kops.KernelConfig] = None,
    slot_valid: Optional[Array] = None,
) -> SearchResult:
    """Batched faithful NSA. ``Q``: [B, d] (or [d]).

    ``with_stats=False`` skips the candidate-count reduction (one full
    [B, n] pass) — the serving configuration. ``kernel`` carries the
    kernel-layer block knobs (None = defaults). ``slot_valid`` is the online
    substrate's tombstone mask over leaf slots (True = live, DESIGN.md
    §3.7): deleted slots never become candidates; the navigation levels are
    untouched (prototypes are copies, not results).
    """
    radii = _per_level_radii(r, len(index.levels))
    squeeze = Q.ndim == 1
    Qb = Q[None, :] if squeeze else Q
    res = _search_dense_batch(
        index, dist, Qb, k=k, radii=radii,
        leaf_radius_filter=leaf_radius_filter,
        kernel=kernel or kops.DEFAULT, with_stats=with_stats,
        slot_valid=slot_valid,
    )
    if squeeze:
        res = jax.tree.map(lambda a: a[0], res)
    return res


# ---------------------------------------------------------------------------
# Batched beam mode (the kernel-layer hot path)
# ---------------------------------------------------------------------------


def _descend_beam(
    index: PDASCIndexData,
    dist: dist_lib.Distance,
    Q: Array,  # [B, d]
    radii: tuple,
    beams: tuple,
    max_children: tuple,
    kernel: kops.KernelConfig,
) -> tuple[Array, Array]:
    """Levels L..1 of the batched beam search: per level one gather + one
    fused rank. Returns the leaf candidate table ``(cand_idx [B, W],
    cand_ok [B, W])`` — the input of the leaf ranking stage, whichever
    payload tier performs it (the fused fp32 rank of :func:`search_beam`, or
    the quantised scan -> exact rerank of ``repro.store.two_stage``).

    The radius filter is applied *after* the beam selection: candidates
    sort ascending by distance, so every in-radius candidate outranks every
    out-of-radius one and post-filtering selects the identical beam — but
    the select itself stays one fused kernel call. Requires a multi-level
    index (callers special-case L == 0, where every valid leaf slot is a
    candidate).
    """
    levels = index.levels
    L = len(levels) - 1
    B = Q.shape[0]

    # Every top-level prototype is a candidate for every query, so the top
    # ranking is one cross pairwise_distance call (no per-query gather —
    # replicating the level B times would cost [B, n_top, d] for what is a
    # shared candidate set) followed by one top-k.
    top = levels[L]
    n_top = top.points.shape[0]
    D_top = kops.pairwise_distance(Q, top.points, dist, config=kernel)
    D_top = jnp.where(top.valid[None, :], D_top, BIG)
    cand_idx = None  # top-level slots are their own indices
    cand_ok = None

    for l in range(L, 0, -1):
        lv = levels[l]
        if l == L:
            beam = min(beams[l], n_top)
            neg, slot = jax.lax.top_k(-D_top, beam)
            d_sel, sel_idx = -neg, slot.astype(jnp.int32)
        else:
            W = cand_idx.shape[1]
            beam = min(beams[l], W)
            d_sel, slot = kops.rank_gathered(  # [B, beam] fused rank
                Q, lv.points, lv.sq_norm, cand_idx, cand_ok, dist, k=beam,
                config=kernel,
            )
            sel_idx = jnp.take_along_axis(cand_idx, slot, axis=1)
        sel_ok = (d_sel < radii[l]) & (d_sel < BIG / 2)

        starts = jnp.take(lv.child_start, sel_idx)  # [B, beam]
        counts = jnp.take(lv.child_count, sel_idx)
        mc = max_children[l]
        grid = starts[:, :, None] + jnp.arange(mc, dtype=jnp.int32)[None, None, :]
        gvalid = (
            jnp.arange(mc)[None, None, :] < counts[:, :, None]
        ) & sel_ok[:, :, None]
        n_lower = levels[l - 1].points.shape[0]
        cand_idx = jnp.clip(grid.reshape(B, beam * mc), 0, n_lower - 1)
        cand_ok = gvalid.reshape(B, beam * mc)
    return cand_idx, cand_ok


@functools.partial(
    jax.jit,
    static_argnames=("dist", "r", "beam", "max_children", "kernel"),
)
def descend_beam(
    index: PDASCIndexData,
    Q: Array,  # [B, d]
    *,
    dist: dist_lib.Distance,
    r,
    beam,
    max_children: tuple,
    kernel: Optional[kops.KernelConfig] = None,
) -> tuple[Array, Array]:
    """Public jitted beam descent: NSA levels L..1 without the leaf ranking.

    Returns ``(cand_idx [B, W], cand_ok [B, W])`` — the leaf candidate rows
    each query would rank. This is stage 0 of the two-stage tiered-store
    search (DESIGN.md §3.6); ``search_beam`` is exactly this followed by one
    fused fp32 leaf rank.
    """
    n_levels = len(index.levels)
    radii = _per_level_radii(r, n_levels)
    beams = tuple(int(b) for b in _per_level_radii(beam, n_levels))
    if n_levels == 1:  # degenerate: every valid leaf slot is a candidate
        n0 = index.levels[0].points.shape[0]
        B = Q.shape[0]
        cand_idx = jnp.broadcast_to(
            jnp.arange(n0, dtype=jnp.int32)[None, :], (B, n0)
        )
        cand_ok = jnp.broadcast_to(index.levels[0].valid[None, :], (B, n0))
        return cand_idx, cand_ok
    return _descend_beam(
        index, dist, Q, radii, beams, tuple(max_children),
        kernel or kops.DEFAULT,
    )


def assemble_result(
    index: PDASCIndexData,
    dists: Array,  # [B, k_eff] ascending leaf-rank output
    slots: Array,  # [B, k_eff] leaf slot indices
    ok: Array,  # [B, W] candidates examined (the pruning metric)
    *,
    k: int,
    leaf_radius: float,
    leaf_radius_filter: bool,
) -> SearchResult:
    """Shared result-assembly tail of every leaf-ranking mode (fused beam
    rank and the tiered-store rerank): radius masking, slot -> dataset-row
    id translation, candidate counting, and padding out to ``k`` when the
    candidate pool was smaller."""
    if leaf_radius_filter:
        dists = jnp.where(dists < leaf_radius, dists, BIG)
    ids = jnp.where(dists < BIG / 2, jnp.take(index.leaf_ids, slots), -1)
    n_cand = jnp.sum(ok, axis=1, dtype=jnp.int32)
    k_eff = dists.shape[1]
    if k_eff < k:  # tiny index edge case: fewer candidate slots than k
        pad = k - k_eff
        dists = jnp.pad(dists, ((0, 0), (0, pad)), constant_values=BIG)
        ids = jnp.pad(ids, ((0, 0), (0, pad)), constant_values=-1)
    return SearchResult(dists=dists, ids=ids, n_candidates=n_cand)


def _search_beam_batch(
    index: PDASCIndexData,
    dist: dist_lib.Distance,
    Q: Array,  # [B, d]
    k: int,
    radii: tuple,
    beams: tuple,
    max_children: tuple,
    leaf_radius_filter: bool,
    kernel: kops.KernelConfig,
    slot_valid: Optional[Array] = None,
) -> SearchResult:
    """Whole-batch beam search: the descent (``_descend_beam``) followed by
    one fused fp32 leaf ranking. ``slot_valid`` (tombstones) masks leaf
    slots out of the ranking only — the descent stays frozen."""
    levels = index.levels
    L = len(levels) - 1
    B = Q.shape[0]

    leaf = levels[0]
    if L == 0:  # degenerate single-level index: the leaf is the top
        W = leaf.points.shape[0]
        D_top = kops.pairwise_distance(Q, leaf.points, dist, config=kernel)
        live = (leaf.valid if slot_valid is None
                else leaf.valid & slot_valid)
        D_top = jnp.where(live[None, :], D_top, BIG)
        ok = jnp.broadcast_to(live[None, :], (B, W))
        k_eff = min(k, W)
        neg, slot = jax.lax.top_k(-D_top, k_eff)
        dists, slots = -neg, slot.astype(jnp.int32)
    else:
        cand_idx, cand_ok = _descend_beam(
            index, dist, Q, radii, beams, max_children, kernel
        )
        W = cand_idx.shape[1]
        ok = kref.fold_slot_valid(cand_idx, cand_ok, slot_valid)
        k_eff = min(k, W)
        dists, slot = kops.rank_gathered(  # fused leaf ranking
            Q, leaf.points, leaf.sq_norm, cand_idx, ok, dist, k=k_eff,
            config=kernel,
        )
        slots = jnp.take_along_axis(cand_idx, slot, axis=1)
    # Candidates counted are those *examined* (the pruning metric). The fused
    # kernel never materialises the full leaf distance vector, so with
    # leaf_radius_filter this counts examined rather than in-radius candidates.
    return assemble_result(
        index, dists, slots, ok, k=k, leaf_radius=radii[0],
        leaf_radius_filter=leaf_radius_filter,
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "dist", "k", "r", "beam", "max_children", "leaf_radius_filter",
        "kernel",
    ),
)
def search_beam(
    index: PDASCIndexData,
    Q: Array,
    *,
    dist: dist_lib.Distance,
    k: int = 10,
    r,
    beam,
    max_children: tuple,
    leaf_radius_filter: bool = False,
    kernel: Optional[kops.KernelConfig] = None,
    slot_valid: Optional[Array] = None,
) -> SearchResult:
    """Batched beam NSA — the serving hot path.

    Args:
      beam: int or per-level tuple — surviving prototypes per level.
      max_children: static per-level max cluster size
        (:func:`repro.core.msa.max_children`).
      kernel: kernel-layer block knobs (None = defaults).
      slot_valid: optional bool[n_0] tombstone mask over leaf slots (True =
        live, DESIGN.md §3.7). Deleted slots rank as ``BIG`` at the leaf
        step; the beam descent over the (frozen) navigation tier is
        unchanged.
    """
    n_levels = len(index.levels)
    radii = _per_level_radii(r, n_levels)
    beams = _per_level_radii(beam, n_levels)
    beams = tuple(int(b) for b in beams)
    squeeze = Q.ndim == 1
    Qb = Q[None, :] if squeeze else Q
    res = _search_beam_batch(
        index,
        dist,
        Qb,
        k=k,
        radii=radii,
        beams=beams,
        max_children=tuple(max_children),
        leaf_radius_filter=leaf_radius_filter,
        kernel=kernel or kops.DEFAULT,
        slot_valid=slot_valid,
    )
    if squeeze:
        res = jax.tree.map(lambda a: a[0], res)
    return res


# ---------------------------------------------------------------------------
# Legacy per-query beam (seed baseline; kept for benchmarks and as an oracle)
# ---------------------------------------------------------------------------


def _search_beam_single(
    index: PDASCIndexData,
    dist: dist_lib.Distance,
    q: Array,
    k: int,
    radii: tuple,
    beams: tuple,
    max_children: tuple,
    leaf_radius_filter: bool,
) -> SearchResult:
    levels = index.levels
    L = len(levels) - 1

    # Start with every top-level prototype as a candidate.
    n_top = levels[L].points.shape[0]
    cand_idx = jnp.arange(n_top, dtype=jnp.int32)
    cand_ok = levels[L].valid

    for l in range(L, 0, -1):
        lv = levels[l]
        pts = jnp.take(lv.points, cand_idx, axis=0)
        d = dist.point(q[None, :], pts)
        ok = cand_ok & (d < radii[l])
        d_masked = jnp.where(ok, d, BIG)

        beam = min(beams[l], cand_idx.shape[0])
        neg, sel = jax.lax.top_k(-d_masked, beam)
        sel_idx = jnp.take(cand_idx, sel)
        sel_ok = -neg < BIG / 2

        starts = jnp.take(lv.child_start, sel_idx)
        counts = jnp.take(lv.child_count, sel_idx)
        mc = max_children[l]
        grid = starts[:, None] + jnp.arange(mc, dtype=jnp.int32)[None, :]
        gvalid = (jnp.arange(mc)[None, :] < counts[:, None]) & sel_ok[:, None]
        n_lower = levels[l - 1].points.shape[0]
        cand_idx = jnp.clip(grid.reshape(-1), 0, n_lower - 1)
        cand_ok = gvalid.reshape(-1)

    leaf = levels[0]
    pts = jnp.take(leaf.points, cand_idx, axis=0)
    d = dist.point(q[None, :], pts)
    ok = cand_ok
    if leaf_radius_filter:
        ok = ok & (d < radii[0])
    d_masked = jnp.where(ok, d, BIG)

    dists, slot_pos = jax.lax.top_k(-d_masked, min(k, d_masked.shape[0]))
    dists = -dists
    slots = jnp.take(cand_idx, slot_pos)
    ids = jnp.where(dists < BIG / 2, jnp.take(index.leaf_ids, slots), -1)
    if dists.shape[0] < k:  # tiny index edge case
        pad = k - dists.shape[0]
        dists = jnp.pad(dists, (0, pad), constant_values=BIG)
        ids = jnp.pad(ids, (0, pad), constant_values=-1)
    return SearchResult(
        dists=dists, ids=ids, n_candidates=jnp.sum(ok, dtype=jnp.int32)
    )


@functools.partial(
    jax.jit,
    static_argnames=("dist", "k", "r", "beam", "max_children", "leaf_radius_filter"),
)
def search_beam_vmap(
    index: PDASCIndexData,
    Q: Array,
    *,
    dist: dist_lib.Distance,
    k: int = 10,
    r,
    beam,
    max_children: tuple,
    leaf_radius_filter: bool = False,
) -> SearchResult:
    """The seed per-query beam NSA (vmap of scalar ``dist.point`` searches).

    Superseded by :func:`search_beam`; retained as the benchmark baseline
    and as an independent oracle for the batched path's tests.
    """
    n_levels = len(index.levels)
    radii = _per_level_radii(r, n_levels)
    beams = _per_level_radii(beam, n_levels)
    beams = tuple(int(b) for b in beams)
    single = functools.partial(
        _search_beam_single,
        index,
        dist,
        k=k,
        radii=radii,
        beams=beams,
        max_children=tuple(max_children),
        leaf_radius_filter=leaf_radius_filter,
    )
    if Q.ndim == 1:
        return single(q=Q)
    return jax.vmap(lambda q: single(q=q))(Q)
