"""Distributed PDASC: sharded build, sharded search, global top-k merge.

The paper's deployment model (§3.1): the dataset is randomly partitioned
across computational nodes; each node clusters its own groups; a query fans
out to the nodes and the per-node results are combined. On a TPU mesh this
maps to (DESIGN.md §3.4):

* **build**  — ``shard_map`` over the database axes: every device runs MSA on
  its local shard and owns an independent sub-index (exactly the paper's
  "groups distributed across nodes" — a PDASC index *is* a forest of
  per-partition trees; stacking sub-indexes adds one more implicit level).
* **search** — queries are replicated across the database axes (each device
  answers against its shard), then the per-device top-k are merged globally.
* **storage** — with a tiered leaf store (DESIGN.md §3.6) the navigation
  tier replicates while the quantised payload shards by leaf-row range:
  ``shard_payload`` slices codes + scales per node and
  ``scan_quantized_sharded`` runs the stage-1 scan locally, merging
  survivors with the same top-k collectives.

Top-k merge operators (the collective hot path):

``topk_merge_allgather``
    one ``all_gather`` of ``[B, k]`` pairs -> every device selects from
    ``P*k`` candidates. Bytes received per device: ``(P-1) * B * k * 8``.

``topk_merge_butterfly``
    recursive-halving butterfly: ``log2(P)`` ``ppermute`` rounds, each
    exchanging exactly ``B * k`` pairs with the round's partner and merging.
    Bytes received per device: ``log2(P) * B * k * 8`` — an ``(P-1)/log2(P)``x
    reduction (e.g. 51x at P=256). This is the beyond-paper collective
    optimisation benchmarked in EXPERIMENTS.md §Perf.

Hierarchical meshes merge axis-by-axis (fast intra-pod axis first, then the
slow ``pod`` axis), so inter-pod traffic is a single butterfly at ``B * k``
pairs per hop.
"""

from __future__ import annotations

import functools
import math
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import distances as dist_lib
from repro.core import msa, nsa
from repro.kernels import ops as kops
from repro.kernels import ref as kref

Array = jax.Array

try:  # jax >= 0.6 top-level API
    from jax import shard_map as _shard_map

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
        )

except ImportError:  # pragma: no cover - older jax
    from jax.experimental.shard_map import shard_map as _shard_map_old

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map_old(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
        )


def axis_size(axis_name: str) -> int:
    """Static mesh-axis size from inside shard_map (jax < 0.6 compat:
    ``lax.axis_size`` does not exist there; ``psum(1, axis)`` is static).
    Public: the model layer's sharded retrieval uses it too."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


# ---------------------------------------------------------------------------
# Global top-k merge collectives
# ---------------------------------------------------------------------------


def topk_merge_allgather(dists: Array, ids: Array, axis_name: str, k: int):
    """Naive merge: all_gather every shard's [B, k] then select."""
    gd = jax.lax.all_gather(dists, axis_name, axis=0)  # [P, B, k]
    gi = jax.lax.all_gather(ids, axis_name, axis=0)
    Pn = gd.shape[0]
    gd = jnp.moveaxis(gd, 0, -2).reshape(*dists.shape[:-1], Pn * k)
    gi = jnp.moveaxis(gi, 0, -2).reshape(*ids.shape[:-1], Pn * k)
    neg, idx = jax.lax.top_k(-gd, k)
    return -neg, jnp.take_along_axis(gi, idx, axis=-1)


def topk_merge_butterfly(dists: Array, ids: Array, axis_name: str, k: int):
    """Butterfly (recursive-doubling) merge: log2(P) ppermute rounds.

    After round t every device holds the top-k over its 2^(t+1)-device
    sub-cube; after log2(P) rounds all devices hold the global top-k
    (replicated). Requires a power-of-two axis size.
    """
    Pn = axis_size(axis_name)
    if Pn & (Pn - 1):
        raise ValueError(f"butterfly merge needs power-of-two axis, got {Pn}")
    rounds = int(math.log2(Pn))
    for t in range(rounds):
        perm = [(i, i ^ (1 << t)) for i in range(Pn)]
        od = jax.lax.ppermute(dists, axis_name, perm)
        oi = jax.lax.ppermute(ids, axis_name, perm)
        cd = jnp.concatenate([dists, od], axis=-1)
        ci = jnp.concatenate([ids, oi], axis=-1)
        neg, idx = jax.lax.top_k(-cd, k)
        dists = -neg
        ids = jnp.take_along_axis(ci, idx, axis=-1)
    return dists, ids


def topk_merge(dists, ids, axis_names: Sequence[str], k: int, *, method="butterfly"):
    """Merge across several mesh axes, fastest axis first."""
    fn = topk_merge_butterfly if method == "butterfly" else topk_merge_allgather
    for ax in axis_names:
        dists, ids = fn(dists, ids, ax, k)
    return dists, ids


# ---------------------------------------------------------------------------
# Sharded MSA build
# ---------------------------------------------------------------------------


def _axes_size(mesh: Mesh, axes: Sequence[str]) -> int:
    out = 1
    for a in axes:
        out *= mesh.shape[a]
    return out


def _shard_index(axes: Sequence[str]):
    """Linear shard index across (possibly several) mesh axes."""
    idx = jnp.int32(0)
    for a in axes:
        idx = idx * axis_size(a) + jax.lax.axis_index(a)
    return idx


def build_sharded(
    data: Array,
    mesh: Mesh,
    *,
    db_axes: Sequence[str] = ("data",),
    gl: int,
    n_prototypes: Optional[int] = None,
    distance="euclidean",
    method: str = "pam",
    max_swaps: int = 64,
    key: Optional[Array] = None,
    row_chunk: int = 512,
    group_chunk: int = 8,
    swap_tol: float = 1e-3,
    bg: int = 128,
):
    """Build one PDASC sub-index per device shard.

    ``data``: [n, d] with ``n`` divisible by the product of ``db_axes`` sizes.
    Returns a stacked ``PDASCIndexData`` whose every leaf has a leading
    per-shard axis of size P (sharded over ``db_axes``). ``group_chunk``
    bounds each shard's clustering working set at O(group_chunk · gl²) —
    the per-node memory budget of the paper's deployment model.
    """
    Pn = _axes_size(mesh, db_axes)
    n, d = data.shape
    if n % Pn:
        raise ValueError(f"n={n} not divisible by shard count {Pn}")
    per = n // Pn
    key = key if key is not None else jax.random.PRNGKey(0)
    spec_in = P(tuple(db_axes), None, None)

    def _build_local(local, k_local):  # local: [1, per, d]
        index, _ = msa.build_index_arrays(
            local[0],
            gl=gl,
            n_prototypes=n_prototypes,
            distance=distance,
            method=method,
            max_swaps=max_swaps,
            key=k_local,
            row_chunk=row_chunk,
            group_chunk=group_chunk,
            swap_tol=swap_tol,
            bg=bg,
        )
        return jax.tree.map(lambda a: a[None], index)

    def body(local):
        shard = _shard_index(db_axes)
        return _build_local(local, jax.random.fold_in(key, shard))

    # out_specs: same tree as the body's output, every leaf sharded over the
    # database axes (evaluated without the axis_index, which needs the mesh).
    shape_tree = jax.eval_shape(
        functools.partial(_build_local, k_local=key),
        jax.ShapeDtypeStruct((1, per, d), jnp.float32),
    )
    out_spec = jax.tree.map(lambda _: P(tuple(db_axes)), shape_tree)
    fn = shard_map(body, mesh, in_specs=(spec_in,), out_specs=out_spec)
    return fn(data.reshape(Pn, per, d).astype(jnp.float32))


# ---------------------------------------------------------------------------
# Sharded NSA search
# ---------------------------------------------------------------------------


# Bounded: each entry pins its Mesh + compiled executable, and a long-lived
# process may cycle meshes/knobs — eviction merely costs the old per-call
# retrace for that config, never correctness.
@functools.lru_cache(maxsize=64)
def _sharded_search_fn(
    mesh: Mesh,
    db_axes: tuple,
    dist,
    k: int,
    r,
    mode: str,
    beam,
    max_children: Optional[tuple],
    merge: str,
    leaf_radius_filter: bool,
    with_stats: bool,
    kernel,
    has_mask: bool,
):
    """Build (once per static config) the jitted shard_map executor behind
    :func:`search_sharded`.

    The cache is what makes repeated sharded execution retrace-free: the
    pre-refactor code rebuilt the ``shard_map`` closure per call, so every
    search re-traced the whole per-shard program. Keyed on every static
    knob (all hashable — the same values the per-shard jits key on), the
    returned callable is one ``jax.jit`` whose own cache then keys on input
    shapes/dtypes only.
    """

    def body(index_stacked, Qr, *sv):
        index = jax.tree.map(lambda a: a[0], index_stacked)
        sv_local = sv[0][0] if sv else None
        shard = _shard_index(db_axes)
        if mode == "dense":
            res = nsa.search_dense(
                index, Qr, dist=dist, k=k, r=r,
                leaf_radius_filter=leaf_radius_filter, with_stats=with_stats,
                kernel=kernel, slot_valid=sv_local,
            )
        else:
            res = nsa.search_beam(
                index, Qr, dist=dist, k=k, r=r, beam=beam,
                max_children=max_children, leaf_radius_filter=leaf_radius_filter,
                kernel=kernel, slot_valid=sv_local,
            )
        # leaf_ids are local rows of this shard's slice; lift to global rows.
        # NOTE: the shard's local shuffle permutes only within the shard, so
        # global_row = shard * per_shard_n + local_row.
        per_shard_n = jnp.int32(index_stacked.leaf_ids.shape[1])
        gids = jnp.where(res.ids >= 0, res.ids + shard * per_shard_n, -1)
        d_m, i_m = topk_merge(res.dists, gids, tuple(db_axes), k, method=merge)
        nc = jax.lax.psum(res.n_candidates, tuple(db_axes))
        return nsa.SearchResult(dists=d_m, ids=i_m, n_candidates=nc)

    # Prefix specs: the index arg's single P broadcasts over its whole tree.
    in_specs = [P(db_axes), P()]  # sharded index, replicated queries
    if has_mask:
        in_specs.append(P(db_axes))  # mask sharded like the index
    out_specs = nsa.SearchResult(dists=P(), ids=P(), n_candidates=P())
    return jax.jit(
        shard_map(body, mesh, in_specs=tuple(in_specs), out_specs=out_specs)
    )


def search_sharded(
    sharded_index: msa.PDASCIndexData,
    Q: Array,
    mesh: Mesh,
    *,
    db_axes: Sequence[str] = ("data",),
    dist,
    k: int = 10,
    r,
    mode: str = "dense",
    beam: int = 32,
    max_children: Optional[tuple] = None,
    merge: str = "butterfly",
    leaf_radius_filter: bool = False,
    with_stats: bool = True,
    kernel: Optional[kops.KernelConfig] = None,
    slot_valid: Optional[Array] = None,
) -> nsa.SearchResult:
    """Distributed NSA: per-shard search + global top-k merge.

    Queries are replicated over ``db_axes`` (every shard answers against its
    own sub-index); returned ids are *global* dataset rows (shard-offset
    applied). Output is replicated. ``kernel`` (block knobs) reaches the
    kernel layer through the per-shard search. ``slot_valid``: optional
    ``[P, n_leaf_local]`` tombstone mask, sharded like the index — each node
    masks its own deleted leaf slots before its local rank, so deleted ids
    never enter the merge (DESIGN.md §3.7; build per-shard masks from global
    ids with :func:`route_writes` + :func:`local_slot_valid`).

    This is the execution substrate of the query layer's sharded pipeline
    (``repro.query.compile_sharded_plan``); the executor is compiled once
    per static configuration (:func:`_sharded_search_fn`), so repeated
    calls — and repeated sharded-plan executions — never retrace.
    """
    dist = dist_lib.get(dist)

    def _freeze(v):
        return tuple(v) if isinstance(v, (list, tuple)) else v

    fn = _sharded_search_fn(
        mesh, tuple(db_axes), dist, k, _freeze(r), mode, _freeze(beam),
        tuple(max_children) if max_children is not None else None, merge,
        leaf_radius_filter, with_stats, kernel, slot_valid is not None,
    )
    args = [sharded_index, jnp.asarray(Q)]
    if slot_valid is not None:
        args.append(jnp.asarray(slot_valid))
    # keep the caller's dtype: bf16 queries + bf16 index points -> bf16
    # distance math (the §Perf H3 memory-halving path)
    return fn(*args)


# ---------------------------------------------------------------------------
# Sharded payload tier (tiered leaf store, DESIGN.md §3.6)
# ---------------------------------------------------------------------------


def shard_payload(store, mesh: Mesh, *, db_axes: Sequence[str] = ("data",)):
    """Split a quantised payload tier across the database axes.

    The storage-aware deployment keeps the *navigation* tier (prototype
    levels) replicated on every node — it is small and every query walks it
    — while the payload codes shard by leaf-row range: node ``p`` owns rows
    ``[p*per, (p+1)*per)`` and the matching slice of the per-block scales.
    Returns ``(codes [P, per, d], scales [P, nb_per])`` ready for
    ``shard_map`` over ``db_axes`` (:func:`scan_quantized_sharded`).
    """
    if store.backend == "fp32" or store.codes is None:
        raise ValueError(
            "shard_payload needs a quantised store (int8/fp16/int4/binary)"
        )
    Pn = _axes_size(mesh, db_axes)
    n, d = store.codes.shape
    if n % Pn:
        raise ValueError(f"payload rows n={n} not divisible by shards {Pn}")
    per = n // Pn
    if per % store.block:
        raise ValueError(
            f"per-shard rows {per} not granule-aligned (block={store.block}); "
            f"scales cannot shard cleanly"
        )
    nb_per = per // store.block
    return (
        store.codes.reshape(Pn, per, d),
        store.scales.reshape(Pn, nb_per),
    )


def payload_placement(n: int, block: int, n_shards: int) -> list:
    """Granule co-placement map for a remote exact tier (DESIGN.md §3.13).

    The same row-range ownership :func:`shard_payload` gives the resident
    codes, expressed in *granule* coordinates: node ``p`` owns rows
    ``[p*per, (p+1)*per)`` and therefore granules
    ``[p*per//block, (p+1)*per//block)`` of the remote payload. Because
    granules never straddle shard boundaries (``per % block == 0``,
    enforced here exactly as in :func:`shard_payload`, and the streaming
    build aligns shard flushes the same way), a node's exact-rerank
    fetches only ever touch its own granule range — co-placement with the
    code shard, no cross-node payload traffic.

    Returns ``[dict(shard=p, rows=(lo, hi), granules=(g_lo, g_hi)), ...]``
    — half-open ranges. Use a node's ``granules`` range to warm its host
    LRU (``RemoteSource.prefetch_async(range(g_lo, g_hi))``) at placement
    time.
    """
    if n % n_shards:
        raise ValueError(f"payload rows n={n} not divisible by "
                         f"shards {n_shards}")
    per = n // n_shards
    if per % block:
        raise ValueError(
            f"per-shard rows {per} not granule-aligned (block={block}); "
            f"granules would straddle shard boundaries"
        )
    g_per = per // block
    return [
        dict(shard=p, rows=(p * per, (p + 1) * per),
             granules=(p * g_per, (p + 1) * g_per))
        for p in range(n_shards)
    ]


def scan_quantized_sharded(
    codes: Array,  # [P, per, d] from shard_payload
    scales: Array,  # [P, nb_per]
    Q: Array,  # [B, d] replicated queries
    cand_idx: Array,  # [B, W] *global* leaf rows (the replicated descent)
    cand_ok: Array,  # [B, W]
    mesh: Mesh,
    *,
    db_axes: Sequence[str] = ("data",),
    distance="l2",
    k: int,
    block: int,
    merge: str = "butterfly",
    kernel: Optional[kops.KernelConfig] = None,
    slot_valid: Optional[Array] = None,
    code_format: str = "dense",
):
    """Distributed stage-1 scan: each node scans the candidates it owns.

    The navigation descent is replicated (every node computes the same
    ``cand_idx``); each shard masks the candidate table to its own row
    range, scans its local codes, and the per-shard top-k merge with the
    same collectives as the search path. Returns ``(dists [B, k],
    slots [B, k])`` replicated, ``slots`` being *global* leaf rows (-1 for
    missing) — the input of the exact rerank fetch. ``slot_valid``:
    optional ``[P, per]`` tombstone mask sharded with the codes — each node
    drops its own deleted rows before the scan. ``code_format``: the store's
    packed-code layout (``"dense"`` | ``"int4"`` | ``"binary"``,
    ``LeafStore.code_format``) — shards carry packed containers and unpack
    per-tile exactly like the local scan.
    """
    kernel = kernel or kops.DEFAULT
    per = codes.shape[1]

    def body(codes_l, scales_l, Qr, ci, ok, *sv):
        shard = _shard_index(db_axes)
        lo = shard * jnp.int32(per)
        local_ok = ok & (ci >= lo) & (ci < lo + per)
        ci_local = jnp.clip(ci - lo, 0, per - 1)
        d, slot = kops.scan_quantized(
            Qr, codes_l[0], scales_l[0], ci_local, local_ok, distance,
            k=k, block=block, slot_valid=sv[0][0] if sv else None,
            code_format=code_format, config=kernel,
        )
        gslots = jnp.take_along_axis(ci, slot, axis=1)
        gslots = jnp.where(d < kref.BIG / 2, gslots, -1)
        return topk_merge(d, gslots, tuple(db_axes), k, method=merge)

    in_specs = [P(tuple(db_axes)), P(tuple(db_axes)), P(), P(), P()]
    args = [codes, scales, jnp.asarray(Q, jnp.float32), cand_idx, cand_ok]
    if slot_valid is not None:
        in_specs.append(P(tuple(db_axes)))
        args.append(jnp.asarray(slot_valid))
    fn = shard_map(
        body,
        mesh,
        in_specs=tuple(in_specs),
        out_specs=(P(), P()),
    )
    return fn(*args)


# ---------------------------------------------------------------------------
# Shard-by-id write routing (online substrate, DESIGN.md §3.7)
# ---------------------------------------------------------------------------


def route_writes(ids, n_shards: int, per_shard_n: int):
    """Route global dataset rows to the shard that owns them.

    The sharded deployment assigns row ranges: shard ``p`` owns global rows
    ``[p*per_shard_n, (p+1)*per_shard_n)`` — the same mapping
    :func:`search_sharded` uses to lift local ids to global ones, so writes
    (upserts / deletes by id) land on the node whose sub-index and payload
    slice hold the row. Returns ``[(shard, local_rows int64[m_p]), ...]``
    for the shards that receive at least one write (host-side: write routing
    is control plane, not a collective).
    """
    ids = np.asarray(ids, np.int64).reshape(-1)
    if ids.size and (ids.min() < 0 or ids.max() >= n_shards * per_shard_n):
        raise ValueError(
            f"write ids out of range [0, {n_shards * per_shard_n}) for "
            f"{n_shards} shards x {per_shard_n} rows"
        )
    shard = ids // per_shard_n
    return [
        (int(s), ids[shard == s] - int(s) * per_shard_n)
        for s in range(n_shards)
        if bool(np.any(shard == s))
    ]


def local_slot_valid(leaf_ids_local, deleted_local_rows):
    """Per-shard tombstone mask from locally-routed deleted rows.

    ``leaf_ids_local``: int32[n_0] — the shard's leaf-slot -> local-row map
    (one row of the stacked ``sharded_index.leaf_ids``).
    ``deleted_local_rows``: the shard's entry from :func:`route_writes`.
    Returns bool[n_0] (True = live) for ``search_sharded(slot_valid=...)``.
    """
    leaf_ids_local = np.asarray(leaf_ids_local)
    dead = np.zeros(int(leaf_ids_local.max(initial=0)) + 1, bool)
    rows = np.asarray(deleted_local_rows, np.int64)
    dead[rows[rows <= leaf_ids_local.max(initial=0)]] = True
    ok = ~dead[np.clip(leaf_ids_local, 0, dead.shape[0] - 1)]
    return ok | (leaf_ids_local < 0)  # padding slots stay "live" (invalid anyway)


# ---------------------------------------------------------------------------
# Distributed exact k-NN (ground truth / retrieval_cand scoring)
# ---------------------------------------------------------------------------


def exact_knn_sharded(
    DB: Array,
    Q: Array,
    mesh: Mesh,
    *,
    db_axes: Sequence[str] = ("data",),
    distance="l2",
    k: int = 10,
    merge: str = "butterfly",
):
    """Brute-force distributed k-NN: shard the database, replicate queries,
    per-shard fused distance+top-k, global merge. The exact baseline every
    recall number is measured against, and the ``retrieval_cand`` scorer."""
    form = distance if distance in kref.FORMS else None
    dist = None if form else dist_lib.get(distance)
    Pn = _axes_size(mesh, db_axes)
    n, d = DB.shape
    if n % Pn:
        raise ValueError(f"n={n} not divisible by {Pn}")
    per = n // Pn

    def body(db_local, Qr):
        db = db_local[0]
        shard = _shard_index(db_axes)
        if form is not None:
            D = kref.pairwise_ref(Qr, db, form)
        else:
            D = dist.pairwise(Qr, db)
        neg, idx = jax.lax.top_k(-D, k)
        gids = idx.astype(jnp.int32) + shard * jnp.int32(per)
        return topk_merge(-neg, gids, tuple(db_axes), k, method=merge)

    fn = shard_map(
        body,
        mesh,
        in_specs=(P(tuple(db_axes), None, None), P()),
        out_specs=(P(), P()),
    )
    return fn(DB.reshape(Pn, per, d), jnp.asarray(Q, jnp.float32))
