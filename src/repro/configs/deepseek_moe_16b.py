"""deepseek-moe-16b [moe] — 28L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=102400, MoE 64e top-6, 2 shared + 64 routed (fine-grained experts).
[arXiv:2401.06066; hf]
"""

from repro.configs.base import ArchDef, LM_SHAPES, register_arch
from repro.models.transformer import MoEConfig, TransformerConfig

ID = "deepseek-moe-16b"


def config() -> TransformerConfig:
    return TransformerConfig(
        name=ID,
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1408,
        vocab=102400,
        moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2),
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=96,
        vocab=512,
        seq_chunk=32,
        kv_chunk=32,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=96, n_shared=2,
                      capacity_factor=2.0),
    )


register_arch(ArchDef(
    id=ID, family="lm", config_fn=config, smoke_fn=smoke_config,
    shapes=LM_SHAPES, source="arXiv:2401.06066; hf",
))
