"""Arch/shape registry: every assigned architecture is a config module that
registers an :class:`ArchDef`; the launcher resolves ``--arch <id>`` here.

A *cell* is one (architecture x input-shape) pair; ``all_cells()`` enumerates
the full dry-run/roofline matrix. Shape kinds:

  train     — train_step: fwd + bwd + AdamW update
  prefill   — inference prefill: fwd, emits KV cache + last logits
  decode    — serve_step: one token against a KV cache of ``seq_len``
  serve     — batched forward-only scoring (recsys)
  retrieval — one query against n_candidates (distributed top-k)
  build     — PDASC MSA sharded build step
  search    — PDASC NSA sharded query step

Shape dims follow the assignment verbatim; tensors that must shard evenly
over the 512-way mesh carry a ``*_padded`` companion (padding is masked, see
DESIGN.md §6 — the configs keep the exact published numbers).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

_MESH_LCM = 512  # pad shardable dims to multiples of the full device count


def pad_to(n: int, m: int = _MESH_LCM) -> int:
    return -(-n // m) * m


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str
    dims: dict
    note: str = ""


@dataclasses.dataclass(frozen=True)
class ArchDef:
    id: str
    family: str  # "lm" | "gnn" | "recsys" | "pdasc"
    config_fn: Callable[[], Any]  # full-size model config
    smoke_fn: Callable[[], Any]  # reduced config for CPU smoke tests
    shapes: dict
    source: str = ""
    notes: str = ""


_REGISTRY: dict[str, ArchDef] = {}


def register_arch(a: ArchDef) -> ArchDef:
    if a.id in _REGISTRY:
        raise ValueError(f"arch {a.id!r} already registered")
    _REGISTRY[a.id] = a
    return a


def get_arch(arch_id: str) -> ArchDef:
    _ensure_loaded()
    try:
        return _REGISTRY[arch_id]
    except KeyError:
        raise KeyError(
            f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}"
        ) from None


def arch_ids() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def all_cells(include_pdasc: bool = True) -> list[tuple[str, str]]:
    """Every (arch, shape) pair in the dry-run matrix."""
    _ensure_loaded()
    out = []
    for aid in sorted(_REGISTRY):
        a = _REGISTRY[aid]
        if a.family == "pdasc" and not include_pdasc:
            continue
        for s in a.shapes:
            out.append((aid, s))
    return out


_LOADED = False


def _ensure_loaded():
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    from repro.configs import (  # noqa: F401
        autoint,
        deepseek_moe_16b,
        din,
        egnn,
        granite_3_2b,
        minitron_8b,
        pdasc,
        qwen3_moe_235b,
        stablelm_1_6b,
        wide_deep,
        xdeepfm,
    )


# ---------------------------------------------------------------------------
# Shared shape sets (assignment: one set per family)
# ---------------------------------------------------------------------------

LM_SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train",
                          dict(seq_len=4096, global_batch=256)),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill",
                             dict(seq_len=32768, global_batch=32)),
    "decode_32k": ShapeSpec("decode_32k", "decode",
                            dict(seq_len=32768, global_batch=128)),
    "long_500k": ShapeSpec(
        "long_500k", "decode", dict(seq_len=524288, global_batch=1),
        note="decode against a 524288-token KV cache is O(S), not O(S^2); "
             "run with fully sharded sequence (DESIGN.md §4 long_500k note)",
    ),
}

RECSYS_SHAPES = {
    "train_batch": ShapeSpec("train_batch", "train", dict(batch=65536)),
    "serve_p99": ShapeSpec("serve_p99", "serve", dict(batch=512)),
    "serve_bulk": ShapeSpec("serve_bulk", "serve", dict(batch=262144)),
    "retrieval_cand": ShapeSpec(
        "retrieval_cand", "retrieval",
        dict(batch=1, n_candidates=1_000_000,
             n_candidates_padded=pad_to(1_000_000)),
        note="padded candidate rows are masked out of the top-k",
    ),
}

GNN_SHAPES = {
    "full_graph_sm": ShapeSpec(
        "full_graph_sm", "train",
        dict(n_nodes=2708, n_edges=10556, d_feat=1433,
             n_edges_padded=pad_to(10556)),
    ),
    "minibatch_lg": ShapeSpec(
        "minibatch_lg", "train",
        dict(n_nodes=232_965, n_edges=114_615_892, batch_nodes=1024,
             fanouts=(15, 10), n_subgraphs=32),
        note="32 sampled subgraphs per step (one per DP shard); static "
             "budget from (batch_nodes, fanouts)",
    ),
    "ogb_products": ShapeSpec(
        "ogb_products", "train",
        dict(n_nodes=2_449_029, n_edges=61_859_140, d_feat=100,
             n_edges_padded=pad_to(61_859_140)),
    ),
    "molecule": ShapeSpec(
        "molecule", "train",
        dict(n_nodes=30, n_edges=64, batch=128),
    ),
}
