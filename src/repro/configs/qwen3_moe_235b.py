"""qwen3-moe-235b-a22b [moe] — 94L d_model=4096 64H (GQA kv=4) d_ff=1536
vocab=151936, MoE 128e top-8 (128 experts, top-8, no shared).
[hf:Qwen/Qwen3-30B-A3B; hf]
"""

from repro.configs.base import ArchDef, LM_SHAPES, register_arch
from repro.models.transformer import MoEConfig, TransformerConfig

ID = "qwen3-moe-235b-a22b"


def config() -> TransformerConfig:
    return TransformerConfig(
        name=ID,
        n_layers=94,
        d_model=4096,
        n_heads=64,
        n_kv_heads=4,
        d_ff=1536,
        vocab=151936,
        moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=1536, n_shared=0),
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_ff=96,
        vocab=512,
        seq_chunk=32,
        kv_chunk=32,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=48, n_shared=0,
                      capacity_factor=2.0),
    )


register_arch(ArchDef(
    id=ID, family="lm", config_fn=config, smoke_fn=smoke_config,
    shapes=LM_SHAPES, source="hf:Qwen/Qwen3-30B-A3B; hf",
))
