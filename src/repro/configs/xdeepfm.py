"""xdeepfm [recsys] — n_sparse=39 embed_dim=10 cin_layers=200-200-200
mlp=400-400 interaction=cin. [arXiv:1803.05170; paper]
"""

from repro.configs.base import ArchDef, RECSYS_SHAPES, register_arch
from repro.models.recsys import RecsysConfig

ID = "xdeepfm"


def config() -> RecsysConfig:
    return RecsysConfig(
        name=ID, kind="xdeepfm", n_sparse=39, embed_dim=10,
        cin_layers=(200, 200, 200), mlp=(400, 400), n_dense=13,
        table_rows=1_000_000,
    )


def smoke_config() -> RecsysConfig:
    return RecsysConfig(
        name=ID + "-smoke", kind="xdeepfm", n_sparse=6, embed_dim=6,
        cin_layers=(12, 12), mlp=(24, 24), n_dense=4, table_rows=128,
    )


register_arch(ArchDef(
    id=ID, family="recsys", config_fn=config, smoke_fn=smoke_config,
    shapes=RECSYS_SHAPES, source="arXiv:1803.05170; paper",
))
