"""pdasc [paper] — the paper's own architecture: the distributed multilevel
ANN index itself, as dry-run cells.

  build_1m   — sharded MSA: every device builds its sub-index over its slice
               of a 2^20 x 100 database (GLOVE-scale, the paper's largest).
  search_1m  — sharded NSA: 4096 queries fan out, per-device dense search,
               butterfly top-k merge (k=10, the paper's 10-NN protocol).
"""

import dataclasses

from repro.configs.base import ArchDef, ShapeSpec, register_arch
from repro.kernels.ops import KernelConfig

_KD = KernelConfig()  # single source of the block-knob defaults


@dataclasses.dataclass(frozen=True)
class PDASCArchConfig:
    name: str = "pdasc"
    n: int = 1 << 20  # database size (padded power of two: shards evenly)
    d: int = 100  # GLOVE dimensionality
    gl: int = 1024  # group length (paper Table 2 uses 1000; padded to 2^10)
    distance: str = "euclidean"
    method: str = "pam"
    k: int = 10  # neighbours (paper protocol: 10-NN)
    n_queries: int = 4096
    radius: float = 13.0  # paper Table 2, GLOVE euclidean
    # Kernel-layer block knobs (DESIGN.md §3.3/§3.5): pairwise grid tiles
    # (bm x bn x bd), fused rank/knn query tile (bq), swap-sweep row tile
    # (bg), CPU streaming chunk, and the build's group-chunk streaming slab.
    bm: int = _KD.bm
    bn: int = _KD.bn
    bd: int = _KD.bd
    bq: int = _KD.bq
    bg: int = _KD.bg
    row_chunk: int = _KD.row_chunk
    group_chunk: int = _KD.group_chunk
    # auto=True resolves knobs left at their defaults from the persisted
    # block-size tuner cache (kernels/autotune.py); explicitly set fields
    # (and explicit per-call knobs) always win over tuned winners.
    auto: bool = _KD.auto
    # Build-algorithm knob (not a block size, so not in KernelConfig): the
    # eager-swap per-sweep relative improvement cutoff (0 = full convergence).
    swap_tol: float = 1e-3
    # Storage substrate (DESIGN.md §3.6): payload-tier backend ("fp32" keeps
    # the dense resident seed path; "int8"/"fp16" quantise the leaf vectors),
    # granule size (quantisation block == out-of-core fetch unit) and the
    # two-stage search's exact-rerank width (0 = ∞, the validation mode).
    store: str = "int8"
    store_block: int = 1024
    rerank_width: int = 128
    # Remote payload tier (DESIGN.md §3.13): host-LRU capacity (decoded
    # granules), the async prefetch pool's worker count and queue depth
    # (None = max(8, cache//2)), and the simulated object store's
    # performance envelope for local experiments (per-op latency, transfer
    # bandwidth, concurrent-op cap).
    remote_cache_granules: int = 256
    remote_prefetch_workers: int = 2
    remote_prefetch_depth: int = None
    remote_latency_ms: float = 0.0
    remote_bandwidth_mbps: float = None
    remote_parallelism: int = 8
    # Online substrate (DESIGN.md §3.7): delta-buffer capacity for live
    # upserts, and the epoch-swap compaction triggers — compact when the
    # delta append cursor passes ``compact_delta_fill`` of capacity or the
    # tombstone count passes ``compact_tombstone_ratio`` of the residents.
    delta_capacity: int = 4096
    compact_delta_fill: float = 0.5
    compact_tombstone_ratio: float = 0.2
    # Replicated serving tier (DESIGN.md §3.10): replica count and the
    # router's fault-tolerance knobs — per-request deadline, bounded
    # retries, p99 hedging, admission limit with the graceful-degradation
    # watermark, and the health-check ejection/probe schedule.
    n_replicas: int = 2
    router_deadline_s: float = 1.0
    router_max_retries: int = 2
    router_hedge: bool = True
    router_queue_limit: int = 256
    router_degrade_at: float = 0.75
    router_eject_failures: int = 3
    router_probe_cooldown_s: float = 0.2
    # Telemetry (DESIGN.md §3.11): trace 1 request in N through the router
    # (deterministic by request seq; 0 = off).
    router_trace_every: int = 0
    # Quality & SLO observability (DESIGN.md §3.12): shadow-sample 1 served
    # request in N for online recall estimation (0 = off), plus the serve
    # SLO — p99 latency target, recall floor, availability target and the
    # rolling window the burn alerts evaluate over. None disables an
    # objective.
    router_shadow_every: int = 0
    slo_latency_p99_s: float = None
    slo_recall_floor: float = None
    slo_availability: float = 0.999
    slo_window_s: float = 60.0

    def kernel_config(self) -> KernelConfig:
        # Built field-wise from KernelConfig's own field list so a knob added
        # to KernelConfig (mirrored here as a same-named config field) can
        # never silently fall out of the arch config's kernel threading —
        # tests/test_configs.py asserts the mirror stays complete.
        mirrored = {
            f: getattr(self, f)
            for f in KernelConfig._fields
            if hasattr(self, f)
        }
        return KernelConfig()._replace(**mirrored)

    def search_query(self, **overrides):
        """The arch's search protocol as a declarative ``repro.query.Query``
        (k / radius / rerank width / kernel knobs from this config;
        ``overrides`` pick the execution preference, beam schedule, ...).
        The launch cells and serving drivers plan from this."""
        from repro.query import Query

        base = dict(k=self.k, radius=self.radius,
                    rerank_width=self.rerank_width,
                    kernel=self.kernel_config())
        base.update(overrides)
        return Query(**base)

    def router_config(self, **overrides):
        """The arch's router knobs as a ``repro.serving.RouterConfig`` (the
        replicated tier's dispatch/retry/hedge/health policy)."""
        from repro.serving.router import RouterConfig

        base = dict(
            deadline_s=self.router_deadline_s,
            max_retries=self.router_max_retries,
            hedge=self.router_hedge,
            queue_limit=self.router_queue_limit,
            degrade_at=self.router_degrade_at,
            eject_failures=self.router_eject_failures,
            probe_cooldown_s=self.router_probe_cooldown_s,
            trace_every=self.router_trace_every,
            shadow_every=self.router_shadow_every,
        )
        base.update(overrides)
        return RouterConfig(**base)

    def slo_spec(self, **overrides):
        """The arch's serve SLO as a ``repro.obs.SLOSpec`` (pass the
        resulting ``obs.SLOTracker`` to ``Router(..., slo=...)``)."""
        from repro.obs.slo import SLOSpec

        base = dict(
            latency_p99_s=self.slo_latency_p99_s,
            recall_floor=self.slo_recall_floor,
            availability=self.slo_availability,
            window_s=self.slo_window_s,
        )
        base.update(overrides)
        return SLOSpec(**base)


def config() -> PDASCArchConfig:
    return PDASCArchConfig()


def smoke_config() -> PDASCArchConfig:
    return PDASCArchConfig(name="pdasc-smoke", n=512, d=8, gl=32,
                           n_queries=16, radius=2.0, bm=32, bn=32, bd=32,
                           store_block=64, rerank_width=32,
                           delta_capacity=128)


SHAPES = {
    "build_1m": ShapeSpec("build_1m", "build", dict(n=1 << 20, d=100)),
    "search_1m": ShapeSpec("search_1m", "search",
                           dict(n=1 << 20, d=100, n_queries=4096, k=10)),
}

register_arch(ArchDef(
    id="pdasc", family="pdasc", config_fn=config, smoke_fn=smoke_config,
    shapes=SHAPES, source="the paper",
))
