"""wide-deep [recsys] — n_sparse=40 embed_dim=32 mlp=1024-512-256
interaction=concat. [arXiv:1606.07792; paper]
"""

from repro.configs.base import ArchDef, RECSYS_SHAPES, register_arch
from repro.models.recsys import RecsysConfig

ID = "wide-deep"


def config() -> RecsysConfig:
    return RecsysConfig(
        name=ID, kind="wide_deep", n_sparse=40, embed_dim=32,
        mlp=(1024, 512, 256), n_dense=13, table_rows=1_000_000,
    )


def smoke_config() -> RecsysConfig:
    return RecsysConfig(
        name=ID + "-smoke", kind="wide_deep", n_sparse=6, embed_dim=8,
        mlp=(32, 16), n_dense=4, table_rows=128,
    )


register_arch(ArchDef(
    id=ID, family="recsys", config_fn=config, smoke_fn=smoke_config,
    shapes=RECSYS_SHAPES, source="arXiv:1606.07792; paper",
))
