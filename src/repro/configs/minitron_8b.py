"""minitron-8b [dense] — 32L d_model=4096 32H (GQA kv=8) d_ff=16384
vocab=256000 (pruned nemotron). [arXiv:2407.14679; hf]
"""

from repro.configs.base import ArchDef, LM_SHAPES, register_arch
from repro.models.transformer import TransformerConfig

ID = "minitron-8b"


def config() -> TransformerConfig:
    return TransformerConfig(
        name=ID,
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=16384,
        vocab=256000,
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_ff=128,
        vocab=512,
        seq_chunk=32,
        kv_chunk=32,
    )


register_arch(ArchDef(
    id=ID, family="lm", config_fn=config, smoke_fn=smoke_config,
    shapes=LM_SHAPES, source="arXiv:2407.14679; hf",
))
