"""autoint [recsys] — n_sparse=39 embed_dim=16 n_attn_layers=3 n_heads=2
d_attn=32 interaction=self-attn. [arXiv:1810.11921; paper]
"""

from repro.configs.base import ArchDef, RECSYS_SHAPES, register_arch
from repro.models.recsys import RecsysConfig

ID = "autoint"


def config() -> RecsysConfig:
    return RecsysConfig(
        name=ID, kind="autoint", n_sparse=39, embed_dim=16,
        n_attn_layers=3, n_attn_heads=2, d_attn=32, mlp=(), n_dense=0,
        table_rows=1_000_000,
    )


def smoke_config() -> RecsysConfig:
    return RecsysConfig(
        name=ID + "-smoke", kind="autoint", n_sparse=6, embed_dim=8,
        n_attn_layers=2, n_attn_heads=2, d_attn=4, mlp=(), n_dense=0,
        table_rows=128,
    )


register_arch(ArchDef(
    id=ID, family="recsys", config_fn=config, smoke_fn=smoke_config,
    shapes=RECSYS_SHAPES, source="arXiv:1810.11921; paper",
))
