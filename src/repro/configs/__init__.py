"""Architecture configs (one module per assigned arch + the paper's own).

Resolve with ``repro.configs.get_arch("<id>")``; list with ``arch_ids()``;
enumerate the dry-run matrix with ``all_cells()``.
"""

from repro.configs.base import (
    ArchDef,
    ShapeSpec,
    all_cells,
    arch_ids,
    get_arch,
    register_arch,
)

__all__ = [
    "ArchDef",
    "ShapeSpec",
    "all_cells",
    "arch_ids",
    "get_arch",
    "register_arch",
]
