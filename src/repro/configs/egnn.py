"""egnn [gnn] — n_layers=4 d_hidden=64 equivariance=E(n).
[arXiv:2102.09844; paper]

Shape-specific feature dims come from the shape (full_graph_sm d=1433,
ogb_products d=100, minibatch_lg/molecule use defaults); the launcher
specialises ``d_feat``/``n_classes``/``task`` per cell via
``specialise(shape)``.
"""

import dataclasses

from repro.configs.base import ArchDef, GNN_SHAPES, register_arch
from repro.models.gnn import EGNNConfig

ID = "egnn"


def config() -> EGNNConfig:
    return EGNNConfig(name=ID, n_layers=4, d_hidden=64, d_feat=128,
                      n_classes=47)


def specialise(cfg: EGNNConfig, shape_name: str) -> EGNNConfig:
    """Bind the per-shape feature dims / task."""
    if shape_name == "full_graph_sm":
        return dataclasses.replace(cfg, d_feat=1433, n_classes=7)
    if shape_name == "minibatch_lg":
        return dataclasses.replace(cfg, d_feat=602, n_classes=41)  # reddit-like
    if shape_name == "ogb_products":
        return dataclasses.replace(cfg, d_feat=100, n_classes=47)
    if shape_name == "molecule":
        return dataclasses.replace(cfg, d_feat=16, task="graph_reg")
    return cfg


def smoke_config() -> EGNNConfig:
    return EGNNConfig(name=ID + "-smoke", n_layers=2, d_hidden=16, d_feat=12,
                      n_classes=5)


register_arch(ArchDef(
    id=ID, family="gnn", config_fn=config, smoke_fn=smoke_config,
    shapes=GNN_SHAPES, source="arXiv:2102.09844; paper",
    notes="irrep regime: E(n) relative-vector messages (no tensor products; "
          "EGNN's O(n) trick replaces the O(L^6) irrep path)",
))
