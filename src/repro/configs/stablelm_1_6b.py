"""stablelm-1.6b [dense] — 24L d_model=2048 32H (GQA kv=32, i.e. MHA)
d_ff=5632 vocab=100352. [hf:stabilityai/stablelm-2-1_6b; unverified]
"""

from repro.configs.base import ArchDef, LM_SHAPES, register_arch
from repro.models.transformer import TransformerConfig

ID = "stablelm-1.6b"


def config() -> TransformerConfig:
    return TransformerConfig(
        name=ID,
        n_layers=24,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=5632,
        vocab=100352,
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=512,
        seq_chunk=32,
        kv_chunk=32,
    )


register_arch(ArchDef(
    id=ID, family="lm", config_fn=config, smoke_fn=smoke_config,
    shapes=LM_SHAPES, source="hf:stabilityai/stablelm-2-1_6b; unverified",
))
