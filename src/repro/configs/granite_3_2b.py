"""granite-3-2b [dense] — 40L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=49155. [hf:ibm-granite/granite-3.0-2b-base; hf]

vocab 49155 is not TP-divisible; the embedding/lm_head are padded to 49408
(masked in the loss — TransformerConfig.vocab_padded).
"""

from repro.configs.base import ArchDef, LM_SHAPES, register_arch
from repro.models.transformer import TransformerConfig

ID = "granite-3-2b"


def config() -> TransformerConfig:
    return TransformerConfig(
        name=ID,
        n_layers=40,
        d_model=2048,
        n_heads=32,
        n_kv_heads=8,
        d_ff=8192,
        vocab=49155,
    )


def smoke_config() -> TransformerConfig:
    return TransformerConfig(
        name=ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_ff=128,
        vocab=515,  # deliberately non-divisible, like the real 49155
        seq_chunk=32,
        kv_chunk=32,
    )


register_arch(ArchDef(
    id=ID, family="lm", config_fn=config, smoke_fn=smoke_config,
    shapes=LM_SHAPES, source="hf:ibm-granite/granite-3.0-2b-base; hf",
))
